"""Synthetic deterministic data pipeline (stateless, resumable, sharded).

Batches are pure functions of (seed, step): a fixed random bigram chain over
the vocab gives the stream learnable structure (a model that learns the
chain drops from ln(V) to the chain entropy), which the end-to-end training
example uses to demonstrate real learning.  Stateless indexing is what makes
checkpoint/restart and elastic resharding trivial: to resume at step k on
any mesh, just ask for batch k with the new sharding.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["SyntheticLM", "TokenBatch"]


@dataclasses.dataclass(frozen=True)
class TokenBatch:
    tokens: jax.Array      # (B, S) int32
    targets: jax.Array     # (B, S) int32 (next-token)


@dataclasses.dataclass(frozen=True)
class SyntheticLM:
    """Bigram-chain token stream.

    branching: number of likely successors per token (entropy ~= ln(branching)).
    """

    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    branching: int = 8

    def _table(self) -> np.ndarray:
        rng = np.random.default_rng(self.seed)
        return rng.integers(0, self.vocab_size,
                            size=(self.vocab_size, self.branching),
                            dtype=np.int32)

    @property
    def table(self) -> jax.Array:
        if not hasattr(self, "_cached"):
            object.__setattr__(self, "_cached", jnp.asarray(self._table()))
        return self._cached

    def batch_at(self, step: int) -> TokenBatch:
        """Deterministic batch for a global step (host-side generation)."""
        key = jax.random.fold_in(jax.random.PRNGKey(self.seed), step)
        k1, k2 = jax.random.split(key)
        B, S = self.global_batch, self.seq_len
        first = jax.random.randint(k1, (B,), 0, self.vocab_size, jnp.int32)
        choices = jax.random.randint(k2, (B, S), 0, self.branching,
                                     jnp.int32)
        table = self.table

        def step_fn(tok, choice):
            nxt = table[tok, choice]
            return nxt, nxt

        _, seq = jax.lax.scan(step_fn, first, choices.T)
        seq = seq.T                                   # (B, S)
        full = jnp.concatenate([first[:, None], seq], axis=1)  # (B, S+1)
        return TokenBatch(tokens=full[:, :-1], targets=full[:, 1:])
