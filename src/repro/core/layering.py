"""Layered resolution via digit decomposition (paper §III, Definition 1).

Fixed-point operands are decomposed in base ``2**d`` into ``m`` digit-plane
chunks.  For matrices ``A = sum_i A_i 2**(i d)`` and ``B = sum_j B_j 2**(j d)``

    A^T B = sum_{i,j} A_i^T B_j 2**((i+j) d)

and grouping the ``m**2`` *mini-jobs* ``(i, j)`` by anti-diagonal
``s = i + j`` (MSB-first, i.e. largest ``s`` first) yields ``L = 2m - 1``
resolution layers.  The ``l``-th resolution (Definition 1) is the partial sum
over ``(2m-2) - l <= i + j <= 2m-2``.  Upgrading resolution ``l-1 -> l`` costs
``J(l) = min(l+1, 2m-1-l)`` extra mini-jobs and ``sum_l J(l) = m**2``:
layering adds zero total compute.

Signed integers are supported exactly: the *top* chunk is an arithmetic
right-shift (so it carries the sign) while lower chunks are unsigned
``d``-bit digits.  Reconstruction is exact for any int32/int64 input that
fits in ``m * d`` bits.
"""

from __future__ import annotations

import functools
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "num_layers",
    "layer_minijobs",
    "minijobs_per_layer",
    "cumulative_minijobs",
    "all_minijobs_msb_first",
    "decompose",
    "reconstruct",
    "quantize",
    "dequantize",
    "layered_matmul_reference",
    "resolution_error_bound",
]


# ---------------------------------------------------------------------------
# Layer bookkeeping (Definition 1)
# ---------------------------------------------------------------------------

def num_layers(m: int) -> int:
    """L = 2m - 1 resolution layers for an m-chunk decomposition."""
    if m < 1:
        raise ValueError(f"m must be >= 1, got {m}")
    return 2 * m - 1


def layer_minijobs(m: int, l: int) -> list[tuple[int, int]]:
    """Mini-jobs (i, j) that layer ``l`` adds: ``i + j = (2m-2) - l``.

    Layer 0 is the single MSB*MSB product (i = j = m-1); the final layer
    ``L-1`` is the LSB*LSB product (i = j = 0).
    """
    L = num_layers(m)
    if not 0 <= l < L:
        raise ValueError(f"layer {l} out of range for m={m} (L={L})")
    s = (2 * m - 2) - l
    return [(i, s - i) for i in range(m) if 0 <= s - i < m]


def minijobs_per_layer(m: int) -> list[int]:
    """J(l) = min(l+1, 2m-1-l); J over all layers sums to m**2."""
    return [min(l + 1, 2 * m - 1 - l) for l in range(num_layers(m))]


def cumulative_minijobs(m: int) -> list[int]:
    """Number of mini-jobs needed for resolution l: sum_{i<=l} J(i)."""
    out, tot = [], 0
    for j in minijobs_per_layer(m):
        tot += j
        out.append(tot)
    return out


def all_minijobs_msb_first(m: int) -> list[tuple[int, int, int]]:
    """All (layer, i, j) triples in execution order (MSB-first)."""
    out = []
    for l in range(num_layers(m)):
        for (i, j) in layer_minijobs(m, l):
            out.append((l, i, j))
    return out


# ---------------------------------------------------------------------------
# Digit decomposition / reconstruction
# ---------------------------------------------------------------------------

def decompose(x: jax.Array, m: int, d: int) -> jax.Array:
    """Decompose integer array into m digit-plane chunks, base 2**d.

    Returns an array of shape ``(m,) + x.shape``; ``chunks[i]`` holds digit
    ``i`` (LSB at i=0).  Chunks ``0..m-2`` are unsigned d-bit digits; chunk
    ``m-1`` is the arithmetic-shift remainder and carries the sign, so

        x == sum_i chunks[i] * 2**(i*d)            (exactly)

    for any signed x representable in the accumulator dtype.
    """
    if m < 1 or d < 1:
        raise ValueError(f"need m >= 1 and d >= 1, got m={m} d={d}")
    if not jnp.issubdtype(x.dtype, jnp.integer):
        raise TypeError(f"decompose expects an integer array, got {x.dtype}")
    x = x.astype(jnp.int32) if x.dtype.itemsize <= 4 else x
    mask = (1 << d) - 1
    chunks = []
    for i in range(m):
        shifted = jnp.right_shift(x, i * d)  # arithmetic shift on signed ints
        if i == m - 1:
            chunks.append(shifted)  # top chunk keeps sign + any overflow bits
        else:
            chunks.append(jnp.bitwise_and(shifted, mask))
    return jnp.stack(chunks, axis=0)


def reconstruct(chunks: jax.Array, d: int) -> jax.Array:
    """Inverse of :func:`decompose`: ``sum_i chunks[i] * 2**(i*d)``."""
    m = chunks.shape[0]
    weights = jnp.asarray(
        [1 << (i * d) for i in range(m)], dtype=chunks.dtype
    ).reshape((m,) + (1,) * (chunks.ndim - 1))
    return jnp.sum(chunks * weights, axis=0)


# ---------------------------------------------------------------------------
# Fixed-point quantization (float <-> int) so real matrices can be layered
# ---------------------------------------------------------------------------

def quantize(x: jax.Array, total_bits: int) -> tuple[jax.Array, jax.Array]:
    """Symmetric per-tensor quantization of a float array to signed ints.

    Returns ``(q, scale)`` with ``x ~= q * scale`` and
    ``q in [-(2**(b-1)-1), 2**(b-1)-1]``.
    """
    absmax = jnp.maximum(jnp.max(jnp.abs(x)), 1e-30)
    qmax = float(2 ** (total_bits - 1) - 1)
    scale = absmax / qmax
    q = jnp.clip(jnp.round(x / scale), -qmax, qmax)
    dtype = jnp.int32 if total_bits <= 31 else jnp.int64
    return q.astype(dtype), scale.astype(jnp.float32)


def dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


# ---------------------------------------------------------------------------
# Reference layered matmul (the oracle every other implementation matches)
# ---------------------------------------------------------------------------

def _np_decompose(x: np.ndarray, m: int, d: int) -> np.ndarray:
    """NumPy twin of :func:`decompose` (int64 host arithmetic, always exact)."""
    x = np.asarray(x, dtype=np.int64)
    mask = (1 << d) - 1
    chunks = []
    for i in range(m):
        shifted = x >> (i * d)
        chunks.append(shifted if i == m - 1 else shifted & mask)
    return np.stack(chunks, axis=0)


def layered_matmul_reference(a, b, *, m: int, d: int) -> np.ndarray:
    """Exact layered computation of ``a.T @ b`` for integer a (K, M), b (K, N).

    Returns ``resolutions`` of shape (L, M, N): ``resolutions[l]`` is the
    l-th resolution per Definition 1 (cumulative over anti-diagonals
    ``s >= 2m-2-l``, scaled by ``2**(s d)``).  ``resolutions[-1] == a.T @ b``
    exactly.

    Host-side NumPy (int64) so exactness never depends on jax_enable_x64;
    this is the oracle that the Pallas kernel and the jnp device path are
    tested against.
    """
    a = np.asarray(a)
    b = np.asarray(b)
    ca = _np_decompose(a, m, d)  # (m, K, M)
    cb = _np_decompose(b, m, d)  # (m, K, N)
    L = num_layers(m)
    partials = []
    for l in range(L):
        acc = np.zeros((a.shape[1], b.shape[1]), dtype=np.int64)
        for (i, j) in layer_minijobs(m, l):
            prod = ca[i].T.astype(np.int64) @ cb[j].astype(np.int64)
            acc = acc + prod * (1 << ((i + j) * d))
        partials.append(acc)
    return np.cumsum(np.stack(partials, axis=0), axis=0)


@functools.partial(jax.jit, static_argnames=("m", "d"))
def layered_matmul_jnp(a: jax.Array, b: jax.Array, *, m: int, d: int):
    """Device-side layered matmul returning float32 resolutions (L, M, N).

    Per-plane products accumulate in int32 (exact for
    ``K * (2**d - 1)**2 < 2**31``, e.g. d=8 and K <= 32768); the cross-plane
    combination ``* 2**((i+j)d)`` is float32, exact for results < 2**24 per
    plane-scale and the standard device path for layered serving.
    """
    ca = decompose(a.astype(jnp.int32), m, d)
    cb = decompose(b.astype(jnp.int32), m, d)
    L = num_layers(m)
    partials = []
    for l in range(L):
        acc = jnp.zeros((a.shape[1], b.shape[1]), dtype=jnp.float32)
        for (i, j) in layer_minijobs(m, l):
            prod = jax.lax.dot(ca[i].T, cb[j],
                               preferred_element_type=jnp.int32)
            acc = acc + prod.astype(jnp.float32) * float(1 << ((i + j) * d))
        partials.append(acc)
    return jnp.cumsum(jnp.stack(partials, axis=0), axis=0)


def resolution_error_bound(m: int, d: int, K: int, l: int) -> int:
    """Worst-case |A^T B - (A^T B)|_l| for unsigned d-bit digits.

    The missing mini-jobs are all (i, j) with i+j < (2m-2)-l; each missing
    term is bounded by K * (2**d - 1)**2 * 2**((i+j) d).
    """
    bound = 0
    for s in range(0, (2 * m - 2) - l):
        count = min(s + 1, 2 * m - 1 - s)
        bound += count * K * (2**d - 1) ** 2 * (1 << (s * d))
    return bound
