"""Executable layered + coded matmul pipeline, and coded data-parallelism.

Three levels, mirroring DESIGN.md §3:

1. :class:`LayeredCodedMatmul` — the paper end-to-end: quantize operands,
   digit-decompose (``repro.core.layering``), iterate mini-jobs MSB-first,
   polynomial-encode each mini-job (``repro.core.coding``), compute the coded
   tasks, *erase* a configurable subset (stragglers), decode from the ``k``
   survivors, and accumulate resolutions.  This is the reference system the
   simulator models in time and the quickstart example runs.

2. :func:`distributed_layered_matmul` — a `shard_map` execution of the coded
   tasks across a device mesh axis: each device computes its slice of the
   codeword batch; the fusion is a gather + host decode.  Lowerable on the
   production mesh (exercised by the dry-run).

3. :class:`GradientCoder` — MDS-coded data parallelism across pods: each pod
   contributes a linear combination of gradient shards; any ``k`` of ``n``
   pod codewords decode the full-batch gradient (pod loss = erasure).  The
   decode weights for a surviving subset collapse to a single per-pod scalar,
   so recovery is one weighted `psum`.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.compat import shard_map
from repro.core import coding, layering

__all__ = [
    "LayeredCodedMatmul", "distributed_layered_matmul", "GradientCoder",
]


@dataclasses.dataclass(frozen=True)
class LayeredCodedMatmul:
    """Layered-resolution coded matmul of ``a.T @ b`` (paper §III).

    Args:
      m, d:      digit decomposition (m chunks of d bits each).
      n1, n2:    polynomial-code block split; recovery threshold k = n1*n2.
      omega:     redundancy ratio (>= 1).
      mode:      "float" (Chebyshev/float64 decode) or "gfp" (bit-exact).
      total_bits: fixed-point quantization width for float inputs (= m*d
                 keeps the decomposition exhaustive).
    """

    m: int = 2
    d: int = 8
    n1: int = 2
    n2: int = 2
    omega: float = 1.25
    mode: str = "float"

    @property
    def total_bits(self) -> int:
        return self.m * self.d

    @property
    def code(self) -> coding.PolynomialCode:
        return coding.PolynomialCode(n1=self.n1, n2=self.n2, omega=self.omega,
                                     mode=self.mode)

    @property
    def num_layers(self) -> int:
        return layering.num_layers(self.m)

    def quantize_operands(self, a: jax.Array, b: jax.Array):
        """Float matrices -> (int chunks, scales).  Ints pass through."""
        if jnp.issubdtype(a.dtype, jnp.floating):
            qa, sa = layering.quantize(a, self.total_bits)
        else:
            qa, sa = a, jnp.float32(1.0)
        if jnp.issubdtype(b.dtype, jnp.floating):
            qb, sb = layering.quantize(b, self.total_bits)
        else:
            qb, sb = b, jnp.float32(1.0)
        return qa, qb, sa * sb

    def run(self, a: jax.Array, b: jax.Array, *,
            erasures: Sequence[int] = (), seed: int | None = None):
        """Run the full pipeline; returns (resolutions, exact, out_scale).

        ``resolutions`` is float64 ndarray (L, M, N) of Definition-1 partial
        results (already scaled back by the quantization scales);
        ``erasures`` are coded-task indices that never return (stragglers);
        if ``seed`` is given, a random (num_tasks - k)-subset is erased.
        """
        qa, qb, scale = self.quantize_operands(a, b)
        code = self.code
        if seed is not None:
            rng = np.random.default_rng(seed)
            n_erase = code.num_tasks - code.k
            erasures = rng.choice(code.num_tasks, size=n_erase, replace=False)
        erased = set(int(e) for e in erasures)
        if code.num_tasks - len(erased) < code.k:
            raise ValueError("too many erasures: fewer than k survivors")
        survivors = [t for t in range(code.num_tasks) if t not in erased]

        # offset so chunks are non-negative for the gfp path
        if self.mode == "gfp":
            qa = np.asarray(qa).astype(np.int64) + (1 << (self.total_bits - 1))
            qb = np.asarray(qb).astype(np.int64) + (1 << (self.total_bits - 1))
            ca = layering._np_decompose(qa, self.m, self.d)
            cb = layering._np_decompose(qb, self.m, self.d)
        else:
            ca = np.asarray(layering.decompose(jnp.asarray(np.asarray(qa),
                                                           jnp.int32),
                                               self.m, self.d))
            cb = np.asarray(layering.decompose(jnp.asarray(np.asarray(qb),
                                                           jnp.int32),
                                               self.m, self.d))

        M, N = ca.shape[2], cb.shape[2]
        acc = np.zeros((M, N), dtype=np.float64)
        resolutions = []
        for l in range(self.num_layers):
            for (i, j) in layering.layer_minijobs(self.m, l):
                mini = self._coded_minijob(code, ca[i], cb[j], survivors)
                acc = acc + np.asarray(mini, np.float64) * float(
                    1 << ((i + j) * self.d))
            resolutions.append(acc.copy())
        resolutions = np.stack(resolutions, axis=0)
        if self.mode == "gfp":
            # undo the offset: (a+h)(b+h) = ab + h(a+b) + h^2 K applied at
            # full resolution only; partial layers keep the offset bias --
            # callers wanting exact partials should pass unsigned inputs.
            # qa/qb here are the OFFSET operands (qa_orig + h), so with
            # S_off = S_orig + h*K the bias h*S_a + h*S_b + h^2 K becomes
            # h*(S_off_a + S_off_b) - h^2 K.
            h = float(1 << (self.total_bits - 1))
            K = qa.shape[0]
            corr = (h * (qa.sum(0)[:, None] + qb.sum(0)[None, :])
                    - (h * h) * K)
            resolutions = resolutions - corr  # exact at l = L-1
        return resolutions * float(scale), scale

    def _coded_minijob(self, code, chunk_a, chunk_b, survivors):
        X, Y = code.encode(jnp.asarray(chunk_a, jnp.float32)
                           if self.mode == "float" else chunk_a.astype(np.uint64),
                           jnp.asarray(chunk_b, jnp.float32)
                           if self.mode == "float" else chunk_b.astype(np.uint64))
        results = code.compute_all_tasks(X, Y)
        ids = survivors[: code.k]
        return code.decode(ids, np.asarray(results)[np.asarray(ids)])


# ---------------------------------------------------------------------------
# shard_map distributed execution of the coded tasks
# ---------------------------------------------------------------------------

def distributed_layered_matmul(mesh: Mesh, axis: str, a: jax.Array,
                               b: jax.Array, *, m: int, d: int,
                               n1: int, n2: int, omega: float):
    """Compute coded task results for every mini-job, sharded over ``axis``.

    Encoding happens once (replicated); each device multiplies its slice of
    the codeword batch; results are all-gathered so any host can decode from
    the first k arrivals.  Returns (task_results, layer_index) where
    ``task_results`` has shape (m*m, T, M/n1, N/n2) laid out mini-job-major
    in MSB-first execution order.
    """
    code = coding.PolynomialCode(n1=n1, n2=n2, omega=omega, mode="float")
    T = code.num_tasks
    naxis = mesh.shape[axis]
    if T % naxis:
        # pad codeword count to the axis size; extra tasks are pure redundancy
        T = ((T // naxis) + 1) * naxis
        code = dataclasses.replace(code, omega=T / code.k)

    ca = layering.decompose(a.astype(jnp.int32), m, d).astype(jnp.float32)
    cb = layering.decompose(b.astype(jnp.int32), m, d).astype(jnp.float32)
    order = layering.all_minijobs_msb_first(m)

    Xs, Ys = [], []
    for (_, i, j) in order:
        X, Y = code.encode(ca[i], cb[j])
        Xs.append(X)
        Ys.append(Y)
    X = jnp.stack(Xs)  # (m*m, T, K, M/n1)
    Y = jnp.stack(Ys)  # (m*m, T, K, N/n2)

    def worker(x_blk, y_blk):
        # x_blk: (m*m, T/naxis, K, M/n1) local codeword slice
        local = jnp.einsum("qtkm,qtkn->qtmn", x_blk, y_blk)
        return jax.lax.all_gather(local, axis, axis=1, tiled=True)

    fn = shard_map(worker, mesh=mesh,
                       in_specs=(P(None, axis), P(None, axis)),
                       out_specs=P(None, None))
    return fn(X, Y), [l for (l, _, _) in order]


# ---------------------------------------------------------------------------
# MDS-coded data parallelism (pod-level erasure tolerance)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class GradientCoder:
    """Cyclic MDS gradient coding over ``n`` pods, tolerating ``n - k`` losses.

    Data is split into ``n`` shards; pod ``p`` computes gradients for shards
    ``p, p+1, ..., p+r-1 (mod n)`` where ``r = n - k + 1`` (the replication
    factor), and sends the combination ``c_p = sum_t G[p, (p+t) % n] g_{p+t}``.
    For any surviving set S (|S| >= k) there exist weights w_p with
    ``sum_{p in S} w_p c_p = sum_s g_s`` -- one weighted psum recovers the
    full-batch gradient.  Coefficients come from a Vandermonde structure so
    every k-subset is invertible (MDS).
    """

    n: int
    k: int

    def __post_init__(self):
        if not 1 <= self.k <= self.n:
            raise ValueError(f"need 1 <= k <= n, got k={self.k} n={self.n}")

    @property
    def replication(self) -> int:
        return self.n - self.k + 1

    @functools.cached_property
    def assignment(self) -> np.ndarray:
        """(n, r) shard ids handled by each pod (cyclic)."""
        r = self.replication
        return (np.arange(self.n)[:, None] + np.arange(r)[None, :]) % self.n

    @functools.cached_property
    def coefficients(self) -> np.ndarray:
        """(n, n) sparse combination matrix C: pod p sends sum_s C[p,s] g_s.

        Tandon et al. (gradient coding) Algorithm-2 construction: draw a
        random H in R^{s x n} (s = n - k stragglers) with H @ 1 = 0, then
        choose each row C[p] supported on ``assignment[p]`` with
        ``C[p, p] = 1`` and the rest solving ``H @ C[p]^T = 0``.  Every row
        lies in null(H), an (n-s)-dim subspace containing the ones vector;
        any n-s rows are (generically) a basis of it, so the ones vector is
        in their span — exactly the decodability condition.
        """
        n, s = self.n, self.n - self.k
        C = np.zeros((n, n))
        if s == 0:
            np.fill_diagonal(C, 1.0)
            return C
        rng = np.random.default_rng(2022)
        H = rng.normal(size=(s, n))
        H = H - H.mean(axis=1, keepdims=True)  # rows orthogonal to ones
        for p in range(n):
            sup = self.assignment[p]          # (s+1,) cyclic support
            rest = sup[1:]                    # solve for these s entries
            x = np.linalg.solve(H[:, rest], -H[:, sup[0]])
            C[p, sup[0]] = 1.0
            C[p, rest] = x
        return C

    def decode_weights(self, survivors: Sequence[int]) -> np.ndarray:
        """w such that ``w @ C[survivors] = ones`` (exists when |S| >= k).

        ``survivors`` order is preserved: ``w[i]`` weights ``survivors[i]``'s
        codeword.
        """
        S = [int(s) for s in survivors]
        if len(set(S)) != len(S):
            raise ValueError(f"duplicate survivor ids: {S}")
        if len(S) < self.k:
            raise ValueError(f"need >= {self.k} survivors, got {len(S)}")
        Cs = self.coefficients[np.asarray(S)]  # (|S|, n)
        w, _, _, _ = np.linalg.lstsq(Cs.T, np.ones(self.n), rcond=None)
        recon = Cs.T @ w
        if not np.allclose(recon, 1.0, atol=1e-6):
            raise RuntimeError(
                f"survivor set {S} is not decodable (residual "
                f"{np.abs(recon - 1).max():.2e}) -- non-MDS corner; "
                f"increase redundancy")
        return w

    def encode_local(self, pod_id: int, shard_grads: Sequence) -> object:
        """Combine pod ``pod_id``'s r shard-gradient pytrees into a codeword."""
        coeffs = self.coefficients[pod_id, self.assignment[pod_id]]
        def comb(*leaves):
            acc = leaves[0] * coeffs[0]
            for c, leaf in zip(coeffs[1:], leaves[1:]):
                acc = acc + c * leaf
            return acc
        return jax.tree.map(comb, *shard_grads)

    def decode(self, survivors: Sequence[int], codewords: Sequence) -> object:
        """Recover the sum of all shard gradients from surviving codewords.

        ``codewords[i]`` must be the codeword pytree sent by pod
        ``survivors[i]``.
        """
        w = self.decode_weights(survivors)
        def comb(*leaves):
            acc = leaves[0] * w[0]
            for wi, leaf in zip(w[1:], leaves[1:]):
                acc = acc + wi * leaf
            return acc
        return jax.tree.map(comb, *list(codewords))
