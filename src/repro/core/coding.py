"""Polynomial codes for distributed coded matrix multiplication.

Implements the scheme of Yu, Maddah-Ali & Avestimehr (NeurIPS'17), reviewed
in the paper's §II-A: split ``A`` into ``n1`` column blocks and ``B`` into
``n2`` column blocks, encode the i-th coded task's inputs as polynomial
evaluations

    X^i = sum_r A^r x_i^r          Y^i = sum_s B^s x_i^(s n1)

so that ``(X^i)^T Y^i = h(x_i)`` where ``h`` is a matrix polynomial of degree
``n1 n2 - 1`` whose coefficient ``(r, s)`` is ``(A^r)^T B^s``.  Any
``k = n1 n2`` of the ``num_tasks = ceil(k * omega)`` evaluations recover all
coefficients (MDS property), i.e. the full product ``A^T B``.

Two arithmetic modes:

* ``"float"``  — Chebyshev evaluation points on [-1, 1], decode by solving the
  k x k Vandermonde system in float64.  Fast, approximate to ~1e-9 for
  k <= ~32; the practical mode for real-valued workloads.
* ``"gfp"``    — exact arithmetic in GF(p) with p = 2**31 - 1 (Mersenne).
  Operands must be non-negative integers < p, and the *true* (integer)
  matmul entries must be < p for the lift back to the integers to be exact.
  Matmuls in GF(p) use 16-bit digit splitting (the paper's own layering
  trick, reused) so accumulation never overflows uint64.

The 1-D special case (``n2 = 1``) is a classic Reed-Solomon-style MDS code
over matrix blocks — exposed as :class:`MDSCode` and used by the coded
data-parallel gradient path (see ``repro/core/layered_matmul.py``).
"""

from __future__ import annotations

import collections
import dataclasses
import functools
import math
import threading
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

try:
    from scipy.linalg import lu_factor, lu_solve
    _HAVE_SCIPY = True
except ImportError:  # pragma: no cover - scipy is a baked-in dep
    _HAVE_SCIPY = False

__all__ = ["PolynomialCode", "HierarchicalCode", "MDSCode", "DecodePlan",
           "modmatmul", "MERSENNE_P"]

MERSENNE_P = (1 << 31) - 1


# ---------------------------------------------------------------------------
# Exact modular matmul via 16-bit digit splitting (no uint64 overflow)
# ---------------------------------------------------------------------------

def modmatmul(x, y, p: int = MERSENNE_P) -> np.ndarray:
    """``(x.T @ y) mod p`` exactly, for non-negative integer inputs < p.

    Splits each operand into 16-bit hi/lo digits (layering, again):
    ``x = xh 2^16 + xl`` so every partial matmul accumulates products
    < 2**32 over at most K <= 2**30 terms inside uint64.  Host NumPy so the
    exactness never depends on jax_enable_x64 (JAX truncates uint64 to
    uint32 in the default config).
    """
    x = np.asarray(x, dtype=np.uint64)
    y = np.asarray(y, dtype=np.uint64)
    if x.shape[0] != y.shape[0]:
        raise ValueError(f"contracting dims differ: {x.shape} vs {y.shape}")
    if x.shape[0] > (1 << 30):
        raise ValueError("K too large for overflow-free uint64 accumulation")
    mask = np.uint64(0xFFFF)
    xh, xl = x >> np.uint64(16), x & mask
    yh, yl = y >> np.uint64(16), y & mask
    hh = (xh.T @ yh) % p
    hl = (xh.T @ yl) % p
    lh = (xl.T @ yh) % p
    ll = (xl.T @ yl) % p
    two16 = np.uint64((1 << 16) % p)
    two32 = np.uint64((1 << 32) % p)
    return (hh * two32 % p + (hl + lh) % p * two16 % p + ll) % p


def _mod_inv(a: int, p: int) -> int:
    return pow(int(a) % p, p - 2, p)


def _vandermonde_inv_mod(points: Sequence[int], p: int) -> np.ndarray:
    """Inverse of the Vandermonde matrix V[r, c] = points[r]**c, mod p.

    Gaussian elimination over GF(p) with Python ints (k is small: <= ~64).
    """
    k = len(points)
    V = [[pow(int(pt) % p, c, p) for c in range(k)] for pt in points]
    A = [V[i][:] + [1 if i == j else 0 for j in range(k)] for i in range(k)]
    # forward elimination
    for col in range(k):
        piv = next(r for r in range(col, k) if A[r][col] % p != 0)
        A[col], A[piv] = A[piv], A[col]
        inv = _mod_inv(A[col][col], p)
        A[col] = [(v * inv) % p for v in A[col]]
        for r in range(k):
            if r != col and A[r][col] % p != 0:
                f = A[r][col]
                A[r] = [(A[r][c] - f * A[col][c]) % p for c in range(2 * k)]
    return np.array([[A[r][k + c] for c in range(k)] for r in range(k)],
                    dtype=object)


# ---------------------------------------------------------------------------
# Decode plans: the per-code precomputation + per-arrival-set operator cache
# ---------------------------------------------------------------------------

class DecodePlan:
    """Precomputed decode operators for one fixed codeword geometry.

    Built once per code: the full ``(T, k)`` Vandermonde over the code's
    evaluation points (Chebyshev in float mode).  Each any-``k`` decode
    then only *indexes* its k rows and applies a solve operator — float
    mode an LU factorization (``scipy.linalg.lu_factor``; cached inverse
    without scipy), gfp mode the exact ``_vandermonde_inv_mod`` — kept in
    a bounded LRU keyed by the sorted arrival-ID tuple.  The same set of
    fast workers fusing round after round therefore pays the
    factorization once and a single small GEMM per round, instead of the
    per-fuse ``np.vander`` + ``np.linalg.solve`` rebuild.

    Thread-safe: the operator LRU is lock-guarded (factorizations happen
    outside the lock, so concurrent decoders never serialize on BLAS),
    and instances are shared process-wide per geometry via
    ``PolynomialCode.plan`` / ``MDSCode.plan`` — which is what makes the
    adaptive-ω controller's geometry switches cheap: revisiting a
    previously-used codeword length finds its plan (and its warm
    operator cache) intact.  ``cache_info()`` exposes hit/miss/eviction
    counters for profiling and tests.  This is the §II-A any-``k``
    decode made incremental; no wall-clock state lives here (plans are
    pure functions of the geometry).
    """

    def __init__(self, points: np.ndarray, k: int, *, mode: str = "float",
                 p: int = MERSENNE_P, cache_size: int = 128):
        if cache_size < 1:
            raise ValueError(f"cache_size must be >= 1, got {cache_size}")
        self.k = k
        self.mode = mode
        self.p = p
        self.points = np.asarray(points)
        if self.points.shape[0] < k:
            raise ValueError(f"{self.points.shape[0]} points for k={k}")
        if mode == "float":
            # one T x k Vandermonde for the whole codeword, built once
            self._V = np.vander(self.points.astype(np.float64), N=k,
                                increasing=True)
        self.cache_size = cache_size
        self._cache: collections.OrderedDict[tuple, tuple] = \
            collections.OrderedDict()
        self._lock = threading.Lock()
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def _build(self, ids: tuple[int, ...]) -> tuple:
        idx = np.asarray(ids)
        if self.mode == "float":
            V = self._V[idx]
            # explicit inverse: applying it is a single tiny GEMM (~8x
            # faster than lu_solve's call overhead) and, with Chebyshev
            # points, just as accurate up to k ~ 16; beyond that LU's
            # backward stability starts to matter.
            if self.k <= 16 or not _HAVE_SCIPY:
                return ("inv", np.linalg.inv(V))
            return ("lu", lu_factor(V))
        return ("gfp", _vandermonde_inv_mod(
            [int(x) for x in self.points[idx]], self.p))

    def operator(self, ids: tuple[int, ...]) -> tuple:
        """The (cached) solve operator for one sorted arrival-ID tuple."""
        with self._lock:
            op = self._cache.get(ids)
            if op is not None:
                self.hits += 1
                self._cache.move_to_end(ids)
                return op
        op = self._build(ids)     # factorize outside the lock
        with self._lock:
            self.misses += 1
            self._cache[ids] = op
            self._cache.move_to_end(ids)
            while len(self._cache) > self.cache_size:
                self._cache.popitem(last=False)
                self.evictions += 1
        return op

    def solve(self, task_ids: Sequence[int], results, *,
              use_cache: bool = True) -> np.ndarray:
        """Polynomial coefficients ``(k, ...)`` from any k task results.

        Arrival order is canonicalized to sorted-ID order (a permutation
        of the linear system's equations) so it never fragments the
        cache.  ``use_cache=False`` rebuilds the operator fresh — same
        arithmetic, bit-identical output — the reference path the
        property tests compare against.
        """
        ids = [int(i) for i in list(task_ids)[: self.k]]
        if len(ids) < self.k:
            raise ValueError(
                f"need {self.k} task results to decode, got {len(ids)}")
        res = np.asarray(results)[: self.k]
        if all(a < b for a, b in zip(ids, ids[1:])):
            key = tuple(ids)
            flat = res.reshape(self.k, -1)
        else:
            order = sorted(range(self.k), key=ids.__getitem__)
            key = tuple(ids[i] for i in order)
            flat = res[order].reshape(self.k, -1)
        kind, data = self.operator(key) if use_cache else self._build(key)
        if kind == "lu":
            coeffs = lu_solve(data, flat)
        elif kind == "lu+inv":
            coeffs = lu_solve(data[0], flat)   # LU stays the solve path
        elif kind == "inv":
            coeffs = data @ flat
        else:
            coeffs = (data @ flat.astype(object)) % self.p
        return coeffs.reshape(self.k, *res.shape[1:])

    def inverse(self, ids: tuple[int, ...]) -> np.ndarray:
        """Explicit inverse for a sorted ID tuple (cached operator).

        For callers that apply the operator elsewhere (e.g. a device
        tensordot) instead of solving on the host.  An "lu" operator is
        materialized once and the cache entry is upgraded in place, so
        repeat decodes of the same ID set don't re-pay the solve (later
        host solves for that set then apply the inverse too).
        """
        kind, data = self.operator(ids)
        if kind == "lu":
            inv = lu_solve(data, np.eye(self.k))
            with self._lock:
                if ids in self._cache:
                    # keep BOTH: LU stays the (more stable) host solve
                    # path, the inverse serves device-side application
                    self._cache[ids] = ("lu+inv", (data, inv))
            return inv
        if kind == "lu+inv":
            return data[1]
        return data            # "inv" and "gfp" both store the inverse

    def cache_info(self) -> dict:
        with self._lock:
            return {"hits": self.hits, "misses": self.misses,
                    "evictions": self.evictions,
                    "currsize": len(self._cache),
                    "maxsize": self.cache_size}


def _assemble_blocks(coeffs: np.ndarray, n1: int, n2: int) -> np.ndarray:
    """Block matrix from coefficients: slot ``r + s*n1`` -> block (r, s).

    One transpose/reshape instead of the former Python concatenate loop;
    works for float and object (GF(p)) arrays alike.
    """
    k, mb, nb = coeffs.shape
    return (coeffs.reshape(n2, n1, mb, nb)
            .transpose(1, 2, 0, 3)
            .reshape(n1 * mb, n2 * nb))


# ---------------------------------------------------------------------------
# Polynomial code
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class PolynomialCode:
    """Polynomial coded matmul: ``A (K, M)``, ``B (K, N)`` -> ``A.T @ B``.

    Args:
      n1, n2: column-block counts for A and B; recovery threshold k = n1*n2.
      omega:  redundancy ratio; num_tasks = ceil(k * omega).
      mode:   "float" (Chebyshev points, float64 decode) or "gfp" (exact).
    """

    n1: int
    n2: int
    omega: float = 1.0
    mode: str = "float"
    p: int = MERSENNE_P

    def __post_init__(self):
        if self.n1 < 1 or self.n2 < 1:
            raise ValueError("n1, n2 must be >= 1")
        if self.omega < 1.0:
            raise ValueError(f"redundancy ratio must be >= 1, got {self.omega}")
        if self.mode not in ("float", "gfp"):
            raise ValueError(f"unknown mode {self.mode!r}")

    @property
    def k(self) -> int:
        return self.n1 * self.n2

    @property
    def num_tasks(self) -> int:
        return max(self.k, math.ceil(self.k * self.omega))

    # -- evaluation points ---------------------------------------------------
    def points(self) -> np.ndarray:
        return _eval_points(self.num_tasks, self.mode)

    # -- precomputed plans ----------------------------------------------------
    def plan(self) -> DecodePlan:
        """The code's decode plan (one per geometry, process-wide)."""
        return _decode_plan(self)

    # -- encoding --------------------------------------------------------------
    def _split(self, mat, nblocks: int):
        K, M = mat.shape
        if M % nblocks:
            raise ValueError(f"second dim {M} not divisible by {nblocks}")
        xp = np if isinstance(mat, np.ndarray) else jnp
        return xp.stack(xp.split(mat, nblocks, axis=1), axis=0)  # (n, K, M/n)

    def encode_a(self, a: np.ndarray) -> np.ndarray:
        """Coded blocks ``X (T, K, M/n1)`` of operand A alone (host float64).

        Encoding is per operand *side*: a runtime driving the ``m**2``
        plane-pair rounds of one job only needs ``m`` A-side and ``m``
        B-side encodes total, reusing each coded side across every round
        that pairs it — not ``m**2`` full ``encode`` calls.
        """
        if self.mode != "float":
            raise ValueError("encode_a is the float-mode host fast path")
        va, _ = _encode_basis(self)
        blocks = self._split(a, self.n1)
        return np.einsum("rkm,rt->tkm", blocks.astype(np.float64), va)

    def encode_b(self, b: np.ndarray) -> np.ndarray:
        """Coded blocks ``Y (T, K, N/n2)`` of operand B alone (host float64)."""
        if self.mode != "float":
            raise ValueError("encode_b is the float-mode host fast path")
        _, vb = _encode_basis(self)
        blocks = self._split(b, self.n2)
        return np.einsum("skn,st->tkn", blocks.astype(np.float64), vb)

    def encode(self, a, b):
        """Returns coded task inputs ``X (T, K, M/n1)`` and ``Y (T, K, N/n2)``.

        Float mode dispatches on input type: NumPy operands are encoded on
        the host in float64 (exact points, no device round-trip — the
        runtime master's per-round hot path); JAX operands go through the
        device einsum (float32 unless jax_enable_x64).
        """
        if (self.mode == "float" and isinstance(a, np.ndarray)
                and isinstance(b, np.ndarray)):
            return self.encode_a(a), self.encode_b(b)
        blocks_a = self._split(a, self.n1)
        blocks_b = self._split(b, self.n2)
        va, vb = _encode_basis(self)     # built once per geometry
        if self.mode == "float":
            dtype = (jnp.float64 if jax.config.jax_enable_x64
                     else jnp.float32)
            va, vb = jnp.asarray(va, dtype), jnp.asarray(vb, dtype)
            X = jnp.einsum("rkm,rt->tkm", blocks_a.astype(dtype), va)
            Y = jnp.einsum("skn,st->tkn", blocks_b.astype(dtype), vb)
            return X, Y
        ba = np.asarray(blocks_a, dtype=np.uint64)
        bb = np.asarray(blocks_b, dtype=np.uint64)
        # accumulate n1 (resp. n2) products of (<p)*(<p): split coefficient
        # into 16-bit digits to stay inside uint64.  Host NumPy: the exact
        # GF(p) path is the bit-exact fusion/verification path, not the
        # accelerator path (which is "float" mode).
        X = _mod_combine(ba, va, self.p)
        Y = _mod_combine(bb, vb, self.p)
        return X, Y

    # -- per-task compute --------------------------------------------------------
    def task_result(self, X_i, Y_i):
        if self.mode == "float":
            return X_i.T @ Y_i
        return modmatmul(X_i, Y_i, self.p)

    def compute_all_tasks(self, X, Y):
        if self.mode == "float":
            return jnp.einsum("tkm,tkn->tmn", X, Y)
        return np.stack([modmatmul(X[i], Y[i], self.p)
                         for i in range(X.shape[0])], 0)

    # -- decoding -------------------------------------------------------------
    def decode(self, task_ids: Sequence[int], results: jax.Array) -> jax.Array:
        """Reconstruct ``A.T @ B`` from any k task results.

        Args:
          task_ids: indices (into the num_tasks codeword) of received results.
          results:  (k, M/n1, N/n2) stacked task outputs, same order.
        Returns:
          (M, N) product.
        """
        coeffs = self.plan().solve(task_ids, results)
        # coefficient (r, s) of x^(r + s*n1) is (A^r).T @ B^s
        out = _assemble_blocks(coeffs, self.n1, self.n2)
        if self.mode == "gfp":
            return _lift_gfp(out, self.p)
        return out


def _eval_points(num_tasks: int, mode: str) -> np.ndarray:
    """The codeword's evaluation points — a function of (T, mode) ONLY.

    Chebyshev nodes in float mode (well-conditioned Vandermonde); 1..T in
    GF(p) mode.  Shared by encode bases and decode plans so both cache by
    *geometry*, never by the exact ``omega`` float that produced it.
    """
    if mode == "float":
        i = np.arange(num_tasks)
        return np.cos((2 * i + 1) * np.pi
                      / (2 * num_tasks)).astype(np.float64)
    return np.arange(1, num_tasks + 1, dtype=np.int64)


# Plans/bases are cached process-wide by GEOMETRY (k or n1/n2, codeword
# length T, mode, p) — not by the PolynomialCode instance — so two codes
# whose omegas differ but land on the same T = ceil(k * omega) share one
# plan and its warm operator cache.  This is what makes the adaptive-ω
# controller's oscillations cheap: AIMD's multiplicative shrink almost
# never reproduces an exact prior omega, but constantly revisits prior
# codeword lengths.  Bounded: a long-lived process retuning the geometry
# (parameter sweeps, the controller) must not accumulate plans forever.
def _decode_plan(code: PolynomialCode) -> DecodePlan:
    return _plan_by_geometry(code.k, code.num_tasks, code.mode, code.p)


@functools.lru_cache(maxsize=64)
def _plan_by_geometry(k: int, num_tasks: int, mode: str,
                      p: int) -> DecodePlan:
    return DecodePlan(_eval_points(num_tasks, mode), k, mode=mode, p=p)


def _encode_basis(code: PolynomialCode) -> tuple[np.ndarray, np.ndarray]:
    return _basis_by_geometry(code.n1, code.n2, code.num_tasks, code.mode,
                              code.p)


@functools.lru_cache(maxsize=64)
def _basis_by_geometry(n1: int, n2: int, num_tasks: int, mode: str,
                       p: int) -> tuple[np.ndarray, np.ndarray]:
    """Per-geometry encode matrices ``va (n1, T)``, ``vb (n2, T)``."""
    pts = _eval_points(num_tasks, mode)
    if mode == "float":
        va = np.stack([pts**r for r in range(n1)], 0)
        vb = np.stack([pts ** (s * n1) for s in range(n2)], 0)
        return va, vb
    # exact GF(p): Python-int powers reduced mod p
    va = np.array([[pow(int(pt), r, p) for pt in pts]
                   for r in range(n1)], dtype=np.uint64)
    vb = np.array([[pow(int(pt), s * n1, p) for pt in pts]
                   for s in range(n2)], dtype=np.uint64)
    return va, vb


def _mod_combine(blocks: np.ndarray, vand: np.ndarray, p: int) -> np.ndarray:
    """``sum_r blocks[r] * vand[r, t] mod p`` without uint64 overflow.

    Single einsum per 16-bit digit pair: each digit product is < 2**32, so
    the raw uint64 accumulation over all n planes is exact for n < 2**26 —
    one reduction replaces the former per-plane Python loop.
    """
    n = blocks.shape[0]
    if n >= (1 << 26):
        raise ValueError(f"too many planes ({n}) for uint64 accumulation")
    vh, vl = vand >> np.uint64(16), vand & np.uint64(0xFFFF)
    bh, bl = blocks >> np.uint64(16), blocks & np.uint64(0xFFFF)
    hh = np.einsum("rkm,rt->tkm", bh, vh) % p
    hl = np.einsum("rkm,rt->tkm", bh, vl)
    lh = np.einsum("rkm,rt->tkm", bl, vh)
    ll = np.einsum("rkm,rt->tkm", bl, vl) % p
    two16 = np.uint64((1 << 16) % p)
    two32 = np.uint64((1 << 32) % p)
    return (hh * two32 % p + (hl + lh) % p * two16 % p + ll) % p


def _lift_gfp(x_obj: np.ndarray, p: int) -> np.ndarray:
    """Map GF(p) representatives back to signed integers in (-p/2, p/2]."""
    flat = np.array([int(v) for v in x_obj.reshape(-1)], dtype=np.int64)
    flat = np.where(flat > p // 2, flat - p, flat)
    return flat.reshape(x_obj.shape)


# ---------------------------------------------------------------------------
# Hierarchical code family (Ferdinand & Draper; Park et al.)
# ---------------------------------------------------------------------------

def _hier_level_lengths(k: int, levels: int, budget: int) -> tuple[int, ...]:
    """MSB-heavy per-level codeword lengths summing exactly to ``budget``.

    Every level keeps at least the recovery threshold ``k``; the surplus
    ``budget - levels*k`` is split with linearly decaying weights
    ``levels, levels-1, ..., 1`` so the level carrying the most
    significant digit planes gets the most redundancy — that is the
    resolution the paper's deadline rule releases first, so it is the
    one that must survive stragglers.  Rounding leftovers also go
    MSB-first, keeping the allocation deterministic.
    """
    if budget < levels * k:
        raise ValueError(
            f"budget {budget} cannot give {levels} levels k={k} each")
    extra = budget - levels * k
    weights = [levels - l for l in range(levels)]
    total_w = sum(weights)
    alloc = [extra * w // total_w for w in weights]
    for l in range(extra - sum(alloc)):      # leftovers, MSB-first
        alloc[l] += 1
    return tuple(k + a for a in alloc)


def _exact_length_code(n1: int, n2: int, num_tasks: int, mode: str,
                       p: int) -> PolynomialCode:
    """A PolynomialCode with *exactly* ``num_tasks`` codeword symbols.

    ``omega = (T - 0.5) / k`` makes ``ceil(k * omega) == T`` for any
    ``T > k`` without floating-point edge cases; ``T == k`` is the
    rate-1 code.  Frozen dataclass, so instances are cheap and the
    plan/basis caches key by geometry anyway.
    """
    k = n1 * n2
    if num_tasks < k:
        raise ValueError(f"codeword length {num_tasks} below k={k}")
    omega = 1.0 if num_tasks == k else (num_tasks - 0.5) / k
    code = PolynomialCode(n1=n1, n2=n2, omega=omega, mode=mode, p=p)
    assert code.num_tasks == num_tasks
    return code


@dataclasses.dataclass(frozen=True)
class HierarchicalCode:
    """Hierarchical coded matmul: L stacked per-level MDS codes.

    Following Ferdinand & Draper's hierarchical coding, each worker's
    assignment is split into ``levels`` sub-tasks, each an independent
    polynomial codeword over the same ``k = n1 * n2`` recovery threshold
    but its *own* MDS rate: level l has ``level_lengths[l]`` coded
    symbols, MSB-heavy at equal aggregate budget
    ``sum(level_lengths) == levels * ceil(k * omega)``.  A straggler that
    finishes only its first sub-tasks has still contributed decodable
    symbols to the earliest levels — partial progress counts instead of
    being purged wholesale.

    The runtime aligns level order with the digit-plane layering's
    MSB-first round order (``layering.all_minijobs_msb_first``): level l
    of a dispatch group *is* plane-pair round ``g0 + l``, so every
    completed sub-task advances some resolution of the layered output.

    Per-level encode/decode delegate to ordinary
    :class:`PolynomialCode` instances, so the per-geometry
    ``DecodePlan`` LRU (and its warm any-k operator caches) is shared
    with the flat family — two levels with equal length use one plan.
    """

    n1: int
    n2: int
    levels: int
    omega: float = 1.0
    mode: str = "float"
    p: int = MERSENNE_P

    def __post_init__(self):
        if self.n1 < 1 or self.n2 < 1:
            raise ValueError("n1, n2 must be >= 1")
        if self.levels < 1:
            raise ValueError(f"levels must be >= 1, got {self.levels}")
        if self.omega < 1.0:
            raise ValueError(f"redundancy ratio must be >= 1, got {self.omega}")
        if self.mode not in ("float", "gfp"):
            raise ValueError(f"unknown mode {self.mode!r}")

    @property
    def k(self) -> int:
        return self.n1 * self.n2

    @property
    def base_tasks(self) -> int:
        """Codeword length the flat polynomial family would use."""
        return max(self.k, math.ceil(self.k * self.omega))

    @property
    def level_lengths(self) -> tuple[int, ...]:
        """Per-level codeword lengths; MSB-heavy, equal aggregate budget."""
        return _hier_level_lengths(self.k, self.levels,
                                   self.levels * self.base_tasks)

    @property
    def num_tasks(self) -> int:
        """Total coded sub-tasks across all levels (== levels * base_tasks)."""
        return sum(self.level_lengths)

    def level_code(self, level: int) -> PolynomialCode:
        """The level's own polynomial code, exactly ``level_lengths[level]``
        symbols long."""
        return _exact_length_code(self.n1, self.n2,
                                  self.level_lengths[level], self.mode,
                                  self.p)

    # -- per-level encode/decode (thin delegation; the runtime drives the
    #    level codes directly when it wants side-split encodes) ------------
    def encode_level(self, level: int, a, b):
        return self.level_code(level).encode(a, b)

    def decode_level(self, level: int, task_ids: Sequence[int], results):
        return self.level_code(level).decode(task_ids, results)

    def plan(self, level: int) -> DecodePlan:
        return self.level_code(level).plan()


# ---------------------------------------------------------------------------
# 1-D MDS code over pytree-of-array shards (coded data parallelism)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class MDSCode:
    """Systematic-free (k, n) MDS code over equal-shape array shards.

    Encoding: codeword ``c_t = sum_r shard_r * x_t**r`` (Chebyshev points).
    Any k of the n codewords decode the k shards.  Used for erasure-tolerant
    coded data parallelism: each pod computes a *coded combination* of
    gradient shards; the fusion decodes from the k fastest/surviving pods.
    """

    k: int
    n: int

    def __post_init__(self):
        if self.n < self.k:
            raise ValueError(f"need n >= k, got n={self.n} < k={self.k}")

    def points(self) -> np.ndarray:
        return _eval_points(self.n, "float")

    def generator(self, dtype=jnp.float32) -> jax.Array:
        """(n, k) generator matrix G: codewords = G @ shards."""
        pts = self.points()
        return jnp.asarray(np.vander(pts, N=self.k, increasing=True), dtype)

    def encode(self, shards: jax.Array) -> jax.Array:
        """shards (k, ...) -> codewords (n, ...)."""
        G = self.generator(shards.dtype)
        return jnp.tensordot(G, shards, axes=1)

    def plan(self) -> DecodePlan:
        """The code's decode plan (one per geometry, process-wide)."""
        return _mds_plan(self)

    def decode(self, ids: Sequence[int], codewords: jax.Array) -> jax.Array:
        """Any k codewords (k, ...) + their ids -> shards (k, ...).

        NumPy codewords decode on the host in float64 through the plan;
        JAX codewords stay on device (jit-traceable: ids are static, only
        the cached inverse crosses to the device) as before.
        """
        ids = [int(i) for i in list(ids)[: self.k]]
        if len(ids) < self.k:
            raise ValueError(f"need {self.k} codewords, got {len(ids)}")
        if isinstance(codewords, np.ndarray):
            shards = self.plan().solve(ids, codewords)
            return jnp.asarray(shards.astype(codewords.dtype))
        order = sorted(range(self.k), key=ids.__getitem__)
        Vinv = self.plan().inverse(tuple(ids[i] for i in order))
        cw = codewords[: self.k]
        if order != list(range(self.k)):
            cw = cw[jnp.asarray(order)]
        return jnp.tensordot(jnp.asarray(Vinv, codewords.dtype), cw, axes=1)


def _mds_plan(code: MDSCode) -> DecodePlan:
    # same geometry keying (and Chebyshev points) as the 2-D code: an
    # MDSCode(k, n) shares its plan with any PolynomialCode of equal
    # (k, T) in float mode
    return _plan_by_geometry(code.k, code.n, "float", MERSENNE_P)
