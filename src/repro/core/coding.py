"""Polynomial codes for distributed coded matrix multiplication.

Implements the scheme of Yu, Maddah-Ali & Avestimehr (NeurIPS'17), reviewed
in the paper's §II-A: split ``A`` into ``n1`` column blocks and ``B`` into
``n2`` column blocks, encode the i-th coded task's inputs as polynomial
evaluations

    X^i = sum_r A^r x_i^r          Y^i = sum_s B^s x_i^(s n1)

so that ``(X^i)^T Y^i = h(x_i)`` where ``h`` is a matrix polynomial of degree
``n1 n2 - 1`` whose coefficient ``(r, s)`` is ``(A^r)^T B^s``.  Any
``k = n1 n2`` of the ``num_tasks = ceil(k * omega)`` evaluations recover all
coefficients (MDS property), i.e. the full product ``A^T B``.

Two arithmetic modes:

* ``"float"``  — Chebyshev evaluation points on [-1, 1], decode by solving the
  k x k Vandermonde system in float64.  Fast, approximate to ~1e-9 for
  k <= ~32; the practical mode for real-valued workloads.
* ``"gfp"``    — exact arithmetic in GF(p) with p = 2**31 - 1 (Mersenne).
  Operands must be non-negative integers < p, and the *true* (integer)
  matmul entries must be < p for the lift back to the integers to be exact.
  Matmuls in GF(p) use 16-bit digit splitting (the paper's own layering
  trick, reused) so accumulation never overflows uint64.

The 1-D special case (``n2 = 1``) is a classic Reed-Solomon-style MDS code
over matrix blocks — exposed as :class:`MDSCode` and used by the coded
data-parallel gradient path (see ``repro/core/layered_matmul.py``).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["PolynomialCode", "MDSCode", "modmatmul", "MERSENNE_P"]

MERSENNE_P = (1 << 31) - 1


# ---------------------------------------------------------------------------
# Exact modular matmul via 16-bit digit splitting (no uint64 overflow)
# ---------------------------------------------------------------------------

def modmatmul(x, y, p: int = MERSENNE_P) -> np.ndarray:
    """``(x.T @ y) mod p`` exactly, for non-negative integer inputs < p.

    Splits each operand into 16-bit hi/lo digits (layering, again):
    ``x = xh 2^16 + xl`` so every partial matmul accumulates products
    < 2**32 over at most K <= 2**30 terms inside uint64.  Host NumPy so the
    exactness never depends on jax_enable_x64 (JAX truncates uint64 to
    uint32 in the default config).
    """
    x = np.asarray(x, dtype=np.uint64)
    y = np.asarray(y, dtype=np.uint64)
    if x.shape[0] != y.shape[0]:
        raise ValueError(f"contracting dims differ: {x.shape} vs {y.shape}")
    if x.shape[0] > (1 << 30):
        raise ValueError("K too large for overflow-free uint64 accumulation")
    mask = np.uint64(0xFFFF)
    xh, xl = x >> np.uint64(16), x & mask
    yh, yl = y >> np.uint64(16), y & mask
    hh = (xh.T @ yh) % p
    hl = (xh.T @ yl) % p
    lh = (xl.T @ yh) % p
    ll = (xl.T @ yl) % p
    two16 = np.uint64((1 << 16) % p)
    two32 = np.uint64((1 << 32) % p)
    return (hh * two32 % p + (hl + lh) % p * two16 % p + ll) % p


def _mod_inv(a: int, p: int) -> int:
    return pow(int(a) % p, p - 2, p)


def _vandermonde_inv_mod(points: Sequence[int], p: int) -> np.ndarray:
    """Inverse of the Vandermonde matrix V[r, c] = points[r]**c, mod p.

    Gaussian elimination over GF(p) with Python ints (k is small: <= ~64).
    """
    k = len(points)
    V = [[pow(int(pt) % p, c, p) for c in range(k)] for pt in points]
    A = [V[i][:] + [1 if i == j else 0 for j in range(k)] for i in range(k)]
    # forward elimination
    for col in range(k):
        piv = next(r for r in range(col, k) if A[r][col] % p != 0)
        A[col], A[piv] = A[piv], A[col]
        inv = _mod_inv(A[col][col], p)
        A[col] = [(v * inv) % p for v in A[col]]
        for r in range(k):
            if r != col and A[r][col] % p != 0:
                f = A[r][col]
                A[r] = [(A[r][c] - f * A[col][c]) % p for c in range(2 * k)]
    return np.array([[A[r][k + c] for c in range(k)] for r in range(k)],
                    dtype=object)


# ---------------------------------------------------------------------------
# Polynomial code
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class PolynomialCode:
    """Polynomial coded matmul: ``A (K, M)``, ``B (K, N)`` -> ``A.T @ B``.

    Args:
      n1, n2: column-block counts for A and B; recovery threshold k = n1*n2.
      omega:  redundancy ratio; num_tasks = ceil(k * omega).
      mode:   "float" (Chebyshev points, float64 decode) or "gfp" (exact).
    """

    n1: int
    n2: int
    omega: float = 1.0
    mode: str = "float"
    p: int = MERSENNE_P

    def __post_init__(self):
        if self.n1 < 1 or self.n2 < 1:
            raise ValueError("n1, n2 must be >= 1")
        if self.omega < 1.0:
            raise ValueError(f"redundancy ratio must be >= 1, got {self.omega}")
        if self.mode not in ("float", "gfp"):
            raise ValueError(f"unknown mode {self.mode!r}")

    @property
    def k(self) -> int:
        return self.n1 * self.n2

    @property
    def num_tasks(self) -> int:
        return max(self.k, math.ceil(self.k * self.omega))

    # -- evaluation points ---------------------------------------------------
    def points(self) -> np.ndarray:
        if self.mode == "float":
            # Chebyshev nodes keep the Vandermonde system well-conditioned.
            t = self.num_tasks
            i = np.arange(t)
            return np.cos((2 * i + 1) * np.pi / (2 * t)).astype(np.float64)
        return np.arange(1, self.num_tasks + 1, dtype=np.int64)

    # -- encoding --------------------------------------------------------------
    def _split(self, mat, nblocks: int):
        K, M = mat.shape
        if M % nblocks:
            raise ValueError(f"second dim {M} not divisible by {nblocks}")
        xp = np if isinstance(mat, np.ndarray) else jnp
        return xp.stack(xp.split(mat, nblocks, axis=1), axis=0)  # (n, K, M/n)

    def encode(self, a, b):
        """Returns coded task inputs ``X (T, K, M/n1)`` and ``Y (T, K, N/n2)``.

        Float mode dispatches on input type: NumPy operands are encoded on
        the host in float64 (exact points, no device round-trip — the
        runtime master's per-round hot path); JAX operands go through the
        device einsum (float32 unless jax_enable_x64).
        """
        blocks_a = self._split(a, self.n1)
        blocks_b = self._split(b, self.n2)
        pts = self.points()
        if self.mode == "float":
            va = np.stack([pts**r for r in range(self.n1)], 0)
            vb = np.stack([pts ** (s * self.n1) for s in range(self.n2)], 0)
            if isinstance(a, np.ndarray) and isinstance(b, np.ndarray):
                X = np.einsum("rkm,rt->tkm",
                              blocks_a.astype(np.float64), va)
                Y = np.einsum("skn,st->tkn",
                              blocks_b.astype(np.float64), vb)
                return X, Y
            dtype = (jnp.float64 if jax.config.jax_enable_x64
                     else jnp.float32)
            va, vb = jnp.asarray(va, dtype), jnp.asarray(vb, dtype)
            X = jnp.einsum("rkm,rt->tkm", blocks_a.astype(dtype), va)
            Y = jnp.einsum("skn,st->tkn", blocks_b.astype(dtype), vb)
            return X, Y
        # exact GF(p): encode with Python-int powers reduced mod p
        va = np.array([[pow(int(pt), r, self.p) for pt in pts]
                       for r in range(self.n1)], dtype=np.uint64)
        vb = np.array([[pow(int(pt), s * self.n1, self.p) for pt in pts]
                       for s in range(self.n2)], dtype=np.uint64)
        ba = np.asarray(blocks_a, dtype=np.uint64)
        bb = np.asarray(blocks_b, dtype=np.uint64)
        # accumulate n1 (resp. n2) products of (<p)*(<p): split coefficient
        # into 16-bit digits to stay inside uint64.  Host NumPy: the exact
        # GF(p) path is the bit-exact fusion/verification path, not the
        # accelerator path (which is "float" mode).
        X = _mod_combine(ba, va, self.p)
        Y = _mod_combine(bb, vb, self.p)
        return X, Y

    # -- per-task compute --------------------------------------------------------
    def task_result(self, X_i, Y_i):
        if self.mode == "float":
            return X_i.T @ Y_i
        return modmatmul(X_i, Y_i, self.p)

    def compute_all_tasks(self, X, Y):
        if self.mode == "float":
            return jnp.einsum("tkm,tkn->tmn", X, Y)
        return np.stack([modmatmul(X[i], Y[i], self.p)
                         for i in range(X.shape[0])], 0)

    # -- decoding -------------------------------------------------------------
    def decode(self, task_ids: Sequence[int], results: jax.Array) -> jax.Array:
        """Reconstruct ``A.T @ B`` from any k task results.

        Args:
          task_ids: indices (into the num_tasks codeword) of received results.
          results:  (k, M/n1, N/n2) stacked task outputs, same order.
        Returns:
          (M, N) product.
        """
        ids = list(task_ids)[: self.k]
        if len(ids) < self.k:
            raise ValueError(
                f"need {self.k} task results to decode, got {len(ids)}")
        res = np.asarray(results)[: self.k]
        pts = self.points()[np.asarray(ids)]
        if self.mode == "float":
            V = np.vander(pts, N=self.k, increasing=True)  # (k, k)
            coeffs = np.linalg.solve(V, res.reshape(self.k, -1))
            coeffs = coeffs.reshape(self.k, *res.shape[1:])
        else:
            Vinv = _vandermonde_inv_mod([int(x) for x in pts], self.p)
            flat = res.reshape(self.k, -1).astype(object)
            coeffs = (Vinv @ flat) % self.p
            coeffs = coeffs.reshape(self.k, *res.shape[1:])
        # coefficient (r, s) of x^(r + s*n1) is (A^r).T @ B^s
        rows = []
        for r in range(self.n1):
            cols = [coeffs[r + s * self.n1] for s in range(self.n2)]
            rows.append(np.concatenate(cols, axis=1))
        out = np.concatenate(rows, axis=0)
        if self.mode == "gfp":
            return _lift_gfp(out, self.p)
        return out


def _mod_combine(blocks: np.ndarray, vand: np.ndarray, p: int) -> np.ndarray:
    """``sum_r blocks[r] * vand[r, t] mod p`` without uint64 overflow."""
    n = blocks.shape[0]
    vh, vl = vand >> np.uint64(16), vand & np.uint64(0xFFFF)
    bh, bl = blocks >> np.uint64(16), blocks & np.uint64(0xFFFF)
    two16, two32 = (1 << 16) % p, (1 << 32) % p
    out = np.zeros((vand.shape[1],) + blocks.shape[1:], dtype=np.uint64)
    for r in range(n):  # n is tiny (n1 or n2)
        hh = (bh[r][None] * vh[r][:, None, None]) % p
        hl = (bh[r][None] * vl[r][:, None, None]) % p
        lh = (bl[r][None] * vh[r][:, None, None]) % p
        ll = (bl[r][None] * vl[r][:, None, None]) % p
        term = (hh * two32 + (hl + lh) * two16 + ll) % p
        out = (out + term) % p
    return out


def _lift_gfp(x_obj: np.ndarray, p: int) -> np.ndarray:
    """Map GF(p) representatives back to signed integers in (-p/2, p/2]."""
    flat = np.array([int(v) for v in x_obj.reshape(-1)], dtype=np.int64)
    flat = np.where(flat > p // 2, flat - p, flat)
    return flat.reshape(x_obj.shape)


# ---------------------------------------------------------------------------
# 1-D MDS code over pytree-of-array shards (coded data parallelism)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class MDSCode:
    """Systematic-free (k, n) MDS code over equal-shape array shards.

    Encoding: codeword ``c_t = sum_r shard_r * x_t**r`` (Chebyshev points).
    Any k of the n codewords decode the k shards.  Used for erasure-tolerant
    coded data parallelism: each pod computes a *coded combination* of
    gradient shards; the fusion decodes from the k fastest/surviving pods.
    """

    k: int
    n: int

    def __post_init__(self):
        if self.n < self.k:
            raise ValueError(f"need n >= k, got n={self.n} < k={self.k}")

    def points(self) -> np.ndarray:
        i = np.arange(self.n)
        return np.cos((2 * i + 1) * np.pi / (2 * self.n)).astype(np.float64)

    def generator(self, dtype=jnp.float32) -> jax.Array:
        """(n, k) generator matrix G: codewords = G @ shards."""
        pts = self.points()
        return jnp.asarray(np.vander(pts, N=self.k, increasing=True), dtype)

    def encode(self, shards: jax.Array) -> jax.Array:
        """shards (k, ...) -> codewords (n, ...)."""
        G = self.generator(shards.dtype)
        return jnp.tensordot(G, shards, axes=1)

    def decode(self, ids: Sequence[int], codewords: jax.Array) -> jax.Array:
        """Any k codewords (k, ...) + their ids -> shards (k, ...)."""
        ids = list(ids)[: self.k]
        if len(ids) < self.k:
            raise ValueError(f"need {self.k} codewords, got {len(ids)}")
        pts = self.points()[np.asarray(ids)]
        V = np.vander(pts, N=self.k, increasing=True)
        Vinv = jnp.asarray(np.linalg.inv(V), codewords.dtype)
        return jnp.tensordot(Vinv, codewords[: self.k], axes=1)
