"""Joint coding-scheduling load balancing for heterogeneous workers.

Implements eq. (1) of the paper (from Esfahanizadeh et al., INFOCOM'22):
given the first two moments of each worker's per-job response time, the
number of coded tasks assigned to worker p is

    kappa_p = b_p / (2 gamma m_p^2) * (-1 + sqrt(1 + 4 gamma m_p^2 theta / b_p^2))

with ``m_p = E[T_p]``, ``sigma_p^2 = Var[T_p]``, ``b_p = m_p + gamma sigma_p^2``
and ``theta > 0`` chosen so that ``sum_p kappa_p = k * omega``.  The real
solution is then rounded to integers preserving the sum (largest-remainder).

The closed form equalises the (mean + gamma * variance)-penalised completion
time distributions across workers, which minimises the time until the fusion
node holds ``k`` task results.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

__all__ = ["WorkerStats", "load_split", "worker_job_moments"]


@dataclasses.dataclass(frozen=True)
class WorkerStats:
    """First/second moments of one worker's per-job computation time."""

    mean: float          # m_p = E[T_p]
    second_moment: float  # E[T_p^2]

    @property
    def variance(self) -> float:
        return max(self.second_moment - self.mean**2, 0.0)


def worker_job_moments(mu: float, k: int, c: float) -> WorkerStats:
    """Moments of a worker's time to do one whole job alone.

    A job is ``k`` tasks of complexity ``c``; each task time is
    Exp(rate = mu / c), so the job time is Gamma(k, mu/c):
    mean = k c / mu, var = k c^2 / mu^2.
    """
    mean = k * c / mu
    var = k * (c / mu) ** 2
    return WorkerStats(mean=mean, second_moment=var + mean**2)


def _kappa_real(stats: Sequence[WorkerStats], theta: float,
                gamma: float) -> np.ndarray:
    m = np.array([s.mean for s in stats], dtype=np.float64)
    var = np.array([s.variance for s in stats], dtype=np.float64)
    b = m + gamma * var
    return b / (2 * gamma * m**2) * (
        -1.0 + np.sqrt(1.0 + 4.0 * gamma * m**2 * theta / b**2))


def load_split(stats: Sequence[WorkerStats], total_tasks: int,
               gamma: float = 1.0) -> np.ndarray:
    """Integer task counts kappa_p (sum == total_tasks) per eq. (1).

    theta is found by bisection: kappa is monotone increasing in theta.
    """
    if total_tasks < 0:
        raise ValueError("total_tasks must be >= 0")
    if not stats:
        raise ValueError("need at least one worker")
    if total_tasks == 0:
        return np.zeros(len(stats), dtype=np.int64)

    lo, hi = 1e-12, 1.0
    while _kappa_real(stats, hi, gamma).sum() < total_tasks:
        hi *= 2.0
        if hi > 1e18:
            raise RuntimeError("theta bisection failed to bracket")
    for _ in range(200):
        mid = 0.5 * (lo + hi)
        if _kappa_real(stats, mid, gamma).sum() < total_tasks:
            lo = mid
        else:
            hi = mid
    kappa = _kappa_real(stats, 0.5 * (lo + hi), gamma)

    # Largest-remainder rounding, preserving the exact sum.
    floor = np.floor(kappa).astype(np.int64)
    short = int(total_tasks - floor.sum())
    if short > 0:
        order = np.argsort(-(kappa - floor))
        floor[order[:short]] += 1
    elif short < 0:  # numerically possible after bisection
        order = np.argsort(kappa - floor)
        take = 0
        for idx in order:
            if take == -short:
                break
            if floor[idx] > 0:
                floor[idx] -= 1
                take += 1
    assert floor.sum() == total_tasks, (floor.sum(), total_tasks)
    return floor
