"""G/G/1 delay theory: eqs. (2)-(4) of the paper.

* Service-time lower bound: the whole cluster is at best one super-worker
  whose rate is the sum of the workers' job rates,
  ``E[T_s] >= 1 / sum_p (1 / E[T_p])``.
* Marchal's approximation for the G/G/1 mean waiting time gives the average
  execution delay (arrival -> delivery), eq. (2):
  ``E[D] ~= E[T_s] + E[T_s] * (rho / (1 - rho)) * (c_a^2 + c_s^2) / 2``.
* With layering, the queueing term is unchanged (no early termination) and
  the computational term scales with the fraction of mini-jobs needed for
  resolution l, eq. (3)-(4):
  ``E[T_s^l] >= (sum_{i<=l} J(i) / m^2) * 1 / sum_p (1 / E[T_p])``.

The waiting-time term alone (:func:`gg1_waiting_time`) is the serving
gateway's admission bound: a request's deadline must cover backlog +
expected wait + its resolution's computational share, or the queue
provably cannot serve it in time (see :mod:`repro.runtime.gateway`).
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from repro.core import layering

__all__ = [
    "Moments", "service_rate_bound", "gg1_waiting_time", "gg1_delay",
    "layered_delay_bounds",
]


@dataclasses.dataclass(frozen=True)
class Moments:
    mean: float
    second_moment: float

    @property
    def variance(self) -> float:
        return max(self.second_moment - self.mean**2, 0.0)

    @property
    def scv(self) -> float:
        """Squared coefficient of variation c^2 = Var / mean^2."""
        return self.variance / self.mean**2 if self.mean > 0 else 0.0


def service_rate_bound(worker_means: Sequence[float]) -> float:
    """Super-worker service rate: sum_p 1/E[T_p] (jobs per unit time)."""
    return float(sum(1.0 / m for m in worker_means))


def gg1_waiting_time(arrival: Moments, service: Moments) -> float:
    """Marchal's G/G/1 mean *waiting* time (the queueing term alone).

    ``W ~= E[T_s] * (rho / (1 - rho)) * (c_a^2 + c_s^2) / 2`` with
    ``rho = E[T_s] / E[T_a]``; ``inf`` when the queue is unstable
    (``rho >= 1``).  Exact for M/D/1, an approximation elsewhere; for
    M/M/1 it reduces to the classic ``Wq = rho / (mu - lambda)``.
    """
    rho = service.mean / arrival.mean
    if rho >= 1.0:
        return float("inf")
    return (service.mean * (rho / (1.0 - rho))
            * (arrival.scv + service.scv) / 2.0)


def gg1_delay(arrival: Moments, service: Moments,
              service_mean_override: float | None = None) -> float:
    """Eq. (2): mean execution delay (compute + queueing), Marchal approx.

    ``service_mean_override`` replaces the *computational* term (first
    summand) — used to inject the theoretical lower bound E[T_s] while the
    queueing term keeps the (empirical or modeled) service moments.
    """
    queue = gg1_waiting_time(arrival, service)
    compute = (service_mean_override
               if service_mean_override is not None else service.mean)
    return compute + queue


def layered_delay_bounds(m: int, worker_means: Sequence[float],
                         arrival: Moments, service: Moments) -> np.ndarray:
    """Eqs. (3)-(4): per-resolution lower bounds on E[D(l)], l = 0..L-1.

    The queueing term uses the supplied service moments (the system's, not
    the layer's: queueing delay is identical across layers for a system
    without termination); the computational term is the layer's share of the
    super-worker bound.
    """
    rate = service_rate_bound(worker_means)
    cum = np.asarray(layering.cumulative_minijobs(m), dtype=np.float64)
    ts_l = (cum / (m * m)) / rate  # eq. (3)
    queue = gg1_waiting_time(arrival, service)
    return ts_l + queue  # eq. (4)
