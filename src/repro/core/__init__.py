"""Core: layered-resolution distributed coded computation (the paper).

Modules:
  layering        digit decomposition + Definition-1 resolution layers
  coding          polynomial coded matmul (float & exact GF(p)) + MDS codes
  scheduling      eq.(1) heterogeneous load balancing
  queueing        eqs.(2)-(4) G/G/1 delay bounds
  simulator       event simulation of the master/workers/fusion system (§IV)
  layered_matmul  executable pipeline + shard_map distribution + coded DP
  progressive     layered (progressive-precision) linear layers for serving
"""

from repro.core import (  # noqa: F401
    coding,
    layering,
    layered_matmul,
    progressive,
    queueing,
    scheduling,
    simulator,
)
