"""Event simulation of the layered distributed coded computing system (§IV).

Reproduces the paper's evaluation: a master node with a FIFO queue of jobs
(Poisson arrivals), P heterogeneous workers (task time ~ Exp(mu_p / c) for a
task of complexity c), and a fusion node that needs any ``k`` of the
``k * omega`` coded task results per matrix-matrix multiplication.

Layered mode decomposes each job into ``m**2`` mini-jobs of complexity
``c / m**2`` each, executed round-by-round in MSB-first resolution order;
round r ends when the fusion holds k results for that mini-job, at which
point the master *purges* the round's outstanding tasks (workers are
immediately free — captured by sampling rounds independently).

Deadline semantics (paper §IV): a running job is terminated at
``t_term = max(service_start + deadline, next_job_arrival)`` if it has not
finished by then — i.e. termination requires BOTH the compute time to exceed
the deadline AND a queued successor.  The fusion then releases the highest
resolution whose rounds completed before ``t_term``.

All task-duration sampling is vectorised; only the O(num_jobs) queue
recursion is a Python loop.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from repro.core import layering, queueing, scheduling

__all__ = ["SystemConfig", "SimResult", "simulate", "PAPER_SYSTEM"]


@dataclasses.dataclass(frozen=True)
class SystemConfig:
    """Cluster + workload parameters (defaults = the paper's §IV setup)."""

    mu: tuple[float, ...] = (385.95, 650.92, 373.40, 415.75, 373.98)
    arrival_rate: float = 0.01        # Poisson job arrivals, lambda
    k: int = 1000                     # critical tasks per matmul
    complexity: float = 50.0          # per-task complexity, no layering
    m: int = 2                        # digit chunks -> L = 2m-1 layers
    omega: float = 1.06               # redundancy ratio
    gamma: float = 1.0                # eq. (1) moment trade-off

    @property
    def num_workers(self) -> int:
        return len(self.mu)

    @property
    def num_layers(self) -> int:
        return layering.num_layers(self.m)

    @property
    def minijob_complexity(self) -> float:
        # Each mini-job multiplies chunk matrices: 1/m**2 of the full work.
        return self.complexity / (self.m * self.m)

    @property
    def total_tasks(self) -> int:
        import math
        return math.ceil(self.k * self.omega)


PAPER_SYSTEM = SystemConfig()


@dataclasses.dataclass
class SimResult:
    """Per-job outcome arrays.

    ``layer_compute[j, l]`` is the compute time (from service start) at which
    resolution l of job j completed; for no-layering runs L == 1.
    ``delay[j, l] = service_start + layer_compute - arrival`` (inf if that
    resolution was cut off by termination).
    """

    arrivals: np.ndarray        # (J,)
    starts: np.ndarray          # (J,)
    ends: np.ndarray            # (J,)  service end (finish or termination)
    layer_compute: np.ndarray   # (J, L)
    success: np.ndarray         # (J, L) bool
    terminated: np.ndarray      # (J,)  bool
    kappa: np.ndarray           # (P,)  eq.(1) load split used

    @property
    def delay(self) -> np.ndarray:
        d = self.starts[:, None] + self.layer_compute - self.arrivals[:, None]
        return np.where(self.success, d, np.inf)

    @property
    def num_jobs(self) -> int:
        return len(self.arrivals)

    def mean_delay(self) -> np.ndarray:
        """Mean execution delay per resolution over successful jobs."""
        d = self.delay
        out = np.empty(d.shape[1])
        for l in range(d.shape[1]):
            ok = np.isfinite(d[:, l])
            out[l] = d[ok, l].mean() if ok.any() else np.inf
        return out

    def success_rate(self) -> np.ndarray:
        return self.success.mean(axis=0)

    def service_moments(self) -> queueing.Moments:
        """Empirical moments of the full (untruncated) service time."""
        ts = self.layer_compute[:, -1]
        return queueing.Moments(mean=float(ts.mean()),
                                second_moment=float((ts**2).mean()))


def _round_durations(rng: np.random.Generator, cfg: SystemConfig,
                     kappa: np.ndarray, num_jobs: int, rounds: int,
                     complexity: float, batch: int = 2048) -> np.ndarray:
    """(num_jobs, rounds) time for the fusion to collect k results per round.

    Worker p runs its kappa_p tasks sequentially (completion offsets are a
    cumulative sum of Exp(c / mu_p) draws); the round ends at the k-th
    smallest completion offset across all workers.  Workers whose queue is
    purged simply idle until the round boundary, matching the paper's
    master-paced, one-mini-job-at-a-time schedule.
    """
    k = cfg.k
    out = np.empty((num_jobs, rounds), dtype=np.float64)
    for lo in range(0, num_jobs, batch):
        hi = min(lo + batch, num_jobs)
        n = hi - lo
        streams = []
        for p, kp in enumerate(kappa):
            if kp == 0:
                continue
            scale = complexity / cfg.mu[p]
            t = rng.exponential(scale=scale, size=(n, rounds, int(kp)))
            streams.append(np.cumsum(t, axis=-1))
        merged = np.concatenate(streams, axis=-1)
        if merged.shape[-1] < k:
            raise ValueError(
                f"only {merged.shape[-1]} tasks assigned but k={k} needed")
        out[lo:hi] = np.partition(merged, k - 1, axis=-1)[..., k - 1]
    return out


def simulate(cfg: SystemConfig, num_jobs: int, *, layered: bool = True,
             deadline: float | None = None, seed: int = 0) -> SimResult:
    """Run the queueing simulation for ``num_jobs`` jobs."""
    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(rng.exponential(1.0 / cfg.arrival_rate,
                                         size=num_jobs))

    if layered:
        rounds = cfg.m * cfg.m
        complexity = cfg.minijob_complexity
        cum = np.asarray(layering.cumulative_minijobs(cfg.m))  # (L,)
    else:
        rounds = 1
        complexity = cfg.complexity
        cum = np.asarray([1])

    stats = [scheduling.worker_job_moments(mu, cfg.k, complexity)
             for mu in cfg.mu]
    kappa = scheduling.load_split(stats, cfg.total_tasks, cfg.gamma)

    durs = _round_durations(rng, cfg, kappa, num_jobs, rounds, complexity)
    round_ends = np.cumsum(durs, axis=1)            # (J, rounds)
    layer_compute = round_ends[:, cum - 1]          # (J, L)
    total_compute = round_ends[:, -1]               # (J,)

    starts = np.empty(num_jobs)
    ends = np.empty(num_jobs)
    terminated = np.zeros(num_jobs, dtype=bool)
    prev_end = 0.0
    for j in range(num_jobs):
        start = max(arrivals[j], prev_end)
        finish = start + total_compute[j]
        if deadline is not None and j + 1 < num_jobs:
            t_term = max(start + deadline, arrivals[j + 1])
            if finish > t_term:
                finish = t_term
                terminated[j] = True
        starts[j] = start
        ends[j] = finish
        prev_end = finish

    success = starts[:, None] + layer_compute <= ends[:, None] + 1e-12
    return SimResult(arrivals=arrivals, starts=starts, ends=ends,
                     layer_compute=layer_compute, success=success,
                     terminated=terminated, kappa=kappa)


def theory_bounds(cfg: SystemConfig, service: queueing.Moments,
                  layered: bool = True) -> np.ndarray:
    """Paper eqs. (2)-(4) lower bounds matching :func:`simulate`'s output.

    The queueing term uses the supplied (empirical) service moments; the
    computational term is the super-worker bound, per layer if layered.
    """
    # E[T_p] for one full job = k tasks of complexity c (Gamma mean).
    worker_means = [cfg.k * cfg.complexity / mu for mu in cfg.mu]
    arrival = queueing.Moments(mean=1.0 / cfg.arrival_rate,
                               second_moment=2.0 / cfg.arrival_rate**2)
    if layered:
        return queueing.layered_delay_bounds(cfg.m, worker_means, arrival,
                                             service)
    bound = 1.0 / queueing.service_rate_bound(worker_means)
    return np.asarray([queueing.gg1_delay(arrival, service,
                                          service_mean_override=bound)])
