"""Progressive-precision (layered) linear layers for deadline-bounded serving.

The paper's layered resolution, applied on-chip (DESIGN.md §3.1): weights
(and optionally activations) are digit-decomposed; computing digit planes
MSB-first means a valid approximate output exists after every plane — a
server hitting its deadline releases the best available resolution instead
of nothing.

Two modes:

* ``weight-only`` (production): only W is decomposed into ``m`` planes;
  activations stay float.  Resolution l uses planes ``m-1 .. m-1-l``:
  ``y_l = x @ (sum_{i >= m-1-l} W_i 2^{id}) * scale`` — m resolutions.
* ``two-sided`` (paper-faithful): both x and W are quantized and decomposed;
  mini-jobs follow Definition 1's anti-diagonals — ``2m-1`` resolutions.

`layered_lm_head` wires the weight-only mode into an LM's final projection,
the serving hot-spot where vocab-size matmuls dominate decode latency.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import layering

__all__ = [
    "LayeredLinear", "make_layered_linear", "layered_linear_apply",
    "two_sided_layered_matmul", "resolution_series", "plane_step",
]


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class LayeredLinear:
    """Digit-plane decomposed weight matrix.

    planes: (m, d_in, d_out) int8 digit planes (LSB at index 0; the top
            plane is signed, lower planes are unsigned d-bit digits stored
            in int8 -- valid for d <= 7, or d = 8 stored offset-free in
            int16 planes).
    scale:  float32 scalar; W ~= reconstruct(planes) * scale.
    d:      digit width in bits.
    """

    planes: jax.Array
    scale: jax.Array
    d: int = dataclasses.field(metadata=dict(static=True))

    @property
    def m(self) -> int:
        return self.planes.shape[0]

    @property
    def num_resolutions(self) -> int:
        return self.m


def make_layered_linear(w: jax.Array, *, m: int, d: int) -> LayeredLinear:
    """Quantize float weights (d_in, d_out) to m*d bits and decompose."""
    q, scale = layering.quantize(w, m * d)
    planes = layering.decompose(q, m, d)
    dtype = jnp.int8 if d <= 7 else jnp.int16
    return LayeredLinear(planes=planes.astype(dtype), scale=scale, d=d)


@functools.partial(jax.jit, static_argnames=("resolution",))
def layered_linear_apply(params: LayeredLinear, x: jax.Array,
                         resolution: Optional[int] = None) -> jax.Array:
    """``x @ W`` truncated to the given resolution (None = full).

    MSB-first partial sums: resolution l uses the top l+1 planes.  Uses one
    fused matmul over the selected planes (the Pallas kernel path computes
    the same contraction plane-by-plane with early exit; see
    ``repro.kernels``).
    """
    m = params.m
    l = m - 1 if resolution is None else resolution
    if not 0 <= l < m:
        raise ValueError(f"resolution {l} out of range (m={m})")
    top = [params.planes[i].astype(x.dtype) * float(1 << (i * params.d))
           for i in range(m - 1 - l, m)]
    w_eff = sum(top) * params.scale.astype(x.dtype)
    return x @ w_eff


def plane_step(params: LayeredLinear, x: jax.Array, l: int,
               acc: Optional[jax.Array] = None) -> jax.Array:
    """One MSB-first incremental step: add plane ``m-1-l``'s contribution.

    Returns the UNSCALED accumulator (multiply by ``params.scale`` for the
    resolution-``l`` output).  The single source of the per-plane math —
    :func:`resolution_series` and the deadline-bounded server
    (``repro.launch.serve``) both build on it.
    """
    i = params.m - 1 - l
    contrib = (x @ params.planes[i].astype(x.dtype)) * float(1 << (i * params.d))
    return contrib if acc is None else acc + contrib


def resolution_series(params: LayeredLinear, x: jax.Array) -> jax.Array:
    """All m weight-only resolutions, shape (m, *x.shape[:-1], d_out).

    Computed incrementally (one plane matmul per step), mirroring what a
    deadline-bounded server does; ``series[-1]`` equals the full-precision
    quantized product.
    """
    outs = []
    acc = None
    for l in range(params.m):
        acc = plane_step(params, x, l, acc)
        outs.append(acc * params.scale.astype(x.dtype))
    return jnp.stack(outs, axis=0)


@functools.partial(jax.jit, static_argnames=("m", "d"))
def two_sided_layered_matmul(x: jax.Array, w: jax.Array, *, m: int, d: int):
    """Paper-faithful two-sided layering of ``x @ w``; returns (L, ..., out).

    Both operands are quantized to ``m*d`` bits, digit-decomposed, and the
    m**2 mini-jobs are accumulated along Definition-1 anti-diagonals.
    Output resolutions are float32, rescaled to the original value range.
    """
    qx, sx = layering.quantize(x, m * d)
    qw, sw = layering.quantize(w, m * d)
    cx = layering.decompose(qx, m, d).astype(jnp.float32)
    cw = layering.decompose(qw, m, d).astype(jnp.float32)
    L = layering.num_layers(m)
    outs, acc = [], None
    for l in range(L):
        part = None
        for (i, j) in layering.layer_minijobs(m, l):
            prod = cx[i] @ cw[j] * float(1 << ((i + j) * d))
            part = prod if part is None else part + prod
        acc = part if acc is None else acc + part
        outs.append(acc)
    scale = (sx * sw).astype(jnp.float32)
    return jnp.stack(outs, axis=0) * scale


def layered_lm_head(params: LayeredLinear, hidden: jax.Array,
                    resolution: Optional[int] = None) -> jax.Array:
    """Progressive LM-head logits at the requested resolution."""
    return layered_linear_apply(params, hidden, resolution)
