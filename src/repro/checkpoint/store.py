"""Checkpointing: atomic save/restore of arbitrary pytrees + elastic resume.

Layout: ``<dir>/step_<k>/`` with one ``.npy`` per leaf (flattened key path
as filename) plus ``manifest.json`` (treedef + shapes + dtypes + step).
Writes go to a temp dir renamed into place (atomic on POSIX), so a crash
mid-save never corrupts the latest checkpoint.  ``AsyncCheckpointer``
snapshots device arrays to host, then writes on a worker thread so the train
loop resumes immediately (the standard TPU pattern).

Elastic resume: arrays are stored unsharded; ``restore`` takes an optional
``sharding_tree`` and ``jax.device_put``s each leaf with its (possibly new)
sharding — restoring a 16x16-trained checkpoint onto any other mesh shape is
the same code path.  Fault tolerance: ``install_sigterm_handler`` triggers a
final synchronous save on preemption.
"""

from __future__ import annotations

import json
import os
import re
import shutil
import signal
import tempfile
import threading
from typing import Any, Callable, Optional

import jax
import numpy as np

from repro.compat import tree_flatten_with_path

__all__ = ["save", "restore", "latest_step", "AsyncCheckpointer",
           "install_sigterm_handler"]


def _leafname(path) -> str:
    keys = []
    for p in path:
        if hasattr(p, "key"):
            keys.append(str(p.key))
        elif hasattr(p, "idx"):
            keys.append(str(p.idx))
        else:
            keys.append(str(p))
    name = "__".join(keys)
    return re.sub(r"[^A-Za-z0-9_.-]", "_", name)


def save(ckpt_dir: str, step: int, tree: Any) -> str:
    """Atomically write ``tree`` as ``<ckpt_dir>/step_<step>/``."""
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = tempfile.mkdtemp(dir=ckpt_dir, prefix=".tmp_")
    leaves, treedef = tree_flatten_with_path(tree)
    manifest = {"step": step, "leaves": []}
    for path, leaf in leaves:
        name = _leafname(path)
        arr = np.asarray(jax.device_get(leaf))
        np.save(os.path.join(tmp, name + ".npy"), arr)
        manifest["leaves"].append({"name": name,
                                   "dtype": str(arr.dtype),
                                   "shape": list(arr.shape)})
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
             if d.startswith("step_")]
    return max(steps) if steps else None


def restore(ckpt_dir: str, step: int, target_tree: Any,
            sharding_tree: Any = None) -> Any:
    """Load ``step_<step>`` into the structure of ``target_tree``.

    ``sharding_tree`` (same structure, jax.sharding.Sharding leaves or None)
    re-shards on load — elastic resume onto a different mesh.
    """
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    leaves, treedef = tree_flatten_with_path(target_tree)
    shardings = (jax.tree.leaves(sharding_tree)
                 if sharding_tree is not None else [None] * len(leaves))
    out = []
    for (path, leaf), shard in zip(leaves, shardings):
        arr = np.load(os.path.join(d, _leafname(path) + ".npy"))
        want = getattr(leaf, "shape", None)
        if want is not None and tuple(arr.shape) != tuple(want):
            raise ValueError(
                f"checkpoint leaf {_leafname(path)} shape {arr.shape} != "
                f"expected {want}")
        if shard is not None:
            out.append(jax.device_put(arr, shard))
        else:
            out.append(jax.device_put(arr))
    return treedef.unflatten(out)


class AsyncCheckpointer:
    """Snapshot-to-host + background write; at most one write in flight."""

    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def save(self, step: int, tree: Any) -> None:
        self.wait()
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)),
                                 tree)

        def work():
            try:
                save(self.ckpt_dir, step, host_tree)
                self._gc()
            except BaseException as e:  # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _gc(self) -> None:
        steps = sorted(s for s in (
            int(d.split("_")[1]) for d in os.listdir(self.ckpt_dir)
            if d.startswith("step_")))
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.ckpt_dir,
                                       f"step_{s:08d}"),
                          ignore_errors=True)


def install_sigterm_handler(fn: Callable[[], None]) -> None:
    """Run ``fn`` (e.g. a final synchronous checkpoint) on SIGTERM."""
    def handler(signum, frame):
        fn()
        raise SystemExit(143)
    signal.signal(signal.SIGTERM, handler)
