from repro.checkpoint import store  # noqa: F401
