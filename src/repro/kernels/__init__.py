"""Pallas TPU kernels (validated in interpret mode on CPU).

  layered_matmul    the paper's mini-job grid as one fused MXU pass
  flash_attention   blockwise causal attention (prefill hot-spot)
  ssd_scan          fused Mamba2 SSD chunk scan (VMEM-resident state)
ops.py holds the jit'd public wrappers; ref.py the pure-jnp oracles
(the SSD oracle is models/ssm.ssd_scan, itself tested against the naive
per-step recurrence).
"""

from repro.kernels import ops, ref  # noqa: F401
