"""jit'd public wrappers around the Pallas kernels.

``interpret`` defaults to True on CPU (this container) and False on real
TPUs, so the same call sites run everywhere; the kernels' BlockSpecs are
written for TPU VMEM either way.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import layering
from repro.kernels.flash_attention import flash_attention_kernel_call
from repro.kernels.layered_matmul import layered_matmul_kernel_call
from repro.kernels.ssd_scan import ssd_scan_kernel_call

__all__ = ["layered_matmul", "layered_matmul_partials", "flash_attention",
           "ssd_scan_fused", "default_interpret"]


def default_interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("m", "d", "interpret"))
def layered_matmul_partials(a: jax.Array, b: jax.Array, *, m: int = 2,
                            d: int = 7,
                            interpret: bool | None = None) -> jax.Array:
    """Exact int32 per-layer partials of ``a.T @ b`` (the worker compute).

    Decomposes integer a (K, M), b (K, N) into int8 digit planes (d <= 7 so
    unsigned digits fit int8) and runs the fused MXU kernel.  Row ``l`` is
    the unscaled layer-l partial sum -- exact as long as
    ``J(l) * K * (2^d - 1)^2 < 2^31``.
    """
    if interpret is None:
        interpret = default_interpret()
    if d > 7:
        raise ValueError("d <= 7 required for int8 digit planes")
    pa = layering.decompose(a.astype(jnp.int32), m, d).astype(jnp.int8)
    pb = layering.decompose(b.astype(jnp.int32), m, d).astype(jnp.int8)
    bm = 128 if a.shape[1] % 128 == 0 else a.shape[1]
    bn = 128 if b.shape[1] % 128 == 0 else b.shape[1]
    bk = 512 if a.shape[0] % 512 == 0 else a.shape[0]
    return layered_matmul_kernel_call(pa, pb, m=m, d=d, bm=bm, bn=bn, bk=bk,
                                      interpret=interpret)


@functools.partial(jax.jit, static_argnames=("m", "d", "interpret"))
def layered_matmul(a: jax.Array, b: jax.Array, *, m: int = 2, d: int = 7,
                   interpret: bool | None = None) -> jax.Array:
    """Layered Definition-1 resolutions of ``a.T @ b``.

    Kernel partials + fp32 fusion (scale by ``2**((i+j) d)`` + cumulative
    sum).  Returns (L, M, N) float32; the final row equals the exact
    product for magnitudes within fp32's 2^24 integer range -- callers
    needing bit-exact fusion use :func:`layered_matmul_partials` and fuse
    in int64/fp64 on the host.
    """
    partials = layered_matmul_partials(a, b, m=m, d=d, interpret=interpret)
    L = partials.shape[0]
    scales = jnp.asarray([float(1 << ((2 * m - 2 - l) * d))
                          for l in range(L)], jnp.float32)
    scaled = partials.astype(jnp.float32) * scales[:, None, None]
    return jnp.cumsum(scaled, axis=0)


@functools.partial(jax.jit,
                   static_argnames=("causal", "window", "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int | None = None,
                    interpret: bool | None = None) -> jax.Array:
    """Flash attention for (B, S, H, dh) tensors with GQA support.

    K/V may have fewer heads (n_kv); they are broadcast group-wise without
    materialising a repeat (reshape-only) before the kernel call.
    """
    if interpret is None:
        interpret = default_interpret()
    B, Sq, H, dh = q.shape
    _, Skv, n_kv, _ = k.shape
    G = H // n_kv
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, Sq, dh)
    kf = jnp.broadcast_to(k.transpose(0, 2, 1, 3)[:, :, None],
                          (B, n_kv, G, Skv, dh)).reshape(B * H, Skv, dh)
    vf = jnp.broadcast_to(v.transpose(0, 2, 1, 3)[:, :, None],
                          (B, n_kv, G, Skv, dh)).reshape(B * H, Skv, dh)
    bq = 512 if Sq % 512 == 0 else Sq
    bk = 512 if Skv % 512 == 0 else Skv
    out = flash_attention_kernel_call(qf, kf, vf, causal=causal,
                                      window=window, bq=bq, bk=bk,
                                      interpret=interpret)
    return out.reshape(B, H, Sq, dh).transpose(0, 2, 1, 3)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan_fused(x: jax.Array, dt: jax.Array, A: jax.Array,
                   Bm: jax.Array, Cm: jax.Array, *, chunk: int = 256,
                   interpret: bool | None = None):
    """Fused-SSD twin of ``repro.models.ssm.ssd_scan`` (G = 1 only).

    x (B, S, H, P), dt (B, S, H), A (H,), Bm/Cm (B, S, 1, N) ->
    (y (B, S, H, P) fp32, final_state (B, H, P, N) fp32).
    """
    if interpret is None:
        interpret = default_interpret()
    B, S, H, P = x.shape
    N = Bm.shape[-1]
    if S % chunk:
        raise ValueError(f"S={S} not divisible by chunk={chunk}")
    nc = S // chunk
    y, state = ssd_scan_kernel_call(
        x.reshape(B, nc, chunk, H, P), dt.reshape(B, nc, chunk, H), A,
        Bm.reshape(B, nc, chunk, N), Cm.reshape(B, nc, chunk, N),
        interpret=interpret)
    return y.reshape(B, S, H, P), state
