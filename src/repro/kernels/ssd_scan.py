"""Pallas TPU kernel: fused Mamba2 SSD chunk scan.

The jnp SSD path (models/ssm.py) materialises per-chunk (l x l) decay and
score matrices plus per-chunk states in HBM — on the mamba2 cells the
memory term, not compute, is the post-collective bottleneck
(EXPERIMENTS.md cell B).  This kernel keeps everything per-(batch, head)
in VMEM: the running (P x N) state lives in scratch across the chunk grid
dimension, and each grid step fuses

    intra:  y_d = (C B^T  ∘  L) · (dt x)          (l x l on the MXU)
    carry:  y_o = (C · state^T) ∘ exp(acum)
    state:  state <- state * exp(acum[-1]) + ((B ∘ decay)^T · dt x)^T

for one (b, h, chunk).  Grid: (B, H, nc) with nc innermost (sequential —
the state recurrence requires it; Pallas TPU iterates the trailing grid
dim fastest, so scratch carries correctly).

VMEM at defaults (l=256, P=64, N=128, fp32): x 64 KiB + B/C 128 KiB +
L/scores 512 KiB + state 32 KiB — comfortably resident.

Single-head-group form (G == 1, the Mamba2 default at these scales): B/C
are shared across heads, indexed per (b, chunk) only.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["ssd_scan_kernel_call"]


def _kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, state_out_ref,
            state_ref, *, chunk: int):
    nc_idx = pl.program_id(2)

    @pl.when(nc_idx == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0, 0, :, 0, :].astype(jnp.float32)      # (l, P)
    dt = dt_ref[0, 0, :, 0].astype(jnp.float32)       # (l,)
    A = a_ref[0].astype(jnp.float32)                  # scalar (negative)
    Bm = b_ref[0, 0].astype(jnp.float32)              # (l, N)
    Cm = c_ref[0, 0].astype(jnp.float32)              # (l, N)

    xdt = x * dt[:, None]                             # (l, P)
    adt = A * dt                                      # (l,)
    acum = jnp.cumsum(adt)                            # (l,)

    # intra-chunk: L[i, j] = exp(acum_i - acum_j) for i >= j else 0
    li = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    lj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    diff = acum[:, None] - acum[None, :]
    Lmat = jnp.where(li >= lj, jnp.exp(diff), 0.0)    # (l, l)
    scores = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    y_diag = jax.lax.dot(scores * Lmat, xdt,
                         preferred_element_type=jnp.float32)  # (l, P)

    # carried-state contribution
    state = state_ref[...]                            # (P, N)
    y_off = jax.lax.dot_general(Cm, state, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
    y_off = y_off * jnp.exp(acum)[:, None]            # (l, P)

    y_ref[0, 0, :, 0, :] = (y_diag + y_off).astype(y_ref.dtype)

    # state update: decay to chunk end, add chunk contribution
    decay = jnp.exp(acum[-1] - acum)                  # (l,)
    contrib = jax.lax.dot_general(xdt * decay[:, None], Bm,
                                  (((0,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)
    state_ref[...] = state * jnp.exp(acum[-1]) + contrib      # (P, N)
    state_out_ref[0, 0, :, :] = state_ref[...]


@functools.partial(jax.jit, static_argnames=("interpret",))
def ssd_scan_kernel_call(x: jax.Array, dt: jax.Array, A: jax.Array,
                         Bm: jax.Array, Cm: jax.Array, *,
                         interpret: bool = False):
    """Fused SSD over chunked inputs.

    x:  (B, nc, l, H, P)   dt: (B, nc, l, H)   A: (H,)
    Bm, Cm: (B, nc, l, N)  (G = 1: shared across heads)
    Returns (y (B, nc, l, H, P) float32, final_state (B, H, P, N) float32).
    """
    Bsz, nc, l, H, P = x.shape
    N = Bm.shape[-1]
    grid = (Bsz, H, nc)
    y, state = pl.pallas_call(
        functools.partial(_kernel, chunk=l),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, l, 1, P), lambda b, h, c: (b, c, 0, h, 0)),
            pl.BlockSpec((1, 1, l, 1), lambda b, h, c: (b, c, 0, h)),
            pl.BlockSpec((1,), lambda b, h, c: (h,)),
            pl.BlockSpec((1, 1, l, N), lambda b, h, c: (b, c, 0, 0)),
            pl.BlockSpec((1, 1, l, N), lambda b, h, c: (b, c, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, l, 1, P), lambda b, h, c: (b, c, 0, h, 0)),
            pl.BlockSpec((1, 1, P, N), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bsz, nc, l, H, P), jnp.float32),
            jax.ShapeDtypeStruct((Bsz, H, P, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        interpret=interpret,
    )(x, dt, A, Bm, Cm)
    return y, state
