"""Pallas TPU kernel: layered-resolution int8 matmul (the paper's mini-job
grid as ONE fused MXU pass).

TPU-native rethinking of §III (DESIGN.md §6): instead of shipping the m**2
digit-plane mini-jobs ``A_i^T B_j`` to separate workers, the kernel walks
the anti-diagonals **MSB-first inside the systolic array's dataflow**: for
each (M, N) output tile it accumulates the plane-pair products layer by
layer into an (L, bm, bn) VMEM tile, so after layer ``l``'s planes the tile
already holds a *valid Definition-1 resolution*.  A deadline-bounded server
reads resolution ``l`` from output row ``l`` — the early-release semantics
come for free from the accumulation order.

Grid: ``(M/bm, N/bn, K/bk)`` with the K axis innermost (sequential
accumulation into the output tile, standard Pallas matmul pattern).  Planes
are int8 (use digit width d <= 7 so unsigned digits fit int8); per-plane
products run on the MXU via ``preferred_element_type=int32`` and are scaled
into the fp32 accumulator by ``2**((i+j) d)``.

VMEM per step (defaults bm=bn=128, bk=512, m=2):
  A tile  m*bk*bm  int8 = 128 KiB       B tile  m*bk*bn int8 = 128 KiB
  out     L*bm*bn  fp32 = 192 KiB       -- comfortably inside 16 MiB VMEM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import layering

__all__ = ["layered_matmul_kernel_call"]


def _kernel(a_ref, b_ref, out_ref, *, m: int, d: int, nk: int):
    """One (mi, ni, ki) grid step.

    a_ref: (m, bk, bm) int8    b_ref: (m, bk, bn) int8
    out_ref: (L, bm, bn) int32, accumulated across ki.

    Emits EXACT per-layer partial sums ``sum_{i+j = 2m-2-l} A_i^T B_j``
    (unscaled, non-cumulative): the fusion applies the ``2**((i+j) d)``
    scales and the cumulative sum (ops.py), exactly mirroring the paper's
    worker/fusion split.  int32 is exact for J(l)*K*(2^d-1)^2 < 2^31 —
    e.g. d=7, K <= 32768, J <= 4.
    """
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    L = 2 * m - 1
    for l in range(L):
        part = jnp.zeros(out_ref.shape[1:], jnp.int32)
        for (i, j) in layering.layer_minijobs(m, l):
            prod = jax.lax.dot_general(
                a_ref[i], b_ref[j],
                dimension_numbers=(((0,), (0,)), ((), ())),
                preferred_element_type=jnp.int32)
            part = part + prod
        out_ref[l, :, :] += part


@functools.partial(jax.jit,
                   static_argnames=("m", "d", "bm", "bn", "bk", "interpret"))
def layered_matmul_kernel_call(a_planes: jax.Array, b_planes: jax.Array, *,
                               m: int, d: int, bm: int = 128, bn: int = 128,
                               bk: int = 512,
                               interpret: bool = False) -> jax.Array:
    """Exact per-layer partial sums of ``A^T B`` from int8 digit planes.

    a_planes: (m, K, M) int8   b_planes: (m, K, N) int8
    Returns (L, M, N) int32; row ``l`` holds the UNSCALED layer-l partial
    ``sum_{i+j = 2m-2-l} A_i^T B_j`` — the fusion step (ops.layered_matmul)
    applies ``2**((i+j) d)`` and the cumulative sum.
    """
    mm, K, M = a_planes.shape
    _, _, N = b_planes.shape
    if mm != m or b_planes.shape[0] != m:
        raise ValueError(f"plane count mismatch: {a_planes.shape} vs m={m}")
    bm, bn, bk = min(bm, M), min(bn, N), min(bk, K)
    if M % bm or N % bn or K % bk:
        raise ValueError(f"dims ({M},{N},{K}) not divisible by blocks "
                         f"({bm},{bn},{bk})")
    L = 2 * m - 1
    grid = (M // bm, N // bn, K // bk)
    return pl.pallas_call(
        functools.partial(_kernel, m=m, d=d, nk=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((m, bk, bm), lambda mi, ni, ki: (0, ki, mi)),
            pl.BlockSpec((m, bk, bn), lambda mi, ni, ki: (0, ki, ni)),
        ],
        out_specs=pl.BlockSpec((L, bm, bn), lambda mi, ni, ki: (0, mi, ni)),
        out_shape=jax.ShapeDtypeStruct((L, M, N), jnp.int32),
        # M/N output tiles are independent (megacore-parallel); the K axis
        # accumulates into the output tile and must stay sequential.
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(a_planes, b_planes)
