"""Pallas TPU kernel: blockwise causal flash attention (online softmax).

The prefill_32k hot-spot: materialising S x S attention scores at S = 32768
is 2 GiB per (batch, head) in fp32 — this kernel never materialises more
than a (bq, bk) tile.  Standard flash-attention recurrence with running
max/sum in VMEM scratch; the K/V axis is the innermost grid dimension so
the output tile accumulates across K blocks.

Causality is exploited structurally: K blocks strictly above the diagonal
are skipped with ``pl.when`` (no MXU work), halving compute for causal
masks.  Sliding-window masks reuse the same in-tile position mask.

Grid: (B*H, Sq/bq, Skv/bk); scratch: m (bq,1), l (bq,1), acc (bq, dh).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["flash_attention_kernel_call"]

_NEG_INF = -0.7 * float(np.finfo(np.float32).max)


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            scale: float, causal: bool, window: int | None,
            bq: int, bk: int, nk: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = qi * bq
    k_start = ki * bk

    def needed() -> bool | jax.Array:
        if not causal:
            return True
        return k_start <= q_start + bq - 1

    @pl.when(needed())
    def _step():
        q = q_ref[0].astype(jnp.float32)              # (bq, dh)
        k = k_ref[0].astype(jnp.float32)              # (bk, dh)
        v = v_ref[0].astype(jnp.float32)              # (bk, dh)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        ok = jnp.ones((bq, bk), jnp.bool_)
        if causal:
            ok = ok & (kpos <= qpos)
        if window is not None:
            ok = ok & (kpos > qpos - window)
        s = jnp.where(ok, s, _NEG_INF)

        m_prev = m_ref[...]                            # (bq, 1)
        m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)                         # (bq, bk)
        corr = jnp.exp(m_prev - m_new)                 # (bq, 1)
        l_ref[...] = l_ref[...] * corr + p.sum(axis=-1, keepdims=True)
        acc_ref[...] = (acc_ref[...] * corr
                        + jax.lax.dot(p.astype(v.dtype), v,
                                      preferred_element_type=jnp.float32))
        m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _finish():
        denom = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, :, :] = (acc_ref[...] / denom).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("causal", "window", "bq", "bk", "interpret"))
def flash_attention_kernel_call(q: jax.Array, k: jax.Array, v: jax.Array, *,
                                causal: bool = True,
                                window: int | None = None,
                                bq: int = 512, bk: int = 512,
                                interpret: bool = False) -> jax.Array:
    """Attention over (BH, S, dh) tensors (batch*heads flattened).

    q: (BH, Sq, dh), k/v: (BH, Skv, dh) -> (BH, Sq, dh), q.dtype.
    GQA: repeat/reshape K,V to q's head count before calling (the jnp ops.py
    wrapper handles the grouping).
    """
    BH, Sq, dh = q.shape
    _, Skv, _ = k.shape
    bq, bk = min(bq, Sq), min(bk, Skv)
    if Sq % bq or Skv % bk:
        raise ValueError(f"seq dims ({Sq},{Skv}) not divisible by ({bq},{bk})")
    nk = Skv // bk
    scale = 1.0 / float(np.sqrt(dh))
    grid = (BH, Sq // bq, nk)
    return pl.pallas_call(
        functools.partial(_kernel, scale=scale, causal=causal, window=window,
                          bq=bq, bk=bk, nk=nk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, dh), lambda b, qi, ki: (b, qi, 0)),
            pl.BlockSpec((1, bk, dh), lambda b, qi, ki: (b, ki, 0)),
            pl.BlockSpec((1, bk, dh), lambda b, qi, ki: (b, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, dh), lambda b, qi, ki: (b, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Sq, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),    # running max m
            pltpu.VMEM((bq, 1), jnp.float32),    # running denom l
            pltpu.VMEM((bq, dh), jnp.float32),   # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)
