"""Pure-jnp/NumPy oracles for every Pallas kernel (the allclose targets)."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import layering

__all__ = ["layered_matmul_ref", "flash_attention_ref"]


def layered_matmul_ref(a_planes, b_planes, *, d: int) -> np.ndarray:
    """(m, K, M) x (m, K, N) int planes -> (L, M, N) float64 resolutions.

    Host NumPy, exact: the same Definition-1 cumulative anti-diagonal sums
    the kernel accumulates.
    """
    a = np.asarray(a_planes, dtype=np.int64)
    b = np.asarray(b_planes, dtype=np.int64)
    m = a.shape[0]
    L = layering.num_layers(m)
    M, N = a.shape[2], b.shape[2]
    out = np.zeros((L, M, N), dtype=np.float64)
    running = np.zeros((M, N), dtype=np.float64)
    for l in range(L):
        for (i, j) in layering.layer_minijobs(m, l):
            prod = a[i].T @ b[j]
            running = running + prod.astype(np.float64) * float(
                1 << ((i + j) * d))
        out[l] = running
    return out


def flash_attention_ref(q, k, v, *, causal: bool = True,
                        window: int | None = None) -> jax.Array:
    """Naive softmax attention over (BH, S, dh); fp32 math, q.dtype out."""
    q32 = jnp.asarray(q, jnp.float32)
    k32 = jnp.asarray(k, jnp.float32)
    v32 = jnp.asarray(v, jnp.float32)
    scale = 1.0 / np.sqrt(q.shape[-1])
    s = jnp.einsum("bqd,bkd->bqk", q32, k32) * scale
    Sq, Skv = s.shape[-2], s.shape[-1]
    qpos = jnp.arange(Sq)[:, None]
    kpos = jnp.arange(Skv)[None, :]
    ok = jnp.ones((Sq, Skv), bool)
    if causal:
        ok = ok & (kpos <= qpos)
    if window is not None:
        ok = ok & (kpos > qpos - window)
    s = jnp.where(ok[None], s, -0.7 * np.finfo(np.float32).max)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, v32).astype(q.dtype)
