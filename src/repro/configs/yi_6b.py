"""yi-6b [arXiv:2403.04652] — llama-architecture dense GQA.

32L d_model=4096 32H (GQA kv=4) d_ff=11008 vocab=64000, rope 5M.
"""
import dataclasses

from repro.configs.base import AttentionConfig, ModelConfig

CONFIG = ModelConfig(
    name="yi-6b",
    family="dense",
    num_layers=32,
    d_model=4096,
    d_ff=11008,
    vocab_size=64_000,
    attention=AttentionConfig(num_heads=32, num_kv_heads=4, head_dim=128,
                              rope_theta=5_000_000.0),
    tie_embeddings=False,
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, num_layers=2, d_model=64, d_ff=160, vocab_size=512,
        attention=AttentionConfig(num_heads=4, num_kv_heads=2, head_dim=16))
