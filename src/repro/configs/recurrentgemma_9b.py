"""recurrentgemma-9b [arXiv:2402.19427] — RG-LRU + local attention, 2:1.

38L d_model=4096; pattern (R, R, A) x 12 + (R, R): 26 recurrent + 12
local-attention layers.  Attention is MQA (16H kv=1, head_dim 256) with a
2048-token sliding window; d_ff=12288 (GeGLU-style), vocab=256000.
Sub-quadratic (bounded state): runs long_500k.
"""
import dataclasses

from repro.configs.base import AttentionConfig, ModelConfig, RGLRUConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    num_layers=38,
    d_model=4096,
    d_ff=12288,
    vocab_size=256_000,
    attention=AttentionConfig(num_heads=16, num_kv_heads=1, head_dim=256,
                              rope_theta=10_000.0, window=2048),
    rglru=RGLRUConfig(d_rnn=4096, d_conv=4, window=2048),
    tie_embeddings=True,
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, num_layers=5, d_model=64, d_ff=128, vocab_size=512,
        attention=AttentionConfig(num_heads=4, num_kv_heads=1, head_dim=16,
                                  window=8),
        rglru=RGLRUConfig(d_rnn=64, d_conv=4, window=8))
