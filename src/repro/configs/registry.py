"""Architecture registry: ``--arch <id>`` resolution + per-cell input specs.

``input_specs(cfg, shape)`` returns ``(kind, specs)`` where ``specs`` is a
dict of ``jax.ShapeDtypeStruct`` stand-ins for every input of the step
function that the cell lowers — weak-type-correct and shardable, with **no
device allocation** (the full configs are only ever exercised through
``.lower()``; real arrays exist only for smoke/reduced configs).
"""

from __future__ import annotations

import importlib
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import SHAPES, ModelConfig, ShapeConfig

__all__ = ["ARCH_IDS", "get_config", "get_smoke_config", "shape_cells",
           "input_specs", "cache_specs"]

ARCH_IDS: dict[str, str] = {
    "qwen2-moe-a2.7b": "qwen2_moe_a2_7b",
    "llama4-maverick-400b-a17b": "llama4_maverick_400b_a17b",
    "internvl2-1b": "internvl2_1b",
    "mamba2-370m": "mamba2_370m",
    "llama3-8b": "llama3_8b",
    "yi-6b": "yi_6b",
    "glm4-9b": "glm4_9b",
    "starcoder2-7b": "starcoder2_7b",
    "whisper-tiny": "whisper_tiny",
    "recurrentgemma-9b": "recurrentgemma_9b",
}


def _module(arch: str):
    if arch not in ARCH_IDS:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(ARCH_IDS)}")
    return importlib.import_module(f"repro.configs.{ARCH_IDS[arch]}")


def get_config(arch: str) -> ModelConfig:
    return _module(arch).CONFIG


def get_smoke_config(arch: str) -> ModelConfig:
    return _module(arch).smoke_config()


def shape_cells(cfg: ModelConfig) -> list[str]:
    """Applicable input-shape cells for this architecture.

    ``long_500k`` needs sub-quadratic sequence mixing — skipped for pure
    full-attention archs (see DESIGN.md §Arch-applicability).  All ten archs
    bear a decoder, so decode shapes always apply.
    """
    cells = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.sub_quadratic:
        cells.append("long_500k")
    return cells


def _sds(shape, dtype) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(shape, jnp.dtype(dtype))


def cache_specs(cfg: ModelConfig, batch: int, max_len: int):
    """ShapeDtypeStructs for the decode caches (no allocation)."""
    from repro.models import transformer as T

    caches = jax.eval_shape(
        lambda: T.init_cache(cfg, batch, max_len))
    if not cfg.is_encdec:
        return caches

    def enc_kv_shapes():
        dt = cfg.cdtype()
        a = cfg.attention
        kvs = []
        from repro.models.transformer import block_groups
        for (unit, reps) in block_groups(cfg):
            for _ in unit:
                shp = (reps, batch, cfg.encoder_seq, a.num_kv_heads,
                       a.head_dim)
                kvs.append((jnp.zeros(shp, dt), jnp.zeros(shp, dt)))
        return kvs

    enc_kvs = jax.eval_shape(enc_kv_shapes)
    return (caches, enc_kvs)


def input_specs(cfg: ModelConfig, shape: ShapeConfig | str) -> tuple[str, dict]:
    """(kind, specs) for the step function this (arch x shape) cell lowers.

    kind == "train":   train_step(params, opt_state, batch) — specs = batch
    kind == "prefill": prefill_step(params, batch)
    kind == "decode":  serve_step(params, batch) with KV/state caches inside
    """
    if isinstance(shape, str):
        shape = SHAPES[shape]
    B, S = shape.global_batch, shape.seq_len
    specs: dict[str, Any] = {}

    if shape.kind in ("train", "prefill"):
        specs["tokens"] = _sds((B, S), jnp.int32)
        if shape.kind == "train":
            specs["targets"] = _sds((B, S), jnp.int32)
        if cfg.num_image_tokens:
            specs["extra_embeds"] = _sds(
                (B, cfg.num_image_tokens, cfg.d_model), cfg.cdtype())
        if cfg.is_encdec:
            specs["audio_embeds"] = _sds(
                (B, cfg.encoder_seq, cfg.d_model), cfg.cdtype())
        return shape.kind, specs

    # decode: one new token against caches of length S
    specs["token"] = _sds((B, 1), jnp.int32)
    specs["pos"] = _sds((), jnp.int32)
    specs["caches"] = cache_specs(cfg, B, S)
    return "decode", specs
