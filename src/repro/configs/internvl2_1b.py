"""internvl2-1b [arXiv:2404.16821] — InternViT frontend + Qwen2-0.5B LM.

LM backbone: 24L d_model=896 14H (GQA kv=2) d_ff=4864 vocab=151655.
The ViT frontend is a STUB per the assignment: input_specs() provides
precomputed patch embeddings for the first ``num_image_tokens`` positions.
"""
import dataclasses

from repro.configs.base import AttentionConfig, ModelConfig

CONFIG = ModelConfig(
    name="internvl2-1b",
    family="vlm",
    num_layers=24,
    d_model=896,
    d_ff=4864,
    vocab_size=151_680,   # 151655 padded to /256 for even vocab sharding
    attention=AttentionConfig(num_heads=14, num_kv_heads=2, head_dim=64,
                              rope_theta=1_000_000.0),
    tie_embeddings=True,
    num_image_tokens=256,
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, num_layers=2, d_model=64, d_ff=128, vocab_size=512,
        attention=AttentionConfig(num_heads=4, num_kv_heads=2, head_dim=16),
        num_image_tokens=8)
