"""llama4-maverick-400b-a17b [hf:meta-llama/Llama-4-*; unverified].

48L d_model=5120 40H (GQA kv=8) d_ff=8192 vocab=202048; MoE 128 routed
experts top-1 + one shared 8192 expert, interleaved every other layer
(dense, moe, dense, moe, ...) per the Maverick interleave_moe_layer_step=2.
Total params ~400B, active ~17B/token.
"""
import dataclasses

from repro.configs.base import AttentionConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    num_layers=48,
    d_model=5120,
    d_ff=8192,
    vocab_size=202_048,
    attention=AttentionConfig(num_heads=40, num_kv_heads=8, head_dim=128,
                              rope_theta=500_000.0),
    moe=MoEConfig(num_experts=128, top_k=1, d_ff_expert=8192,
                  d_ff_shared=8192, capacity_factor=1.25,
                  interleave_step=2),
    tie_embeddings=False,
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, num_layers=4, d_model=64, d_ff=128, vocab_size=512,
        attention=AttentionConfig(num_heads=8, num_kv_heads=2, head_dim=8),
        moe=MoEConfig(num_experts=8, top_k=1, d_ff_expert=128,
                      d_ff_shared=128, capacity_factor=2.0,
                      interleave_step=2))
