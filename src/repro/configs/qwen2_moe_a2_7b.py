"""qwen2-moe-a2.7b — Qwen1.5-MoE-A2.7B [hf:Qwen/Qwen1.5-MoE-A2.7B].

24L d_model=2048 16H (MHA, kv=16) vocab=151936; MoE: 60 routed experts
(d_ff_expert=1408) top-4 + shared expert of 5632 (= "4 shared" experts of
1408, fused as one SwiGLU, matching the HF shared_expert_intermediate_size).
"""
import dataclasses

from repro.configs.base import AttentionConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b",
    family="moe",
    num_layers=24,
    d_model=2048,
    d_ff=1408,                      # = expert hidden (informational)
    vocab_size=151_936,
    attention=AttentionConfig(num_heads=16, num_kv_heads=16, head_dim=128,
                              rope_theta=1_000_000.0),
    moe=MoEConfig(num_experts=60, top_k=4, d_ff_expert=1408,
                  d_ff_shared=5632, capacity_factor=1.25),
    tie_embeddings=False,
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, num_layers=2, d_model=64, d_ff=96, vocab_size=512,
        attention=AttentionConfig(num_heads=4, num_kv_heads=4, head_dim=16),
        moe=MoEConfig(num_experts=8, top_k=4, d_ff_expert=96, d_ff_shared=128,
                      capacity_factor=2.0))
