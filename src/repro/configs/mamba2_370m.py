"""mamba2-370m [arXiv:2405.21060] — SSD (state-space duality), attn-free.

48L d_model=1024 vocab=50280; d_state=128, expand=2 (d_inner=2048),
head_dim=64 (32 SSD heads), conv width 4.  Sub-quadratic: runs long_500k.
"""
import dataclasses

from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-370m",
    family="ssm",
    num_layers=48,
    d_model=1024,
    d_ff=0,
    vocab_size=50_288,    # 50280 padded to /16 for even vocab sharding
    attention=None,
    ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=64,
                  chunk_size=256),
    tie_embeddings=True,
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, num_layers=2, d_model=64, vocab_size=512,
        ssm=SSMConfig(d_state=16, d_conv=4, expand=2, head_dim=16,
                      chunk_size=8))
