"""Config system: model/architecture configs, input shapes, smoke reductions.

Every assigned architecture gets a ``configs/<id>.py`` exporting
``CONFIG`` (exact published hyper-parameters) and ``smoke_config()`` (a
reduced same-family config for CPU tests).  ``repro.configs.registry``
resolves ``--arch <id>`` strings.

Input-shape cells (LM family): ``train_4k``, ``prefill_32k``, ``decode_32k``,
``long_500k`` — see ``SHAPES``.  ``decode_*``/``long_*`` lower one
``serve_step`` (single new token against a KV/state cache of the given
length); ``long_500k`` only applies to sub-quadratic archs.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import jax.numpy as jnp

__all__ = [
    "AttentionConfig", "MoEConfig", "SSMConfig", "RGLRUConfig",
    "ModelConfig", "ShapeConfig", "SHAPES", "TrainConfig",
]


@dataclasses.dataclass(frozen=True)
class AttentionConfig:
    num_heads: int
    num_kv_heads: int
    head_dim: int
    rope_theta: float = 10_000.0
    window: Optional[int] = None          # sliding-window size (local attn)
    causal: bool = True
    qk_norm: bool = False
    attn_logit_softcap: Optional[float] = None

    @property
    def q_dim(self) -> int:
        return self.num_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.head_dim

    @property
    def group_size(self) -> int:
        assert self.num_heads % self.num_kv_heads == 0, \
            f"heads {self.num_heads} not a multiple of kv {self.num_kv_heads}"
        return self.num_heads // self.num_kv_heads


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff_expert: int                      # per-expert hidden size
    d_ff_shared: int = 0                  # shared-expert hidden size (0 = none)
    capacity_factor: float = 1.25
    router_noise: float = 0.0
    interleave_step: int = 1              # every n-th layer is MoE (1 = all)
    dispatch_group: int = 4096            # tokens per dispatch group (GShard G)
    # 1 -> all layers MoE; 2 -> layers 1,3,5,... MoE (llama4-style)


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    chunk_size: int = 256

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def num_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclasses.dataclass(frozen=True)
class RGLRUConfig:
    d_rnn: Optional[int] = None           # None -> d_model
    d_conv: int = 4
    block_pattern: tuple[str, ...] = ("R", "R", "A")  # Griffin 2:1
    window: int = 2048                    # local-attention window


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                           # dense|moe|ssm|hybrid|vlm|audio
    num_layers: int
    d_model: int
    d_ff: int
    vocab_size: int
    attention: Optional[AttentionConfig] = None
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    rglru: Optional[RGLRUConfig] = None
    activation: str = "silu"              # silu (SwiGLU) | gelu (plain MLP)
    norm: str = "rmsnorm"                 # rmsnorm | layernorm
    tie_embeddings: bool = False
    # enc-dec (audio) extras
    encoder_layers: int = 0
    encoder_seq: int = 0                  # stub frontend sequence length
    # vlm extras
    num_image_tokens: int = 0             # stub patch-embedding positions
    # numerics
    compute_dtype: str = "bfloat16"
    param_dtype: str = "float32"
    kv_cache_dtype: str = ""              # "" = compute dtype; "int8" packs
    # the KV cache at 2x density (static scale; see models/transformer.py)
    # scan/remat
    remat_policy: str = "minimal"         # none|minimal|full
    scan_layers: bool = True
    # layered-resolution serving (the paper's technique)
    layered_lm_head: bool = False
    layered_m: int = 2
    layered_d: int = 7

    @property
    def sub_quadratic(self) -> bool:
        """Can this arch decode at 500k context (O(1)-ish state)?"""
        return self.family in ("ssm", "hybrid")

    @property
    def is_encdec(self) -> bool:
        return self.encoder_layers > 0

    def cdtype(self):
        return jnp.dtype(self.compute_dtype)

    def pdtype(self):
        return jnp.dtype(self.param_dtype)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                             # "train" | "prefill" | "decode"


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    optimizer: str = "adamw"              # adamw | adafactor
    learning_rate: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 1_000
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    b1: float = 0.9
    b2: float = 0.95
    # coded data parallelism across pods (the paper's erasure story)
    coded_dp: bool = False
    coded_dp_k: int = 0                   # 0 -> n_pods (no redundancy)
    # layered gradient all-reduce (beyond-paper)
    layered_grad_planes: int = 0          # 0 = off
    # cast fp32 master weights to compute dtype BEFORE use so FSDP
    # all-gathers move bf16 (see EXPERIMENTS.md §Perf)
    bf16_weight_gather: bool = False
    # differentiate wrt the bf16 weight copy so the gradient
    # reduce-scatter also moves bf16; grads are cast to fp32 after the
    # reduction for the optimizer update
    bf16_grads: bool = False
