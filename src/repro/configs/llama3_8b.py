"""llama3-8b [arXiv:2407.21783] — dense GQA with 128k vocab.

32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=128256, rope 500k.
"""
import dataclasses

from repro.configs.base import AttentionConfig, ModelConfig

CONFIG = ModelConfig(
    name="llama3-8b",
    family="dense",
    num_layers=32,
    d_model=4096,
    d_ff=14336,
    vocab_size=128_256,
    attention=AttentionConfig(num_heads=32, num_kv_heads=8, head_dim=128,
                              rope_theta=500_000.0),
    tie_embeddings=False,
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, num_layers=2, d_model=64, d_ff=192, vocab_size=512,
        attention=AttentionConfig(num_heads=4, num_kv_heads=1, head_dim=16))
