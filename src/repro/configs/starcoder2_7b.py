"""starcoder2-7b [arXiv:2402.19173] — GQA, RoPE, GELU MLP, layernorm.

32L d_model=4608 36H (GQA kv=4) d_ff=18432 vocab=49152.
"""
import dataclasses

from repro.configs.base import AttentionConfig, ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-7b",
    family="dense",
    num_layers=32,
    d_model=4608,
    d_ff=18432,
    vocab_size=49_152,
    attention=AttentionConfig(num_heads=36, num_kv_heads=4, head_dim=128,
                              rope_theta=100_000.0),
    activation="gelu",
    norm="layernorm",
    tie_embeddings=True,
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, num_layers=2, d_model=64, d_ff=256, vocab_size=512,
        attention=AttentionConfig(num_heads=4, num_kv_heads=2, head_dim=16))
