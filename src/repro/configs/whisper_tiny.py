"""whisper-tiny [arXiv:2212.04356] — encoder-decoder; conv frontend stubbed.

4 encoder + 4 decoder layers, d_model=384 6H (MHA) d_ff=1536 vocab=51865,
layernorm + GELU.  The audio conv frontend is a STUB per the assignment:
input_specs() provides precomputed frame embeddings (B, 1500, 384).
Deviation noted in DESIGN.md: sinusoidal/rope positions instead of
Whisper's learned 448-position table so the assigned 4k/32k shapes lower.
"""
import dataclasses

from repro.configs.base import AttentionConfig, ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny",
    family="audio",
    num_layers=4,                     # decoder layers
    d_model=384,
    d_ff=1536,
    vocab_size=51_872,    # 51865 padded to /16 for even vocab sharding
    attention=AttentionConfig(num_heads=6, num_kv_heads=6, head_dim=64),
    activation="gelu",
    norm="layernorm",
    tie_embeddings=True,
    encoder_layers=4,
    encoder_seq=1500,
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, num_layers=2, d_model=64, d_ff=128, vocab_size=512,
        attention=AttentionConfig(num_heads=4, num_kv_heads=4, head_dim=16),
        encoder_layers=2, encoder_seq=24)
