"""glm4-9b [hf:THUDM/glm-4-9b] — RoPE + deep GQA-2.

40L d_model=4096 32H (GQA kv=2) d_ff=13696 vocab=151552.
"""
import dataclasses

from repro.configs.base import AttentionConfig, ModelConfig

CONFIG = ModelConfig(
    name="glm4-9b",
    family="dense",
    num_layers=40,
    d_model=4096,
    d_ff=13696,
    vocab_size=151_552,
    attention=AttentionConfig(num_heads=32, num_kv_heads=2, head_dim=128,
                              rope_theta=10_000.0),
    tie_embeddings=False,
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, num_layers=2, d_model=64, d_ff=160, vocab_size=512,
        attention=AttentionConfig(num_heads=4, num_kv_heads=2, head_dim=16))
