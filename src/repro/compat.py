"""Version-tolerant shims over the pinned JAX's moved/renamed APIs.

The container pins jax 0.4.37, where some of the newer aliases this code
was written against do not exist yet:

* ``jax.tree.flatten_with_path`` landed after 0.4.37; the functionality
  has lived in ``jax.tree_util.tree_flatten_with_path`` since 0.4.6.
* ``jax.shard_map`` (top-level) is newer than the pinned version; the
  implementation is ``jax.experimental.shard_map.shard_map``.

Each shim prefers the modern spelling when present (so nothing changes on
a newer JAX) and falls back to the stable long-form path otherwise.  Keep
this module dependency-free besides jax itself — it sits below everything
in the import graph.
"""

from __future__ import annotations

import jax
import jax.tree_util

__all__ = ["tree_flatten_with_path", "shard_map"]

tree_flatten_with_path = getattr(
    getattr(jax, "tree", None), "flatten_with_path", None)
if tree_flatten_with_path is None:
    tree_flatten_with_path = jax.tree_util.tree_flatten_with_path

shard_map = getattr(jax, "shard_map", None)
if shard_map is None:
    from jax.experimental.shard_map import shard_map  # noqa: F401
