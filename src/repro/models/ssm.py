"""Mamba2 (SSD — state-space duality) block, chunked scan + decode step.

Training/prefill uses the SSD chunked algorithm (Dao & Gu, arXiv:2405.21060):
the sequence is split into chunks of ``chunk_size``; each chunk computes a
dense intra-chunk (quadratic-in-chunk) term plus an inter-chunk linear
recurrence over per-chunk states — O(S) total with matmul-friendly inner
shapes (this is the TPU-appropriate formulation; the CUDA kernel's
warp-level scan does not transfer, per DESIGN.md hardware-adaptation notes).

Decode keeps a recurrent state (B, H, P, N) plus a (d_conv-1)-deep causal
conv cache; one token costs O(H*P*N) — sequence-length-independent, which is
why mamba2 runs the ``long_500k`` cell.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import SSMConfig
from repro.launch.axes import constrain
from repro.models.layers import init_linear, rms_norm

__all__ = ["init_ssm_params", "ssm_block", "ssm_decode_step", "ssd_scan",
           "init_ssm_cache"]

NGROUPS = 1  # B/C projection groups (Mamba2 default for these scales)


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------

def init_ssm_params(key: jax.Array, d_model: int, cfg: SSMConfig, dtype,
                    extra_dims: tuple[int, ...] = ()) -> dict:
    """Projections are SPLIT per stream (gate/x/B/C/dt) rather than one
    fused in_proj: a fused (D, 2*d_in + 2GN + H) output sharded over the
    model axis puts every stream's slice off shard boundaries, which
    GSPMD repairs with per-layer halo collective-permutes (measured 24
    GB/device on the mamba2 prefill cell).  Separate weights make each
    stream's TP sharding exact.  Math is identical."""
    d_in = cfg.d_inner(d_model)
    H = cfg.num_heads(d_model)
    N = cfg.d_state
    ks = jax.random.split(key, 8)
    shp = lambda *s: extra_dims + s
    return {
        "gate_proj": init_linear(ks[0], d_model, d_in, dtype, extra_dims),
        "x_proj": init_linear(ks[3], d_model, d_in, dtype, extra_dims),
        "B_proj": init_linear(ks[4], d_model, NGROUPS * N, dtype,
                              extra_dims),
        "C_proj": init_linear(ks[5], d_model, NGROUPS * N, dtype,
                              extra_dims),
        "dt_proj": init_linear(ks[6], d_model, H, dtype, extra_dims),
        "conv_x": (jax.random.normal(ks[1], shp(cfg.d_conv, d_in),
                                     jnp.float32) / np.sqrt(cfg.d_conv)
                   ).astype(dtype),
        "conv_x_b": jnp.zeros(shp(d_in), dtype),
        "conv_B": (jax.random.normal(ks[7], shp(cfg.d_conv, NGROUPS * N),
                                     jnp.float32) / np.sqrt(cfg.d_conv)
                   ).astype(dtype),
        "conv_B_b": jnp.zeros(shp(NGROUPS * N), dtype),
        "conv_C": (jax.random.normal(jax.random.fold_in(key, 9),
                                     shp(cfg.d_conv, NGROUPS * N),
                                     jnp.float32) / np.sqrt(cfg.d_conv)
                   ).astype(dtype),
        "conv_C_b": jnp.zeros(shp(NGROUPS * N), dtype),
        "A_log": jnp.broadcast_to(
            jnp.log(jnp.linspace(1.0, 16.0, H, dtype=jnp.float32)),
            shp(H)).astype(jnp.float32),
        "D": jnp.ones(shp(H), jnp.float32),
        "dt_bias": jnp.broadcast_to(
            jnp.log(jnp.expm1(jnp.logspace(-3, -1, H, dtype=jnp.float32))),
            shp(H)).astype(jnp.float32),
        "norm_scale": jnp.zeros(shp(d_in), dtype),
        "out_proj": init_linear(ks[2], d_in, d_model, dtype, extra_dims),
    }


def init_ssm_cache(batch: int, d_model: int, cfg: SSMConfig, dtype) -> dict:
    """Per-stream conv caches (a single fused cache would need a concat of
    differently-sharded streams -- measured as per-layer all-to-alls)."""
    d_in = cfg.d_inner(d_model)
    H = cfg.num_heads(d_model)
    K = cfg.d_conv - 1
    N = NGROUPS * cfg.d_state
    return {
        "conv_x": jnp.zeros((batch, K, d_in), dtype),
        "conv_B": jnp.zeros((batch, K, N), dtype),
        "conv_C": jnp.zeros((batch, K, N), dtype),
        "state": jnp.zeros((batch, H, cfg.head_dim, cfg.d_state),
                           jnp.float32),
    }


def _streams(params, x, cfg: SSMConfig, d_model: int):
    """Per-stream projections: gate, xs, B, C, dt_raw."""
    dt = x.dtype
    gate = x @ params["gate_proj"].astype(dt)
    xs = x @ params["x_proj"].astype(dt)
    Bm = x @ params["B_proj"].astype(dt)
    Cm = x @ params["C_proj"].astype(dt)
    dtr = x @ params["dt_proj"].astype(dt)
    return gate, xs, Bm, Cm, dtr


# ---------------------------------------------------------------------------
# SSD chunked scan
# ---------------------------------------------------------------------------

def _segsum(a: jax.Array) -> jax.Array:
    """Lower-triangular pairwise segment sums.

    a: (..., L) -> (..., L, L) with out[..., i, j] = sum_{j < t <= i} a[t]
    (−inf above the diagonal).
    """
    L = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((L, L), bool), k=0)
    return jnp.where(mask, diff, -jnp.inf)


def ssd_scan(x: jax.Array, dt: jax.Array, A: jax.Array, Bm: jax.Array,
             Cm: jax.Array, chunk: int, init_state=None):
    """SSD over a full sequence.

    x:  (B, S, H, P)   per-head inputs
    dt: (B, S, H)      positive step sizes
    A:  (H,)           negative decay rates
    Bm, Cm: (B, S, G, N) input/output projections (G = NGROUPS)
    Returns (y (B, S, H, P) float32, final_state (B, H, P, N) float32).
    """
    Bsz, S, H, P = x.shape
    G, N = Bm.shape[-2], Bm.shape[-1]
    assert S % chunk == 0, (S, chunk)
    nc = S // chunk
    rep = H // G

    xc = x.reshape(Bsz, nc, chunk, H, P).astype(jnp.float32)
    dtc = dt.reshape(Bsz, nc, chunk, H).astype(jnp.float32)
    Bc = Bm.reshape(Bsz, nc, chunk, G, N).astype(jnp.float32)
    Cc = Cm.reshape(Bsz, nc, chunk, G, N).astype(jnp.float32)

    xdt = xc * dtc[..., None]                       # dt-weighted input
    Adt = A[None, None, None, :] * dtc              # (B, nc, l, H)
    Acum = jnp.cumsum(Adt, axis=2)                  # within-chunk cumsum

    # 1) intra-chunk (quadratic in chunk length, matmul-shaped)
    Lmat = jnp.exp(_segsum(Adt.transpose(0, 1, 3, 2)))   # (B, nc, H, l, l)
    if G == 1:
        # Keep B/C in grouped (G=1) form and let the einsums sum over the
        # singleton g axis instead of jnp.repeat-ing to H: the repeat
        # produced an H-replicated (B, nc, H, l, l) score tensor that GSPMD
        # then re-sharded against the H-sharded Lmat/xdt — measured 290
        # GB/device of all-reduce/all-gather on the mamba2 prefill cell.
        scores = jnp.einsum("bclgn,bcsgn->bcls", Cc, Bc)     # tiny (g=1)
        # explicit broadcast-multiply: scores (replicated) * Lmat
        # (H-sharded) stays H-sharded; a 3-operand einsum here made GSPMD
        # all-gather Lmat to replicated (96 GB/device measured)
        W = scores[:, :, None, :, :] * Lmat                  # (B,nc,H,l,l)
        y_diag = jnp.einsum("bchls,bcshp->bclhp", W, xdt)
        decay_states = jnp.exp(Acum[:, :, -1:, :] - Acum)    # (B, nc, l, H)
        states = jnp.einsum("bclgn,bclh,bclhp->bchpn", Bc, decay_states,
                            xdt)
    else:
        Bh = jnp.repeat(Bc, rep, axis=3)   # (B, nc, l, H, N)
        Ch = jnp.repeat(Cc, rep, axis=3)
        scores_h = jnp.einsum("bclhn,bcshn->bchls", Ch, Bh)
        y_diag = jnp.einsum("bchls,bcshp->bclhp", scores_h * Lmat, xdt)
        decay_states = jnp.exp(Acum[:, :, -1:, :] - Acum)
        states = jnp.einsum("bclhn,bclh,bclhp->bchpn", Bh, decay_states,
                            xdt)

    # 3) inter-chunk recurrence (scan over chunks)
    chunk_decay = jnp.exp(Acum[:, :, -1, :])             # (B, nc, H)
    if init_state is None:
        init_state = jnp.zeros((Bsz, H, P, N), jnp.float32)

    def step(carry, inp):
        s_c, d_c = inp                                    # (B,H,P,N), (B,H)
        new = carry * d_c[:, :, None, None] + s_c
        return new, carry                                 # emit state BEFORE chunk

    final, prev_states = jax.lax.scan(
        step, init_state,
        (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)))
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)    # (B, nc, H, P, N)

    # 4) contribution of carried state to each position
    state_decay = jnp.exp(Acum)                           # (B, nc, l, H)
    if G == 1:
        y_off = jnp.einsum("bclgn,bchpn,bclh->bclhp", Cc, prev_states,
                           state_decay)
    else:
        y_off = jnp.einsum("bclhn,bchpn,bclh->bclhp", Ch, prev_states,
                           state_decay)
    y = (y_diag + y_off).reshape(Bsz, S, H, P)
    return y, final


# ---------------------------------------------------------------------------
# Block forward (train/prefill) and decode step
# ---------------------------------------------------------------------------

def _split_proj(z: jax.Array, d_in: int, N: int, H: int):
    zs = [2 * d_in, 2 * d_in + NGROUPS * N, 2 * d_in + 2 * NGROUPS * N]
    gate_x = z[..., : 2 * d_in]
    Bm = z[..., zs[0]: zs[1]]
    Cm = z[..., zs[1]: zs[2]]
    dt = z[..., zs[2]:]
    return gate_x[..., :d_in], gate_x[..., d_in:], Bm, Cm, dt


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv over (B, S, C) with taps (K, C)."""
    K = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = sum(pad[:, i: i + x.shape[1], :] * w[i][None, None, :]
              for i in range(K))
    return out + b[None, None, :]


def ssm_block(params: dict, x: jax.Array, d_model: int, cfg: SSMConfig,
              init_state=None):
    """Mamba2 block over (B, S, D); returns (y, cache) with final state."""
    d_in = cfg.d_inner(d_model)
    H = cfg.num_heads(d_model)
    N, P = cfg.d_state, cfg.head_dim
    gate, xs, Bm, Cm, dtr = _streams(params, x, cfg, d_model)
    gate = constrain(gate, "batch", None, "tp")
    xs = constrain(xs, "batch", None, "tp")

    K = cfg.d_conv - 1
    cache_tail = {"conv_x": xs[:, -K:], "conv_B": Bm[:, -K:],
                  "conv_C": Cm[:, -K:]}
    xs = jax.nn.silu(_causal_conv(xs, params["conv_x"].astype(x.dtype),
                                  params["conv_x_b"].astype(x.dtype)))
    Bm = jax.nn.silu(_causal_conv(Bm, params["conv_B"].astype(x.dtype),
                                  params["conv_B_b"].astype(x.dtype)))
    Cm = jax.nn.silu(_causal_conv(Cm, params["conv_C"].astype(x.dtype),
                                  params["conv_C_b"].astype(x.dtype)))

    dt = jax.nn.softplus(dtr.astype(jnp.float32)
                         + params["dt_bias"][None, None, :])
    A = -jnp.exp(params["A_log"])
    Bsz, S = x.shape[0], x.shape[1]
    xh = xs.reshape(Bsz, S, H, P)
    Bh = Bm.reshape(Bsz, S, NGROUPS, N)
    Ch = Cm.reshape(Bsz, S, NGROUPS, N)

    # Pad the sequence to a chunk multiple; padded steps have dt = 0, so
    # their decay is exp(0) = 1 and their input weight is 0 -- the final
    # state is exactly the state at position S.
    chunk = min(cfg.chunk_size, S)
    pad = (-S) % chunk
    if pad:
        padseq = lambda t: jnp.pad(t, ((0, 0), (0, pad)) +
                                   ((0, 0),) * (t.ndim - 2))
        xh, dt, Bh, Ch = map(padseq, (xh, dt, Bh, Ch))

    y, final = ssd_scan(xh, dt, A, Bh, Ch, chunk, init_state)
    y = y[:, :S] + params["D"][None, None, :, None] * xh[:, :S].astype(
        jnp.float32)
    xh = xh[:, :S]
    y = y.reshape(Bsz, S, d_in).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(gate), params["norm_scale"])
    out = constrain(y @ params["out_proj"].astype(x.dtype),
                    "batch", None, None)
    cache = dict(cache_tail, state=final)
    return out, cache


def ssm_decode_step(params: dict, x: jax.Array, cache: dict, d_model: int,
                    cfg: SSMConfig):
    """One-token Mamba2 step. x: (B, 1, D); returns (y (B,1,D), new cache)."""
    d_in = cfg.d_inner(d_model)
    H, N, P = cfg.num_heads(d_model), cfg.d_state, cfg.head_dim
    gate, xs, Bm, Cm, dtr = _streams(params, x, cfg, d_model)

    win_x = jnp.concatenate([cache["conv_x"], xs], axis=1)   # (B, K, d_in)
    win_B = jnp.concatenate([cache["conv_B"], Bm], axis=1)
    win_C = jnp.concatenate([cache["conv_C"], Cm], axis=1)
    xs = jax.nn.silu(jnp.einsum("bkc,kc->bc", win_x,
                                params["conv_x"].astype(x.dtype))
                     + params["conv_x_b"].astype(x.dtype))[:, None, :]
    Bm = jax.nn.silu(jnp.einsum("bkc,kc->bc", win_B,
                                params["conv_B"].astype(x.dtype))
                     + params["conv_B_b"].astype(x.dtype))[:, None, :]
    Cm = jax.nn.silu(jnp.einsum("bkc,kc->bc", win_C,
                                params["conv_C"].astype(x.dtype))
                     + params["conv_C_b"].astype(x.dtype))[:, None, :]

    dt = jax.nn.softplus(dtr.astype(jnp.float32)
                         + params["dt_bias"][None, None, :])[:, 0]  # (B, H)
    A = -jnp.exp(params["A_log"])
    Bsz = x.shape[0]
    xh = xs.reshape(Bsz, H, P).astype(jnp.float32)
    Bh = jnp.repeat(Bm.reshape(Bsz, NGROUPS, N), H // NGROUPS, 1)
    Ch = jnp.repeat(Cm.reshape(Bsz, NGROUPS, N), H // NGROUPS, 1)

    dA = jnp.exp(dt * A[None, :])                          # (B, H)
    dBx = jnp.einsum("bh,bhn,bhp->bhpn", dt, Bh.astype(jnp.float32),
                     xh)
    state = cache["state"] * dA[:, :, None, None] + dBx
    y = jnp.einsum("bhpn,bhn->bhp", state, Ch.astype(jnp.float32))
    y = y + params["D"][None, :, None] * xh
    y = y.reshape(Bsz, 1, d_in).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(gate), params["norm_scale"])
    out = y @ params["out_proj"].astype(x.dtype)
    return out, {"conv_x": win_x[:, 1:], "conv_B": win_B[:, 1:],
                 "conv_C": win_C[:, 1:], "state": state}
