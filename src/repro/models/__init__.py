"""Model zoo substrate: layers, MoE, SSM, RG-LRU, transformer assembly."""

from repro.models import (  # noqa: F401
    layers,
    loss,
    moe,
    rglru,
    ssm,
    transformer,
)
