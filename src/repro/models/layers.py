"""Shared neural-net layers: norms, RoPE, GQA attention, MLPs, embeddings.

All layers are pure functions over explicit parameter pytrees (no framework).
Attention is grouped-query throughout: queries are reshaped to
``(B, S, n_kv, group, head_dim)`` so K/V are never repeated — the grouped
einsum keeps the KV cache memory footprint exact, which matters for the
decode_32k/long_500k roofline cells.

Prefill attention over long sequences is query-chunked (lax.scan over query
blocks with an online max/sum) so the ``S_q x S_kv`` score matrix is never
materialised — the jnp twin of the Pallas flash-attention kernel in
``repro.kernels.flash_attention``.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import AttentionConfig

__all__ = [
    "rms_norm", "layer_norm", "rope", "attention", "decode_attention",
    "mlp_swiglu", "mlp_gelu", "init_linear", "init_norm",
]

_NEG_INF = -0.7 * float(np.finfo(np.float32).max)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rms_norm(x: jax.Array, weight: jax.Array, eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * (1.0 + weight.astype(jnp.float32))).astype(dtype)


def layer_norm(x: jax.Array, weight: jax.Array, bias: jax.Array,
               eps: float = 1e-5) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mean = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    x = (x - mean) * jax.lax.rsqrt(var + eps)
    return (x * weight.astype(jnp.float32)
            + bias.astype(jnp.float32)).astype(dtype)


def apply_norm(norm_kind: str, x: jax.Array, params: dict) -> jax.Array:
    if norm_kind == "rmsnorm":
        return rms_norm(x, params["scale"])
    return layer_norm(x, params["scale"], params["bias"])


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------

def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Apply RoPE to ``x (..., S, n, head_dim)`` given ``positions (..., S)``."""
    head_dim = x.shape[-1]
    fraction = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    timescale = theta**fraction                       # (head_dim/2,)
    angles = (positions[..., None].astype(jnp.float32)
              / timescale[None, :])                   # (..., S, head_dim/2)
    angles = angles[..., None, :]                     # broadcast over heads
    sin, cos = jnp.sin(angles), jnp.cos(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention (grouped-query; full / causal / sliding-window; chunked prefill)
# ---------------------------------------------------------------------------

def _mask_bias(pos_q: jax.Array, pos_k: jax.Array, causal: bool,
               window: Optional[int], kv_valid: Optional[jax.Array] = None):
    """(B, 1, 1, Sq, Skv) additive mask bias from position comparisons."""
    ok = jnp.ones(pos_q.shape[-1:] + pos_k.shape[-1:], dtype=bool)
    dq, dk = pos_q[..., :, None], pos_k[..., None, :]
    if causal:
        ok = ok & (dk <= dq)
    if window is not None:
        ok = ok & (dk > dq - window)
    if kv_valid is not None:
        ok = ok & kv_valid[..., None, :]
    # (B, Sq, Skv) -> (B, 1, 1, Sq, Skv): broadcasts over (n_kv, G)
    return jnp.where(ok, 0.0, _NEG_INF)[..., None, None, :, :]


def _attend(q: jax.Array, k: jax.Array, v: jax.Array, bias: jax.Array,
            softcap: Optional[float]) -> jax.Array:
    """Grouped attention core.

    q: (B, Sq, n_kv, G, Dh); k, v: (B, Skv, n_kv, Dh);
    bias: broadcastable to (B, n_kv, G, Sq, Skv).  Returns (B, Sq, n_kv, G, Dh).
    """
    scale = 1.0 / np.sqrt(q.shape[-1])
    logits = jnp.einsum("bqhgd,bkhd->bhgqk", q, k,
                        preferred_element_type=jnp.float32) * scale
    if softcap is not None:
        logits = jnp.tanh(logits / softcap) * softcap
    logits = logits + bias
    probs = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    return jnp.einsum("bhgqk,bkhd->bqhgd", probs, v)


def attention(q: jax.Array, k: jax.Array, v: jax.Array,
              pos_q: jax.Array, pos_k: jax.Array, cfg: AttentionConfig,
              *, q_chunk: int = 2048,
              kv_valid: Optional[jax.Array] = None) -> jax.Array:
    """Full attention for train/prefill.

    q: (B, Sq, n_heads, Dh); k/v: (B, Skv, n_kv, Dh); positions are (B, S).
    Query-chunked when Sq > q_chunk so scores never materialise at S^2.
    Returns (B, Sq, n_heads, Dh).
    """
    B, Sq, H, Dh = q.shape
    n_kv, G = cfg.num_kv_heads, cfg.group_size
    qg = q.reshape(B, Sq, n_kv, G, Dh)

    def block(q_blk, pos_blk):
        bias = _mask_bias(pos_blk, pos_k, cfg.causal, cfg.window, kv_valid)
        return _attend(q_blk, k, v, bias, cfg.attn_logit_softcap)

    if Sq <= q_chunk:
        out = block(qg, pos_q)
    else:
        assert Sq % q_chunk == 0, (Sq, q_chunk)
        nblk = Sq // q_chunk
        qs = qg.reshape(B, nblk, q_chunk, n_kv, G, Dh).swapaxes(0, 1)
        ps = pos_q.reshape(B, nblk, q_chunk).swapaxes(0, 1)
        out = jax.lax.map(lambda args: block(*args), (qs, ps))
        out = out.swapaxes(0, 1).reshape(B, Sq, n_kv, G, Dh)
    return out.reshape(B, Sq, H, Dh)


def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     pos: jax.Array, cfg: AttentionConfig,
                     cache_len: jax.Array) -> jax.Array:
    """Single-token attention against a (B, S_cache, n_kv, Dh) KV cache.

    q: (B, 1, n_heads, Dh); ``pos`` (B,) is the new token's position;
    ``cache_len`` (B,) marks how many cache slots are valid.
    """
    B, _, H, Dh = q.shape
    n_kv, G = cfg.num_kv_heads, cfg.group_size
    S = k_cache.shape[1]
    qg = q.reshape(B, 1, n_kv, G, Dh)
    slots = jnp.arange(S, dtype=jnp.int32)[None, :]           # (1, S)
    valid = slots < cache_len[:, None]
    if cfg.window is not None:
        valid = valid & (slots > (pos[:, None] - cfg.window))
    bias = jnp.where(valid, 0.0, _NEG_INF)[:, None, None, None, :]
    out = _attend(qg, k_cache, v_cache, bias, cfg.attn_logit_softcap)
    return out.reshape(B, 1, H, Dh)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def mlp_swiglu(x: jax.Array, w_gate: jax.Array, w_up: jax.Array,
               w_down: jax.Array) -> jax.Array:
    h = jax.nn.silu(x @ w_gate) * (x @ w_up)
    return h @ w_down


def mlp_gelu(x: jax.Array, w_fc: jax.Array, b_fc: jax.Array,
             w_proj: jax.Array, b_proj: jax.Array) -> jax.Array:
    h = jax.nn.gelu(x @ w_fc + b_fc, approximate=True)
    return h @ w_proj + b_proj


# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------

def init_linear(key: jax.Array, d_in: int, d_out: int, dtype,
                extra_dims: tuple[int, ...] = ()) -> jax.Array:
    shape = extra_dims + (d_in, d_out)
    scale = 1.0 / np.sqrt(d_in)
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def init_norm(d: int, dtype, kind: str = "rmsnorm") -> dict:
    if kind == "rmsnorm":
        return {"scale": jnp.zeros((d,), dtype)}
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}
