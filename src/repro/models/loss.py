"""Cross-entropy loss that stays sharded over the vocab axis.

Logits arrive sharded ``(batch -> ("pod","data"), vocab -> "model")``; the
fp32 logsumexp reduces over the sharded vocab dimension, which GSPMD lowers
to a per-shard reduction + small all-reduce — the full unsharded logits
tensor is never materialised on one device (it wouldn't fit for
vocab=256000 x 1M tokens).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["cross_entropy", "top1_accuracy"]


def cross_entropy(logits: jax.Array, targets: jax.Array,
                  mask: jax.Array | None = None):
    """Token-mean CE.  logits (B, S, V) any float dtype; targets (B, S) int.

    Returns (loss, metrics) with fp32 math.  ``mask`` (B, S) bool/float
    selects which positions contribute (VLM text positions, padding, ...).
    """
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)                    # (B, S)
    true_logit = jnp.take_along_axis(
        logits, targets[..., None].astype(jnp.int32), axis=-1)[..., 0]
    nll = lse - true_logit                                     # (B, S)
    if mask is None:
        denom = jnp.asarray(nll.size, jnp.float32)
        loss = nll.sum() / denom
    else:
        m = mask.astype(jnp.float32)
        denom = jnp.maximum(m.sum(), 1.0)
        loss = (nll * m).sum() / denom
    metrics = {"loss": loss, "ntokens": denom}
    return loss, metrics


def top1_accuracy(logits: jax.Array, targets: jax.Array,
                  mask: jax.Array | None = None) -> jax.Array:
    pred = jnp.argmax(logits, axis=-1)
    hit = (pred == targets).astype(jnp.float32)
    if mask is None:
        return hit.mean()
    m = mask.astype(jnp.float32)
    return (hit * m).sum() / jnp.maximum(m.sum(), 1.0)
