"""Mixture-of-Experts block: top-k router + GShard-style grouped dispatch.

Dispatch strategy (baseline): tokens are split into groups of
``group_size``; each group dispatches into per-expert capacity buffers
``C = ceil(group_size / E * k * capacity_factor)`` via one-hot einsums.
The dispatch tensor is ``(G, Tg, E, C)`` with G sharded over "data" and E
over "model", so its per-device footprint is
``G/n_data * Tg * E/n_model * C`` — bounded by the *group* size, not the
global token count (the ungrouped (T, E, C) tensor is O(T^2 k / E) and blows
up at 1M tokens; this grouping is why GShard has groups).  Tokens over a
group's capacity are dropped (pass through the residual), standard for
capacity-based MoE.

A shared expert (Qwen2-MoE: 4x1408 fused; Llama4: one 8192) runs densely
alongside the routed experts.

The expert-parallel all-to-all alternative is explored in the perf
hillclimb (EXPERIMENTS.md §Perf).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import MoEConfig
from repro.launch.axes import constrain
from repro.models.layers import init_linear, mlp_swiglu

__all__ = ["init_moe_params", "moe_block", "router_topk"]

DISPATCH_GROUP = 4096  # tokens per dispatch group (GShard's G)


def init_moe_params(key: jax.Array, d_model: int, cfg: MoEConfig, dtype,
                    extra_dims: tuple[int, ...] = ()) -> dict:
    ks = jax.random.split(key, 7)
    E, F = cfg.num_experts, cfg.d_ff_expert
    params = {
        "router": init_linear(ks[0], d_model, E, dtype, extra_dims),
        # experts stacked on a leading E axis (sharded over "model");
        # distinct "we_*" names so sharding rules can't collide with the
        # dense/shared-expert "w_*" weights.
        "we_gate": init_linear(ks[1], d_model, F, dtype, extra_dims + (E,)),
        "we_up": init_linear(ks[2], d_model, F, dtype, extra_dims + (E,)),
        "we_down": init_linear(ks[3], F, d_model, dtype, extra_dims + (E,)),
    }
    if cfg.d_ff_shared:
        params["shared"] = {
            "w_gate": init_linear(ks[4], d_model, cfg.d_ff_shared, dtype,
                                  extra_dims),
            "w_up": init_linear(ks[5], d_model, cfg.d_ff_shared, dtype,
                                extra_dims),
            "w_down": init_linear(ks[6], cfg.d_ff_shared, d_model, dtype,
                                  extra_dims),
        }
    return params


def router_topk(logits: jax.Array, k: int):
    """Top-k gates (renormalised over the k picks) + expert indices.

    logits: (..., E) -> gates (..., k) float32, idx (..., k) int32.
    """
    gates_full = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    gates, idx = jax.lax.top_k(gates_full, k)
    gates = gates / jnp.clip(gates.sum(-1, keepdims=True), 1e-9)
    return gates, idx


def moe_block(params: dict, x: jax.Array, cfg: MoEConfig,
              group_size: int | None = None) -> jax.Array:
    """Apply the routed-expert FFN to x (..., D); returns the same shape."""
    orig_shape = x.shape
    D = x.shape[-1]
    xf = x.reshape(-1, D)                          # (T, D)
    T = xf.shape[0]
    E, k = cfg.num_experts, cfg.top_k

    if group_size is None:
        group_size = cfg.dispatch_group or DISPATCH_GROUP
    Tg = min(group_size, T)
    if T % Tg:  # shapes in this repo are powers of two; guard anyway
        Tg = int(np.gcd(T, Tg))
    G = T // Tg
    capacity = int(np.ceil(Tg / E * k * cfg.capacity_factor))
    capacity = max(capacity, 2)

    xg = xf.reshape(G, Tg, D)
    router_logits = jnp.einsum("gtd,de->gte", xg,
                               params["router"].astype(x.dtype))
    gates, idx = router_topk(router_logits, k)     # (G, Tg, k)

    # Position of each (token, choice) inside its expert's group buffer.
    onehot_e = jax.nn.one_hot(idx, E, dtype=jnp.int32)        # (G, Tg, k, E)
    flat = onehot_e.reshape(G, Tg * k, E)
    pos = jnp.cumsum(flat, axis=1) - 1                         # (G, Tg*k, E)
    pos = (pos * flat).sum(-1).reshape(G, Tg, k)               # (G, Tg, k)
    keep = pos < capacity
    gates = jnp.where(keep, gates, 0.0)
    # one_hot(index == capacity) == all-zeros, so dropped tokens vanish.
    pos = jnp.where(keep, pos, capacity)

    dtype = x.dtype
    # Accumulate over the k choices with an unrolled loop (k <= 4) so the
    # (G, Tg, k, E, C) intermediate never materialises -- only the
    # (G, Tg, E, C) dispatch/combine pair is live.
    dispatch = jnp.zeros((G, Tg, E, capacity), dtype)
    combine = jnp.zeros((G, Tg, E, capacity), dtype)
    for kk in range(k):
        oh = (jax.nn.one_hot(idx[..., kk], E, dtype=dtype)[..., None]
              * jax.nn.one_hot(pos[..., kk], capacity,
                               dtype=dtype)[..., None, :])     # (G,Tg,E,C)
        dispatch = dispatch + oh
        combine = combine + oh * gates[..., kk, None, None].astype(dtype)

    dispatch = constrain(dispatch, "batch", None, "tp", None)
    combine = constrain(combine, "batch", None, "tp", None)
    expert_in = jnp.einsum("gtd,gtec->gecd", xg, dispatch)     # (G, E, C, D)
    expert_in = constrain(expert_in, "batch", "tp", None, None)
    wg, wu, wd = (params["we_gate"].astype(dtype),
                  params["we_up"].astype(dtype),
                  params["we_down"].astype(dtype))
    h = (jax.nn.silu(jnp.einsum("gecd,edf->gecf", expert_in, wg))
         * jnp.einsum("gecd,edf->gecf", expert_in, wu))
    expert_out = constrain(jnp.einsum("gecf,efd->gecd", h, wd),
                           "batch", "tp", None, None)
    yg = jnp.einsum("gecd,gtec->gtd", expert_out, combine)     # (G, Tg, D)
    yg = constrain(yg, "batch", None, None)

    yf = yg.reshape(T, D)
    if cfg.d_ff_shared:
        sp = params["shared"]
        yf = yf + mlp_swiglu(xf, sp["w_gate"].astype(dtype),
                             sp["w_up"].astype(dtype),
                             sp["w_down"].astype(dtype))
    return yf.reshape(orig_shape)
