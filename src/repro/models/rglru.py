"""RG-LRU recurrent block (RecurrentGemma / Griffin, arXiv:2402.19427).

The temporal-mixing block is:

    branch 1: Linear(D -> D_rnn) -> GeLU
    branch 2: Linear(D -> D_rnn) -> causal depthwise Conv1D(4) -> RG-LRU
    merge:    elementwise product -> Linear(D_rnn -> D)

with the RG-LRU recurrence (all elementwise, diagonal):

    r_t = sigmoid(x_t W_a + b_a)            (recurrence gate)
    i_t = sigmoid(x_t W_x + b_x)            (input gate)
    log a_t = -c * softplus(Lambda) * r_t   (c = 8)
    h_t = a_t h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

Train/prefill evaluates the diagonal linear recurrence with
``jax.lax.associative_scan`` (log-depth, TPU-friendly — the GPU kernel's
sequential fused scan does not transfer; see DESIGN.md).  Decode carries
``h`` directly: O(D_rnn) per token, so recurrentgemma runs ``long_500k``
(its attention layers are sliding-window, cache bounded by the window).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import RGLRUConfig
from repro.launch.axes import constrain
from repro.models.layers import init_linear

__all__ = ["init_rglru_params", "rglru_block", "rglru_decode_step",
           "init_rglru_cache"]

_C = 8.0  # RG-LRU temperature


def init_rglru_params(key: jax.Array, d_model: int, cfg: RGLRUConfig, dtype,
                      extra_dims: tuple[int, ...] = ()) -> dict:
    d_rnn = cfg.d_rnn or d_model
    ks = jax.random.split(key, 6)
    shp = lambda *s: extra_dims + s
    # Lambda init so that a^c in [0.9, 0.999] (Griffin appendix)
    u = jax.random.uniform(ks[0], shp(d_rnn), jnp.float32, 0.9, 0.999)
    lam = jnp.log(jnp.expm1(-jnp.log(u) / _C))  # softplus^-1(-log(u)/c)
    return {
        "in_gelu": init_linear(ks[1], d_model, d_rnn, dtype, extra_dims),
        "in_rnn": init_linear(ks[2], d_model, d_rnn, dtype, extra_dims),
        "conv_w": (jax.random.normal(ks[3], shp(cfg.d_conv, d_rnn),
                                     jnp.float32)
                   / np.sqrt(cfg.d_conv)).astype(dtype),
        "conv_b": jnp.zeros(shp(d_rnn), dtype),
        "w_a": init_linear(ks[4], d_rnn, d_rnn, dtype, extra_dims),
        "b_a": jnp.zeros(shp(d_rnn), jnp.float32),
        "w_x": init_linear(ks[5], d_rnn, d_rnn, dtype, extra_dims),
        "b_x": jnp.zeros(shp(d_rnn), jnp.float32),
        "Lambda": lam,
        "out": init_linear(jax.random.fold_in(key, 7), d_rnn, d_model, dtype,
                           extra_dims),
    }


def init_rglru_cache(batch: int, d_model: int, cfg: RGLRUConfig,
                     dtype) -> dict:
    d_rnn = cfg.d_rnn or d_model
    return {
        "conv": jnp.zeros((batch, cfg.d_conv - 1, d_rnn), dtype),
        "h": jnp.zeros((batch, d_rnn), jnp.float32),
    }


def _rglru_gates(params: dict, x: jax.Array):
    """Common gate math. x: (..., d_rnn) -> (a, gated_input) float32."""
    xf = x.astype(jnp.float32)
    r = jax.nn.sigmoid(xf @ params["w_a"].astype(jnp.float32)
                       + params["b_a"])
    i = jax.nn.sigmoid(xf @ params["w_x"].astype(jnp.float32)
                       + params["b_x"])
    log_a = -_C * jax.nn.softplus(params["Lambda"]) * r
    a = jnp.exp(log_a)
    # sqrt(1 - a^2) computed stably via expm1: 1 - exp(2 log a)
    beta = jnp.sqrt(-jnp.expm1(2.0 * log_a))
    return a, beta * (i * xf)


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    K = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = sum(pad[:, i: i + x.shape[1], :] * w[i][None, None, :]
              for i in range(K))
    return out + b[None, None, :]


def rglru_block(params: dict, x: jax.Array, cfg: RGLRUConfig,
                init_h=None):
    """(B, S, D) -> (y, cache).  Linear scan via associative_scan."""
    gelu_branch = jax.nn.gelu(
        constrain(x @ params["in_gelu"].astype(x.dtype),
                  "batch", None, "tp"), approximate=True)
    u = constrain(x @ params["in_rnn"].astype(x.dtype), "batch", None, "tp")
    conv_in = u
    u = _causal_conv(u, params["conv_w"].astype(x.dtype),
                     params["conv_b"].astype(x.dtype))

    a, bx = _rglru_gates(params, u)               # (B, S, d_rnn) fp32
    if init_h is not None:
        # fold the carried state into the first step: h_0-contribution
        bx = bx.at[:, 0, :].add(a[:, 0, :] * init_h)

    def combine(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a1 * a2, a2 * b1 + b2

    aa, hh = jax.lax.associative_scan(combine, (a, bx), axis=1)
    h_final = hh[:, -1, :]
    y = constrain((hh.astype(x.dtype) * gelu_branch)
                  @ params["out"].astype(x.dtype), "batch", None, None)
    cache = {"conv": conv_in[:, -(params["conv_w"].shape[0] - 1):, :],
             "h": h_final}
    return y, cache


def rglru_decode_step(params: dict, x: jax.Array, cache: dict,
                      cfg: RGLRUConfig):
    """One-token step. x: (B, 1, D) -> (y (B, 1, D), new cache)."""
    gelu_branch = jax.nn.gelu(x @ params["in_gelu"].astype(x.dtype),
                              approximate=True)
    u_new = x @ params["in_rnn"].astype(x.dtype)   # (B, 1, d_rnn)
    window = jnp.concatenate([cache["conv"], u_new], axis=1)
    w = params["conv_w"].astype(x.dtype)
    u = (jnp.einsum("bkc,kc->bc", window, w)
         + params["conv_b"].astype(x.dtype))[:, None, :]

    a, bx = _rglru_gates(params, u)                # (B, 1, d_rnn)
    h = a[:, 0] * cache["h"] + bx[:, 0]
    y = (h[:, None, :].astype(x.dtype) * gelu_branch) @ params["out"].astype(x.dtype)
    return y, {"conv": window[:, 1:, :], "h": h}
