"""Decoder-LM assembly: pattern-grouped scan-over-layers, train/prefill/decode.

An architecture is a sequence of *block groups*: each group is a repeating
unit of layer kinds (e.g. ``("dense",) x 32`` for llama3,
``("dense", "moe") x 24`` for llama4's interleaved MoE,
``("rglru", "rglru", "local_attn") x 12 + ("rglru", "rglru") x 1`` for
recurrentgemma).  Per-group parameters are stacked on a leading ``repeats``
axis and the group runs under ``jax.lax.scan`` (+ configurable
``jax.checkpoint``), keeping HLO size O(1) in depth and bounding live
activations — required for the 48L/400B dry-run cells to compile quickly
and fit.

Layer kinds:
  dense       GQA attention + (SwiGLU | GELU) MLP
  moe         GQA attention + routed-experts FFN (repro.models.moe)
  ssm         Mamba2 SSD block (repro.models.ssm)
  rglru       RG-LRU recurrent block + MLP (repro.models.rglru)
  local_attn  sliding-window GQA + MLP (recurrentgemma's attention layers)
  cross       encoder-decoder layer: causal self-attn + cross-attn + MLP

Caches: every kind owns a cache pytree stacked like its params; decode
scans over (params, cache) pairs and emits updated caches.
"""

from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.launch.axes import constrain
from repro.models import layers as L
from repro.models import moe as moe_lib
from repro.models import rglru as rglru_lib
from repro.models import ssm as ssm_lib

__all__ = [
    "block_groups", "init_params", "forward_train", "prefill",
    "decode_step", "init_cache", "count_params", "active_params",
]

# Static KV-cache quantization scale (int8 mode).  Keys/values are
# post-RoPE bf16 activations with |x| <~ 4 for RMS-normed streams; a static
# scale keeps the cache layout trivially shardable.  A production system
# would calibrate per-head scales; the decode-consistency test bounds the
# logit error this introduces.
_KV_SCALE = 24.0


def _cache_dtype(cfg: ModelConfig):
    return jnp.int8 if cfg.kv_cache_dtype == "int8" else cfg.cdtype()


def _quant_kv(x: jax.Array, cfg: ModelConfig) -> jax.Array:
    if cfg.kv_cache_dtype != "int8":
        return x
    return jnp.clip(jnp.round(x.astype(jnp.float32) * _KV_SCALE),
                    -127, 127).astype(jnp.int8)


def _dequant_kv(x: jax.Array, cfg: ModelConfig) -> jax.Array:
    if x.dtype != jnp.int8:
        return x
    return (x.astype(cfg.cdtype()) / jnp.asarray(_KV_SCALE, cfg.cdtype()))


# ---------------------------------------------------------------------------
# Architecture pattern
# ---------------------------------------------------------------------------

def block_groups(cfg: ModelConfig) -> list[tuple[tuple[str, ...], int]]:
    """[(unit kinds, repeats)] covering cfg.num_layers exactly."""
    Lnum = cfg.num_layers
    if cfg.family == "ssm":
        return [(("ssm",), Lnum)]
    if cfg.family == "hybrid":
        unit = tuple("rglru" if c == "R" else "local_attn"
                     for c in cfg.rglru.block_pattern)
        reps, rem = divmod(Lnum, len(unit))
        groups = [(unit, reps)] if reps else []
        if rem:
            groups.append((unit[:rem], 1))
        return groups
    if cfg.family == "moe" and cfg.moe.interleave_step > 1:
        step = cfg.moe.interleave_step
        assert Lnum % step == 0, (Lnum, step)
        unit = tuple("dense" if i < step - 1 else "moe" for i in range(step))
        return [(unit, Lnum // step)]
    if cfg.family == "moe":
        return [(("moe",), Lnum)]
    if cfg.is_encdec:
        return [(("cross",), Lnum)]
    return [(("dense",), Lnum)]


# ---------------------------------------------------------------------------
# Parameter init
# ---------------------------------------------------------------------------

def _init_attn(key, cfg: ModelConfig, reps: tuple[int, ...]) -> dict:
    a = cfg.attention
    ks = jax.random.split(key, 4)
    dt = cfg.pdtype()
    D = cfg.d_model
    return {
        "wq": L.init_linear(ks[0], D, a.num_heads * a.head_dim, dt, reps
                            ).reshape(reps + (D, a.num_heads, a.head_dim)),
        "wk": L.init_linear(ks[1], D, a.num_kv_heads * a.head_dim, dt, reps
                            ).reshape(reps + (D, a.num_kv_heads, a.head_dim)),
        "wv": L.init_linear(ks[2], D, a.num_kv_heads * a.head_dim, dt, reps
                            ).reshape(reps + (D, a.num_kv_heads, a.head_dim)),
        "wo": L.init_linear(ks[3], a.num_heads * a.head_dim, D, dt, reps
                            ).reshape(reps + (a.num_heads, a.head_dim, D)),
    }


def _init_mlp(key, cfg: ModelConfig, reps: tuple[int, ...]) -> dict:
    dt = cfg.pdtype()
    ks = jax.random.split(key, 3)
    D, F = cfg.d_model, cfg.d_ff
    if cfg.activation == "gelu":
        return {
            "w_fc": L.init_linear(ks[0], D, F, dt, reps),
            "b_fc": jnp.zeros(reps + (F,), dt),
            "w_proj": L.init_linear(ks[1], F, D, dt, reps),
            "b_proj": jnp.zeros(reps + (D,), dt),
        }
    return {
        "w_gate": L.init_linear(ks[0], D, F, dt, reps),
        "w_up": L.init_linear(ks[1], D, F, dt, reps),
        "w_down": L.init_linear(ks[2], F, D, dt, reps),
    }


def _init_norm(cfg: ModelConfig, reps: tuple[int, ...]) -> dict:
    dt = cfg.pdtype()
    if cfg.norm == "rmsnorm":
        return {"scale": jnp.zeros(reps + (cfg.d_model,), dt)}
    return {"scale": jnp.ones(reps + (cfg.d_model,), dt),
            "bias": jnp.zeros(reps + (cfg.d_model,), dt)}


def _init_layer(key, kind: str, cfg: ModelConfig,
                reps: tuple[int, ...]) -> dict:
    ks = jax.random.split(key, 4)
    p: dict[str, Any] = {"ln1": _init_norm(cfg, reps)}
    if kind in ("dense", "moe", "local_attn", "cross"):
        p["attn"] = _init_attn(ks[0], cfg, reps)
        p["ln2"] = _init_norm(cfg, reps)
        if kind == "moe":
            p["ffn"] = moe_lib.init_moe_params(ks[1], cfg.d_model, cfg.moe,
                                               cfg.pdtype(), reps)
        else:
            p["ffn"] = _init_mlp(ks[1], cfg, reps)
        if kind == "cross":
            p["xattn"] = _init_attn(ks[2], cfg, reps)
            p["ln_x"] = _init_norm(cfg, reps)
    elif kind == "ssm":
        p["ssm"] = ssm_lib.init_ssm_params(ks[0], cfg.d_model, cfg.ssm,
                                           cfg.pdtype(), reps)
    elif kind == "rglru":
        p["rglru"] = rglru_lib.init_rglru_params(ks[0], cfg.d_model,
                                                 cfg.rglru, cfg.pdtype(),
                                                 reps)
        p["ln2"] = _init_norm(cfg, reps)
        p["ffn"] = _init_mlp(ks[1], cfg, reps)
    else:
        raise ValueError(f"unknown layer kind {kind!r}")
    return p


def init_params(key: jax.Array, cfg: ModelConfig) -> dict:
    ks = jax.random.split(key, 8)
    dt = cfg.pdtype()
    params: dict[str, Any] = {
        "embed": (jax.random.normal(ks[0], (cfg.vocab_size, cfg.d_model),
                                    jnp.float32) * 0.02).astype(dt),
        "final_norm": _init_norm(cfg, ()),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = L.init_linear(ks[1], cfg.d_model, cfg.vocab_size,
                                          dt)
    groups = []
    for gi, (unit, reps) in enumerate(block_groups(cfg)):
        gkey = jax.random.fold_in(ks[2], gi)
        unit_params = []
        for ui, kind in enumerate(unit):
            unit_params.append(_init_layer(jax.random.fold_in(gkey, ui),
                                           kind, cfg, (reps,)))
        groups.append(unit_params)
    params["groups"] = groups
    if cfg.is_encdec:
        enc = {"layers": _init_layer(ks[3], "dense",
                                     _encoder_cfg(cfg),
                                     (cfg.encoder_layers,)),
               "final_norm": _init_norm(cfg, ())}
        params["encoder"] = enc
    return params


def _encoder_cfg(cfg: ModelConfig) -> ModelConfig:
    import dataclasses
    return dataclasses.replace(
        cfg, attention=dataclasses.replace(cfg.attention, causal=False))


# ---------------------------------------------------------------------------
# Layer bodies
# ---------------------------------------------------------------------------

def _attn_apply(p: dict, x: jax.Array, cfg: ModelConfig, pos_q, pos_k,
                k_ext=None, v_ext=None, window=None, causal=None,
                q_chunk=2048):
    """Projection + attention + output projection.  Returns (out, (k, v))."""
    a = cfg.attention
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
    q = constrain(q, "batch", None, "tp", None)
    if k_ext is None:
        k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(x.dtype))
        v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(x.dtype))
        k = constrain(k, "batch", None, "tp", None)
        v = constrain(v, "batch", None, "tp", None)
        q = L.rope(q, pos_q, a.rope_theta)
        k = L.rope(k, pos_k, a.rope_theta)
    else:  # cross-attention: K/V precomputed from encoder output
        k, v = k_ext, v_ext
    import dataclasses
    acfg = dataclasses.replace(
        a,
        causal=a.causal if causal is None else causal,
        window=a.window if window is None else window)
    out = L.attention(q, k, v, pos_q, pos_k, acfg, q_chunk=q_chunk)
    out = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))
    out = constrain(out, "batch", None, None)
    return out, (k, v)


def _ffn_apply(p: dict, x: jax.Array, cfg: ModelConfig, kind: str):
    if kind == "moe":
        out = moe_lib.moe_block(p, x, cfg.moe)
    elif cfg.activation == "gelu":
        h = jax.nn.gelu(x @ p["w_fc"].astype(x.dtype)
                        + p["b_fc"].astype(x.dtype), approximate=True)
        h = constrain(h, "batch", None, "tp")
        out = h @ p["w_proj"].astype(x.dtype) + p["b_proj"].astype(x.dtype)
    else:
        h = (jax.nn.silu(x @ p["w_gate"].astype(x.dtype))
             * (x @ p["w_up"].astype(x.dtype)))
        h = constrain(h, "batch", None, "tp")
        out = h @ p["w_down"].astype(x.dtype)
    return constrain(out, "batch", None, None)


def _layer_fwd(kind: str, p: dict, x: jax.Array, cfg: ModelConfig,
               positions: jax.Array, enc_kv=None, q_chunk=2048):
    """Full-sequence layer forward.  Returns (x, cache_entry)."""
    norm = lambda n, h: L.apply_norm(cfg.norm, h, n)
    cache: dict[str, Any] = {}
    if kind in ("dense", "moe", "local_attn", "cross"):
        window = cfg.rglru.window if (kind == "local_attn" and cfg.rglru) \
            else cfg.attention.window
        h, (k, v) = _attn_apply(p["attn"], norm(p["ln1"], x), cfg,
                                positions, positions, window=window,
                                q_chunk=q_chunk)
        x = x + h
        if kind == "cross":
            ek, ev = enc_kv
            enc_pos = jnp.zeros(ek.shape[:2], jnp.int32)
            h, _ = _attn_apply(p["xattn"], norm(p["ln_x"], x), cfg,
                               positions, enc_pos, k_ext=ek, v_ext=ev,
                               causal=False, q_chunk=q_chunk)
            x = x + h
        x = x + _ffn_apply(p["ffn"], norm(p["ln2"], x), cfg, kind)
        if kind == "local_attn" and cfg.rglru:
            W = cfg.rglru.window
            cache = {"k": k[:, -W:], "v": v[:, -W:],
                     "pos": positions[:, -W:]}
        else:
            cache = {"k": k, "v": v}
    elif kind == "ssm":
        h, cache = ssm_lib.ssm_block(p["ssm"], norm(p["ln1"], x),
                                     cfg.d_model, cfg.ssm)
        x = x + h
    elif kind == "rglru":
        h, cache = rglru_lib.rglru_block(p["rglru"], norm(p["ln1"], x),
                                         cfg.rglru)
        x = x + h
        x = x + _ffn_apply(p["ffn"], norm(p["ln2"], x), cfg, "dense")
    return x, cache


def _layer_decode(kind: str, p: dict, x: jax.Array, cache: dict,
                  cfg: ModelConfig, pos: jax.Array, enc_kv=None):
    """Single-token layer step against a cache.  Returns (x, new_cache)."""
    norm = lambda n, h: L.apply_norm(cfg.norm, h, n)
    B = x.shape[0]
    pos_b = jnp.broadcast_to(pos, (B,))
    if kind in ("dense", "moe", "local_attn", "cross"):
        a = cfg.attention
        hin = norm(p["ln1"], x)
        ap = p["attn"]
        q = jnp.einsum("bsd,dhk->bshk", hin, ap["wq"].astype(x.dtype))
        k = jnp.einsum("bsd,dhk->bshk", hin, ap["wk"].astype(x.dtype))
        v = jnp.einsum("bsd,dhk->bshk", hin, ap["wv"].astype(x.dtype))
        q = L.rope(q, pos_b[:, None], a.rope_theta)
        k = L.rope(k, pos_b[:, None], a.rope_theta)
        if kind == "local_attn" and cfg.rglru:
            W = cfg.rglru.window
            slot = pos % W
            k_cache = jax.lax.dynamic_update_slice_in_dim(
                cache["k"], _quant_kv(k, cfg), slot, 1)
            v_cache = jax.lax.dynamic_update_slice_in_dim(
                cache["v"], _quant_kv(v, cfg), slot, 1)
            pos_cache = jax.lax.dynamic_update_slice_in_dim(
                cache["pos"], pos_b[:, None], slot, 1)
            valid = (pos_cache <= pos) & (pos_cache > pos - W)
            bias = jnp.where(valid, 0.0, -0.7 * np.finfo(np.float32).max)
            qg = q.reshape(B, 1, a.num_kv_heads, a.group_size, a.head_dim)
            out = L._attend(qg, _dequant_kv(k_cache, cfg),
                            _dequant_kv(v_cache, cfg),
                            bias[:, None, None, None, :],
                            a.attn_logit_softcap)
            new_cache = {"k": k_cache, "v": v_cache, "pos": pos_cache}
        else:
            k_cache = jax.lax.dynamic_update_slice_in_dim(
                cache["k"], _quant_kv(k, cfg), pos, 1)
            v_cache = jax.lax.dynamic_update_slice_in_dim(
                cache["v"], _quant_kv(v, cfg), pos, 1)
            out = L.decode_attention(q, _dequant_kv(k_cache, cfg),
                                     _dequant_kv(v_cache, cfg), pos_b, a,
                                     cache_len=pos_b + 1)
            out = out.reshape(B, 1, a.num_kv_heads, a.group_size, a.head_dim)
            new_cache = {"k": k_cache, "v": v_cache}
        out = out.reshape(B, 1, a.num_heads, a.head_dim)
        h = jnp.einsum("bshk,hkd->bsd", out, ap["wo"].astype(x.dtype))
        x = x + h
        if kind == "cross":
            ek, ev = enc_kv
            enc_pos = jnp.zeros(ek.shape[:2], jnp.int32)
            h, _ = _attn_apply(p["xattn"], norm(p["ln_x"], x), cfg,
                               pos_b[:, None], enc_pos, k_ext=ek, v_ext=ev,
                               causal=False)
            x = x + h
        x = x + _ffn_apply(p["ffn"], norm(p["ln2"], x), cfg, kind)
        return x, new_cache
    if kind == "ssm":
        h, new_cache = ssm_lib.ssm_decode_step(p["ssm"], norm(p["ln1"], x),
                                               cache, cfg.d_model, cfg.ssm)
        return x + h, new_cache
    if kind == "rglru":
        h, new_cache = rglru_lib.rglru_decode_step(p["rglru"],
                                                   norm(p["ln1"], x), cache,
                                                   cfg.rglru)
        x = x + h
        x = x + _ffn_apply(p["ffn"], norm(p["ln2"], x), cfg, "dense")
        return x, new_cache
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# Remat policy
# ---------------------------------------------------------------------------

def _remat(fn, cfg: ModelConfig):
    if cfg.remat_policy == "none":
        return fn
    if cfg.remat_policy == "minimal":
        policy = jax.checkpoint_policies.dots_with_no_batch_dims_saveable
    else:  # "full"
        policy = jax.checkpoint_policies.nothing_saveable
    return jax.checkpoint(fn, policy=policy)


# ---------------------------------------------------------------------------
# Cache init
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> list:
    """Stacked cache pytrees aligned with params['groups']."""
    a = cfg.attention
    dt = cfg.cdtype()
    kv_dt = _cache_dtype(cfg)
    groups = []
    for (unit, reps) in block_groups(cfg):
        unit_caches = []
        for kind in unit:
            if kind == "ssm":
                c = ssm_lib.init_ssm_cache(batch, cfg.d_model, cfg.ssm, dt)
            elif kind == "rglru":
                c = rglru_lib.init_rglru_cache(batch, cfg.d_model, cfg.rglru,
                                               dt)
            elif kind == "local_attn":
                W = cfg.rglru.window if cfg.rglru else a.window
                c = {"k": jnp.zeros((batch, W, a.num_kv_heads, a.head_dim),
                                    kv_dt),
                     "v": jnp.zeros((batch, W, a.num_kv_heads, a.head_dim),
                                    kv_dt),
                     "pos": -jnp.ones((batch, W), jnp.int32)}
            else:
                c = {"k": jnp.zeros((batch, max_len, a.num_kv_heads,
                                     a.head_dim), kv_dt),
                     "v": jnp.zeros((batch, max_len, a.num_kv_heads,
                                     a.head_dim), kv_dt)}
            unit_caches.append(jax.tree.map(
                lambda x: jnp.broadcast_to(x, (reps,) + x.shape), c))
        groups.append(unit_caches)
    return groups


# ---------------------------------------------------------------------------
# Full passes
# ---------------------------------------------------------------------------

def _embed_inputs(params, tokens, cfg: ModelConfig, extra_embeds=None):
    x = jnp.take(params["embed"], tokens, axis=0).astype(cfg.cdtype())
    if cfg.family == "hybrid":  # gemma-style embedding scale
        x = x * jnp.asarray(np.sqrt(cfg.d_model), x.dtype)
    if cfg.num_image_tokens and extra_embeds is not None:
        n = cfg.num_image_tokens
        x = jnp.concatenate([extra_embeds.astype(x.dtype), x[:, n:]], axis=1)
    return constrain(x, "batch", None, None)


def _encoder_fwd(params, audio_embeds, cfg: ModelConfig):
    """Whisper-style encoder over stub frame embeddings (B, S_enc, D)."""
    x = audio_embeds.astype(cfg.cdtype())
    pos = jnp.broadcast_to(jnp.arange(x.shape[1], dtype=jnp.int32),
                           x.shape[:2])
    ecfg = _encoder_cfg(cfg)
    p = params["encoder"]["layers"]

    def body(h, pl):
        h, _ = _layer_fwd("dense", pl, h, ecfg, pos)
        return h, None

    x, _ = jax.lax.scan(_remat(body, cfg), x, p)
    return L.apply_norm(cfg.norm, x, params["encoder"]["final_norm"])


def _enc_cross_kv(params, enc_out, cfg: ModelConfig):
    """Precompute per-decoder-layer cross-attention K/V from encoder out."""
    kvs = []
    for unit_params in params["groups"]:
        for p in unit_params:
            xp = p["xattn"]
            k = jnp.einsum("bsd,rdhk->rbshk", enc_out,
                           xp["wk"].astype(enc_out.dtype))
            v = jnp.einsum("bsd,rdhk->rbshk", enc_out,
                           xp["wv"].astype(enc_out.dtype))
            kvs.append((k, v))
    return kvs


def _lm_logits(params, x, cfg: ModelConfig):
    x = L.apply_norm(cfg.norm, x, params["final_norm"])
    if cfg.tie_embeddings:
        w = params["embed"].astype(x.dtype).T
    else:
        w = params["lm_head"].astype(x.dtype)
    return constrain(x @ w, "batch", None, "tp")


def forward(params: dict, tokens: jax.Array, cfg: ModelConfig, *,
            extra_embeds: Optional[jax.Array] = None,
            audio_embeds: Optional[jax.Array] = None,
            q_chunk: int = 2048, want_cache: bool = False):
    """Full-sequence forward.  Returns (logits, cache-or-None)."""
    B, S = tokens.shape
    x = _embed_inputs(params, tokens, cfg, extra_embeds)
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))

    enc_kvs = None
    if cfg.is_encdec:
        enc_out = _encoder_fwd(params, audio_embeds, cfg)
        enc_kvs = _enc_cross_kv(params, enc_out, cfg)

    caches = []
    gi_cross = 0
    for g, (unit, reps) in enumerate(block_groups(cfg)):
        unit_params = params["groups"][g]

        if cfg.is_encdec:
            ek, ev = enc_kvs[gi_cross]
            gi_cross += 1

            def body(h, xs):
                pl, ekl, evl = xs
                h, c = _layer_fwd("cross", pl, h, cfg, positions,
                                  enc_kv=(ekl, evl), q_chunk=q_chunk)
                return h, c

            x, cache = jax.lax.scan(_remat(body, cfg), x,
                                    (unit_params[0], ek, ev))
            caches.append([cache])
            continue

        def body(h, pl):
            cs = []
            for kind, pk in zip(unit, pl):
                h, c = _layer_fwd(kind, pk, h, cfg, positions,
                                  q_chunk=q_chunk)
                cs.append(c)
            return h, cs

        x, cache = jax.lax.scan(_remat(body, cfg), x, unit_params)
        caches.append(cache)

    logits = _lm_logits(params, x, cfg)
    return logits, (caches if want_cache else None)


def forward_train(params, tokens, targets, cfg: ModelConfig, *,
                  loss_mask=None, extra_embeds=None, audio_embeds=None,
                  q_chunk: int = 2048):
    """Token-mean cross-entropy loss (fp32 logsumexp)."""
    logits, _ = forward(params, tokens, cfg, extra_embeds=extra_embeds,
                        audio_embeds=audio_embeds, q_chunk=q_chunk)
    from repro.models.loss import cross_entropy
    if loss_mask is None and cfg.num_image_tokens:
        B, S = tokens.shape
        pos = jnp.arange(S)[None, :]
        loss_mask = jnp.broadcast_to(pos >= cfg.num_image_tokens, (B, S))
    return cross_entropy(logits, targets, loss_mask)


def prefill(params, tokens, cfg: ModelConfig, max_len: int, *,
            extra_embeds=None, audio_embeds=None, q_chunk: int = 2048):
    """Run the prompt; returns (last-position logits, caches @ max_len)."""
    logits, caches = forward(params, tokens, cfg, extra_embeds=extra_embeds,
                             audio_embeds=audio_embeds, q_chunk=q_chunk,
                             want_cache=True)
    S = tokens.shape[1]
    padded = []
    for g, (unit, reps) in enumerate(block_groups(cfg)):
        unit_caches = []
        for u, kind in enumerate(unit):
            c = caches[g][u]
            if kind in ("dense", "moe", "cross") and "k" in c:
                pad = [(0, 0)] * c["k"].ndim
                pad[2] = (0, max_len - S)
                c = {"k": jnp.pad(_quant_kv(c["k"], cfg), pad),
                     "v": jnp.pad(_quant_kv(c["v"], cfg), pad)}
            elif kind == "local_attn" and "k" in c:
                c = dict(c, k=_quant_kv(c["k"], cfg),
                         v=_quant_kv(c["v"], cfg))
            unit_caches.append(c)
        padded.append(unit_caches)
    if cfg.is_encdec:
        enc_out = _encoder_fwd(params, audio_embeds, cfg)
        return logits[:, -1, :], (padded, _enc_cross_kv(params, enc_out, cfg))
    return logits[:, -1, :], padded


def decode_step(params, token: jax.Array, caches, pos: jax.Array,
                cfg: ModelConfig, *, enc_kvs=None):
    """One serving step: token (B, 1) at position ``pos`` (scalar int32).

    Returns (logits (B, V), new caches).  The KV/state caches are donated in
    the jitted serve_step (see launch/serve.py) so updates are in-place.
    """
    x = _embed_inputs(params, token, cfg)
    if cfg.is_encdec and enc_kvs is None:
        caches, enc_kvs = caches

    new_caches = []
    gi = 0
    for g, (unit, reps) in enumerate(block_groups(cfg)):
        unit_params = params["groups"][g]
        unit_cache = caches[g]

        if cfg.is_encdec:
            ek, ev = enc_kvs[gi]
            gi += 1

            def body(h, xs):
                pl, cl, ekl, evl = xs
                h, c = _layer_decode("cross", pl, h, cl, cfg, pos,
                                     enc_kv=(ekl, evl))
                return h, c

            x, ncache = jax.lax.scan(body, x,
                                     (unit_params[0], unit_cache[0], ek, ev))
            new_caches.append([ncache])
            continue

        def body(h, xs):
            pl, cl = xs
            ncs = []
            for kind, pk, ck in zip(unit, pl, cl):
                h, nc = _layer_decode(kind, pk, h, ck, cfg, pos)
                ncs.append(nc)
            return h, ncs

        x, ncache = jax.lax.scan(body, x, (unit_params, unit_cache))
        new_caches.append(ncache)

    logits = _lm_logits(params, x, cfg)
    if cfg.is_encdec:
        return logits[:, 0, :], (new_caches, enc_kvs)
    return logits[:, 0, :], new_caches


# ---------------------------------------------------------------------------
# Parameter accounting (roofline MODEL_FLOPS)
# ---------------------------------------------------------------------------

def count_params(params) -> int:
    return sum(int(np.prod(x.shape)) for x in jax.tree.leaves(params))


def active_params(cfg: ModelConfig, total: int) -> int:
    """Active parameters per token (MoE: only top-k experts count)."""
    if cfg.family != "moe":
        return total
    m = cfg.moe
    per_expert = 3 * cfg.d_model * m.d_ff_expert
    n_moe_layers = cfg.num_layers // m.interleave_step
    inactive = per_expert * (m.num_experts - m.top_k) * n_moe_layers
    return total - inactive
