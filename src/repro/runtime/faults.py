"""Worker-loss supervision: quarantine, re-dispatch, degraded release.

The master consults a :class:`FaultSupervisor` from its fusion wait loops
instead of letting transport liveness errors propagate.  Behaviour is
selected by ``RuntimeConfig.fault_policy``:

``fail-fast`` (default)
    Today's contract, unchanged: any unexpectedly-dead worker raises
    :class:`~repro.runtime.errors.TransportDeadError` out of the run.

``degrade``
    The run *survives* worker death.  On every consultation the
    supervisor

    1. offers quarantined workers a way back in
       (:meth:`WorkerTransport.try_readmit` — only the socket backend's
       reconnect path can ever succeed), re-splitting the eq. (1)
       ``kappa`` over the enlarged fleet;
    2. scans :meth:`WorkerTransport.dead_worker_map` for *new* deaths,
       quarantines each (the transport withholds all future slices and
       tears down its side of the worker), and has the
       :class:`~repro.runtime.adaptive.OmegaController` re-split
       ``kappa`` over the survivors — shrinking redundancy in proportion
       to the lost service capacity, floored at ``omega = 1``
       (see :meth:`OmegaController.refit_fleet`);
    3. re-dispatches the in-flight round's *lost* tasks — every coded
       task whose current owner is quarantined, whether it was sent and
       died with the worker or withheld at submit because the round's
       buffered ``kappa`` predates the death — to survivors, with a
       bounded number of attempts per round and exponential backoff
       (jittered so repeated fleet-wide retries do not synchronize).
       Duplicate deliveries are legal: the fusion node dedupes by
       ``task_id``, so a re-dispatch racing the original worker's
       last-gasp result can never hand the Vandermonde decode a
       singular arrival set.

    The supervisor's verdict (:meth:`check` returning True) means *give
    up on the in-flight round*: either the fleet collapsed below the
    recovery threshold ``k`` (``collapsed`` — no geometry can decode;
    the master releases every in-flight and queued job promptly at its
    best-ready resolution, marked degraded) or the round exhausted its
    re-dispatch budget (the master terminates just that job, degraded,
    and keeps serving).  Never a hang, never an abort.

Everything the supervisor does is recorded twice: as telemetry events
(``QUARANTINE`` / ``READMIT`` / ``REDISPATCH``) when the run traces, and
unconditionally in :attr:`fault_log` — a list of plain dicts (``t``
seconds from run start, ``kind`` in {``quarantine``, ``readmit``,
``redispatch``, ``redispatch-exhausted``, ``fleet-collapse``,
``fleet-recovered``}, plus per-kind fields) surfaced on
:class:`~repro.runtime.metrics.RuntimeResult`.
"""

from __future__ import annotations

import random
import time
from typing import Optional

import numpy as np

from repro.runtime import telemetry
from repro.runtime.adaptive import OmegaController
from repro.runtime.fusion import RoundFusion
from repro.runtime.tasks import RoundContext, RuntimeConfig
from repro.runtime.transport.base import WorkerTransport

__all__ = ["FaultSupervisor"]

clock = time.monotonic


class _TrackedRound:
    """Dispatch state of the in-flight round, as the supervisor sees it.

    ``owner`` maps every coded task index to the worker currently
    responsible for it — initialized from the round's own eq. (1)
    ``kappa`` (the split it was *encoded* with, which may predate a
    quarantine) and rewritten by each re-dispatch, so nested failures
    (a survivor dying while holding a re-dispatched slice) re-lose
    exactly the right tasks.
    """

    __slots__ = ("ctx", "X", "Y", "rf", "owner", "attempts",
                 "next_attempt", "abandoned")

    def __init__(self, ctx: RoundContext, X: np.ndarray, Y: np.ndarray,
                 kappa: np.ndarray, rf: RoundFusion):
        self.ctx = ctx
        self.X = X
        self.Y = Y
        self.rf = rf
        self.owner: dict[int, int] = {}
        lo = 0
        for p, kp in enumerate(np.asarray(kappa, dtype=np.int64)):
            for t in range(lo, lo + int(kp)):
                self.owner[t] = p
            lo += int(kp)
        self.attempts = 0
        self.next_attempt = 0.0
        self.abandoned = False

    def settled(self) -> bool:
        """True when the round no longer needs supervision."""
        return self.abandoned or self.ctx.cancelled or self.rf.wait(0.0)

    def lost_runs(self, quarantined: set[int]) -> list[tuple[int, int]]:
        """Maximal contiguous ``[lo, hi)`` runs of tasks whose owner is
        quarantined — the units a re-dispatch ships (``_send_slice``
        moves one contiguous slice of the coded buffers)."""
        lost = sorted(t for t, p in self.owner.items() if p in quarantined)
        runs: list[tuple[int, int]] = []
        for t in lost:
            if runs and runs[-1][1] == t:
                runs[-1] = (runs[-1][0], t + 1)
            else:
                runs.append((t, t + 1))
        return runs


class FaultSupervisor:
    """Master-side fault authority for one run (see module docstring)."""

    #: Re-dispatch attempts per round before the job is released degraded.
    MAX_REDISPATCH = 3
    #: Base / ceiling of the jittered exponential re-dispatch backoff (s).
    REDISPATCH_BACKOFF = 0.05
    REDISPATCH_BACKOFF_CAP = 1.0
    #: Seconds between readmission probes (socket reconnect is a dial).
    READMIT_INTERVAL = 1.0

    def __init__(self, cfg: RuntimeConfig, pool: WorkerTransport,
                 controller: OmegaController,
                 tracer: Optional[telemetry.Tracer] = None):
        self.cfg = cfg
        self.pool = pool
        self.controller = controller
        self._tracer = tracer
        self.degrade = cfg.fault_policy == "degrade"
        #: Chronological fault record (RuntimeResult.fault_log).
        self.fault_log: list[dict] = []
        #: Distinct worker deaths handled (readmission re-arms a slot).
        self.workers_lost = 0
        #: Fleet fell below k: no geometry can decode any further round.
        self.collapsed = False
        self._handled: dict[int, str] = {}
        self._round: Optional[_TrackedRound] = None
        self._next_readmit = 0.0
        self._t0 = clock()
        self._rng = random.Random(cfg.seed ^ 0xFA17)

    # -- master-facing surface ------------------------------------------------
    @property
    def wait_slice(self) -> float:
        """How often the master's fusion wait yields to :meth:`check`.

        Fail-fast keeps the historical 5 s liveness slice; degrade mode
        polls fast enough that detection -> quarantine -> re-dispatch
        costs a fraction of a round, not multiples of one.
        """
        return 0.25 if self.degrade else 5.0

    def set_origin(self, t0: float) -> None:
        """Anchor ``fault_log`` timestamps on the run start instant."""
        self._t0 = t0

    def track_round(self, ctx: RoundContext, X: np.ndarray, Y: np.ndarray,
                    kappa: np.ndarray, rf: RoundFusion) -> None:
        """Register the just-dispatched round as the supervised in-flight
        round (master calls this right after ``submit_round``)."""
        if self.degrade:
            self._round = _TrackedRound(ctx, X, Y, kappa, rf)

    def check(self) -> bool:
        """One supervision step; called from the master's wait loops.

        Returns True when the master must give up on the in-flight
        round (fleet collapse or re-dispatch budget exhausted) and
        release the job at its best-ready resolution, degraded.  Under
        ``fail-fast`` this is exactly the historical
        ``pool.assert_alive()`` (raises instead of returning True).
        """
        if not self.degrade:
            self.pool.assert_alive()
            return False
        if self.collapsed:
            # terminal for a fleet that cannot come back (thread/process
            # workers), but a socket host reconnecting can re-arm the run
            if (self._readmit(clock())
                    and self.controller.refit_fleet(
                        self.pool.active_workers)):
                self.collapsed = False
                self._log("fleet-recovered",
                          survivors=len(self.pool.active_workers))
                return False
            return True
        now = clock()
        refit = self._readmit(now)
        refit = self._quarantine_new_deaths() or refit
        if refit and not self.controller.refit_fleet(
                self.pool.active_workers):
            self.collapsed = True
            self._log("fleet-collapse",
                      survivors=len(self.pool.active_workers),
                      k=self.cfg.k)
            return True
        return self._redispatch(now)

    # -- internals ------------------------------------------------------------
    def _log(self, kind: str, **fields) -> None:
        self.fault_log.append(
            {"t": round(clock() - self._t0, 6), "kind": kind, **fields})

    def _readmit(self, now: float) -> bool:
        """Offer quarantined workers a way back; True if the fleet grew."""
        if not self.pool.quarantined or now < self._next_readmit:
            return False
        self._next_readmit = now + self.READMIT_INTERVAL
        readmitted = self.pool.try_readmit()
        for p in readmitted:
            # re-arm the death slot: a readmitted worker that dies again
            # is a NEW fault, not an already-handled one
            reason = self._handled.pop(p, "")
            self._log("readmit", worker=p, was=reason)
            if self._tracer is not None:
                self._tracer.emit(telemetry.READMIT, clock(), worker=p,
                                  label=reason)
        return bool(readmitted)

    def _quarantine_new_deaths(self) -> bool:
        """Quarantine unhandled deaths; True if the fleet shrank."""
        dead = self.pool.dead_worker_map()
        newly = {p: desc for p, desc in dead.items()
                 if p not in self._handled}
        for p, desc in sorted(newly.items()):
            self._handled[p] = desc
            self.pool.quarantine(p, desc)   # emits QUARANTINE when traced
            self.workers_lost += 1
            self._log("quarantine", worker=p, reason=desc)
        return bool(newly)

    def _redispatch(self, now: float) -> bool:
        """Re-send the in-flight round's lost tasks to survivors.

        Returns True only when the round exhausted its re-dispatch
        budget — the master's cue to release this job degraded.
        """
        r = self._round
        if r is None or r.settled():
            return False
        runs = r.lost_runs(self.pool.quarantined)
        if not runs or now < r.next_attempt:
            return False
        if r.attempts >= self.MAX_REDISPATCH:
            r.abandoned = True
            self._log("redispatch-exhausted", job=r.ctx.job_id,
                      round=r.ctx.round_idx, attempts=r.attempts,
                      tasks=sum(hi - lo for lo, hi in runs))
            return True
        r.attempts += 1
        backoff = min(self.REDISPATCH_BACKOFF_CAP,
                      self.REDISPATCH_BACKOFF * (2 ** (r.attempts - 1)))
        r.next_attempt = now + backoff * self._rng.uniform(0.5, 1.5)
        survivors = self.pool.active_workers
        for i, (lo, hi) in enumerate(runs):
            target = survivors[i % len(survivors)]
            # zero injected delays: the re-dispatch replaces work whose
            # straggler draw already happened; re-drawing would double-
            # penalize the round, and a lost slice should recover at the
            # survivor's native speed
            self.pool.resend_slice(target, r.ctx, lo, r.X[lo:hi],
                                   r.Y[lo:hi], np.zeros(hi - lo))
            for t in range(lo, hi):
                r.owner[t] = target
            self._log("redispatch", job=r.ctx.job_id,
                      round=r.ctx.round_idx, worker=target,
                      first_task=lo, tasks=hi - lo, attempt=r.attempts)
            if self._tracer is not None:
                self._tracer.emit(telemetry.REDISPATCH, clock(),
                                  job=r.ctx.job_id, round=r.ctx.round_idx,
                                  worker=target, value=float(hi - lo))
        return False
