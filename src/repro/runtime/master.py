"""The master node: queue, dispatch, purge, terminate, release (§IV).

A measured, genuinely-concurrent execution of the system the simulator
models: jobs arrive (Poisson or trace), are served FIFO one at a time
(the paper's single-master discipline), and each job's ``m**2`` coded
mini-job rounds run MSB-first on an abstract
:class:`~repro.runtime.transport.base.WorkerTransport` — thread workers,
multiprocessing workers, or JAX-device workers, selected by
``RuntimeConfig.backend``; the loop below is identical over all of them:

1. service start — operands are quantized (floats) and digit-decomposed;
2. per round, the mini-job's plane pair is polynomial-encoded
   (:class:`~repro.core.coding.PolynomialCode`) and its ``T`` coded tasks
   are dispatched per the eq. (1) ``kappa`` split;
3. the fusion node decodes at the k-th arrival and the master *purges*
   the round's stragglers (their cancel event reclaims them instantly);
4. each completed layer is published MSB-first on the job's
   :class:`~repro.runtime.fusion.LayeredResult`;
5. the §IV rule terminates a job at
   ``t_term = max(service_start + deadline, next_arrival)`` — termination
   requires BOTH deadline excess AND a queued successor — releasing the
   highest completed resolution.

Jobs reach the loop through one of two *sources* sharing the identical
service path: :meth:`Master.run` replays a fixed arrival trace (the
historical mode — the full job list is known up front and arrivals are
slept out on the master clock), while :meth:`Master.serve_queue` drains
an open :class:`JobQueue` that other threads feed *while the loop runs* —
continuous admission over one warm fleet, the serving-gateway substrate
(:mod:`repro.runtime.gateway`).  Queued jobs carry their own absolute
deadline (:attr:`~repro.runtime.tasks.JobSpec.deadline_at`, an
unconditional release instant), an optional guaranteed minimum
resolution the deadline may not cut, and an optional resolution cap
that bounds the round budget (an admission down-resolve never computes
LSB rounds it won't release).

The per-round loop is *software-pipelined* so the master's own work hides
behind the in-flight round's worker compute instead of serializing with
it: round ``r``'s codeword is double-buffered and dispatched, then —
while the workers chew on it — the master decodes round ``r-1``
(publishing any completed layer), encodes round ``r+1`` into the spare
buffer, and, on a job's final round, digit-decomposes the next *queued*
job's operands.  Purge safety is preserved because each round still owns
its private :class:`RoundContext`; the §IV termination check still gates
every dispatch; and decode itself rides on the code's cached
:class:`~repro.core.coding.DecodePlan` (LRU of per-arrival-set solve
operators), so the steady-state critical path per round is dispatch +
fusion wait.  Per-stage wall time is accounted in
``RuntimeResult.stage_seconds``.

Redundancy is controlled *online*: after every round the master feeds the
:class:`~repro.runtime.adaptive.OmegaController` a
:class:`~repro.runtime.adaptive.RoundObservation` (fusion wait, stale
count, deadline margin, utilization) and subsequent encodes pick up any
retuned ``(code, kappa)`` — see :mod:`repro.runtime.adaptive` and
``docs/adaptive-omega.md``.  With the default ``cfg.adapt = "fixed"`` the
geometry never moves and the loop is the paper's static-ω system.

With ``verify=True`` every published resolution is checked against the
exact layered oracle (``layering.layered_matmul_reference``, the same
oracle the Pallas kernel in ``repro.kernels.layered_matmul`` is tested
against), so a measured run is decode-verified end-to-end.
"""

from __future__ import annotations

import collections
import threading
import time
from typing import Optional, Sequence

import jax.numpy as jnp
import numpy as np

from repro.core import coding, layering
from repro.runtime import metrics, telemetry
from repro.runtime.adaptive import OmegaController, RoundObservation
from repro.runtime.faults import FaultSupervisor
from repro.runtime.fusion import FusionNode, LayeredResult
from repro.runtime.tasks import JobSpec, RoundContext, RuntimeConfig
from repro.runtime.transport import make_transport
from repro.runtime.worker import clock

__all__ = ["JobQueue", "Master", "make_jobs", "run_jobs"]


def make_jobs(cfg: RuntimeConfig, num_jobs: int, *, K: int = 64, M: int = 8,
              N: int = 8, rng: Optional[np.random.Generator] = None,
              arrivals: Optional[Sequence[float]] = None) -> list[JobSpec]:
    """Random integer-matrix jobs with Poisson (or trace) arrivals.

    Operand magnitudes stay well inside ``m * d`` bits so float-mode decode
    is tight; ``M``/``N`` must be divisible by ``n1``/``n2``.
    """
    rng = rng if rng is not None else np.random.default_rng(cfg.seed)
    if arrivals is None:
        arrivals = np.cumsum(
            rng.exponential(1.0 / cfg.arrival_rate, size=num_jobs))
    arrivals = np.asarray(arrivals, dtype=np.float64)
    if len(arrivals) != num_jobs:
        raise ValueError(f"{len(arrivals)} arrivals for {num_jobs} jobs")
    lim = 1 << (cfg.m * cfg.d - 2)
    return [JobSpec(job_id=j,
                    a=rng.integers(-lim, lim, size=(K, M), dtype=np.int64),
                    b=rng.integers(-lim, lim, size=(K, N), dtype=np.int64),
                    arrival=float(arrivals[j]))
            for j in range(num_jobs)]


class JobQueue:
    """Thread-safe open job queue feeding :meth:`Master.serve_queue`.

    Producers (any thread — the serving gateway's submit path) ``put``
    :class:`~repro.runtime.tasks.JobSpec` items; the master consumes
    them FIFO.  :meth:`close` ends admission: the master drains whatever
    is still queued and returns.  A ``put`` after ``close`` raises — the
    caller must surface it as a rejected request, never a silent drop.
    """

    def __init__(self):
        self._cv = threading.Condition()
        self._items: collections.deque = collections.deque()
        self._closed = False

    def put(self, job: JobSpec) -> None:
        """Enqueue one job; raises ``RuntimeError`` once closed."""
        with self._cv:
            if self._closed:
                raise RuntimeError("JobQueue is closed")
            self._items.append(job)
            self._cv.notify_all()

    def close(self) -> None:
        """End admission (idempotent); wakes a blocked consumer."""
        with self._cv:
            self._closed = True
            self._cv.notify_all()

    @property
    def closed(self) -> bool:
        with self._cv:
            return self._closed

    def __len__(self) -> int:
        with self._cv:
            return len(self._items)

    # -- consumer side (the master's _QueueSource) ---------------------------
    def _next(self) -> Optional[JobSpec]:
        """Pop the next job, blocking until one arrives; ``None`` once
        closed and drained."""
        with self._cv:
            while not self._items and not self._closed:
                self._cv.wait()
            return self._items.popleft() if self._items else None

    def _peek(self) -> Optional[JobSpec]:
        """The next queued job without consuming it (``None`` if empty)."""
        with self._cv:
            return self._items[0] if self._items else None


class _TraceSource:
    """Replays a fixed arrival trace — the legacy :meth:`Master.run`
    semantics: sleep out each arrival, and expose the next trace arrival
    as the §IV queued-successor signal."""

    def __init__(self, jobs: Sequence[JobSpec]):
        self.jobs = list(jobs)
        self._i = 0
        self._t0 = 0.0

    def bind(self, t0: float) -> None:
        self._t0 = t0

    def next(self) -> Optional[JobSpec]:
        if self._i >= len(self.jobs):
            return None
        job = self.jobs[self._i]
        self._i += 1
        return job

    def wait_arrival(self, job: JobSpec) -> None:
        wait = (self._t0 + job.arrival) - clock()
        if wait > 0:           # idle until the job actually arrives
            time.sleep(wait)

    def peek_ready(self) -> Optional[JobSpec]:
        """The next job, only once its arrival instant has passed —
        the encode-ahead prep must not front-run the arrival process."""
        i = self._i
        if (i < len(self.jobs)
                and clock() >= self._t0 + self.jobs[i].arrival):
            return self.jobs[i]
        return None

    def successor_hint(self) -> Optional[float]:
        """Absolute arrival instant of the queued successor (§IV)."""
        i = self._i
        if i < len(self.jobs):
            return self._t0 + self.jobs[i].arrival
        return None


class _QueueSource:
    """Drains an open :class:`JobQueue` — continuous admission.

    A queued job has, by construction, already arrived (the producer
    stamped ``JobSpec.arrival`` at submit time), so ``wait_arrival`` is a
    no-op; and with no trace there is no next-arrival signal, so
    ``cfg.deadline`` alone never terminates a queued job — per-job
    deadlines travel on ``JobSpec.deadline_at`` instead."""

    def __init__(self, queue: JobQueue):
        self.queue = queue

    def bind(self, t0: float) -> None:
        del t0

    def next(self) -> Optional[JobSpec]:
        return self.queue._next()

    def wait_arrival(self, job: JobSpec) -> None:
        del job

    def peek_ready(self) -> Optional[JobSpec]:
        return self.queue._peek()

    def successor_hint(self) -> Optional[float]:
        return None


class Master:
    """Event loop owning the worker transport, fusion node, and
    ω-controller.

    Single-threaded driver: :meth:`run` (fixed trace) or
    :meth:`serve_queue` (open queue) is meant to be called once, from one
    thread — it starts the configured worker transport (``cfg.backend``:
    thread / process / jax / socket, via
    :func:`repro.runtime.transport.make_transport`), blocks until every
    job is served, and shuts the transport down (purge-mode: every
    submitted round is already fused or terminated by then).  The
    cross-thread surfaces are the
    :class:`~repro.runtime.fusion.LayeredResult` futures it returns
    (consumable concurrently while the run progresses), the fusion
    node's result sink (remote transports pump it from a drain thread),
    and — in queue mode — the :class:`JobQueue` itself plus the
    :attr:`started` event / :attr:`t0` origin that producers use to put
    their timestamps on the master's clock.  All reported times are
    seconds (``time.monotonic`` deltas from the run start).

    The code geometry is owned by an
    :class:`~repro.runtime.adaptive.OmegaController` (``cfg.adapt`` picks
    the policy; the default ``"fixed"`` reproduces the paper's static-ω
    §IV system exactly): after every round the master feeds it a
    :class:`~repro.runtime.adaptive.RoundObservation` and subsequent
    encodes pick up any retuned ``(code, kappa)``.
    """

    def __init__(self, cfg: RuntimeConfig, *, verify: bool = False):
        self.cfg = cfg
        self.verify = verify
        # telemetry is opt-in (cfg.trace) and free when off: the tracer is
        # None and every call site below guards on it — no event objects
        # are ever built on the untraced path
        self.tracer = telemetry.Tracer() if cfg.trace else None
        self.fusion = FusionNode(tracer=self.tracer)
        self.controller = OmegaController(cfg)
        #: eq. (1) splits cached per ``(T, active)`` for the hierarchical
        #: family: level lengths repeat every group, and the optimization
        #: behind :meth:`RuntimeConfig.load_split` is ms-scale — paying it
        #: per level would dwarf a whole round's fuse time.  (The flat
        #: family's split is cached the same way, as ``controller.kappa``.)
        self._hier_kappas: dict = {}
        #: Monotonic origin of the serve loop — valid once :attr:`started`
        #: is set.  Queue-mode producers stamp ``JobSpec.arrival`` /
        #: ``deadline_at`` as offsets from this instant.
        self.t0: Optional[float] = None
        #: Set just before the first job is consumed (fleet started,
        #: warmup done, :attr:`t0` valid).
        self.started = threading.Event()

    # -- operand preparation -------------------------------------------------
    def _prepare(self, job: JobSpec):
        """Quantize float operands, digit-decompose both into m planes."""
        cfg = self.cfg
        bits = cfg.m * cfg.d
        if np.issubdtype(np.asarray(job.a).dtype, np.floating):
            qa, sa = layering.quantize(jnp.asarray(job.a), bits)
            qa, sa = np.asarray(qa, np.int64), float(sa)
        else:
            qa, sa = np.asarray(job.a, np.int64), 1.0
        if np.issubdtype(np.asarray(job.b).dtype, np.floating):
            qb, sb = layering.quantize(jnp.asarray(job.b), bits)
            qb, sb = np.asarray(qb, np.int64), float(sb)
        else:
            qb, sb = np.asarray(job.b, np.int64), 1.0
        ca = layering._np_decompose(qa, cfg.m, cfg.d)   # (m, K, M)
        cb = layering._np_decompose(qb, cfg.m, cfg.d)   # (m, K, N)
        return qa, qb, sa * sb, ca, cb

    def _warmup(self, job: JobSpec) -> None:
        """Run one encode/compute/decode off the clock (BLAS/cache warm)."""
        code = self.controller.code
        _, _, _, ca, cb = self._prepare(job)
        X = code.encode_a(np.asarray(ca[0], np.float64))
        Y = code.encode_b(np.asarray(cb[0], np.float64))
        code.decode(list(range(code.k)),
                    np.stack([X[t].T @ Y[t] for t in range(code.k)]))

    def _warmup_job(self) -> JobSpec:
        """A tiny synthetic job for off-the-clock warmup — queue mode,
        where no real job is known before the fleet starts."""
        cfg = self.cfg
        rng = np.random.default_rng(cfg.seed ^ 0x5EED)
        lim = min(1 << (cfg.m * cfg.d - 2), 1 << 16)
        return JobSpec(
            job_id=-1,
            a=rng.integers(-lim, lim, size=(16, 2 * cfg.n1), dtype=np.int64),
            b=rng.integers(-lim, lim, size=(16, 2 * cfg.n2), dtype=np.int64))

    # -- hierarchical (sub-task-granular) service ------------------------------
    def _serve_hier_job(self, job, lr, prep, pool, sup, t_term, R_job,
                        guaranteed, stage, global_round, prev_stale):
        """Serve one job with the hierarchical code family.

        Rounds are dispatched in *groups* of up to ``cfg.levels``
        consecutive MSB-first mini-jobs, each level its own coded round
        under one :class:`~repro.core.coding.HierarchicalCode` (per-level
        MDS rates, MSB-heavy at the controller's current aggregate
        budget).  Every worker receives its slices of the whole group in
        one message and flows through the levels in order, so while the
        master waits on the frontier level, results for deeper levels
        bank in the fusion group — straggler work is never discarded,
        only the *specific level* that fused is purged
        (:meth:`WorkerTransport.purge_level`).  A deadline or fault that
        cuts the job mid-group still ships every level that completed —
        the §IV release happens at the best level-complete resolution.

        Returns ``(term, faulted, rounds_timed, global_round,
        prev_stale)`` so the caller's shared release tail and controller
        bookkeeping continue unchanged.
        """
        cfg = self.cfg
        ctrl = self.controller
        tr = self.tracer
        t0 = self.t0
        qa, qb, scale, ca, cb = prep
        order = layering.all_minijobs_msb_first(cfg.m)
        cum = layering.cumulative_minijobs(cfg.m)
        acc = np.zeros((qa.shape[1], qb.shape[1]), dtype=np.float64)
        # per-side coded planes keyed by (T, plane): level lengths vary
        # across the group (MSB-heavy), so each length caches separately
        enc_a: dict[tuple[int, int], np.ndarray] = {}
        enc_b: dict[tuple[int, int], np.ndarray] = {}
        n_ret = len(ctrl.trace)
        timed = 0
        term = False
        faulted = False
        ridx0 = 0
        while ridx0 < R_job and not term:
            g_end = min(ridx0 + cfg.levels, R_job)
            rounds = order[ridx0:g_end]
            G = len(rounds)
            if sup.check():
                faulted = term = True
                break
            if (t_term is not None and ridx0 >= guaranteed
                    and clock() >= t_term):
                term = True      # don't dispatch a dead group
                break
            # the group's code picks up the controller's current geometry
            # (ω retune / fleet refit): per-level lengths are re-derived
            # from ctrl.omega and the split from ctrl.active every group
            hc = coding.HierarchicalCode(n1=cfg.n1, n2=cfg.n2, levels=G,
                                         omega=ctrl.omega, mode="float")
            ts = clock()
            ctxs: list[RoundContext] = []
            Xs, Ys, kappas, codes = [], [], [], []
            for lvl in range(G):
                lcode = hc.level_code(lvl)
                T = lcode.num_tasks
                _, pi, pj = rounds[lvl]
                Xa = enc_a.get((T, pi))
                if Xa is None:
                    Xa = enc_a[(T, pi)] = lcode.encode_a(
                        np.asarray(ca[pi], np.float64))
                Yb = enc_b.get((T, pj))
                if Yb is None:
                    Yb = enc_b[(T, pj)] = lcode.encode_b(
                        np.asarray(cb[pj], np.float64))
                ctxs.append(RoundContext(job.job_id, ridx0 + lvl))
                Xs.append(Xa)
                Ys.append(Yb)
                kappa = self._hier_kappas.get((T, ctrl.active))
                if kappa is None:
                    kappa = self._hier_kappas[(T, ctrl.active)] = \
                        cfg.load_split(total=T, active=ctrl.active)
                kappas.append(kappa)
                codes.append(lcode)
            te = clock()
            stage["encode"] += te - ts
            if tr is not None:
                tr.emit(telemetry.ENCODE, ts, te - ts, job=job.job_id,
                        round=ridx0)
            rfs = self.fusion.begin_group(ctxs, cfg.k)
            ts = t_disp = clock()
            pool.submit_group(ctxs, Xs, Ys, kappas)
            stage["dispatch"] += clock() - ts
            timed += G
            # frontier walk: wait the levels out MSB-first; any result
            # landing beyond the frontier banks as salvaged sub-task work
            for lvl in range(G):
                ridx = ridx0 + lvl
                l, pi, pj = rounds[lvl]
                rf = rfs[lvl]
                ctx = ctxs[lvl]
                self.fusion.set_frontier(ridx)
                # frontier level is the one a worker death re-dispatches
                sup.track_round(ctx, Xs[lvl], Ys[lvl], kappas[lvl], rf)
                global_round += 1
                ts = clock()
                if t_term is None or ridx < guaranteed:
                    while not (fused := rf.wait(sup.wait_slice)):
                        if sup.check():
                            faulted = True
                            break
                else:
                    while True:
                        remaining = t_term - clock()
                        if remaining <= 0.0:
                            fused = rf.wait(0.0)
                            break
                        if (fused := rf.wait(min(remaining,
                                                 sup.wait_slice))):
                            break
                        if sup.check():
                            faulted = True
                            break
                if faulted and rf.wait(0.0):
                    # fused in the window between the wait slice timing
                    # out and the supervisor giving up — never discarded
                    fused, faulted = True, False
                tw = clock()
                stage["wait"] += tw - ts
                if tr is not None:
                    tr.emit(telemetry.ROUND, t_disp, tw - t_disp,
                            job=job.job_id, round=ridx,
                            label="fused" if fused else "purged")
                if fused:
                    # purge only THIS level's stragglers: deeper levels
                    # of the group stay live on every worker
                    pool.purge_level(ctx)
                    td = clock()
                    mini = rf.decode(codes[lvl])
                    tp = clock()
                    stage["decode"] += tp - td
                    acc[...] += mini * float(1 << ((pi + pj) * cfg.d))
                    published = ridx + 1 == cum[l]
                    if published:
                        lr.mark_resolution(l, acc * scale, rf.fused_at)
                    stage["publish"] += clock() - tp
                    if tr is not None:
                        tr.emit(telemetry.DECODE, td, tp - td,
                                job=job.job_id, round=ridx)
                        if published:
                            tr.emit(telemetry.RESOLUTION, rf.fused_at,
                                    job=job.job_id, round=ridx,
                                    value=float(l), label=f"res{l}")
                tc = clock()
                stale_now = self.fusion.stale_results
                ctrl.observe(RoundObservation(
                    round_idx=global_round - 1, job_id=job.job_id,
                    wait=tw - ts, fused=bool(fused),
                    stale=stale_now - prev_stale,
                    deadline_margin=(None if t_term is None
                                     else t_term - tw),
                    rounds_left=R_job - ridx - 1,
                    utilization=pool.busy_seconds
                    / max(tw - t0, 1e-9)))
                prev_stale = stale_now
                if tr is not None and len(ctrl.trace) > n_ret:
                    for rt in ctrl.trace[n_ret:]:
                        tr.emit(telemetry.RETUNE, tc, job=job.job_id,
                                round=ridx,
                                value=float(rt["omega_new"]),
                                label=rt["reason"])
                    n_ret = len(ctrl.trace)
                stage["control"] += clock() - tc
                if not fused:
                    term = True
                    break
            # group end: close the fusion group (late results become
            # stale exactly once), cancel every level master-side, and
            # push the wire watermark over the whole group's seq
            self.fusion.end_group()
            for ctx in ctxs:
                ctx.purge()
            pool.purge_round(ctxs[-1])
            ridx0 = g_end
        return term, faulted, timed, global_round, prev_stale

    # -- the event loop --------------------------------------------------------
    def run(self, jobs: Sequence[JobSpec]
            ) -> tuple[metrics.RuntimeResult, list[LayeredResult]]:
        """Serve ``jobs`` FIFO; returns (measured result, per-job futures)."""
        if len(jobs) == 0:
            raise ValueError("need at least one job")
        return self._serve(_TraceSource(jobs), warmup_job=jobs[0])

    def serve_queue(self, queue: JobQueue
                    ) -> tuple[metrics.RuntimeResult, list[LayeredResult]]:
        """Serve an *open* :class:`JobQueue` until closed and drained.

        Continuous-admission mode (the serving gateway's substrate):
        producers ``put`` jobs from other threads while the master loop
        is mid-job, and a queued successor lands in the encode-ahead
        pipeline between rounds — one warm fleet, no restart.  Per-job
        deadlines travel on ``JobSpec.deadline_at`` (absolute seconds
        from :attr:`t0`); with no successor trace there is no §IV
        next-arrival signal, so ``cfg.deadline`` alone never terminates
        a queued job.

        Blocks until :meth:`JobQueue.close` and every queued job is
        served; returns the same artifacts as :meth:`run` (empty but
        well-formed arrays when zero jobs were queued).
        """
        return self._serve(_QueueSource(queue),
                           warmup_job=self._warmup_job())

    def _serve(self, source, warmup_job: JobSpec
               ) -> tuple[metrics.RuntimeResult, list[LayeredResult]]:
        cfg = self.cfg
        ctrl = self.controller
        kappa0 = ctrl.kappa.copy()      # geometry at run start (eq. 1)
        L = cfg.num_layers
        order = layering.all_minijobs_msb_first(cfg.m)
        cum = layering.cumulative_minijobs(cfg.m)

        tr = self.tracer
        pool = make_transport(cfg, sink=self.fusion.post,
                              rng=np.random.default_rng(cfg.seed + 1),
                              tracer=tr)
        pool.start()
        # the fault authority for this run: under "fail-fast" it is the
        # historical assert_alive (raises TransportDeadError); under
        # "degrade" it quarantines, re-dispatches, and decides when a job
        # must be released degraded — see repro.runtime.faults
        sup = FaultSupervisor(cfg, pool, ctrl, tracer=tr)
        self._warmup(warmup_job)

        # per-job rows, appended in service order and stacked at the end:
        # queue mode has no up-front job count (zero jobs is well-formed)
        arrivals_l: list[float] = []
        starts_l: list[float] = []
        ends_l: list[float] = []
        lc_rows: list[np.ndarray] = []
        ok_rows: list[np.ndarray] = []
        term_l: list[bool] = []
        degr_l: list[bool] = []
        rel_l: list[int] = []
        ver_rows: Optional[list[np.ndarray]] = [] if self.verify else None
        futures: list[LayeredResult] = []
        stage = {name: 0.0 for name in metrics.STAGES}
        rounds_timed = 0
        global_round = 0                  # across jobs (controller clock)
        prev_stale = 0
        n_retunes = 0                     # controller retunes already traced
        R = len(order)
        prepared: dict[int, tuple] = {}   # job_id -> pre-decomposed planes

        t0 = clock()
        sup.set_origin(t0)
        source.bind(t0)
        self.t0 = t0
        self.started.set()
        try:
            while (job := source.next()) is not None:
                if sup.collapsed and sup.check():
                    # fleet below k and not coming back right now: no
                    # round can reach k results, so every remaining job
                    # is released *promptly* — no arrival sleep, no
                    # dispatch — at its best-ready resolution (nothing,
                    # for a job that never started), marked degraded
                    now = clock()
                    lr = (job.result if job.result is not None
                          else LayeredResult(job.job_id, L))
                    futures.append(lr)
                    lr.release(terminated=True)
                    arrivals_l.append(job.arrival)
                    starts_l.append(now - t0)
                    ends_l.append(now - t0)
                    lc_rows.append(np.full(L, np.inf))
                    ok_rows.append(np.zeros(L, dtype=bool))
                    term_l.append(True)
                    degr_l.append(True)
                    rel_l.append(lr.released_resolution)
                    if ver_rows is not None:
                        ver_rows.append(np.full(L, np.nan))
                    if tr is not None:
                        tr.emit(telemetry.JOB, now, 0.0, job=job.job_id,
                                label="degraded")
                    continue
                source.wait_arrival(job)
                start = clock()
                prep = prepared.pop(job.job_id, None)
                if prep is None:
                    ts = clock()
                    prep = self._prepare(job)
                    tp = clock()
                    stage["prep"] += tp - ts
                    if tr is not None:
                        tr.emit(telemetry.PREP, ts, tp - ts,
                                job=job.job_id)
                qa, qb, scale, ca, cb = prep
                lr = (job.result if job.result is not None
                      else LayeredResult(job.job_id, L))
                futures.append(lr)
                lr.mark_started(start)

                if job.deadline_at is not None:
                    # serving mode: a per-job absolute deadline is an
                    # unconditional release instant — an open stream has
                    # a queued successor in the limit, so §IV's second
                    # condition is taken as always met (and it takes
                    # precedence over cfg.deadline)
                    t_term = t0 + job.deadline_at
                else:
                    t_term = None
                    nh = source.successor_hint()
                    if cfg.deadline is not None and nh is not None:
                        # §IV: BOTH deadline excess AND a queued successor.
                        t_term = max(start + cfg.deadline, nh)
                # resolution window: max_resolution caps the round budget
                # (an admission down-resolve never computes LSB rounds it
                # will not release — a capped job that finishes them all
                # is complete, not terminated); min_resolution marks the
                # rounds the deadline may NOT cut, so the fusion wait is
                # unbounded inside them
                if job.max_resolution is not None:
                    R_job = cum[min(job.max_resolution, L - 1)]
                else:
                    R_job = R
                if job.min_resolution >= 0:
                    guaranteed = min(cum[min(job.min_resolution, L - 1)],
                                     R_job)
                else:
                    guaranteed = 0

                if cfg.code_family == "hierarchical":
                    # sub-task-granular path: grouped level rounds,
                    # per-level any-k fusion, salvage ledger
                    (term, faulted, timed, global_round,
                     prev_stale) = self._serve_hier_job(
                        job, lr, prep, pool, sup, t_term, R_job,
                        guaranteed, stage, global_round, prev_stale)
                    rounds_timed += timed
                else:
                    acc = np.zeros((qa.shape[1], qb.shape[1]), dtype=np.float64)
                    # per-side coded planes, filled on first use: the m**2
                    # rounds need only m A-side + m B-side encodes per job.
                    # Keyed by (T, plane): an ω retune mid-job switches the
                    # codeword length, and the old-T entries simply stop being
                    # hit (a switch costs at most m re-encodes per side).
                    enc_a: dict[tuple[int, int], np.ndarray] = {}
                    enc_b: dict[tuple[int, int], np.ndarray] = {}

                    def encode_round(pi, pj, ridx=-1):
                        """Encode one round under the controller's *current*
                        geometry; the returned buffer carries its own
                        ``(code, kappa)`` so a later retune never orphans it —
                        an already-encoded round dispatches and decodes with
                        the geometry it was built for."""
                        ts = clock()
                        rcode, rkappa = ctrl.code, ctrl.kappa
                        T = rcode.num_tasks
                        Xa = enc_a.get((T, pi))
                        if Xa is None:
                            Xa = enc_a[(T, pi)] = rcode.encode_a(
                                np.asarray(ca[pi], np.float64))
                        Yb = enc_b.get((T, pj))
                        if Yb is None:
                            Yb = enc_b[(T, pj)] = rcode.encode_b(
                                np.asarray(cb[pj], np.float64))
                        te = clock()
                        stage["encode"] += te - ts
                        if tr is not None:
                            tr.emit(telemetry.ENCODE, ts, te - ts,
                                    job=job.job_id, round=ridx)
                        return Xa, Yb, rcode, rkappa

                    def finish_round_traced(rf, ridx, l, published, ts, tp):
                        tr.emit(telemetry.DECODE, ts, tp - ts,
                                job=job.job_id, round=ridx)
                        if published:
                            tr.emit(telemetry.RESOLUTION, rf.fused_at,
                                    job=job.job_id, round=ridx,
                                    value=float(l), label=f"res{l}")

                    def finish_round(rf, ridx, l, pi, pj, rcode):
                        """Decode a fused round, publish its layer if last.

                        Runs *behind* the next round's dispatch, so the layer
                        is timestamped with the round's ``fused_at`` (its k-th
                        task arrival) — the simulator's order-statistic
                        semantics — not the later decode instant, keeping the
                        measured delay free of next-round dispatch cost.
                        """
                        ts = clock()
                        mini = rf.decode(rcode)
                        tp = clock()
                        stage["decode"] += tp - ts
                        acc[...] += mini * float(1 << ((pi + pj) * cfg.d))
                        published = ridx + 1 == cum[l]
                        if published:   # layer l's last mini-job fused
                            lr.mark_resolution(l, acc * scale, rf.fused_at)
                        stage["publish"] += clock() - tp
                        if tr is not None:
                            finish_round_traced(rf, ridx, l, published, ts, tp)

                    # prime the pipeline: round 0's codeword + injected delays
                    nxt = encode_round(order[0][1], order[0][2], 0)
                    nxt_delays = pool.sample_round_delays(nxt[3])
                    pending = None        # fused-but-undecoded previous round
                    term = False
                    faulted = False       # released by the fault supervisor
                    for ridx, (l, pi, pj) in enumerate(order[:R_job]):
                        if (t_term is not None and ridx >= guaranteed
                                and clock() >= t_term):
                            term = True   # don't dispatch a dead round
                            break
                        # per-round liveness gate: when rounds fuse fast the
                        # wait loops below may never time out, so a death
                        # would otherwise go undetected while dispatches pile
                        # buffers onto the corpse — fail-fast raises here,
                        # degrade quarantines and re-splits kappa before the
                        # next dispatch (True only on fleet collapse: there
                        # is no in-flight round to give up on at this point)
                        if sup.check():
                            faulted = term = True
                            break
                        ctx = RoundContext(job.job_id, ridx)
                        rf = self.fusion.begin_round(ctx, cfg.k)
                        rcode = nxt[2]
                        ts = t_disp = clock()
                        pool.submit_round(ctx, nxt[0], nxt[1], nxt[3],
                                          delays=nxt_delays)
                        # hand the supervisor the round's buffers + split so a
                        # worker death mid-round can re-dispatch the lost slice
                        sup.track_round(ctx, nxt[0], nxt[1], nxt[3], rf)
                        stage["dispatch"] += clock() - ts
                        rounds_timed += 1
                        global_round += 1
                        nxt = None
                        # -- overlapped with this round's worker compute: --
                        # 1. decode the previous round, publish its layer
                        if pending is not None:
                            finish_round(*pending)
                            pending = None
                        # 2. encode round r+1 + presample its delays into the
                        #    spare buffer, or (last round) digit-decompose the
                        #    next *queued* job — continuous admission lands
                        #    here: a job put() mid-service preps between
                        #    rounds with no fleet restart
                        if ridx + 1 < R_job:
                            _, npi, npj = order[ridx + 1]
                            nxt = encode_round(npi, npj, ridx + 1)
                            nxt_delays = pool.sample_round_delays(nxt[3])
                        else:
                            nj = source.peek_ready()
                            if nj is not None and nj.job_id not in prepared:
                                ts = clock()
                                prepared[nj.job_id] = self._prepare(nj)
                                tp = clock()
                                stage["prep"] += tp - ts
                                if tr is not None:
                                    tr.emit(telemetry.PREP, ts, tp - ts,
                                            job=nj.job_id)
                        # ---------------------------------------------------
                        ts = clock()
                        if t_term is None or ridx < guaranteed:
                            # unbounded wait (no deadline, or a guaranteed
                            # minimum-resolution round the deadline may not
                            # cut): slice it so a worker that died (OOM-kill,
                            # crashed child, dead remote host) is handled
                            # promptly — fail-fast raises out of sup.check();
                            # degrade quarantines/re-dispatches, returning
                            # True only when the round is beyond saving —
                            # instead of blocking the run forever on a round
                            # that can no longer reach k results
                            while not (fused := rf.wait(sup.wait_slice)):
                                if sup.check():
                                    faulted = True
                                    break
                        else:
                            # bounded wait: still slice it — a multi-second
                            # §IV deadline must not delay dead-host detection
                            # (socket heartbeats, process joins) to the
                            # termination instant
                            while True:
                                remaining = t_term - clock()
                                if remaining <= 0.0:
                                    fused = rf.wait(0.0)
                                    break
                                if (fused := rf.wait(min(remaining,
                                                         sup.wait_slice))):
                                    break
                                if sup.check():
                                    faulted = True
                                    break
                        if faulted and rf.wait(0.0):
                            # the round fused in the window between the wait
                            # timing out and the supervisor giving up on it —
                            # a completed round is never thrown away
                            fused, faulted = True, False
                        tw = clock()
                        stage["wait"] += tw - ts
                        if tr is not None:
                            tr.emit(telemetry.ROUND, t_disp, tw - t_disp,
                                    job=job.job_id, round=ridx,
                                    label="fused" if fused else "purged")
                        # reclaim the round's stragglers.  View-lifetime
                        # invariant for zero-copy transports: this round's
                        # accepted results are NOT yet decoded (decode rides
                        # one iteration behind, see ``pending``), so its
                        # purge must not recycle their result slots — only
                        # strictly older rounds', which this same loop
                        # already decoded (finish_round(r-1) above precedes
                        # purge(r) on this thread, hence precedes purge(r+1)
                        # a fortiori).  Dispatch-slot reuse is safe
                        # immediately: a straggler still reading a recycled
                        # block can only produce a result fusion rejects
                        # without dereferencing.
                        pool.purge_round(ctx)
                        # feed the controller this round's signals; a retune
                        # takes effect from the NEXT encode (the buffered
                        # round keeps the geometry it was encoded with)
                        tc = clock()       # purge wake-ups stay out of the
                        stale_now = self.fusion.stale_results   # control stage
                        ctrl.observe(RoundObservation(
                            round_idx=global_round - 1, job_id=job.job_id,
                            wait=tw - ts, fused=bool(fused),
                            stale=stale_now - prev_stale,
                            deadline_margin=(None if t_term is None
                                             else t_term - tw),
                            rounds_left=R_job - ridx - 1,
                            utilization=pool.busy_seconds
                            / max(tw - t0, 1e-9)))
                        prev_stale = stale_now
                        if tr is not None and len(ctrl.trace) > n_retunes:
                            for rt in ctrl.trace[n_retunes:]:
                                tr.emit(telemetry.RETUNE, tc, job=job.job_id,
                                        round=ridx,
                                        value=float(rt["omega_new"]),
                                        label=rt["reason"])
                            n_retunes = len(ctrl.trace)
                        stage["control"] += clock() - tc
                        if not fused:
                            term = True
                            break
                        pending = (rf, ridx, l, pi, pj, rcode)
                    if pending is not None:   # drain the decode-behind stage
                        finish_round(*pending)
                end = clock()
                lr.release(terminated=term)
                if tr is not None:
                    tr.emit(telemetry.JOB, start, end - start,
                            job=job.job_id,
                            label=("degraded" if faulted else
                                   "terminated" if term else "completed"))

                arrivals_l.append(job.arrival)
                starts_l.append(start - t0)
                ends_l.append(end - t0)
                term_l.append(term)
                degr_l.append(faulted)
                rel_l.append(lr.released_resolution)
                lc = np.full(L, np.inf)
                ok = np.zeros(L, dtype=bool)
                for l in range(L):
                    if lr.resolution_ready(l):
                        ok[l] = True
                        lc[l] = lr.ready_at(l) - start
                lc_rows.append(lc)
                ok_rows.append(ok)
                if self.verify:
                    ref = layering.layered_matmul_reference(
                        qa, qb, m=cfg.m, d=cfg.d).astype(np.float64) * scale
                    ver = np.full(L, np.nan)
                    for l in range(L):
                        if lr.resolution_ready(l):
                            denom = max(float(np.abs(ref[l]).max()), 1.0)
                            ver[l] = float(
                                np.abs(lr.resolution(l) - ref[l]).max()
                                / denom)
                    ver_rows.append(ver)
        finally:
            pool.shutdown()

        # transports that cross a wire expose frame/byte counters and the
        # zero-copy ledger (process: arena vs pickle rounds; socket:
        # serialization-copied vs out-of-band bytes, negotiated frame
        # protocol); purely in-process backends leave this None
        transport_stats = getattr(pool, "wire_stats", None)
        if cfg.code_family == "hierarchical":
            # the salvage ledger rides transport_stats on every backend:
            # sub-task results accepted at all, and the subset that landed
            # beyond the master's wait frontier (banked straggler work)
            transport_stats = dict(transport_stats or {})
            transport_stats["subtask_results"] = self.fusion.subtask_results
            transport_stats["salvaged_subtasks"] = (
                self.fusion.salvaged_subtasks)

        J = len(starts_l)
        result = metrics.RuntimeResult(
            arrivals=np.asarray(arrivals_l, dtype=np.float64),
            starts=np.asarray(starts_l, dtype=np.float64),
            ends=np.asarray(ends_l, dtype=np.float64),
            layer_compute=(np.vstack(lc_rows) if J
                           else np.zeros((0, L))),
            success=(np.vstack(ok_rows) if J
                     else np.zeros((0, L), dtype=bool)),
            terminated=np.asarray(term_l, dtype=bool), kappa=kappa0,
            worker_busy=pool.busy_seconds, wall_elapsed=clock() - t0,
            stale_results=self.fusion.stale_results,
            released=np.asarray(rel_l, dtype=np.int64),
            verify_errors=(None if ver_rows is None
                           else np.vstack(ver_rows) if J
                           else np.zeros((0, L))),
            stage_seconds=stage,
            stage_rounds=rounds_timed, controller=ctrl.summary(),
            omega_trace=list(ctrl.trace), backend=pool.name,
            transport_stats=transport_stats,
            tasks_done=pool.tasks_done, tasks_purged=pool.tasks_purged,
            fault_policy=cfg.fault_policy, fault_log=sup.fault_log,
            workers_lost=sup.workers_lost, degraded=np.asarray(
                degr_l, dtype=bool),
            trace_events=(tr.events() if tr is not None else None),
            trace_dropped=(tr.dropped if tr is not None else 0),
            trace_t0=t0,
            clock_sync=getattr(pool, "clock_sync", None))
        return result, futures


def run_jobs(cfg: RuntimeConfig, num_jobs: int, *, K: int = 64, M: int = 8,
             N: int = 8, verify: bool = False,
             arrivals: Optional[Sequence[float]] = None
             ) -> tuple[metrics.RuntimeResult, list[LayeredResult]]:
    """Convenience: generate ``num_jobs`` random jobs and run them."""
    jobs = make_jobs(cfg, num_jobs, K=K, M=M, N=N, arrivals=arrivals)
    return Master(cfg, verify=verify).run(jobs)
