"""The master node: queue, dispatch, purge, terminate, release (§IV).

A measured, genuinely-concurrent execution of the system the simulator
models: jobs arrive (Poisson or trace), are served FIFO one at a time
(the paper's single-master discipline), and each job's ``m**2`` coded
mini-job rounds run MSB-first on the worker pool:

1. service start — operands are quantized (floats) and digit-decomposed;
2. per round, the mini-job's plane pair is polynomial-encoded
   (:class:`~repro.core.coding.PolynomialCode`) and its ``T`` coded tasks
   are dispatched per the eq. (1) ``kappa`` split;
3. the fusion node decodes at the k-th arrival and the master *purges*
   the round's stragglers (their cancel event reclaims them instantly);
4. each completed layer is published MSB-first on the job's
   :class:`~repro.runtime.fusion.LayeredResult`;
5. the §IV rule terminates a job at
   ``t_term = max(service_start + deadline, next_arrival)`` — termination
   requires BOTH deadline excess AND a queued successor — releasing the
   highest completed resolution.

With ``verify=True`` every published resolution is checked against the
exact layered oracle (``layering.layered_matmul_reference``, the same
oracle the Pallas kernel in ``repro.kernels.layered_matmul`` is tested
against), so a measured run is decode-verified end-to-end.
"""

from __future__ import annotations

import time
from typing import Optional, Sequence

import jax.numpy as jnp
import numpy as np

from repro.core import layering
from repro.runtime import metrics
from repro.runtime.fusion import FusionNode, LayeredResult
from repro.runtime.tasks import JobSpec, RoundContext, RuntimeConfig
from repro.runtime.worker import WorkerPool, clock

__all__ = ["Master", "make_jobs", "run_jobs"]


def make_jobs(cfg: RuntimeConfig, num_jobs: int, *, K: int = 64, M: int = 8,
              N: int = 8, rng: Optional[np.random.Generator] = None,
              arrivals: Optional[Sequence[float]] = None) -> list[JobSpec]:
    """Random integer-matrix jobs with Poisson (or trace) arrivals.

    Operand magnitudes stay well inside ``m * d`` bits so float-mode decode
    is tight; ``M``/``N`` must be divisible by ``n1``/``n2``.
    """
    rng = rng if rng is not None else np.random.default_rng(cfg.seed)
    if arrivals is None:
        arrivals = np.cumsum(
            rng.exponential(1.0 / cfg.arrival_rate, size=num_jobs))
    arrivals = np.asarray(arrivals, dtype=np.float64)
    if len(arrivals) != num_jobs:
        raise ValueError(f"{len(arrivals)} arrivals for {num_jobs} jobs")
    lim = 1 << (cfg.m * cfg.d - 2)
    return [JobSpec(job_id=j,
                    a=rng.integers(-lim, lim, size=(K, M), dtype=np.int64),
                    b=rng.integers(-lim, lim, size=(K, N), dtype=np.int64),
                    arrival=float(arrivals[j]))
            for j in range(num_jobs)]


class Master:
    """Event loop owning the worker pool and the fusion node."""

    def __init__(self, cfg: RuntimeConfig, *, verify: bool = False):
        self.cfg = cfg
        self.verify = verify
        self.fusion = FusionNode()
        self._code = cfg.code()
        self._kappa = cfg.load_split()

    # -- operand preparation -------------------------------------------------
    def _prepare(self, job: JobSpec):
        """Quantize float operands, digit-decompose both into m planes."""
        cfg = self.cfg
        bits = cfg.m * cfg.d
        if np.issubdtype(np.asarray(job.a).dtype, np.floating):
            qa, sa = layering.quantize(jnp.asarray(job.a), bits)
            qa, sa = np.asarray(qa, np.int64), float(sa)
        else:
            qa, sa = np.asarray(job.a, np.int64), 1.0
        if np.issubdtype(np.asarray(job.b).dtype, np.floating):
            qb, sb = layering.quantize(jnp.asarray(job.b), bits)
            qb, sb = np.asarray(qb, np.int64), float(sb)
        else:
            qb, sb = np.asarray(job.b, np.int64), 1.0
        ca = layering._np_decompose(qa, cfg.m, cfg.d)   # (m, K, M)
        cb = layering._np_decompose(qb, cfg.m, cfg.d)   # (m, K, N)
        return qa, qb, sa * sb, ca, cb

    def _encode_round(self, ca_i: np.ndarray, cb_j: np.ndarray):
        """Polynomial-encode one mini-job (host float64 fast path)."""
        return self._code.encode(np.asarray(ca_i, np.float64),
                                 np.asarray(cb_j, np.float64))

    def _warmup(self, job: JobSpec) -> None:
        """Run one encode/compute/decode off the clock (BLAS/cache warm)."""
        _, _, _, ca, cb = self._prepare(job)
        X, Y = self._encode_round(ca[0], cb[0])
        self._code.decode(list(range(self._code.k)),
                          np.stack([X[t].T @ Y[t]
                                    for t in range(self._code.k)]))

    # -- the event loop --------------------------------------------------------
    def run(self, jobs: Sequence[JobSpec]
            ) -> tuple[metrics.RuntimeResult, list[LayeredResult]]:
        """Serve ``jobs`` FIFO; returns (measured result, per-job futures)."""
        cfg = self.cfg
        code, kappa = self._code, self._kappa
        L = cfg.num_layers
        order = layering.all_minijobs_msb_first(cfg.m)
        cum = layering.cumulative_minijobs(cfg.m)
        J = len(jobs)
        if J == 0:
            raise ValueError("need at least one job")

        pool = WorkerPool(cfg, sink=self.fusion.post,
                          rng=np.random.default_rng(cfg.seed + 1))
        pool.start()
        self._warmup(jobs[0])

        arrivals = np.asarray([jb.arrival for jb in jobs])
        starts = np.zeros(J)
        ends = np.zeros(J)
        layer_compute = np.full((J, L), np.inf)
        success = np.zeros((J, L), dtype=bool)
        terminated = np.zeros(J, dtype=bool)
        released = np.full(J, -1, dtype=np.int64)
        verify_errors = np.full((J, L), np.nan) if self.verify else None
        futures: list[LayeredResult] = []

        t0 = clock()
        try:
            for j, job in enumerate(jobs):
                wait = (t0 + job.arrival) - clock()
                if wait > 0:           # idle until the job actually arrives
                    time.sleep(wait)
                start = clock()
                qa, qb, scale, ca, cb = self._prepare(job)
                lr = LayeredResult(job.job_id, L)
                futures.append(lr)

                next_arrival = (t0 + jobs[j + 1].arrival
                                if j + 1 < J else None)
                t_term = None
                if cfg.deadline is not None and next_arrival is not None:
                    # §IV: BOTH deadline excess AND a queued successor.
                    t_term = max(start + cfg.deadline, next_arrival)

                acc = np.zeros((qa.shape[1], qb.shape[1]), dtype=np.float64)
                term = False
                for ridx, (l, pi, pj) in enumerate(order):
                    if t_term is not None and clock() >= t_term:
                        term = True   # don't encode/dispatch a dead round
                        break
                    ctx = RoundContext(job.job_id, ridx)
                    X, Y = self._encode_round(ca[pi], cb[pj])
                    rf = self.fusion.begin_round(ctx, code.k)
                    pool.dispatch_round(ctx, X, Y, kappa)
                    timeout = (None if t_term is None
                               else max(0.0, t_term - clock()))
                    fused = rf.wait(timeout)
                    ctx.purge()        # reclaim the round's stragglers
                    if not fused:
                        term = True
                        break
                    mini = rf.decode(code)
                    acc += mini * float(1 << ((pi + pj) * cfg.d))
                    if ridx + 1 == cum[l]:   # layer l's last mini-job fused
                        lr.mark_resolution(l, acc * scale, clock())
                end = clock()
                lr.release(terminated=term)

                starts[j] = start - t0
                ends[j] = end - t0
                terminated[j] = term
                released[j] = lr.released_resolution
                for l in range(L):
                    if lr.resolution_ready(l):
                        success[j, l] = True
                        layer_compute[j, l] = lr.ready_at(l) - start
                if self.verify:
                    ref = layering.layered_matmul_reference(
                        qa, qb, m=cfg.m, d=cfg.d).astype(np.float64) * scale
                    for l in range(L):
                        if lr.resolution_ready(l):
                            denom = max(float(np.abs(ref[l]).max()), 1.0)
                            verify_errors[j, l] = float(
                                np.abs(lr.resolution(l) - ref[l]).max()
                                / denom)
        finally:
            pool.shutdown()

        result = metrics.RuntimeResult(
            arrivals=arrivals, starts=starts, ends=ends,
            layer_compute=layer_compute, success=success,
            terminated=terminated, kappa=kappa,
            worker_busy=pool.busy_seconds, wall_elapsed=clock() - t0,
            stale_results=self.fusion.stale_results, released=released,
            verify_errors=verify_errors)
        return result, futures


def run_jobs(cfg: RuntimeConfig, num_jobs: int, *, K: int = 64, M: int = 8,
             N: int = 8, verify: bool = False,
             arrivals: Optional[Sequence[float]] = None
             ) -> tuple[metrics.RuntimeResult, list[LayeredResult]]:
    """Convenience: generate ``num_jobs`` random jobs and run them."""
    jobs = make_jobs(cfg, num_jobs, K=K, M=M, N=N, arrivals=arrivals)
    return Master(cfg, verify=verify).run(jobs)
