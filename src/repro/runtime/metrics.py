"""Measured-run accounting, shaped like the simulator's ``SimResult``.

:class:`RuntimeResult` *is a* :class:`repro.core.simulator.SimResult`
(same per-job arrays, same ``delay`` / ``mean_delay`` / ``success_rate``
semantics, times in seconds from the run start) so a measured run drops
straight into any analysis written for ``simulate()`` — in particular the
runtime-vs-simulator agreement checks and the paper's per-resolution delay
tables.  On top it records what only a real execution has: worker
occupancy, stale (purged-too-late) results, and per-layer decode-vs-oracle
verification errors.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import simulator

__all__ = ["RuntimeResult", "delay_table", "format_delay_table",
           "format_stage_table", "format_controller_trace", "STAGES"]

#: Per-round pipeline stages the master accounts for.  ``wait`` is worker
#: compute (the master blocks on fusion); ``control`` is the ω-controller
#: (observation build + policy step + any geometry switch); everything
#: else is master-side critical-path overhead the pipelined engine works
#: to hide or shrink.
STAGES = ("prep", "encode", "dispatch", "wait", "decode", "publish",
          "control")


@dataclasses.dataclass
class RuntimeResult(simulator.SimResult):
    """Per-job outcome arrays of a measured runtime execution.

    Inherited (see ``SimResult``): arrivals, starts, ends, layer_compute,
    success, terminated, kappa — all wall-clock seconds relative to the run
    start.  Added:

    ``worker_busy[p]``   seconds worker p spent occupied (delay + compute).
    ``wall_elapsed``     run duration (last service end - run start).
    ``stale_results``    task results that arrived after their round fused.
    ``released[j]``      highest resolution released for job j (-1 = none).
    ``verify_errors``    (J, L) max relative decode error vs the exact
                         layered oracle, NaN where unverified/incomplete
                         (populated when the master runs with verify=True).
    ``stage_seconds``    total seconds per pipeline stage (see ``STAGES``)
                         across the run; decode/encode here are the
                         *observed* (pipelined) costs, so overlapped work
                         does not inflate the critical path it hid behind.
    ``stage_rounds``     rounds dispatched (the divisor for per-round
                         stage costs).
    ``controller``       the ω-controller's outcome summary (policy name,
                         initial/final omega, retune/switch counts, total
                         DecodePlan prime seconds) — present even for the
                         static ``fixed`` policy (zero retunes).
    ``omega_trace``      one dict per retune event (round, job, old/new
                         omega and T, new kappa, reason, prime seconds);
                         empty list when omega never moved.
    ``backend``          the worker transport that executed the run
                         (``thread`` / ``process`` / ``jax`` /
                         ``socket``) — the effective backend, after any
                         legacy-flag upgrade, for bench/JSON provenance.
    ``transport_stats``  wire-level counters for transports that cross a
                         network (socket backend: frames, dispatch/result
                         raw-vs-wire bytes, compression ratio); None for
                         in-process backends.
    ``tasks_done``       coded tasks computed and emitted across all
                         workers (exact: collected post-shutdown).
    ``tasks_purged``     tasks reclaimed by purges before completion.
    ``fault_policy``     the worker-loss policy the run executed under
                         (``fail-fast`` / ``degrade``).
    ``fault_log``        chronological fault-supervision record: one dict
                         per quarantine / readmit / redispatch /
                         fleet-collapse event (``t`` seconds from run
                         start, ``kind``, per-kind fields) — see
                         :mod:`repro.runtime.faults`.  Empty when no
                         worker was lost.
    ``workers_lost``     distinct worker deaths the supervisor handled
                         (a readmitted-then-lost-again socket host
                         counts once per death).
    ``degraded``         (J,) bool: job was released by the fault
                         supervisor (fleet collapse or re-dispatch
                         budget exhausted) rather than finishing or
                         hitting the ordinary §IV deadline rule.
    ``trace_events``     time-sorted :class:`~repro.runtime.telemetry.
                         TraceEvent` list when the run traced
                         (``cfg.trace=True``); None otherwise.  Remote
                         events are already rebased onto the master clock.
    ``trace_dropped``    events lost to tracer ring overflow (0 in any
                         sanely-sized run).
    ``trace_t0``         master monotonic-clock instant of the run start;
                         subtract from ``TraceEvent.t`` to get seconds
                         from run start (the exporters do this).
    ``clock_sync``       per-link clock alignment for networked backends:
                         a list of ``{worker, host, offset_s, rtt_s}``
                         dicts (offset error is bounded by ``rtt_s``);
                         None for in-process backends.

    ``kappa`` (inherited) is the eq. (1) split of the *initial* geometry;
    under an adaptive policy the per-retune splits live in
    ``omega_trace`` and the final one in ``controller``.
    """

    worker_busy: np.ndarray = dataclasses.field(
        default_factory=lambda: np.zeros(0))
    wall_elapsed: float = 0.0
    stale_results: int = 0
    released: np.ndarray = dataclasses.field(
        default_factory=lambda: np.zeros(0, dtype=np.int64))
    verify_errors: np.ndarray | None = None
    stage_seconds: dict | None = None
    stage_rounds: int = 0
    controller: dict | None = None
    omega_trace: list | None = None
    backend: str = "thread"
    transport_stats: dict | None = None
    tasks_done: int = 0
    tasks_purged: int = 0
    fault_policy: str = "fail-fast"
    fault_log: list | None = None
    workers_lost: int = 0
    degraded: np.ndarray | None = None
    trace_events: list | None = None
    trace_dropped: int = 0
    trace_t0: float = 0.0
    clock_sync: list | None = None

    @property
    def utilization(self) -> np.ndarray:
        """Fraction of the run each worker spent occupied."""
        if self.wall_elapsed <= 0:
            return np.zeros_like(self.worker_busy)
        return self.worker_busy / self.wall_elapsed

    def per_round_overhead(self) -> float:
        """Master-side seconds/round (encode + decode, excluding worker
        wait and dispatch/publish) — the ISSUE's headline metric."""
        if not self.stage_seconds or not self.stage_rounds:
            return float("nan")
        s = self.stage_seconds
        return (s.get("encode", 0.0) + s.get("decode", 0.0)
                ) / self.stage_rounds

    def release_histogram(self) -> np.ndarray:
        """(L + 1,) job counts by released resolution; slot 0 = none (-1)."""
        L = self.layer_compute.shape[1]
        rel = np.asarray(self.released, dtype=np.int64)
        return np.bincount(rel + 1, minlength=L + 1)


def delay_table(result: simulator.SimResult,
                bounds: np.ndarray | None = None) -> list[dict]:
    """Per-resolution summary rows (the paper's Fig.-style table).

    Works for both simulated and measured results; ``bounds`` (optional)
    attaches the eq. (4) theoretical lower bounds per resolution.
    """
    mean = result.mean_delay()
    rate = result.success_rate()
    d = result.delay
    rows = []
    for l in range(d.shape[1]):
        ok = np.isfinite(d[:, l])
        row = {
            "resolution": l,
            "mean_delay": float(mean[l]),
            "p50_delay": float(np.median(d[ok, l])) if ok.any() else None,
            "p95_delay": (float(np.percentile(d[ok, l], 95))
                          if ok.any() else None),
            "success_rate": float(rate[l]),
        }
        if bounds is not None:
            row["theory_lower_bound"] = float(bounds[l])
        rows.append(row)
    return rows


def format_stage_table(result: "RuntimeResult") -> str:
    """Per-stage timing breakdown: total seconds, us/round, share."""
    if not result.stage_seconds or not result.stage_rounds:
        return "(no stage timings recorded)"
    s = result.stage_seconds
    total = sum(s.get(k, 0.0) for k in STAGES)
    lines = [f"{'stage':>9} {'total s':>10} {'us/round':>10} {'share':>7}"]
    for k in STAGES:
        v = s.get(k, 0.0)
        lines.append(f"{k:>9} {v:>10.4f} "
                     f"{v / result.stage_rounds * 1e6:>10.1f} "
                     f"{v / total:>7.1%}")
    ov = result.per_round_overhead()
    lines.append(f"master-side overhead (encode+decode): "
                 f"{ov * 1e6:.1f} us/round over {result.stage_rounds} rounds")
    return "\n".join(lines)


def format_controller_trace(result: "RuntimeResult",
                            max_rows: int = 24) -> str:
    """The ω-controller's retune history, fixed-width for CLI output."""
    ctl = result.controller
    if not ctl:
        return "(no controller summary recorded)"
    head = (f"policy={ctl['policy']}  omega {ctl['omega_initial']:.2f} -> "
            f"{ctl['omega_final']:.2f} (bounds "
            f"[{ctl['omega_bounds'][0]:.2f}, {ctl['omega_bounds'][1]:.2f}])"
            f"  retunes={ctl['retunes']}  geometry switches="
            f"{ctl['switches']}  plan prime total "
            f"{ctl['prime_seconds_total'] * 1e3:.2f} ms")
    trace = result.omega_trace or []
    if not trace:
        return head + "\n(omega never moved)"
    lines = [head,
             f"{'round':>6} {'job':>5} {'omega':>13} {'T':>7} "
             f"{'prime ms':>9}  reason"]
    shown = trace if len(trace) <= max_rows else trace[:max_rows]
    for ev in shown:
        omega = f"{ev['omega_old']:.2f}->{ev['omega_new']:.2f}"
        T = (f"{ev['T_old']}->{ev['T_new']}" if ev["switched"]
             else str(ev["T_old"]))
        lines.append(f"{ev['round']:>6} {ev['job']:>5} {omega:>13} {T:>7} "
                     f"{ev['prime_seconds'] * 1e3:>9.3f}  {ev['reason']}")
    if len(trace) > max_rows:
        lines.append(f"... ({len(trace) - max_rows} more retunes)")
    return "\n".join(lines)


def format_delay_table(rows: list[dict]) -> str:
    """Fixed-width rendering of :func:`delay_table` for CLI/bench output.

    An empty ``rows`` list (zero-resolution geometry or a run terminated
    before any release) renders a placeholder instead of crashing.
    """
    if not rows:
        return "(no resolutions to report)"
    has_bound = "theory_lower_bound" in rows[0]
    head = (f"{'res':>4} {'mean delay':>12} {'p50':>10} {'p95':>10} "
            f"{'success':>8}")
    if has_bound:
        head += f" {'eq.(4) bound':>13}"
    lines = [head]
    for r in rows:
        p50 = f"{r['p50_delay']:.4f}" if r["p50_delay"] is not None else "-"
        p95 = f"{r['p95_delay']:.4f}" if r["p95_delay"] is not None else "-"
        line = (f"{r['resolution']:>4} {r['mean_delay']:>12.4f} {p50:>10} "
                f"{p95:>10} {r['success_rate']:>8.3f}")
        if has_bound:
            line += f" {r['theory_lower_bound']:>13.4f}"
        lines.append(line)
    return "\n".join(lines)
