"""Measured-run accounting, shaped like the simulator's ``SimResult``.

:class:`RuntimeResult` *is a* :class:`repro.core.simulator.SimResult`
(same per-job arrays, same ``delay`` / ``mean_delay`` / ``success_rate``
semantics, times in seconds from the run start) so a measured run drops
straight into any analysis written for ``simulate()`` — in particular the
runtime-vs-simulator agreement checks and the paper's per-resolution delay
tables.  On top it records what only a real execution has: worker
occupancy, stale (purged-too-late) results, and per-layer decode-vs-oracle
verification errors.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import simulator

__all__ = ["RuntimeResult", "delay_table", "format_delay_table"]


@dataclasses.dataclass
class RuntimeResult(simulator.SimResult):
    """Per-job outcome arrays of a measured runtime execution.

    Inherited (see ``SimResult``): arrivals, starts, ends, layer_compute,
    success, terminated, kappa — all wall-clock seconds relative to the run
    start.  Added:

    ``worker_busy[p]``   seconds worker p spent occupied (delay + compute).
    ``wall_elapsed``     run duration (last service end - run start).
    ``stale_results``    task results that arrived after their round fused.
    ``released[j]``      highest resolution released for job j (-1 = none).
    ``verify_errors``    (J, L) max relative decode error vs the exact
                         layered oracle, NaN where unverified/incomplete
                         (populated when the master runs with verify=True).
    """

    worker_busy: np.ndarray = dataclasses.field(
        default_factory=lambda: np.zeros(0))
    wall_elapsed: float = 0.0
    stale_results: int = 0
    released: np.ndarray = dataclasses.field(
        default_factory=lambda: np.zeros(0, dtype=np.int64))
    verify_errors: np.ndarray | None = None

    @property
    def utilization(self) -> np.ndarray:
        """Fraction of the run each worker spent occupied."""
        if self.wall_elapsed <= 0:
            return np.zeros_like(self.worker_busy)
        return self.worker_busy / self.wall_elapsed

    def release_histogram(self) -> np.ndarray:
        """(L + 1,) job counts by released resolution; slot 0 = none (-1)."""
        L = self.layer_compute.shape[1]
        counts = np.zeros(L + 1, dtype=np.int64)
        for r in self.released:
            counts[int(r) + 1] += 1
        return counts


def delay_table(result: simulator.SimResult,
                bounds: np.ndarray | None = None) -> list[dict]:
    """Per-resolution summary rows (the paper's Fig.-style table).

    Works for both simulated and measured results; ``bounds`` (optional)
    attaches the eq. (4) theoretical lower bounds per resolution.
    """
    mean = result.mean_delay()
    rate = result.success_rate()
    d = result.delay
    rows = []
    for l in range(d.shape[1]):
        ok = np.isfinite(d[:, l])
        row = {
            "resolution": l,
            "mean_delay": float(mean[l]),
            "p50_delay": float(np.median(d[ok, l])) if ok.any() else None,
            "p95_delay": (float(np.percentile(d[ok, l], 95))
                          if ok.any() else None),
            "success_rate": float(rate[l]),
        }
        if bounds is not None:
            row["theory_lower_bound"] = float(bounds[l])
        rows.append(row)
    return rows


def format_delay_table(rows: list[dict]) -> str:
    """Fixed-width rendering of :func:`delay_table` for CLI/bench output."""
    has_bound = "theory_lower_bound" in rows[0]
    head = (f"{'res':>4} {'mean delay':>12} {'p50':>10} {'p95':>10} "
            f"{'success':>8}")
    if has_bound:
        head += f" {'eq.(4) bound':>13}"
    lines = [head]
    for r in rows:
        p50 = f"{r['p50_delay']:.4f}" if r["p50_delay"] is not None else "-"
        p95 = f"{r['p95_delay']:.4f}" if r["p95_delay"] is not None else "-"
        line = (f"{r['resolution']:>4} {r['mean_delay']:>12.4f} {p50:>10} "
                f"{p95:>10} {r['success_rate']:>8.3f}")
        if has_bound:
            line += f" {r['theory_lower_bound']:>13.4f}"
        lines.append(line)
    return "\n".join(lines)
