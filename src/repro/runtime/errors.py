"""Typed runtime exceptions.

The runtime used to signal every abnormal condition with a bare
``RuntimeError``, which forced the fault supervisor (and tests) to match
on message strings.  The hierarchy below keeps ``RuntimeError`` as the
common base — existing ``except RuntimeError`` / ``pytest.raises``
call sites keep working — while letting precise handlers catch exactly
the failure class they can deal with:

``TransportDeadError``
    A worker (thread, process, or remote host) died outside an orderly
    shutdown and the transport's liveness machinery declared it dead.
    Raised by :meth:`~repro.runtime.transport.base.WorkerTransport.
    assert_alive` under the ``fail-fast`` fault policy; under
    ``degrade`` the :class:`~repro.runtime.faults.FaultSupervisor`
    intercepts the same condition and quarantines instead of raising.

``FusionStateError``
    A fusion-layer state violation: decoding a round that has not fused,
    or reading a resolution that is not ready.  Always a caller bug or a
    deliberately-degraded release being read too eagerly — never a
    transport condition, which is why it is a separate type.
"""

from __future__ import annotations

__all__ = ["TransportDeadError", "FusionStateError"]


class TransportDeadError(RuntimeError):
    """A worker died mid-run and the transport declared it dead.

    ``workers`` carries the transport's per-worker descriptions (name or
    ``worker-id@host:port`` plus the death reason) so supervisors can
    act per worker instead of re-parsing the message.
    """

    def __init__(self, message: str, workers: list[str] | None = None):
        super().__init__(message)
        self.workers = list(workers or [])


class FusionStateError(RuntimeError):
    """A fusion-node or layered-result state invariant was violated."""
