"""Asynchronous master-worker coded execution engine with layered fusion.

The measured counterpart of ``repro.core.simulator``: real coded matmul
tasks on concurrent workers, any-k fusion per MSB-first round, purge of
stale tasks, and §IV deadline termination releasing the highest completed
resolution.  Results come back in the simulator's ``SimResult`` shape so
measured runs validate directly against ``simulate()`` and
``theory_bounds()``.

Quickstart::

    from repro.runtime import RuntimeConfig, run_jobs

    cfg = RuntimeConfig(mu=(400.0, 650.0, 380.0), arrival_rate=30.0,
                        complexity=2.0, deadline=0.05, straggler="exp")
    result, futures = run_jobs(cfg, num_jobs=50, verify=True)
    print(result.mean_delay(), result.success_rate())
"""

from repro.runtime.adaptive import (POLICIES, AIMDPolicy,
                                    DeadlineMarginPolicy, FixedPolicy,
                                    OmegaController, OmegaPolicy,
                                    RoundObservation, margin_ratio)
from repro.runtime.errors import FusionStateError, TransportDeadError
from repro.runtime.faults import FaultSupervisor
from repro.runtime.fusion import FusionNode, LayeredResult, RoundFusion
from repro.runtime.gateway import (AdmissionController, GatewayStats,
                                   ServingGateway, Ticket)
from repro.runtime.master import JobQueue, Master, make_jobs, run_jobs
from repro.runtime.metrics import (STAGES, RuntimeResult, delay_table,
                                   format_controller_trace,
                                   format_delay_table, format_stage_table)
from repro.runtime.tasks import (BACKEND_NAMES, CODE_FAMILIES,
                                 FAULT_POLICIES,
                                 FRAME_PROTOS, SHM_MODES, JobSpec,
                                 RoundBatch, RoundContext, RuntimeConfig,
                                 TaskResult, WireBatch)
from repro.runtime.telemetry import TraceEvent, Tracer
from repro.runtime.trace_export import (chrome_trace, format_timeline,
                                        jsonl_lines, prometheus_snapshot,
                                        write_chrome_trace, write_jsonl)
# NOTE: the concrete backend classes (ThreadTransport / ProcessTransport /
# JaxDeviceTransport) are deliberately NOT re-exported here — importing
# them eagerly would materialize every backend module (multiprocessing
# plumbing included) on every `import repro.runtime`, defeating the
# transport package's lazy registry.  Reach them via
# `repro.runtime.transport.<Name>` (lazy, PEP 562) or `BACKENDS[name]`.
from repro.runtime.transport import (BACKENDS, WorkerTransport,
                                     make_transport)
from repro.runtime.worker import (BatchRunner, StragglerModel, Worker,
                                  WorkerPool, make_compute)

__all__ = [
    "RuntimeConfig", "JobSpec", "RoundContext", "RoundBatch", "TaskResult",
    "WireBatch", "BACKEND_NAMES", "FAULT_POLICIES", "SHM_MODES",
    "FRAME_PROTOS", "CODE_FAMILIES",
    "FaultSupervisor", "TransportDeadError", "FusionStateError",
    "Worker", "WorkerPool", "StragglerModel", "BatchRunner", "make_compute",
    "WorkerTransport", "BACKENDS", "make_transport",
    "FusionNode", "RoundFusion", "LayeredResult",
    "Master", "JobQueue", "make_jobs", "run_jobs",
    "ServingGateway", "AdmissionController", "GatewayStats", "Ticket",
    "OmegaController", "OmegaPolicy", "RoundObservation", "POLICIES",
    "FixedPolicy", "AIMDPolicy", "DeadlineMarginPolicy", "margin_ratio",
    "RuntimeResult", "delay_table", "format_delay_table",
    "format_stage_table", "format_controller_trace", "STAGES",
    "Tracer", "TraceEvent", "chrome_trace", "write_chrome_trace",
    "jsonl_lines", "write_jsonl", "prometheus_snapshot", "format_timeline",
]
