"""Asynchronous master-worker coded execution engine with layered fusion.

The measured counterpart of ``repro.core.simulator``: real coded matmul
tasks on concurrent workers, any-k fusion per MSB-first round, purge of
stale tasks, and §IV deadline termination releasing the highest completed
resolution.  Results come back in the simulator's ``SimResult`` shape so
measured runs validate directly against ``simulate()`` and
``theory_bounds()``.

Quickstart::

    from repro.runtime import RuntimeConfig, run_jobs

    cfg = RuntimeConfig(mu=(400.0, 650.0, 380.0), arrival_rate=30.0,
                        complexity=2.0, deadline=0.05, straggler="exp")
    result, futures = run_jobs(cfg, num_jobs=50, verify=True)
    print(result.mean_delay(), result.success_rate())
"""

from repro.runtime.adaptive import (POLICIES, AIMDPolicy,
                                    DeadlineMarginPolicy, FixedPolicy,
                                    OmegaController, OmegaPolicy,
                                    RoundObservation)
from repro.runtime.fusion import FusionNode, LayeredResult, RoundFusion
from repro.runtime.master import Master, make_jobs, run_jobs
from repro.runtime.metrics import (STAGES, RuntimeResult, delay_table,
                                   format_controller_trace,
                                   format_delay_table, format_stage_table)
from repro.runtime.tasks import (JobSpec, RoundBatch, RoundContext,
                                 RuntimeConfig, TaskResult)
from repro.runtime.worker import StragglerModel, Worker, WorkerPool

__all__ = [
    "RuntimeConfig", "JobSpec", "RoundContext", "RoundBatch", "TaskResult",
    "Worker", "WorkerPool", "StragglerModel",
    "FusionNode", "RoundFusion", "LayeredResult",
    "Master", "make_jobs", "run_jobs",
    "OmegaController", "OmegaPolicy", "RoundObservation", "POLICIES",
    "FixedPolicy", "AIMDPolicy", "DeadlineMarginPolicy",
    "RuntimeResult", "delay_table", "format_delay_table",
    "format_stage_table", "format_controller_trace", "STAGES",
]
