"""Online redundancy control: retune ``omega`` between rounds (§IV, ROADMAP).

The paper fixes the redundancy ratio ``omega`` offline, but the whole point
of layering is graceful behavior under *uncertain* straggling.  The measured
runtime already produces exactly the signals an online controller needs —
per-round ``wait`` wall time (worker-side slack, isolated from master
overhead by the pipelined stage accounting), stale-result counts (redundant
work that was actually performed and thrown away), per-worker utilization,
and missed-deadline flags.  :class:`OmegaController` consumes one
:class:`RoundObservation` per dispatched round and retunes ``omega`` — and
with it the code geometry ``T = ceil(k * omega)`` and the eq. (1) task
split ``kappa`` (:func:`repro.core.scheduling.load_split`) — between
rounds.

Geometry economics (why this is cheap): ``omega`` changes the *codeword
length* ``T`` but never the recovery threshold ``k = n1 * n2``, so decode
semantics are untouched.  Each distinct ``T`` has its own
:class:`~repro.core.coding.DecodePlan` (one Vandermonde build, then an LRU
of per-arrival-set solve operators) held in a process-wide per-geometry
cache, so switching *back* to a previously-used geometry is free; the first
switch to a fresh geometry pays one plan construction — measured here and
reported per switch in the controller trace (``prime_seconds``) — and the
first fuse under it pays one solve-operator factorization inside the plan's
LRU.

Policies (pluggable via :data:`POLICIES` or any :class:`OmegaPolicy`):

``fixed``
    Never moves.  The default; makes an adaptive run degrade to the static
    paper system, and gives the benchmarks their static baselines.
``aimd``
    TCP-style additive-increase / multiplicative-decrease.  Grow ``omega``
    additively when a round misses its deadline, when the EWMA of round
    waits projects the job past ``t_term``, or when one round's wait
    spikes far above the EWMA (the deadline-*free* grow signal — without
    it a deadline-less run could only ever shrink); shrink multiplicatively
    when stale results pile up (redundant tasks that finished compute
    after fusion — pure waste).
``deadline-margin``
    Band controller on the *margin ratio* — remaining time to ``t_term``
    over projected remaining round time.  Grow when the ratio drops below
    the band (or on a realized miss / wait spike), shrink (additively)
    when the ratio sits comfortably above the band while stale results
    accumulate.  More conservative than ``aimd``: it acts on the
    predicted miss, not only the realized one.

All times are seconds (``time.monotonic`` deltas).  The controller is
master-thread-only (no locking): :meth:`OmegaController.observe` is called
from :meth:`repro.runtime.master.Master.run` between rounds, never
concurrently.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Optional, Sequence, Union

import numpy as np

__all__ = ["RoundObservation", "OmegaPolicy", "FixedPolicy", "AIMDPolicy",
           "DeadlineMarginPolicy", "OmegaController", "POLICIES",
           "make_policy", "margin_ratio"]


def margin_ratio(margin: Optional[float], unit_ewma: Optional[float],
                 units_left: int) -> Optional[float]:
    """The §IV deadline-margin ratio, shared across consumers.

    ``margin`` seconds remain before the deadline; ``units_left`` units of
    work (mini-job rounds for the runtime, head planes for serving) are
    still to run, each projected to take ``unit_ewma`` seconds.  The ratio
    is *how many projected remainders fit in the time left* — < 1 means a
    predicted miss.  Returns None when undefined (no deadline, no work
    left, or no cost estimate yet); callers treat None as "no signal".

    :class:`DeadlineMarginPolicy` (retuning ω between rounds) leans on
    this function.  (The serving path's historical plane-budget adapter
    did too; since ``launch/serve.py`` routes deadlines through the
    runtime itself, the runtime's §IV machinery is the only deadline
    controller left.)
    """
    if (margin is None or units_left <= 0 or unit_ewma is None
            or unit_ewma <= 0.0):
        return None
    return margin / (unit_ewma * units_left)


@dataclasses.dataclass(frozen=True)
class RoundObservation:
    """What the master saw for one dispatched round (all times seconds).

    ``wait``
        Seconds the master blocked on fusion for this round — the worker-
        side slack signal (``RuntimeResult.stage_seconds['wait']``'s
        per-round term), free of master-side encode/decode overhead.
    ``fused``
        False when the round timed out at ``t_term`` (the §IV missed-
        deadline flag: the job was terminated with this round unfused).
    ``stale``
        Task results that arrived after a round fused (counted since the
        previous observation): redundant work that was actually *performed*
        and dropped — the over-provisioning signal.
    ``deadline_margin``
        ``t_term - now`` right after the round resolved (None when the job
        has no termination time, i.e. no deadline or no queued successor).
    ``rounds_left``
        Mini-job rounds still to run for this job after this round.
    ``utilization``
        Per-worker busy fraction since the run started (delay + compute
        over wall time), from the pool's occupancy counters.  The
        built-in policies key on wait/stale/margin only; this field is
        part of the observation contract for *custom* policies (e.g.
        per-worker blacklisting or load-aware splits).
    """

    round_idx: int
    job_id: int
    wait: float
    fused: bool
    stale: int
    deadline_margin: Optional[float]
    rounds_left: int
    utilization: Optional[np.ndarray] = None


class OmegaPolicy:
    """One retuning rule: maps an observation to a new (unclipped) omega.

    Stateful (EWMAs live on the instance); instances are single-run,
    master-thread-only.  :meth:`step` returns ``(new_omega, reason)`` with
    ``reason`` a short human-readable string when the policy moved, else
    ``None`` (``new_omega == omega``).  Bounds are enforced by the
    controller, not the policy.
    """

    def step(self, obs: RoundObservation,
             omega: float) -> tuple[float, Optional[str]]:
        raise NotImplementedError

    def _ewma(self, prev: Optional[float], x: float, alpha: float) -> float:
        return x if prev is None else (1.0 - alpha) * prev + alpha * x


class FixedPolicy(OmegaPolicy):
    """The static paper system: omega never moves."""

    name = "fixed"

    def step(self, obs, omega):
        return omega, None


class _EwmaPolicy(OmegaPolicy):
    """Shared scaffolding for the built-in adaptive policies.

    Maintains the stale-per-round and round-wait EWMAs, and implements the
    signals both policies agree on:

    * a realized §IV miss (``obs.fused`` False) always grows;
    * a **wait spike** — one round's wait exceeding ``spike_factor`` times
      the wait EWMA — always grows.  This is the deadline-*free* grow
      signal: without it, a run with no configured deadline has no miss
      signal at all and stale-driven shrinks would ratchet omega one-way
      to ``omega_min`` (T = k), exactly the brittle geometry an outage
      punishes;
    * stale pile-up (EWMA above ``stale_tolerance``) shrinks, gated by the
      subclass (``_may_shrink``), and the EWMA resets after acting so one
      burst is acted on once.

    Subclasses provide the policy-specific grow trigger (``_grow_reason``,
    called with the pre-spike-update EWMA) and shrink arithmetic
    (``_shrink``).
    """

    def __init__(self, *, grow_step: float, stale_tolerance: float,
                 alpha: float, spike_factor: float):
        if spike_factor <= 1.0:
            raise ValueError(
                f"spike_factor must be > 1, got {spike_factor}")
        self.grow_step = grow_step
        self.stale_tolerance = stale_tolerance
        self.alpha = alpha
        self.spike_factor = spike_factor
        self._wait_ewma: Optional[float] = None
        self._stale_ewma = 0.0

    def step(self, obs, omega):
        self._stale_ewma = self._ewma(self._stale_ewma, float(obs.stale),
                                      self.alpha)
        if not obs.fused:
            return omega + self.grow_step, "missed deadline"
        prev_wait = self._wait_ewma
        self._wait_ewma = self._ewma(prev_wait, obs.wait, self.alpha)
        if (prev_wait is not None and prev_wait > 0.0
                and obs.wait > self.spike_factor * prev_wait):
            return omega + self.grow_step, (
                f"wait spike ({obs.wait * 1e3:.1f} ms > "
                f"{self.spike_factor:g}x ewma)")
        reason = self._grow_reason(obs)
        if reason is not None:
            return omega + self.grow_step, reason
        if self._stale_ewma > self.stale_tolerance and self._may_shrink(obs):
            self._stale_ewma = 0.0   # acted on the signal; re-accumulate
            return self._shrink(omega), "stale results piling up"
        return omega, None

    def _grow_reason(self, obs) -> Optional[str]:
        """Policy-specific grow trigger (EWMAs already updated)."""
        return None

    def _may_shrink(self, obs) -> bool:
        return True

    def _shrink(self, omega: float) -> float:
        raise NotImplementedError


class AIMDPolicy(_EwmaPolicy):
    """Additive increase on miss signals, multiplicative decrease on
    stale pile-up.

    ``increase``        additive omega step on a grow signal.
    ``decrease``        multiplicative factor (< 1) on a waste signal.
    ``stale_tolerance`` EWMA stale-results-per-round above which redundancy
                        is considered wasteful.
    ``headroom``        projected-miss guard: grow when
                        ``rounds_left * wait_ewma * headroom`` exceeds the
                        remaining deadline margin.
    ``spike_factor``    deadline-free guard: grow when one round's wait
                        exceeds this multiple of the wait EWMA.
    """

    name = "aimd"

    def __init__(self, *, increase: float = 0.25, decrease: float = 0.85,
                 stale_tolerance: float = 1.0, headroom: float = 1.0,
                 alpha: float = 0.3, spike_factor: float = 4.0):
        if not 0.0 < decrease < 1.0:
            raise ValueError(f"decrease must be in (0, 1), got {decrease}")
        super().__init__(grow_step=increase, stale_tolerance=stale_tolerance,
                         alpha=alpha, spike_factor=spike_factor)
        self.decrease = decrease
        self.headroom = headroom

    def _grow_reason(self, obs):
        if (obs.deadline_margin is not None and obs.rounds_left > 0
                and self._wait_ewma is not None
                and obs.rounds_left * self._wait_ewma * self.headroom
                > obs.deadline_margin):
            return "projected deadline miss"
        return None

    def _shrink(self, omega):
        return omega * self.decrease


class DeadlineMarginPolicy(_EwmaPolicy):
    """Band control on the deadline margin ratio.

    The margin ratio is ``deadline_margin / (wait_ewma * rounds_left)`` —
    how many projected-remaining-job-times fit in the time left before
    ``t_term``.  Below ``low`` the job is threatened: grow omega by
    ``step_up``.  Above ``high`` with stale results accumulating, the
    redundancy is buying nothing: shrink by ``step_down``.  A realized
    miss or a wait spike (the deadline-free signal) always grows.
    """

    name = "deadline-margin"

    def __init__(self, *, low: float = 1.5, high: float = 6.0,
                 step_up: float = 0.25, step_down: float = 0.125,
                 stale_tolerance: float = 1.0, alpha: float = 0.3,
                 spike_factor: float = 4.0):
        if low >= high:
            raise ValueError(f"need low < high, got {low} >= {high}")
        super().__init__(grow_step=step_up, stale_tolerance=stale_tolerance,
                         alpha=alpha, spike_factor=spike_factor)
        self.low = low
        self.high = high
        self.step_down = step_down
        self._last_ratio: Optional[float] = None

    def _margin_ratio(self, obs) -> Optional[float]:
        return margin_ratio(obs.deadline_margin, self._wait_ewma or None,
                            obs.rounds_left)

    def _grow_reason(self, obs):
        self._last_ratio = ratio = self._margin_ratio(obs)
        if ratio is not None and ratio < self.low:
            return f"margin ratio {ratio:.2f} < {self.low}"
        return None

    def _may_shrink(self, obs):
        # never trim while the margin is anywhere near the grow band
        return self._last_ratio is None or self._last_ratio > self.high

    def _shrink(self, omega):
        return omega - self.step_down


POLICIES: dict[str, type[OmegaPolicy]] = {
    FixedPolicy.name: FixedPolicy,
    AIMDPolicy.name: AIMDPolicy,
    DeadlineMarginPolicy.name: DeadlineMarginPolicy,
}


def make_policy(policy: Union[str, OmegaPolicy, None]) -> OmegaPolicy:
    """Resolve a policy name (see :data:`POLICIES`) or pass an instance."""
    if policy is None:
        return FixedPolicy()
    if isinstance(policy, OmegaPolicy):
        return policy
    try:
        return POLICIES[policy]()
    except KeyError:
        raise ValueError(f"unknown omega policy {policy!r}; "
                         f"known: {sorted(POLICIES)}") from None


class OmegaController:
    """Owns the runtime's *current* code geometry and retunes it online.

    The master asks the controller for the current ``(code, kappa)`` pair
    when encoding a round, and feeds back one :class:`RoundObservation`
    after each round resolves.  When the policy's (clipped) omega crosses a
    codeword-length boundary (``T = max(k, ceil(k * omega))`` changes), the
    controller *switches geometry*: it builds the new
    :class:`~repro.core.coding.PolynomialCode`, primes its per-geometry
    :class:`~repro.core.coding.DecodePlan` (timed — ``prime_seconds`` in
    the trace; ~0 when returning to a previously-seen geometry, one
    Vandermonde build otherwise), and recomputes the eq. (1) split for the
    new ``T``.  Omega moves *within* a codeword-length bucket are traced
    but switch nothing.

    Master-thread-only (called between rounds, never concurrently); the
    per-geometry plan caches it leans on are themselves thread-safe.
    """

    def __init__(self, cfg, policy: Union[str, OmegaPolicy, None] = None):
        self.cfg = cfg
        self.policy = make_policy(policy if policy is not None
                                  else getattr(cfg, "adapt", "fixed"))
        self.omega_min = float(getattr(cfg, "omega_min", 1.0))
        self.omega_max = float(getattr(cfg, "omega_max", 3.0))
        # Bounds constrain the *adaptive* policies only: a fixed-policy
        # controller must reproduce the configured static geometry
        # verbatim (simulator agreement depends on it), even when
        # cfg.omega sits outside the (inert) adaptive bounds.
        if isinstance(self.policy, FixedPolicy):
            self.omega = float(cfg.omega)
        else:
            self.omega = float(np.clip(cfg.omega, self.omega_min,
                                       self.omega_max))
        self.omega_initial = self.omega
        self.code = cfg.code(omega=self.omega)
        self.kappa = cfg.load_split(total=self.code.num_tasks)
        self.trace: list[dict] = []
        self.switches = 0
        self.prime_seconds_total = 0.0
        # fault-supervision state: the surviving fleet the eq. (1) split
        # runs over (None = everyone), and the omega the fleet forced us
        # down from (restored when readmissions regrow the fleet)
        self.active: Optional[tuple[int, ...]] = None
        self._omega_pre_shrink: Optional[float] = None

    @property
    def total_tasks(self) -> int:
        """Current codeword length ``T``."""
        return self.code.num_tasks

    def observe(self, obs: RoundObservation) -> bool:
        """Feed one round's observation; returns True on a geometry switch.

        A switch means subsequently-encoded rounds use a different codeword
        length (the already-encoded in-flight/buffered round keeps the
        geometry it was encoded with — the master carries ``kappa``
        alongside each encoded buffer).
        """
        new_omega, reason = self.policy.step(obs, self.omega)
        new_omega = float(np.clip(new_omega, self.omega_min, self.omega_max))
        if new_omega == self.omega:
            return False
        old_omega, old_T = self.omega, self.code.num_tasks
        # the codeword-length rule lives in ONE place (PolynomialCode):
        # derive T from the candidate code rather than re-deriving the
        # ceil formula here
        new_code = self.cfg.code(omega=new_omega)
        new_T = new_code.num_tasks
        self.omega = new_omega
        prime = 0.0
        switched = new_T != old_T
        if switched:
            t0 = time.perf_counter()
            self.code = new_code
            self.code.plan()    # per-geometry DecodePlan: built or reused
            prime = time.perf_counter() - t0
            self.kappa = self.cfg.load_split(total=new_T,
                                             active=self.active)
            self.switches += 1
            self.prime_seconds_total += prime
        self.trace.append({
            "round": obs.round_idx, "job": obs.job_id,
            "omega_old": round(old_omega, 4), "omega_new": round(new_omega, 4),
            "T_old": old_T, "T_new": new_T, "switched": switched,
            "kappa": [int(x) for x in self.kappa],
            "reason": reason, "prime_seconds": prime,
        })
        return switched

    def refit_fleet(self, active: Sequence[int]) -> bool:
        """Re-split the eq. (1) kappa over a changed surviving fleet.

        The fault supervisor calls this after a quarantine (fleet shrank)
        or a readmission (fleet grew).  Returns False — and changes
        nothing — when the surviving fleet fell below the recovery
        threshold (``len(active) < k``, the ISSUE's fleet-collapse line):
        the caller must then release at a degraded resolution.

        Geometry rule — shrink proportionally, "if omega allows": the
        codeword length ``T = ceil(k * omega)`` was provisioned for the
        FULL fleet's service capacity, so when survivors carry only a
        fraction of ``sum(mu)`` the effective redundancy is scaled by
        that same fraction, floored at ``omega = 1`` (``T = k``, the
        structural minimum — past that there is nothing left to shrink).
        ``kappa`` is always re-split over the survivors alone (workers
        legitimately hold multi-task slices — ``T`` may exceed the
        worker count even at full fleet).  The un-scaled omega is
        remembered so a readmission that restores capacity restores the
        geometry with it; a policy retune while shrunk rebases the
        remembered value the next time the fleet changes.  All moves are
        traced like policy retunes (``reason`` prefixed ``fleet``).
        """
        k = self.cfg.k
        active = tuple(sorted(set(active)))
        S = len(active)
        if S < k:
            return False
        full = S >= self.cfg.num_workers
        self.active = None if full else active
        base = (self.omega if self._omega_pre_shrink is None
                else self._omega_pre_shrink)
        mu = np.asarray(self.cfg.mu, dtype=np.float64)
        scale = float(mu[list(active)].sum() / mu.sum())
        new_omega = max(1.0, base * scale)
        self._omega_pre_shrink = None if full else base
        old_omega, old_T = self.omega, self.code.num_tasks
        new_code = self.cfg.code(omega=new_omega)
        new_T = new_code.num_tasks
        self.omega = new_omega
        prime = 0.0
        switched = new_T != old_T
        if switched:
            t0 = time.perf_counter()
            self.code = new_code
            self.code.plan()
            prime = time.perf_counter() - t0
            self.switches += 1
            self.prime_seconds_total += prime
        self.kappa = self.cfg.load_split(total=new_T, active=self.active)
        self.trace.append({
            "round": -1, "job": -1,
            "omega_old": round(old_omega, 4),
            "omega_new": round(new_omega, 4),
            "T_old": old_T, "T_new": new_T, "switched": switched,
            "kappa": [int(x) for x in self.kappa],
            "reason": f"fleet refit: {S}/{self.cfg.num_workers} workers "
                      f"active",
            "prime_seconds": prime,
        })
        return True

    def summary(self) -> dict:
        """JSON-serializable controller outcome (RuntimeResult.controller)."""
        return {
            "policy": getattr(self.policy, "name",
                              type(self.policy).__name__),
            "omega_initial": self.omega_initial,
            "omega_final": self.omega,
            "omega_bounds": [self.omega_min, self.omega_max],
            "T_final": self.total_tasks,
            "retunes": len(self.trace),
            "switches": self.switches,
            "prime_seconds_total": self.prime_seconds_total,
        }
