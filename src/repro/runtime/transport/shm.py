"""Shared-memory block arenas: the process backend's zero-copy wire path.

Pickling a coded block over a pipe costs two copies (serialize into the
pipe, deserialize out of it) plus a scheduler wake-up per hop — overhead
paid by *every* round, and therefore by every resolution's release delay,
res-0 included (the early release the paper's layered construction exists
for).  This module removes the copies: master and worker share a
:class:`BlockArena` — one ``multiprocessing.shared_memory`` segment per
direction per worker — and the pipe carries only a tiny descriptor
(:class:`~repro.runtime.tasks.ArenaSlice`: offset, shape, dtype).  The
receiving side maps the slice as an ndarray view; nobody serializes block
payloads at all.

Allocation is a :class:`RingAllocator`: a bump pointer over the segment
with FIFO reclamation keyed on the dispatch ``seq`` — the same monotonic
sequence number the purge watermark already speaks.  Rounds are allocated
in ``seq`` order and purged in ``seq`` order, so freeing "everything at or
below the watermark" is exact, O(slots freed), and needs no free-list:

* the **master** owns each worker's *dispatch* ring — slots are claimed at
  ``_send_slice`` and recycled by ``free_through(seq)`` when the round is
  purged (fused, terminated, or shut down);
* the **worker** owns its *result* ring — slots are claimed as tasks
  complete (the compute kernel writes straight into the slot) and recycled
  by ``free_below(watermark)`` when the purge watermark passes *beyond*
  them.  The master only ever *views* result slots, never allocates.

One allocating side per ring means no cross-process allocator state and no
locks in shared memory.  Safety of reuse rests on two runtime invariants:
the master's round loop decodes a fused round one iteration *behind* its
purge but always *before* the next round's purge is sent
(``RoundFusion.decode`` copies via ``np.stack``) — which is why the result
ring frees strictly below the watermark, never the watermark round itself
— and the fusion sink rejects every result of a purged round without
reading its value.  Together: a recycled slot can only ever be observed by
a read that is already dead.

A full ring is not an error: the caller falls back to the pickled pipe
path for that slice (``alloc`` returns None), so arena exhaustion degrades
to exactly the pre-arena behavior.

SIGKILL safety: segments are created (and therefore unlinked) only on the
master side.  A worker killed mid-round strands nothing — the master's
``shutdown`` unlinks every arena it created and then sweeps ``/dev/shm``
for its own name prefix (:func:`unlink_segments`), so even a master that
lost track of a segment cannot leak it.  Workers *attach* by name with the
attach-side ``resource_tracker`` registration suppressed (bpo-38119: on
3.10 the attach side registers too, and a tracker-driven unlink at worker
exit would destroy a segment the master still owns — worse, under fork
the worker shares the master's tracker, so even an attach-then-unregister
dance would strip the owner's entry).
"""

from __future__ import annotations

import collections
import math
import os
import pathlib
import uuid
from multiprocessing import resource_tracker, shared_memory
from typing import Optional

import numpy as np

from repro.runtime.tasks import ArenaSlice

__all__ = ["BlockArena", "RingAllocator", "ALIGNMENT", "arena_prefix",
           "leaked_segments", "unlink_segments"]

#: Slot alignment in bytes.  64 keeps every mapped ndarray cache-line
#: aligned (and SIMD-load aligned for every dtype numpy ships).
ALIGNMENT = 64

#: Where POSIX shared memory appears as files on Linux — the leak scan's
#: ground truth.  On platforms without it the scan degrades to a no-op
#: (and the arena still works; only the belt-and-braces sweep is lost).
SHM_DIR = pathlib.Path("/dev/shm")


def arena_prefix() -> str:
    """A collision-safe ``/dev/shm`` name prefix for one transport.

    Embeds the pid so concurrent runs on one host cannot sweep each
    other's segments, plus random hex so sequential transports in one
    process (the conformance suite) stay distinct even if a shutdown
    raced.
    """
    return f"lra-{os.getpid():x}-{uuid.uuid4().hex[:8]}-"


def leaked_segments(prefix: str) -> list[str]:
    """Names of shared-memory segments under ``prefix`` still on disk."""
    if not SHM_DIR.is_dir():
        return []
    return sorted(p.name for p in SHM_DIR.iterdir()
                  if p.name.startswith(prefix))


def unlink_segments(prefix: str) -> list[str]:
    """Force-unlink every segment under ``prefix``; returns what it swept.

    The shutdown backstop: normally every arena is unlinked by its owner
    and this returns ``[]`` — anything else is a segment that would have
    outlived the run (e.g. the master lost track of it mid-teardown).
    """
    swept = []
    for name in leaked_segments(prefix):
        try:
            (SHM_DIR / name).unlink()
            swept.append(name)
        except OSError:           # pragma: no cover - raced another sweep
            pass
    return swept


class RingAllocator:
    """FIFO ring allocator over ``capacity`` bytes, keyed by ``seq``.

    Slots are claimed front-to-back and released oldest-first against a
    sequence watermark — the access pattern of round dispatch + purge.
    Offsets are :data:`ALIGNMENT`-aligned.  ``alloc`` returns None when
    the request does not fit (the caller's pickle-fallback signal), never
    raises.

    Live slots are ``(seq, offset, size)`` in allocation order; the free
    space is the gap from the write head to the oldest live slot (wrapping
    at capacity).  Because both allocation and release are FIFO, that gap
    is exactly the free region — a new slot can never overlap a live one
    (the property the hypothesis suite drives arbitrary interleavings at).
    """

    __slots__ = ("capacity", "_head", "_live", "used_bytes", "high_water")

    def __init__(self, capacity: int):
        if capacity <= 0:
            raise ValueError(f"ring capacity must be > 0, got {capacity}")
        self.capacity = capacity
        self._head = 0            # next byte after the newest slot
        self._live: collections.deque[tuple[int, int, int]] = \
            collections.deque()   # (seq, offset, size), oldest first
        self.used_bytes = 0
        self.high_water = 0

    def __len__(self) -> int:
        return len(self._live)

    @property
    def used_fraction(self) -> float:
        return self.used_bytes / self.capacity

    def alloc(self, nbytes: int, seq: int) -> Optional[int]:
        """Claim an aligned slot for ``nbytes``; returns its offset.

        ``seq`` tags the slot for watermark release and must be
        non-decreasing across calls (dispatch order).  None = no room.
        """
        size = max(ALIGNMENT, ALIGNMENT * math.ceil(nbytes / ALIGNMENT))
        if not self._live:
            if size > self.capacity:
                return None
            self._head = size
        else:
            first = self._live[0][1]
            head = self._head
            if head > first:
                # un-wrapped: free space is [head, cap) then [0, first)
                if head + size <= self.capacity:
                    pass                       # place at head
                elif size <= first:
                    head = 0                   # wrap; tail gap is wasted
                    #                            until the wrap slot frees
                else:
                    return None
            elif head < first:
                # wrapped: free space is only [head, first)
                if head + size > first:
                    return None
            else:
                return None                    # head == first: ring full
            self._head = head + size
            offset = head
            self._live.append((seq, offset, size))
            self.used_bytes += size
            self.high_water = max(self.high_water, self.used_bytes)
            return offset
        self._live.append((seq, 0, size))
        self.used_bytes += size
        self.high_water = max(self.high_water, self.used_bytes)
        return 0

    def _release(self, seq: int, inclusive: bool) -> int:
        freed = 0
        live = self._live
        while live:
            slot_seq, _, size = live[0]
            if slot_seq > seq or (slot_seq == seq and not inclusive):
                break
            live.popleft()
            self.used_bytes -= size
            freed += 1
        if not live:
            self._head = 0        # empty ring: restart at the base
        return freed

    def free_through(self, seq: int) -> int:
        """Release every slot with ``slot_seq <= seq`` (purge watermark);
        returns the number of slots freed."""
        return self._release(seq, inclusive=True)

    def free_below(self, seq: int) -> int:
        """Release every slot with ``slot_seq < seq`` (strict watermark);
        returns the number of slots freed."""
        return self._release(seq, inclusive=False)

    def live_spans(self) -> list[tuple[int, int, int]]:
        """Snapshot of live ``(seq, offset, size)`` slots (test hook)."""
        return list(self._live)


class BlockArena:
    """A shared-memory segment + ring allocator + ndarray slot views.

    ``create=True`` makes this side the *owner*: it creates the segment
    and is the only side allowed to ``unlink`` it.  ``create=False``
    attaches to an existing segment by name and deregisters from the
    resource tracker (see module docstring) — attach-side ``close`` only
    unmaps.

    Each side may allocate on its own arenas (one allocating side per
    ring, by protocol); ``view`` maps any :class:`ArenaSlice` regardless
    of who allocated it.
    """

    def __init__(self, capacity: int, *, name: Optional[str] = None,
                 create: bool = True):
        if create:
            capacity = max(ALIGNMENT,
                           ALIGNMENT * math.ceil(capacity / ALIGNMENT))
            self._shm = shared_memory.SharedMemory(
                name=name, create=True, size=capacity)
        else:
            # Suppress the attach-side resource_tracker registration
            # (bpo-38119: on 3.10 attaching registers too) rather than
            # undoing it after the fact: under the fork start method the
            # worker shares the master's tracker process, so a worker's
            # unregister would strip the *owner's* entry and the owner's
            # later unlink would make the tracker traceback on the
            # unknown name.  Never registering keeps exactly one entry —
            # the creator's — for the tracker to reconcile.
            orig_register = resource_tracker.register
            resource_tracker.register = lambda *a, **kw: None
            try:
                self._shm = shared_memory.SharedMemory(name=name)
            finally:
                resource_tracker.register = orig_register
        self.owner = create
        self.ring = RingAllocator(self._shm.size)

    @property
    def name(self) -> str:
        return self._shm.name

    @property
    def capacity(self) -> int:
        return self._shm.size

    # -- slot lifecycle -------------------------------------------------------
    def alloc_view(self, shape: tuple[int, ...], dtype, seq: int
                   ) -> Optional[tuple[ArenaSlice, np.ndarray]]:
        """Claim a slot for an array of ``shape``/``dtype``; returns the
        wire descriptor plus a writable ndarray view of the slot (None if
        the ring is full — caller falls back to the pickle path)."""
        dt = np.dtype(dtype)
        nbytes = dt.itemsize * math.prod(shape)
        offset = self.ring.alloc(nbytes, seq)
        if offset is None:
            return None
        view = np.ndarray(shape, dtype=dt, buffer=self._shm.buf,
                          offset=offset)
        return ArenaSlice(offset=offset, shape=tuple(shape),
                          dtype=dt.str), view

    def write(self, arr: np.ndarray, seq: int) -> Optional[ArenaSlice]:
        """Copy ``arr`` into a fresh slot; returns its descriptor (None
        if the ring is full).  The single master-side copy of dispatch —
        the pickle path's two copies and its allocation both go away."""
        got = self.alloc_view(arr.shape, arr.dtype, seq)
        if got is None:
            return None
        desc, view = got
        np.copyto(view, arr)
        return desc

    def view(self, desc: ArenaSlice) -> np.ndarray:
        """Map a descriptor as an ndarray view over the segment."""
        return np.ndarray(desc.shape, dtype=np.dtype(desc.dtype),
                          buffer=self._shm.buf, offset=desc.offset)

    def free_through(self, seq: int) -> int:
        return self.ring.free_through(seq)

    def free_below(self, seq: int) -> int:
        return self.ring.free_below(seq)

    @property
    def used_fraction(self) -> float:
        return self.ring.used_fraction

    # -- teardown -------------------------------------------------------------
    def close(self) -> None:
        """Unmap the segment.  Tolerates live ndarray views: numpy keeps
        the mapping's buffer exported, so ``close`` raises BufferError
        until they are collected — the memory is reclaimed at process
        exit regardless, and ``unlink`` (the part that outlives the
        process) never depends on ``close`` having succeeded."""
        try:
            self._shm.close()
        except BufferError:
            pass

    def unlink(self) -> None:
        """Remove the segment name (owner side only; idempotent)."""
        if not self.owner:
            return
        try:
            self._shm.unlink()
        except FileNotFoundError:     # pragma: no cover - already swept
            pass
