"""Pluggable worker transports for the runtime engine.

One dispatch interface — :class:`~repro.runtime.transport.base.WorkerTransport`
(start / sample delays / submit round / purge / shutdown, push-style
result return into the fusion sink) — and three backends behind it:

``thread``
    Today's in-process worker pool (:mod:`repro.runtime.worker`), now the
    reference adapter: zero-copy round views, shared cancel events.
``process``
    Multiprocessing workers over pipes
    (:mod:`repro.runtime.transport.process`): GIL-free parallel compute,
    wire-serialized batches, purge watermarks, a master-side drain thread.
``jax``
    One worker per local JAX device
    (:mod:`repro.runtime.transport.jax_device`): thread loop, device-pinned
    async-dispatch compute.
``socket``
    TCP worker hosts on other machines
    (:mod:`repro.runtime.transport.socket_host`): length-prefixed
    compressed frames, purge watermarks, heartbeat liveness,
    reconnect-or-fail — the multi-HOST backend (``runctl serve-worker``
    runs the remote side).

The master never names a backend class — it calls :func:`make_transport`
with the run's :class:`~repro.runtime.tasks.RuntimeConfig`, whose
``backend`` field picks the substrate.  Every backend must pass the same
conformance suite (``tests/test_transport_conformance.py``): identical
round-trip decode, purge, shutdown, and simulator-agreement behavior.

Backend modules load lazily (PEP 562): the base contract lives below the
worker module in the import graph (it hosts the shared master-side
dispatch template), while the concrete backends live above it, so eager
package-level imports of both would be circular.
"""

from __future__ import annotations

import importlib
from typing import Callable, Optional, Type

import numpy as np

from repro.runtime.tasks import RuntimeConfig, TaskResult
from repro.runtime.transport.base import StragglerModel, WorkerTransport

__all__ = ["WorkerTransport", "StragglerModel", "ThreadTransport",
           "ProcessTransport", "JaxDeviceTransport", "SocketTransport",
           "BACKENDS", "make_transport"]

#: backend name -> (module, class) — the ``RuntimeConfig.backend`` registry.
_BACKEND_PATHS: dict[str, tuple[str, str]] = {
    "thread": ("repro.runtime.transport.thread", "ThreadTransport"),
    "process": ("repro.runtime.transport.process", "ProcessTransport"),
    "jax": ("repro.runtime.transport.jax_device", "JaxDeviceTransport"),
    "socket": ("repro.runtime.transport.socket_host", "SocketTransport"),
}


def _load(backend: str) -> Type[WorkerTransport]:
    module, cls_name = _BACKEND_PATHS[backend]
    return getattr(importlib.import_module(module), cls_name)


class _BackendRegistry(dict):
    """Name -> transport class, materializing backend modules on access."""

    def __missing__(self, name: str) -> Type[WorkerTransport]:
        if name not in _BACKEND_PATHS:
            raise KeyError(name)
        cls = _load(name)
        self[name] = cls
        return cls

    def __iter__(self):
        return iter(_BACKEND_PATHS)

    def __len__(self):
        return len(_BACKEND_PATHS)

    def keys(self):
        return _BACKEND_PATHS.keys()

    def items(self):
        return [(name, self[name]) for name in _BACKEND_PATHS]

    def values(self):
        return [self[name] for name in _BACKEND_PATHS]


BACKENDS: dict[str, Type[WorkerTransport]] = _BackendRegistry()

_LAZY_CLASSES = {"ThreadTransport": "thread", "ProcessTransport": "process",
                 "JaxDeviceTransport": "jax", "SocketTransport": "socket"}


def __getattr__(name: str):
    backend = _LAZY_CLASSES.get(name)
    if backend is not None:
        return _load(backend)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def make_transport(cfg: RuntimeConfig,
                   sink: Callable[[TaskResult], None],
                   rng: Optional[np.random.Generator] = None,
                   tracer=None) -> WorkerTransport:
    """Build the configured worker transport (not yet started).

    ``cfg.backend`` picks the class; the legacy ``use_jax_devices`` flag
    upgrades a default ``thread`` selection to the ``jax`` backend, which
    preserves its pre-transport behavior exactly (thread workers, compute
    placed round-robin over local devices).  Conflicting combinations
    (``use_jax_devices`` with an explicitly non-thread backend) are
    rejected at config construction, not here.

    ``tracer`` (a :class:`repro.runtime.telemetry.Tracer`, or None) makes
    the transport emit dispatch/task/liveness events; in-process backends
    record straight into it, remote ones ship worker-stamped events back
    and ingest them clock-rebased.
    """
    backend = cfg.backend
    if backend == "thread" and cfg.use_jax_devices:
        backend = "jax"
    try:
        cls = BACKENDS[backend]
    except KeyError:
        raise ValueError(f"unknown worker backend {backend!r}; "
                         f"known: {sorted(_BACKEND_PATHS)}") from None
    return cls(cfg, sink=sink, rng=rng, tracer=tracer)
