"""The ``socket`` backend: TCP worker hosts behind the transport seam.

The first genuinely multi-HOST transport: each worker is a standalone
``worker_host`` process (``runctl serve-worker``, possibly on another
machine) listening on a TCP port; the master-side :class:`SocketTransport`
dials one connection per worker and speaks a length-prefixed frame
protocol over it.  The §IV contract is the process backend's, faced at a
network for the first time:

* **Dispatch** — each worker's ``kappa_p``-slice ships as a
  :class:`~repro.runtime.tasks.WireBatch` inside a ``("round", wire)``
  frame.  Frames above a size threshold are transparently compressed
  (zlib, or lz4 when installed — the big coded blocks and result matrices
  are the ROADMAP's "result-path compression" case); the frame header is
  self-describing, so each side decodes whatever the other chose.
* **Purge** — ``("purge", seq)`` is the same watermark message the
  process backend uses: the worker drops every batch with
  ``seq <= watermark``, queued *or* currently delaying (the delay wait
  polls the socket, so a purge interrupts it immediately).
* **Results** — ``("result", wire, busy_seconds)`` frames return on the
  same connection; a master-side receiver thread per worker rebuilds
  :class:`~repro.runtime.tasks.TaskResult` and posts it to the fusion
  sink.
* **Liveness** — a master-side heartbeat thread pings every worker; a
  worker that has not produced *any* frame (pong, result, stats) within
  ``heartbeat_timeout`` — or whose connection dropped and could not be
  re-established — is reported dead via
  :meth:`~repro.runtime.transport.base.WorkerTransport.assert_alive`, so
  a SIGKILLed host fails the run promptly instead of hanging fusion.
* **Reconnect-or-fail** — a dropped connection (sever, host restart
  window) is re-dialed a bounded number of times; on success the master
  re-sends its hello carrying the session id and the current purge
  watermark, so rounds lost with the connection are cleanly dropped by
  the worker the moment it resumes.  On failure the worker is dead.
* **Shutdown** — ``("stop", drain)``: the worker drains or purges its
  queue, answers with a final ``("stats", ...)`` envelope (exact
  ``tasks_done``/``tasks_purged``/``busy_seconds``), and closes the
  session; the host then loops back to ``accept`` for the next master.
  No master-side thread outlives the call.

Frame layout (16-byte header, network byte order)::

    0      4    5     6      8         12        16
    ┌──────┬────┬─────┬──────┬─────────┬─────────┐
    │MAGIC │ver │codec│ rsvd │ raw_len │wire_len │ payload (wire_len B)
    └──────┴────┴─────┴──────┴─────────┴─────────┘
    MAGIC = b"LRF1" (v1) or b"LRF2" (v2); codec ∈ {none, zlib, lz4};
    raw_len is the decompressed payload size (integrity-checked).

An **LRF1** payload is one pickle of the message.  An **LRF2** payload
is pickle-free for ndarray data::

    ┌─────────┬──────┬────────────┬──────┬─────────────────┐
    │meta_len │ nbuf │ nbuf × len │ meta │ buffers ...     │
    │   u32   │ u16  │    u64     │      │ (raw C order)   │
    └─────────┴──────┴────────────┴──────┴─────────────────┘

``meta`` is the message tuple pickled at protocol 5 with a
``buffer_callback``, so every contiguous ndarray (the coded blocks, the
result matrices) is lifted *out of the pickle stream*: its dtype, shape,
and contiguity ride in ``meta`` (numpy's reconstructor) while the bytes
themselves are appended as raw buffers — memoryviews over the original
arrays, handed straight to the compressor / socket with no intermediate
serialization copy.  Control messages (purge, ping, stats) simply have
``nbuf = 0`` and stay pure pickle.  The protocol is negotiated in the
hello (see :func:`serve_worker_host`): LRF1 peers remain readable for
one release, and a v2-offering master fails clean — a clear
``ConnectionError``, not a garbled stream — against a worker host that
predates the offer.

The worker-side event loop *is* the process backend's
(:class:`~repro.runtime.transport.process._WorkerLoop` over a socket
adapter), so purge/drain/occupancy semantics cannot drift between the
single-host and multi-host paths.  :class:`LocalCluster` spawns worker
hosts on localhost ports — the conformance suite's stand-in for a real
cluster, and the fault-injection harness (SIGKILL a host, sever a
connection).

Security note: frames carry pickles, as the multiprocessing backend's
pipes do.  The protocol authenticates nothing — run it on a trusted
network segment only (the paper's cluster model), never an open port on
the internet.
"""

from __future__ import annotations

import dataclasses
import os
import pathlib
import random
import select
import socket
import struct
import subprocess
import sys
import threading
import time
import uuid
import zlib
from typing import Callable, Optional

import numpy as np
import pickle

from repro.runtime import telemetry
from repro.runtime.tasks import (RoundContext, RuntimeConfig, TaskResult,
                                 WireBatch, WireGroup)
from repro.runtime.transport.base import WorkerTransport
from repro.runtime.transport.process import _WorkerLoop

__all__ = ["SocketTransport", "LocalCluster", "FrameError", "encode_frame",
           "decode_frame", "serve_worker_host", "MAGIC", "MAGIC2", "CODECS"]

clock = time.monotonic

# -- frame protocol -----------------------------------------------------------

MAGIC = b"LRF1"
_VERSION = 1
MAGIC2 = b"LRF2"
_VERSION2 = 2
#: LRF2 payload prologue: meta_len(4) nbuf(2), then nbuf u64 buffer lens
_V2HEAD = struct.Struct("!IH")
_V2LEN = struct.Struct("!Q")
#: header: magic(4) version(1) codec(1) reserved(2) raw_len(4) wire_len(4)
_HEADER = struct.Struct("!4sBBHII")
HEADER_SIZE = _HEADER.size

CODEC_NONE, CODEC_ZLIB, CODEC_LZ4 = 0, 1, 2
CODECS = {"none": CODEC_NONE, "zlib": CODEC_ZLIB, "lz4": CODEC_LZ4}

#: "auto" mode compresses only payloads at least this large: the typical
#: control message (purge/ping/stats) is tens of bytes and would pay the
#: codec call for nothing, while coded blocks and result matrices of any
#: interesting size clear it easily.
COMPRESS_MIN_BYTES = 4096

try:                               # optional: the container may lack lz4
    import lz4.frame as _lz4
except ImportError:                # pragma: no cover - depends on image
    _lz4 = None


def have_lz4() -> bool:
    """True when the optional lz4 codec is importable."""
    return _lz4 is not None


class FrameError(Exception):
    """A frame failed to parse: bad magic/version/codec, truncation, or a
    decompressed-size mismatch.  Deliberately distinct from the connection
    errors (EOFError/OSError) that mean the peer went away."""


def _compress(payload: bytes, codec: int) -> bytes:
    if codec == CODEC_ZLIB:
        return zlib.compress(payload, 1)
    if codec == CODEC_LZ4:
        return _lz4.compress(payload)
    return payload


def _decompress(payload: bytes, codec: int) -> bytes:
    if codec == CODEC_ZLIB:
        return zlib.decompress(payload)
    if codec == CODEC_LZ4:
        if _lz4 is None:
            raise FrameError("frame compressed with lz4 but lz4 is not "
                             "installed on this side")
        return _lz4.decompress(payload)
    return payload


def _pick_codec(compress: str, raw_len: int) -> int:
    """Codec id for ``compress`` mode and a payload of ``raw_len``."""
    if compress == "zlib":
        return CODEC_ZLIB
    if compress == "lz4":
        if _lz4 is None:
            raise ValueError("compress='lz4' but lz4 is not installed; "
                             "use 'zlib' or 'auto'")
        return CODEC_LZ4
    if compress == "auto" and raw_len >= COMPRESS_MIN_BYTES:
        return CODEC_LZ4 if _lz4 is not None else CODEC_ZLIB
    if compress not in ("auto", "none"):
        raise ValueError(f"unknown compress mode {compress!r}")
    return CODEC_NONE


def _compress_parts(parts: list, codec: int) -> bytes:
    """Compress a multi-part payload without first joining it.

    The zlib path streams each part through one ``compressobj`` — the
    ndarray memoryviews feed the compressor directly, so the only copy
    of the block bytes is the compressed output itself.  (lz4's one-shot
    API wants a single buffer; it pays the join.)
    """
    if codec == CODEC_ZLIB:
        z = zlib.compressobj(1)
        out = [z.compress(p) for p in parts]
        out.append(z.flush())
        return b"".join(out)
    return _compress(b"".join(parts), codec)


def _encode_v2_parts(obj) -> tuple:
    """LRF2 payload for ``obj``: ``(parts, inband_len, oob_len)``.

    ``parts`` is a flat list of buffers (prologue + meta pickle + raw
    ndarray buffers); ``inband_len`` is what went *through* the pickler
    (prologue + meta), ``oob_len`` the ndarray bytes that did not.
    """
    bufs: list[pickle.PickleBuffer] = []
    meta = pickle.dumps(obj, protocol=5, buffer_callback=bufs.append)
    raws = [b.raw() for b in bufs]
    head = (_V2HEAD.pack(len(meta), len(raws))
            + b"".join(_V2LEN.pack(r.nbytes) for r in raws))
    parts = [head, meta]
    parts.extend(raws)
    return parts, len(head) + len(meta), sum(r.nbytes for r in raws)


def _decode_v2_payload(payload: bytes):
    """Rebuild the message from a (decompressed) LRF2 payload.

    ndarrays come back as zero-copy views over ``payload``'s memory
    (read-only is fine: results are only ever read by fusion).
    """
    try:
        mv = memoryview(payload)
        meta_len, nbuf = _V2HEAD.unpack_from(mv, 0)
        off = _V2HEAD.size
        lens = [_V2LEN.unpack_from(mv, off + i * _V2LEN.size)[0]
                for i in range(nbuf)]
        off += nbuf * _V2LEN.size
        meta = mv[off:off + meta_len]
        if len(meta) != meta_len:
            raise FrameError("LRF2 payload truncated inside meta")
        off += meta_len
        buffers = []
        for n in lens:
            buf = mv[off:off + n]
            if len(buf) != n:
                raise FrameError("LRF2 payload truncated inside buffers")
            buffers.append(buf)
            off += n
        return pickle.loads(meta, buffers=buffers)
    except FrameError:
        raise
    except Exception as e:
        raise FrameError(f"corrupt LRF2 payload: {e}") from None


def _encode_frame_info(obj, compress: str = "auto", proto: int = 1
                       ) -> tuple:
    """Encode ``obj``; returns ``(parts, raw_len, inband, oob)``.

    ``parts[0]`` is the 16-byte header; the rest is the (possibly
    compressed) payload.  ``inband``/``oob`` split the raw payload into
    pickled bytes vs out-of-band ndarray buffer bytes (LRF1 is all
    in-band by construction).
    """
    if proto not in (1, 2):
        raise ValueError(f"unknown frame proto {proto} (LRF1 or LRF2)")
    if proto == 2:
        magic, version = MAGIC2, _VERSION2
        payload_parts, inband, oob = _encode_v2_parts(obj)
        raw_len = inband + oob
    else:
        magic, version = MAGIC, _VERSION
        payload_parts = [pickle.dumps(obj, protocol=5)]
        raw_len = inband = len(payload_parts[0])
        oob = 0
    codec = _pick_codec(compress, raw_len)
    if codec != CODEC_NONE:
        packed = _compress_parts(payload_parts, codec)
        if len(packed) < raw_len:
            payload_parts = [packed]
        else:                      # incompressible: ship raw, save the CPU
            codec = CODEC_NONE
    wire_len = sum(len(p) for p in payload_parts)
    header = _HEADER.pack(magic, version, codec, 0, raw_len, wire_len)
    return [header] + payload_parts, raw_len, inband, oob


def encode_frame(obj, compress: str = "auto", proto: int = 1) -> bytes:
    """Serialize ``obj`` into one self-describing frame.

    ``compress`` is a :data:`~repro.runtime.tasks.COMPRESS_MODES` key:
    ``auto`` compresses payloads >= :data:`COMPRESS_MIN_BYTES` with lz4
    when available (fast path) else zlib, and keeps the compressed form
    only if it is actually smaller; ``zlib``/``lz4`` force the codec;
    ``none`` disables.  ``proto`` selects the frame protocol: 1 = LRF1
    (one pickle), 2 = LRF2 (pickle-free ndarray buffers).
    """
    parts, _, _, _ = _encode_frame_info(obj, compress, proto)
    return b"".join(parts)


def decode_frame(buf: bytes) -> tuple:
    """Parse one frame from ``buf``; returns ``(obj, consumed_bytes)``.

    Raises :class:`FrameError` on a short/garbage header, an unknown
    version or codec, a truncated payload, or a decompressed size that
    does not match the header's ``raw_len``.
    """
    if len(buf) < HEADER_SIZE:
        raise FrameError(f"truncated header: {len(buf)} < {HEADER_SIZE} "
                         f"bytes")
    magic, version, codec, _, raw_len, wire_len = _HEADER.unpack(
        buf[:HEADER_SIZE])
    if magic not in (MAGIC, MAGIC2):
        raise FrameError(f"bad magic {magic!r} (expected {MAGIC!r} or "
                         f"{MAGIC2!r})")
    if version != (_VERSION2 if magic == MAGIC2 else _VERSION):
        raise FrameError(f"unsupported frame version {version} for "
                         f"magic {magic!r}")
    if codec not in (CODEC_NONE, CODEC_ZLIB, CODEC_LZ4):
        raise FrameError(f"unknown codec {codec}")
    end = HEADER_SIZE + wire_len
    if len(buf) < end:
        raise FrameError(f"truncated payload: have {len(buf) - HEADER_SIZE} "
                         f"of {wire_len} bytes")
    try:
        payload = _decompress(bytes(buf[HEADER_SIZE:end]), codec)
    except FrameError:
        raise
    except Exception as e:
        # zlib raises zlib.error but lz4 raises RuntimeError: either way
        # corruption must surface as FrameError so the receiver re-dials
        # instead of dying on an unexpected exception type
        raise FrameError(f"corrupt compressed payload: {e}") from None
    if len(payload) != raw_len:
        raise FrameError(f"decompressed size {len(payload)} != header "
                         f"raw_len {raw_len}")
    if magic == MAGIC2:
        return _decode_v2_payload(payload), end
    try:
        obj = pickle.loads(payload)
    except Exception as e:
        raise FrameError(f"corrupt pickle payload: {e}") from None
    return obj, end


# -- socket plumbing ----------------------------------------------------------

def _read_exact(sock: socket.socket, n: int) -> bytes:
    """Blocking read of exactly ``n`` bytes; EOFError on a closed peer.

    Never over-reads, so ``select`` on the raw socket stays an accurate
    "a frame (or part of one) is pending" signal — the property the
    worker's cancellable delay wait relies on.
    """
    chunks = []
    got = 0
    while got < n:
        chunk = sock.recv(n - got)
        if not chunk:
            raise EOFError("connection closed by peer")
        chunks.append(chunk)
        got += len(chunk)
    return b"".join(chunks)


class _SockConn:
    """Duck-type of ``multiprocessing.Connection`` over a TCP socket.

    Provides exactly the surface the process backend's worker loop uses
    (``poll(timeout)`` / ``recv()`` / ``send(obj)`` / ``close()``), so
    :class:`~repro.runtime.transport.process._WorkerLoop` runs unmodified
    over it.  Single-reader/single-writer per side; byte counters feed the
    transport's ``wire_stats``.
    """

    def __init__(self, sock: socket.socket, compress: str = "auto"):
        self.sock = sock
        self.compress = compress
        #: Negotiated frame protocol for *outbound* frames (1 until the
        #: hello exchange agrees on something newer); inbound frames are
        #: always self-describing, so both magics decode regardless.
        self.proto = 1
        self.frames_in = 0
        self.frames_out = 0
        self.raw_bytes_in = 0
        self.wire_bytes_in = 0
        self.raw_bytes_out = 0
        self.wire_bytes_out = 0
        self.inband_bytes_out = 0    # raw bytes that crossed the pickler
        self.oob_bytes_out = 0       # raw bytes lifted out of it (LRF2)

    def poll(self, timeout: float = 0.0) -> bool:
        try:
            ready, _, _ = select.select([self.sock], [], [], timeout)
        except (OSError, ValueError):   # closed underneath us
            return True                 # let recv() raise the real error
        return bool(ready)

    def recv(self):
        header = _read_exact(self.sock, HEADER_SIZE)
        magic, version, codec, _, raw_len, wire_len = _HEADER.unpack(header)
        if not ((magic == MAGIC and version == _VERSION)
                or (magic == MAGIC2 and version == _VERSION2)):
            raise FrameError(f"bad frame header from peer: magic={magic!r} "
                             f"version={version}")
        payload = _read_exact(self.sock, wire_len)
        obj, _ = decode_frame(header + payload)
        self.frames_in += 1
        self.raw_bytes_in += raw_len
        self.wire_bytes_in += wire_len + HEADER_SIZE
        return obj

    def send(self, obj) -> None:
        parts, raw_len, inband, oob = _encode_frame_info(
            obj, self.compress, self.proto)
        # scatter-gather write: LRF2's ndarray buffers go to the kernel
        # straight from the arrays, never joined into one frame buffer
        vecs = [memoryview(p) for p in parts if len(p)]
        while vecs:
            sent = self.sock.sendmsg(vecs)
            while vecs and sent >= len(vecs[0]):
                sent -= len(vecs[0])
                vecs.pop(0)
            if sent and vecs:
                vecs[0] = vecs[0][sent:]
        self.frames_out += 1
        self.wire_bytes_out += sum(len(p) for p in parts)
        self.raw_bytes_out += raw_len
        self.inband_bytes_out += inband
        self.oob_bytes_out += oob

    def close(self) -> None:
        try:
            self.sock.close()
        except OSError:       # pragma: no cover - already torn down
            pass


# -- worker host (remote side) ------------------------------------------------

class _SocketWorkerLoop(_WorkerLoop):
    """The process backend's worker loop, pumping a socket connection.

    Adds only the heartbeat reply; rounds, purge watermarks, and
    drain-or-purge stops are handled by the base class, so the multi-host
    path cannot diverge from the single-host one.
    """

    def _handle(self, msg: tuple) -> None:
        if msg[0] == "ping":
            # echo the master's send instant and stamp our own monotonic
            # clock: the master estimates this host's clock offset as
            # t_worker - (t_send + t_recv)/2, error bounded by rtt/2.
            # A bare ("ping",) (older master) gets the bare legacy pong.
            if len(msg) > 1:
                self.conn.send(("pong", msg[1], clock()))
            else:
                self.conn.send(("pong",))
        else:
            super()._handle(msg)


class _ConnResults:
    """Adapter: the worker loop's result "queue" is the connection."""

    __slots__ = ("_conn",)

    def __init__(self, conn: _SockConn):
        self._conn = conn

    def put(self, item) -> None:
        self._conn.send(item)


def serve_worker_host(port: int = 0, host: str = "127.0.0.1", *,
                      once: bool = False,
                      announce: Callable[[str], None] = print,
                      metrics_port: Optional[int] = None) -> None:
    """Run one worker host: listen, serve master sessions until killed.

    A *session* starts with a ``("hello", worker_id, cfg, session_id,
    watermark)`` frame and ends with a ``stop`` (orderly: final stats are
    sent, state is discarded) or a dropped connection (crash/sever: state
    is *kept* so the master can reconnect and resume — its hello carries
    the same ``session_id`` and the authoritative purge watermark).  A
    hello with a new ``session_id`` always starts fresh, so a master that
    never said goodbye cannot leak its watermark or counters into the
    next run.

    ``port=0`` binds an ephemeral port; the chosen one is announced as
    ``LISTENING <host> <port>`` (the line :class:`LocalCluster` parses).
    ``once`` exits after the first orderly session — CI hygiene.

    ``metrics_port`` (``0`` = ephemeral) additionally serves this host's
    live counters (busy seconds, tasks done/purged, sessions served) as a
    Prometheus text endpoint on ``/metrics``, announced as
    ``METRICS <host> <port>`` — scrapeable mid-run, surviving between
    sessions with the last session's totals.
    """
    srv = socket.create_server((host, port))
    srv.listen(1)
    bound_port = srv.getsockname()[1]
    announce(f"LISTENING {host} {bound_port}")

    state = {"runner": None, "sessions": 0}
    metrics_server = None
    if metrics_port is not None:
        def _render() -> str:
            return telemetry.worker_metrics_text(
                state["runner"], sessions=state["sessions"])
        metrics_server, bound_metrics = telemetry.serve_metrics(
            _render, metrics_port, host)
        announce(f"METRICS {host} {bound_metrics}")

    session_id = None          # the session a reconnect may resume
    runner = None
    watermark = -1

    try:
        while True:
            try:
                raw_sock, _addr = srv.accept()
            except (KeyboardInterrupt, OSError):
                return
            raw_sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            conn = _SockConn(raw_sock)
            try:
                hello = conn.recv()
                if not (isinstance(hello, tuple) and hello[0] == "hello"):
                    raise FrameError(f"expected hello, got {hello!r}")
                _, worker_id, cfg, sid, master_watermark, *rest = hello
                conn.compress = cfg.compress
                if rest:
                    # frame-protocol offer (6-element hello): agree on
                    # the newest protocol both sides speak.  The ack is
                    # sent *before* switching, so it is always readable
                    # by the offering master whatever was agreed.
                    agreed = max(1, min(2, int(rest[0])))
                    conn.send(("helloack", agreed))
                    conn.proto = agreed
                loop = _SocketWorkerLoop(worker_id, cfg, conn,
                                         _ConnResults(conn))
                if sid == session_id and runner is not None:
                    # same master reconnecting: keep its counters and
                    # watermark, pointing the kept runner's emit at the
                    # fresh connection
                    loop.runner = runner
                    runner._emit = loop._emit
                    loop.watermark = max(watermark, master_watermark)
                else:
                    # a new master (or one that lost its old host state):
                    # the loop's own fresh runner, master's watermark only
                    loop.watermark = master_watermark
                    state["sessions"] += 1
                runner = loop.runner
                state["runner"] = runner
                session_id = sid
                try:
                    loop.run()
                finally:
                    watermark = loop.watermark
                # run() returned: orderly stop — stats are already sent;
                # discard session state so the next hello starts clean
                session_id = None
                runner = None
                watermark = -1
                if once:
                    return
            except (EOFError, ConnectionError, FrameError, OSError):
                # dropped/garbled connection: keep session state for a
                # resuming master; anything queued died with the
                # connection and the master's purge watermark will cover
                # it
                pass
            except KeyboardInterrupt:
                return
            finally:
                conn.close()
    finally:
        if metrics_server is not None:
            metrics_server.shutdown()
        srv.close()


# -- master side --------------------------------------------------------------

class _WorkerLink:
    """Master-side state for one remote worker: socket, receiver thread,
    liveness, reconnect."""

    def __init__(self, transport: "SocketTransport", worker_id: int,
                 addr: str):
        self.transport = transport
        self.worker_id = worker_id
        host, _, port = addr.rpartition(":")
        self.host, self.port = host, int(port)
        self.conn: Optional[_SockConn] = None
        self.lock = threading.RLock()    # serializes send + reconnect
        self.gen = 0                     # bumped on every (re)connect
        self.last_seen = clock()
        self.dead: Optional[str] = None  # reason, once declared dead
        self.got_stats = threading.Event()
        self._closed_conn_stats = np.zeros(8, dtype=np.int64)
        # clock alignment: offset = worker_clock - master_clock, taken
        # from the minimum-RTT ping/pong exchange so the error is bounded
        # by rtt/2 (<= clock_rtt); refreshed by every heartbeat pong
        self.clock_offset = 0.0
        self.clock_rtt = float("inf")
        self.receiver = threading.Thread(
            target=self._receive, daemon=True,
            name=f"runtime-socket-recv-{worker_id}")

    # -- connection management ------------------------------------------------
    def _dial(self, timeout: float) -> _SockConn:
        deadline = clock() + timeout
        last_err: Exception = ConnectionError("never attempted")
        while clock() < deadline:
            try:
                sock = socket.create_connection(
                    (self.host, self.port),
                    timeout=max(0.1, deadline - clock()))
                # create_connection's timeout sticks to the socket: left
                # in place it turns every idle stretch on the receiver
                # into a spurious "recv: timed out" re-dial that kills
                # the in-flight rounds of the connection it replaces.
                # The dial bound must not outlive the dial.
                sock.settimeout(None)
                sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
                return _SockConn(sock, self.transport._cfg.compress)
            except OSError as e:
                last_err = e
                time.sleep(0.05)
        raise ConnectionError(
            f"worker {self.worker_id} at {self.host}:{self.port} "
            f"unreachable within {timeout}s: {last_err}")

    def connect(self, timeout: float) -> None:
        """Initial dial + hello (start path; raises on failure)."""
        with self.lock:
            self.conn = self._dial(timeout)
            self._hello()
            self.gen += 1
            self.last_seen = clock()

    def _hello(self) -> None:
        """Session hello + frame-protocol negotiation.

        ``cfg.frame_proto`` 0 (auto) or 2 offers LRF2 in a 6-element
        hello and *requires* the worker's ``helloack`` (sent as LRF1, so
        it is readable before any switch): a worker host that predates
        the offer never answers — its parse of the longer hello fails —
        and the bounded wait turns that into a clean ``ConnectionError``
        instead of a garbled-stream death mid-run.  ``frame_proto=1``
        sends the legacy 5-element hello: no ack, pure LRF1, the
        mixed-version escape hatch for one release.
        """
        t = self.transport
        offer = t._cfg.frame_proto or 2
        if offer <= 1:
            self.conn.send(("hello", self.worker_id, t._cfg, t._session,
                            t._watermark))
            self.conn.proto = 1
            return
        self.conn.send(("hello", self.worker_id, t._cfg, t._session,
                        t._watermark, offer))
        if not self.conn.poll(5.0):
            raise ConnectionError(
                f"worker {self.worker_id} at {self.host}:{self.port} did "
                f"not acknowledge the LRF{offer} offer within 5s — the "
                f"host likely predates frame protocol {offer}; upgrade "
                f"it or run with frame_proto=1")
        try:
            ack = self.conn.recv()
        except (EOFError, OSError, FrameError) as e:
            raise ConnectionError(
                f"worker {self.worker_id} at {self.host}:{self.port} "
                f"closed or garbled the hello exchange ({e}) — mixed "
                f"frame-protocol versions? upgrade the host or run with "
                f"frame_proto=1") from None
        if not (isinstance(ack, tuple) and ack[0] == "helloack"
                and int(ack[1]) in (1, 2)):
            raise ConnectionError(
                f"worker {self.worker_id} at {self.host}:{self.port} "
                f"answered the hello with {ack!r}, not a helloack")
        self.conn.proto = int(ack[1])

    def sync_clock(self, samples: int = 5) -> None:
        """Estimate this link's clock offset with synchronous ping/pong
        roundtrips (start path, before the receiver thread runs).

        Keeps the estimate from the minimum-RTT exchange:
        ``offset = t_worker - (t_send + t_recv)/2`` — symmetric-path
        assumption, so the alignment error is at most ``rtt/2``.
        Heartbeat pongs keep refreshing it for the rest of the run.
        """
        with self.lock:
            conn = self.conn
            if conn is None or self.dead is not None:
                return
            for _ in range(samples):
                try:
                    t_send = clock()
                    conn.send(("ping", t_send))
                    msg = conn.recv()
                    t_recv = clock()
                except (OSError, ConnectionError, EOFError, FrameError):
                    return          # liveness machinery will handle it
                if msg[0] != "pong" or len(msg) < 3:
                    continue
                rtt = t_recv - t_send
                if rtt < self.clock_rtt:
                    self.clock_rtt = rtt
                    self.clock_offset = msg[2] - 0.5 * (t_send + t_recv)
            self.last_seen = clock()

    def observe_pong(self, t_send: float, t_worker: float,
                     t_recv: float) -> float:
        """Fold one timestamped pong into the offset estimate; returns
        the exchange's RTT."""
        rtt = t_recv - t_send
        if 0.0 <= rtt < self.clock_rtt:
            self.clock_rtt = rtt
            self.clock_offset = t_worker - 0.5 * (t_send + t_recv)
        return rtt

    def _reconnect_or_fail(self, why: str) -> bool:
        """One bounded reconnect pass; returns True if the link is back.

        Runs under ``lock``.  The re-sent hello carries the session id
        and the current purge watermark, so a worker that kept state
        resumes exactly, and one that lost it starts clean *with the
        watermark already applied* — either way no purged round can
        execute after the reconnect.
        """
        if self.dead or self.transport._shutting_down:
            return False
        old = self.conn
        for attempt in range(self.transport.reconnect_attempts):
            try:
                self.conn = self._dial(self.transport.reconnect_timeout)
                self._hello()
                self.gen += 1
                self.last_seen = clock()
                if old is not None and old is not self.conn:
                    self._fold_stats(old)
                    old.close()
                tr = self.transport._tracer
                if tr is not None:
                    tr.emit(telemetry.RECONNECT, clock(),
                            worker=self.worker_id, label=why)
                return True
            except (OSError, ConnectionError, EOFError):
                # exponential backoff with jitter: a whole fleet re-dialing
                # a restarted host in lockstep (every link dropped at the
                # same instant) must not thundering-herd it
                delay = min(self.transport.reconnect_backoff_cap,
                            self.transport.reconnect_backoff * (2 ** attempt))
                time.sleep(delay * random.uniform(0.5, 1.5))
        self.mark_dead(f"connection lost ({why}); reconnect failed after "
                       f"{self.transport.reconnect_attempts} attempts")
        return False

    def mark_dead(self, reason: str) -> None:
        with self.lock:
            if self.dead is None:
                self.dead = reason
                tr = self.transport._tracer
                if tr is not None and reason != "shutdown":
                    tr.emit(telemetry.DEAD, clock(),
                            worker=self.worker_id, label=reason)
            if self.conn is not None:
                self.conn.close()

    def _fold_stats(self, conn: _SockConn) -> None:
        """Accumulate a retiring connection's byte counters (reconnects
        must not zero the run's wire totals)."""
        self._closed_conn_stats += (
            conn.frames_out, conn.raw_bytes_out, conn.wire_bytes_out,
            conn.frames_in, conn.raw_bytes_in, conn.wire_bytes_in,
            conn.inband_bytes_out, conn.oob_bytes_out)

    def stats_tuple(self) -> np.ndarray:
        """(frames_out, raw_out, wire_out, frames_in, raw_in, wire_in,
        inband_out, oob_out) over every connection this link has had."""
        with self.lock:
            total = self._closed_conn_stats.copy()
            conn = self.conn
            if conn is not None:
                total += (conn.frames_out, conn.raw_bytes_out,
                          conn.wire_bytes_out, conn.frames_in,
                          conn.raw_bytes_in, conn.wire_bytes_in,
                          conn.inband_bytes_out, conn.oob_bytes_out)
        return total

    # -- traffic --------------------------------------------------------------
    def send(self, msg: tuple) -> bool:
        """Send one frame; transparently reconnects once on a dropped
        connection.  Returns False (dropping the message) only for a
        dead link — the caller's next ``assert_alive`` reports it."""
        with self.lock:
            if self.dead is not None or self.conn is None:
                return False
            try:
                self.conn.send(msg)
                return True
            except (OSError, ConnectionError) as e:
                if self._reconnect_or_fail(f"send: {e}"):
                    try:
                        self.conn.send(msg)
                        return True
                    except (OSError, ConnectionError) as e2:
                        self.mark_dead(f"send failed twice: {e2}")
            return False

    def _receive(self) -> None:
        """Receiver loop: results/stats/pongs, EOF -> reconnect-or-fail."""
        t = self.transport
        while True:
            with self.lock:
                conn, gen = self.conn, self.gen
                if self.dead is not None:
                    return
            if conn is None:
                return
            try:
                msg = conn.recv()
            except FrameError as e:
                # garbled stream: cannot resynchronize mid-connection —
                # drop it and re-dial for a clean frame boundary
                with self.lock:
                    if t._shutting_down or self.dead is not None:
                        return
                    if self.gen == gen and not self._reconnect_or_fail(
                            f"garbled frame: {e}"):
                        return
                continue
            except (EOFError, OSError, ConnectionError) as e:
                with self.lock:
                    if t._shutting_down or self.dead is not None:
                        return
                    if self.gen != gen:   # send path already reconnected
                        continue
                    if not self._reconnect_or_fail(f"recv: {e}"):
                        return
                continue
            self.last_seen = clock()
            kind = msg[0]
            if kind == "result":
                wire, busy = msg[1], msg[2]
                result = TaskResult.from_wire(wire)
                off = self.clock_offset
                if off:
                    # rebase the remote finished_at onto the master's
                    # clock so fusion timestamps (fused_at, delay tables)
                    # stay comparable on genuinely multi-host clusters
                    result = dataclasses.replace(
                        result, finished_at=result.finished_at - off)
                with t._stats_lock:
                    t._busy[result.worker_id] = busy
                if len(msg) > 3 and t._tracer is not None:
                    # piggybacked worker events, rebased into master time
                    t._tracer.ingest(msg[3], shift=-off)
                t._sink(result)
            elif kind == "stats":
                worker_id, busy, done, purged = msg[1:5]
                with t._stats_lock:
                    t._busy[worker_id] = busy
                    t._done += done
                    t._purged += purged
                if len(msg) > 5 and t._tracer is not None:
                    t._tracer.ingest(msg[5], shift=-self.clock_offset)
                self.got_stats.set()
            elif kind == "pong":
                if len(msg) >= 3:   # timestamped: refresh clock estimate
                    rtt = self.observe_pong(msg[1], msg[2], self.last_seen)
                    if t._tracer is not None:
                        t._tracer.emit(telemetry.HEARTBEAT, self.last_seen,
                                       worker=self.worker_id, value=rtt)
            # unknown frames are ignored: forward compatibility


class SocketTransport(WorkerTransport):
    """``cfg.num_workers`` remote worker hosts over TCP (one per
    ``cfg.hosts`` entry), length-prefixed compressed frames, heartbeat
    liveness, reconnect-or-fail."""

    name = "socket"

    def __init__(self, cfg: RuntimeConfig,
                 sink: Callable[[TaskResult], None],
                 rng: Optional[np.random.Generator] = None,
                 tracer=None, *,
                 connect_timeout: float = 30.0,
                 heartbeat_interval: Optional[float] = None,
                 heartbeat_timeout: Optional[float] = None,
                 reconnect_attempts: Optional[int] = None,
                 reconnect_timeout: float = 1.0,
                 reconnect_backoff: Optional[float] = None,
                 reconnect_backoff_cap: Optional[float] = None):
        super().__init__(cfg, sink, rng, tracer)
        if cfg.compress == "lz4" and not have_lz4():
            raise ValueError("compress='lz4' but lz4 is not installed; "
                             "use 'zlib' or 'auto'")
        # liveness knobs default from the RuntimeConfig (runctl-settable);
        # explicit kwargs still override for tests that tighten one knob
        def _knob(kwarg, cfg_value):
            return cfg_value if kwarg is None else kwarg
        self.connect_timeout = connect_timeout
        self.heartbeat_interval = _knob(heartbeat_interval,
                                        cfg.heartbeat_interval)
        self.heartbeat_timeout = _knob(heartbeat_timeout,
                                       cfg.heartbeat_timeout)
        self.reconnect_attempts = _knob(reconnect_attempts,
                                        cfg.reconnect_attempts)
        self.reconnect_timeout = reconnect_timeout
        self.reconnect_backoff = _knob(reconnect_backoff,
                                       cfg.reconnect_backoff)
        self.reconnect_backoff_cap = _knob(reconnect_backoff_cap,
                                           cfg.reconnect_backoff_cap)
        self._retired_link_stats = np.zeros(8, dtype=np.int64)
        self._session = uuid.uuid4().hex
        self._watermark = -1          # highest purged dispatch seq
        self._busy = np.zeros(cfg.num_workers)
        self._done = 0
        self._purged = 0
        self._stats_lock = threading.Lock()
        self._started = False
        self._shutting_down = False
        self._stop_heartbeat = threading.Event()
        self._heartbeat = threading.Thread(
            target=self._heartbeat_loop, daemon=True,
            name="runtime-socket-heartbeat")
        self.links = [_WorkerLink(self, p, addr)
                      for p, addr in enumerate(cfg.hosts)]

    # -- lifecycle ------------------------------------------------------------
    def start(self) -> None:
        for link in self.links:
            link.connect(self.connect_timeout)
        for link in self.links:
            # synchronous roundtrips before the receiver competes for the
            # connection: every link starts with a bounded-error clock
            # offset, refreshed by heartbeat pongs for the rest of the run
            link.sync_clock()
        for link in self.links:
            link.receiver.start()
        self._heartbeat.start()
        self._started = True

    def shutdown(self, timeout: float = 10.0, *, drain: bool = False
                 ) -> None:
        self._shutting_down = True
        self._stop_heartbeat.set()
        if not self._started:
            for link in self.links:
                if link.conn is not None:
                    link.conn.close()
            return
        live = [ln for ln in self.links if ln.dead is None]
        for link in live:
            link.send(("stop", drain))
        deadline = clock() + timeout
        missing = []
        for link in live:
            if not link.got_stats.wait(max(0.0, deadline - clock())):
                missing.append(f"worker-{link.worker_id}@"
                               f"{link.host}:{link.port}")
        for link in self.links:
            link.mark_dead("shutdown")    # closes conns -> receivers exit
        self._heartbeat.join(timeout=timeout)
        leaked = []
        for link in self.links:
            if link.receiver.is_alive():
                link.receiver.join(timeout=timeout)
                if link.receiver.is_alive():
                    leaked.append(link.receiver.name)
        if leaked:
            raise RuntimeError(
                f"socket transport receiver thread(s) failed to stop "
                f"within {timeout}s: {leaked}")
        if missing:
            raise RuntimeError(
                f"worker host(s) never returned final stats within "
                f"{timeout}s: {missing}")

    # -- liveness -------------------------------------------------------------
    def _heartbeat_loop(self) -> None:
        while not self._stop_heartbeat.wait(self.heartbeat_interval):
            now = clock()
            for link in self.links:
                if link.dead is not None:
                    continue
                if now - link.last_seen > self.heartbeat_timeout:
                    link.mark_dead(
                        f"no frame for {now - link.last_seen:.1f}s "
                        f"(heartbeat timeout {self.heartbeat_timeout}s)")
                    continue
                link.send(("ping", clock()))

    def dead_worker_map(self) -> dict[int, str]:
        if not self._started or self._shutting_down:
            return {}
        return {ln.worker_id: f"socket-worker-{ln.worker_id}@"
                              f"{ln.host}:{ln.port} ({ln.dead})"
                for ln in self.links if ln.dead is not None}

    def _quarantine_worker(self, worker_id: int, reason: str) -> None:
        """Close the dead link (idempotent); its host may later come back
        through :meth:`try_readmit`'s fresh dial + hello resync."""
        self.links[worker_id].mark_dead(reason)

    def try_readmit(self) -> list[int]:
        """One quick re-dial pass over quarantined workers.

        A restarted (or revived) host accepts the dial; the fresh link's
        hello carries the run's session id and the authoritative purge
        watermark, so the host resumes (kept state) or starts clean with
        every purged round already dropped (lost state) — the same resync
        contract as a mid-run reconnect.  Unreachable hosts cost one
        short dial timeout each, so the caller rate-limits this.
        """
        readmitted = []
        for p in sorted(self.quarantined):
            old = self.links[p]
            link = _WorkerLink(self, p, f"{old.host}:{old.port}")
            try:
                link.connect(timeout=0.25)
            except (ConnectionError, OSError, EOFError, FrameError):
                if link.conn is not None:
                    link.conn.close()
                continue
            link.sync_clock(samples=2)
            link.receiver.start()
            # the retiring link's byte counters must survive replacement
            self._retired_link_stats += old.stats_tuple()
            old.mark_dead("superseded by readmitted link")
            self.links[p] = link
            self.quarantined.discard(p)
            readmitted.append(p)
        return readmitted

    # -- dispatch / purge -----------------------------------------------------
    def _send_slice(self, worker_id: int, ctx: RoundContext, first_task: int,
                    x: np.ndarray, y: np.ndarray,
                    delays: np.ndarray) -> None:
        wire = WireBatch(seq=ctx.seq, job_id=ctx.job_id,
                         round_idx=ctx.round_idx, first_task_id=first_task,
                         x=np.ascontiguousarray(x),
                         y=np.ascontiguousarray(y), delays=delays)
        # a dead worker's slice is dropped, not raised: redundancy may
        # still fuse the round, and assert_alive() reports the death at
        # the master's next liveness check either way
        self.links[worker_id].send(("round", wire))

    def _send_group(self, worker_id: int, seq: int, entries: list) -> None:
        levels = tuple(
            WireBatch(seq=seq, job_id=ctx.job_id, round_idx=ctx.round_idx,
                      first_task_id=lo, x=np.ascontiguousarray(x),
                      y=np.ascontiguousarray(y), delays=d)
            for ctx, lo, x, y, d in entries)
        group = WireGroup(seq=seq, job_id=levels[0].job_id,
                          base_round=levels[0].round_idx, levels=levels)
        self.links[worker_id].send(("group", group))

    def purge_round(self, ctx: RoundContext) -> None:
        ctx.purge()               # master side: fusion drops stale results
        if ctx.seq < 0:
            return                # never dispatched
        self._watermark = max(self._watermark, ctx.seq)
        for link in self.links:
            link.send(("purge", ctx.seq))

    def purge_level(self, ctx: RoundContext) -> None:
        ctx.purge()
        if ctx.seq < 0:
            return
        for link in self.links:
            link.send(("purgelvl", ctx.seq, ctx.round_idx))

    # -- occupancy / outcome counters ----------------------------------------
    @property
    def busy_seconds(self) -> np.ndarray:
        """Live values ride each result envelope (lagging a worker's
        current delay wait by one task); final stats make them exact."""
        with self._stats_lock:
            return self._busy.copy()

    @property
    def tasks_done(self) -> int:
        """Exact after shutdown (final stats); 0 while running."""
        with self._stats_lock:
            return self._done

    @property
    def tasks_purged(self) -> int:
        """Exact after shutdown (final stats); 0 while running."""
        with self._stats_lock:
            return self._purged

    @property
    def clock_sync(self) -> list:
        """Per-link clock alignment: ``{worker, host, offset_s, rtt_s}``.

        ``offset_s`` is the estimated ``worker_clock - master_clock``
        from the minimum-RTT ping/pong exchange; the estimation error is
        bounded by ``rtt_s`` (strictly, rtt/2 under symmetric paths).
        ``rtt_s`` is None only if a link never completed a timestamped
        exchange (dead before start finished).
        """
        return [{"worker": ln.worker_id,
                 "host": f"{ln.host}:{ln.port}",
                 "offset_s": ln.clock_offset,
                 "rtt_s": (ln.clock_rtt
                           if ln.clock_rtt != float("inf") else None)}
                for ln in self.links]

    @property
    def wire_stats(self) -> dict:
        """Aggregate frame/byte counters over all links.

        ``result_raw_bytes`` / ``result_wire_bytes`` are the result-path
        totals (worker -> master, pickles vs on-the-wire after
        compression); ``compression_ratio`` is raw/wire on that path
        (1.0 = incompressible or compression off).
        """
        total = self._retired_link_stats.copy()
        for link in self.links:
            total += link.stats_tuple()
        (frames_out, raw_out, bytes_out, frames_in, raw_in, wire_in,
         inband_out, oob_out) = (int(x) for x in total)
        protos = {link.conn.proto for link in self.links
                  if link.conn is not None}
        return {
            "transport": "socket",
            "frames_sent": frames_out,
            "dispatch_raw_bytes": raw_out,
            "dispatch_wire_bytes": bytes_out,
            # the zero-copy ledger: dispatch_copied_bytes crossed the
            # pickler (a serialization copy), dispatch_oob_bytes were
            # LRF2 out-of-band buffers shipped straight from the arrays
            "dispatch_copied_bytes": inband_out,
            "dispatch_oob_bytes": oob_out,
            "frame_proto": max(protos) if protos else 1,
            "frames_received": frames_in,
            "result_raw_bytes": raw_in,
            "result_wire_bytes": wire_in,
            "compression_ratio": (raw_in / wire_in) if wire_in else 1.0,
            "compress": self._cfg.compress,
            "lz4_available": have_lz4(),
        }

    # -- test hook ------------------------------------------------------------
    def sever_for_test(self, worker_id: int) -> None:
        """Forcibly drop one link's TCP connection (fault injection).

        Simulates a network sever: the socket is shut down under the
        link, so the next send/recv on it fails and the
        reconnect-or-fail path runs.  Test-only by contract.
        """
        conn = self.links[worker_id].conn
        if conn is not None:
            try:
                conn.sock.shutdown(socket.SHUT_RDWR)
            except OSError:       # pragma: no cover - already down
                pass


# -- localhost test/bench harness ---------------------------------------------

class LocalCluster:
    """Spawn ``n`` worker hosts on localhost ports (subprocesses).

    The conformance suite's stand-in for a real multi-host cluster: each
    worker is a genuine OS process running ``runctl serve-worker`` (via
    ``python -m repro.launch.worker_host``), reachable only over TCP —
    and killable with SIGKILL for fault-injection tests.

    Use as a context manager::

        with LocalCluster(3) as cluster:
            cfg = RuntimeConfig(mu=(..,)*3, backend="socket",
                                hosts=cluster.hosts)
            ...

    Hosts serve sessions in a loop, so one cluster backs any number of
    sequential runs.
    """

    def __init__(self, num_workers: int, *, host: str = "127.0.0.1",
                 spawn_timeout: float = 60.0):
        self.host = host
        self.spawn_timeout = spawn_timeout
        self.processes: list[subprocess.Popen] = []
        self.hosts: tuple[str, ...] = ()
        src_root = pathlib.Path(__file__).resolve().parents[3]
        self._env = dict(os.environ)
        self._env["PYTHONPATH"] = (str(src_root) + os.pathsep
                                   + self._env.get("PYTHONPATH", ""))
        ports = []
        try:
            for _ in range(num_workers):
                self.processes.append(self._spawn(0))
            deadline = clock() + spawn_timeout
            for proc in self.processes:
                ports.append(self._await_announce(proc, deadline))
            self.hosts = tuple(f"{host}:{p}" for p in ports)
        except BaseException:
            self.close()
            raise

    def _spawn(self, port: int) -> subprocess.Popen:
        return subprocess.Popen(
            [sys.executable, "-m", "repro.launch.worker_host",
             "--host", self.host, "--port", str(port)],
            stdout=subprocess.PIPE, stderr=subprocess.DEVNULL,
            env=self._env, text=True)

    def _await_announce(self, proc: subprocess.Popen,
                        deadline: float) -> int:
        """Parse one host's ``LISTENING`` line; returns its bound port.

        ``select`` before ``readline``: a wedged host that never prints
        its announce line must trip the timeout, not block forever (the
        announce is a single flushed line, so once readable it arrives
        whole).
        """
        ready, _, _ = select.select(
            [proc.stdout], [], [], max(0.0, deadline - clock()))
        if not ready:
            raise RuntimeError(
                f"worker host did not announce within "
                f"{self.spawn_timeout}s (exit code {proc.poll()})")
        line = proc.stdout.readline()
        if not line.startswith("LISTENING"):
            raise RuntimeError(
                f"worker host failed to start (said {line!r}, "
                f"exit code {proc.poll()})")
        return int(line.split()[2])

    def kill(self, index: int) -> None:
        """SIGKILL one worker host (the dead-node fault injection)."""
        self.processes[index].kill()
        self.processes[index].wait(timeout=10.0)

    def revive(self, index: int) -> None:
        """Restart a killed worker host on its original port.

        The chaos suite's recovery injection: the revived host is a fresh
        process with no session state, reachable at the same
        ``host:port`` the master was configured with — exactly the
        restart the transport's readmission path (re-dial + hello/
        watermark resync) exists for.
        """
        old = self.processes[index]
        if old.poll() is None:
            raise RuntimeError(f"worker host {index} is still alive; "
                               f"kill it before reviving")
        if old.stdout is not None:
            old.stdout.close()
        port = int(self.hosts[index].rpartition(":")[2])
        proc = self._spawn(port)
        try:
            self._await_announce(proc, clock() + self.spawn_timeout)
        except BaseException:
            proc.terminate()
            raise
        self.processes[index] = proc

    def close(self) -> None:
        for proc in self.processes:
            if proc.poll() is None:
                proc.terminate()
        for proc in self.processes:
            try:
                proc.wait(timeout=10.0)
            except subprocess.TimeoutExpired:   # pragma: no cover
                proc.kill()
                proc.wait(timeout=10.0)
            if proc.stdout is not None:
                proc.stdout.close()

    def __enter__(self) -> "LocalCluster":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
