"""The ``process`` backend: multiprocessing workers, zero-copy wire path.

True GIL-free parallel compute and *real* stragglers: each worker is an
OS process with a private duplex pipe.  The pipe is the *control* plane;
block payloads take the fastest path available:

* **Dispatch** — with the shared-memory arena enabled (``cfg.shm`` is
  ``auto``/``on``, see :mod:`repro.runtime.transport.shm`), the master
  copies each worker's ``kappa_p``-slice once into the worker's dispatch
  :class:`~repro.runtime.transport.shm.BlockArena` and sends only a
  descriptor (:class:`~repro.runtime.tasks.ArenaBatchRef`: arena offsets,
  shapes, dtypes, ``seq``) down the pipe; the worker maps the blocks as
  ndarray views.  With the arena off — or full — the slice falls back to
  the original pickled :class:`~repro.runtime.tasks.WireBatch` message,
  so exhaustion degrades to the pre-arena path, never an error.
* **Purge** — a ``("purge", seq)`` watermark message, exactly as before:
  workers drop every batch with ``seq <= watermark``, queued or
  in-flight (the delay wait polls the pipe).  The same watermark drives
  arena reclamation on both sides: the master recycles the purged
  round's dispatch slots immediately, the worker recycles result slots
  of rounds *strictly below* the watermark.  Slot reuse is safe because
  a purged round's results are *rejected by the fusion sink's dedupe*
  without ever being read (see
  :meth:`repro.runtime.fusion.FusionNode.post`), and a fused round —
  decoded one master-loop iteration behind its own purge — is always
  decoded (copied out) before the *next* purge is sent.
* **Results** — workers compute each product straight into a slot of
  their result arena (the ``out=`` path of the compute kernel) and send
  an :class:`~repro.runtime.tasks.ArenaResultRef` descriptor back on
  their *own pipe*; the master's drain thread hands fusion a zero-copy
  view of the slot.  Without an arena, results return as pickle
  protocol-5 envelopes with out-of-band ndarray buffers — one buffer
  copy on the pipe instead of a serialize/deserialize pair, and no
  shared ``mp.Queue`` (whose feeder thread added a scheduler hop and
  re-pickled every envelope at protocol 2... the default).  Either way
  the drain thread multiplexes all worker pipes with
  ``multiprocessing.connection.wait``.
* **Shutdown** — ``("stop", drain)`` then join, as before; afterwards
  the master unlinks every arena it created and sweeps ``/dev/shm`` for
  its own name prefix, so a SIGKILLed worker can never strand a segment
  (workers only ever *attach*; the master is the sole owner).

Timestamps: workers stamp ``finished_at`` with ``time.monotonic``, which
is CLOCK_MONOTONIC — system-wide, comparable across processes on Linux
(the platform this backend targets; the CI smoke job pins it).

Start method: ``fork`` where available (cheap, and child workers inherit
the already-imported numpy/BLAS state instead of paying a multi-second
re-import that would pollute the first measured rounds), else ``spawn``;
the worker entrypoint and all its arguments are picklable either way.
Forking a process whose parent has live JAX threads draws CPython's
fork-safety warning; the children here touch only numpy and pipe I/O
(never JAX), which is why the master still watches liveness
(:meth:`ProcessTransport.dead_worker_map` via
:meth:`~repro.runtime.transport.base.WorkerTransport.assert_alive`) so a
child lost for *any* reason fails the run promptly instead of hanging an
unbounded fusion wait.  Pass ``start_method="spawn"`` to opt out of fork
entirely.
"""

from __future__ import annotations

import collections
import multiprocessing
import multiprocessing.connection as _mpc
import pickle
import struct
import threading
from typing import Callable, Optional

import numpy as np

from repro.runtime import telemetry
from repro.runtime.tasks import (ArenaBatchRef, ArenaResultRef,
                                 RoundContext, RuntimeConfig, TaskResult,
                                 WireBatch, WireGroup)
from repro.runtime.transport import shm as shm_mod
from repro.runtime.transport.base import WorkerTransport
from repro.runtime.worker import (BatchRunner, WAIT_SLICE, clock,
                                  make_compute)

__all__ = ["ProcessTransport"]


# -- result envelopes: pickle protocol 5, buffers out of band -----------------
#
# Worker -> master messages are byte envelopes on the worker's own duplex
# pipe (sent with send_bytes / received with recv_bytes, so they never
# touch the Connection's pickler):
#
#     [meta_len u32][nbuf u16][nbuf x u64 buffer lens][meta][buffers...]
#
# ``meta`` is the message tuple pickled at protocol 5 with a
# buffer_callback, so every contiguous ndarray payload is lifted out as a
# raw buffer instead of being copied through the pickle stream; unpacking
# reconstructs the arrays as zero-copy views over the received bytes.

_ENV_HEAD = struct.Struct("!IH")
_ENV_LEN = struct.Struct("!Q")


def _pack_envelope(msg: tuple) -> bytes:
    bufs: list[pickle.PickleBuffer] = []
    meta = pickle.dumps(msg, protocol=5, buffer_callback=bufs.append)
    raws = [b.raw() for b in bufs]
    parts = [_ENV_HEAD.pack(len(meta), len(raws)),
             b"".join(_ENV_LEN.pack(r.nbytes) for r in raws), meta]
    parts.extend(raws)
    return b"".join(parts)


def _unpack_envelope(payload: bytes) -> tuple:
    mv = memoryview(payload)
    meta_len, nbuf = _ENV_HEAD.unpack_from(mv, 0)
    off = _ENV_HEAD.size
    lens = [_ENV_LEN.unpack_from(mv, off + i * _ENV_LEN.size)[0]
            for i in range(nbuf)]
    off += nbuf * _ENV_LEN.size
    meta = mv[off:off + meta_len]
    off += meta_len
    buffers = []
    for n in lens:
        buffers.append(mv[off:off + n])
        off += n
    return pickle.loads(meta, buffers=buffers)


class _PipeResults:
    """The worker loop's result "queue": byte envelopes on its pipe."""

    __slots__ = ("_conn",)

    def __init__(self, conn):
        self._conn = conn

    def put(self, msg: tuple) -> None:
        self._conn.send_bytes(_pack_envelope(msg))


class _PipeGuard:
    """Worker-side cancellation guard backed by the control pipe.

    ``cancelled`` is true once the batch's ``seq`` falls under the purge
    watermark (or a purge-mode stop arrived); ``wait`` blocks on the pipe
    so a purge message interrupts an injected delay the moment it lands.
    """

    __slots__ = ("_loop", "_seq")

    def __init__(self, loop: "_WorkerLoop", seq: int):
        self._loop = loop
        self._seq = seq

    def cancelled(self) -> bool:
        self._loop.pump(block=False)
        return self._seq <= self._loop.watermark or self._loop.purging

    def wait(self, delay: float) -> bool:
        loop = self._loop
        end = clock() + delay
        while True:
            remaining = end - clock()
            if remaining <= 0.0:
                return False
            # block on the pipe, not time.sleep: a purge (or stop) message
            # wakes this worker immediately, like the thread backend's
            # cancel event.  WAIT_SLICE only caps the window so a dead
            # master can't strand a multi-second stall forever.
            if loop.conn.poll(timeout=min(remaining, WAIT_SLICE)):
                loop.pump(block=False)
            if self._seq <= loop.watermark or loop.purging:
                return True


class _GroupLevelGuard:
    """Per-level guard inside a group batch: cancels on the group's purge
    watermark (whole group dead) OR on a ``purgelvl`` mark for this
    level's round (level fused elsewhere) — later levels keep running."""

    __slots__ = ("_loop", "_seq", "_round")

    def __init__(self, loop: "_WorkerLoop", seq: int, round_idx: int):
        self._loop = loop
        self._seq = seq
        self._round = round_idx

    def _hit(self) -> bool:
        loop = self._loop
        return (self._seq <= loop.watermark or loop.purging
                or self._round <= loop.level_marks.get(self._seq, -1))

    def cancelled(self) -> bool:
        self._loop.pump(block=False)
        return self._hit()

    def wait(self, delay: float) -> bool:
        loop = self._loop
        end = clock() + delay
        while True:
            remaining = end - clock()
            if remaining <= 0.0:
                return False
            if loop.conn.poll(timeout=min(remaining, WAIT_SLICE)):
                loop.pump(block=False)
            if self._hit():
                return True


class _WorkerLoop:
    """One worker process's event loop (runs inside the child).

    Arena support is armed by an ``("arena", dispatch_name, result_name)``
    control message (sent by the master before the first arena-form
    round, so pipe FIFO ordering guarantees the attach happens first).
    Until then — and on the socket backend, always — the loop behaves
    exactly as the pickled path.
    """

    def __init__(self, worker_id: int, cfg: RuntimeConfig, conn, results):
        self.conn = conn
        self._results = results
        self.watermark = -1          # highest purged dispatch seq
        #: per-group level-purge marks: group seq -> highest purged round
        #: index within that group (a fused level's stragglers are
        #: reclaimed without touching the group's later levels)
        self.level_marks: dict[int, int] = {}
        self.stopping = False
        self._drain_on_stop = True
        self.queue: collections.deque = collections.deque()
        # worker-side tracer: events are stamped on THIS host's monotonic
        # clock and ride back piggybacked on result / final-stats
        # envelopes (optional trailing element, absent when tracing is
        # off so the wire format is unchanged for untraced runs)
        self.tracer = telemetry.Tracer() if cfg.trace else None
        self._base_compute = make_compute(cfg, worker_id)
        self._dispatch_arena = None      # attached on ("arena", ...)
        self._result_arena = None
        self._cur_seq = -1               # seq of the batch being run
        self._slot = None                # (ArenaSlice, view) mid-task
        self.runner = BatchRunner(worker_id, self._compute, self._emit,
                                  self.tracer)

    @property
    def purging(self) -> bool:
        return self.stopping and not self._drain_on_stop

    # -- compute: straight into the result arena when there is one -----------
    def _compute(self, x: np.ndarray, y: np.ndarray) -> np.ndarray:
        arena = self._result_arena
        self._slot = None
        if arena is None:
            return self._base_compute(x, y)
        got = arena.alloc_view((x.shape[1], y.shape[1]),
                               np.result_type(x, y), self._cur_seq)
        if got is None:              # ring full: pickled-result fallback
            return self._base_compute(x, y)
        desc, view = got
        try:
            out = self._base_compute(x, y, out=view)
        except (TypeError, ValueError):
            # kernel without out= support, or a dtype the out-buffer
            # can't take exactly: compute normally (the orphaned slot is
            # recycled when the watermark passes it)
            return self._base_compute(x, y)
        self._slot = (desc, view)
        return out

    def _emit(self, result: TaskResult) -> None:
        slot, self._slot = self._slot, None
        if slot is not None and result.value is slot[1]:
            ref = ArenaResultRef(
                job_id=result.job_id, round_idx=result.round_idx,
                task_id=result.task_id, worker_id=result.worker_id,
                seq=self._cur_seq, value=slot[0],
                finished_at=result.finished_at)
            env = ("aresult", ref, self.runner.busy_seconds)
        else:
            env = ("result", result.to_wire(), self.runner.busy_seconds)
        if self.tracer is not None:
            env += (self.tracer.drain(),)
        self._results.put(env)

    def _handle(self, msg: tuple) -> None:
        kind = msg[0]
        if kind == "round":
            self.queue.append(msg[1])
        elif kind == "group":
            self.queue.append(msg[1])
        elif kind == "purgelvl":
            # level-scoped purge: cancel round msg[2] of group msg[1]
            # only — later levels of the group keep computing (they are
            # future rounds the master has not fused yet)
            seq, ridx = msg[1], msg[2]
            self.level_marks[seq] = max(self.level_marks.get(seq, -1), ridx)
        elif kind == "purge":
            self.watermark = max(self.watermark, msg[1])
            if self.level_marks:
                # group seqs at/below the watermark are dead wholesale;
                # their per-level marks are no longer reachable
                self.level_marks = {s: r for s, r in self.level_marks.items()
                                    if s > self.watermark}
            if self._result_arena is not None:
                # recycle result slots of rounds STRICTLY older than the
                # watermark, not the watermark round itself: the master
                # decodes a fused round one iteration behind its purge,
                # so purge(r) can still have round r's accepted views
                # undecoded — but decode(r) always precedes the send of
                # purge(r+1), which is when r's slots fall below the
                # watermark and recycle here.  (Rejected/stale results
                # are never dereferenced, so over-retention is the only
                # cost, bounded at one round.)
                self._result_arena.free_below(self.watermark)
        elif kind == "arena":
            self._dispatch_arena = shm_mod.BlockArena(
                0, name=msg[1], create=False)
            self._result_arena = shm_mod.BlockArena(
                0, name=msg[2], create=False)
        elif kind == "stop":
            self.stopping = True
            self._drain_on_stop = msg[1]
        else:  # pragma: no cover - protocol violation
            raise RuntimeError(f"unknown control message {kind!r}")

    def pump(self, *, block: bool) -> None:
        """Ingest every pending control message.

        With ``block=True``, additionally park on the pipe until there is
        *something* to do (a batch arrives, or stop) — the worker's idle
        state.  Purge watermarks are ingested either way, so a queued dead
        round is dropped before a single task of it runs.
        """
        while True:
            if self.conn.poll():
                self._handle(self.conn.recv())
                continue
            if block and not self.queue and not self.stopping:
                self._handle(self.conn.recv())   # idle: park on the pipe
                continue
            return

    def close_arenas(self) -> None:
        for arena in (self._dispatch_arena, self._result_arena):
            if arena is not None:
                arena.close()        # attach side: unmap only, no unlink
        self._dispatch_arena = None
        self._result_arena = None

    def run(self) -> None:
        while True:
            self.pump(block=True)
            if self.queue:
                batch = self.queue.popleft()
                if batch.seq <= self.watermark or self.purging:
                    self.runner.count_purged_any(batch)
                    continue
                self._cur_seq = batch.seq
                if isinstance(batch, WireGroup):
                    seq = batch.seq
                    self.runner.run_group(
                        batch.levels,
                        lambda lb: _GroupLevelGuard(self, seq,
                                                    lb.round_idx))
                    continue
                if isinstance(batch, ArenaBatchRef):
                    batch = batch.to_batch(self._dispatch_arena)
                self.runner.run(batch, _PipeGuard(self, batch.seq))
            elif self.stopping:
                break
        stats = ("stats", self.runner.worker_id,
                 self.runner.busy_seconds, self.runner.tasks_done,
                 self.runner.tasks_purged)
        if self.tracer is not None:
            stats += (self.tracer.drain(),)
        self._results.put(stats)


#: Fork-start handoff: every pipe of the transport being started, so each
#: child can close its *siblings'* inherited ends.  Without this, a
#: SIGKILLed worker's pipe keeps open read ends in every sibling process,
#: the master never sees EPIPE, and a send to the corpse blocks forever
#: once the kernel buffer fills — the exact hang the fault supervisor
#: exists to prevent.  Under spawn the module is re-imported (global is
#: None) and nothing is inherited anyway.
_FORK_CONNS: Optional[list] = None


def _worker_main(worker_id: int, cfg: RuntimeConfig, conn) -> None:
    """Child-process entrypoint (module-level: picklable under spawn)."""
    if _FORK_CONNS is not None:
        for parent, child in _FORK_CONNS:
            parent.close()
            if child is not conn:
                child.close()
    loop = _WorkerLoop(worker_id, cfg, conn, _PipeResults(conn))
    try:
        loop.run()
    except (EOFError, BrokenPipeError, KeyboardInterrupt):
        pass                      # master died or interrupted: exit quietly
    finally:
        loop.close_arenas()
        conn.close()


class _ArenaPair:
    """Master-side handle on one worker's dispatch + result arenas."""

    __slots__ = ("dispatch", "result")

    def __init__(self, dispatch: shm_mod.BlockArena,
                 result: shm_mod.BlockArena):
        self.dispatch = dispatch
        self.result = result

    def teardown(self) -> None:
        for arena in (self.dispatch, self.result):
            arena.close()
            arena.unlink()       # owner side: the name dies with the run


class ProcessTransport(WorkerTransport):
    """``cfg.num_workers`` OS-process workers: control pipes + shared-
    memory block arenas (descriptor dispatch, zero-copy results)."""

    name = "process"

    def __init__(self, cfg: RuntimeConfig,
                 sink: Callable[[TaskResult], None],
                 rng: Optional[np.random.Generator] = None,
                 tracer=None, *,
                 start_method: Optional[str] = None):
        super().__init__(cfg, sink, rng, tracer)
        if start_method is None:
            methods = multiprocessing.get_all_start_methods()
            start_method = "fork" if "fork" in methods else "spawn"
        self._mp = multiprocessing.get_context(start_method)
        self._conns = []
        self.processes = []
        for p in range(cfg.num_workers):
            parent, child = self._mp.Pipe()
            proc = self._mp.Process(
                target=_worker_main, args=(p, cfg, child),
                name=f"runtime-proc-worker-{p}", daemon=True)
            self._conns.append((parent, child))
            self.processes.append(proc)
        # arenas are created lazily at the first dispatch per worker
        # (sized from the actual slice), under a unique /dev/shm prefix
        # so shutdown's leak sweep has an exact ground truth
        self._arena_mode = cfg.shm          # "auto" | "on" | "off"
        self._arena_prefix = shm_mod.arena_prefix()
        self._arenas: dict[int, _ArenaPair] = {}
        self._arena_failed: set[int] = set()
        self._busy = np.zeros(cfg.num_workers)
        self._done = 0
        self._purged = 0
        self._stats_lock = threading.Lock()
        # wire accounting (wire_stats): all monotonic counters, kept past
        # shutdown so the master can report them with the run result
        self._arena_rounds = 0          # slices dispatched as descriptors
        self._pickle_rounds = 0         # slices dispatched as pickles
        self._group_dispatches = 0      # hierarchical group messages sent
        self._arena_fallbacks = 0       # ring-full (or dead-pipe) declines
        self._arena_dispatch_bytes = 0  # block bytes copied into arenas
        self._pickle_dispatch_bytes = 0  # block bytes sent through pickles
        self._arena_results = 0         # results returned as descriptors
        self._pickle_results = 0        # results returned in envelopes
        self._stale_arena_results = 0   # arena results fusion rejected
        self._drainer = threading.Thread(target=self._drain, daemon=True,
                                         name="runtime-process-drain")
        self._started = False
        self._shutting_down = False
        self._stop_drain = threading.Event()

    # -- master side ---------------------------------------------------------
    def start(self) -> None:
        global _FORK_CONNS
        _FORK_CONNS = self._conns
        try:
            for proc in self.processes:
                proc.start()
        finally:
            _FORK_CONNS = None
        for _, child in self._conns:
            child.close()        # parent keeps only its end of each pipe
        self._drainer.start()
        self._started = True

    # -- arena management (master thread only) -------------------------------
    def _ensure_arena(self, worker_id: int, x: np.ndarray, y: np.ndarray
                      ) -> Optional[_ArenaPair]:
        """The worker's arena pair, created + announced on first use.

        Sized from the first slice: the ring only ever holds the (at
        most two) in-flight rounds plus slack, and a later job too big
        for it degrades per-slice to the pickled path.
        """
        pair = self._arenas.get(worker_id)
        if pair is not None:
            return pair
        if self._arena_mode == "off" or worker_id in self._arena_failed:
            return None
        slice_bytes = x.nbytes + y.nbytes
        item_bytes = (x.shape[2] * y.shape[2]
                      * np.result_type(x, y).itemsize)
        try:
            dispatch = shm_mod.BlockArena(
                max(1 << 20, 8 * slice_bytes),
                name=f"{self._arena_prefix}d{worker_id}")
            try:
                result = shm_mod.BlockArena(
                    max(1 << 20, 32 * x.shape[0] * item_bytes),
                    name=f"{self._arena_prefix}r{worker_id}")
            except BaseException:
                dispatch.close()
                dispatch.unlink()
                raise
        except Exception:
            if self._arena_mode == "on":
                raise
            self._arena_failed.add(worker_id)   # auto: degrade quietly
            return None
        try:
            self._conns[worker_id][0].send(
                ("arena", dispatch.name, result.name))
        except (BrokenPipeError, OSError):
            # worker died before the announce: nothing attached, reclaim
            for arena in (dispatch, result):
                arena.close()
                arena.unlink()
            self._arena_failed.add(worker_id)
            return None
        pair = _ArenaPair(dispatch, result)
        self._arenas[worker_id] = pair
        return pair

    def _send_slice(self, worker_id: int, ctx: RoundContext, first_task: int,
                    x: np.ndarray, y: np.ndarray,
                    delays: np.ndarray) -> None:
        """One round slice: an arena descriptor when the blocks fit, the
        pickled ``("round", WireBatch)`` message otherwise."""
        pair = self._ensure_arena(worker_id, x, y)
        if pair is not None:
            xd = pair.dispatch.write(x, ctx.seq)
            yd = pair.dispatch.write(y, ctx.seq) if xd is not None else None
            if yd is not None:
                ref = ArenaBatchRef(seq=ctx.seq, job_id=ctx.job_id,
                                    round_idx=ctx.round_idx,
                                    first_task_id=first_task,
                                    x=xd, y=yd, delays=delays)
                try:
                    self._conns[worker_id][0].send(("round", ref))
                except (BrokenPipeError, OSError):
                    # worker died under us: drop the slice, like the
                    # socket backend — redundancy may still fuse the
                    # round, and the next liveness check reports the
                    # death either way (the slots recycle at purge)
                    return
                self._arena_rounds += 1
                self._arena_dispatch_bytes += x.nbytes + y.nbytes
                return
            # ring full (an unpurged backlog): fall back for this slice
            self._arena_fallbacks += 1
            if self._tracer is not None:
                self._tracer.emit(telemetry.ARENA, clock(),
                                  job=ctx.job_id, round=ctx.round_idx,
                                  worker=worker_id,
                                  value=pair.dispatch.used_fraction,
                                  label="fallback")
        wire = WireBatch(seq=ctx.seq, job_id=ctx.job_id,
                         round_idx=ctx.round_idx, first_task_id=first_task,
                         x=x, y=y, delays=delays)
        try:
            self._conns[worker_id][0].send(("round", wire))
        except (BrokenPipeError, OSError):
            return
        self._pickle_rounds += 1
        self._pickle_dispatch_bytes += x.nbytes + y.nbytes

    def _send_group(self, worker_id: int, seq: int,
                    entries: list[tuple]) -> None:
        """One pickled ``("group", WireGroup)`` message per worker.

        Groups always ride the pickled pipe path: per-level slices are a
        fraction of a flat round each, and the block arena's seq-keyed
        ring reclamation is level-blind (config validation rejects
        ``shm='on'`` with the hierarchical family for exactly this
        reason).
        """
        levels = tuple(
            WireBatch(seq=seq, job_id=ctx.job_id, round_idx=ctx.round_idx,
                      first_task_id=lo, x=x, y=y, delays=d)
            for ctx, lo, x, y, d in entries)
        group = WireGroup(seq=seq, job_id=levels[0].job_id,
                          base_round=levels[0].round_idx, levels=levels)
        try:
            self._conns[worker_id][0].send(("group", group))
        except (BrokenPipeError, OSError):
            return               # worker died under us: drop the slices
        self._group_dispatches += 1
        self._pickle_dispatch_bytes += sum(b.x.nbytes + b.y.nbytes
                                           for b in levels)

    def purge_level(self, ctx: RoundContext) -> None:
        """Level-scoped purge: reclaim one fused level's stragglers with
        a ``("purgelvl", seq, round)`` mark while the group's later
        levels keep computing (banked ahead-of-frontier work)."""
        ctx.purge()              # master side: fusion drops stale results
        if ctx.seq < 0:
            return               # never dispatched
        for conn, _ in self._conns:
            try:
                if not conn.closed:
                    conn.send(("purgelvl", ctx.seq, ctx.round_idx))
            except (BrokenPipeError, OSError):  # worker already gone
                pass

    def dead_worker_map(self) -> dict[int, str]:
        if not self._started or self._shutting_down:
            return {}
        return {p: f"{proc.name} (exit code {proc.exitcode})"
                for p, proc in enumerate(self.processes)
                if not proc.is_alive()}

    def _quarantine_worker(self, worker_id: int, reason: str) -> None:
        """Retire a dead worker process: reap it and close the master's
        pipe end so shutdown cannot block on a corpse.  Its final stats
        envelope is lost with it — the fault log records the loss.  Its
        arenas stay mapped (master-owned) until shutdown unlinks them:
        a SIGKILLed attacher leaks nothing."""
        proc = self.processes[worker_id]
        if proc.is_alive():      # defensive: quarantine targets the dead
            proc.terminate()
        proc.join(timeout=1.0)
        try:
            conn = self._conns[worker_id][0]
            if not conn.closed:
                conn.close()
        except OSError:          # pragma: no cover - already closed
            pass

    def purge_round(self, ctx: RoundContext) -> None:
        ctx.purge()              # master side: fusion drops stale results
        if ctx.seq < 0:
            return               # never dispatched
        for conn, _ in self._conns:
            try:
                if not conn.closed:
                    conn.send(("purge", ctx.seq))
            except (BrokenPipeError, OSError):  # worker already gone
                pass
        if self._arenas:
            # recycle the purged rounds' dispatch slots immediately.
            # Safe even with stragglers mid-compute on them: a worker
            # still reading a recycled block can only produce a result
            # for a round that is already fused or cancelled, which the
            # fusion sink rejects without dereferencing — ctx.purge()
            # above happens-before any reuse of the region.
            occupancy = 0.0
            for pair in self._arenas.values():
                occupancy = max(occupancy, pair.dispatch.used_fraction)
                pair.dispatch.free_through(ctx.seq)
            if self._tracer is not None:
                self._tracer.emit(telemetry.ARENA, clock(),
                                  job=ctx.job_id, round=ctx.round_idx,
                                  value=occupancy, label="reclaim")

    def shutdown(self, timeout: float = 10.0, *, drain: bool = False
                 ) -> None:
        self._shutting_down = True
        if not self._started:
            for proc in self.processes:
                if proc.is_alive():  # pragma: no cover - defensive
                    proc.terminate()
            self._teardown_arenas()
            return
        for conn, _ in self._conns:
            try:
                if not conn.closed:
                    conn.send(("stop", drain))
            except (BrokenPipeError, OSError):
                pass
        leaked = []
        for proc in self.processes:
            proc.join(timeout=timeout)
            if proc.is_alive():
                leaked.append(proc.name)
                proc.terminate()
                proc.join(timeout=1.0)
        # orderly workers wrote results + final stats into their pipes
        # before exiting; the buffered tail stays readable after the
        # process is gone, so the drain loop empties it and exits on the
        # stop flag once nothing more is pending
        self._stop_drain.set()
        self._drainer.join(timeout=timeout)
        for conn, _ in self._conns:
            try:
                if not conn.closed:
                    conn.close()
            except OSError:      # pragma: no cover - raced the drain
                pass
        self._teardown_arenas()
        if leaked:
            raise RuntimeError(
                f"worker processes failed to stop within {timeout}s "
                f"(terminated): {leaked}")

    def _teardown_arenas(self) -> None:
        """Owner-side unlink of every arena + a /dev/shm leak sweep.

        The sweep is the SIGKILL backstop: whatever happened to the
        workers (they only attach) or to this teardown's bookkeeping, no
        segment under this transport's prefix survives the call.
        """
        for pair in self._arenas.values():
            pair.teardown()
        self._arenas.clear()
        shm_mod.unlink_segments(self._arena_prefix)

    # -- result drain (master-side thread) -----------------------------------
    def _drain(self) -> None:
        conns = [parent for parent, _ in self._conns]
        while True:
            live = [c for c in conns if not c.closed]
            if not live:
                if self._stop_drain.wait(timeout=0.05):
                    return
                continue
            try:
                ready = _mpc.wait(live, timeout=0.25)
            except (OSError, ValueError):
                # a pipe was closed under the wait (quarantine): re-scan
                continue
            if not ready:
                if self._stop_drain.is_set():
                    return       # joined workers + idle pipes: all drained
                continue
            for conn in ready:
                self._pump_conn(conn)

    def _pump_conn(self, conn) -> None:
        try:
            payload = conn.recv_bytes()
        except (EOFError, OSError, ValueError):
            # worker exited (EOF after its buffered tail) or the pipe
            # closed underneath us: stop waiting on this conn.  The
            # master's send paths all tolerate the closed end.
            try:
                if not conn.closed:
                    conn.close()
            except OSError:      # pragma: no cover - raced shutdown
                pass
            return
        try:
            msg = _unpack_envelope(payload)
        except Exception:        # pragma: no cover - killed mid-write
            return
        kind = msg[0]
        if kind == "result":
            wire, busy = msg[1], msg[2]
            result = TaskResult.from_wire(wire)
            with self._stats_lock:
                self._busy[result.worker_id] = busy
                self._pickle_results += 1
            # piggybacked worker events (traced runs only); process
            # workers share the system-wide CLOCK_MONOTONIC, so no
            # clock rebase is needed
            if len(msg) > 3 and self._tracer is not None:
                self._tracer.ingest(msg[3])
            self._sink(result)
        elif kind == "aresult":
            ref, busy = msg[1], msg[2]
            pair = self._arenas.get(ref.worker_id)
            if pair is None:     # arena already torn down (late stats)
                return
            result = ref.to_result(pair.result)
            with self._stats_lock:
                self._busy[ref.worker_id] = busy
                self._arena_results += 1
            if len(msg) > 3 and self._tracer is not None:
                self._tracer.ingest(msg[3])
            # the fusion sink's verdict IS the slot-lifetime decision:
            # accepted values are copied out at decode, rejected ones are
            # never read — either way nothing master-side pins the slot
            # once the purge watermark passes it (worker-side reclaim)
            if self._sink(result) is False:
                with self._stats_lock:
                    self._stale_arena_results += 1
        elif kind == "stats":
            worker_id, busy, done, purged = msg[1:5]
            with self._stats_lock:
                self._busy[worker_id] = busy
                self._done += done
                self._purged += purged
            if len(msg) > 5 and self._tracer is not None:
                self._tracer.ingest(msg[5])

    # -- wire accounting ------------------------------------------------------
    @property
    def wire_stats(self) -> dict:
        """Dispatch/result path counters (all plain ints/bools/strs).

        ``shm_active`` reports whether any arena actually ran (``auto``
        may have degraded); the ``*_bytes`` counters split block traffic
        by path, so "bytes copied through a pickler" is directly
        readable: it is the ``pickle_*`` share.
        """
        with self._stats_lock:
            return {
                "transport": "process",
                "shm": self._arena_mode,
                "shm_active": bool(self._arena_rounds),
                "arena_rounds": self._arena_rounds,
                "pickle_rounds": self._pickle_rounds,
                "group_dispatches": self._group_dispatches,
                "arena_fallbacks": self._arena_fallbacks,
                "dispatch_arena_bytes": self._arena_dispatch_bytes,
                "dispatch_pickle_bytes": self._pickle_dispatch_bytes,
                "arena_results": self._arena_results,
                "pickle_results": self._pickle_results,
                "stale_arena_results": self._stale_arena_results,
            }

    # -- occupancy / outcome counters ----------------------------------------
    @property
    def busy_seconds(self) -> np.ndarray:
        """Per-worker occupancy; live values ride each result envelope
        (so this lags a worker's *current* delay wait by one task), and
        the final stats envelopes make it exact after shutdown."""
        with self._stats_lock:
            return self._busy.copy()

    @property
    def tasks_done(self) -> int:
        """Exact after shutdown (final stats); 0 while running."""
        with self._stats_lock:
            return self._done

    @property
    def tasks_purged(self) -> int:
        """Exact after shutdown (final stats); 0 while running."""
        with self._stats_lock:
            return self._purged
