"""The ``process`` backend: multiprocessing workers over pipes.

True GIL-free parallel compute and *real* stragglers: each worker is an
OS process with a private duplex pipe for control/batches and a shared
result queue back to the master, where a drain thread pumps completed
tasks into the fusion sink.  The §IV semantics are preserved exactly:

* **Dispatch** — the master serializes each worker's ``kappa_p``-slice as
  a :class:`~repro.runtime.tasks.WireBatch` (primitives + ndarrays; a
  view pickles as just its slice) and sends it down the worker's pipe.
* **Purge** — a ``("purge", seq)`` message carrying the round's monotonic
  dispatch sequence number.  Workers treat it as a watermark: every batch
  with ``seq <= watermark`` — queued *or* currently delaying — is dropped
  and counted.  An in-flight delay wait polls the pipe
  (``Connection.poll`` with the remaining-delay timeout), so a purge
  wakes a delayed worker immediately, matching the thread backend's
  shared cancel event.
* **Results** — workers push ``("result", wire, busy_seconds)`` envelopes
  onto one shared queue; the master-side drain thread rebuilds
  :class:`~repro.runtime.tasks.TaskResult` and posts it to the fusion
  sink.  The piggybacked cumulative ``busy_seconds`` keeps the
  ω-controller's utilization signal fresh without a stats RPC.
* **Shutdown** — ``("stop", drain)`` then join: workers finish (drain) or
  purge their queues, emit a final ``("stats", ...)`` envelope (so
  ``tasks_purged``/``busy_seconds`` are exact even for tasks that never
  produced results), and exit.  Stragglers are terminated and reported —
  the transport never leaks a process.

Timestamps: workers stamp ``finished_at`` with ``time.monotonic``, which
is CLOCK_MONOTONIC — system-wide, comparable across processes on Linux
(the platform this backend targets; the CI smoke job pins it).

Start method: ``fork`` where available (cheap, and child workers inherit
the already-imported numpy/BLAS state instead of paying a multi-second
re-import that would pollute the first measured rounds), else ``spawn``;
the worker entrypoint and all its arguments are picklable either way.
Forking a process whose parent has live JAX threads draws CPython's
fork-safety warning; the children here touch only numpy and pipe I/O
(never JAX), which is why the master still watches liveness
(:meth:`ProcessTransport._dead_workers` via
:meth:`~repro.runtime.transport.base.WorkerTransport.assert_alive`) so a
child lost for *any* reason fails the run promptly instead of hanging an
unbounded fusion wait.  Pass ``start_method="spawn"`` to opt out of fork
entirely.
"""

from __future__ import annotations

import collections
import multiprocessing
import queue as _queue
import threading
from typing import Callable, Optional

import numpy as np

from repro.runtime import telemetry
from repro.runtime.tasks import (RoundContext, RuntimeConfig, TaskResult,
                                 WireBatch)
from repro.runtime.transport.base import WorkerTransport
from repro.runtime.worker import (BatchRunner, WAIT_SLICE, clock,
                                  make_compute)

__all__ = ["ProcessTransport"]


class _PipeGuard:
    """Worker-side cancellation guard backed by the control pipe.

    ``cancelled`` is true once the batch's ``seq`` falls under the purge
    watermark (or a purge-mode stop arrived); ``wait`` blocks on the pipe
    so a purge message interrupts an injected delay the moment it lands.
    """

    __slots__ = ("_loop", "_seq")

    def __init__(self, loop: "_WorkerLoop", seq: int):
        self._loop = loop
        self._seq = seq

    def cancelled(self) -> bool:
        self._loop.pump(block=False)
        return self._seq <= self._loop.watermark or self._loop.purging

    def wait(self, delay: float) -> bool:
        loop = self._loop
        end = clock() + delay
        while True:
            remaining = end - clock()
            if remaining <= 0.0:
                return False
            # block on the pipe, not time.sleep: a purge (or stop) message
            # wakes this worker immediately, like the thread backend's
            # cancel event.  WAIT_SLICE only caps the window so a dead
            # master can't strand a multi-second stall forever.
            if loop.conn.poll(timeout=min(remaining, WAIT_SLICE)):
                loop.pump(block=False)
            if self._seq <= loop.watermark or loop.purging:
                return True


class _WorkerLoop:
    """One worker process's event loop (runs inside the child)."""

    def __init__(self, worker_id: int, cfg: RuntimeConfig, conn, results):
        self.conn = conn
        self._results = results
        self.watermark = -1          # highest purged dispatch seq
        self.stopping = False
        self._drain_on_stop = True
        self.queue: collections.deque[WireBatch] = collections.deque()
        # worker-side tracer: events are stamped on THIS host's monotonic
        # clock and ride back piggybacked on result / final-stats
        # envelopes (optional trailing element, absent when tracing is
        # off so the wire format is unchanged for untraced runs)
        self.tracer = telemetry.Tracer() if cfg.trace else None
        self.runner = BatchRunner(worker_id, make_compute(cfg, worker_id),
                                  self._emit, self.tracer)

    @property
    def purging(self) -> bool:
        return self.stopping and not self._drain_on_stop

    def _emit(self, result: TaskResult) -> None:
        if self.tracer is not None:
            self._results.put(("result", result.to_wire(),
                               self.runner.busy_seconds,
                               self.tracer.drain()))
        else:
            self._results.put(("result", result.to_wire(),
                               self.runner.busy_seconds))

    def _handle(self, msg: tuple) -> None:
        kind = msg[0]
        if kind == "round":
            self.queue.append(msg[1])
        elif kind == "purge":
            self.watermark = max(self.watermark, msg[1])
        elif kind == "stop":
            self.stopping = True
            self._drain_on_stop = msg[1]
        else:  # pragma: no cover - protocol violation
            raise RuntimeError(f"unknown control message {kind!r}")

    def pump(self, *, block: bool) -> None:
        """Ingest every pending control message.

        With ``block=True``, additionally park on the pipe until there is
        *something* to do (a batch arrives, or stop) — the worker's idle
        state.  Purge watermarks are ingested either way, so a queued dead
        round is dropped before a single task of it runs.
        """
        while True:
            if self.conn.poll():
                self._handle(self.conn.recv())
                continue
            if block and not self.queue and not self.stopping:
                self._handle(self.conn.recv())   # idle: park on the pipe
                continue
            return

    def run(self) -> None:
        while True:
            self.pump(block=True)
            if self.queue:
                batch = self.queue.popleft()
                if batch.seq <= self.watermark or self.purging:
                    self.runner.count_purged(batch)
                    continue
                self.runner.run(batch, _PipeGuard(self, batch.seq))
            elif self.stopping:
                break
        stats = ("stats", self.runner.worker_id,
                 self.runner.busy_seconds, self.runner.tasks_done,
                 self.runner.tasks_purged)
        if self.tracer is not None:
            stats += (self.tracer.drain(),)
        self._results.put(stats)


#: Fork-start handoff: every pipe of the transport being started, so each
#: child can close its *siblings'* inherited ends.  Without this, a
#: SIGKILLed worker's pipe keeps open read ends in every sibling process,
#: the master never sees EPIPE, and a send to the corpse blocks forever
#: once the kernel buffer fills — the exact hang the fault supervisor
#: exists to prevent.  Under spawn the module is re-imported (global is
#: None) and nothing is inherited anyway.
_FORK_CONNS: Optional[list] = None


def _worker_main(worker_id: int, cfg: RuntimeConfig, conn, results) -> None:
    """Child-process entrypoint (module-level: picklable under spawn)."""
    if _FORK_CONNS is not None:
        for parent, child in _FORK_CONNS:
            parent.close()
            if child is not conn:
                child.close()
    try:
        _WorkerLoop(worker_id, cfg, conn, results).run()
    except (EOFError, BrokenPipeError, KeyboardInterrupt):
        pass                      # master died or interrupted: exit quietly
    finally:
        conn.close()


class ProcessTransport(WorkerTransport):
    """``cfg.num_workers`` OS-process workers, pipes + result queue."""

    name = "process"

    def __init__(self, cfg: RuntimeConfig,
                 sink: Callable[[TaskResult], None],
                 rng: Optional[np.random.Generator] = None,
                 tracer=None, *,
                 start_method: Optional[str] = None):
        super().__init__(cfg, sink, rng, tracer)
        if start_method is None:
            methods = multiprocessing.get_all_start_methods()
            start_method = "fork" if "fork" in methods else "spawn"
        self._mp = multiprocessing.get_context(start_method)
        # mp.Queue, not SimpleQueue: the drain loop needs get(timeout) so
        # it can notice the stop flag without a sentinel message — a
        # sentinel put() could block forever on the queue's write lock if
        # a leaked worker was terminated mid-put.  Workers' feeder threads
        # are flushed on orderly process exit, so final stats envelopes
        # are never lost.
        self._results = self._mp.Queue()
        self._conns = []
        self.processes = []
        for p in range(cfg.num_workers):
            parent, child = self._mp.Pipe()
            proc = self._mp.Process(
                target=_worker_main, args=(p, cfg, child, self._results),
                name=f"runtime-proc-worker-{p}", daemon=True)
            self._conns.append((parent, child))
            self.processes.append(proc)
        self._busy = np.zeros(cfg.num_workers)
        self._done = 0
        self._purged = 0
        self._stats_lock = threading.Lock()
        self._drainer = threading.Thread(target=self._drain, daemon=True,
                                         name="runtime-process-drain")
        self._started = False
        self._shutting_down = False
        self._stop_drain = threading.Event()

    # -- master side ---------------------------------------------------------
    def start(self) -> None:
        global _FORK_CONNS
        _FORK_CONNS = self._conns
        try:
            for proc in self.processes:
                proc.start()
        finally:
            _FORK_CONNS = None
        for _, child in self._conns:
            child.close()        # parent keeps only its end of each pipe
        self._drainer.start()
        self._started = True

    def _send_slice(self, worker_id: int, ctx: RoundContext, first_task: int,
                    x: np.ndarray, y: np.ndarray,
                    delays: np.ndarray) -> None:
        """One ``("round", WireBatch)`` message down the worker's pipe."""
        wire = WireBatch(seq=ctx.seq, job_id=ctx.job_id,
                         round_idx=ctx.round_idx, first_task_id=first_task,
                         x=x, y=y, delays=delays)
        try:
            self._conns[worker_id][0].send(("round", wire))
        except (BrokenPipeError, OSError):
            # worker died under us: drop the slice, like the socket
            # backend — redundancy may still fuse the round, and the
            # next liveness check reports the death either way
            pass

    def dead_worker_map(self) -> dict[int, str]:
        if not self._started or self._shutting_down:
            return {}
        return {p: f"{proc.name} (exit code {proc.exitcode})"
                for p, proc in enumerate(self.processes)
                if not proc.is_alive()}

    def _quarantine_worker(self, worker_id: int, reason: str) -> None:
        """Retire a dead worker process: reap it and close the master's
        pipe end so shutdown cannot block on a corpse.  Its final stats
        envelope is lost with it — the fault log records the loss."""
        proc = self.processes[worker_id]
        if proc.is_alive():      # defensive: quarantine targets the dead
            proc.terminate()
        proc.join(timeout=1.0)
        try:
            self._conns[worker_id][0].close()
        except OSError:          # pragma: no cover - already closed
            pass

    def purge_round(self, ctx: RoundContext) -> None:
        ctx.purge()              # master side: fusion drops stale results
        if ctx.seq < 0:
            return               # never dispatched
        for conn, _ in self._conns:
            try:
                conn.send(("purge", ctx.seq))
            except (BrokenPipeError, OSError):  # worker already gone
                pass

    def shutdown(self, timeout: float = 10.0, *, drain: bool = False
                 ) -> None:
        self._shutting_down = True
        if not self._started:
            for proc in self.processes:
                if proc.is_alive():  # pragma: no cover - defensive
                    proc.terminate()
            return
        for conn, _ in self._conns:
            try:
                conn.send(("stop", drain))
            except (BrokenPipeError, OSError):
                pass
        leaked = []
        for proc in self.processes:
            proc.join(timeout=timeout)
            if proc.is_alive():
                leaked.append(proc.name)
                proc.terminate()
                proc.join(timeout=1.0)
        # orderly workers flushed results + final stats before exiting
        # (their queue feeder threads are joined at process exit); the
        # drain loop empties what is there and exits on the stop flag —
        # no sentinel message, so a worker terminated mid-put cannot
        # deadlock the shutdown path
        self._stop_drain.set()
        self._drainer.join(timeout=timeout)
        for conn, _ in self._conns:
            conn.close()
        self._results.close()
        if leaked:
            raise RuntimeError(
                f"worker processes failed to stop within {timeout}s "
                f"(terminated): {leaked}")

    # -- result drain (master-side thread) -----------------------------------
    def _drain(self) -> None:
        while True:
            try:
                msg = self._results.get(timeout=0.25)
            except _queue.Empty:
                if self._stop_drain.is_set():
                    return
                continue
            except (EOFError, OSError):  # pragma: no cover - queue torn down
                return
            except Exception:            # pragma: no cover - corrupt pickle
                # a worker terminated mid-write can leave a truncated
                # pickle; drop it and keep draining the healthy tail
                if self._stop_drain.is_set():
                    return
                continue
            if msg[0] == "result":
                wire, busy = msg[1], msg[2]
                result = TaskResult.from_wire(wire)
                with self._stats_lock:
                    self._busy[result.worker_id] = busy
                # piggybacked worker events (traced runs only); process
                # workers share the system-wide CLOCK_MONOTONIC, so no
                # clock rebase is needed
                if len(msg) > 3 and self._tracer is not None:
                    self._tracer.ingest(msg[3])
                self._sink(result)
            elif msg[0] == "stats":
                worker_id, busy, done, purged = msg[1:5]
                with self._stats_lock:
                    self._busy[worker_id] = busy
                    self._done += done
                    self._purged += purged
                if len(msg) > 5 and self._tracer is not None:
                    self._tracer.ingest(msg[5])

    # -- occupancy / outcome counters ----------------------------------------
    @property
    def busy_seconds(self) -> np.ndarray:
        """Per-worker occupancy; live values ride each result envelope
        (so this lags a worker's *current* delay wait by one task), and
        the final stats envelopes make it exact after shutdown."""
        with self._stats_lock:
            return self._busy.copy()

    @property
    def tasks_done(self) -> int:
        """Exact after shutdown (final stats); 0 while running."""
        with self._stats_lock:
            return self._done

    @property
    def tasks_purged(self) -> int:
        """Exact after shutdown (final stats); 0 while running."""
        with self._stats_lock:
            return self._purged
