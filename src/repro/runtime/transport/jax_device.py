"""The ``jax`` backend: one worker per local JAX device.

Thread workers (the in-process transport loop is identical to the
``thread`` backend — shared cancel events, zero-copy batches) whose
compute kernel lives on a JAX device: each worker pins
``jax.devices()[p % len(devices)]`` and runs its coded products as a
jitted ``device_put → matmul`` with asynchronous dispatch, synchronizing
only when the result is materialized for the fusion node.  On a
multi-device host this gives ``num_workers``-way accelerator parallelism
behind the same seam; on CPU (one device) it is a smoke-able stand-in
exercised by the conformance suite.

This subsumes the legacy ``RuntimeConfig.use_jax_devices`` flag:
``make_transport`` routes that flag here, so old configs keep working.
"""

from __future__ import annotations

from typing import Callable, Optional

import numpy as np

from repro.runtime.tasks import RuntimeConfig, TaskResult
from repro.runtime.transport.thread import ThreadTransport
from repro.runtime.worker import make_compute

__all__ = ["JaxDeviceTransport"]


class JaxDeviceTransport(ThreadTransport):
    """Thread transport with per-worker device-pinned JAX compute."""

    name = "jax"

    def __init__(self, cfg: RuntimeConfig,
                 sink: Callable[[TaskResult], None],
                 rng: Optional[np.random.Generator] = None,
                 tracer=None):
        import jax
        self._devices = jax.devices()
        super().__init__(cfg, sink, rng, tracer)

    def _compute_for(self, worker_id: int):
        device = self._devices[worker_id % len(self._devices)]
        return make_compute(self._cfg, worker_id, device=device)
