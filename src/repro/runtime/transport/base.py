"""The worker-transport contract every runtime backend implements.

:class:`WorkerTransport` is the seam between the master's §IV round loop
and the execution substrate.  The master speaks only this interface; the
thread, process, and jax-device backends (and any future remote/RPC one)
implement it.  The contract, precisely:

* ``start()`` brings up ``cfg.num_workers`` workers (threads, processes,
  or device-bound executors).  Worker ``p`` corresponds to service rate
  ``cfg.mu[p]`` — the eq. (1) split indexes workers by position.
* ``sample_round_delays(kappa)`` draws one round's injected straggler
  delays **master-side** (deterministic per seed, identical across
  backends) so every transport faces the same straggler trace.
* ``submit_round(ctx, X, Y, kappa, delays)`` dispatches one round: worker
  ``p`` receives the contiguous ``kappa_p``-slice of the ``(T, ...)``
  coded buffers.  The transport stamps ``ctx.seq`` with a monotonic
  dispatch sequence number; backends that cross a process boundary ship
  the slice as a :class:`~repro.runtime.tasks.WireBatch` keyed by it.
* Results return **push-style**: each completed task is delivered to the
  ``sink`` callable (the fusion node's ``post``) as a
  :class:`~repro.runtime.tasks.TaskResult`.  In-process backends call the
  sink from their worker threads; remote backends pump it from a drain
  thread that polls the transport's result channel.  The sink must
  therefore be thread-safe (the fusion node is), and ``finished_at``
  timestamps must be mutually comparable with the master's clock
  (``time.monotonic`` — system-wide on Linux, the platform the process
  backend targets).
* ``purge_round(ctx)`` reclaims the round's stragglers *immediately*:
  workers delaying on one of its tasks abort the wait, queued slices are
  dropped and counted.  Purge-then-result races are legal — the fusion
  node drops and counts stale results — but a purged round must never
  occupy a worker longer than one in-flight task.
* ``shutdown(timeout, drain=...)`` is deterministic drain-or-purge:
  ``drain=False`` (the master's default — every submitted round is
  already fused or terminated) purges outstanding work; ``drain=True``
  completes it.  Either way, *no worker thread or process may outlive the
  call* — implementations raise rather than leak.
* ``busy_seconds`` / ``tasks_done`` / ``tasks_purged`` expose per-worker
  occupancy (delay + compute, purged waits included) and task outcomes
  with identical semantics everywhere; ``busy_seconds`` feeds the
  ω-controller's utilization signal each round, so it may lag by at most
  the transport's result-return latency.

The adaptive controller's :class:`~repro.runtime.adaptive.RoundObservation`
carries only scalars and small arrays (wait, stale count, margin,
utilization) measured master-side, so the retune loop is transport-
agnostic by construction — the ROADMAP's multi-host claim, enforced by
the backend-conformance suite (``tests/test_transport_conformance.py``).
"""

from __future__ import annotations

import abc
import time
from typing import Callable, Optional

import numpy as np

from repro.runtime import telemetry
from repro.runtime.errors import TransportDeadError
from repro.runtime.tasks import RoundContext, RuntimeConfig, TaskResult

__all__ = ["StragglerModel", "WorkerTransport"]

clock = time.monotonic


class StragglerModel:
    """Samples per-task injected delays for each worker (master-side RNG).

    Delays are in seconds.  The time-varying modes (``shift``/``burst``)
    measure elapsed time from the model's first sample; the master
    presamples each round's delays one round ahead, so a regime boundary
    lands within ~one round of its nominal wall-clock instant.

    Sampling is a *transport-level* concern but always runs master-side,
    whatever the backend: the delays travel to the workers inside the
    (wire) batch, so a thread run and a process run with the same seed
    face the same injected trace.  (Historically lived in
    :mod:`repro.runtime.worker`, which still re-exports it.)
    """

    def __init__(self, cfg: RuntimeConfig, rng: np.random.Generator):
        self._cfg = cfg
        self._rng = rng
        self._origin: float | None = None

    def _elapsed(self) -> float:
        """Seconds since the first sample (the regime clock)."""
        now = clock()
        if self._origin is None:
            self._origin = now
        return now - self._origin

    def _stalled(self, worker_id: int) -> bool:
        """Is this worker dark *right now* under the configured regime?"""
        cfg = self._cfg
        if worker_id not in cfg.stall_workers:
            return False
        if cfg.straggler == "stall":
            return True
        if cfg.straggler == "shift":
            return self._elapsed() >= cfg.shift_at
        if cfg.straggler == "burst":
            return (self._elapsed() % cfg.burst_period) < cfg.burst_len
        return False

    def sample(self, worker_id: int, num_tasks: int) -> np.ndarray:
        """(num_tasks,) delays in seconds for one worker's round queue."""
        cfg = self._cfg
        if self._origin is None:
            # anchor the regime clock on the run's FIRST sample, whoever
            # it is for: a stall-listed worker can legitimately hold
            # kappa = 0 (eq. 1), and anchoring lazily inside its own
            # branch would silently delay or disable the regime change
            self._origin = clock()
        if num_tasks == 0 or cfg.straggler == "none":
            return np.zeros(num_tasks)
        if self._stalled(worker_id):
            return np.full(num_tasks, cfg.stall_seconds)
        scale = cfg.minijob_complexity / cfg.mu[worker_id]
        return self._rng.exponential(scale=scale, size=num_tasks)


class WorkerTransport(abc.ABC):
    """Abstract worker substrate: start / submit / purge / shutdown.

    Subclasses set :attr:`name` (the ``RuntimeConfig.backend`` key) and
    implement the abstract surface below; see the module docstring for
    the exact semantics each method must honour.

    The master-side half of dispatch is *shared*: delay sampling
    (:meth:`sample_round_delays`) and the seq-stamp + eq. (1) kappa-slice
    loop (:meth:`submit_round`) are implemented here once, so the
    "identical straggler trace and task split across backends" invariant
    cannot drift; backends only provide :meth:`_send_slice` — how one
    worker's contiguous slice actually reaches that worker.
    """

    #: Registry key (``RuntimeConfig.backend`` value) for this backend.
    name: str = "abstract"

    #: Wire-path accounting.  Transports that move data across a process
    #: or network boundary override this (as a property) with a dict of
    #: plain counters — frames/bytes per path, serialization-copied vs
    #: zero-copy splits; the master surfaces it as
    #: ``RuntimeResult.transport_stats``.  Purely in-process backends
    #: (thread, jax) have no wire and leave it ``None``.
    wire_stats: Optional[dict] = None

    def __init__(self, cfg: RuntimeConfig,
                 sink: Callable[[TaskResult], None],
                 rng: Optional[np.random.Generator] = None,
                 tracer: Optional[telemetry.Tracer] = None):
        self._cfg = cfg
        self._sink = sink
        self._tracer = tracer
        self.straggler = StragglerModel(
            cfg, rng if rng is not None else np.random.default_rng(cfg.seed))
        self._seq = 0
        #: Workers removed from the active fleet by the fault supervisor
        #: (degrade policy).  A quarantined worker receives no further
        #: slices; its liveness state stays reported via
        #: :meth:`dead_worker_map` so accounting never loses the death.
        self.quarantined: set[int] = set()

    def sample_round_delays(self, kappa: np.ndarray) -> list[np.ndarray]:
        """Master-side per-worker injected-delay vectors for one round.

        Split out of :meth:`submit_round` so the master can presample the
        next round's delays off the critical path (in its encode-ahead
        slot) and dispatch with buffers alone.
        """
        return [self.straggler.sample(p, int(kappa[p]))
                for p in range(self._cfg.num_workers)]

    def submit_round(self, ctx: RoundContext, X: np.ndarray, Y: np.ndarray,
                     kappa: np.ndarray,
                     delays: Optional[list] = None) -> None:
        """Dispatch one round's T coded tasks per the eq. (1) split:
        worker p gets the contiguous ``kappa_p``-slice ``[lo, hi)`` of
        the coded buffers; the round is stamped with a monotonic dispatch
        ``seq`` first (the purge-watermark key for remote backends)."""
        if delays is None:
            delays = self.sample_round_delays(kappa)
        ctx.seq = self._seq
        self._seq += 1
        if self._tracer is not None:
            self._tracer.emit(telemetry.DISPATCH, clock(), job=ctx.job_id,
                              round=ctx.round_idx, value=float(ctx.seq))
        lo = 0
        for p in range(self._cfg.num_workers):
            hi = lo + int(kappa[p])
            if lo == hi:
                continue
            # a quarantined worker's slice is withheld, not sent into the
            # void: the fault supervisor sees the round's kappa and
            # re-dispatches exactly these tasks to survivors (a stale
            # buffered round can carry a pre-death split)
            if p not in self.quarantined:
                self._send_slice(p, ctx, lo, X[lo:hi], Y[lo:hi], delays[p])
            lo = hi

    @abc.abstractmethod
    def _send_slice(self, worker_id: int, ctx: RoundContext, first_task: int,
                    x: np.ndarray, y: np.ndarray,
                    delays: np.ndarray) -> None:
        """Deliver one worker's round slice (backend-specific hop)."""

    def submit_group(self, ctxs: list[RoundContext], Xs: list[np.ndarray],
                     Ys: list[np.ndarray], kappas: list[np.ndarray],
                     delays: Optional[list] = None) -> None:
        """Dispatch one hierarchical group: level l's codeword (plane-pair
        round ``ctxs[l].round_idx``) is sliced per its *own* eq. (1) split
        ``kappas[l]``, and each worker receives ONE group message holding
        its per-level slices in MSB-first level order.  All levels share a
        single dispatch ``seq`` (the group purge watermark); each level
        keeps its own context so fused levels purge individually
        (:meth:`purge_level`) while later levels keep computing.
        """
        if delays is None:
            delays = [self.sample_round_delays(kappa) for kappa in kappas]
        seq = self._seq
        self._seq += 1
        for ctx in ctxs:
            ctx.seq = seq
        if self._tracer is not None:
            self._tracer.emit(telemetry.DISPATCH, clock(),
                              job=ctxs[0].job_id, round=ctxs[0].round_idx,
                              value=float(seq),
                              label=f"group+{len(ctxs)}")
        for p in range(self._cfg.num_workers):
            if p in self.quarantined:
                # withheld exactly like submit_round's slices: the fault
                # supervisor re-dispatches the frontier level from kappa
                continue
            entries = []
            for l, ctx in enumerate(ctxs):
                kappa = kappas[l]
                lo = int(np.sum(kappa[:p]))
                hi = lo + int(kappa[p])
                if lo == hi:
                    continue
                entries.append((ctx, lo, Xs[l][lo:hi], Ys[l][lo:hi],
                                delays[l][p]))
            if entries:
                self._send_group(p, seq, entries)

    def _send_group(self, worker_id: int, seq: int,
                    entries: list[tuple]) -> None:
        """Deliver one worker's group of per-level slices (each entry is
        ``(ctx, first_task, x, y, delays)``).  Backends that support the
        hierarchical family override this; the config layer only admits
        ``code_family='hierarchical'`` for backends that do."""
        raise NotImplementedError(
            f"{self.name} transport does not dispatch hierarchical groups")

    def purge_level(self, ctx: RoundContext) -> None:
        """Reclaim one fused level's stragglers without cancelling the
        rest of its group.  The shared cancel event covers in-process
        backends; remote backends additionally send a level-scoped purge
        keyed by (group seq, round index)."""
        ctx.purge()

    @abc.abstractmethod
    def start(self) -> None:
        """Bring up the workers; must be called before any submit."""

    def dead_worker_map(self) -> dict[int, str]:
        """``worker_id -> description`` of unexpectedly-dead workers.

        The structured liveness report: quarantined workers stay listed
        (their death is a fact), and it is the fault supervisor's job to
        remember which deaths it already handled.  Backends override
        this; the default (no liveness tracking) reports nothing.
        """
        return {}

    def _dead_workers(self) -> list[str]:
        """Names of workers that died *unexpectedly* (not stopping)."""
        return [desc for _, desc in sorted(self.dead_worker_map().items())]

    def assert_alive(self) -> None:
        """Raise if any worker died outside an orderly shutdown.

        The master calls this between unbounded fusion waits: a worker
        process OOM-killed (or a worker thread killed by an unexpected
        exception) while holding more than ``T - k`` of a round's tasks
        would otherwise leave the round unable to fuse and the run
        blocked forever.  Turning that into a prompt
        :class:`~repro.runtime.errors.TransportDeadError` is the
        ``fail-fast`` contract; backends report deaths via
        :meth:`dead_worker_map`.  Under ``fault_policy="degrade"`` the
        fault supervisor consults :meth:`dead_worker_map` directly and
        quarantines instead of calling this.
        """
        dead = self._dead_workers()
        if dead:
            raise TransportDeadError(
                f"{self.name} transport: worker(s) died mid-run: {dead}",
                workers=dead)

    # -- fault-supervision hooks (degrade policy) -----------------------------
    @property
    def active_workers(self) -> list[int]:
        """Worker ids still in the dispatch fleet (not quarantined)."""
        return [p for p in range(self._cfg.num_workers)
                if p not in self.quarantined]

    def quarantine(self, worker_id: int, reason: str) -> None:
        """Remove one dead worker from the active fleet (idempotent).

        Subsequent :meth:`submit_round` calls withhold the worker's
        slice; backends additionally tear down their side of the worker
        (:meth:`_quarantine_worker`) so a half-dead peer cannot wedge
        shutdown.
        """
        if worker_id in self.quarantined:
            return
        self.quarantined.add(worker_id)
        self._quarantine_worker(worker_id, reason)
        if self._tracer is not None:
            self._tracer.emit(telemetry.QUARANTINE, clock(),
                              worker=worker_id, label=reason)

    def _quarantine_worker(self, worker_id: int, reason: str) -> None:
        """Backend-specific quarantine teardown (default: nothing)."""

    def resend_slice(self, worker_id: int, ctx: RoundContext,
                     first_task: int, x: np.ndarray, y: np.ndarray,
                     delays: np.ndarray) -> None:
        """Re-dispatch a lost slice of an in-flight round to a survivor.

        The fault supervisor's re-dispatch hop: same delivery path as
        :meth:`submit_round`'s slices (``ctx.seq`` is already stamped),
        addressed to a surviving worker of the supervisor's choosing.
        """
        self._send_slice(worker_id, ctx, first_task, x, y, delays)

    def try_readmit(self) -> list[int]:
        """Attempt to re-establish quarantined workers; returns the ids
        readmitted (removed from quarantine).  Only backends with a
        reconnect path (socket) can ever readmit; the default is none —
        a dead thread or process does not come back.
        """
        return []

    @abc.abstractmethod
    def purge_round(self, ctx: RoundContext) -> None:
        """Reclaim the round's stragglers immediately (idempotent)."""

    @abc.abstractmethod
    def shutdown(self, timeout: float = 10.0, *, drain: bool = False
                 ) -> None:
        """Deterministic drain-or-purge stop; raises on leaked workers."""

    @property
    @abc.abstractmethod
    def busy_seconds(self) -> np.ndarray:
        """(num_workers,) seconds each worker spent occupied so far."""

    @property
    @abc.abstractmethod
    def tasks_done(self) -> int:
        """Completed (result-emitting) tasks across all workers."""

    @property
    @abc.abstractmethod
    def tasks_purged(self) -> int:
        """Tasks abandoned by purges or purge-mode shutdown."""
