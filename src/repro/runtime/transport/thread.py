"""The ``thread`` backend: today's worker pool behind the transport seam.

:class:`~repro.runtime.worker.WorkerPool` already *is* the reference
implementation of the
:class:`~repro.runtime.transport.base.WorkerTransport` contract — it
subclasses it, inheriting the shared master-side dispatch template and
providing the in-process hop (zero-copy ``RoundBatch`` views, shared
cancel events, sink called straight from the worker threads).  This
module just binds it into the transport registry, so the historical
import path (``repro.runtime.worker.WorkerPool``) and the transport path
(``backend="thread"``) are one and the same object with one behavior.
"""

from __future__ import annotations

from repro.runtime.worker import WorkerPool

__all__ = ["ThreadTransport"]


class ThreadTransport(WorkerPool):
    """Thread workers with shared-memory rounds (the in-process backend)."""

    name = "thread"
