"""Shared task/job/config types for the asynchronous runtime.

The runtime executes the paper's system for real: each *job* is a coded
layered matmul ``A.T @ B``; each of its ``m**2`` *mini-jobs* (one digit
plane pair ``(i, j)``) is polynomial-encoded into ``T = ceil(k * omega)``
*coded tasks* that are dispatched to concurrent workers.  A mini-job is one
master-paced *round*: it fuses as soon as any ``k`` task results land, and
the master purges the round's stragglers.

``RoundContext`` carries the purge signal: workers wait out their injected
straggler delay on ``cancel`` so a purge (or job termination) reclaims them
*immediately* — the runtime analogue of the simulator's "workers idle until
the round boundary" semantics.

Wire forms: :class:`RoundBatch` and :class:`TaskResult` are the *local*
(zero-copy, live-object) forms the thread backend hands around;
:class:`WireBatch` and :meth:`TaskResult.to_wire` /
:meth:`TaskResult.from_wire` are their transport-serializable twins — no
threading primitives, only primitives + contiguous ndarrays — used by any
backend that crosses a process (or host) boundary.  The cancel event does
not serialize; remote purging is a transport concern (a purge message
against the batch's monotonic ``seq``, see
:mod:`repro.runtime.transport.process`).
"""

from __future__ import annotations

import dataclasses
import math
import threading
from typing import Optional

import numpy as np

from repro.core import coding, layering, scheduling

__all__ = ["RuntimeConfig", "JobSpec", "RoundContext", "RoundBatch",
           "GroupBatch", "TaskResult", "WireBatch", "WireGroup",
           "ArenaSlice", "ArenaBatchRef", "ArenaResultRef",
           "BACKEND_NAMES", "CODE_FAMILIES", "COMPRESS_MODES",
           "FAULT_POLICIES", "SHM_MODES", "FRAME_PROTOS"]

#: Worker-transport backends the runtime can dispatch over (see
#: :mod:`repro.runtime.transport`).
BACKEND_NAMES = ("thread", "process", "jax", "socket")

#: Coded-task families: ``polynomial`` is the paper's flat §II-A code
#: (one codeword per round, a purge discards a straggler's whole task);
#: ``hierarchical`` stacks ``levels`` per-level MDS codewords per
#: dispatch (Ferdinand & Draper), aligned MSB-plane-first with the digit
#: layering, so a straggler's completed sub-tasks stay decode-usable.
CODE_FAMILIES = ("polynomial", "hierarchical")

#: Worker-loss policies (see :mod:`repro.runtime.faults`): ``fail-fast``
#: raises :class:`~repro.runtime.errors.TransportDeadError` on the first
#: dead worker; ``degrade`` quarantines it, re-dispatches its lost tasks
#: to survivors, and releases jobs at a degraded resolution when the
#: fleet drops below the recovery threshold ``k``.
FAULT_POLICIES = ("fail-fast", "degrade")

#: Result/batch compression modes for the socket transport's frame
#: protocol (see :mod:`repro.runtime.transport.socket_host`): ``auto``
#: compresses payloads above a size threshold with the best available
#: codec, ``zlib``/``lz4`` force one codec, ``none`` disables.
COMPRESS_MODES = ("auto", "none", "zlib", "lz4")

#: Shared-memory arena modes for the process backend (see
#: :mod:`repro.runtime.transport.shm`): ``auto`` uses the zero-copy block
#: arena when the platform supports it and silently falls back to the
#: pickled pipe path otherwise; ``on`` requires it (construction fails
#: where shared memory is unavailable); ``off`` disables it.
SHM_MODES = ("auto", "on", "off")

#: Socket frame protocol selection: ``0`` negotiates the highest version
#: both ends speak (LRF2 against a current worker host, LRF1 against an
#: older one); ``1``/``2`` pin the offered protocol (``1`` = the pickled
#: LRF1 frames every release speaks, ``2`` = zero-copy LRF2 ndarray
#: frames).
FRAME_PROTOS = (0, 1, 2)


@dataclasses.dataclass(frozen=True)
class RuntimeConfig:
    """Cluster + code + workload parameters for a runtime execution.

    Mirrors :class:`repro.core.simulator.SystemConfig` where the concepts
    overlap (``mu``, ``arrival_rate``, ``m``, ``omega``, ``gamma``,
    ``complexity``) so measured runs validate directly against
    ``simulate()`` (the paper's §IV system); adds the code geometry
    (``n1``, ``n2``, ``d``), the straggler-injection model that the
    simulator only samples, and the online redundancy controller
    (``adapt``, see :mod:`repro.runtime.adaptive`).

    Units: every duration field (``deadline``, ``stall_seconds``,
    ``shift_at``, ``burst_period``, ``burst_len``) is wall-clock seconds;
    ``arrival_rate`` and ``mu`` are per-second rates.  Instances are frozen
    (hashable, safely shared across threads); all derived properties are
    pure functions of the fields.
    """

    mu: tuple[float, ...] = (385.95, 650.92, 373.40, 415.75, 373.98)
    arrival_rate: float = 50.0     # Poisson job arrivals per second
    n1: int = 2                    # polynomial-code column blocks of A
    n2: int = 2                    # polynomial-code column blocks of B
    omega: float = 1.5             # redundancy ratio: T = ceil(n1*n2*omega)
    m: int = 2                     # digit chunks -> L = 2m-1 resolutions
    d: int = 8                     # digit width (bits)
    gamma: float = 1.0             # eq. (1) moment trade-off
    complexity: float = 1.0        # per-task complexity (full, unlayered)
    deadline: Optional[float] = None   # seconds from service start
    straggler: str = "none"        # "none"|"exp"|"stall"|"shift"|"burst"
    stall_workers: tuple[int, ...] = ()   # worker ids that go dark
    stall_seconds: float = 30.0    # stall duration (>> any deadline)
    shift_at: float = 0.0          # "shift": seconds until regime change
    burst_period: float = 1.0      # "burst": seconds between burst starts
    burst_len: float = 0.2        # "burst": stall window per period
    adapt: str = "fixed"           # omega policy: adaptive.POLICIES key
    omega_min: float = 1.0         # adaptive omega lower bound
    omega_max: float = 3.0         # adaptive omega upper bound
    backend: str = "thread"        # worker transport: BACKEND_NAMES key
    use_jax_devices: bool = False  # legacy alias for backend="jax"
    hosts: tuple[str, ...] = ()    # socket backend: "host:port" per worker
    compress: str = "auto"         # socket frame codec: COMPRESS_MODES key
    shm: str = "auto"              # process backend arena: SHM_MODES key
    frame_proto: int = 0           # socket frame protocol: FRAME_PROTOS key
    code_family: str = "polynomial"   # coded-task family: CODE_FAMILIES key
    levels: int = 1                # hierarchical: sub-tasks per dispatch
    fault_policy: str = "fail-fast"   # worker loss: FAULT_POLICIES key
    heartbeat_interval: float = 1.0   # socket: seconds between pings
    heartbeat_timeout: float = 15.0   # socket: silence -> worker dead
    reconnect_attempts: int = 2       # socket: re-dials before giving up
    reconnect_backoff: float = 0.05   # socket: base re-dial backoff (s)
    reconnect_backoff_cap: float = 2.0  # socket: exp backoff ceiling (s)
    trace: bool = False            # structured tracing (telemetry module);
    #                                off by default and free when off
    seed: int = 0

    def __post_init__(self):
        if self.straggler not in ("none", "exp", "stall", "shift", "burst"):
            raise ValueError(f"unknown straggler model {self.straggler!r}")
        if self.backend not in BACKEND_NAMES:
            raise ValueError(f"unknown worker backend {self.backend!r}; "
                             f"known: {BACKEND_NAMES}")
        if self.use_jax_devices and self.backend not in ("thread", "jax"):
            # the legacy flag only upgrades the default thread selection;
            # combined with an explicit other backend it would be silently
            # ignored — reject the contradiction instead
            raise ValueError(
                f"use_jax_devices (legacy alias for backend='jax') "
                f"conflicts with backend={self.backend!r}")
        if self.compress not in COMPRESS_MODES:
            raise ValueError(f"unknown compress mode {self.compress!r}; "
                             f"known: {COMPRESS_MODES}")
        if self.backend == "socket":
            if len(self.hosts) != self.num_workers:
                raise ValueError(
                    f"backend='socket' needs one host:port per worker: got "
                    f"{len(self.hosts)} hosts for {self.num_workers} "
                    f"workers (mu has {self.num_workers} entries)")
            for h in self.hosts:
                host, sep, port = h.rpartition(":")
                if not sep or not host or not port.isdigit():
                    raise ValueError(
                        f"socket host {h!r} is not of the form 'host:port'")
        elif self.hosts:
            # hosts with a non-socket backend would be silently ignored —
            # reject the contradiction, mirroring the use_jax_devices rule
            raise ValueError(
                f"hosts= is only meaningful with backend='socket' "
                f"(got backend={self.backend!r})")
        if self.shm not in SHM_MODES:
            raise ValueError(f"unknown shm mode {self.shm!r}; "
                             f"known: {SHM_MODES}")
        if self.shm == "on" and self.backend != "process":
            # "on" is a hard requirement for the shared-memory arena,
            # which only the process backend implements; with any other
            # backend it would be silently ignored — reject the
            # contradiction, mirroring the hosts= rule ("auto"/"off" are
            # fine anywhere: no-ops off the process backend)
            raise ValueError(
                f"shm='on' is only meaningful with backend='process' "
                f"(got backend={self.backend!r})")
        if self.frame_proto not in FRAME_PROTOS:
            raise ValueError(f"unknown frame_proto {self.frame_proto!r}; "
                             f"known: {FRAME_PROTOS}")
        if self.frame_proto and self.backend != "socket":
            # a pinned frame protocol with a non-socket backend would be
            # silently ignored — reject the contradiction (0 = negotiate
            # is the anywhere-safe default)
            raise ValueError(
                f"frame_proto={self.frame_proto} is only meaningful with "
                f"backend='socket' (got backend={self.backend!r})")
        if self.code_family not in CODE_FAMILIES:
            raise ValueError(f"unknown code family {self.code_family!r}; "
                             f"known: {CODE_FAMILIES}")
        if self.code_family == "hierarchical":
            if self.levels < 2:
                raise ValueError(
                    f"code_family='hierarchical' needs levels >= 2 (one "
                    f"level IS the polynomial family); got {self.levels}")
            if self.shm == "on":
                # group dispatches carry per-level slices over the pickled
                # pipe path — the block arena's seq-keyed ring reclamation
                # is level-blind, so requiring it would silently degrade
                # to pickling anyway; reject the contradiction
                raise ValueError(
                    "shm='on' is incompatible with "
                    "code_family='hierarchical': group dispatch bypasses "
                    "the block arena (use shm='auto' or 'off')")
        elif self.levels != 1:
            # a level count with the flat family would be silently
            # ignored — reject the contradiction, mirroring hosts=
            raise ValueError(
                f"levels={self.levels} is only meaningful with "
                f"code_family='hierarchical' (got "
                f"code_family={self.code_family!r})")
        if self.fault_policy not in FAULT_POLICIES:
            raise ValueError(f"unknown fault policy {self.fault_policy!r}; "
                             f"known: {FAULT_POLICIES}")
        if self.heartbeat_interval <= 0.0:
            raise ValueError(f"heartbeat_interval must be > 0, got "
                             f"{self.heartbeat_interval}")
        if self.heartbeat_timeout <= self.heartbeat_interval:
            raise ValueError(
                f"heartbeat_timeout ({self.heartbeat_timeout}) must exceed "
                f"heartbeat_interval ({self.heartbeat_interval}): a timeout "
                f"shorter than one ping period declares every worker dead")
        if self.reconnect_attempts < 0:
            raise ValueError(f"reconnect_attempts must be >= 0, got "
                             f"{self.reconnect_attempts}")
        if not 0.0 < self.reconnect_backoff <= self.reconnect_backoff_cap:
            raise ValueError(
                f"need 0 < reconnect_backoff <= reconnect_backoff_cap, got "
                f"{self.reconnect_backoff} / {self.reconnect_backoff_cap}")
        if self.omega < 1.0:
            raise ValueError(f"redundancy ratio must be >= 1, got {self.omega}")
        if any(not 0 <= w < len(self.mu) for w in self.stall_workers):
            raise ValueError(f"stall_workers {self.stall_workers} out of "
                             f"range for {len(self.mu)} workers")
        if not 1.0 <= self.omega_min <= self.omega_max:
            raise ValueError(f"need 1 <= omega_min <= omega_max, got "
                             f"[{self.omega_min}, {self.omega_max}]")
        if self.straggler == "burst" and not (
                0.0 < self.burst_len <= self.burst_period):
            raise ValueError(f"need 0 < burst_len <= burst_period, got "
                             f"{self.burst_len} / {self.burst_period}")
        if self.straggler in ("shift", "burst") and not self.stall_workers:
            raise ValueError(
                f"straggler={self.straggler!r} needs stall_workers: with "
                f"none, the regime change is a silent no-op (plain 'exp')")

    @property
    def num_workers(self) -> int:
        return len(self.mu)

    @property
    def k(self) -> int:
        """Recovery threshold: any k of the T coded tasks decode a round."""
        return self.n1 * self.n2

    @property
    def total_tasks(self) -> int:
        return max(self.k, math.ceil(self.k * self.omega))

    @property
    def num_layers(self) -> int:
        return layering.num_layers(self.m)

    @property
    def num_rounds(self) -> int:
        return self.m * self.m

    @property
    def minijob_complexity(self) -> float:
        return self.complexity / (self.m * self.m)

    def code(self, omega: Optional[float] = None) -> coding.PolynomialCode:
        """The float-mode polynomial code for this geometry.

        ``omega`` overrides the configured redundancy (same ``k``, different
        codeword length ``T``) — how the adaptive controller materializes a
        retuned geometry while everything else stays fixed.
        """
        return coding.PolynomialCode(
            n1=self.n1, n2=self.n2,
            omega=self.omega if omega is None else omega, mode="float")

    def hier_code(self, levels: Optional[int] = None,
                  omega: Optional[float] = None) -> coding.HierarchicalCode:
        """The hierarchical code family for this geometry.

        ``levels`` overrides the configured level count (the master clips
        the last dispatch group of a job to the rounds that remain);
        ``omega`` overrides the redundancy the same way :meth:`code` does,
        so the adaptive controller's retunes and the fault supervisor's
        fleet refits flow into the per-level lengths unchanged.
        """
        return coding.HierarchicalCode(
            n1=self.n1, n2=self.n2,
            levels=self.levels if levels is None else levels,
            omega=self.omega if omega is None else omega, mode="float")

    def to_system_config(self):
        """The §IV simulator configuration this runtime config realises.

        Time units line up because the simulator's per-task time for
        complexity c on worker p is Exp(mu_p / c) — exactly the runtime's
        "exp" straggler injection in seconds.
        """
        from repro.core import simulator
        return simulator.SystemConfig(
            mu=self.mu, arrival_rate=self.arrival_rate, k=self.k,
            complexity=self.complexity, m=self.m, omega=self.omega,
            gamma=self.gamma)

    def load_split(self, total: Optional[int] = None,
                   active: Optional[tuple[int, ...]] = None) -> np.ndarray:
        """Eq. (1) integer task split kappa_p over workers (sum == total).

        ``total`` defaults to the configured ``total_tasks``; the adaptive
        controller passes a retuned codeword length instead, recomputing
        the split for the new ``T`` against the same worker moments.

        ``active`` restricts the split to a surviving subset of workers
        (the fault supervisor's quarantine path): the eq. (1) optimization
        runs over the survivors' moments only, and every non-active worker
        gets ``kappa_p = 0``.  The returned vector always has
        ``num_workers`` entries so transport indexing is unchanged.
        """
        if active is None:
            active = tuple(range(self.num_workers))
        else:
            active = tuple(sorted(set(active)))
            if not active:
                raise ValueError("load_split needs at least one active "
                                 "worker")
            if any(not 0 <= p < self.num_workers for p in active):
                raise ValueError(f"active workers {active} out of range "
                                 f"for {self.num_workers} workers")
        stats = [scheduling.worker_job_moments(self.mu[p], self.k,
                                               self.minijob_complexity)
                 for p in active]
        sub = scheduling.load_split(
            stats, self.total_tasks if total is None else total, self.gamma)
        if len(active) == self.num_workers:
            return sub
        kappa = np.zeros(self.num_workers, dtype=sub.dtype)
        kappa[list(active)] = sub
        return kappa


@dataclasses.dataclass(frozen=True)
class JobSpec:
    """One job: compute ``a.T @ b`` with layered resolution.

    ``a (K, M)`` and ``b (K, N)``; float inputs are quantized to ``m*d``
    bits at service start (ints pass through).  ``arrival`` is the offset in
    seconds from the run start at which the job enters the queue.

    The serving fields give each job its *own* deadline contract (the
    multi-tenant gateway's per-request semantics) instead of the global
    ``RuntimeConfig.deadline``:

    ``deadline_at``
        Absolute release instant, seconds from the run start (same clock
        as ``arrival``).  Unlike the §IV trace rule — which terminates
        only with BOTH deadline excess AND a queued successor — a per-job
        deadline is unconditional: an open request stream is the queued
        successor in the limit, so the job releases its best-ready
        resolution at this instant no matter what is behind it.  Takes
        precedence over ``RuntimeConfig.deadline``.
    ``min_resolution``
        Resolutions up to this index are computed even past
        ``deadline_at`` (the "always release *something*" serving
        guarantee; -1 disables it, so a job that starts after its
        deadline releases immediately with nothing).
    ``max_resolution``
        Caps the job at ``cumulative_minijobs(m)[max_resolution]``
        rounds — how a down-resolved admission actually sheds fleet
        work.  A capped job that runs all its rounds is *complete* (not
        terminated): it delivered its admitted resolution.
    ``result``
        Optional pre-built :class:`~repro.runtime.fusion.LayeredResult`
        the master publishes into; lets a submitter hold the future
        *before* the job reaches service (the gateway's drain thread
        waits on it).  The master builds its own when None.
    """

    job_id: int
    a: np.ndarray
    b: np.ndarray
    arrival: float = 0.0
    deadline_at: Optional[float] = None
    min_resolution: int = -1
    max_resolution: Optional[int] = None
    result: Optional[object] = dataclasses.field(
        default=None, repr=False, compare=False)

    def __post_init__(self):
        if self.deadline_at is not None and self.deadline_at < 0.0:
            raise ValueError(
                f"deadline_at is seconds from run start, must be >= 0; "
                f"got {self.deadline_at}")
        if self.min_resolution < -1:
            raise ValueError(f"min_resolution must be >= -1 (-1 = no "
                             f"guarantee), got {self.min_resolution}")
        if self.max_resolution is not None:
            if self.max_resolution < 0:
                raise ValueError(f"max_resolution must be >= 0, got "
                                 f"{self.max_resolution}")
            if self.min_resolution > self.max_resolution:
                raise ValueError(
                    f"min_resolution {self.min_resolution} exceeds "
                    f"max_resolution {self.max_resolution}")


class RoundContext:
    """Purge/cancel state shared by one round's coded tasks.

    ``cancel`` is set when the round fuses (purge) or the job is terminated;
    workers block on it instead of sleeping so reclamation is immediate.
    The event is a *local* primitive: in-process backends share it with
    their workers directly, while remote backends keep it master-side (the
    fusion node still checks it to drop stale results) and propagate the
    purge over the wire against ``seq`` — the transport-assigned, globally
    monotonic dispatch sequence number (-1 until submitted).
    """

    __slots__ = ("job_id", "round_idx", "cancel", "seq")

    def __init__(self, job_id: int, round_idx: int):
        self.job_id = job_id
        self.round_idx = round_idx
        self.cancel = threading.Event()
        self.seq = -1

    @property
    def cancelled(self) -> bool:
        return self.cancel.is_set()

    def purge(self) -> None:
        self.cancel.set()


@dataclasses.dataclass(frozen=True)
class RoundBatch:
    """One worker's slice of a round's codeword, dispatched as a unit.

    ``x``/``y`` are zero-copy views into the round's encoded ``(T, K, *)``
    buffers (``X[lo:hi]``), not per-task copies: the worker indexes task
    ``i`` as ``x[i]``/``y[i]`` (again views) right before computing.  One
    queue append + one notify per worker per round, instead of ``kappa_p``
    task objects.
    """

    ctx: RoundContext
    first_task_id: int      # codeword index of x[0]
    x: np.ndarray           # (n, K, M/n1) view of coded A blocks
    y: np.ndarray           # (n, K, N/n2) view of coded B blocks
    delays: np.ndarray      # (n,) injected straggler delays (seconds)

    @property
    def count(self) -> int:
        return self.x.shape[0]

    @property
    def job_id(self) -> int:
        return self.ctx.job_id

    @property
    def round_idx(self) -> int:
        return self.ctx.round_idx

    def to_wire(self) -> "WireBatch":
        """Serializable twin of this batch (drops the live context).

        Pickling an ndarray view serializes only the viewed slice, so the
        wire form stays as small as the batch itself.
        """
        return WireBatch(seq=self.ctx.seq, job_id=self.ctx.job_id,
                         round_idx=self.ctx.round_idx,
                         first_task_id=self.first_task_id,
                         x=self.x, y=self.y, delays=self.delays)


@dataclasses.dataclass(frozen=True)
class WireBatch:
    """Transport-serializable form of :class:`RoundBatch`.

    Primitives + ndarrays only — safe over a pipe, socket, or shared
    memory.  ``seq`` is the transport's monotonic dispatch counter: a purge
    message names a sequence watermark, and a remote worker drops every
    batch (queued or in-flight) with ``seq <= watermark``.
    """

    seq: int
    job_id: int
    round_idx: int
    first_task_id: int
    x: np.ndarray           # (n, K, M/n1) coded A blocks
    y: np.ndarray           # (n, K, N/n2) coded B blocks
    delays: np.ndarray      # (n,) injected straggler delays (seconds)

    @property
    def count(self) -> int:
        return self.x.shape[0]


@dataclasses.dataclass(frozen=True)
class GroupBatch:
    """One worker's slice of a hierarchical dispatch group (local form).

    ``levels`` holds one :class:`RoundBatch` per level the worker was
    assigned sub-tasks for, in MSB-first level order — level l is
    plane-pair round ``base_round + l``.  The worker runs them in order
    with a cancellation checkpoint before every sub-task, so a purge of
    one fused level skips exactly that level's remainder while later
    levels (banked ahead-of-frontier work) keep computing.  Each level
    keeps its *own* :class:`RoundContext` (they fuse and purge
    independently); the group shares one transport ``seq``.
    """

    levels: tuple[RoundBatch, ...]

    @property
    def count(self) -> int:
        return sum(b.count for b in self.levels)


@dataclasses.dataclass(frozen=True)
class WireGroup:
    """Transport-serializable twin of :class:`GroupBatch`.

    One :class:`WireBatch` per level, all stamped with the group's shared
    ``seq``: the existing purge watermark drops a whole queued group,
    while a ``purgelvl`` message (seq + round index) cancels a single
    fused level without touching its siblings.
    """

    seq: int
    job_id: int
    base_round: int
    levels: tuple[WireBatch, ...]

    @property
    def count(self) -> int:
        return sum(b.count for b in self.levels)


@dataclasses.dataclass(frozen=True)
class TaskResult:
    """A completed coded task, as delivered to the fusion node."""

    job_id: int
    round_idx: int
    task_id: int
    worker_id: int
    value: np.ndarray       # (M/n1, N/n2)
    finished_at: float      # wall-clock (time.monotonic)

    def to_wire(self) -> tuple:
        """Flat picklable tuple (the cross-process result envelope)."""
        return (self.job_id, self.round_idx, self.task_id, self.worker_id,
                self.value, self.finished_at)

    @staticmethod
    def from_wire(wire: tuple) -> "TaskResult":
        """Rebuild a result on the master side of a transport."""
        job_id, round_idx, task_id, worker_id, value, finished_at = wire
        return TaskResult(job_id=job_id, round_idx=round_idx,
                          task_id=task_id, worker_id=worker_id,
                          value=value, finished_at=finished_at)


# -- shared-memory arena descriptors ------------------------------------------
#
# The zero-copy twins of WireBatch / TaskResult.to_wire(): when master and
# worker share a BlockArena (repro.runtime.transport.shm), the pipe
# carries only these descriptors — a few ints and a dtype string — and
# each side maps the block payloads as ndarray views into the arena.
# ``seq`` plays double duty: the purge watermark AND the ring-allocator
# reclamation key, so slot lifetime rides the purge protocol that already
# exists.

@dataclasses.dataclass(frozen=True)
class ArenaSlice:
    """One block's location in a shared-memory arena (wire descriptor).

    ``dtype`` is the numpy dtype *string* (``'<f8'``), not the dtype
    object, so the descriptor pickles as pure primitives.
    """

    offset: int             # byte offset into the arena segment
    shape: tuple[int, ...]  # ndarray shape of the block
    dtype: str              # np.dtype(...).str

    @property
    def nbytes(self) -> int:
        return int(np.dtype(self.dtype).itemsize * math.prod(self.shape))


@dataclasses.dataclass(frozen=True)
class ArenaBatchRef:
    """Descriptor form of :class:`WireBatch`: blocks live in the dispatch
    arena, only ``delays`` (a ``(n,)`` float vector) rides the pipe."""

    seq: int
    job_id: int
    round_idx: int
    first_task_id: int
    x: ArenaSlice           # (n, K, M/n1) coded A blocks, in the arena
    y: ArenaSlice           # (n, K, N/n2) coded B blocks, in the arena
    delays: np.ndarray      # (n,) injected straggler delays (seconds)

    @property
    def count(self) -> int:
        return self.x.shape[0]

    def to_batch(self, arena) -> "WireBatch":
        """Materialize as a :class:`WireBatch` of views into ``arena``
        (any object with a ``view(ArenaSlice) -> ndarray`` method)."""
        return WireBatch(seq=self.seq, job_id=self.job_id,
                         round_idx=self.round_idx,
                         first_task_id=self.first_task_id,
                         x=arena.view(self.x), y=arena.view(self.y),
                         delays=self.delays)


@dataclasses.dataclass(frozen=True)
class ArenaResultRef:
    """Descriptor form of a result envelope: the value matrix lives in
    the worker's result arena (the compute kernel wrote it there)."""

    job_id: int
    round_idx: int
    task_id: int
    worker_id: int
    seq: int                # dispatch seq of the result's round
    value: ArenaSlice       # (M/n1, N/n2) product block, in the arena
    finished_at: float      # worker-side time.monotonic

    def to_result(self, arena) -> "TaskResult":
        """Materialize as a :class:`TaskResult` whose value is a zero-copy
        view into ``arena`` — handed straight to the fusion sink."""
        return TaskResult(job_id=self.job_id, round_idx=self.round_idx,
                          task_id=self.task_id, worker_id=self.worker_id,
                          value=arena.view(self.value),
                          finished_at=self.finished_at)
