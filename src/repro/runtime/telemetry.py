"""Low-overhead structured tracing for the layered-resolution runtime.

The runtime's headline artifacts are *timing distributions* — res-0 delay
vs final, deadline success under stragglers (paper §IV, Figs. 4–5) — but
aggregate counters cannot answer "which worker stalled round 17, when did
its purge land, and why did res-1 miss the deadline by 3 ms".  This module
is the event layer that can: a :class:`Tracer` collects typed
:class:`TraceEvent` records covering the full task lifecycle

    encode → dispatch(seq) → worker task span → result arrival
           → fused | purged | stale

plus round spans, per-resolution release instants, omega retunes, and
transport liveness (heartbeat RTT, reconnects, dead workers).

Design constraints, in order:

1. **Free when off.**  Tracing is opt-in via
   :attr:`repro.runtime.tasks.RuntimeConfig.trace`; when off the tracer
   is ``None`` and every call site is guarded with ``if tr is not None``
   — no event objects, no dict building, no lock traffic.
2. **Lock-cheap when on.**  Each recording thread appends to its own
   ring buffer (``threading.local``); the only lock is taken once per
   thread at registration and once at collection time.  Worker threads,
   the fusion sink, transport receiver threads, and the master loop never
   contend on a shared structure per event.
3. **One timeline across hosts.**  Remote workers stamp events on their
   *own* monotonic clocks and ship them back piggybacked on result /
   final-stats envelopes; the socket transport estimates each link's
   clock offset from ping/pong exchanges (offset = t_worker − midpoint
   of the master's send/recv instants, taken at the minimum observed
   RTT, so the alignment error is bounded by rtt/2) and
   :meth:`Tracer.ingest` rebases the events into the master's clock
   domain on arrival.

Events are plain ``NamedTuple`` rows (picklable across process/socket
boundaries); exporters live in :mod:`repro.runtime.trace_export`.
"""

from __future__ import annotations

import http.server
import threading
import time
from typing import Iterable, List, NamedTuple, Optional, Tuple

__all__ = [
    "TraceEvent", "Tracer", "EVENT_KINDS", "SPAN_KINDS", "INSTANT_KINDS",
    "PREP", "ENCODE", "DISPATCH", "ROUND", "DECODE", "RESOLUTION", "JOB",
    "RETUNE", "TASK", "RESULT", "FUSED", "STALE", "HEARTBEAT", "RECONNECT",
    "DEAD", "QUARANTINE", "READMIT", "REDISPATCH", "REQUEST", "ADMIT",
    "RELEASE", "ARENA", "serve_metrics", "worker_metrics_text",
]

clock = time.monotonic

# -- event taxonomy -----------------------------------------------------------
#
# Master pipeline (one per master loop iteration / stage):
PREP = "prep"              # span: operand prep for one job
ENCODE = "encode"          # span: polynomial encode of one round
DISPATCH = "dispatch"      # instant: round handed to transport; value = seq
ROUND = "round"            # span: dispatch → fuse/purge; label fused|purged
DECODE = "decode"          # span: decode + accumulate of one fused round
RESOLUTION = "resolution"  # instant: resolution l released; value = l
JOB = "job"                # span: service start → completed|terminated
RETUNE = "retune"          # instant: omega retuned; value = new omega
# Fusion node (result arrival at the master sink):
RESULT = "result"          # instant: accepted result; task/worker set
FUSED = "fused"            # instant: k-th result fused the round
STALE = "stale"            # instant: rejected result (late/purged round)
# Worker side (stamped on the executing host's clock, rebased on ingest):
TASK = "task"              # span: delay wait + compute; label done|purged,
#                            value = injected delay (seconds)
# Transport liveness:
HEARTBEAT = "hb"           # instant: pong received; value = RTT (seconds)
RECONNECT = "reconnect"    # instant: link re-established after a drop
DEAD = "dead"              # instant: worker declared dead; label = reason
# Fault supervision (degrade policy, repro.runtime.faults):
QUARANTINE = "quarantine"  # instant: dead worker removed from the fleet;
#                            label = death reason
READMIT = "readmit"        # instant: quarantined worker rejoined (socket
#                            reconnect + hello/watermark resync)
REDISPATCH = "redispatch"  # instant: a lost slice re-sent to a survivor;
#                            value = task count, worker = new owner
# Zero-copy wire path (repro.runtime.transport.shm):
ARENA = "arena"            # instant: arena event; label = reclaim (slots
#                            recycled at a purge; value = peak dispatch-
#                            ring occupancy fraction) | fallback (ring
#                            full, slice took the pickled pipe path)
# Serving gateway (repro.runtime.gateway, one lifecycle per request):
REQUEST = "request"        # span: submit -> client release; label =
#                            admitted|down-resolved|rejected[/degraded],
#                            value = released resolution (-1 = nothing)
ADMIT = "admit"            # instant: admission verdict; value = admitted
#                            resolution (-1 = rejected), label = decision
RELEASE = "release"        # instant: client release (deadline fire or
#                            early completion); value = resolution

SPAN_KINDS = frozenset({PREP, ENCODE, ROUND, DECODE, JOB, TASK, REQUEST})
INSTANT_KINDS = frozenset({DISPATCH, RESOLUTION, RETUNE, RESULT, FUSED,
                           STALE, HEARTBEAT, RECONNECT, DEAD, QUARANTINE,
                           READMIT, REDISPATCH, ADMIT, RELEASE, ARENA})
EVENT_KINDS = SPAN_KINDS | INSTANT_KINDS


class TraceEvent(NamedTuple):
    """One typed trace record.

    ``t`` is seconds on the recorder's monotonic clock — after
    :meth:`Tracer.ingest` rebasing, always the *master's* clock domain.
    ``dur`` is 0.0 for instants.  Unused id fields are -1; ``value``
    carries the kind-specific scalar payload (seq, layer, omega, RTT,
    injected delay) and ``label`` the kind-specific tag
    (``done``/``purged``/``fused``/reason strings).
    """

    kind: str
    t: float
    dur: float = 0.0
    job: int = -1
    round: int = -1
    task: int = -1
    worker: int = -1
    value: float = 0.0
    label: str = ""


class _Ring:
    """A bounded per-thread event buffer: overwrite-oldest on overflow."""

    __slots__ = ("buf", "cap", "head", "dropped")

    def __init__(self, cap: int):
        self.buf: List[TraceEvent] = []
        self.cap = cap
        self.head = 0           # next overwrite slot once full
        self.dropped = 0

    def append(self, ev: TraceEvent) -> None:
        if len(self.buf) < self.cap:
            self.buf.append(ev)
        else:
            self.buf[self.head] = ev
            self.head = (self.head + 1) % self.cap
            self.dropped += 1

    def snapshot(self) -> List[TraceEvent]:
        if self.head:
            return self.buf[self.head:] + self.buf[:self.head]
        return list(self.buf)

    def clear(self) -> None:
        self.buf = []
        self.head = 0


class Tracer:
    """Lock-cheap multi-thread event collector.

    Every recording thread gets its own :class:`_Ring` (created lazily,
    registered once under the tracer lock); :meth:`emit` is then a pure
    thread-local append.  :meth:`events` merges all rings time-sorted;
    :meth:`drain` additionally clears them — the worker-host side uses
    drain to piggyback pending events onto outbound envelopes.
    """

    def __init__(self, capacity: int = 1 << 16):
        self._capacity = capacity
        self._local = threading.local()
        self._rings: List[_Ring] = []
        self._lock = threading.Lock()

    def _ring(self) -> _Ring:
        ring = getattr(self._local, "ring", None)
        if ring is None:
            ring = _Ring(self._capacity)
            with self._lock:
                self._rings.append(ring)
            self._local.ring = ring
        return ring

    def emit(self, kind: str, t: float, dur: float = 0.0, job: int = -1,
             round: int = -1, task: int = -1, worker: int = -1,
             value: float = 0.0, label: str = "") -> None:
        """Record one event on the calling thread's ring."""
        self._ring().append(
            TraceEvent(kind, t, dur, job, round, task, worker, value, label))

    def ingest(self, events: Iterable[Tuple], shift: float = 0.0) -> None:
        """Adopt remote-stamped events, rebased into this clock domain.

        ``shift`` is added to every timestamp: for a link with estimated
        clock offset ``off = worker_clock − master_clock``, pass
        ``shift=-off`` so remote spans land on the master timeline.
        """
        ring = self._ring()
        if shift == 0.0:
            for ev in events:
                ring.append(TraceEvent(*ev))
        else:
            for ev in events:
                ring.append(TraceEvent(ev[0], ev[1] + shift, *ev[2:]))

    def events(self) -> List[TraceEvent]:
        """All recorded events, time-sorted (non-destructive)."""
        with self._lock:
            rings = list(self._rings)
        out: List[TraceEvent] = []
        for ring in rings:
            out.extend(ring.snapshot())
        out.sort(key=lambda ev: ev.t)
        return out

    def drain(self) -> List[TraceEvent]:
        """Take and clear all pending events (time-sorted)."""
        with self._lock:
            rings = list(self._rings)
        out: List[TraceEvent] = []
        for ring in rings:
            out.extend(ring.snapshot())
            ring.clear()
        out.sort(key=lambda ev: ev.t)
        return out

    @property
    def dropped(self) -> int:
        """Events lost to ring overflow (0 unless a run out-paced the
        per-thread capacity)."""
        with self._lock:
            return sum(r.dropped for r in self._rings)


# -- live metrics endpoint ----------------------------------------------------

def worker_metrics_text(runner, *, worker_id: int = -1,
                        sessions: int = 0) -> str:
    """Prometheus text-format snapshot of one worker host's live counters.

    ``runner`` is the host's current :class:`~repro.runtime.worker.
    BatchRunner` (or ``None`` between sessions); served by
    ``runctl serve-worker --metrics-port`` for scraping mid-run.
    """
    wid = getattr(runner, "worker_id", worker_id)
    busy = getattr(runner, "busy_seconds", 0.0)
    done = getattr(runner, "tasks_done", 0)
    purged = getattr(runner, "tasks_purged", 0)
    lab = f'{{worker="{wid}"}}'
    return "".join([
        "# HELP repro_worker_busy_seconds Injected-delay + compute "
        "occupancy of this worker host.\n",
        "# TYPE repro_worker_busy_seconds counter\n",
        f"repro_worker_busy_seconds{lab} {busy:.6f}\n",
        "# HELP repro_worker_tasks_done_total Coded tasks computed and "
        "emitted.\n",
        "# TYPE repro_worker_tasks_done_total counter\n",
        f"repro_worker_tasks_done_total{lab} {done}\n",
        "# HELP repro_worker_tasks_purged_total Tasks reclaimed by round "
        "purges before completion.\n",
        "# TYPE repro_worker_tasks_purged_total counter\n",
        f"repro_worker_tasks_purged_total{lab} {purged}\n",
        "# HELP repro_worker_sessions_total Master sessions served by "
        "this host process.\n",
        "# TYPE repro_worker_sessions_total counter\n",
        f"repro_worker_sessions_total{lab} {sessions}\n",
    ])


def serve_metrics(render, port: int = 0, host: str = "127.0.0.1"):
    """Serve ``render()`` as a Prometheus text endpoint on ``/metrics``.

    Returns ``(server, bound_port)``; the server runs on a daemon thread
    until ``server.shutdown()``.  ``render`` is called per request, so the
    text always reflects live counters.
    """

    class _Handler(http.server.BaseHTTPRequestHandler):
        def do_GET(self):  # noqa: N802 - stdlib handler naming
            if self.path not in ("/", "/metrics"):
                self.send_error(404)
                return
            body = render().encode("utf-8")
            self.send_response(200)
            self.send_header("Content-Type",
                             "text/plain; version=0.0.4; charset=utf-8")
            self.send_header("Content-Length", str(len(body)))
            self.end_headers()
            self.wfile.write(body)

        def log_message(self, *args):  # silence per-request stderr spam
            del args

    server = http.server.ThreadingHTTPServer((host, port), _Handler)
    thread = threading.Thread(target=server.serve_forever,
                              name="metrics-endpoint", daemon=True)
    thread.start()
    return server, server.server_address[1]
