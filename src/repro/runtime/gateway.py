"""Multi-tenant layered serving gateway over one shared runtime fleet.

The paper's serving story, measured: many concurrent requests — each a
layered matmul job with its own deadline and an optional minimum
acceptable resolution — multiplex over a single warm worker fleet, and
every request is released to its client at its best-ready resolution the
moment its deadline fires (or earlier, on completion).  Three moving
parts:

* **Continuous admission.**  The gateway owns a
  :class:`~repro.runtime.master.Master` running
  :meth:`~repro.runtime.master.Master.serve_queue` on a background
  thread: submitted requests become
  :class:`~repro.runtime.tasks.JobSpec` items on an open
  :class:`~repro.runtime.master.JobQueue`, entering the master's
  encode-ahead pipeline between rounds — no fleet restart, one transport
  for the whole stream.

* **Queueing-bound admission control** (``admission="gg1"``).  The
  G/G/1 machinery of :mod:`repro.core.queueing` (paper eqs. 2-4) prices
  a request before it is queued: estimated delay at resolution ``l`` is
  ``backlog + W + E[T_s] * cum(l)/m**2`` with ``W`` Marchal's waiting
  time (:func:`~repro.core.queueing.gg1_waiting_time`) over measured
  arrival/service moments (modeled priors until enough samples land).
  A request whose deadline cannot cover the full-resolution estimate is
  *down-resolved* to the largest resolution that fits — its job's round
  budget is capped, so LSB rounds it would never release are never
  computed — and one that cannot even meet its minimum acceptable
  resolution is *rejected* at the door.  ``admission="none"`` admits
  everything at the requested resolution (load-generation mode).

* **Deadline-fire release.**  A background drain thread watches every
  outstanding :class:`Ticket` and finalizes it at the earlier of the
  job's release (completion or the master's §IV termination) and the
  request's own deadline — so a client is answered *at the deadline*
  even when its job is still queued behind a long service.  A request
  released below its admitted resolution is marked ``degraded``.

Per-request outcomes (decision, release resolution, slack, queue wait)
accumulate in a :class:`GatewayStats` artifact — surfaced by
``runctl serve-gateway --json`` — whose always-on event log reconciles
exactly with the counters (and is mirrored into the runtime tracer as
``request``/``admit``/``release`` events when ``cfg.trace`` is on).
"""

from __future__ import annotations

import collections
import dataclasses
import threading
from typing import Optional

import numpy as np

from repro.core import layering
from repro.core.queueing import (Moments, gg1_waiting_time,
                                 service_rate_bound)
from repro.runtime import telemetry
from repro.runtime.fusion import LayeredResult
from repro.runtime.master import JobQueue, Master
from repro.runtime.tasks import JobSpec, RuntimeConfig
from repro.runtime.worker import clock

__all__ = ["ServingGateway", "AdmissionController", "GatewayStats",
           "Ticket"]

#: measured-moment sample floor: below it the admission bound runs on the
#: modeled priors (cfg arrival rate; super-worker service bound)
MIN_SAMPLES = 8


@dataclasses.dataclass
class Ticket:
    """One request's lifecycle record (returned by
    :meth:`ServingGateway.submit`).

    All times are seconds relative to the gateway's clock origin
    (``master.t0``).  ``slack`` is ``deadline_at - released_at``:
    positive when the release beat the deadline.  ``degraded`` means the
    released resolution fell below the *admitted* one — a down-resolve
    at admission is priced, not degraded.
    """

    request_id: int
    decision: str               # admitted | down-resolved | rejected
    arrival: float
    deadline: float             # requested budget (seconds)
    deadline_at: float          # arrival + deadline
    requested_resolution: int
    admitted_resolution: int    # -1 when rejected
    min_resolution: int
    estimate: float             # admission-time delay estimate (seconds)
    service_share: float = 0.0  # this ticket's backlog contribution
    result: Optional[LayeredResult] = dataclasses.field(
        default=None, repr=False)
    released_resolution: int = -1
    released_at: Optional[float] = None
    slack: Optional[float] = None
    degraded: bool = False
    queue_wait: Optional[float] = None
    done: threading.Event = dataclasses.field(
        default_factory=threading.Event, repr=False, compare=False)

    @property
    def admitted(self) -> bool:
        return self.decision != "rejected"

    def wait(self, timeout: Optional[float] = None) -> bool:
        """Block until the gateway releases this request to its client."""
        return self.done.wait(timeout=timeout)

    def value(self) -> np.ndarray:
        """The released resolution's matrix (raises if nothing landed)."""
        if self.result is None or self.released_resolution < 0:
            raise RuntimeError(
                f"request {self.request_id}: no resolution released")
        return self.result.resolution(self.released_resolution)


@dataclasses.dataclass
class GatewayStats:
    """Per-request outcome counters + the authoritative event log.

    ``events`` is always on (unlike the opt-in runtime tracer, which can
    drop on ring overflow): one ``("admit", id, decision, res, t)`` per
    submit and one ``("release", id, res, degraded, t)`` per client
    release.  :meth:`reconcile` proves the counters against it exactly.
    """

    num_layers: int
    submitted: int = 0
    admitted: int = 0
    rejected: int = 0
    down_resolved: int = 0
    released: int = 0
    degraded: int = 0
    release_histogram: dict = dataclasses.field(default_factory=dict)
    slacks: list = dataclasses.field(default_factory=list)
    queue_waits: list = dataclasses.field(default_factory=list)
    records: list = dataclasses.field(default_factory=list)
    events: list = dataclasses.field(default_factory=list)

    def reconcile(self) -> None:
        """Raise ``ValueError`` unless every counter matches the event
        log exactly (valid mid-stream: released may trail admitted)."""
        admits = [e for e in self.events if e[0] == "admit"]
        releases = [e for e in self.events if e[0] == "release"]
        checks = [
            ("submitted", self.submitted, len(admits)),
            ("rejected", self.rejected,
             sum(1 for e in admits if e[2] == "rejected")),
            ("down_resolved", self.down_resolved,
             sum(1 for e in admits if e[2] == "down-resolved")),
            ("admitted", self.admitted, self.submitted - self.rejected),
            ("released", self.released, len(releases)),
            ("degraded", self.degraded,
             sum(1 for e in releases if e[3])),
            ("records", len(self.records), self.submitted),
        ]
        for name, got, want in checks:
            if got != want:
                raise ValueError(
                    f"gateway stats mismatch: {name}={got}, "
                    f"event log says {want}")
        hist: dict = {}
        for e in releases:
            hist[e[2]] = hist.get(e[2], 0) + 1
        if hist != self.release_histogram:
            raise ValueError(
                f"gateway stats mismatch: release_histogram="
                f"{self.release_histogram}, event log says {hist}")

    def deadline_success(self, resolution: int) -> float:
        """Fraction of *submitted* requests that got at least
        ``resolution`` by their deadline (a rejection counts as a miss —
        the client asked and was not served)."""
        if self.submitted == 0:
            return float("nan")
        ok = sum(1 for r in self.records
                 if (r["released_resolution"] >= resolution
                     and r["slack"] is not None and r["slack"] >= 0.0))
        return ok / self.submitted

    def to_json(self) -> dict:
        slacks = [s for s in self.slacks if s is not None]
        waits = [w for w in self.queue_waits if w is not None]
        return {
            "num_layers": self.num_layers,
            "submitted": self.submitted,
            "admitted": self.admitted,
            "rejected": self.rejected,
            "down_resolved": self.down_resolved,
            "released": self.released,
            "degraded": self.degraded,
            "release_histogram": {str(k): v for k, v
                                  in sorted(self.release_histogram.items())},
            "deadline_success": {
                str(l): self.deadline_success(l)
                for l in range(self.num_layers)},
            "mean_slack": (float(np.mean(slacks)) if slacks else None),
            "mean_queue_wait": (float(np.mean(waits)) if waits else None),
            "records": self.records,
        }


class AdmissionController:
    """Queueing-bound admission: price a request, admit/down-resolve/
    reject before it queues.

    The pure bound lives in :meth:`decide` (unit-testable against
    hand-computed G/G/1 numbers); the instance wraps it with *measured*
    arrival/service moments — sliding windows fed by the gateway,
    falling back to modeled priors (cfg arrival rate; the eq.-(3)
    super-worker service bound with exponential-like variance) until
    :data:`MIN_SAMPLES` samples land.
    """

    def __init__(self, cfg: RuntimeConfig, *, policy: str = "gg1",
                 safety: float = 1.3, window: int = 64):
        if policy not in ("gg1", "none"):
            raise ValueError(f"unknown admission policy {policy!r}")
        self.cfg = cfg
        self.policy = policy
        self.safety = float(safety)
        self._service: collections.deque = collections.deque(maxlen=window)
        self._gaps: collections.deque = collections.deque(maxlen=window)
        self._last_arrival: Optional[float] = None
        worker_means = [cfg.k * cfg.complexity / mu for mu in cfg.mu]
        prior = 1.0 / service_rate_bound(worker_means)
        self._service_prior = Moments(prior, 2.0 * prior * prior)
        lam = cfg.arrival_rate
        self._arrival_prior = Moments(1.0 / lam, 2.0 / (lam * lam))

    # -- moment tracking -----------------------------------------------------
    def note_arrival(self, t: float) -> None:
        """Record one arrival instant (monotonic seconds)."""
        if self._last_arrival is not None:
            self._gaps.append(max(t - self._last_arrival, 1e-9))
        self._last_arrival = t

    def note_service(self, seconds: float) -> None:
        """Record one measured *full-resolution-equivalent* service time
        (the gateway normalizes resolution-capped jobs by
        ``m**2 / cum(l)``)."""
        self._service.append(seconds)

    @staticmethod
    def _moments(samples, prior: Moments) -> Moments:
        if len(samples) < MIN_SAMPLES:
            return prior
        arr = np.asarray(samples, dtype=np.float64)
        return Moments(float(arr.mean()), float((arr * arr).mean()))

    def arrival_moments(self) -> Moments:
        return self._moments(self._gaps, self._arrival_prior)

    def service_moments(self) -> Moments:
        return self._moments(self._service, self._service_prior)

    # -- the bound -----------------------------------------------------------
    @staticmethod
    def decide(deadline: float, requested: int, min_resolution: int,
               backlog_seconds: float, arrival: Moments, service: Moments,
               m: int, safety: float = 1.3
               ) -> tuple[str, int, float]:
        """Price resolutions ``requested`` down to ``min_resolution``;
        admit the largest whose estimated delay fits the deadline.

        Estimated delay at resolution ``l`` is ``backlog + W +
        E[T_s] * cum(l)/m**2`` (eq. 2 with eq. 3's layered computational
        share): the work already admitted, Marchal's G/G/1 waiting time,
        and this job's own compute.  ``safety`` inflates the estimate —
        the bound is a mean, not a quantile.  Returns ``(decision,
        admitted_resolution, estimate)``; a rejection carries resolution
        ``-1`` and the floor resolution's (unaffordable) estimate.
        """
        cum = layering.cumulative_minijobs(m)
        m2 = float(m * m)
        wait = gg1_waiting_time(arrival, service)
        floor = max(min_resolution, 0)
        for l in range(requested, floor - 1, -1):
            est = backlog_seconds + wait + service.mean * (cum[l] / m2)
            if safety * est <= deadline:
                return (("admitted" if l == requested else "down-resolved"),
                        l, est)
        est = backlog_seconds + wait + service.mean * (cum[floor] / m2)
        return "rejected", -1, est

    def admit(self, deadline: float, requested: int, min_resolution: int,
              backlog_seconds: float) -> tuple[str, int, float]:
        """Decide under the current (measured-or-prior) moments."""
        arrival = self.arrival_moments()
        service = self.service_moments()
        if self.policy == "none":
            cum = layering.cumulative_minijobs(self.cfg.m)
            est = (backlog_seconds + gg1_waiting_time(arrival, service)
                   + service.mean * (cum[requested] / float(self.cfg.m ** 2)))
            return "admitted", requested, est
        return self.decide(deadline, requested, min_resolution,
                           backlog_seconds, arrival, service, self.cfg.m,
                           self.safety)


class ServingGateway:
    """Open-stream serving front-end over one shared runtime fleet.

    Usage::

        gw = ServingGateway(cfg, admission="gg1").start()
        t = gw.submit(a, b, deadline=0.05)      # returns immediately
        t.wait()                                # released by its deadline
        if t.released_resolution >= 0:
            y = t.value()
        stats = gw.stop()                       # GatewayStats artifact

    Threads: ``gateway-master`` runs
    :meth:`Master.serve_queue <repro.runtime.master.Master.serve_queue>`
    over the shared transport; ``gateway-drain`` finalizes tickets at
    release-or-deadline.  ``submit`` may be called from any number of
    client threads.  :meth:`stop` closes admission, drains every queued
    job, joins both threads, and leaves the fleet shut down; it is
    idempotent, and ``submit`` after ``stop`` raises.
    """

    def __init__(self, cfg: RuntimeConfig, *, admission: str = "gg1",
                 safety: float = 1.3, verify: bool = False,
                 window: int = 64):
        self.cfg = cfg
        self.master = Master(cfg, verify=verify)
        self.queue = JobQueue()
        self.admission = AdmissionController(cfg, policy=admission,
                                             safety=safety, window=window)
        self.stats = GatewayStats(num_layers=cfg.num_layers)
        self._lock = threading.RLock()
        self._drain_cv = threading.Condition(self._lock)
        self._pending: dict[int, Ticket] = {}
        self._next_id = 0
        self._backlog = 0.0          # admitted-but-unreleased service est.
        self._t0: Optional[float] = None
        self._started = False
        self._stopping = False       # drain thread: finalize all + exit
        self._closed = False         # submission refused
        self._master_thread: Optional[threading.Thread] = None
        self._drain_thread: Optional[threading.Thread] = None
        self._master_error: Optional[BaseException] = None
        #: the fleet's RuntimeResult, available after :meth:`stop`
        self.result = None
        self.futures = None

    # -- lifecycle -----------------------------------------------------------
    def start(self) -> "ServingGateway":
        """Start the fleet; returns self once the master clock is live."""
        if self._started:
            raise RuntimeError("gateway already started")
        self._master_thread = threading.Thread(
            target=self._master_main, name="gateway-master", daemon=True)
        self._master_thread.start()
        while not self.master.started.wait(timeout=0.1):
            if not self._master_thread.is_alive():
                raise RuntimeError(
                    "gateway master failed to start") from self._master_error
        self._t0 = self.master.t0
        self._drain_thread = threading.Thread(
            target=self._drain_loop, name="gateway-drain", daemon=True)
        self._drain_thread.start()
        self._started = True
        return self

    def _master_main(self) -> None:
        try:
            self.result, self.futures = self.master.serve_queue(self.queue)
        except BaseException as exc:   # surfaced by stop(); drain thread
            self._master_error = exc   # finalizes orphaned tickets
            self.master.started.set()

    def stop(self) -> GatewayStats:
        """Close admission, drain all queued jobs, join both threads."""
        if not self._started:
            raise RuntimeError("gateway not started")
        with self._lock:
            if self._closed:
                return self.stats      # idempotent
            self._closed = True
        self.queue.close()
        self._master_thread.join(timeout=600.0)
        if self._master_thread.is_alive():
            raise RuntimeError("gateway master failed to drain")
        with self._drain_cv:
            self._stopping = True
            self._drain_cv.notify_all()
        self._drain_thread.join(timeout=60.0)
        if self._drain_thread.is_alive():
            raise RuntimeError("gateway drain thread failed to stop")
        if self._master_error is not None:
            raise RuntimeError(
                "gateway master died mid-stream") from self._master_error
        return self.stats

    def __enter__(self) -> "ServingGateway":
        return self.start() if not self._started else self

    def __exit__(self, *exc) -> None:
        del exc
        self.stop()

    # -- client side ---------------------------------------------------------
    def submit(self, a: np.ndarray, b: np.ndarray, *, deadline: float,
               resolution: Optional[int] = None,
               min_resolution: int = 0) -> Ticket:
        """Admit one layered job ``a.T @ b``; returns its :class:`Ticket`
        immediately (``decision`` tells admitted / down-resolved /
        rejected; a rejected ticket is already ``done``).

        ``deadline`` is seconds from now — the client is answered by
        then, whatever is ready.  ``resolution`` is the requested
        (default: final) resolution; ``min_resolution`` the lowest the
        admission bound may down-resolve to AND the resolution the
        runtime guarantees to finish even past the deadline (pass ``-1``
        for pure best-effort).
        """
        if not self._started:
            raise RuntimeError("gateway not started")
        if deadline <= 0.0:
            raise ValueError(f"deadline must be positive, got {deadline}")
        L = self.cfg.num_layers
        requested = L - 1 if resolution is None else int(resolution)
        if not 0 <= requested < L:
            raise ValueError(f"resolution {requested} not in [0, {L})")
        min_res = int(min_resolution)
        if min_res > requested:
            raise ValueError(
                f"min_resolution {min_res} > requested {requested}")
        cum = layering.cumulative_minijobs(self.cfg.m)
        with self._lock:
            if self._closed:
                raise RuntimeError("gateway is stopped")
            now = clock()
            t_rel = now - self._t0
            self.admission.note_arrival(now)
            decision, adm, est = self.admission.admit(
                deadline, requested, min_res, self._backlog)
            rid = self._next_id
            self._next_id += 1
            ticket = Ticket(
                request_id=rid, decision=decision, arrival=t_rel,
                deadline=deadline, deadline_at=t_rel + deadline,
                requested_resolution=requested, admitted_resolution=adm,
                min_resolution=min_res, estimate=est)
            self.stats.submitted += 1
            self.stats.events.append(("admit", rid, decision, adm, t_rel))
            tr = self.master.tracer
            if tr is not None:
                tr.emit(telemetry.ADMIT, now, job=rid, value=float(adm),
                        label=decision)
            if decision == "rejected":
                self.stats.rejected += 1
                self.stats.records.append(self._record(ticket))
                ticket.done.set()
                return ticket
            self.stats.admitted += 1
            if decision == "down-resolved":
                self.stats.down_resolved += 1
            lr = LayeredResult(rid, L)
            ticket.result = lr
            share = (self.admission.service_moments().mean
                     * (cum[adm] / float(self.cfg.m ** 2)))
            ticket.service_share = share
            self._backlog += share
            job = JobSpec(job_id=rid, a=np.asarray(a), b=np.asarray(b),
                          arrival=t_rel, deadline_at=t_rel + deadline,
                          min_resolution=min_res, max_resolution=adm,
                          result=lr)
            self._pending[rid] = ticket
            # register before put: once queued the master may release the
            # job at any instant, and on_release-after-release would call
            # back on THIS thread while we hold the lock (RLock makes it
            # safe, registration order makes it a non-event)
            lr.on_release(self._on_job_release)
            try:
                self.queue.put(job)
            except RuntimeError:
                self._pending.pop(rid, None)
                self._backlog -= share
                raise
            self.stats.records.append(self._record(ticket))
            return ticket

    # -- drain side ----------------------------------------------------------
    def _on_job_release(self, lr: LayeredResult) -> None:
        # master-thread callback: wake the drain, nothing else
        del lr
        with self._drain_cv:
            self._drain_cv.notify_all()

    def _drain_loop(self) -> None:
        while True:
            with self._drain_cv:
                now = clock()
                ready = [t for t in self._pending.values()
                         if (self._stopping
                             or t.result.wait_released(0.0)
                             or now >= self._t0 + t.deadline_at)]
                if not ready:
                    if self._stopping:
                        return
                    timeout = None
                    if self._pending:
                        nxt = min(self._t0 + t.deadline_at
                                  for t in self._pending.values())
                        timeout = max(nxt - now, 0.0)
                    self._drain_cv.wait(timeout=timeout)
                    continue
                for t in ready:
                    self._finalize(t)

    def _finalize(self, t: Ticket) -> None:
        """Release ticket ``t`` to its client (drain thread, under lock)."""
        lr = t.result
        now = clock()
        job_released = lr.wait_released(0.0)
        res = (lr.released_resolution if job_released
               else lr.best_resolution())
        rel_at = now - self._t0
        if job_released and lr.released_at is not None:
            # the job's own release drove this finalize: stamp ITS instant,
            # not the drain thread's wake-up latency
            rel_at = min(rel_at, lr.released_at - self._t0)
        t.released_resolution = res
        t.released_at = rel_at
        t.slack = t.deadline_at - rel_at
        t.degraded = res < t.admitted_resolution
        if lr.service_started_at is not None:
            t.queue_wait = (lr.service_started_at - self._t0) - t.arrival
            self.stats.queue_waits.append(t.queue_wait)
            if (job_released and not lr.terminated
                    and lr.released_at is not None):
                # feed the admission moments — untruncated services only,
                # normalized to full-m**2 equivalents when the job was
                # resolution-capped
                svc = lr.released_at - lr.service_started_at
                cum = layering.cumulative_minijobs(self.cfg.m)
                frac = cum[t.admitted_resolution] / float(self.cfg.m ** 2)
                if svc > 0.0 and frac > 0.0:
                    self.admission.note_service(svc / frac)
        self._backlog = max(self._backlog - t.service_share, 0.0)
        self._pending.pop(t.request_id, None)
        self.stats.released += 1
        if t.degraded:
            self.stats.degraded += 1
        self.stats.release_histogram[res] = (
            self.stats.release_histogram.get(res, 0) + 1)
        self.stats.slacks.append(t.slack)
        self.stats.events.append(
            ("release", t.request_id, res, t.degraded, rel_at))
        self._update_record(t)
        tr = self.master.tracer
        if tr is not None:
            tr.emit(telemetry.RELEASE, self._t0 + rel_at,
                    job=t.request_id, value=float(res),
                    label="degraded" if t.degraded else "ok")
            tr.emit(telemetry.REQUEST, self._t0 + t.arrival,
                    rel_at - t.arrival, job=t.request_id, value=float(res),
                    label=t.decision + ("/degraded" if t.degraded else ""))
        t.done.set()

    # -- records -------------------------------------------------------------
    @staticmethod
    def _record(t: Ticket) -> dict:
        return {
            "request_id": t.request_id, "decision": t.decision,
            "arrival": t.arrival, "deadline": t.deadline,
            "requested_resolution": t.requested_resolution,
            "admitted_resolution": t.admitted_resolution,
            "min_resolution": t.min_resolution, "estimate": t.estimate,
            "released_resolution": t.released_resolution,
            "released_at": t.released_at, "slack": t.slack,
            "degraded": t.degraded, "queue_wait": t.queue_wait,
        }

    def _update_record(self, t: Ticket) -> None:
        for r in self.stats.records:
            if r["request_id"] == t.request_id:
                r.update(self._record(t))
                return
