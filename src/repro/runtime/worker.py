"""Concurrent worker pool executing real coded matmul tasks.

Each worker is a thread with its own FIFO task queue (the master assigns
``kappa_p`` coded tasks per round, eq. (1)).  A task is a genuine matrix
product ``x.T @ y`` of polynomial-coded blocks; heterogeneity and
stragglers are injected as a pre-task delay sampled by the master from the
pluggable straggler model:

* ``"none"``  — no injected delay; tasks run as fast as the host allows.
* ``"exp"``   — delay ~ Exp(scale = complexity / mu_p), the §IV service
  model (worker p's task time for complexity c is Exp(mu_p / c)).
* ``"stall"`` — like ``"exp"`` but workers listed in ``stall_workers``
  freeze for ``stall_seconds`` per task (a dead/hogged node); redundancy
  (omega > 1) is what keeps rounds fusing without them.
* ``"shift"`` — regime change: ``"exp"`` until ``shift_at`` seconds after
  the first sample, then the ``stall_workers`` go dark (``stall_seconds``
  per task) for the rest of the run — a node failure mid-run, the
  scenario the adaptive omega controller exists for.
* ``"burst"`` — recurring outages: the ``stall_workers`` go dark for the
  first ``burst_len`` seconds of every ``burst_period``-second window,
  ``"exp"`` otherwise — a periodically hogged/GC-ing node.

The time-varying modes are wall-clock based (seconds since the model's
first sample), so every variant of a sweep — static or adaptive omega —
faces the same regime timeline against the same arrival trace.

Workers wait out the injected delay on the round's ``cancel`` event, so a
purge (round fused elsewhere, or job terminated) reclaims a delayed worker
immediately — matching the simulator's master-paced round boundaries.

Optionally (``use_jax_devices``) each worker places its products on a JAX
device (round-robin over ``jax.devices()``); the default compute path is
host BLAS, which releases the GIL so the pool genuinely overlaps.
"""

from __future__ import annotations

import collections
import threading
import time
from typing import Callable, Optional

import numpy as np

from repro.runtime.tasks import RoundBatch, RuntimeConfig, TaskResult

__all__ = ["StragglerModel", "Worker", "WorkerPool", "clock"]

clock = time.monotonic


class StragglerModel:
    """Samples per-task injected delays for each worker (master-side RNG).

    Delays are in seconds.  The time-varying modes (``shift``/``burst``)
    measure elapsed time from the model's first sample; the master
    presamples each round's delays one round ahead, so a regime boundary
    lands within ~one round of its nominal wall-clock instant.
    """

    def __init__(self, cfg: RuntimeConfig, rng: np.random.Generator):
        self._cfg = cfg
        self._rng = rng
        self._origin: float | None = None

    def _elapsed(self) -> float:
        """Seconds since the first sample (the regime clock)."""
        now = clock()
        if self._origin is None:
            self._origin = now
        return now - self._origin

    def _stalled(self, worker_id: int) -> bool:
        """Is this worker dark *right now* under the configured regime?"""
        cfg = self._cfg
        if worker_id not in cfg.stall_workers:
            return False
        if cfg.straggler == "stall":
            return True
        if cfg.straggler == "shift":
            return self._elapsed() >= cfg.shift_at
        if cfg.straggler == "burst":
            return (self._elapsed() % cfg.burst_period) < cfg.burst_len
        return False

    def sample(self, worker_id: int, num_tasks: int) -> np.ndarray:
        """(num_tasks,) delays in seconds for one worker's round queue."""
        cfg = self._cfg
        if self._origin is None:
            # anchor the regime clock on the run's FIRST sample, whoever
            # it is for: a stall-listed worker can legitimately hold
            # kappa = 0 (eq. 1), and anchoring lazily inside its own
            # branch would silently delay or disable the regime change
            self._origin = clock()
        if num_tasks == 0 or cfg.straggler == "none":
            return np.zeros(num_tasks)
        if self._stalled(worker_id):
            return np.full(num_tasks, cfg.stall_seconds)
        scale = cfg.minijob_complexity / cfg.mu[worker_id]
        return self._rng.exponential(scale=scale, size=num_tasks)


def _host_compute(x: np.ndarray, y: np.ndarray) -> np.ndarray:
    return x.T @ y


def _jax_compute(device) -> Callable[[np.ndarray, np.ndarray], np.ndarray]:
    import jax
    import jax.numpy as jnp

    fn = jax.jit(lambda x, y: jnp.matmul(x.T, y))

    def compute(x: np.ndarray, y: np.ndarray) -> np.ndarray:
        return np.asarray(fn(jax.device_put(x, device),
                             jax.device_put(y, device)))

    return compute


class Worker(threading.Thread):
    """One worker thread: FIFO queue, cancellation-aware delay, compute."""

    def __init__(self, worker_id: int,
                 sink: Callable[[TaskResult], None],
                 compute: Callable[[np.ndarray, np.ndarray], np.ndarray]):
        super().__init__(name=f"runtime-worker-{worker_id}", daemon=True)
        self.worker_id = worker_id
        self._sink = sink
        self._compute = compute
        self._queue: collections.deque[RoundBatch] = collections.deque()
        self._cv = threading.Condition()
        self._stopping = False
        self.busy_seconds = 0.0      # occupied (delay + compute), incl. purged
        self.tasks_done = 0
        self.tasks_purged = 0

    def submit_round(self, batch: RoundBatch) -> None:
        """Enqueue one round's whole slice: one append, one notify."""
        with self._cv:
            self._queue.append(batch)
            self._cv.notify()

    def stop(self) -> None:
        with self._cv:
            self._stopping = True
            self._cv.notify()

    def run(self) -> None:
        while True:
            with self._cv:
                while not self._queue and not self._stopping:
                    self._cv.wait()
                if not self._queue:
                    return          # stopping and drained
                batch = self._queue.popleft()
            self._process_batch(batch)

    def _process_batch(self, batch: RoundBatch) -> None:
        for i in range(batch.count):
            if batch.ctx.cancelled:
                self.tasks_purged += batch.count - i
                return
            self._process_one(batch.ctx, batch.first_task_id + i,
                              batch.x[i], batch.y[i],
                              float(batch.delays[i]))

    def _process_one(self, ctx, task_id: int, x: np.ndarray, y: np.ndarray,
                     delay: float) -> None:
        if ctx.cancelled:
            self.tasks_purged += 1
            return
        t0 = clock()
        if delay > 0.0:
            # block on the purge event, not time.sleep: a fused round
            # reclaims this worker immediately.
            if ctx.cancel.wait(timeout=delay):
                self.busy_seconds += clock() - t0
                self.tasks_purged += 1
                return
        elif ctx.cancelled:
            self.tasks_purged += 1
            return
        value = self._compute(x, y)
        now = clock()
        self.busy_seconds += now - t0
        self.tasks_done += 1
        self._sink(TaskResult(job_id=ctx.job_id, round_idx=ctx.round_idx,
                              task_id=task_id, worker_id=self.worker_id,
                              value=value, finished_at=now))


class WorkerPool:
    """The cluster: ``cfg.num_workers`` concurrent workers + straggler model."""

    def __init__(self, cfg: RuntimeConfig,
                 sink: Callable[[TaskResult], None],
                 rng: Optional[np.random.Generator] = None):
        self._cfg = cfg
        self.straggler = StragglerModel(
            cfg, rng if rng is not None else np.random.default_rng(cfg.seed))
        devices = None
        if cfg.use_jax_devices:
            import jax
            devices = jax.devices()
        self.workers = []
        for p in range(cfg.num_workers):
            compute = (_jax_compute(devices[p % len(devices)])
                       if devices else _host_compute)
            self.workers.append(Worker(p, sink, compute))

    def start(self) -> None:
        for w in self.workers:
            w.start()

    def sample_round_delays(self, kappa: np.ndarray) -> list[np.ndarray]:
        """Per-worker injected-delay vectors for one round's split.

        Split out of :meth:`dispatch_round` so the master can presample
        the next round's delays off the critical path (in its
        encode-ahead slot) and dispatch with buffers alone.
        """
        return [self.straggler.sample(p, int(kappa[p]))
                for p in range(len(self.workers))]

    def dispatch_round(self, ctx, X: np.ndarray, Y: np.ndarray,
                      kappa: np.ndarray,
                      delays: Optional[list] = None) -> None:
        """Assign the round's T coded tasks: worker p gets a contiguous
        ``kappa_p``-slice of the codeword as ONE zero-copy
        :class:`RoundBatch` (views into X/Y, no per-task objects), with
        per-task injected delays."""
        if delays is None:
            delays = self.sample_round_delays(kappa)
        lo = 0
        for p, w in enumerate(self.workers):
            hi = lo + int(kappa[p])
            if lo == hi:
                continue
            w.submit_round(RoundBatch(ctx=ctx, first_task_id=lo,
                                      x=X[lo:hi], y=Y[lo:hi],
                                      delays=delays[p]))
            lo = hi

    def shutdown(self, timeout: float = 10.0) -> None:
        for w in self.workers:
            w.stop()
        for w in self.workers:
            w.join(timeout=timeout)

    @property
    def busy_seconds(self) -> np.ndarray:
        return np.asarray([w.busy_seconds for w in self.workers])

    @property
    def tasks_done(self) -> int:
        return sum(w.tasks_done for w in self.workers)

    @property
    def tasks_purged(self) -> int:
        return sum(w.tasks_purged for w in self.workers)
