"""Worker-side execution: compute kernels, batch runner, thread workers.

This module is split along the transport seam (see
:mod:`repro.runtime.transport`):

* **Compute kernels** (:func:`make_compute`) — the actual coded-task math,
  ``x.T @ y`` on host BLAS (releases the GIL) or on a JAX device.  Pure
  functions of the operands; no knowledge of queues or processes.
* **:class:`BatchRunner`** — the backend-agnostic per-batch engine: walk a
  round slice task by task, wait out each task's injected straggler delay
  against a cancellation guard, compute, and emit a
  :class:`~repro.runtime.tasks.TaskResult`.  Every backend (thread,
  process, jax-device) runs its tasks through this one class, so purge
  semantics and occupancy accounting cannot drift between transports.
* **:class:`Worker` / :class:`WorkerPool`** — the in-process *thread*
  transport loop: one thread per worker with a FIFO queue, shared-memory
  :class:`~repro.runtime.tasks.RoundContext` cancellation, and
  deterministic drain-or-purge shutdown.  :class:`WorkerPool` implements
  the :class:`~repro.runtime.transport.base.WorkerTransport` contract and
  is re-exported as the ``thread`` backend.

Each worker executes the ``kappa_p`` coded tasks the master assigned for
the round (eq. (1)).  A task is a genuine matrix product ``x.T @ y`` of
polynomial-coded blocks; heterogeneity and stragglers are injected as a
pre-task delay sampled master-side from the pluggable straggler model:

* ``"none"``  — no injected delay; tasks run as fast as the host allows.
* ``"exp"``   — delay ~ Exp(scale = complexity / mu_p), the §IV service
  model (worker p's task time for complexity c is Exp(mu_p / c)).
* ``"stall"`` — like ``"exp"`` but workers listed in ``stall_workers``
  freeze for ``stall_seconds`` per task (a dead/hogged node); redundancy
  (omega > 1) is what keeps rounds fusing without them.
* ``"shift"`` — regime change: ``"exp"`` until ``shift_at`` seconds after
  the first sample, then the ``stall_workers`` go dark (``stall_seconds``
  per task) for the rest of the run — a node failure mid-run, the
  scenario the adaptive omega controller exists for.
* ``"burst"`` — recurring outages: the ``stall_workers`` go dark for the
  first ``burst_len`` seconds of every ``burst_period``-second window,
  ``"exp"`` otherwise — a periodically hogged/GC-ing node.

The time-varying modes are wall-clock based (seconds since the model's
first sample), so every variant of a sweep — static or adaptive omega —
faces the same regime timeline against the same arrival trace.

Workers wait out the injected delay on the round's cancellation guard, so
a purge (round fused elsewhere, or job terminated) reclaims a delayed
worker immediately — matching the simulator's master-paced round
boundaries.
"""

from __future__ import annotations

import collections
import threading
import time
from typing import Callable, Optional, Protocol

import numpy as np

from repro.runtime import telemetry
from repro.runtime.tasks import (GroupBatch, RoundBatch, RoundContext,
                                 RuntimeConfig, TaskResult, WireBatch)
from repro.runtime.transport.base import StragglerModel, WorkerTransport

__all__ = ["StragglerModel", "Worker", "WorkerPool", "BatchRunner",
           "CancelGuard", "make_compute", "clock"]

clock = time.monotonic

#: Poll granularity (seconds) for long cancellable waits.  Delays shorter
#: than one slice — the typical exp draw — are a single plain wait, so the
#: injected-delay precision the simulator-agreement tests rely on is
#: untouched; only multi-second stalls are sliced, where the slack lets a
#: stopping worker notice a pool-wide purge that bypassed its round guard.
WAIT_SLICE = 0.1


# -- compute kernels ----------------------------------------------------------

def _host_compute(x: np.ndarray, y: np.ndarray,
                  out: Optional[np.ndarray] = None) -> np.ndarray:
    # ``out`` lets a transport provide the destination buffer — the
    # process backend's shared-memory arena path computes each product
    # straight into its result slot, so the value never exists anywhere
    # else.  Same BLAS kernel either way: results are bit-identical.
    if out is None:
        return x.T @ y
    return np.matmul(x.T, y, out=out)


def _jax_compute(device) -> Callable[[np.ndarray, np.ndarray], np.ndarray]:
    import jax
    import jax.numpy as jnp

    fn = jax.jit(lambda x, y: jnp.matmul(x.T, y))

    def compute(x: np.ndarray, y: np.ndarray) -> np.ndarray:
        # dispatch is asynchronous (jit returns immediately); the
        # np.asarray materialization is the only synchronization point,
        # right before the result is emitted to the fusion node.
        out = fn(jax.device_put(x, device), jax.device_put(y, device))
        return np.asarray(out)

    return compute


def make_compute(cfg: RuntimeConfig, worker_id: int, *, device=None
                 ) -> Callable[[np.ndarray, np.ndarray], np.ndarray]:
    """The coded-task kernel for one worker: host BLAS or a JAX device.

    ``device`` pins the worker to a specific JAX device (the ``jax``
    backend passes ``jax.devices()[worker_id % len(devices)]``); with
    ``device=None`` the worker computes on host BLAS, which releases the
    GIL so a thread pool genuinely overlaps.
    """
    del worker_id  # reserved for per-worker kernel variants
    if device is not None:
        return _jax_compute(device)
    return _host_compute


# -- the backend-agnostic batch engine ---------------------------------------

class CancelGuard(Protocol):
    """The cancellation primitive a transport hands the batch runner.

    ``cancelled()`` is the instantaneous probe (checked before every
    task); ``wait(delay)`` blocks for up to ``delay`` seconds and returns
    True the moment the batch is cancelled (purge, termination, or a
    purge-mode shutdown) — the hook that makes straggler reclamation
    immediate on every backend.
    """

    def cancelled(self) -> bool: ...

    def wait(self, delay: float) -> bool: ...


class BatchRunner:
    """Executes round slices for one worker, whatever the transport.

    Owns the worker's occupancy/outcome counters (``busy_seconds`` =
    injected delay + compute, including purged waits; ``tasks_done``;
    ``tasks_purged``) so the accounting is identical across backends.
    ``emit`` delivers each completed :class:`TaskResult` — directly into
    the fusion node for in-process backends, onto the result queue for
    remote ones.
    """

    def __init__(self, worker_id: int,
                 compute: Callable[[np.ndarray, np.ndarray], np.ndarray],
                 emit: Callable[[TaskResult], None],
                 tracer: Optional[telemetry.Tracer] = None):
        self.worker_id = worker_id
        self._compute = compute
        self._emit = emit
        self._tracer = tracer
        self.busy_seconds = 0.0
        self.tasks_done = 0
        self.tasks_purged = 0

    def count_purged(self, batch: RoundBatch | WireBatch,
                     start: int = 0) -> None:
        """Account a batch tail ``[start:]`` abandoned without running.

        Transports call this for slices they drop wholesale (purge-mode
        shutdown, dead-on-arrival remote batches) so the purge counter —
        and, when tracing, the per-task ``purged`` span — stays exact on
        every backend.
        """
        self.tasks_purged += batch.count - start
        if self._tracer is not None:
            now = clock()
            for i in range(start, batch.count):
                self._tracer.emit(telemetry.TASK, now, 0.0, batch.job_id,
                                  batch.round_idx, batch.first_task_id + i,
                                  self.worker_id, 0.0, "purged")

    def run(self, batch: RoundBatch | WireBatch, guard: CancelGuard) -> None:
        """Run one round slice to completion or cancellation."""
        tr = self._tracer
        for i in range(batch.count):
            if guard.cancelled():
                self.count_purged(batch, i)
                return
            t0 = clock()
            delay = float(batch.delays[i])
            if delay > 0.0 and guard.wait(delay):
                # reclaimed mid-delay: the wait so far was real occupancy
                now = clock()
                self.busy_seconds += now - t0
                self.tasks_purged += 1
                if tr is not None:
                    tr.emit(telemetry.TASK, t0, now - t0, batch.job_id,
                            batch.round_idx, batch.first_task_id + i,
                            self.worker_id, delay, "purged")
                self.count_purged(batch, i + 1)
                return
            if guard.cancelled():
                now = clock()
                self.busy_seconds += now - t0
                self.tasks_purged += 1
                if tr is not None:
                    tr.emit(telemetry.TASK, t0, now - t0, batch.job_id,
                            batch.round_idx, batch.first_task_id + i,
                            self.worker_id, delay, "purged")
                self.count_purged(batch, i + 1)
                return
            value = self._compute(batch.x[i], batch.y[i])
            now = clock()
            self.busy_seconds += now - t0
            self.tasks_done += 1
            if tr is not None:
                tr.emit(telemetry.TASK, t0, now - t0, batch.job_id,
                        batch.round_idx, batch.first_task_id + i,
                        self.worker_id, delay, "done")
            self._emit(TaskResult(job_id=batch.job_id,
                                  round_idx=batch.round_idx,
                                  task_id=batch.first_task_id + i,
                                  worker_id=self.worker_id,
                                  value=value, finished_at=now))

    def run_group(self, batches, make_guard) -> None:
        """Run a hierarchical group's level slices in MSB-first order.

        ``make_guard(batch)`` builds each level's own cancellation guard,
        and :meth:`run` re-checks it before every sub-task — the
        between-level (in fact between-sub-task) checkpoint: a level
        purge (that level fused elsewhere) skips exactly that level's
        remaining sub-tasks while later levels still run, and a group
        purge or deadline termination cancels everything *from the next
        checkpoint on*.  Completed sub-tasks were already emitted one by
        one, so a purge never discards shipped progress — the
        hierarchical family's whole point.
        """
        for batch in batches:
            self.run(batch, make_guard(batch))

    def count_purged_any(self, batch) -> None:
        """`count_purged` that also accepts a group form — local
        :class:`GroupBatch` or wire :class:`~repro.runtime.tasks.WireGroup`
        — by dropping every level."""
        levels = getattr(batch, "levels", None)
        if levels is not None:
            for b in levels:
                self.count_purged(b)
        else:
            self.count_purged(batch)


class _EventGuard:
    """Thread-backend guard: the round's shared cancel event + pool stop.

    A purge wakes the wait instantly through the event; a purge-mode
    worker stop is noticed at worst one :data:`WAIT_SLICE` later (only
    relevant for multi-second stall delays — shorter delays are a single
    un-sliced wait).
    """

    __slots__ = ("_ctx", "_worker")

    def __init__(self, ctx, worker: "Worker"):
        self._ctx = ctx
        self._worker = worker

    def cancelled(self) -> bool:
        return self._ctx.cancelled or self._worker.purging

    def wait(self, delay: float) -> bool:
        end = clock() + delay
        while True:
            remaining = end - clock()
            if remaining <= 0.0:
                return False
            if self._ctx.cancel.wait(timeout=min(remaining, WAIT_SLICE)):
                return True
            if self._worker.purging:
                return True


# -- the thread transport loop ------------------------------------------------

class Worker(threading.Thread):
    """One worker thread: FIFO queue, cancellation-aware delay, compute."""

    def __init__(self, worker_id: int,
                 sink: Callable[[TaskResult], None],
                 compute: Callable[[np.ndarray, np.ndarray], np.ndarray],
                 tracer: Optional[telemetry.Tracer] = None):
        super().__init__(name=f"runtime-worker-{worker_id}", daemon=True)
        self.worker_id = worker_id
        self.runner = BatchRunner(worker_id, compute, sink, tracer)
        self._queue: collections.deque[RoundBatch] = collections.deque()
        self._cv = threading.Condition()
        self._stopping = False
        self._purge_on_stop = False

    @property
    def busy_seconds(self) -> float:
        return self.runner.busy_seconds

    @property
    def tasks_done(self) -> int:
        return self.runner.tasks_done

    @property
    def tasks_purged(self) -> int:
        return self.runner.tasks_purged

    @property
    def purging(self) -> bool:
        """True once a purge-mode stop was requested (drains nothing)."""
        return self._stopping and self._purge_on_stop

    def submit_round(self, batch: RoundBatch) -> None:
        """Enqueue one round's whole slice: one append, one notify."""
        with self._cv:
            self._queue.append(batch)
            self._cv.notify()

    def stop(self, *, drain: bool = False) -> None:
        """Request shutdown, deterministically.

        ``drain=True`` finishes every queued batch first (delays and all);
        ``drain=False`` (the default) *purges*: queued and in-flight
        batches are abandoned and counted in ``tasks_purged``, and an
        in-progress delay wait aborts within one :data:`WAIT_SLICE`.
        Either way the thread exits on its own — results can no longer be
        silently dropped by interpreter teardown racing a daemon thread.
        """
        with self._cv:
            self._stopping = True
            self._purge_on_stop = not drain
            self._cv.notify()

    def run(self) -> None:
        while True:
            with self._cv:
                while not self._queue and not self._stopping:
                    self._cv.wait()
                if not self._queue:
                    return          # stopping and drained
                if self.purging:    # stopping in purge mode: count + exit
                    for b in self._queue:
                        self.runner.count_purged_any(b)
                    self._queue.clear()
                    return
                batch = self._queue.popleft()
            if isinstance(batch, GroupBatch):
                self.runner.run_group(
                    batch.levels, lambda b: _EventGuard(b.ctx, self))
            else:
                self.runner.run(batch, _EventGuard(batch.ctx, self))


class WorkerPool(WorkerTransport):
    """The thread backend: ``cfg.num_workers`` worker threads + straggler
    model.

    This is the reference implementation of the
    :class:`~repro.runtime.transport.base.WorkerTransport` contract (the
    ``thread`` backend re-exports it): rounds are submitted as zero-copy
    :class:`RoundBatch` views (the seq-stamp + eq. (1) slicing loop is
    the base class's; only the per-worker hop lives here), results flow
    straight into ``sink`` from the worker threads, and purges propagate
    through the shared :class:`~repro.runtime.tasks.RoundContext` cancel
    event.
    """

    name = "thread"

    def __init__(self, cfg: RuntimeConfig,
                 sink: Callable[[TaskResult], None],
                 rng: Optional[np.random.Generator] = None,
                 tracer: Optional[telemetry.Tracer] = None):
        super().__init__(cfg, sink, rng, tracer)
        self.workers = [Worker(p, sink, self._compute_for(p), tracer)
                        for p in range(cfg.num_workers)]
        self._started = False
        self._shutting_down = False

    def _compute_for(self, worker_id: int):
        """Kernel factory hook; the jax backend overrides with devices."""
        device = None
        if self._cfg.use_jax_devices:
            import jax
            devices = jax.devices()
            device = devices[worker_id % len(devices)]
        return make_compute(self._cfg, worker_id, device=device)

    def start(self) -> None:
        for w in self.workers:
            w.start()
        self._started = True

    def dead_worker_map(self) -> dict[int, str]:
        if not self._started or self._shutting_down:
            return {}
        return {w.worker_id: w.name for w in self.workers
                if not w.is_alive()}

    def _quarantine_worker(self, worker_id: int, reason: str) -> None:
        """Retire a dead worker thread: purge-count its orphaned queue so
        the task accounting stays exact, and make sure a (somehow) still-
        running thread stops instead of computing for a fleet that no
        longer includes it."""
        w = self.workers[worker_id]
        if w.is_alive():
            w.stop()         # purge mode: counts its own queue on exit
            return
        with w._cv:          # dead thread: count what it left behind
            for b in w._queue:
                w.runner.count_purged_any(b)
            w._queue.clear()

    def _send_slice(self, worker_id: int, ctx: RoundContext, first_task: int,
                    x: np.ndarray, y: np.ndarray,
                    delays: np.ndarray) -> None:
        """One zero-copy :class:`RoundBatch` (views, no per-task objects),
        one queue append, one notify."""
        self.workers[worker_id].submit_round(
            RoundBatch(ctx=ctx, first_task_id=first_task, x=x, y=y,
                       delays=delays))

    def _send_group(self, worker_id: int, seq: int,
                    entries: list[tuple]) -> None:
        """One :class:`GroupBatch` of per-level zero-copy views; the
        worker thread runs the levels in order against each level's own
        shared cancel event, so ``purge_level`` (the base default —
        ``ctx.purge()``) reclaims a fused level immediately."""
        del seq    # in-process: the live contexts carry the purge signal
        batches = tuple(
            RoundBatch(ctx=ctx, first_task_id=lo, x=x, y=y, delays=d)
            for ctx, lo, x, y, d in entries)
        self.workers[worker_id].submit_round(GroupBatch(levels=batches))

    def dispatch_round(self, ctx, X, Y, kappa, delays=None) -> None:
        """Back-compat alias (pre-transport name) for ``submit_round``."""
        self.submit_round(ctx, X, Y, kappa, delays=delays)

    def purge_round(self, ctx) -> None:
        """Purge one round: the shared cancel event reclaims every worker
        holding (or delaying on) one of its tasks immediately."""
        ctx.purge()

    def shutdown(self, timeout: float = 10.0, *, drain: bool = False
                 ) -> None:
        """Stop all workers deterministically; raise on a leaked thread.

        ``drain=False`` (default) purges outstanding batches — the master
        has already fused or terminated every round it submitted, so
        anything still queued is a straggler by definition.  ``drain=True``
        completes queued work first (delays included; may block up to the
        longest remaining injected delay).
        """
        self._shutting_down = True
        for w in self.workers:
            w.stop(drain=drain)
        leaked = []
        for w in self.workers:
            w.join(timeout=timeout)
            if w.is_alive():
                leaked.append(w.name)
        if leaked:
            raise RuntimeError(
                f"worker threads failed to stop within {timeout}s: {leaked}")

    @property
    def busy_seconds(self) -> np.ndarray:
        return np.asarray([w.busy_seconds for w in self.workers])

    @property
    def tasks_done(self) -> int:
        return sum(w.tasks_done for w in self.workers)

    @property
    def tasks_purged(self) -> int:
        return sum(w.tasks_purged for w in self.workers)
