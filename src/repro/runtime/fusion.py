"""Any-``k``-of-``n`` fusion node and the per-job layered-result future.

The fusion node holds the current round's buffer: as soon as any ``k`` of
the round's ``T`` coded task results land it signals the master, which
decodes (Vandermonde solve, :meth:`PolynomialCode.decode`) and purges the
round's stragglers.  Late results from a purged round are dropped and
counted (``stale_results``) — the runtime analogue of the simulator
sampling round durations as the k-th order statistic.

:meth:`FusionNode.post` is the transport-facing sink: in-process backends
call it straight from their worker threads, remote backends from the
transport's result drain thread.  It is safe from any number of posting
threads concurrently with the master's ``begin_round``; a result's round
identity is checked against the current round *and* its (master-side)
cancel event, so a purge is effective even before the remote worker has
seen the purge message.

:class:`LayeredResult` is the job's progressive future: a consumer can
block on *any* resolution independently (``wait_resolution``), read the
best resolution available right now (``best_resolution``), or wait for the
job's release (finish or deadline termination).  Per Definition 1,
resolution ``l`` becomes ready the moment its last mini-job fuses —
MSB-first, so resolution 0 is ready after a single round.
"""

from __future__ import annotations

import threading
import time
from typing import Optional

import numpy as np

from repro.core import coding
from repro.runtime import telemetry
from repro.runtime.errors import FusionStateError
from repro.runtime.tasks import RoundContext, TaskResult

__all__ = ["RoundFusion", "FusionNode", "LayeredResult"]


class RoundFusion:
    """Collects one round's task results; fuses at the k-th arrival."""

    def __init__(self, ctx: RoundContext, k: int,
                 tracer: Optional[telemetry.Tracer] = None):
        self.ctx = ctx
        self.k = k
        self._lock = threading.Lock()
        self._fused = threading.Event()
        self._ids: list[int] = []
        self._id_set: set[int] = set()
        self._values: list[np.ndarray] = []
        self._tracer = tracer
        self.fused_at: Optional[float] = None

    def post(self, result: TaskResult) -> bool:
        """Deliver one task result; returns False if stale (late/purged).

        Duplicate ``task_id`` deliveries are rejected as stale: a fault-
        supervised re-dispatch can race the original worker's last-gasp
        result, and fusing the same codeword index twice would hand the
        Vandermonde decode a singular arrival set.
        """
        fused_now = False
        with self._lock:
            if self._fused.is_set() or self.ctx.cancelled:
                return False
            if result.task_id in self._id_set:
                return False
            self._id_set.add(result.task_id)
            self._ids.append(result.task_id)
            self._values.append(result.value)
            if len(self._ids) == self.k:
                self.fused_at = result.finished_at
                fused_now = True
                self._fused.set()
        tr = self._tracer
        if tr is not None:
            tr.emit(telemetry.RESULT, result.finished_at,
                    job=result.job_id, round=result.round_idx,
                    task=result.task_id, worker=result.worker_id)
            if fused_now:
                tr.emit(telemetry.FUSED, result.finished_at,
                        job=result.job_id, round=result.round_idx,
                        value=float(self.k))
        return True

    def wait(self, timeout: Optional[float]) -> bool:
        """Block until k results landed; False on timeout (deadline)."""
        return self._fused.wait(timeout=timeout)

    def decode(self, code: coding.PolynomialCode) -> np.ndarray:
        """Reconstruct the round's mini-job product from the k results."""
        if not self._fused.is_set():
            raise FusionStateError("round has not fused yet")
        return np.asarray(code.decode(self._ids, np.stack(self._values)))


class FusionNode:
    """Routes worker results to the live round(s); drops stale ones.

    Two routing regimes share one sink:

    * **Task-granular** (polynomial family): :meth:`begin_round` installs
      a single current round; anything else is stale.
    * **Sub-task-granular** (hierarchical family): :meth:`begin_group`
      installs a whole group of level rounds at once, keyed by
      ``(job_id, round_idx)``.  A result for *any* open level is
      accepted — including levels beyond the one the master is currently
      waiting on (:meth:`set_frontier`) — so straggler work on deeper
      levels is banked, never discarded.  Those banked acceptances are
      the **salvage ledger**: ``subtask_results`` counts every accepted
      grouped result, ``salvaged_subtasks`` the subset that landed ahead
      of the master's wait frontier.

    Staleness accounting is exact in both regimes: a result is counted
    stale at most once, at the single point it is rejected — whether it
    is late for a purged level, a duplicate ``task_id`` (a purged
    worker's last-gasp sub-task racing a re-dispatch), or arrives after
    :meth:`end_group` closed its group.
    """

    def __init__(self, tracer: Optional[telemetry.Tracer] = None):
        self._lock = threading.Lock()
        self._current: Optional[RoundFusion] = None
        self._group: dict[tuple[int, int], RoundFusion] = {}
        self._frontier = -1
        self._tracer = tracer
        self.stale_results = 0
        self.subtask_results = 0
        self.salvaged_subtasks = 0

    def begin_round(self, ctx: RoundContext, k: int) -> RoundFusion:
        rf = RoundFusion(ctx, k, self._tracer)
        with self._lock:
            self._current = rf
        return rf

    def begin_group(self, ctxs: list[RoundContext],
                    k: int) -> list[RoundFusion]:
        """Open one fusion per level round of a hierarchical group.

        All level rounds accept results concurrently until
        :meth:`end_group`; the wait frontier starts below every round so
        the first :meth:`set_frontier` defines it.
        """
        rfs = [RoundFusion(ctx, k, self._tracer) for ctx in ctxs]
        with self._lock:
            self._current = None
            self._group = {(rf.ctx.job_id, rf.ctx.round_idx): rf
                           for rf in rfs}
            self._frontier = -1
        return rfs

    def set_frontier(self, round_idx: int) -> None:
        """Declare the round the master is about to wait on: any accepted
        result for a *deeper* round is salvaged straggler work."""
        with self._lock:
            self._frontier = round_idx

    def end_group(self) -> None:
        """Close the open group; late results for it become stale."""
        with self._lock:
            self._group = {}
            self._frontier = -1

    def post(self, result: TaskResult) -> bool:
        """Route one result; returns True iff it was accepted.

        The verdict is the round's dedupe/staleness decision (late,
        purged, or duplicate ``task_id`` -> False), and it is the *only*
        point that decides whether a result's value will ever be read
        again: an accepted value is copied out at decode
        (:meth:`RoundFusion.decode` stacks), a rejected one is never
        dereferenced.  Transports with zero-copy result buffers key their
        slot accounting on this verdict — a rejected arena view pins
        nothing, so its slot is reclaimable the moment the purge
        watermark passes it.
        """
        with self._lock:
            rf = self._group.get((result.job_id, result.round_idx))
            grouped = rf is not None
            if rf is None:
                rf = self._current
            frontier = self._frontier
        if (rf is None
                or rf.ctx.job_id != result.job_id
                or rf.ctx.round_idx != result.round_idx
                or not rf.post(result)):
            with self._lock:
                self.stale_results += 1
            if self._tracer is not None:
                self._tracer.emit(telemetry.STALE, result.finished_at,
                                  job=result.job_id, round=result.round_idx,
                                  task=result.task_id,
                                  worker=result.worker_id)
            return False
        if grouped:
            with self._lock:
                self.subtask_results += 1
                if result.round_idx > frontier:
                    self.salvaged_subtasks += 1
        return True


class LayeredResult:
    """Future-like progressive result of one job (L resolutions).

    The runtime realization of Definition 1 + the §IV release rule:
    ``resolution(l)`` / ``wait_resolution(l)`` expose per-resolution
    readiness (resolution ``l`` is ready the moment its last mini-job
    decodes, MSB-first, so resolution 0 is ready after one round);
    ``released`` fires at job end (all rounds done, or §IV deadline
    termination) with ``released_resolution`` the highest completed layer
    (-1 if even resolution 0 was cut off).

    Threading: the producer is the master thread (``mark_resolution`` /
    ``release``); any number of consumer threads may concurrently wait on
    or read resolutions.  Each per-layer value is stored *before* its
    event is set, so an observed-set event is the happens-before edge
    that makes the read safe — consumers must go through the accessors,
    which enforce it.  Timestamps (``ready_at``) are seconds on the
    runtime's monotonic clock, the round's ``fused_at`` k-th-arrival
    instant (simulator order-statistic semantics, not the decode time).
    """

    def __init__(self, job_id: int, num_layers: int):
        self.job_id = job_id
        self.num_layers = num_layers
        self._events = [threading.Event() for _ in range(num_layers)]
        self._values: list[Optional[np.ndarray]] = [None] * num_layers
        self._ready_at: list[Optional[float]] = [None] * num_layers
        self._released = threading.Event()
        self._cb_lock = threading.Lock()
        self._callbacks: list = []
        self.released_resolution: int = -1
        self.terminated = False
        #: Monotonic instant service started (master sets it; None while
        #: the job is still queued).  With the job's ``arrival`` this is
        #: the measured queue wait — the number the gateway's admission
        #: bound is checked against.
        self.service_started_at: Optional[float] = None
        #: Monotonic release instant (set by :meth:`release`).
        self.released_at: Optional[float] = None

    # -- producer side (master) ---------------------------------------------
    def mark_started(self, t: float) -> None:
        """Record the service-start instant (master thread only)."""
        self.service_started_at = t

    def mark_resolution(self, l: int, value: np.ndarray, t: float) -> None:
        """Publish resolution ``l`` (master thread only).

        ``t`` is the round's ``fused_at`` instant in monotonic seconds.
        Value first, then event: the event IS the publication barrier.
        """
        self._values[l] = value
        self._ready_at[l] = t
        self._events[l].set()

    def release(self, *, terminated: bool) -> None:
        """End the job (§IV finish or termination); master thread only."""
        self.terminated = terminated
        self.released_resolution = self.best_resolution()
        self.released_at = time.monotonic()
        self._released.set()
        with self._cb_lock:
            callbacks, self._callbacks = self._callbacks, []
        for fn in callbacks:
            fn(self)

    def on_release(self, fn) -> None:
        """Register ``fn(self)`` to run at release (any thread).

        Runs immediately if the job already released — registration can
        never miss the edge.  Callbacks fire on the *releasing* thread
        (the master loop), so they must be cheap and non-blocking: the
        gateway's drain thread uses one to wake its condition variable,
        nothing more.
        """
        with self._cb_lock:
            if not self._released.is_set():
                self._callbacks.append(fn)
                return
        fn(self)

    # -- consumer side -------------------------------------------------------
    def resolution_ready(self, l: int) -> bool:
        """Non-blocking readiness probe; safe from any thread."""
        return self._events[l].is_set()

    def wait_resolution(self, l: int,
                        timeout: Optional[float] = None) -> bool:
        """Block until resolution ``l`` is ready; ``timeout`` in seconds
        (None = wait forever).  Returns False on timeout."""
        return self._events[l].wait(timeout=timeout)

    def resolution(self, l: int) -> np.ndarray:
        # read strictly under the ready event: mark_resolution stores the
        # value *before* setting the event, so a set event is the happens-
        # before edge that makes the read safe against the publisher.
        if not self._events[l].is_set():
            raise FusionStateError(f"resolution {l} not ready")
        return self._values[l]

    def ready_at(self, l: int) -> Optional[float]:
        """Monotonic-seconds instant resolution ``l`` fused (None if not
        ready) — the delay-table timestamp."""
        return self._ready_at[l]

    def best_resolution(self) -> int:
        """Highest ready resolution index, or -1 if none.

        Scans from the top: layers publish MSB-first, so the first set
        event from the top IS the answer — O(1) once any high layer is
        ready, instead of a full O(L) walk.
        """
        for l in range(self.num_layers - 1, -1, -1):
            if self._events[l].is_set():
                return l
        return -1

    def wait_released(self, timeout: Optional[float] = None) -> bool:
        """Block until the job ends (finish or §IV termination);
        ``timeout`` in seconds.  Returns False on timeout."""
        return self._released.wait(timeout=timeout)

    def result(self) -> np.ndarray:
        """The released (or current best) resolution's value."""
        best = self.best_resolution()
        if best < 0:
            raise FusionStateError(
                f"job {self.job_id}: no resolution completed")
        return self.resolution(best)   # event-guarded read
