"""Trace exporters: Chrome trace-event JSON, JSONL, Prometheus, ASCII Gantt.

All exporters consume a traced :class:`~repro.runtime.metrics.RuntimeResult`
(``cfg.trace=True`` → ``result.trace_events`` is a time-sorted
:class:`~repro.runtime.telemetry.TraceEvent` list, already rebased onto the
master's monotonic clock; ``result.trace_t0`` anchors t=0 at the run
start).

* :func:`chrome_trace` / :func:`write_chrome_trace` — Chrome trace-event
  format (the ``traceEvents`` JSON object).  Loads directly in Perfetto
  (https://ui.perfetto.dev → *Open trace file*) or ``chrome://tracing``:
  pid 0 is the master with one named track per pipeline stage (rounds,
  encode, decode, fusion arrivals, control), pid ``1 + worker`` is one
  track per worker/host with its task spans.
* :func:`write_jsonl` / :func:`jsonl_lines` — one JSON object per event,
  for ad-hoc ``jq``/pandas analysis.
* :func:`prometheus_snapshot` — Prometheus text-format dump of the run's
  final counters (the master-side complement of the live
  ``runctl serve-worker --metrics-port`` endpoint).
* :func:`format_timeline` — ASCII Gantt for terminal triage: one row per
  worker plus a master round-span row, no external viewer needed.
"""

from __future__ import annotations

import json
import pathlib
from typing import Iterable, Iterator, List

from repro.runtime import telemetry
from repro.runtime.telemetry import SPAN_KINDS, TraceEvent

__all__ = ["chrome_trace", "write_chrome_trace", "jsonl_lines",
           "write_jsonl", "prometheus_snapshot", "format_timeline"]

#: Master-track (pid 0) thread layout: kind -> (tid, track name).  Worker
#: task spans go to pid 1 + worker instead.
_MASTER_TRACKS = {
    telemetry.JOB: (0, "jobs"),
    telemetry.PREP: (1, "prep"),
    telemetry.ENCODE: (2, "encode"),
    telemetry.DISPATCH: (3, "dispatch"),
    telemetry.ROUND: (4, "rounds"),
    telemetry.DECODE: (5, "decode"),
    telemetry.RESULT: (6, "fusion"),
    telemetry.FUSED: (6, "fusion"),
    telemetry.STALE: (6, "fusion"),
    telemetry.RESOLUTION: (7, "releases"),
    telemetry.RETUNE: (8, "control"),
    telemetry.HEARTBEAT: (9, "transport"),
    telemetry.RECONNECT: (9, "transport"),
    telemetry.DEAD: (9, "transport"),
}


def _events_of(result) -> List[TraceEvent]:
    events = getattr(result, "trace_events", None)
    if events is None:
        raise ValueError(
            "result carries no trace events — run with cfg.trace=True "
            "(runctl --trace/--timeline sets it)")
    return events


def _event_name(ev: TraceEvent) -> str:
    if ev.kind == telemetry.TASK:
        return f"task {ev.task} (j{ev.job} r{ev.round})"
    if ev.kind == telemetry.ROUND:
        return f"round j{ev.job}.{ev.round}"
    if ev.kind == telemetry.JOB:
        return f"job {ev.job}"
    if ev.kind == telemetry.RESOLUTION:
        return f"res-{int(ev.value)}"
    return ev.kind


def chrome_trace(result) -> dict:
    """Build the Chrome trace-event object for a traced run."""
    events = _events_of(result)
    t0 = getattr(result, "trace_t0", 0.0)
    hosts = {int(row["worker"]): str(row.get("host", ""))
             for row in (getattr(result, "clock_sync", None) or [])}

    out: List[dict] = [
        {"ph": "M", "name": "process_name", "pid": 0, "tid": 0,
         "args": {"name": f"master ({getattr(result, 'backend', '?')})"}},
    ]
    for tid, track in sorted(set(_MASTER_TRACKS.values())):
        out.append({"ph": "M", "name": "thread_name", "pid": 0, "tid": tid,
                    "args": {"name": track}})
    seen_workers = set()

    for ev in events:
        ts = (ev.t - t0) * 1e6
        if ev.kind == telemetry.TASK:
            pid, tid = 1 + ev.worker, 0
            if ev.worker not in seen_workers:
                seen_workers.add(ev.worker)
                name = f"worker-{ev.worker}"
                if hosts.get(ev.worker):
                    name += f" ({hosts[ev.worker]})"
                out.append({"ph": "M", "name": "process_name", "pid": pid,
                            "tid": 0, "args": {"name": name}})
        else:
            pid, tid = 0, _MASTER_TRACKS.get(ev.kind, (10, "misc"))[0]
        args = {"job": ev.job, "round": ev.round}
        if ev.task >= 0:
            args["task"] = ev.task
        if ev.worker >= 0:
            args["worker"] = ev.worker
        if ev.value:
            args["value"] = ev.value
        if ev.label:
            args["label"] = ev.label
        rec = {"name": _event_name(ev), "cat": ev.kind, "pid": pid,
               "tid": tid, "ts": ts, "args": args}
        if ev.kind in SPAN_KINDS:
            rec["ph"] = "X"
            rec["dur"] = ev.dur * 1e6
        else:
            rec["ph"] = "i"
            rec["s"] = "t"   # thread-scoped instant
        out.append(rec)

    meta = {
        "backend": getattr(result, "backend", None),
        "trace_dropped": getattr(result, "trace_dropped", 0),
        "clock_sync": getattr(result, "clock_sync", None),
    }
    return {"traceEvents": out, "displayTimeUnit": "ms",
            "otherData": meta}


def write_chrome_trace(path, result) -> pathlib.Path:
    """Write :func:`chrome_trace` JSON to ``path`` (Perfetto-loadable)."""
    path = pathlib.Path(path)
    path.write_text(json.dumps(chrome_trace(result)))
    return path


def jsonl_lines(result) -> Iterator[str]:
    """One compact JSON object per event, times in seconds from run
    start."""
    t0 = getattr(result, "trace_t0", 0.0)
    for ev in _events_of(result):
        rec = {"kind": ev.kind, "t": round(ev.t - t0, 9)}
        if ev.dur:
            rec["dur"] = round(ev.dur, 9)
        for field in ("job", "round", "task", "worker"):
            v = getattr(ev, field)
            if v >= 0:
                rec[field] = v
        if ev.value:
            rec["value"] = ev.value
        if ev.label:
            rec["label"] = ev.label
        yield json.dumps(rec)


def write_jsonl(path, result) -> pathlib.Path:
    path = pathlib.Path(path)
    with path.open("w") as fh:
        for line in jsonl_lines(result):
            fh.write(line + "\n")
    return path


def prometheus_snapshot(result) -> str:
    """Prometheus text-format dump of a finished run's counters.

    Works on any :class:`~repro.runtime.metrics.RuntimeResult` (tracing
    not required) — it reads the aggregate counters, not the event log.
    """
    backend = getattr(result, "backend", "unknown")
    lines = [
        "# HELP repro_run_wall_seconds Run duration (last service end - "
        "run start).",
        "# TYPE repro_run_wall_seconds gauge",
        f'repro_run_wall_seconds{{backend="{backend}"}} '
        f"{result.wall_elapsed:.6f}",
        "# HELP repro_jobs_total Jobs executed.",
        "# TYPE repro_jobs_total counter",
        f'repro_jobs_total{{backend="{backend}"}} {len(result.arrivals)}',
        "# HELP repro_jobs_terminated_total Jobs cut off at the deadline "
        "(paper §IV termination).",
        "# TYPE repro_jobs_terminated_total counter",
        f'repro_jobs_terminated_total{{backend="{backend}"}} '
        f"{int(result.terminated.sum())}",
        "# HELP repro_rounds_total Rounds dispatched.",
        "# TYPE repro_rounds_total counter",
        f'repro_rounds_total{{backend="{backend}"}} {result.stage_rounds}',
        "# HELP repro_tasks_done_total Coded tasks computed across all "
        "workers.",
        "# TYPE repro_tasks_done_total counter",
        f'repro_tasks_done_total{{backend="{backend}"}} '
        f"{result.tasks_done}",
        "# HELP repro_tasks_purged_total Tasks reclaimed by purges.",
        "# TYPE repro_tasks_purged_total counter",
        f'repro_tasks_purged_total{{backend="{backend}"}} '
        f"{result.tasks_purged}",
        "# HELP repro_stale_results_total Results that arrived after "
        "their round fused or was purged.",
        "# TYPE repro_stale_results_total counter",
        f'repro_stale_results_total{{backend="{backend}"}} '
        f"{result.stale_results}",
    ]
    lines += [
        "# HELP repro_worker_busy_seconds Per-worker occupancy (delay + "
        "compute).",
        "# TYPE repro_worker_busy_seconds counter",
    ]
    for p, busy in enumerate(result.worker_busy):
        lines.append(f'repro_worker_busy_seconds{{worker="{p}"}} '
                     f"{float(busy):.6f}")
    if result.stage_seconds:
        lines += [
            "# HELP repro_stage_seconds_total Master pipeline seconds by "
            "stage.",
            "# TYPE repro_stage_seconds_total counter",
        ]
        for stage, v in result.stage_seconds.items():
            lines.append(f'repro_stage_seconds_total{{stage="{stage}"}} '
                         f"{v:.6f}")
    hist = result.release_histogram()
    lines += [
        "# HELP repro_jobs_released_total Jobs by highest released "
        'resolution (resolution="-1" = none).',
        "# TYPE repro_jobs_released_total counter",
    ]
    for slot, count in enumerate(hist):
        lines.append(
            f'repro_jobs_released_total{{resolution="{slot - 1}"}} '
            f"{int(count)}")
    for row in (getattr(result, "clock_sync", None) or []):
        lines.append(
            f'repro_clock_offset_seconds{{worker="{row["worker"]}"}} '
            f"{row['offset_s']:.9f}")
        if row.get("rtt_s") is not None:   # None = link never synced
            lines.append(
                f'repro_clock_rtt_seconds{{worker="{row["worker"]}"}} '
                f"{row['rtt_s']:.9f}")
    return "\n".join(lines) + "\n"


def _paint(row: list, lo: float, scale: float, t_from: float, t_to: float,
           ch: str) -> None:
    a = int((t_from - lo) * scale)
    b = max(a + 1, int((t_to - lo) * scale))
    for i in range(max(a, 0), min(b, len(row))):
        row[i] = ch


def format_timeline(result, width: int = 72) -> str:
    """ASCII Gantt of a traced run: master rounds + per-worker task spans.

    Legend: ``#`` task compute/delay that completed, ``x`` purged task
    occupancy, ``=`` a round span on the master row (``!`` if the round
    was purged unfused), ``.`` idle.
    """
    events = _events_of(result)
    if not events:
        return "(trace is empty)"
    t0 = getattr(result, "trace_t0", 0.0) or min(ev.t for ev in events)
    lo = min(min(ev.t for ev in events), t0) - t0
    hi = max(ev.t + ev.dur for ev in events) - t0
    span = max(hi - lo, 1e-9)
    scale = width / span

    master = ["."] * width
    workers: dict[int, list] = {}
    for ev in events:
        a, b = ev.t - t0, ev.t - t0 + ev.dur
        if ev.kind == telemetry.ROUND:
            _paint(master, lo, scale, a, b,
                   "=" if ev.label == "fused" else "!")
        elif ev.kind == telemetry.TASK:
            row = workers.setdefault(ev.worker, ["."] * width)
            _paint(row, lo, scale, a, b,
                   "#" if ev.label == "done" else "x")

    lines = [f"timeline  [{lo:.3f}s .. {hi:.3f}s from run start]  "
             f"('=' fused round  '!' purged  '#' task done  'x' purged)",
             f"{'master':>9} |{''.join(master)}|"]
    for w in sorted(workers):
        lines.append(f"{f'worker {w}':>9} |{''.join(workers[w])}|")
    return "\n".join(lines)
