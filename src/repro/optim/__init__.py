"""Optimizers + layered gradient compression."""

from repro.optim import layered_grads, optimizers  # noqa: F401
from repro.optim.optimizers import make_optimizer  # noqa: F401
