"""Layered gradient all-reduce — the paper's resolution layers on collectives.

Beyond-paper application (DESIGN.md §3.3): gradients are quantized and
digit-decomposed (``repro.core.layering``); the all-reduce then runs
**MSB-plane-first**.  A deadline-bounded synchronous step can apply the
optimizer update from the first plane(s) and feed the unsent remainder back
as error-feedback — the paper's "release a lower resolution at the deadline"
transplanted from task results to gradient collectives.

This module provides the math (plane split / reconstruct / error feedback)
plus a ``shard_map`` execution that issues one ``psum`` per plane so the
collective schedule in the lowered HLO is visibly layered (the dry-run
counts one all-reduce per plane).  Plane psums commute with the decode
because the code is linear — summing plane-wise then reconstructing equals
reconstructing then summing, up to the shared quantization scale.
"""

from __future__ import annotations

import functools
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.compat import shard_map
from repro.core import layering

__all__ = ["plane_split", "plane_reconstruct", "layered_psum",
           "layered_allreduce_tree"]


def plane_split(g: jax.Array, m: int, d: int):
    """Quantize a float gradient tensor and split into m digit planes.

    Returns (planes (m, *g.shape) float32-encoded ints, scale).  Planes are
    float so they ride the regular all-reduce datapath; each plane's values
    fit in d bits (plus sign for the top plane), so a d<=8 plane could be
    shipped as int8 — the dtype choice is the transport's concern.
    """
    q, scale = layering.quantize(g, m * d)
    planes = layering.decompose(q, m, d).astype(jnp.float32)
    return planes, scale


def plane_reconstruct(planes: jax.Array, scale: jax.Array, d: int,
                      up_to_plane: int | None = None) -> jax.Array:
    """Rebuild the (summed) gradient from the top ``up_to_plane+1`` planes.

    ``up_to_plane`` indexes MSB-first resolutions: 0 = only the top plane.
    """
    m = planes.shape[0]
    k = m if up_to_plane is None else up_to_plane + 1
    acc = jnp.zeros(planes.shape[1:], jnp.float32)
    for i in range(m - 1, m - 1 - k, -1):
        acc = acc + planes[i] * float(1 << (i * d))
    return acc * scale


def layered_psum(planes: jax.Array, axis_name: str) -> jax.Array:
    """One psum per plane, MSB-first — the layered collective schedule.

    Inside shard_map.  Each plane is an independent all-reduce so an
    implementation with a deadline can consume the partial sums in layer
    order; XLA sees ``m`` distinct all-reduce ops (verified by the dry-run
    HLO scan).
    """
    m = planes.shape[0]
    out = []
    for i in range(m - 1, -1, -1):          # MSB plane first
        out.append(jax.lax.psum(planes[i], axis_name))
    return jnp.stack(out[::-1], axis=0)


def layered_allreduce_tree(grads, mesh: Mesh, axis: str, *, m: int = 2,
                           d: int = 8, resolution: int | None = None):
    """Data-parallel mean of a gradient pytree via layered all-reduce.

    Each leaf is quantized per-device, plane-split, psum'd plane-by-plane
    (MSB first), reconstructed at ``resolution`` (None = full), and divided
    by the axis size.  Scales are psum-maxed so all devices share one scale.
    """
    n = mesh.shape[axis]

    def per_leaf(g):
        def inner(gl):
            # shared scale: max over devices so planes are commensurable
            absmax = jax.lax.pmax(jnp.max(jnp.abs(gl)), axis)
            qmax = float(2 ** (m * d - 1) - 1)
            scale = jnp.maximum(absmax, 1e-30) / qmax
            q = jnp.clip(jnp.round(gl / scale), -qmax, qmax).astype(jnp.int32)
            planes = layering.decompose(q, m, d).astype(jnp.float32)
            planes = layered_psum(planes, axis)
            return plane_reconstruct(planes, scale, d, resolution) / n

        return shard_map(inner, mesh=mesh, in_specs=P(axis),
                             out_specs=P(axis))(g)

    return jax.tree.map(per_leaf, grads)
