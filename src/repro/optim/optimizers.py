"""Optimizers: AdamW and Adafactor, with schedule + global-norm clipping.

Self-contained (no optax in this environment).  Both optimizers follow the
``init(params) -> state`` / ``update(grads, state, params) -> (params',
state')`` interface and keep fp32 master weights regardless of the compute
dtype; the 400B llama4 config defaults to Adafactor so the optimizer state
fits the single-pod memory budget (see EXPERIMENTS.md §Dry-run).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import TrainConfig

__all__ = ["make_optimizer", "Optimizer", "cosine_schedule", "global_norm"]


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda x: (x.astype(jnp.float32) * scale), tree), norm


def cosine_schedule(cfg: TrainConfig) -> Callable[[jax.Array], jax.Array]:
    def lr(step):
        step = step.astype(jnp.float32)
        warm = cfg.learning_rate * step / max(cfg.warmup_steps, 1)
        t = jnp.clip((step - cfg.warmup_steps)
                     / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
        cos = 0.5 * cfg.learning_rate * (1.0 + jnp.cos(np.pi * t))
        return jnp.where(step < cfg.warmup_steps, warm, cos)
    return lr


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any], tuple[Any, Any]]
    # state pytree structure mirrors params; scalars live in state["_"]


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------

def adamw(cfg: TrainConfig) -> Optimizer:
    lr_fn = cosine_schedule(cfg)

    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        # gnorm/lr live in the state so init and update return IDENTICAL
        # pytree structures (jit in_shardings are structure-keyed)
        return {
            "step": jnp.zeros((), jnp.int32),
            "m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "gnorm": jnp.zeros((), jnp.float32),
            "lr": jnp.zeros((), jnp.float32),
        }

    def update(grads, state, params):
        step = state["step"] + 1
        grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
        b1, b2 = cfg.b1, cfg.b2
        m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g,
                         state["m"], grads)
        v = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2) * jnp.square(g),
                         state["v"], grads)
        bc1 = 1.0 - b1 ** step.astype(jnp.float32)
        bc2 = 1.0 - b2 ** step.astype(jnp.float32)
        lr = lr_fn(step)

        def upd(p, m_, v_):
            mhat = m_ / bc1
            vhat = v_ / bc2
            delta = mhat / (jnp.sqrt(vhat) + 1e-8)
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * delta).astype(p.dtype)

        new_params = jax.tree.map(upd, params, m, v)
        return new_params, {"step": step, "m": m, "v": v,
                            "gnorm": gnorm, "lr": lr}

    return Optimizer(init=init, update=update)


# ---------------------------------------------------------------------------
# Adafactor (factored second moments; the 400B-scale default)
# ---------------------------------------------------------------------------

def _factored(shape) -> bool:
    return len(shape) >= 2 and shape[-1] >= 8 and shape[-2] >= 8


def adafactor(cfg: TrainConfig) -> Optimizer:
    lr_fn = cosine_schedule(cfg)
    eps = 1e-30

    def init(params):
        def per_leaf(p):
            if _factored(p.shape):
                return {
                    "vr": jnp.zeros(p.shape[:-1], jnp.float32),
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:],
                                    jnp.float32),
                }
            return {"v": jnp.zeros(p.shape, jnp.float32)}
        return {"step": jnp.zeros((), jnp.int32),
                "v": jax.tree.map(per_leaf, params,
                                  is_leaf=lambda x: hasattr(x, "shape")),
                "gnorm": jnp.zeros((), jnp.float32),
                "lr": jnp.zeros((), jnp.float32)}

    def update(grads, state, params):
        step = state["step"] + 1
        grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
        t = step.astype(jnp.float32)
        beta2 = 1.0 - t ** (-0.8)          # Adafactor decay schedule
        lr = lr_fn(step)

        def upd(p, g, v):
            g2 = jnp.square(g) + eps
            if _factored(p.shape):
                vr = beta2 * v["vr"] + (1 - beta2) * g2.mean(-1)
                vc = beta2 * v["vc"] + (1 - beta2) * g2.mean(-2)
                denom = (vr[..., None] * vc[..., None, :]
                         / jnp.maximum(vr.mean(-1, keepdims=True)[..., None],
                                       eps))
                pre = g * jax.lax.rsqrt(denom + eps)
                nv = {"vr": vr, "vc": vc}
            else:
                nv = {"v": beta2 * v["v"] + (1 - beta2) * g2}
                pre = g * jax.lax.rsqrt(nv["v"] + eps)
            # update clipping (Adafactor's d=1.0 RMS clip)
            rms = jnp.sqrt(jnp.mean(jnp.square(pre)) + eps)
            pre = pre / jnp.maximum(1.0, rms)
            delta = pre + cfg.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), nv

        flat_p, tdef = jax.tree.flatten(params)
        flat_g = tdef.flatten_up_to(grads)
        flat_v = tdef.flatten_up_to(state["v"])
        out = [upd(p, g, v) for p, g, v in zip(flat_p, flat_g, flat_v)]
        new_params = tdef.unflatten([o[0] for o in out])
        new_v = tdef.unflatten([o[1] for o in out])
        return new_params, {"step": step, "v": new_v,
                            "gnorm": gnorm, "lr": lr}

    return Optimizer(init=init, update=update)


def make_optimizer(cfg: TrainConfig) -> Optimizer:
    if cfg.optimizer == "adamw":
        return adamw(cfg)
    if cfg.optimizer == "adafactor":
        return adafactor(cfg)
    raise ValueError(f"unknown optimizer {cfg.optimizer!r}")
