"""Fault tolerance at 1000+-node scale: the four mechanisms and their wiring.

1. **Checkpoint/restart** — ``repro.checkpoint.store``: atomic async saves,
   SIGTERM final save, latest-step discovery.  Exercised by launch/train.py.
2. **Elastic resume** — checkpoints are stored unsharded; ``elastic_restore``
   re-places every leaf with the sharding rules evaluated on the *current*
   mesh, so a job that lost a pod restarts on the remaining pods (or a
   resized slice) without conversion tooling.
3. **Coded data parallelism** — the paper's erasure story at pod granularity
   (DESIGN.md §3.2): with n pods and redundancy n/k, each pod computes the
   gradient of an MDS-coded combination of data shards
   (``repro.core.layered_matmul.GradientCoder``).  If a pod is lost mid-step
   (preemption, network partition), the fusion decodes the full-batch
   gradient from any k surviving pod codewords — one weighted psum, no
   recomputation, no straggler wait.  ``coded_dp_grads`` packages this.
4. **Straggler mitigation / deadline release** — within-step: the layered
   LM head (launch/serve.py) releases lower resolutions at the deadline;
   across steps: redundant coded tasks + purging (core/simulator.py shows
   the delay math the scheduler relies on).

On real multi-pod hardware the survivor set comes from the runtime's health
checks; here the degraded step function takes the survivor list statically
(it is a *different compiled program* — recompilation on pod loss is the
production behaviour too, and elastic resume covers the general case).
"""

from __future__ import annotations

from typing import Callable, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.checkpoint import store
from repro.core.layered_matmul import GradientCoder
from repro.launch import sharding as sh

__all__ = ["elastic_restore", "coded_dp_grads", "degraded_step_grads"]


def elastic_restore(ckpt_dir: str, step: int, template: dict, mesh) -> dict:
    """Restore {params, opt} re-sharded for the (possibly different) mesh."""
    pspecs = sh.param_specs(template["params"], mesh)
    ospecs = sh.opt_state_specs(template["opt"], pspecs, mesh)
    shardings = {"params": sh.named(mesh, pspecs),
                 "opt": sh.named(mesh, ospecs)}
    return store.restore(ckpt_dir, step, template, shardings)


def coded_dp_grads(loss_fn: Callable, params, shard_batches: Sequence,
                   coder: GradientCoder):
    """Per-pod coded gradient codewords (what each pod would transmit).

    ``shard_batches[s]`` is data shard s (n shards total).  Pod p computes
    grads for its ``coder.assignment[p]`` shards and combines them with its
    code row.  Returns the list of n codeword pytrees.
    """
    grad_fn = jax.grad(loss_fn)
    shard_grads = [grad_fn(params, b) for b in shard_batches]
    return [coder.encode_local(p, [shard_grads[s]
                                   for s in coder.assignment[p]])
            for p in range(coder.n)]


def degraded_step_grads(codewords: Sequence, survivors: Sequence[int],
                        coder: GradientCoder):
    """Fusion after pod loss: decode the full-batch gradient sum from the
    surviving codewords (>= k of n)."""
    return coder.decode(survivors, [codewords[p] for p in survivors])
