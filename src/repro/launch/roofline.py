"""Roofline analysis from compiled dry-run artifacts.

Three terms per (arch x shape x mesh) cell, per the brief:

    compute   = HLO_FLOPs / (chips * peak_FLOP/s)
    memory    = HLO_bytes / (chips * HBM_bw)
    collective = collective_bytes / (chips * link_bw)

``compiled.cost_analysis()`` supplies FLOPs and bytes-accessed for the
(already SPMD-partitioned) per-device module, so the "/ chips" division is
implicit — we report per-device seconds directly.  Collective bytes are NOT
in cost_analysis: we parse the post-optimization HLO text and sum, per op,
the bytes a ring implementation moves per device:

    all-gather      (n-1)/n * result_bytes
    reduce-scatter  (n-1)/n * operand_bytes  (= result * n)
    all-reduce      2 (n-1)/n * result_bytes
    all-to-all      (n-1)/n * result_bytes
    collective-permute  result_bytes

where n = participants per replica group (parsed from replica_groups).
"""

from __future__ import annotations

import dataclasses
import json
import re
from typing import Any, Optional

import numpy as np

from repro.launch.mesh import TPU_V5E, HardwareSpec

__all__ = ["CollectiveStats", "parse_collectives", "roofline_terms",
           "RooflineReport"]

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLLECTIVE_RE = re.compile(
    r"=\s*(?:\(([^)]*)\)|(\w+)\[([\d,]*)\][^ ]*)\s*"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{?\{([\d,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_SRC_TGT_RE = re.compile(r"source_target_pairs=\{\{")


def _shape_bytes(dtype: str, dims: str) -> int:
    nb = _DTYPE_BYTES.get(dtype)
    if nb is None:
        return 0
    if not dims:
        return nb
    return nb * int(np.prod([int(d) for d in dims.split(",") if d]))


@dataclasses.dataclass
class CollectiveStats:
    counts: dict[str, int]
    bytes_moved: dict[str, float]   # per-device bytes on the wire

    @property
    def total_bytes(self) -> float:
        return float(sum(self.bytes_moved.values()))

    @property
    def total_count(self) -> int:
        return int(sum(self.counts.values()))

    def as_dict(self) -> dict:
        return {"counts": self.counts, "bytes": self.bytes_moved,
                "total_bytes": self.total_bytes}


def parse_collectives(hlo_text: str) -> CollectiveStats:
    counts: dict[str, int] = {}
    moved: dict[str, float] = {}
    for line in hlo_text.splitlines():
        m = _COLLECTIVE_RE.search(line)
        if not m:
            continue
        op = m.group(4)
        # result bytes: sum over (possibly tuple) result shapes
        if m.group(1) is not None:
            rbytes = sum(_shape_bytes(d, s)
                         for d, s in _SHAPE_RE.findall(m.group(1)))
        else:
            rbytes = _shape_bytes(m.group(2), m.group(3))
        # participants per group
        g = _GROUPS_RE.search(line)
        if g:
            n = len(g.group(1).split(","))
        else:
            gi = _GROUPS_IOTA_RE.search(line)
            n = int(gi.group(2)) if gi else 1
        if op == "collective-permute":
            b = float(rbytes)
        elif n <= 1:
            b = 0.0
        elif op == "all-reduce":
            b = 2.0 * (n - 1) / n * rbytes
        elif op == "reduce-scatter":
            b = float((n - 1) * rbytes)
        else:  # all-gather, all-to-all
            b = (n - 1) / n * rbytes
        counts[op] = counts.get(op, 0) + 1
        moved[op] = moved.get(op, 0.0) + b
    return CollectiveStats(counts=counts, bytes_moved=moved)


def analytic_memory_bytes(cfg, shape, kind: str, mesh, n_params: int,
                          opt_state_bytes_per_dev: float = 0.0,
                          cache_bytes_per_dev: float = 0.0) -> float:
    """Structural per-device HBM-traffic estimate (the memory-term source).

    The CPU backend's HLO is barely fused, so instruction-level byte
    counting over-reports TPU HBM traffic by ~50x (measured); instead we
    count what a well-fused TPU execution must move:

      weights   passes * P_bf16 / TP  (each device reads its TP shard of
                every layer's weights once per pass; FSDP gathering is
                counted in the COLLECTIVE term, not here)
                + P_fp32 / n_dev (master read) + optimizer read/write
      acts      L * tokens_loc * d_model * bytes * C, C = 24 access
                equivalents per layer (qkv/o + mlp in/out + 4 norms in
                fp32 + residuals + remat re-reads; attention assumed
                flash-fused so no S^2 traffic)
      caches    decode reads the whole per-device KV/state cache once per
                step and writes one slot; prefill writes it once.

    passes: train = 3 (fwd, remat-recompute, bwd), prefill = 1, decode = 1.
    """
    import numpy as np

    n_dev = mesh.devices.size
    tp = mesh.shape.get("model", 1)
    data_shards = int(np.prod([mesh.shape.get(a, 1)
                               for a in ("pod", "data")]))
    cbytes = 2 if cfg.compute_dtype == "bfloat16" else 4
    passes = 3.0 if kind == "train" else 1.0

    weights = passes * n_params * cbytes / tp
    if kind == "train":
        weights += n_params * 4 / n_dev            # fp32 master read
        weights += 2.0 * opt_state_bytes_per_dev   # states read + write
        weights += 2.0 * n_params * 4 / n_dev      # grads write + read

    if kind == "decode":
        tokens_loc = max(shape.global_batch // data_shards, 1)
    else:
        tokens_loc = shape.global_batch * shape.seq_len // data_shards
    acts = cfg.num_layers * tokens_loc * cfg.d_model * cbytes * 24.0
    if kind == "train":
        acts *= 2.0                                # bwd touches them again
    logits = tokens_loc * cfg.vocab_size // tp * 4 * (3 if kind == "train"
                                                      else 1)
    if kind == "decode":
        logits = max(shape.global_batch // data_shards, 1) \
            * cfg.vocab_size // tp * 4

    cache = cache_bytes_per_dev * (1.0 if kind == "decode" else 1.0)
    return float(weights + acts + logits + cache)


@dataclasses.dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    kind: str
    chips: int
    flops_per_device: float
    bytes_per_device: float
    collective_bytes: float
    collective_counts: dict[str, int]
    peak_memory_per_device: Optional[float]
    model_flops: Optional[float] = None        # 6*N*D (active) global

    def terms(self, hw: HardwareSpec = TPU_V5E) -> dict[str, float]:
        compute = self.flops_per_device / hw.peak_flops
        memory = self.bytes_per_device / hw.hbm_bw
        collective = self.collective_bytes / hw.ici_bw
        dominant = max(("compute", compute), ("memory", memory),
                       ("collective", collective), key=lambda kv: kv[1])
        out = {
            "compute_s": compute,
            "memory_s": memory,
            "collective_s": collective,
            "bound": dominant[0],
            "step_s": dominant[1],
        }
        if self.model_flops:
            useful = self.model_flops / self.chips
            out["model_flops_ratio"] = (useful / self.flops_per_device
                                        if self.flops_per_device else 0.0)
            # roofline fraction: useful-FLOPs time over the dominant term
            out["roofline_fraction"] = ((useful / hw.peak_flops)
                                        / dominant[1] if dominant[1] else 0.0)
        return out

    def as_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d.update(self.terms())
        return d


def roofline_terms(compiled, *, arch: str, shape: str, mesh_name: str,
                   kind: str, chips: int,
                   model_flops: Optional[float] = None) -> RooflineReport:
    """Derive the three terms from the compiled per-device module.

    Uses the trip-count-aware HLO parser (launch/hlo_costs.py): the raw
    ``cost_analysis()`` counts every ``while`` (scan-over-layers!) body
    once, silently dividing FLOPs/bytes/per-layer-collectives by the layer
    count — verified empirically and corrected here.
    """
    from repro.launch.hlo_costs import module_costs

    mc = module_costs(compiled.as_text())
    try:
        mem = compiled.memory_analysis()
        peak = float(getattr(mem, "temp_size_in_bytes", 0)
                     + getattr(mem, "argument_size_in_bytes", 0)
                     + getattr(mem, "output_size_in_bytes", 0)
                     - getattr(mem, "alias_size_in_bytes", 0))
    except Exception:
        peak = None
    return RooflineReport(
        arch=arch, shape=shape, mesh=mesh_name, kind=kind, chips=chips,
        flops_per_device=mc.flops, bytes_per_device=mc.hbm_bytes,
        collective_bytes=mc.collective_bytes,
        collective_counts=mc.collective_counts,
        peak_memory_per_device=peak,
        model_flops=model_flops)
