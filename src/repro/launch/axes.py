"""Logical-axis activation sharding constraints (MaxText-style).

Without explicit constraints, GSPMD occasionally prefers
"partial-matmul + all-reduce the activations" over "all-gather the (much
smaller) FSDP weight shards" inside scanned layers — measured on the
llama3 train cell as ~2 TB/device of fp32 batch-replicated activation
all-reduces.  Pinning activations to ``(batch, ..., tp)`` makes weight
gathering the only legal partitioning, which is the intended FSDP/TP
schedule.

Model code calls ``constrain(x, "batch", None, "tp")`` with logical names;
the mesh is ambient (context manager set by launch/steps.py around jit
tracing).  With no ambient mesh (plain tests, eager use) it's a no-op.
Specs are divisibility-checked through ``fix_spec`` with relocation
disabled, so e.g. batch=1 long-context cells silently drop the batch axis.
"""

from __future__ import annotations

import contextlib
import threading
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = ["constrain", "mesh_context", "current_mesh"]

_STATE = threading.local()

# sharding profiles (see launch/sharding.py):
#   "tp_fsdp" (default): batch over (pod, data); TP over model; FSDP data
#   "fsdp":   batch over (pod, data, model); no activation TP (pure ZeRO-3)
#   "serve":  like tp_fsdp for activations; params keep TP but drop FSDP
_PROFILES = {
    "tp_fsdp": {"batch": ("pod", "data"), "fsdp": ("data",),
                "tp": ("model",)},
    "fsdp": {"batch": ("pod", "data", "model"), "fsdp": ("data",),
             "tp": ()},
    "serve": {"batch": ("pod", "data"), "fsdp": ("data",),
              "tp": ("model",)},
}


def current_mesh() -> Optional[Mesh]:
    return getattr(_STATE, "mesh", None)


def current_profile() -> str:
    return getattr(_STATE, "profile", "tp_fsdp")


@contextlib.contextmanager
def mesh_context(mesh: Optional[Mesh], profile: str = "tp_fsdp"):
    prev = current_mesh()
    prev_prof = current_profile()
    _STATE.mesh = mesh
    _STATE.profile = profile
    try:
        yield
    finally:
        _STATE.mesh = prev
        _STATE.profile = prev_prof


def _resolve(axis, mesh: Mesh):
    if axis is None:
        return None
    logical = _PROFILES[current_profile()]
    names = logical.get(axis, (axis,))
    present = tuple(a for a in names if a in mesh.axis_names)
    if not present:
        return None
    return present if len(present) > 1 else present[0]


def constrain(x: jax.Array, *logical_spec) -> jax.Array:
    """Pin ``x`` to a logical sharding if an ambient mesh is set."""
    mesh = current_mesh()
    if mesh is None:
        return x
    from repro.launch.sharding import fix_spec

    spec = tuple(_resolve(a, mesh) for a in logical_spec)
    fixed = fix_spec(x.shape, spec, mesh, relocate=False)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, fixed))
