"""End-to-end training driver.

Runs on anything from this container's single CPU device (quickstart-100m,
smoke configs) to the production mesh (full configs; same code path the
dry-run lowers).  Fault tolerance: async checkpointing every
``--ckpt-every`` steps, SIGTERM -> synchronous final checkpoint, and
``--resume`` restarts from the latest checkpoint — onto a *different* mesh
shape if needed (elastic resume; arrays are stored unsharded and re-placed
with the current sharding rules).

    PYTHONPATH=src python -m repro.launch.train \
        --arch quickstart-100m --steps 300 --batch 8 --seq 256
"""

from __future__ import annotations

import argparse
import dataclasses
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import store
from repro.configs import registry
from repro.configs.base import (AttentionConfig, ModelConfig, ShapeConfig,
                                TrainConfig)
from repro.data.pipeline import SyntheticLM
from repro.launch import sharding as sh
from repro.launch import steps as steps_lib
from repro.launch.mesh import make_test_mesh
from repro.models import transformer as T

__all__ = ["quickstart_100m_config", "train_loop", "main"]


def quickstart_100m_config(vocab: int = 32_768) -> ModelConfig:
    """~100M-param dense LM that trains in minutes on CPU at short seq."""
    return ModelConfig(
        name="quickstart-100m", family="dense", num_layers=12, d_model=768,
        d_ff=3072, vocab_size=vocab,
        attention=AttentionConfig(num_heads=12, num_kv_heads=4, head_dim=64),
        tie_embeddings=True, compute_dtype="float32",
        remat_policy="none")


def _resolve_config(arch: str) -> ModelConfig:
    if arch == "quickstart-100m":
        return quickstart_100m_config()
    if arch.endswith("-smoke"):
        return registry.get_smoke_config(arch[: -len("-smoke")])
    return registry.get_config(arch)


def train_loop(cfg: ModelConfig, tcfg: TrainConfig, *, batch: int, seq: int,
               steps: int, ckpt_dir: str | None = None, ckpt_every: int = 100,
               resume: bool = False, log_every: int = 10,
               mesh=None, seed: int = 0) -> dict:
    mesh = mesh or make_test_mesh(1, 1)
    shape = ShapeConfig("train", seq, batch, "train")
    cell = steps_lib.build_cell(cfg, shape, mesh, tcfg)

    data = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=seq,
                       global_batch=batch, seed=seed)
    _, optimizer = steps_lib.make_train_step(cfg, tcfg)

    params = T.init_params(jax.random.PRNGKey(seed), cfg)
    opt_state = optimizer.init(params)
    start_step = 0

    ckpt = None
    if ckpt_dir:
        ckpt = store.AsyncCheckpointer(ckpt_dir)
        latest = store.latest_step(ckpt_dir)
        if resume and latest is not None:
            state = {"params": params, "opt": opt_state}
            pspecs = sh.param_specs(state["params"], mesh)
            shardings = {"params": sh.named(mesh, pspecs),
                         "opt": None}
            state = store.restore(ckpt_dir, latest, state)
            params, opt_state = state["params"], state["opt"]
            start_step = latest
            print(f"[train] resumed from step {latest}")

        def final_save():
            ckpt.wait()
            store.save(ckpt_dir, int(last_step[0]),
                       {"params": params, "opt": opt_state})

        store.install_sigterm_handler(final_save)

    last_step = [start_step]
    losses = []
    t0 = time.time()
    for step in range(start_step, steps):
        b = data.batch_at(step)
        batch_dict = {"tokens": b.tokens, "targets": b.targets}
        if cfg.num_image_tokens:
            batch_dict["extra_embeds"] = jnp.zeros(
                (batch, cfg.num_image_tokens, cfg.d_model), cfg.cdtype())
        if cfg.is_encdec:
            batch_dict["audio_embeds"] = jnp.zeros(
                (batch, cfg.encoder_seq, cfg.d_model), cfg.cdtype())
        params, opt_state, metrics = cell.fn(params, opt_state, batch_dict)
        last_step[0] = step + 1
        if (step + 1) % log_every == 0 or step + 1 == steps:
            loss = float(metrics["loss"])
            losses.append((step + 1, loss))
            rate = (step + 1 - start_step) / (time.time() - t0)
            print(f"[train] step {step + 1:5d} loss {loss:.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f} "
                  f"({rate:.2f} steps/s)", flush=True)
        if ckpt and (step + 1) % ckpt_every == 0:
            ckpt.save(step + 1, {"params": params, "opt": opt_state})
    if ckpt:
        ckpt.wait()
        store.save(ckpt_dir, steps, {"params": params, "opt": opt_state})
    return {"losses": losses, "params": params, "opt_state": opt_state}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="quickstart-100m",
                    help="arch id, '<id>-smoke', or 'quickstart-100m'")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--optimizer", default="adamw")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args(argv)

    cfg = _resolve_config(args.arch)
    tcfg = TrainConfig(optimizer=args.optimizer, learning_rate=args.lr,
                       warmup_steps=min(100, args.steps // 10 + 1),
                       total_steps=args.steps)
    out = train_loop(cfg, tcfg, batch=args.batch, seq=args.seq,
                     steps=args.steps, ckpt_dir=args.ckpt_dir,
                     ckpt_every=args.ckpt_every, resume=args.resume)
    first, last = out["losses"][0][1], out["losses"][-1][1]
    print(f"[train] loss {first:.4f} -> {last:.4f}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
