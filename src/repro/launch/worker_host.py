"""``runctl serve-worker`` — run one socket-transport worker host.

The remote half of the runtime's ``socket`` backend
(:mod:`repro.runtime.transport.socket_host`): a standalone process that
listens on a TCP port, accepts a master session, and executes the coded
tasks the master dispatches — rounds in, results out, over the
length-prefixed frame protocol.  One host per worker slot: a 5-worker
``RuntimeConfig`` needs 5 of these (possibly on 5 machines), named in
``cfg.hosts`` / ``runctl --hosts``.

Start one per machine::

    PYTHONPATH=src python -m repro.launch.runctl serve-worker --port 7001
    # or equivalently
    PYTHONPATH=src python -m repro.launch.worker_host --port 7001

then point the master at them::

    PYTHONPATH=src python -m repro.launch.runctl --jobs 100 \
        --backend socket --hosts hostA:7001,hostB:7001,hostC:7001 \
        --mu 400,650,380

``--port 0`` binds an ephemeral port and announces it on stdout as
``LISTENING <host> <port>`` — how the test harness
(:class:`repro.runtime.transport.socket_host.LocalCluster`) discovers its
workers.  The host serves sessions in a loop (a new master can connect
after the previous one stopped); ``--once`` exits after the first orderly
session.

The wire protocol carries pickles and authenticates nothing: bind to a
trusted interface (the default is loopback; use ``--host 0.0.0.0`` only
on a private cluster network).
"""

from __future__ import annotations

import argparse

from repro.runtime.transport.socket_host import serve_worker_host

__all__ = ["main"]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="runctl serve-worker", description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--host", default="127.0.0.1",
                    help="interface to bind (default loopback; use a "
                         "private-network address for real multi-host runs)")
    ap.add_argument("--port", type=int, default=0,
                    help="TCP port to listen on (0 = ephemeral, announced "
                         "as 'LISTENING <host> <port>' on stdout)")
    ap.add_argument("--once", action="store_true",
                    help="exit after the first orderly master session")
    ap.add_argument("--metrics-port", type=int, default=None,
                    help="also serve live Prometheus worker metrics on this "
                         "TCP port at /metrics (0 = ephemeral, announced as "
                         "'METRICS <host> <port>' on stdout)")
    args = ap.parse_args(argv)
    serve_worker_host(args.port, args.host, once=args.once,
                      announce=lambda line: print(line, flush=True),
                      metrics_port=args.metrics_port)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
