"""Launch layer: meshes, sharding rules, step builders, dry-run, drivers.

NOTE: repro.launch.dryrun sets XLA_FLAGS at import time (512 host devices);
import it only as an entry point, never from library code.
"""

from repro.launch import mesh, roofline, sharding  # noqa: F401
