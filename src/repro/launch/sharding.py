"""Sharding rules: parameter / optimizer-state / cache / batch PartitionSpecs.

MaxText-style logical layout on a ("pod"?, "data", "model") mesh:

* batch            -> ("pod", "data")      (pods are pure DP; see fault.py)
* vocab / heads / experts / ffn / d_inner  -> "model"   (tensor parallel)
* d_model (embed) on weight matrices       -> "data"    (ZeRO-3 / FSDP)
* scanned-layer leading axis               -> replicated (scan carries it)
* optimizer state mirrors its parameter (factored Adafactor states inherit
  the parameter's spec minus the reduced dimension)

Rules are keyed on the *leaf name* (the last key in the parameter path) and
the leaf's rank, so they apply uniformly to every architecture in the zoo.
pjit rejects non-divisible argument shardings, so ``fix_spec`` relocates a
mesh axis to a dividing dim (8 KV heads can't split 16 ways -> shard
head_dim instead) or drops it; every fallback is visible in the dry-run's
sharding dump.
"""

from __future__ import annotations

from typing import Any, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.compat import tree_flatten_with_path
from repro.launch.mesh import batch_axes

__all__ = ["param_specs", "opt_state_specs", "batch_specs", "cache_specs_tree",
           "named", "spec_bytes_per_device"]

# body specs EXCLUDING any leading scanned-layer axis (prepended if present)
_FSDP = "data"
_TP = "model"

_BODY_RULES: dict[tuple[str, int], tuple] = {
    # attention
    ("wq", 3): (_FSDP, _TP, None),
    ("wk", 3): (_FSDP, _TP, None),
    ("wv", 3): (_FSDP, _TP, None),
    ("wo", 3): (_TP, None, _FSDP),
    # dense / shared-expert MLPs
    ("w_gate", 2): (_FSDP, _TP),
    ("w_up", 2): (_FSDP, _TP),
    ("w_down", 2): (_TP, _FSDP),
    ("w_fc", 2): (_FSDP, _TP),
    ("w_proj", 2): (_TP, _FSDP),
    ("b_fc", 1): (_TP,),
    ("b_proj", 1): (None,),
    # MoE experts (leading E axis; "we_*" names are the routed experts)
    ("we_gate", 3): (_TP, _FSDP, None),
    ("we_up", 3): (_TP, _FSDP, None),
    ("we_down", 3): (_TP, None, _FSDP),
    ("router", 2): (_FSDP, None),
    # Mamba2 (split per-stream projections; see models/ssm.py)
    ("gate_proj", 2): (_FSDP, _TP),
    ("x_proj", 2): (_FSDP, _TP),
    # B/C/dt projections are tiny (d_model x 128 / x H); TP-sharding their
    # outputs makes the SSD score einsum a psum -- replicate instead.
    ("B_proj", 2): (_FSDP, None),
    ("C_proj", 2): (_FSDP, None),
    ("dt_proj", 2): (_FSDP, None),
    ("out_proj", 2): (_TP, _FSDP),
    ("conv_x", 2): (None, _TP),
    ("conv_x_b", 1): (_TP,),
    ("conv_B", 2): (None, _TP),
    ("conv_B_b", 1): (_TP,),
    ("conv_C", 2): (None, _TP),
    ("conv_C_b", 1): (_TP,),
    ("conv_w", 2): (None, _TP),
    ("conv_b", 1): (_TP,),
    ("A_log", 1): (_TP,),
    ("D", 1): (_TP,),
    ("dt_bias", 1): (_TP,),
    ("norm_scale", 1): (_TP,),
    # RG-LRU
    ("in_gelu", 2): (_FSDP, _TP),
    ("in_rnn", 2): (_FSDP, _TP),
    ("w_a", 2): (None, _TP),
    ("w_x", 2): (None, _TP),
    ("b_a", 1): (_TP,),
    ("b_x", 1): (_TP,),
    ("Lambda", 1): (_TP,),
    ("out", 2): (_TP, _FSDP),
    # norms: tiny, replicated
    ("scale", 1): (None,),
    ("bias", 1): (None,),
}

_TOP_RULES: dict[str, tuple] = {
    "embed": (_TP, _FSDP),       # (V, D)
    "lm_head": (_FSDP, _TP),     # (D, V)
}


def _leaf_name(path) -> str:
    last = path[-1]
    return str(getattr(last, "key", getattr(last, "idx", last)))


def _axis_size(mesh: Mesh, ax) -> int:
    axes = ax if isinstance(ax, tuple) else (ax,)
    return int(np.prod([mesh.shape[a] for a in axes]))


def fix_spec(shape: tuple, spec: tuple, mesh: Mesh, *,
             relocate: bool = True) -> P:
    """Make a proposed spec legal for ``shape`` on ``mesh``.

    pjit requires every *argument* dimension to divide evenly by its mesh
    axes (GSPMD pads intermediates, not inputs).  For each named axis whose
    proposed dim does not divide, try to relocate it to a later (then
    earlier) unassigned dim that does divide — e.g. 8 KV heads cannot shard
    over a 16-way "model" axis, but head_dim=128 can, so
    (..., "model", None) becomes (..., None, "model").  If no dim fits, the
    axis is dropped (replicated) — visible honestly in the roofline.
    """
    spec = tuple(spec)[: len(shape)]
    spec = spec + (None,) * (len(shape) - len(spec))
    out: list = [None] * len(shape)
    for i, ax in enumerate(spec):
        if ax is None:
            continue
        size = _axis_size(mesh, ax)
        candidates = (list(range(i, len(shape))) + list(range(i))
                      if relocate else [i])
        for j in candidates:
            if out[j] is None and spec[j] in (None, ax) \
                    and shape[j] % size == 0:
                out[j] = ax
                break
        # else: dropped (replicated)
    return P(*out)


# Attention projections must NOT relocate their TP axis to head_dim when
# the heads don't divide: dh-sharded q/k makes every score matmul a psum of
# an S x S tensor (measured: ~2 TB/device on llama3 pre-fix).  Dropping TP
# (heads replicated across "model", FSDP kept on d_model) is strictly
# better; the redundant attention compute shows up honestly in the HLO
# FLOPs term.
_NO_RELOCATE = {"wq", "wk", "wv", "wo"}


def _spec_for(path, leaf, mesh: Mesh) -> P:
    name = _leaf_name(path)
    ndim = len(leaf.shape)
    reloc = name not in _NO_RELOCATE
    if name in _TOP_RULES and ndim == len(_TOP_RULES[name]):
        return fix_spec(leaf.shape, _TOP_RULES[name], mesh, relocate=reloc)
    if (name, ndim) in _BODY_RULES:
        return fix_spec(leaf.shape, _BODY_RULES[(name, ndim)], mesh,
                        relocate=reloc)
    if (name, ndim - 1) in _BODY_RULES:  # scanned: leading repeats axis
        return fix_spec(leaf.shape,
                        (None,) + _BODY_RULES[(name, ndim - 1)], mesh,
                        relocate=reloc)
    return P()  # replicate anything unmatched (scalars, counters, ...)


def param_specs(params_shapes: Any, mesh: Mesh,
                profile: str = "tp_fsdp") -> Any:
    """PartitionSpec pytree matching a params (shape) pytree.

    profile "serve" drops the FSDP axis (weights stay TP-sharded,
    replicated over data): serving must not re-gather weights per token.
    """
    flat, treedef = tree_flatten_with_path(params_shapes)
    specs = [_spec_for(p, l, mesh) for p, l in flat]
    if profile == "serve":
        specs = [P(*(None if ax == _FSDP else ax for ax in tuple(sp)))
                 for sp in specs]
    return treedef.unflatten(specs)


def opt_state_specs(opt_shapes: Any, pspecs: Any, mesh: Mesh) -> Any:
    """Optimizer-state specs.

    m/v mirror their parameter; Adafactor's factored "vr" (param minus last
    dim) and "vc" (param minus second-to-last) drop that entry of the spec;
    scalars (step/gnorm/lr) replicate.
    """
    pflat, _ = tree_flatten_with_path(pspecs,
                                      is_leaf=lambda x: isinstance(x, P))
    by_path = {tuple(_leaf_name_seq(p)): s for p, s in pflat}

    def spec_of(path, leaf):
        names = _leaf_name_seq(path)
        if not names or names[0] in ("step", "gnorm", "lr"):
            return P()
        kind = names[0]              # "m" | "v" | ...
        rest = tuple(names[1:])
        if kind in ("m", "v") and rest and rest[-1] in ("vr", "vc", "v"):
            sub, rest = rest[-1], rest[:-1]
        else:
            sub = None
        pspec = by_path.get(rest)
        if pspec is None:
            return P()
        spec = tuple(pspec)
        spec = spec + (None,) * (len(_shape_of(leaf)) - len(spec)) \
            if len(spec) < len(_shape_of(leaf)) else spec
        if sub == "vr":
            spec = spec[:-1]
        elif sub == "vc":
            spec = spec[:-2] + spec[-1:]
        if len(spec) != len(_shape_of(leaf)):
            spec = spec[: len(_shape_of(leaf))]
        return fix_spec(_shape_of(leaf), spec, mesh)

    flat, treedef = tree_flatten_with_path(opt_shapes)
    return treedef.unflatten([spec_of(p, l) for p, l in flat])


def _shape_of(leaf):
    return getattr(leaf, "shape", ())


def _leaf_name_seq(path) -> list[str]:
    return [str(getattr(p, "key", getattr(p, "idx", p))) for p in path]


def batch_specs(batch_shapes: Any, mesh: Mesh,
                profile: str = "tp_fsdp") -> Any:
    """Shard dim 0 of every batch leaf over the batch axes; scalars replicate."""
    baxes = batch_axes(mesh)
    if profile == "fsdp":  # pure-DP: the model axis also carries batch
        baxes = tuple(a for a in ("pod", "data", "model")
                      if a in mesh.axis_names)

    def spec(leaf):
        shape = _shape_of(leaf)
        if len(shape) == 0:
            return P()
        return fix_spec(shape, (baxes,) + (None,) * (len(shape) - 1),
                        mesh, relocate=False)

    return jax.tree.map(spec, batch_shapes)


def cache_specs_tree(cache_shapes: Any, mesh: Mesh) -> Any:
    """Decode caches: (reps, B, ...) leaves -> batch on dim 1, heads/model
    dims heuristically on the axis whose name matches, else replicated.

    Cache layouts (see transformer.init_cache):
      k/v   (reps, B, S, n_kv, Dh) -> (None, batch, None, "model", None)
      pos   (reps, B, W)           -> (None, batch, None)
      conv  (reps, B, K, C)        -> (None, batch, None, "model")
      state (reps, B, H, P, N)     -> (None, batch, "model", None, None)
      h     (reps, B, R)           -> (None, batch, "model")
    Distinguishing k/v from state: state is fp32 and named "state".
    """
    baxes = batch_axes(mesh)
    flat, treedef = tree_flatten_with_path(cache_shapes)

    def _first_legal(shape, candidates):
        """First candidate whose named axes all survive fix_spec."""
        best = None
        for prop in candidates:
            want = sum(1 for a in prop if a is not None)
            fixed = fix_spec(shape, prop, mesh, relocate=False)
            got = sum(1 for a in tuple(fixed) if a is not None)
            if best is None:
                best = fixed
            if got == want:
                return fixed
        return best

    def spec(path, leaf):
        name = _leaf_name_seq(path)[-1]
        nd = len(_shape_of(leaf))
        shape = _shape_of(leaf)
        if (name in ("k", "v") or nd == 5) and nd == 5:
            # KV caches (reps, B, S, n_kv, Dh): head-parallel when the KV
            # heads divide the TP axis, else context-parallel on S
            # (flash-decoding style) so the cache never replicates.
            return _first_legal(shape, [(None, baxes, None, _TP, None),
                                        (None, baxes, _TP, None, None)])
        if name == "state":
            prop = (None, baxes, _TP, None, None)
        elif name == "conv":
            prop = (None, baxes, None, _TP)
        elif name == "h":
            prop = (None, baxes, _TP)
        elif name == "pos":
            prop = (None, baxes, None)
        else:
            prop = (None,) * nd
        return fix_spec(shape, prop, mesh, relocate=False)

    return treedef.unflatten([spec(p, l) for p, l in flat])


def named(mesh: Mesh, spec_tree: Any) -> Any:
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def spec_bytes_per_device(shapes: Any, specs: Any, mesh: Mesh) -> int:
    """Estimated per-device bytes for a (shape, spec) pytree pair."""
    total = 0
    for leaf, spec in zip(jax.tree.leaves(shapes),
                          jax.tree.leaves(specs,
                                          is_leaf=lambda x: isinstance(x, P))):
        shape = list(leaf.shape)
        for i, ax in enumerate(tuple(spec)[: len(shape)]):
            if ax is None:
                continue
            axes = ax if isinstance(ax, tuple) else (ax,)
            div = int(np.prod([mesh.shape[a] for a in axes]))
            shape[i] = int(np.ceil(shape[i] / div))
        total += int(np.prod(shape)) * leaf.dtype.itemsize
    return total
