"""Production meshes.

Defined as FUNCTIONS (never module-level constants) so importing this module
never touches jax device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import and only then calls these.

Single pod: 256 chips as (data=16, model=16).
Multi-pod:  2 pods x 256 chips as (pod=2, data=16, model=16); the "pod" axis
carries data parallelism (optionally MDS-coded, see repro.core) and is the
unit of failure/erasure in the fault-tolerance design.
"""

from __future__ import annotations

import jax
import numpy as np
from jax.sharding import Mesh

__all__ = ["make_production_mesh", "make_test_mesh", "batch_axes",
           "HardwareSpec", "TPU_V5E"]


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(data: int = 1, model: int = 1, pod: int = 0) -> Mesh:
    """Small mesh over however many (host) devices the test owns."""
    if pod:
        return jax.make_mesh((pod, data, model), ("pod", "data", "model"))
    return jax.make_mesh((data, model), ("data", "model"))


def batch_axes(mesh: Mesh) -> tuple[str, ...]:
    """Mesh axes that shard the global batch."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


class HardwareSpec:
    """Roofline constants for the target chip."""

    def __init__(self, name: str, peak_flops: float, hbm_bw: float,
                 ici_bw: float, hbm_bytes: float):
        self.name = name
        self.peak_flops = peak_flops        # bf16 FLOP/s per chip
        self.hbm_bw = hbm_bw                # bytes/s per chip
        self.ici_bw = ici_bw                # bytes/s per link
        self.hbm_bytes = hbm_bytes          # HBM capacity per chip


TPU_V5E = HardwareSpec("tpu_v5e", peak_flops=197e12, hbm_bw=819e9,
                       ici_bw=50e9, hbm_bytes=16 * 1024**3)
