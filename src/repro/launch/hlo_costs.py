"""Trip-count-aware cost extraction from post-optimization HLO text.

``compiled.cost_analysis()`` counts each ``while`` body ONCE, so any model
using scan-over-layers (all of ours) under-reports FLOPs, bytes and — worst
— per-layer collectives by the layer count (verified empirically: a scanned
8-step matmul reports 1 matmul of FLOPs).  This module re-derives costs
from ``compiled.as_text()``:

* parses every computation block and its instruction shapes,
* finds each ``while``'s trip count from the loop-condition's comparison
  constant (jax scans lower to ``lt(induction, constant(N))``),
* costs dots (2 * prod(out_dims) * contract size), collectives (ring-model
  bytes/device, as launch/roofline.py) and top-level instruction bytes
  (operands + results at fusion boundaries — internal temps excluded),
* and folds callee costs into callers: while bodies/conditions x trip
  count, fusions/calls x 1, conditionals at the max of their branches.

The result is the per-device (FLOPs, HBM bytes, collective bytes) triple
the roofline terms need.  It is an *estimate* (elementwise FLOPs are not
counted; bytes use fusion-boundary accounting) — both choices are
documented in EXPERIMENTS.md §Roofline methodology.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Optional

import numpy as np

__all__ = ["module_costs", "ModuleCosts"]

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s4": 1, "u4": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
}

# headers like: %region_0.2 (arg: (s32[], f32[2,2])) -> (s32[], f32[2,2]) {
# (parameter lists may contain nested tuple parens, so just anchor on the
#  leading name, a "->" and a trailing "{")
_COMP_HEADER_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*->.*\{\s*$")
_INSTR_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.+)$")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_TUPLE_SHAPE_RE = re.compile(r"^\(")
_OP_RE = re.compile(r"^(?:\(.*?\)|\w+\[[\d,]*\][^\s]*)\s+([\w\-]+)\(")
_CALLED_RE = re.compile(
    r"(?:body|condition|to_apply|calls)=\{?%?([\w.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_CONST_RE = re.compile(r"constant\((\d+)\)")
_OPERANDS_RE = re.compile(r"\(([^)]*)\)")

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_bytes_all(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        nb = _DTYPE_BYTES.get(dt)
        if nb is None:
            continue
        n = 1
        for dim in dims.split(","):
            if dim:
                n *= int(dim)
        total += nb * n
    return total


@dataclasses.dataclass
class _Instr:
    name: str
    type_str: str
    op: str
    line: str


@dataclasses.dataclass
class _Computation:
    name: str
    instrs: list
    shapes: dict           # value name -> type string


@dataclasses.dataclass
class ModuleCosts:
    flops: float
    hbm_bytes: float
    collective_bytes: float
    collective_counts: dict
    trip_counts: dict      # while body name -> trip count

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


def _parse(text: str) -> tuple[dict, Optional[str]]:
    comps: dict[str, _Computation] = {}
    entry = None
    cur: Optional[_Computation] = None
    for raw in text.splitlines():
        line = raw.rstrip()
        if cur is None:
            m = _COMP_HEADER_RE.match(line.strip())
            if m:
                cur = _Computation(m.group(2), [], {})
                if m.group(1):
                    entry = m.group(2)
            continue
        if line.strip() == "}":
            comps[cur.name] = cur
            cur = None
            continue
        m = _INSTR_RE.match(line)
        if not m:
            continue
        name, rest = m.group(1), m.group(2)
        # type is everything up to the op token
        om = _OP_RE.match(rest)
        op = om.group(1) if om else ""
        type_str = rest.split(f" {op}(")[0] if op else rest
        cur.shapes[name] = type_str
        cur.instrs.append(_Instr(name, type_str, op, rest))
    return comps, entry


def _operand_names(line: str) -> list[str]:
    """Operand value names from an instruction's ``op(...)`` list.

    XLA emits operands either bare (``%name``) or typed
    (``f32[128,128]{1,0} %name`` — the form newer dumps use); either way
    the value name is the last whitespace-separated token of each
    comma-separated entry.
    """
    ops_m = _OPERANDS_RE.search(line)
    if not ops_m:
        return []
    names = []
    for entry in _split_top_level(ops_m.group(1)):
        toks = entry.strip().split()
        if toks:
            names.append(toks[-1].lstrip("%"))
    return names


def _split_top_level(text: str) -> list[str]:
    """Split on commas outside ``[...]``/``{...}`` (shape dims, layouts)."""
    out, depth, start = [], 0, 0
    for i, ch in enumerate(text):
        if ch in "[{(":
            depth += 1
        elif ch in "]})":
            depth -= 1
        elif ch == "," and depth == 0:
            out.append(text[start:i])
            start = i + 1
    out.append(text[start:])
    return out


def _dot_flops(instr: _Instr, comp: _Computation) -> float:
    out_elems = 1
    m = _SHAPE_RE.search(instr.type_str)
    if m:
        for dim in m.group(2).split(","):
            if dim:
                out_elems *= int(dim)
    # contracting size from lhs operand shape; typed operand entries carry
    # the shape inline, so fall back to parsing the entry itself when the
    # value name is defined in another computation (e.g. a parameter)
    cm = _CONTRACT_RE.search(instr.line)
    operands = _operand_names(instr.line)
    contract = 1
    if cm and operands:
        lhs_type = comp.shapes.get(operands[0], "")
        if not _SHAPE_RE.search(lhs_type):
            ops_m = _OPERANDS_RE.search(instr.line)
            lhs_type = (_split_top_level(ops_m.group(1))[0]
                        if ops_m else "")
        sm = _SHAPE_RE.search(lhs_type)
        if sm:
            dims = [int(x) for x in sm.group(2).split(",") if x]
            for ci in cm.group(1).split(","):
                if ci and int(ci) < len(dims):
                    contract *= dims[int(ci)]
    return 2.0 * out_elems * contract


def _collective_bytes(instr: _Instr) -> float:
    rbytes = _shape_bytes_all(instr.type_str)
    # The CPU backend legalizes bf16 reductions by promoting to f32
    # (to_apply=%..._promoted): on TPU these all-reduces move bf16, so
    # count half the f32 bytes.
    if "promoted" in instr.line and "f32" in instr.type_str:
        rbytes //= 2
    g = _GROUPS_RE.search(instr.line)
    if g:
        n = len(g.group(1).split(","))
    else:
        gi = _GROUPS_IOTA_RE.search(instr.line)
        n = int(gi.group(2)) if gi else 1
    op = instr.op
    if op.startswith("collective-permute"):
        return float(rbytes)
    if n <= 1:
        return 0.0
    if op.startswith("all-reduce"):
        return 2.0 * (n - 1) / n * rbytes
    if op.startswith("reduce-scatter"):
        return float((n - 1) * rbytes)
    return (n - 1) / n * rbytes  # all-gather / all-to-all


def _instr_bytes(instr: _Instr, comp: _Computation) -> float:
    """Fusion-boundary bytes: result + operands of top-level instrs."""
    skip_ops = {"parameter", "constant", "tuple", "get-tuple-element",
                "bitcast", "while", "conditional", "call", "after-all",
                "partition-id", "replica-id", "iota"}
    if instr.op in skip_ops or not instr.op:
        return 0.0
    total = float(_shape_bytes_all(instr.type_str))
    ops_m = _OPERANDS_RE.search(instr.line)
    if ops_m:
        for entry in _split_top_level(ops_m.group(1)):
            toks = entry.strip().split()
            if not toks:
                continue
            t = comp.shapes.get(toks[-1].lstrip("%"))
            if t:
                total += _shape_bytes_all(t)
            elif len(toks) > 1:       # typed operand: shape is inline
                total += _shape_bytes_all(" ".join(toks[:-1]))
    return total


def _trip_count(cond: _Computation) -> int:
    """Largest integer compared against in the condition (scan bound)."""
    best = 1
    for instr in cond.instrs:
        if instr.op in ("compare", "lt", "le"):
            for c in _CONST_RE.findall(instr.line):
                best = max(best, int(c))
        elif instr.op == "constant":
            for c in _CONST_RE.findall(instr.line):
                best = max(best, int(c))
    return best


def module_costs(text: str) -> ModuleCosts:
    comps, entry = _parse(text)
    if entry is None:
        # fall back: biggest computation
        entry = max(comps, key=lambda c: len(comps[c].instrs)) if comps \
            else None
    memo: dict[str, tuple] = {}
    trip_counts: dict[str, int] = {}

    def cost_of(name: str, stack=()) -> tuple:
        if name in memo:
            return memo[name]
        if name not in comps or name in stack:
            return (0.0, 0.0, 0.0, {})
        comp = comps[name]
        flops = byts = coll = 0.0
        counts: dict[str, int] = {}
        for instr in comp.instrs:
            if instr.op == "dot":
                flops += _dot_flops(instr, comp)
            if any(instr.op.startswith(c) for c in _COLLECTIVES):
                if instr.op.endswith("-done"):
                    continue
                coll += _collective_bytes(instr)
                key = instr.op.replace("-start", "")
                counts[key] = counts.get(key, 0) + 1
            byts += _instr_bytes(instr, comp)
            # recurse into called computations
            called = _CALLED_RE.findall(instr.line)
            if instr.op == "while" and called:
                body = cond = None
                bm = re.search(r"body=%?([\w.\-]+)", instr.line)
                cm = re.search(r"condition=%?([\w.\-]+)", instr.line)
                body = bm.group(1) if bm else None
                cond = cm.group(1) if cm else None
                trips = _trip_count(comps[cond]) if cond in comps else 1
                if body:
                    trip_counts[body] = trips
                    f, b, c, k = cost_of(body, stack + (name,))
                    flops += f * trips
                    byts += b * trips
                    coll += c * trips
                    for kk, vv in k.items():
                        counts[kk] = counts.get(kk, 0) + vv * trips
            elif instr.op == "conditional":
                brm = _BRANCHES_RE.search(instr.line)
                branches = ([b.strip().lstrip("%") for b in
                             brm.group(1).split(",")] if brm else called)
                if branches:
                    sub = [cost_of(b, stack + (name,)) for b in branches]
                    f, b_, c, k = max(sub, key=lambda t: t[0] + t[1])
                    flops += f
                    byts += b_
                    coll += c
                    for kk, vv in k.items():
                        counts[kk] = counts.get(kk, 0) + vv
            else:
                for cal in called:
                    f, b, c, k = cost_of(cal, stack + (name,))
                    flops += f
                    byts += b
                    coll += c
                    for kk, vv in k.items():
                        counts[kk] = counts.get(kk, 0) + vv
        memo[name] = (flops, byts, coll, counts)
        return memo[name]

    if entry is None:
        return ModuleCosts(0, 0, 0, {}, {})
    f, b, c, k = cost_of(entry)
    return ModuleCosts(flops=f, hbm_bytes=b, collective_bytes=c,
                       collective_counts=k, trip_counts=trip_counts)
