"""Batched serving driver with deadline-bounded progressive resolution.

The paper's §IV deadline experiment at the LM-head (DESIGN.md §3.1):
each decode step has a budget, logits are produced resolution-by-
resolution MSB-first, and when the budget expires the server releases
the best resolution computed so far instead of nothing.

Two budget modes, one release contract:

* ``layer_budget`` — the budget is a *resolution count* (deterministic,
  test-friendly): the jitted on-chip head series
  (:func:`repro.core.progressive.resolution_series`) computes ``m``
  plane-partial logits and the step releases layer ``budget``.
* ``deadline_ms`` — the budget is wall-clock, and the step IS a runtime
  job: the head matmul ``hidden @ W`` is submitted to a
  :class:`~repro.runtime.gateway.ServingGateway` (thread-backend fleet,
  one per batch shape) with the step's deadline and a guaranteed
  minimum of resolution 0, so all deadline logic — §IV termination,
  best-ready release, guaranteed-minimum rounds — flows through the
  runtime's own machinery rather than a serving-side controller.  Both
  operands are digit-decomposed, so the step walks the full
  ``L = 2m - 1`` layered resolutions of Definition 1.

The historical ``PlaneBudgetController`` (a serving-local EWMA deadline
predictor) is gone: ``launch/serve.py`` no longer owns any deadline
controller.
"""

from __future__ import annotations

import argparse
import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import registry
from repro.configs.base import ModelConfig
from repro.core import progressive
from repro.models import transformer as T
from repro.runtime import RuntimeConfig, ServingGateway

__all__ = ["ProgressiveServer", "ServeStats", "main"]


@dataclasses.dataclass
class ServeStats:
    steps: int = 0
    full_resolution: int = 0
    released_at_layer: Optional[list] = None
    #: the release scale: ``m`` head planes (layer_budget / unbudgeted
    #: mode) or ``2m - 1`` layered resolutions (deadline_ms mode)
    resolutions: int = 0
    #: measured head-service seconds per step (deadline_ms mode only) —
    #: the calibration signal for deadline-sizing tests
    head_service_seconds: Optional[list] = None

    def __post_init__(self):
        if self.released_at_layer is None:
            self.released_at_layer = []
        if self.head_service_seconds is None:
            self.head_service_seconds = []


class _RuntimeHead:
    """The LM head as runtime jobs: one warm thread-backend gateway per
    batch shape, each decode step one deadline-bounded layered job.

    ``hidden @ W`` is submitted as ``a.T @ b`` with ``a = hidden.T``
    (so the coded split needs ``n1 | batch`` and ``n2 | vocab``), a
    per-step absolute deadline, and ``min_resolution=0`` — the runtime
    guarantees resolution 0 even past the deadline, the §IV
    release-something contract the old plane controller hand-rolled.
    """

    def __init__(self, w: np.ndarray, m: int, d: int, batch: int):
        vocab = w.shape[1]
        n1 = next(n for n in (4, 2, 1) if batch % n == 0)
        n2 = next(n for n in (8, 4, 2, 1) if vocab % n == 0)
        cfg = RuntimeConfig(mu=(500.0, 500.0, 500.0), arrival_rate=1000.0,
                            n1=n1, n2=n2, omega=1.0, m=m, d=d,
                            straggler="none", backend="thread")
        self.w = np.asarray(w, np.float64)
        self.num_layers = cfg.num_layers
        self.gateway = ServingGateway(cfg, admission="none").start()

    def step(self, hidden: np.ndarray,
             deadline_s: float) -> tuple[np.ndarray, int, float]:
        """One head matmul under a deadline; returns
        ``(logits, released_resolution, service_seconds)``."""
        ticket = self.gateway.submit(hidden.T, self.w,
                                     deadline=max(deadline_s, 1e-6),
                                     min_resolution=0)
        ticket.wait()
        lr = ticket.result
        rel = ticket.released_resolution
        if rel < 0:
            # deadline fired before even resolution 0 landed; the
            # guaranteed-minimum rounds still finish it — block for the
            # res-0 value, the step must release *something*
            lr.wait_resolution(0)
            rel = 0
        svc = (0.0 if lr.service_started_at is None
               or lr.released_at is None
               else lr.released_at - lr.service_started_at)
        return np.asarray(lr.resolution(rel)), rel, svc

    def close(self) -> None:
        self.gateway.stop()


class ProgressiveServer:
    """Greedy batched decoding with a layered LM head."""

    def __init__(self, cfg: ModelConfig, params: dict, *, m: int = 2,
                 d: int = 7):
        self.cfg = cfg
        self.params = params
        w = (params["embed"].T if cfg.tie_embeddings
             else params["lm_head"]).astype(jnp.float32)
        self.lm_head = progressive.make_layered_linear(w, m=m, d=d)
        self._head_w = w
        self.m = m
        self.d = d
        self._runtime_heads: dict[int, _RuntimeHead] = {}

        def hidden_step(params, token, caches, pos):
            """decode_step but returning final hidden state, not logits."""
            # reuse decode_step minus the head: cheapest correct route is to
            # run it and also recompute hidden; instead we call the internal
            # machinery directly.
            x = T._embed_inputs(params, token, cfg)
            new_caches = []
            if cfg.is_encdec:
                caches, enc_kvs = caches
            gi = 0
            from repro.models.transformer import (_layer_decode,
                                                  block_groups)
            for g, (unit, reps) in enumerate(block_groups(cfg)):
                unit_params = params["groups"][g]
                unit_cache = caches[g]
                if cfg.is_encdec:
                    ek, ev = enc_kvs[gi]
                    gi += 1

                    def body(h, xs):
                        pl_, cl, ekl, evl = xs
                        h, c = _layer_decode("cross", pl_, h, cl, cfg, pos,
                                             enc_kv=(ekl, evl))
                        return h, c

                    x, nc = jax.lax.scan(body, x, (unit_params[0],
                                                   unit_cache[0], ek, ev))
                    new_caches.append([nc])
                    continue

                def body(h, xs):
                    pl_, cl = xs
                    ncs = []
                    for kind, pk, ck in zip(unit, pl_, cl):
                        h, nc_ = _layer_decode(kind, pk, h, ck, cfg, pos)
                        ncs.append(nc_)
                    return h, ncs

                x, nc = jax.lax.scan(body, x, (unit_params, unit_cache))
                new_caches.append(nc)
            from repro.models.layers import apply_norm
            x = apply_norm(cfg.norm, x, params["final_norm"])
            if cfg.is_encdec:
                return x[:, 0, :], (new_caches, enc_kvs)
            return x[:, 0, :], new_caches

        self._hidden_step = jax.jit(hidden_step)
        self._head_series = jax.jit(
            lambda h: progressive.resolution_series(self.lm_head,
                                                    h.astype(jnp.float32)))

    def _runtime_head(self, batch: int) -> _RuntimeHead:
        head = self._runtime_heads.get(batch)
        if head is None:
            head = _RuntimeHead(np.asarray(self._head_w), self.m, self.d,
                                batch)
            self._runtime_heads[batch] = head
        return head

    def close(self) -> None:
        """Stop every runtime-head gateway fleet (idempotent)."""
        heads, self._runtime_heads = self._runtime_heads, {}
        for head in heads.values():
            head.close()

    def __enter__(self) -> "ProgressiveServer":
        return self

    def __exit__(self, *exc) -> None:
        del exc
        self.close()

    def prefill(self, tokens, max_len: int, **extras):
        return T.prefill(self.params, tokens, self.cfg, max_len=max_len,
                         **extras)

    def decode(self, tokens, caches, start_pos: int, num_tokens: int, *,
               layer_budget: Optional[int] = None,
               deadline_ms: Optional[float] = None):
        """Greedy decode; each step releases logits at the resolution the
        budget allows.  Returns (tokens (B, num_tokens), stats).

        With ``deadline_ms``, ``stats.released_at_layer`` counts layered
        resolutions (1..2m-1: the runtime decomposes BOTH operands);
        otherwise head planes (1..m).  ``stats.resolutions`` carries the
        scale in use.
        """
        if layer_budget is not None and deadline_ms is not None:
            raise ValueError(
                "layer_budget and deadline_ms are mutually exclusive "
                "budgets; pass one or the other")
        stats = ServeStats(resolutions=(2 * self.m - 1
                                        if deadline_ms is not None
                                        else self.m))
        tok = tokens
        out = []
        for i in range(num_tokens):
            pos = jnp.int32(start_pos + i)
            hidden, caches = self._hidden_step(self.params, tok, caches, pos)
            if deadline_ms is not None:
                # the step is a runtime job: deadline release, best-ready
                # resolution, and the guaranteed res-0 minimum all come
                # from the runtime's §IV machinery
                head = self._runtime_head(int(hidden.shape[0]))
                logits_np, rel, svc = head.step(
                    np.asarray(hidden, np.float64), deadline_ms / 1e3)
                release = rel + 1
                stats.head_service_seconds.append(svc)
                logits = jnp.asarray(logits_np)
            else:
                release = (self.m if layer_budget is None
                           else max(1, min(layer_budget, self.m)))
                series = self._head_series(hidden)     # (m, B, V)
                logits = series[release - 1]
            stats.steps += 1
            stats.full_resolution += int(release == stats.resolutions)
            stats.released_at_layer.append(release)
            tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
            out.append(tok)
        return jnp.concatenate(out, axis=1), stats


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="llama3-8b-smoke")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--layer-budget", type=int, default=None,
                    help="resolutions computable per step (None = all)")
    ap.add_argument("--deadline-ms", type=float, default=None,
                    help="wall-clock budget per decode step; the head "
                         "runs as a deadline-bounded runtime job")
    ap.add_argument("--planes", type=int, default=2)
    args = ap.parse_args(argv)

    if args.arch.endswith("-smoke"):
        cfg = registry.get_smoke_config(args.arch[: -len("-smoke")])
    else:
        cfg = registry.get_config(args.arch)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    server = ProgressiveServer(cfg, params, m=args.planes)

    rng = np.random.default_rng(0)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size,
                                      (args.batch, args.prompt_len)),
                         jnp.int32)
    max_len = args.prompt_len + args.gen
    extras = {}
    if cfg.is_encdec:
        extras["audio_embeds"] = jnp.zeros(
            (args.batch, cfg.encoder_seq, cfg.d_model), cfg.cdtype())
    if cfg.num_image_tokens:
        extras["extra_embeds"] = jnp.zeros(
            (args.batch, cfg.num_image_tokens, cfg.d_model), cfg.cdtype())
    try:
        _, caches = server.prefill(tokens, max_len, **extras)
        out, stats = server.decode(tokens[:, -1:], caches, args.prompt_len,
                                   args.gen,
                                   layer_budget=args.layer_budget,
                                   deadline_ms=args.deadline_ms)
    finally:
        server.close()
    print(f"[serve] generated {out.shape} tokens; "
          f"{stats.full_resolution}/{stats.steps} steps at full resolution "
          f"(of {stats.resolutions}); "
          f"release layers: {stats.released_at_layer}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
