"""Batched serving driver with deadline-bounded progressive resolution.

The paper's §IV deadline experiment, on-chip (DESIGN.md §3.1): each decode
step has a time budget.  The LM head is a :class:`LayeredLinear`
(digit-plane decomposed); logits are produced resolution-by-resolution,
MSB-planes first.  When the deadline hits, the server releases the best
resolution computed so far instead of nothing — mirroring the fusion node
releasing the highest completed layer.

On CPU the "budget" is measured in *resolution layers* rather than
wall-time (deterministic tests); ``--deadline-ms`` switches to wall-clock.
The wall-clock path is driven by :class:`PlaneBudgetController` — the
runtime engine's deadline-margin policy signal
(:func:`repro.runtime.adaptive.margin_ratio`) applied per decode step:
instead of reactively checking whether the deadline has *already* passed,
the server predicts whether the next plane's projected cost still fits
the remaining margin, and stops issuing planes the step before a miss.
"""

from __future__ import annotations

import argparse
import dataclasses
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import registry
from repro.configs.base import ModelConfig
from repro.core import progressive
from repro.models import transformer as T
from repro.runtime.adaptive import margin_ratio

__all__ = ["ProgressiveServer", "PlaneBudgetController", "main"]


class PlaneBudgetController:
    """Per-step plane budget from the runtime's deadline-margin signal.

    The serving twin of the runtime's ``deadline-margin`` ω-policy,
    sharing its margin arithmetic (:func:`repro.runtime.adaptive.
    margin_ratio`): the work unit is one MSB-first head plane instead of
    one mini-job round, and the control action is "issue the next plane
    or release now" instead of retuning ω.  An EWMA of measured per-plane
    seconds (persistent across decode steps — plane cost is stationary)
    projects the next plane's cost; the plane is issued only while the
    projected cost fits the remaining margin (``ratio >= low``).  Plane 0
    is always computed — releasing *something* is the §IV contract.
    """

    def __init__(self, deadline_ms: float, *, low: float = 1.0,
                 alpha: float = 0.3):
        if deadline_ms < 0.0:
            raise ValueError(f"deadline_ms must be >= 0, got {deadline_ms}")
        self.deadline = deadline_ms / 1e3   # seconds
        self.low = low
        self.alpha = alpha
        self._plane_ewma: Optional[float] = None
        self._t0 = 0.0

    def begin_step(self) -> None:
        """Start one decode step's clock."""
        self._t0 = time.perf_counter()

    def observe_plane(self, seconds: float) -> None:
        """Feed one plane's measured wall cost into the EWMA."""
        self._plane_ewma = (seconds if self._plane_ewma is None
                            else (1.0 - self.alpha) * self._plane_ewma
                            + self.alpha * seconds)

    def should_continue(self) -> bool:
        """Issue the next plane?  Shared margin math, one unit of work."""
        margin = self.deadline - (time.perf_counter() - self._t0)
        ratio = margin_ratio(margin, self._plane_ewma, 1)
        if ratio is None:
            # no cost estimate yet (first plane of the first step failed
            # to record?) — fall back to the reactive check
            return margin > 0.0
        return ratio >= self.low


@dataclasses.dataclass
class ServeStats:
    steps: int = 0
    full_resolution: int = 0
    released_at_layer: Optional[list] = None

    def __post_init__(self):
        if self.released_at_layer is None:
            self.released_at_layer = []


class ProgressiveServer:
    """Greedy batched decoding with a layered LM head."""

    def __init__(self, cfg: ModelConfig, params: dict, *, m: int = 2,
                 d: int = 7):
        self.cfg = cfg
        self.params = params
        w = (params["embed"].T if cfg.tie_embeddings
             else params["lm_head"]).astype(jnp.float32)
        self.lm_head = progressive.make_layered_linear(w, m=m, d=d)
        self.m = m

        def hidden_step(params, token, caches, pos):
            """decode_step but returning final hidden state, not logits."""
            # reuse decode_step minus the head: cheapest correct route is to
            # run it and also recompute hidden; instead we call the internal
            # machinery directly.
            x = T._embed_inputs(params, token, cfg)
            new_caches = []
            if cfg.is_encdec:
                caches, enc_kvs = caches
            gi = 0
            from repro.models.transformer import (_layer_decode,
                                                  block_groups)
            for g, (unit, reps) in enumerate(block_groups(cfg)):
                unit_params = params["groups"][g]
                unit_cache = caches[g]
                if cfg.is_encdec:
                    ek, ev = enc_kvs[gi]
                    gi += 1

                    def body(h, xs):
                        pl_, cl, ekl, evl = xs
                        h, c = _layer_decode("cross", pl_, h, cl, cfg, pos,
                                             enc_kv=(ekl, evl))
                        return h, c

                    x, nc = jax.lax.scan(body, x, (unit_params[0],
                                                   unit_cache[0], ek, ev))
                    new_caches.append([nc])
                    continue

                def body(h, xs):
                    pl_, cl = xs
                    ncs = []
                    for kind, pk, ck in zip(unit, pl_, cl):
                        h, nc_ = _layer_decode(kind, pk, h, ck, cfg, pos)
                        ncs.append(nc_)
                    return h, ncs

                x, nc = jax.lax.scan(body, x, (unit_params, unit_cache))
                new_caches.append(nc)
            from repro.models.layers import apply_norm
            x = apply_norm(cfg.norm, x, params["final_norm"])
            if cfg.is_encdec:
                return x[:, 0, :], (new_caches, enc_kvs)
            return x[:, 0, :], new_caches

        self._hidden_step = jax.jit(hidden_step)
        self._head_series = jax.jit(
            lambda h: progressive.resolution_series(self.lm_head,
                                                    h.astype(jnp.float32)))

        # Per-plane incremental head steps (progressive.plane_step), MSB
        # first.  Separate jitted fns (not one fused series) so a deadline
        # can stop BEFORE the next plane's matmul is issued.
        def make_plane_fn(l: int):
            if l == 0:
                return jax.jit(lambda h: progressive.plane_step(
                    self.lm_head, h.astype(jnp.float32), 0))
            return jax.jit(lambda h, acc: progressive.plane_step(
                self.lm_head, h.astype(jnp.float32), l, acc))

        self._plane_fns = [make_plane_fn(l) for l in range(self.m)]
        self._warm_plane_shapes: set = set()

    def prefill(self, tokens, max_len: int, **extras):
        return T.prefill(self.params, tokens, self.cfg, max_len=max_len,
                         **extras)

    def decode(self, tokens, caches, start_pos: int, num_tokens: int, *,
               layer_budget: Optional[int] = None,
               deadline_ms: Optional[float] = None):
        """Greedy decode; each step releases logits at the resolution the
        budget allows.  Returns (tokens (B, num_tokens), stats)."""
        if layer_budget is not None and deadline_ms is not None:
            raise ValueError(
                "layer_budget and deadline_ms are mutually exclusive "
                "budgets; pass one or the other")
        stats = ServeStats()
        budget: Optional[PlaneBudgetController] = None
        tok = tokens
        out = []
        for i in range(num_tokens):
            pos = jnp.int32(start_pos + i)
            hidden, caches = self._hidden_step(self.params, tok, caches, pos)
            if deadline_ms is not None:
                # Incremental MSB-first accumulation under the runtime's
                # deadline-margin policy signal: after each plane, the
                # budget controller projects the next plane's cost (EWMA,
                # persistent across steps) against the remaining margin
                # and stops issuing planes the step BEFORE a predicted
                # miss — the partial sum (a valid Definition-1
                # resolution) is released as-is.
                warm_key = (hidden.shape, str(hidden.dtype))
                if warm_key not in self._warm_plane_shapes:
                    # compile every plane fn off the clock: a first call's
                    # cost is XLA compilation, not plane compute — timed,
                    # it would poison the persistent EWMA and suppress
                    # higher resolutions for many subsequent steps.  Keyed
                    # by operand shape/dtype because jit caching is.
                    warm = None
                    for fn in self._plane_fns:
                        warm = fn(hidden) if warm is None else fn(hidden,
                                                                  warm)
                    jax.block_until_ready(warm)
                    self._warm_plane_shapes.add(warm_key)
                if budget is None:
                    budget = PlaneBudgetController(deadline_ms)
                budget.begin_step()
                acc = None
                release = 0
                for l in range(self.m):
                    tp = time.perf_counter()
                    acc = (self._plane_fns[l](hidden) if acc is None
                           else self._plane_fns[l](hidden, acc))
                    jax.block_until_ready(acc)
                    budget.observe_plane(time.perf_counter() - tp)
                    release = l + 1
                    if release < self.m and not budget.should_continue():
                        break
                logits = acc * self.lm_head.scale
            else:
                release = (self.m if layer_budget is None
                           else max(1, min(layer_budget, self.m)))
                series = self._head_series(hidden)     # (m, B, V)
                logits = series[release - 1]
            stats.steps += 1
            stats.full_resolution += int(release == self.m)
            stats.released_at_layer.append(release)
            tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
            out.append(tok)
        return jnp.concatenate(out, axis=1), stats


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="llama3-8b-smoke")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--layer-budget", type=int, default=None,
                    help="resolutions computable per step (None = all)")
    ap.add_argument("--deadline-ms", type=float, default=None,
                    help="wall-clock budget per decode step; planes are "
                         "accumulated MSB-first until it expires")
    ap.add_argument("--planes", type=int, default=2)
    args = ap.parse_args(argv)

    if args.arch.endswith("-smoke"):
        cfg = registry.get_smoke_config(args.arch[: -len("-smoke")])
    else:
        cfg = registry.get_config(args.arch)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    server = ProgressiveServer(cfg, params, m=args.planes)

    rng = np.random.default_rng(0)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size,
                                      (args.batch, args.prompt_len)),
                         jnp.int32)
    max_len = args.prompt_len + args.gen
    extras = {}
    if cfg.is_encdec:
        extras["audio_embeds"] = jnp.zeros(
            (args.batch, cfg.encoder_seq, cfg.d_model), cfg.cdtype())
    if cfg.num_image_tokens:
        extras["extra_embeds"] = jnp.zeros(
            (args.batch, cfg.num_image_tokens, cfg.d_model), cfg.cdtype())
    _, caches = server.prefill(tokens, max_len, **extras)
    out, stats = server.decode(tokens[:, -1:], caches, args.prompt_len,
                               args.gen, layer_budget=args.layer_budget,
                               deadline_ms=args.deadline_ms)
    print(f"[serve] generated {out.shape} tokens; "
          f"{stats.full_resolution}/{stats.steps} steps at full resolution; "
          f"release layers: {stats.released_at_layer}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
