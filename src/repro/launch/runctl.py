"""``runctl`` — drive the measured runtime engine from the command line.

Runs a coded layered-matmul workload on the real master/worker/fusion
runtime (``repro.runtime``), prints the paper-style per-resolution delay
table, and optionally validates the measurement against the §IV event
simulator and the eq. (4) theory bounds on the same configuration.

Examples::

    # 200 jobs, exp stragglers, 35 ms deadline, verify decodes, JSON out
    PYTHONPATH=src python -m repro.launch.runctl --jobs 200 \
        --complexity 10 --deadline 0.035 --straggler exp \
        --json results/runctl.json

    # same cluster, cross-checked against the simulator
    PYTHONPATH=src python -m repro.launch.runctl --jobs 100 --compare-sim

    # multi-host: start a worker host per machine, then drive them
    PYTHONPATH=src python -m repro.launch.runctl serve-worker --port 7001
    PYTHONPATH=src python -m repro.launch.runctl --jobs 100 \
        --backend socket --hosts hostA:7001,hostB:7001,hostC:7001 \
        --mu 400,650,380

    # traced run: Perfetto-loadable timeline of the whole pipeline,
    # remote worker spans clock-aligned onto the master timebase
    PYTHONPATH=src python -m repro.launch.runctl --jobs 20 \
        --backend socket --local-cluster --trace out.json --timeline

    # serving gateway: open request stream with per-request deadlines
    # and G/G/1 admission over one shared fleet
    PYTHONPATH=src python -m repro.launch.runctl serve-gateway \
        --requests 60 --rate 20 --deadline 0.06 --json gateway.json
"""

from __future__ import annotations

import argparse
import json
import pathlib
import sys

import numpy as np

from repro.core import simulator
from repro.runtime import (BACKEND_NAMES, CODE_FAMILIES, FAULT_POLICIES,
                           FRAME_PROTOS, POLICIES, SHM_MODES,
                           RuntimeConfig, delay_table,
                           format_controller_trace, format_delay_table,
                           format_stage_table, run_jobs)

__all__ = ["main", "build_config", "summarize"]


def _floats(text: str) -> tuple[float, ...]:
    return tuple(float(x) for x in text.split(",") if x)


def _ints(text: str) -> tuple[int, ...]:
    return tuple(int(x) for x in text.split(",") if x)


def _wants_trace(args: argparse.Namespace) -> bool:
    """Any trace-output flag turns structured tracing on for the run."""
    return bool(getattr(args, "trace", None)
                or getattr(args, "trace_jsonl", None)
                or getattr(args, "timeline", False)
                or getattr(args, "metrics_out", None))


def build_config(args: argparse.Namespace,
                 hosts: tuple[str, ...] | None = None) -> RuntimeConfig:
    return RuntimeConfig(
        mu=_floats(args.mu), arrival_rate=args.arrival_rate,
        n1=args.n1, n2=args.n2, omega=args.omega, m=args.planes, d=args.d,
        gamma=args.gamma, complexity=args.complexity,
        deadline=args.deadline, straggler=args.straggler,
        stall_workers=_ints(args.stall_workers),
        stall_seconds=args.stall_seconds,
        shift_at=args.shift_at if args.shift_at is not None else 0.0,
        burst_period=args.burst_period, burst_len=args.burst_len,
        adapt=args.adapt, omega_min=args.omega_min,
        omega_max=args.omega_max, backend=args.backend,
        use_jax_devices=args.jax_devices,
        hosts=(hosts if hosts is not None
               else tuple(h for h in args.hosts.split(",") if h)),
        compress=args.compress, shm=args.shm,
        frame_proto=args.frame_proto,
        code_family=args.code_family, levels=args.levels,
        trace=_wants_trace(args), seed=args.seed,
        fault_policy=args.fault_policy,
        heartbeat_interval=args.heartbeat_interval,
        heartbeat_timeout=args.heartbeat_timeout,
        reconnect_attempts=args.reconnect_attempts,
        reconnect_backoff=args.reconnect_backoff,
        reconnect_backoff_cap=args.reconnect_backoff_cap)


def summarize(cfg: RuntimeConfig, result) -> dict:
    """JSON-serializable run summary (the ``--json`` artifact)."""
    rows = delay_table(result)
    out = {
        "config": {
            "mu": list(cfg.mu), "arrival_rate": cfg.arrival_rate,
            "n1": cfg.n1, "n2": cfg.n2, "omega": cfg.omega, "m": cfg.m,
            "d": cfg.d, "gamma": cfg.gamma, "complexity": cfg.complexity,
            "deadline": cfg.deadline, "straggler": cfg.straggler,
            "stall_workers": list(cfg.stall_workers), "seed": cfg.seed,
            "backend": cfg.backend, "code_family": cfg.code_family,
            "levels": cfg.levels,
        },
        "backend": result.backend,
        "num_jobs": int(result.num_jobs),
        "kappa": [int(x) for x in result.kappa],
        "delay_per_resolution": rows,
        "terminated_jobs": int(result.terminated.sum()),
        "release_histogram": [int(x) for x in result.release_histogram()],
        "worker_utilization": [round(float(u), 4)
                               for u in result.utilization],
        "stale_results": int(result.stale_results),
        "tasks_done": int(result.tasks_done),
        "tasks_purged": int(result.tasks_purged),
        "fault_policy": result.fault_policy,
        "workers_lost": int(result.workers_lost),
        "degraded_jobs": (int(result.degraded.sum())
                          if result.degraded is not None else 0),
        "fault_log": result.fault_log or [],
        "clock_sync": result.clock_sync,
        "wall_elapsed": float(result.wall_elapsed),
        "stage_seconds": {k: float(v)
                          for k, v in (result.stage_seconds or {}).items()},
        "stage_rounds": int(result.stage_rounds),
        "controller": result.controller,
        "omega_trace": result.omega_trace,
        "transport_stats": result.transport_stats,
    }
    if result.verify_errors is not None:
        finite = result.verify_errors[np.isfinite(result.verify_errors)]
        out["max_verify_rel_error"] = (float(finite.max())
                                       if finite.size else None)
    return out


def main(argv=None) -> int:
    argv = list(sys.argv[1:] if argv is None else argv)
    if argv and argv[0] == "serve-worker":
        # the remote half of the socket backend: run one worker host
        # (kept out of the flag namespace below — it is a different
        # program sharing the runctl entrypoint)
        from repro.launch import worker_host
        return worker_host.main(argv[1:])
    if argv and argv[0] == "serve-gateway":
        # the serving front-end: open request stream, per-request
        # deadlines, G/G/1 admission — see repro.launch.serve_gateway
        from repro.launch import serve_gateway
        return serve_gateway.main(argv[1:])
    ap = argparse.ArgumentParser(
        prog="runctl", description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--jobs", type=int, default=50)
    ap.add_argument("--mu", default="385.95,650.92,373.40,415.75,373.98",
                    help="comma list of worker service rates")
    ap.add_argument("--arrival-rate", type=float, default=12.0,
                    help="Poisson job arrivals per second")
    ap.add_argument("--n1", type=int, default=2)
    ap.add_argument("--n2", type=int, default=2)
    ap.add_argument("--omega", type=float, default=1.5)
    ap.add_argument("--planes", "-m", type=int, default=2, dest="planes",
                    help="digit chunks m (L = 2m-1 resolutions)")
    ap.add_argument("--d", type=int, default=8, help="digit width, bits")
    ap.add_argument("--gamma", type=float, default=1.0)
    ap.add_argument("--complexity", type=float, default=10.0,
                    help="per-task complexity: exp straggler delay scale is "
                         "complexity / (m^2 mu_p) seconds")
    ap.add_argument("--deadline", type=float, default=None,
                    help="seconds from service start (None = no deadline)")
    ap.add_argument("--straggler",
                    choices=("none", "exp", "stall", "shift", "burst"),
                    default="exp")
    ap.add_argument("--stall-workers", default="",
                    help="comma list of worker ids that go dark "
                         "(stall/shift/burst modes)")
    ap.add_argument("--stall-seconds", type=float, default=30.0)
    ap.add_argument("--shift-at", type=float, default=None,
                    help="shift mode: seconds until stall-workers go dark "
                         "(required with --straggler shift; 0 would just "
                         "be 'stall' with extra steps)")
    ap.add_argument("--burst-period", type=float, default=1.0,
                    help="burst mode: seconds between outage starts")
    ap.add_argument("--burst-len", type=float, default=0.2,
                    help="burst mode: outage seconds per period")
    ap.add_argument("--adapt", choices=tuple(sorted(POLICIES)),
                    default="fixed",
                    help="online omega policy (fixed = the paper's static "
                         "redundancy)")
    ap.add_argument("--omega-min", type=float, default=1.0)
    ap.add_argument("--omega-max", type=float, default=3.0)
    ap.add_argument("--backend", choices=BACKEND_NAMES, default="thread",
                    help="worker transport: thread (in-process pool), "
                         "process (multiprocessing workers, GIL-free), "
                         "jax (one thread worker per local JAX device), or "
                         "socket (remote worker hosts over TCP — see "
                         "'runctl serve-worker')")
    ap.add_argument("--jax-devices", action="store_true",
                    help="legacy alias for --backend jax")
    ap.add_argument("--hosts", default="",
                    help="socket backend: comma list of host:port worker "
                         "hosts, one per --mu entry (each running "
                         "'runctl serve-worker')")
    ap.add_argument("--compress", choices=("auto", "none", "zlib", "lz4"),
                    default="auto",
                    help="socket backend frame compression (auto = "
                         "compress big payloads with the best available "
                         "codec)")
    ap.add_argument("--shm", choices=SHM_MODES, default="auto",
                    help="process backend: shared-memory block arenas "
                         "(zero-copy dispatch/results over descriptors; "
                         "auto = on when available, falling back to "
                         "pickled pipes; on = required, raise if arenas "
                         "cannot be created)")
    ap.add_argument("--frame-proto", type=int, choices=FRAME_PROTOS,
                    default=0, dest="frame_proto",
                    help="socket backend frame protocol: 0 = negotiate "
                         "the newest both sides speak (LRF2 when "
                         "possible), 1 = force LRF1 (one pickle per "
                         "frame, mixed-version escape hatch), 2 = "
                         "require LRF2 (pickle-free ndarray frames)")
    ap.add_argument("--code-family", choices=CODE_FAMILIES,
                    default="polynomial", dest="code_family",
                    help="coded-task family: polynomial = one coded round "
                         "per mini-job (the paper's scheme), hierarchical "
                         "= grouped level rounds with per-level MDS rates "
                         "and sub-task-granular dispatch/fusion (straggler "
                         "work on deeper levels is salvaged, not purged)")
    ap.add_argument("--levels", type=int, default=1,
                    help="hierarchical group size: consecutive MSB-first "
                         "rounds dispatched as one group (>= 2 with "
                         "--code-family hierarchical; must stay 1 for "
                         "polynomial)")
    ap.add_argument("--fault-policy", choices=FAULT_POLICIES,
                    default="fail-fast",
                    help="worker-loss handling: fail-fast raises on any "
                         "dead worker; degrade quarantines it, "
                         "re-dispatches its in-flight slice to survivors, "
                         "and releases at a degraded resolution only when "
                         "the fleet falls below k (docs/fault-tolerance.md)")
    ap.add_argument("--heartbeat-interval", type=float, default=1.0,
                    help="socket backend: seconds between liveness pings")
    ap.add_argument("--heartbeat-timeout", type=float, default=15.0,
                    help="socket backend: seconds of silence before a "
                         "worker host is declared dead")
    ap.add_argument("--reconnect-attempts", type=int, default=2,
                    help="socket backend: re-dials before a dropped "
                         "connection is declared dead")
    ap.add_argument("--reconnect-backoff", type=float, default=0.05,
                    help="socket backend: base re-dial backoff in seconds "
                         "(doubles per attempt, jittered)")
    ap.add_argument("--reconnect-backoff-cap", type=float, default=2.0,
                    help="socket backend: ceiling of the exponential "
                         "re-dial backoff, seconds")
    ap.add_argument("--K", type=int, default=64)
    ap.add_argument("--M", type=int, default=8)
    ap.add_argument("--N", type=int, default=8)
    ap.add_argument("--no-verify", action="store_true",
                    help="skip decode-vs-oracle verification")
    ap.add_argument("--profile", action="store_true",
                    help="print the per-stage master pipeline breakdown "
                         "(prep/encode/dispatch/wait/decode/publish/"
                         "control) and the omega controller trace")
    ap.add_argument("--compare-sim", action="store_true",
                    help="also run the §IV simulator + eq.(4) bounds on the "
                         "same configuration")
    ap.add_argument("--sim-jobs", type=int, default=4000)
    ap.add_argument("--json", default=None, help="write summary JSON here")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="record a structured trace and write it here as "
                         "Chrome trace-event JSON (load in Perfetto / "
                         "chrome://tracing); remote worker spans are "
                         "clock-aligned onto the master timebase")
    ap.add_argument("--trace-jsonl", default=None, metavar="PATH",
                    help="also write the raw trace as one JSON event per "
                         "line (for ad-hoc analysis)")
    ap.add_argument("--timeline", action="store_true",
                    help="print an ASCII Gantt of the traced run (implies "
                         "tracing, like --trace)")
    ap.add_argument("--metrics-out", default=None, metavar="PATH",
                    help="dump a Prometheus text-format snapshot of the "
                         "run's counters here (implies tracing)")
    ap.add_argument("--local-cluster", action="store_true",
                    help="socket backend: spawn one worker-host process per "
                         "--mu entry on localhost instead of naming "
                         "--hosts (smoke runs and demos)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    if args.straggler == "shift" and args.shift_at is None:
        ap.error("--straggler shift needs an explicit --shift-at (seconds "
                 "until the outage); an implicit 0 would start the run "
                 "already degraded, never showing the regime change")
    if args.straggler in ("shift", "burst") and not _ints(args.stall_workers):
        ap.error(f"--straggler {args.straggler} needs --stall-workers: "
                 f"with none listed, the regime change is a no-op")
    if args.jax_devices and args.backend not in ("thread", "jax"):
        ap.error(f"--jax-devices is a legacy alias for --backend jax and "
                 f"conflicts with --backend {args.backend}")
    if args.local_cluster and args.backend != "socket":
        ap.error("--local-cluster spawns socket worker hosts; it needs "
                 f"--backend socket, not {args.backend!r}")
    if args.local_cluster and args.hosts:
        ap.error("--local-cluster and --hosts are exclusive: the former "
                 "spawns its own localhost worker hosts")
    if args.backend == "socket" and not (args.hosts or args.local_cluster):
        ap.error("--backend socket needs --hosts host:port,... (one per "
                 "--mu entry; start each with 'runctl serve-worker') or "
                 "--local-cluster")

    cluster = None
    if args.local_cluster:
        from repro.runtime.transport.socket_host import LocalCluster
        cluster = LocalCluster(len(_floats(args.mu)))
    try:
        cfg = build_config(
            args, hosts=cluster.hosts if cluster is not None else None)
        return _run(args, cfg)
    finally:
        if cluster is not None:
            cluster.close()


def _run(args: argparse.Namespace, cfg: RuntimeConfig) -> int:
    print(f"[runctl] {cfg.num_workers} workers ({cfg.backend} backend), "
          f"k={cfg.k} of T={cfg.total_tasks} coded tasks/round, "
          f"{cfg.num_rounds} rounds, L={cfg.num_layers} resolutions, "
          f"straggler={cfg.straggler}, deadline={cfg.deadline}, "
          f"adapt={cfg.adapt}, fault={cfg.fault_policy}")
    result, _ = run_jobs(cfg, args.jobs, K=args.K, M=args.M, N=args.N,
                         verify=not args.no_verify)
    print(f"[runctl] kappa (eq.1 split): {result.kappa.tolist()}  "
          f"utilization: {np.round(result.utilization, 3).tolist()}")
    print(f"[runctl] terminated {int(result.terminated.sum())}/"
          f"{result.num_jobs} jobs; release histogram "
          f"(none, res0..): {result.release_histogram().tolist()}; "
          f"stale results: {result.stale_results}")
    if result.workers_lost or (result.degraded is not None
                               and result.degraded.any()):
        kinds = sorted({e["kind"] for e in (result.fault_log or ())})
        print(f"[runctl] faults ({result.fault_policy} policy): "
              f"{result.workers_lost} worker(s) lost, "
              f"{int(result.degraded.sum())} job(s) released degraded; "
              f"fault log: {len(result.fault_log or ())} events "
              f"({', '.join(kinds)})")
    if result.verify_errors is not None:
        finite = result.verify_errors[np.isfinite(result.verify_errors)]
        if finite.size:
            print(f"[runctl] decode verified vs exact layered oracle: "
                  f"max rel error {finite.max():.2e}")
    print("[runctl] measured delay per resolution (seconds):")
    print(format_delay_table(delay_table(result)))
    if args.profile:
        print("[runctl] per-stage master pipeline breakdown:")
        print(format_stage_table(result))
        print("[runctl] omega controller trace:")
        print(format_controller_trace(result))

    if cfg.trace:
        from repro.runtime import trace_export
        n_ev = len(result.trace_events or ())
        drop = (f" ({result.trace_dropped} dropped)"
                if result.trace_dropped else "")
        print(f"[runctl] trace: {n_ev} events{drop}")
        if result.clock_sync:
            worst = max(result.clock_sync,
                        key=lambda s: s["rtt_s"] or float("inf"))
            print(f"[runctl] clock sync: worst link {worst['host']} "
                  f"offset {worst['offset_s'] * 1e6:+.1f} us, "
                  f"rtt {(worst['rtt_s'] or 0.0) * 1e6:.1f} us "
                  f"(alignment error <= rtt/2)")
        if args.trace:
            path = pathlib.Path(args.trace)
            path.parent.mkdir(parents=True, exist_ok=True)
            trace_export.write_chrome_trace(path, result)
            print(f"[runctl] wrote {path} (load in Perfetto or "
                  f"chrome://tracing)")
        if args.trace_jsonl:
            path = pathlib.Path(args.trace_jsonl)
            path.parent.mkdir(parents=True, exist_ok=True)
            trace_export.write_jsonl(path, result)
            print(f"[runctl] wrote {path}")
        if args.metrics_out:
            path = pathlib.Path(args.metrics_out)
            path.parent.mkdir(parents=True, exist_ok=True)
            path.write_text(trace_export.prometheus_snapshot(result))
            print(f"[runctl] wrote {path}")
        if args.timeline:
            print(trace_export.format_timeline(result))

    if args.compare_sim:
        scfg = cfg.to_system_config()
        sim = simulator.simulate(scfg, args.sim_jobs, layered=True,
                                 deadline=cfg.deadline, seed=cfg.seed)
        bounds = simulator.theory_bounds(scfg, sim.service_moments(),
                                         layered=True)
        print(f"[runctl] simulator ({args.sim_jobs} jobs, same config):")
        print(format_delay_table(delay_table(sim, bounds=bounds)))

    if args.json:
        path = pathlib.Path(args.json)
        path.parent.mkdir(parents=True, exist_ok=True)
        path.write_text(json.dumps(summarize(cfg, result), indent=2))
        print(f"[runctl] wrote {path}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
