"""``runctl serve-gateway`` — drive the multi-tenant serving gateway.

Generates an open stream of layered requests (Poisson or bursty
inter-arrivals), submits each to a
:class:`~repro.runtime.gateway.ServingGateway` with its own deadline,
and reports the per-request outcomes: admitted / down-resolved /
rejected at the G/G/1 admission bound, release resolution and slack at
the deadline fire, per-resolution deadline-success rates.  The
:class:`~repro.runtime.gateway.GatewayStats` artifact lands in
``--json``.

Examples::

    # 60 Poisson requests at 20 req/s, 60 ms deadlines, G/G/1 admission
    PYTHONPATH=src python -m repro.launch.runctl serve-gateway \
        --requests 60 --rate 20 --deadline 0.06 --json gateway.json

    # bursty open traffic over a localhost socket fleet
    PYTHONPATH=src python -m repro.launch.runctl serve-gateway \
        --backend socket --local-cluster --traffic bursty --requests 40
"""

from __future__ import annotations

import argparse
import json
import pathlib
import sys
import time

import numpy as np

from repro.runtime import RuntimeConfig, ServingGateway
from repro.runtime.tasks import BACKEND_NAMES

__all__ = ["main", "request_gaps"]


def request_gaps(kind: str, rate: float, n: int,
                 rng: np.random.Generator, *, burst_factor: float = 4.0,
                 period: float = 0.5) -> np.ndarray:
    """Inter-arrival gaps (seconds) for an open request stream.

    ``poisson`` is exponential at ``rate``.  ``bursty`` is on/off
    modulated Poisson at the *same mean rate*: each ``period`` opens with
    an on-window of ``period / burst_factor`` seconds during which
    arrivals come ``burst_factor`` times faster, then goes silent — the
    arrival SCV the G/G/1 bound charges for.
    """
    if kind == "poisson":
        return rng.exponential(1.0 / rate, size=n)
    if kind != "bursty":
        raise ValueError(f"unknown traffic kind {kind!r}")
    on = period / burst_factor
    gaps = np.empty(n)
    t = 0.0
    for i in range(n):
        g = rng.exponential(1.0 / (burst_factor * rate))
        pos = (t + g) % period
        if pos > on:               # landed in the off-window: hold the
            g += period - pos      # arrival until the next burst opens
        gaps[i] = g
        t += g
    return gaps


def _print_summary(stats) -> None:
    js = stats.to_json()
    print(f"[serve-gateway] submitted {stats.submitted}: "
          f"{stats.admitted} admitted ({stats.down_resolved} down-resolved), "
          f"{stats.rejected} rejected; released {stats.released}, "
          f"{stats.degraded} degraded")
    hist = ", ".join(f"res{k}:{v}" if k != "-1" else f"none:{v}"
                     for k, v in js["release_histogram"].items())
    print(f"[serve-gateway] release histogram: {hist or '(empty)'}")
    succ = "  ".join(f"res{l}={js['deadline_success'][str(l)]:.3f}"
                     for l in range(stats.num_layers))
    print(f"[serve-gateway] deadline success by resolution: {succ}")
    if js["mean_slack"] is not None:
        print(f"[serve-gateway] mean slack {js['mean_slack'] * 1e3:+.1f} ms"
              + (f", mean queue wait {js['mean_queue_wait'] * 1e3:.1f} ms"
                 if js["mean_queue_wait"] is not None else ""))


def main(argv=None) -> int:
    argv = list(sys.argv[1:] if argv is None else argv)
    ap = argparse.ArgumentParser(
        prog="runctl serve-gateway", description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--requests", type=int, default=40)
    ap.add_argument("--rate", type=float, default=20.0,
                    help="mean request arrivals per second")
    ap.add_argument("--traffic", choices=("poisson", "bursty"),
                    default="poisson")
    ap.add_argument("--burst-factor", type=float, default=4.0,
                    help="bursty traffic: on-window speed-up (mean rate "
                         "is unchanged)")
    ap.add_argument("--burst-period", type=float, default=0.5,
                    help="bursty traffic: seconds per on/off cycle")
    ap.add_argument("--deadline", type=float, default=0.06,
                    help="per-request deadline, seconds from submit")
    ap.add_argument("--resolution", type=int, default=None,
                    help="requested resolution (default: final, 2m-2)")
    ap.add_argument("--min-resolution", type=int, default=0,
                    help="lowest acceptable resolution (-1 = best-effort)")
    ap.add_argument("--admission", choices=("gg1", "none"), default="gg1",
                    help="admission policy: gg1 prices each request "
                         "against the G/G/1 bound; none admits all")
    ap.add_argument("--safety", type=float, default=1.3,
                    help="admission estimate inflation factor")
    ap.add_argument("--mu", default="385.95,650.92,373.40,415.75,373.98",
                    help="comma list of worker service rates")
    ap.add_argument("--n1", type=int, default=2)
    ap.add_argument("--n2", type=int, default=2)
    ap.add_argument("--omega", type=float, default=1.5)
    ap.add_argument("--planes", "-m", type=int, default=2, dest="planes",
                    help="digit chunks m (L = 2m-1 resolutions)")
    ap.add_argument("--d", type=int, default=8, help="digit width, bits")
    ap.add_argument("--complexity", type=float, default=10.0)
    ap.add_argument("--straggler",
                    choices=("none", "exp", "stall", "shift", "burst"),
                    default="exp")
    ap.add_argument("--backend", choices=BACKEND_NAMES, default="thread")
    ap.add_argument("--hosts", default="",
                    help="socket backend: comma list of host:port worker "
                         "hosts (one per --mu entry)")
    ap.add_argument("--local-cluster", action="store_true",
                    help="socket backend: spawn localhost worker hosts")
    ap.add_argument("--fault-policy", choices=("fail-fast", "degrade"),
                    default="fail-fast")
    ap.add_argument("--K", type=int, default=64)
    ap.add_argument("--M", type=int, default=8)
    ap.add_argument("--N", type=int, default=8)
    ap.add_argument("--verify", action="store_true",
                    help="decode-verify every job against the layered "
                         "oracle (slow; test runs)")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="record per-request telemetry spans and write a "
                         "Chrome trace-event JSON here")
    ap.add_argument("--json", default=None, help="write GatewayStats here")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    if args.local_cluster and args.backend != "socket":
        ap.error("--local-cluster needs --backend socket")
    if args.backend == "socket" and not (args.hosts or args.local_cluster):
        ap.error("--backend socket needs --hosts or --local-cluster")

    mu = tuple(float(x) for x in args.mu.split(",") if x)
    cluster = None
    if args.local_cluster:
        from repro.runtime.transport.socket_host import LocalCluster
        cluster = LocalCluster(len(mu))
    try:
        cfg = RuntimeConfig(
            mu=mu, arrival_rate=args.rate, n1=args.n1, n2=args.n2,
            omega=args.omega, m=args.planes, d=args.d,
            complexity=args.complexity, straggler=args.straggler,
            backend=args.backend,
            hosts=(cluster.hosts if cluster is not None
                   else tuple(h for h in args.hosts.split(",") if h)),
            fault_policy=args.fault_policy, trace=bool(args.trace),
            seed=args.seed)
        return _serve(args, cfg)
    finally:
        if cluster is not None:
            cluster.close()


def _serve(args: argparse.Namespace, cfg: RuntimeConfig) -> int:
    print(f"[serve-gateway] {cfg.num_workers} workers ({cfg.backend} "
          f"backend), L={cfg.num_layers} resolutions, "
          f"{args.requests} requests at ~{args.rate:g}/s ({args.traffic}), "
          f"deadline {args.deadline * 1e3:.1f} ms, "
          f"admission={args.admission}")
    rng = np.random.default_rng(cfg.seed)
    gaps = request_gaps(args.traffic, args.rate, args.requests, rng,
                        burst_factor=args.burst_factor,
                        period=args.burst_period)
    lim = 1 << (cfg.m * cfg.d - 2)
    gw = ServingGateway(cfg, admission=args.admission, safety=args.safety,
                        verify=args.verify).start()
    tickets = []
    try:
        for i in range(args.requests):
            time.sleep(float(gaps[i]))
            a = rng.integers(-lim, lim, size=(args.K, args.M),
                             dtype=np.int64)
            b = rng.integers(-lim, lim, size=(args.K, args.N),
                             dtype=np.int64)
            tickets.append(gw.submit(a, b, deadline=args.deadline,
                                     resolution=args.resolution,
                                     min_resolution=args.min_resolution))
    finally:
        stats = gw.stop()
    stats.reconcile()
    _print_summary(stats)
    result = gw.result
    if args.trace and result is not None and result.trace_events:
        from repro.runtime import trace_export
        path = pathlib.Path(args.trace)
        path.parent.mkdir(parents=True, exist_ok=True)
        trace_export.write_chrome_trace(path, result)
        print(f"[serve-gateway] wrote {path} "
              f"({len(result.trace_events)} events)")
    if args.json:
        out = {
            "config": {
                "mu": list(cfg.mu), "rate": args.rate,
                "traffic": args.traffic, "deadline": args.deadline,
                "admission": args.admission, "safety": args.safety,
                "m": cfg.m, "d": cfg.d, "omega": cfg.omega,
                "straggler": cfg.straggler, "backend": cfg.backend,
                "requests": args.requests, "seed": cfg.seed,
            },
            "gateway": stats.to_json(),
            "fleet": (None if result is None else {
                "backend": result.backend,
                "tasks_done": int(result.tasks_done),
                "tasks_purged": int(result.tasks_purged),
                "stale_results": int(result.stale_results),
                "workers_lost": int(result.workers_lost),
                "wall_elapsed": float(result.wall_elapsed),
            }),
        }
        path = pathlib.Path(args.json)
        path.parent.mkdir(parents=True, exist_ok=True)
        path.write_text(json.dumps(out, indent=2))
        print(f"[serve-gateway] wrote {path}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
