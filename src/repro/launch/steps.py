"""Step functions (train / prefill / serve) and their sharded lowering.

``build_cell`` is the single entry point the dry-run, the roofline pass and
the drivers share: given (arch config, shape cell, mesh) it constructs the
step function, the ShapeDtypeStruct inputs, and the in/out shardings, and
returns a ``jax.jit``-wrapped callable ready to ``.lower()`` (dry-run) or
execute (CPU-scale smoke/train).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs import registry
from repro.configs.base import SHAPES, ModelConfig, ShapeConfig, TrainConfig
from repro.launch import sharding as sh
from repro.launch.axes import mesh_context
from repro.models import transformer as T
from repro.optim.optimizers import make_optimizer

__all__ = ["make_train_step", "make_prefill_step", "make_serve_step",
           "build_cell", "Cell"]

_QCHUNK = 1024  # query-block size for chunked attention (prefill & train)


def _cast_for_compute(params, cfg: ModelConfig):
    """fp32 master -> compute-dtype copy BEFORE use, so FSDP all-gathers
    move bf16 (half the wire bytes).  Only weight matrices are cast
    (ndim >= 3 under scanned groups, plus embed/lm_head); fp32-sensitive
    1-2D leaves (A_log, dt_bias, Lambda, norm scales) stay fp32."""
    cd = cfg.cdtype()

    def leaf(path, x):
        name = str(getattr(path[-1], "key", getattr(path[-1], "idx", "")))
        if x.dtype == jnp.float32 and (x.ndim >= 3
                                       or name in ("embed", "lm_head")):
            return x.astype(cd)
        return x

    return jax.tree_util.tree_map_with_path(leaf, params)


def make_train_step(cfg: ModelConfig, tcfg: TrainConfig):
    optimizer = make_optimizer(tcfg)

    def train_step(params, opt_state, batch):
        def loss_fn(p):
            if tcfg.bf16_weight_gather and not tcfg.bf16_grads:
                p = _cast_for_compute(p, cfg)
            loss, metrics = T.forward_train(
                p, batch["tokens"], batch["targets"], cfg,
                extra_embeds=batch.get("extra_embeds"),
                audio_embeds=batch.get("audio_embeds"),
                q_chunk=_QCHUNK)
            return loss, metrics

        if tcfg.bf16_grads:
            # differentiate wrt the bf16 copy: the data-parallel gradient
            # reduce-scatter then moves bf16; cast up AFTER the reduction
            params_c = _cast_for_compute(params, cfg)
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params_c)
            grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        else:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params)
        new_params, new_opt = optimizer.update(grads, opt_state, params)
        metrics = dict(metrics)
        metrics["grad_norm"] = new_opt.get("gnorm", jnp.float32(0))
        return new_params, new_opt, metrics

    return train_step, optimizer


def make_prefill_step(cfg: ModelConfig, max_len: int):
    def prefill_step(params, batch):
        return T.prefill(params, batch["tokens"], cfg, max_len=max_len,
                         extra_embeds=batch.get("extra_embeds"),
                         audio_embeds=batch.get("audio_embeds"),
                         q_chunk=_QCHUNK)

    return prefill_step


def make_serve_step(cfg: ModelConfig):
    def serve_step(params, batch):
        logits, caches = T.decode_step(params, batch["token"],
                                       batch["caches"], batch["pos"], cfg)
        next_token = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return logits, next_token, caches

    return serve_step


# ---------------------------------------------------------------------------
# Cell construction (arch x shape x mesh)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Cell:
    """Everything needed to lower or run one (arch x shape x mesh) cell."""

    cfg: ModelConfig
    shape: ShapeConfig
    mesh: Mesh
    kind: str                    # train | prefill | decode
    fn: Any                      # jitted step function
    arg_shapes: tuple            # ShapeDtypeStructs, positional
    in_shardings: tuple
    out_shardings: Any

    def lower(self):
        return self.fn.lower(*self.arg_shapes)


def _abstract_params(cfg: ModelConfig):
    return jax.eval_shape(
        functools.partial(T.init_params, cfg=cfg), jax.random.PRNGKey(0))


def build_cell(cfg: ModelConfig, shape: ShapeConfig | str, mesh: Mesh,
               tcfg: Optional[TrainConfig] = None,
               profile: str = "tp_fsdp") -> Cell:
    if isinstance(shape, str):
        shape = SHAPES[shape]
    kind, batch_shapes = registry.input_specs(cfg, shape)
    params_shapes = _abstract_params(cfg)
    pspecs = sh.param_specs(params_shapes, mesh, profile)

    if kind == "train":
        tcfg = tcfg or TrainConfig()
        step, optimizer = make_train_step(cfg, tcfg)
        opt_shapes = jax.eval_shape(optimizer.init, params_shapes)
        ospecs = sh.opt_state_specs(opt_shapes, pspecs, mesh)
        bspecs = sh.batch_specs(batch_shapes, mesh, profile)
        arg_shapes = (params_shapes, opt_shapes, batch_shapes)
        in_specs = (pspecs, ospecs, bspecs)
        # output opt_state grows scalar entries (gnorm/lr) -> respecify
        out_shapes = jax.eval_shape(step, *arg_shapes)
        out_specs = (pspecs, sh.opt_state_specs(out_shapes[1], pspecs, mesh),
                     jax.tree.map(lambda _: P(), out_shapes[2]))
        donate = (0, 1)
    elif kind == "prefill":
        step = make_prefill_step(cfg, max_len=shape.seq_len)
        bspecs = sh.batch_specs(batch_shapes, mesh)
        arg_shapes = (params_shapes, batch_shapes)
        in_specs = (pspecs, bspecs)
        out_shapes = jax.eval_shape(step, *arg_shapes)
        baxes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
        logits_spec = P(baxes, "model")
        out_specs = (logits_spec, sh.cache_specs_tree(out_shapes[1], mesh))
        donate = ()
    elif kind == "decode":
        step = make_serve_step(cfg)
        cspecs = sh.cache_specs_tree(batch_shapes["caches"], mesh)
        baxes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
        # batch=1 (long_500k) cannot shard over the batch axes: fix_spec
        # drops the axis (single-sequence decode is TP-only, by design)
        tok_spec = sh.fix_spec(batch_shapes["token"].shape, (baxes, None),
                               mesh, relocate=False)
        bspecs = {"token": tok_spec, "pos": P(), "caches": cspecs}
        arg_shapes = (params_shapes, batch_shapes)
        in_specs = (pspecs, bspecs)
        out_shapes = jax.eval_shape(step, *arg_shapes)
        B, V = out_shapes[0].shape
        logits_spec = sh.fix_spec((B, V), (baxes, "model"), mesh,
                                  relocate=False)
        next_spec = sh.fix_spec((B,), (baxes,), mesh, relocate=False)
        out_specs = (logits_spec, next_spec, cspecs)
        donate = ()   # caches donated at run time; lowering keeps both
    else:
        raise ValueError(kind)

    named_in = sh.named(mesh, in_specs)
    named_out = sh.named(mesh, out_specs)

    def step_in_mesh(*args, _step=step):
        # activation sharding constraints (launch/axes.py) need the ambient
        # mesh DURING tracing, which happens lazily inside jit
        with mesh_context(mesh, profile):
            return _step(*args)

    jitted = jax.jit(step_in_mesh, in_shardings=named_in,
                     out_shardings=named_out, donate_argnums=donate)
    return Cell(cfg=cfg, shape=shape, mesh=mesh, kind=kind, fn=jitted,
                arg_shapes=arg_shapes, in_shardings=named_in,
                out_shardings=named_out)
