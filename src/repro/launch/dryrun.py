import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")
# ^ MUST precede any jax import: jax locks the device count on first init.
# This is the ONLY entry point that forces 512 host devices -- tests and
# benchmarks see the real single CPU device.

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this proves, without hardware:
  * the sharding config is coherent (GSPMD partitions every op),
  * the program fits (memory_analysis),
  * and it emits the roofline terms (cost_analysis + HLO collective scan)
    consumed by EXPERIMENTS.md §Roofline.

Usage:
  python -m repro.launch.dryrun --arch llama3-8b --shape train_4k --mesh both
  python -m repro.launch.dryrun --arch all --out results/dryrun
"""

import argparse
import json
import sys
import time
import traceback


def run_cell(arch: str, shape_name: str, mesh_name: str, out_dir: str,
             verbose: bool = True, profile: str = "tp_fsdp",
             bf16_gather: bool = False, remat: str = "",
             tag: str = "", moe_group: int = 0,
             bf16_grads: bool = False, kv_dtype: str = "") -> dict:
    import dataclasses as _dc

    import jax

    from repro.configs import registry
    from repro.configs.base import SHAPES, TrainConfig
    from repro.launch import roofline as rl
    from repro.launch import steps as steps_lib
    from repro.launch.mesh import make_production_mesh
    from repro.models import transformer as T

    cfg = registry.get_config(arch)
    if remat:
        cfg = _dc.replace(cfg, remat_policy=remat)
    if moe_group and cfg.moe is not None:
        cfg = _dc.replace(cfg, moe=_dc.replace(cfg.moe,
                                               dispatch_group=moe_group))
    if kv_dtype:
        cfg = _dc.replace(cfg, kv_cache_dtype=kv_dtype)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=(mesh_name == "multi"))
    chips = mesh.devices.size

    tcfg = TrainConfig(optimizer="adafactor") \
        if arch == "llama4-maverick-400b-a17b" else TrainConfig()
    if bf16_gather:
        tcfg = _dc.replace(tcfg, bf16_weight_gather=True)
    if bf16_grads:
        tcfg = _dc.replace(tcfg, bf16_weight_gather=True, bf16_grads=True)

    rec: dict = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                 "chips": chips, "status": "ok", "profile": profile,
                 "bf16_gather": bf16_gather, "remat": remat or
                 cfg.remat_policy, "tag": tag}
    t0 = time.time()
    try:
        cell = steps_lib.build_cell(cfg, shape, mesh, tcfg, profile=profile)
        t1 = time.time()
        lowered = cell.lower()
        t2 = time.time()
        compiled = lowered.compile()
        t3 = time.time()

        import gzip

        import numpy as np

        from repro.launch import sharding as sh
        if out_dir:  # cache the HLO so roofline iteration needs no recompile
            hlo_dir = os.path.join(out_dir, "..", "hlo")
            os.makedirs(hlo_dir, exist_ok=True)
            suffix = f"__{tag}" if tag else ""
        with gzip.open(os.path.join(
                    hlo_dir,
                    f"{arch}__{shape_name}__{mesh_name}{suffix}.txt.gz"),
                    "wt") as f:
                f.write(compiled.as_text())

        params_shapes = cell.arg_shapes[0]
        n_params = sum(int(np.prod(x.shape))
                       for x in jax.tree.leaves(params_shapes))
        n_active = T.active_params(cfg, n_params)
        if cell.kind == "train":
            tokens = shape.global_batch * shape.seq_len
            model_flops = 6.0 * n_active * tokens
        elif cell.kind == "prefill":
            tokens = shape.global_batch * shape.seq_len
            model_flops = 2.0 * n_active * tokens
        else:  # decode: one token per sequence
            model_flops = 2.0 * n_active * shape.global_batch

        report = rl.roofline_terms(
            compiled, arch=arch, shape=shape_name, mesh_name=mesh_name,
            kind=cell.kind, chips=chips, model_flops=model_flops)
        # analytic memory term (see roofline.analytic_memory_bytes): the
        # unfused-CPU instruction bytes stay in the record as an upper bound
        opt_bytes = 0.0
        if cell.kind == "train":
            pspecs = sh.param_specs(params_shapes, mesh)
            opt_shapes = cell.arg_shapes[1]
            ospecs = sh.opt_state_specs(opt_shapes, pspecs, mesh)
            opt_bytes = sh.spec_bytes_per_device(opt_shapes, ospecs, mesh)
        cache_bytes = 0.0
        if cell.kind == "decode":
            cspecs = sh.cache_specs_tree(cell.arg_shapes[1]["caches"], mesh)
            cache_bytes = sh.spec_bytes_per_device(
                cell.arg_shapes[1]["caches"], cspecs, mesh)
        rec["hlo_bytes_upper_bound"] = report.bytes_per_device
        report.bytes_per_device = rl.analytic_memory_bytes(
            cfg, shape, cell.kind, mesh, n_params,
            opt_state_bytes_per_dev=opt_bytes,
            cache_bytes_per_dev=cache_bytes)
        rec["opt_state_bytes_per_dev"] = opt_bytes
        rec["cache_bytes_per_dev"] = cache_bytes
        rec.update(report.as_dict())
        rec["n_params"] = n_params
        rec["n_active_params"] = n_active
        try:
            ma = compiled.memory_analysis()
            rec["memory_analysis"] = {
                k: int(getattr(ma, k)) for k in
                ("temp_size_in_bytes", "argument_size_in_bytes",
                 "output_size_in_bytes", "alias_size_in_bytes",
                 "generated_code_size_in_bytes")
                if hasattr(ma, k)}
        except Exception as e:  # CPU backend may not implement it
            rec["memory_analysis"] = {"error": str(e)}
        rec["timings_s"] = {"build": t1 - t0, "lower": t2 - t1,
                            "compile": t3 - t2}
        if verbose:
            print(compiled.memory_analysis())
            print({k: v for k, v in (compiled.cost_analysis() or {}).items()
                   if k in ("flops", "bytes accessed")})
    except Exception as e:
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
    rec["wall_s"] = time.time() - t0

    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        suffix = f"__{tag}" if tag else ""
        fn = os.path.join(out_dir,
                          f"{arch}__{shape_name}__{mesh_name}{suffix}.json")
        with open(fn, "w") as f:
            json.dump(rec, f, indent=1)
    return rec


def main(argv=None) -> int:
    from repro.configs import registry

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=["single", "multi",
                                                       "both"])
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--quiet", action="store_true")
    ap.add_argument("--profile", default="tp_fsdp",
                    choices=["tp_fsdp", "fsdp", "serve"])
    ap.add_argument("--bf16-gather", action="store_true")
    ap.add_argument("--remat", default="")
    ap.add_argument("--tag", default="",
                    help="suffix for output files (perf variants)")
    ap.add_argument("--moe-group", type=int, default=0)
    ap.add_argument("--bf16-grads", action="store_true")
    ap.add_argument("--kv-dtype", default="")
    args = ap.parse_args(argv)

    archs = list(registry.ARCH_IDS) if args.arch == "all" else [args.arch]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    failures = 0
    for arch in archs:
        cfg = registry.get_config(arch)
        shapes = (registry.shape_cells(cfg) if args.shape == "all"
                  else [args.shape])
        for shape_name in shapes:
            for mesh_name in meshes:
                rec = run_cell(arch, shape_name, mesh_name, args.out,
                               verbose=not args.quiet,
                               profile=args.profile,
                               bf16_gather=args.bf16_gather,
                               remat=args.remat, tag=args.tag,
                               moe_group=args.moe_group,
                               bf16_grads=args.bf16_grads,
                               kv_dtype=args.kv_dtype)
                tag = (f"{arch} x {shape_name} x {mesh_name}"
                       f" [{rec.get('kind', '?')}]")
                if rec["status"] == "ok":
                    t = {k: round(v, 4) for k, v in
                         {"compute_s": rec["compute_s"],
                          "memory_s": rec["memory_s"],
                          "collective_s": rec["collective_s"]}.items()}
                    print(f"OK   {tag}: bound={rec['bound']} {t} "
                          f"wall={rec['wall_s']:.1f}s", flush=True)
                else:
                    failures += 1
                    print(f"FAIL {tag}: {rec['error']}", flush=True)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
