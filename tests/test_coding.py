"""Polynomial code (MDS) properties: any-k decoding, exactness, erasures."""

import itertools

import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import hypothesis, st

from repro.core import coding
from repro.core.layered_matmul import GradientCoder, LayeredCodedMatmul


class TestModMatmul:
    def test_matches_python_ints(self, rng):
        p = coding.MERSENNE_P
        x = rng.integers(0, p, size=(8, 5), dtype=np.uint64)
        y = rng.integers(0, p, size=(8, 4), dtype=np.uint64)
        got = coding.modmatmul(x, y, p)
        want = np.zeros((5, 4), dtype=object)
        for i in range(5):
            for j in range(4):
                want[i, j] = sum(int(x[k, i]) * int(y[k, j])
                                 for k in range(8)) % p
        assert (got.astype(object) == want).all()


class TestPolynomialCodeFloat:
    @pytest.mark.parametrize("n1,n2,omega", [(2, 2, 1.0), (2, 2, 1.5),
                                             (4, 2, 1.25), (3, 3, 1.2)])
    def test_any_k_subset_decodes(self, rng, n1, n2, omega):
        code = coding.PolynomialCode(n1=n1, n2=n2, omega=omega, mode="float")
        A = jnp.asarray(rng.normal(size=(32, 4 * n1)), jnp.float32)
        B = jnp.asarray(rng.normal(size=(32, 4 * n2)), jnp.float32)
        X, Y = code.encode(A, B)
        assert X.shape[0] == code.num_tasks
        tasks = np.asarray(code.compute_all_tasks(X, Y))
        exact = np.asarray(A.T @ B)
        # try several k-subsets including adversarial (first k, last k)
        ids_list = [list(range(code.k)),
                    list(range(code.num_tasks - code.k, code.num_tasks)),
                    list(rng.choice(code.num_tasks, code.k, replace=False))]
        for ids in ids_list:
            dec = np.asarray(code.decode(ids, tasks[np.asarray(ids)]))
            np.testing.assert_allclose(dec, exact, rtol=2e-2, atol=5e-3)

    def test_insufficient_results_raise(self, rng):
        code = coding.PolynomialCode(n1=2, n2=2, omega=1.5)
        with pytest.raises(ValueError):
            code.decode([0, 1], np.zeros((2, 4, 4)))

    def test_redundancy_ratio(self):
        code = coding.PolynomialCode(n1=2, n2=2, omega=1.06)
        assert code.num_tasks == 5  # ceil(4 * 1.06)
        with pytest.raises(ValueError):
            coding.PolynomialCode(n1=2, n2=2, omega=0.9)


class TestPolynomialCodeGFp:
    def test_exact_decode_all_subsets(self, rng):
        code = coding.PolynomialCode(n1=2, n2=1, omega=1.5, mode="gfp")
        A = rng.integers(0, 255, size=(16, 6)).astype(np.uint64)
        B = rng.integers(0, 255, size=(16, 3)).astype(np.uint64)
        X, Y = code.encode(A, B)
        tasks = code.compute_all_tasks(X, Y)
        exact = A.astype(np.int64).T @ B.astype(np.int64)
        for ids in itertools.combinations(range(code.num_tasks), code.k):
            dec = code.decode(list(ids), tasks[np.asarray(ids)])
            np.testing.assert_array_equal(np.asarray(dec), exact)


class TestMDSCode:
    @hypothesis.given(st.integers(2, 6), st.integers(0, 3))
    @hypothesis.settings(max_examples=25, deadline=None)
    def test_erasure_recovery(self, k, extra):
        n = k + extra
        rng = np.random.default_rng(k * 10 + extra)
        mds = coding.MDSCode(k=k, n=n)
        shards = jnp.asarray(rng.normal(size=(k, 6)), jnp.float32)
        cw = mds.encode(shards)
        ids = rng.choice(n, size=k, replace=False)
        rec = mds.decode(ids, cw[jnp.asarray(ids)])
        np.testing.assert_allclose(np.asarray(rec), np.asarray(shards),
                                   rtol=1e-3, atol=1e-4)


class TestLayeredCodedPipeline:
    def test_float_pipeline_resolution_improves(self, rng):
        pipe = LayeredCodedMatmul(m=2, d=8, n1=2, n2=2, omega=1.5)
        A = jnp.asarray(rng.normal(size=(64, 8)), jnp.float32)
        B = jnp.asarray(rng.normal(size=(64, 8)), jnp.float32)
        res, _ = pipe.run(A, B, seed=3)
        exact = np.asarray(A.T @ B)
        errs = [np.abs(res[l] - exact).max() for l in range(res.shape[0])]
        assert errs[0] > errs[-1]
        assert errs[-1] < 1e-2 * np.abs(exact).max()

    def test_gfp_pipeline_bit_exact_under_erasure(self, rng):
        pipe = LayeredCodedMatmul(m=2, d=8, n1=2, n2=1, omega=1.5,
                                  mode="gfp")
        A = jnp.asarray(rng.integers(-5000, 5000, size=(32, 4)), jnp.int32)
        B = jnp.asarray(rng.integers(-5000, 5000, size=(32, 4)), jnp.int32)
        res, _ = pipe.run(A, B, erasures=[1])
        exact = np.asarray(A, np.int64).T @ np.asarray(B, np.int64)
        np.testing.assert_array_equal(res[-1].astype(np.int64), exact)

    def test_too_many_erasures_rejected(self, rng):
        pipe = LayeredCodedMatmul(m=2, d=8, n1=2, n2=2, omega=1.0)
        A = jnp.zeros((8, 4), jnp.float32)
        with pytest.raises(ValueError):
            pipe.run(A, A, erasures=[0])


class TestGradientCoder:
    @pytest.mark.parametrize("n,k", [(2, 1), (4, 3), (4, 2), (8, 6)])
    def test_all_survivor_sets_decode(self, rng, n, k):
        gc = GradientCoder(n=n, k=k)
        shards = [jnp.asarray(rng.normal(size=(5,)), jnp.float32)
                  for _ in range(n)]
        cws = [gc.encode_local(p, [shards[s] for s in gc.assignment[p]])
               for p in range(n)]
        total = np.asarray(sum(shards))
        for surv in itertools.combinations(range(n), k):
            dec = gc.decode(list(surv), [cws[s] for s in surv])
            np.testing.assert_allclose(np.asarray(dec), total, rtol=1e-4,
                                       atol=1e-4)

    def test_below_threshold_raises(self):
        gc = GradientCoder(n=4, k=3)
        with pytest.raises(ValueError):
            gc.decode_weights([0, 1])

    def test_replication_factor(self):
        assert GradientCoder(n=8, k=6).replication == 3
        assert GradientCoder(n=4, k=4).replication == 1
