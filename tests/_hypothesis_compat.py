"""Optional-hypothesis shim: property tests run when hypothesis is
installed (see requirements-dev.txt) and are skipped — not collection
errors — when it is not.

Usage in test modules::

    from _hypothesis_compat import hypothesis, st

``hypothesis.given(...)`` / ``hypothesis.settings(...)`` behave normally
when the real package is present; otherwise they decorate the test with
``pytest.mark.skip`` so the rest of the module still collects and runs.
"""

import pytest

try:
    import hypothesis
    import hypothesis.strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised only without hypothesis
    HAVE_HYPOTHESIS = False

    class _StubStrategies:
        """Any ``st.<name>(...)`` call returns an inert placeholder."""

        def __getattr__(self, name):
            return lambda *args, **kwargs: None

    class _StubHypothesis:
        @staticmethod
        def given(*args, **kwargs):
            return pytest.mark.skip(reason="hypothesis not installed")

        @staticmethod
        def settings(*args, **kwargs):
            return lambda fn: fn

        @staticmethod
        def assume(condition):
            return True

        @staticmethod
        def example(*args, **kwargs):
            return lambda fn: fn

    hypothesis = _StubHypothesis()
    st = _StubStrategies()

__all__ = ["hypothesis", "st", "HAVE_HYPOTHESIS"]
