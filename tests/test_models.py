"""Model-zoo behaviour: decode==forward consistency, family coverage,
gradients, and the building blocks (SSD scan, RG-LRU, MoE dispatch)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import (AttentionConfig, ModelConfig, MoEConfig,
                                RGLRUConfig, SSMConfig)
from repro.models import moe as moe_lib
from repro.models import rglru as rglru_lib
from repro.models import ssm as ssm_lib
from repro.models import transformer as T
from repro.models.layers import attention
from repro.models.loss import cross_entropy


def mk(family, **kw):
    base = dict(name="t", family=family, num_layers=4, d_model=64, d_ff=128,
                vocab_size=256, compute_dtype="float32",
                attention=AttentionConfig(num_heads=4, num_kv_heads=2,
                                          head_dim=16))
    base.update(kw)
    return ModelConfig(**base)


CONFIGS = {
    "dense": mk("dense"),
    "dense_gelu": mk("dense", activation="gelu", norm="layernorm",
                     tie_embeddings=True),
    "moe": mk("moe", moe=MoEConfig(num_experts=4, top_k=2, d_ff_expert=64,
                                   d_ff_shared=96, capacity_factor=4.0)),
    "moe_interleaved": mk("moe", moe=MoEConfig(num_experts=4, top_k=1,
                                               d_ff_expert=64,
                                               capacity_factor=4.0,
                                               interleave_step=2)),
    "ssm": mk("ssm", attention=None,
              ssm=SSMConfig(d_state=16, head_dim=16, chunk_size=8)),
    "hybrid": mk("hybrid", num_layers=5,
                 rglru=RGLRUConfig(d_rnn=64, window=8),
                 attention=AttentionConfig(num_heads=4, num_kv_heads=1,
                                           head_dim=16)),
    "audio": mk("audio", encoder_layers=2, encoder_seq=12, norm="layernorm",
                activation="gelu", tie_embeddings=True),
    "vlm": mk("vlm", num_image_tokens=8),
}


def _extras(cfg, B, rng):
    kw = {}
    if cfg.is_encdec:
        kw["audio_embeds"] = jnp.asarray(
            rng.normal(size=(B, cfg.encoder_seq, cfg.d_model)), jnp.float32)
    if cfg.num_image_tokens:
        kw["extra_embeds"] = jnp.asarray(
            rng.normal(size=(B, cfg.num_image_tokens, cfg.d_model)),
            jnp.float32)
    return kw


class TestForward:
    @pytest.mark.parametrize("name", list(CONFIGS))
    def test_train_loss_finite_and_shape(self, rng, name):
        cfg = CONFIGS[name]
        params = T.init_params(jax.random.PRNGKey(0), cfg)
        toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 32)),
                           jnp.int32)
        loss, metrics = T.forward_train(params, toks, toks, cfg,
                                        **_extras(cfg, 2, rng))
        assert np.isfinite(float(loss))
        logits, _ = T.forward(params, toks, cfg, **_extras(cfg, 2, rng))
        assert logits.shape == (2, 32, cfg.vocab_size)
        assert np.isfinite(np.asarray(logits, np.float32)).all()

    @pytest.mark.parametrize("name", list(CONFIGS))
    def test_decode_matches_forward(self, rng, name):
        """The invariant that catches cache/RoPE/mask bugs."""
        cfg = CONFIGS[name]
        params = T.init_params(jax.random.PRNGKey(0), cfg)
        B, S = 2, 16
        toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S + 1)),
                           jnp.int32)
        kw = _extras(cfg, B, rng)
        full_logits, _ = T.forward(params, toks, cfg, **kw)
        want = np.asarray(full_logits[:, -1, :], np.float32)
        _, cache = T.prefill(params, toks[:, :S], cfg, max_len=S + 8, **kw)
        got, _ = T.decode_step(params, toks[:, S:S + 1], cache,
                               jnp.int32(S), cfg)
        got = np.asarray(got, np.float32)
        err = np.abs(got - want).max() / (np.abs(want).max() + 1e-9)
        assert err < 2e-3, f"{name}: {err}"

    def test_multi_token_decode_consistency(self, rng):
        """Decoding 3 tokens sequentially == forward over the longer seq."""
        cfg = CONFIGS["dense"]
        params = T.init_params(jax.random.PRNGKey(0), cfg)
        B, S, extra = 2, 12, 3
        toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S + extra)),
                           jnp.int32)
        _, cache = T.prefill(params, toks[:, :S], cfg, max_len=S + extra + 2)
        for i in range(extra):
            got, cache = T.decode_step(params, toks[:, S + i: S + i + 1],
                                       cache, jnp.int32(S + i), cfg)
        full, _ = T.forward(params, toks, cfg)
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(full[:, -1, :], np.float32),
                                   rtol=1e-3, atol=1e-4)

    @pytest.mark.parametrize("name", ["dense", "moe", "ssm", "hybrid"])
    def test_gradients_flow_to_all_params(self, rng, name):
        cfg = CONFIGS[name]
        params = T.init_params(jax.random.PRNGKey(0), cfg)
        toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 16)),
                           jnp.int32)

        def loss_fn(p):
            return T.forward_train(p, toks, toks, cfg)[0]

        grads = jax.grad(loss_fn)(params)
        zero_leaves = [np.allclose(np.asarray(g), 0.0)
                       for g in jax.tree.leaves(grads)]
        # at most the (rarely hit) biases may be zero-gradient
        assert np.mean(zero_leaves) < 0.3, f"{name}: too many dead params"
        for g in jax.tree.leaves(grads):
            assert np.isfinite(np.asarray(g, np.float32)).all()


class TestBlockGroups:
    def test_recurrentgemma_pattern(self):
        cfg = CONFIGS["hybrid"]  # 5 layers, pattern (R, R, A)
        groups = T.block_groups(cfg)
        assert groups == [(("rglru", "rglru", "local_attn"), 1),
                          (("rglru", "rglru"), 1)]

    def test_interleaved_moe(self):
        groups = T.block_groups(CONFIGS["moe_interleaved"])
        assert groups == [(("dense", "moe"), 2)]

    def test_layer_counts_match(self):
        for name, cfg in CONFIGS.items():
            groups = T.block_groups(cfg)
            n = sum(len(unit) * reps for unit, reps in groups)
            assert n == cfg.num_layers, name


class TestSSD:
    def test_ssd_scan_matches_sequential_recurrence(self, rng):
        """Chunked SSD == naive per-step state recurrence."""
        B, S, H, P, N, chunk = 1, 24, 2, 4, 8, 8
        x = jnp.asarray(rng.normal(size=(B, S, H, P)), jnp.float32)
        dt = jnp.asarray(rng.uniform(0.01, 0.2, size=(B, S, H)), jnp.float32)
        A = -jnp.asarray(rng.uniform(0.5, 2.0, size=(H,)), jnp.float32)
        Bm = jnp.asarray(rng.normal(size=(B, S, 1, N)), jnp.float32)
        Cm = jnp.asarray(rng.normal(size=(B, S, 1, N)), jnp.float32)
        y, final = ssm_lib.ssd_scan(x, dt, A, Bm, Cm, chunk)

        state = np.zeros((B, H, P, N))
        ys = np.zeros((B, S, H, P))
        for t in range(S):
            dA = np.exp(np.asarray(dt[:, t]) * np.asarray(A)[None])
            state = state * dA[:, :, None, None] + np.einsum(
                "bh,bn,bhp->bhpn", np.asarray(dt[:, t]),
                np.asarray(Bm[:, t, 0]), np.asarray(x[:, t]))
            ys[:, t] = np.einsum("bhpn,bn->bhp", state,
                                 np.asarray(Cm[:, t, 0]))
        np.testing.assert_allclose(np.asarray(y), ys, rtol=2e-4, atol=2e-4)
        np.testing.assert_allclose(np.asarray(final), state, rtol=2e-4,
                                   atol=2e-4)

    def test_ssm_block_state_continuity(self, rng):
        """block(x[,:S]) state + decode steps == block(x) outputs."""
        cfg = SSMConfig(d_state=8, head_dim=8, chunk_size=4)
        D = 32
        params = ssm_lib.init_ssm_params(jax.random.PRNGKey(1), D, cfg,
                                         jnp.float32)
        x = jnp.asarray(rng.normal(size=(1, 13, D)), jnp.float32)
        y_full, _ = ssm_lib.ssm_block(params, x, D, cfg)
        y_pre, cache = ssm_lib.ssm_block(params, x[:, :10], D, cfg)
        outs = [y_pre]
        for t in range(10, 13):
            y_t, cache = ssm_lib.ssm_decode_step(params, x[:, t:t + 1],
                                                 cache, D, cfg)
            outs.append(y_t)
        y_steps = jnp.concatenate(outs, axis=1)
        np.testing.assert_allclose(np.asarray(y_steps), np.asarray(y_full),
                                   rtol=2e-3, atol=2e-3)


class TestRGLRU:
    def test_scan_matches_stepwise(self, rng):
        cfg = RGLRUConfig(d_rnn=16, window=4)
        D = 16
        params = rglru_lib.init_rglru_params(jax.random.PRNGKey(2), D, cfg,
                                             jnp.float32)
        x = jnp.asarray(rng.normal(size=(2, 9, D)), jnp.float32)
        y_full, _ = rglru_lib.rglru_block(params, x, cfg)
        cache = rglru_lib.init_rglru_cache(2, D, cfg, jnp.float32)
        outs = []
        for t in range(9):
            y_t, cache = rglru_lib.rglru_decode_step(params, x[:, t:t + 1],
                                                     cache, cfg)
            outs.append(y_t)
        np.testing.assert_allclose(np.asarray(jnp.concatenate(outs, 1)),
                                   np.asarray(y_full), rtol=2e-3, atol=2e-3)

    def test_stability(self, rng):
        """|a| < 1 by construction -> bounded state on long input."""
        cfg = RGLRUConfig(d_rnn=8)
        params = rglru_lib.init_rglru_params(jax.random.PRNGKey(3), 8, cfg,
                                             jnp.float32)
        x = jnp.asarray(10.0 * rng.normal(size=(1, 512, 8)), jnp.float32)
        y, cache = rglru_lib.rglru_block(params, x, cfg)
        assert np.isfinite(np.asarray(y)).all()
        assert np.isfinite(np.asarray(cache["h"])).all()


class TestMoE:
    def test_high_capacity_is_lossless_routing(self, rng):
        """With capacity >= tokens, MoE == explicit per-token expert mix."""
        cfg = MoEConfig(num_experts=4, top_k=2, d_ff_expert=16,
                        capacity_factor=8.0)
        D = 8
        params = moe_lib.init_moe_params(jax.random.PRNGKey(4), D, cfg,
                                         jnp.float32)
        x = jnp.asarray(rng.normal(size=(6, D)), jnp.float32)
        got = np.asarray(moe_lib.moe_block(params, x, cfg))

        logits = np.asarray(x @ params["router"])
        gates, idx = moe_lib.router_topk(jnp.asarray(logits), cfg.top_k)
        gates, idx = np.asarray(gates), np.asarray(idx)
        want = np.zeros_like(got)
        for t in range(x.shape[0]):
            for kk in range(cfg.top_k):
                e = idx[t, kk]
                h = (np.asarray(jax.nn.silu(x[t] @ params["we_gate"][e]))
                     * np.asarray(x[t] @ params["we_up"][e]))
                want[t] += gates[t, kk] * (h @ np.asarray(
                    params["we_down"][e]))
        np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)

    def test_capacity_drops_dont_nan(self, rng):
        cfg = MoEConfig(num_experts=2, top_k=1, d_ff_expert=8,
                        capacity_factor=0.25)  # aggressive dropping
        params = moe_lib.init_moe_params(jax.random.PRNGKey(5), 8, cfg,
                                         jnp.float32)
        x = jnp.asarray(rng.normal(size=(16, 8)), jnp.float32)
        y = moe_lib.moe_block(params, x, cfg)
        assert np.isfinite(np.asarray(y)).all()

    def test_grouping_invariance(self, rng):
        """Same result whatever the dispatch group size (no drops)."""
        cfg = MoEConfig(num_experts=4, top_k=1, d_ff_expert=8,
                        capacity_factor=8.0)
        params = moe_lib.init_moe_params(jax.random.PRNGKey(6), 8, cfg,
                                         jnp.float32)
        x = jnp.asarray(rng.normal(size=(16, 8)), jnp.float32)
        y1 = np.asarray(moe_lib.moe_block(params, x, cfg, group_size=16))
        y2 = np.asarray(moe_lib.moe_block(params, x, cfg, group_size=4))
        np.testing.assert_allclose(y1, y2, rtol=2e-4, atol=2e-4)


class TestLoss:
    def test_cross_entropy_matches_manual(self, rng):
        logits = jnp.asarray(rng.normal(size=(2, 5, 11)), jnp.float32)
        targets = jnp.asarray(rng.integers(0, 11, (2, 5)), jnp.int32)
        loss, _ = cross_entropy(logits, targets)
        p = jax.nn.log_softmax(logits, -1)
        want = -np.mean([p[b, s, targets[b, s]] for b in range(2)
                         for s in range(5)])
        assert float(loss) == pytest.approx(float(want), rel=1e-5)

    def test_mask(self, rng):
        logits = jnp.asarray(rng.normal(size=(1, 4, 7)), jnp.float32)
        targets = jnp.zeros((1, 4), jnp.int32)
        mask = jnp.asarray([[1, 1, 0, 0]], jnp.float32)
        loss_m, m = cross_entropy(logits, targets, mask)
        loss_2, _ = cross_entropy(logits[:, :2], targets[:, :2])
        assert float(loss_m) == pytest.approx(float(loss_2), rel=1e-5)
        assert float(m["ntokens"]) == 2.0
