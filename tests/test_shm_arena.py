"""Shared-memory arena suite: allocator properties, zero-copy wire path.

Three layers, matching the safety argument in
``repro/runtime/transport/shm.py``:

* **Allocator properties** (hypothesis): arbitrary alloc/free
  interleavings never hand out overlapping live slots, never exceed
  capacity, and the watermark releases exactly the slots it claims to.
* **Arena mechanics**: write/view round-trips are bit-identical, the
  attach side sees the owner's bytes, ring exhaustion degrades to the
  pickle fallback (None, never an exception), and the transport keeps
  completing rounds through it.
* **Crash hygiene**: a worker SIGKILLed mid-round leaks no ``/dev/shm``
  segment once the master shuts down, and the zero-copy path's decoded
  results are bit-identical to the pickled pipe path's.
"""

import os
import signal
import time

import numpy as np
import pytest

from _hypothesis_compat import HAVE_HYPOTHESIS, hypothesis, st
from repro.runtime.master import run_jobs
from repro.runtime.tasks import ArenaSlice, RoundContext, RuntimeConfig
from repro.runtime.transport import shm
from repro.runtime.transport.process import ProcessTransport, _ArenaPair
from repro.runtime.worker import _host_compute

MU1 = (300.0,)
MU3 = (300.0, 300.0, 300.0)


def _collect(sink_list, count, timeout=30.0):
    """Wait until ``sink_list`` holds ``count`` results (drain thread)."""
    deadline = time.monotonic() + timeout
    while len(sink_list) < count:
        if time.monotonic() > deadline:
            raise AssertionError(
                f"only {len(sink_list)}/{count} results within {timeout}s")
        time.sleep(0.01)


# -- RingAllocator ------------------------------------------------------------

class TestRingAllocator:
    def test_alloc_is_aligned_and_fifo(self):
        ring = shm.RingAllocator(1024)
        offs = [ring.alloc(10, seq) for seq in range(3)]
        assert offs == [0, 64, 128]
        assert all(off % shm.ALIGNMENT == 0 for off in offs)
        assert ring.used_bytes == 192

    def test_free_through_vs_below(self):
        ring = shm.RingAllocator(1024)
        for seq in (0, 0, 1, 2):
            assert ring.alloc(64, seq) is not None
        assert ring.free_below(1) == 2      # both seq-0 slots, nothing else
        assert {s for s, _, _ in ring.live_spans()} == {1, 2}
        assert ring.free_through(2) == 2    # inclusive: everything left
        assert len(ring) == 0
        assert ring.alloc(64, 3) == 0       # empty ring restarts at base

    def test_full_ring_returns_none(self):
        ring = shm.RingAllocator(128)
        assert ring.alloc(64, 0) == 0
        assert ring.alloc(64, 1) == 64
        assert ring.alloc(1, 2) is None     # head == first: full
        assert ring.alloc(4096, 3) is None  # larger than capacity

    def test_wraparound_reuses_freed_base(self):
        ring = shm.RingAllocator(256)
        assert ring.alloc(64, 0) == 0
        assert ring.alloc(64, 1) == 64
        assert ring.alloc(64, 2) == 128
        ring.free_through(1)                # base [0, 128) free again
        assert ring.alloc(100, 3) == 0      # tail gap too small: wraps
        # wrapped state: head caught up with the oldest slot -> full
        assert ring.alloc(64, 4) is None


if HAVE_HYPOTHESIS:
    ring_settings = hypothesis.settings(max_examples=80, deadline=None)

    class TestRingAllocatorProperties:
        @ring_settings
        @hypothesis.given(
            capacity=st.integers(1, 32).map(lambda c: c * shm.ALIGNMENT),
            ops=st.lists(
                st.one_of(
                    st.tuples(st.just("alloc"), st.integers(1, 512)),
                    st.tuples(st.just("free"), st.integers(0, 40)),
                ),
                max_size=120),
        )
        def test_live_slots_never_overlap(self, capacity, ops):
            """Any alloc/free interleaving: live slots are disjoint, in
            bounds, aligned, and the byte ledger matches exactly."""
            ring = shm.RingAllocator(capacity)
            seq = 0
            for op, arg in ops:
                if op == "alloc":
                    off = ring.alloc(arg, seq)
                    seq += 1
                    if off is not None:
                        assert off % shm.ALIGNMENT == 0
                else:
                    ring.free_through(arg)
                spans = ring.live_spans()
                claimed = sorted((off, off + size)
                                 for _, off, size in spans)
                for (lo1, hi1), (lo2, hi2) in zip(claimed, claimed[1:]):
                    assert hi1 <= lo2, \
                        f"overlap: [{lo1},{hi1}) vs [{lo2},{hi2})"
                assert all(0 <= lo and hi <= ring.capacity
                           for lo, hi in claimed)
                assert ring.used_bytes == sum(s for _, _, s in spans)
                assert ring.used_bytes <= ring.capacity

        @ring_settings
        @hypothesis.given(
            seqs=st.lists(st.integers(0, 10), min_size=1, max_size=40)
                .map(sorted),
            watermark=st.integers(0, 10),
        )
        def test_watermark_releases_exactly_the_purged_seqs(
                self, seqs, watermark):
            ring = shm.RingAllocator(1 << 20)
            placed = [s for s in seqs if ring.alloc(64, s) is not None]
            freed = ring.free_through(watermark)
            assert freed == sum(1 for s in placed if s <= watermark)
            assert [s for s, _, _ in ring.live_spans()] \
                == [s for s in placed if s > watermark]
            ring.free_below(watermark + 2)
            assert [s for s, _, _ in ring.live_spans()] \
                == [s for s in placed if s > watermark + 1]


# -- BlockArena ---------------------------------------------------------------

class TestBlockArena:
    def test_write_view_roundtrip_bit_identical(self):
        arena = shm.BlockArena(1 << 16)
        try:
            arr = np.random.default_rng(0).normal(size=(13, 7))
            desc = arena.write(arr, seq=0)
            assert desc is not None
            got = arena.view(desc)
            assert got.dtype == arr.dtype and got.shape == arr.shape
            assert np.array_equal(
                got.view(np.uint64), arr.view(np.uint64))  # bitwise
        finally:
            arena.close()
            arena.unlink()

    def test_attach_side_sees_owner_bytes(self):
        owner = shm.BlockArena(1 << 16)
        try:
            other = shm.BlockArena(0, name=owner.name, create=False)
            arr = np.arange(24, dtype=np.int64).reshape(4, 6)
            desc = owner.write(arr, seq=0)
            assert np.array_equal(other.view(desc), arr)
            other.close()                   # attach close never unlinks
            again = shm.BlockArena(0, name=owner.name, create=False)
            assert np.array_equal(again.view(desc), arr)
            again.close()
        finally:
            owner.close()
            owner.unlink()

    def test_exhaustion_returns_none(self):
        arena = shm.BlockArena(shm.ALIGNMENT * 4)
        try:
            big = np.zeros(shm.ALIGNMENT)   # 8 * ALIGNMENT bytes
            assert arena.write(big, seq=0) is None
            small = np.zeros(8)
            assert arena.write(small, seq=0) is not None
        finally:
            arena.close()
            arena.unlink()

    def test_compute_into_slot_bit_identical(self):
        """The out= kernel writing a result slot produces the same bits
        as the plain pipe-path compute."""
        arena = shm.BlockArena(1 << 16)
        try:
            rng = np.random.default_rng(1)
            x = rng.normal(size=(32, 5))
            y = rng.normal(size=(32, 6))
            desc, view = arena.alloc_view((5, 6), np.result_type(x, y), 0)
            out = _host_compute(x, y, out=view)
            assert out is view
            plain = _host_compute(x, y)
            assert np.array_equal(view.view(np.uint64),
                                  plain.view(np.uint64))
        finally:
            arena.close()
            arena.unlink()

    def test_unlink_segments_sweeps_prefix(self):
        prefix = shm.arena_prefix()
        arena = shm.BlockArena(1 << 12, name=f"{prefix}d0")
        arena.close()
        assert shm.leaked_segments(prefix) == [f"{prefix}d0"]
        assert shm.unlink_segments(prefix) == [f"{prefix}d0"]
        assert shm.leaked_segments(prefix) == []


# -- transport-level zero-copy path -------------------------------------------

def _round_buffers(rng, T=6, K=32, a=5, b=4):
    X = rng.normal(size=(T, K, a))
    Y = rng.normal(size=(T, K, b))
    return X, Y


class TestProcessArenaPath:
    def test_ring_full_falls_back_to_pickled_pipe(self):
        """A dispatch slice too big for its arena takes the WireBatch
        path for that slice — degraded, counted, still correct."""
        cfg = RuntimeConfig(backend="process", mu=MU1, straggler="none",
                            shm="on")
        results = []
        pool = ProcessTransport(cfg, lambda r: results.append(r) or True)
        try:
            pool.start()
            # pre-install a deliberately tiny dispatch arena so the
            # first real slice cannot fit and must fall back
            dispatch = shm.BlockArena(
                shm.ALIGNMENT * 2, name=f"{pool._arena_prefix}d0")
            result = shm.BlockArena(1 << 20,
                                    name=f"{pool._arena_prefix}r0")
            pool._conns[0][0].send(("arena", dispatch.name, result.name))
            pool._arenas[0] = _ArenaPair(dispatch, result)
            X, Y = _round_buffers(np.random.default_rng(0))
            ctx = RoundContext(0, 0)
            pool.submit_round(ctx, X, Y, np.array([X.shape[0]]))
            _collect(results, X.shape[0])
            stats = pool.wire_stats
            assert stats["arena_fallbacks"] == 1
            assert stats["pickle_rounds"] == 1
            assert stats["arena_rounds"] == 0
            for r in results:     # results still land (via result arena)
                i = r.task_id
                assert np.allclose(r.value, X[i].T @ Y[i])
        finally:
            pool.shutdown()
        assert shm.leaked_segments(pool._arena_prefix) == []

    def test_sigkill_mid_round_leaks_no_segments(self):
        """SIGKILL a worker while it holds in-flight arena rounds: the
        master's shutdown still unlinks every segment (workers only ever
        attach; the /dev/shm sweep is the backstop)."""
        cfg = RuntimeConfig(backend="process", mu=MU3, straggler="none",
                            shm="on")
        results = []
        pool = ProcessTransport(cfg, lambda r: results.append(r) or True)
        try:
            pool.start()
            X, Y = _round_buffers(np.random.default_rng(1))
            ctx = RoundContext(0, 0)
            kappa = np.array([2, 2, 2])
            # long injected delays keep every task in-flight at the kill
            delays = [np.full(2, 10.0) for _ in MU3]
            pool.submit_round(ctx, X, Y, kappa, delays=delays)
            deadline = time.monotonic() + 10.0
            while len(shm.leaked_segments(pool._arena_prefix)) < 6:
                assert time.monotonic() < deadline, "arenas never appeared"
                time.sleep(0.01)
            os.kill(pool.processes[0].pid, signal.SIGKILL)
            pool.processes[0].join(timeout=10.0)
            assert pool.dead_worker_map() == {
                0: "runtime-proc-worker-0 (exit code -9)"}
        finally:
            pool.shutdown()
        assert shm.leaked_segments(pool._arena_prefix) == []

    def test_decode_bit_identical_to_pipe_path(self):
        """Single-worker runs (deterministic fusion order) decode to the
        exact same bits with the arena on and off."""
        outs = {}
        for mode in ("on", "off"):
            cfg = RuntimeConfig(backend="process", mu=MU1,
                                straggler="none", shm=mode, seed=11)
            result, futures = run_jobs(cfg, num_jobs=2, K=32, M=4, N=4)
            assert (result.transport_stats["shm_active"]
                    == (mode == "on"))
            outs[mode] = [f.resolution(l) for f in futures
                          for l in range(f.num_layers)]
        assert len(outs["on"]) == len(outs["off"])
        for a, b in zip(outs["on"], outs["off"]):
            assert np.array_equal(a.view(np.uint64), b.view(np.uint64))

    def test_shm_off_sends_no_arenas(self):
        cfg = RuntimeConfig(backend="process", mu=MU1, straggler="none",
                            shm="off", seed=5)
        result, _ = run_jobs(cfg, num_jobs=1, K=32, M=4, N=4)
        stats = result.transport_stats
        assert not stats["shm_active"]
        assert stats["arena_rounds"] == 0
        assert stats["pickle_rounds"] > 0

    def test_shm_on_requires_process_backend(self):
        with pytest.raises(ValueError, match="shm"):
            RuntimeConfig(backend="thread", mu=MU1, shm="on")
        with pytest.raises(ValueError, match="shm"):
            RuntimeConfig(backend="process", mu=MU1, shm="bogus")
