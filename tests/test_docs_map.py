"""Docs-consistency gate: every code anchor in docs/ must import.

``docs/paper-map.md`` (and the other docs pages) reference code as
backticked dotted paths — ``repro.module.Symbol`` or
``repro.module.Symbol.attr``.  This test resolves every one of them by
importing the longest module prefix and walking the remaining attributes,
so renaming or deleting a mapped symbol fails CI instead of silently
rotting the paper→code map.
"""

import importlib
import pathlib
import re

import pytest

DOCS = pathlib.Path(__file__).resolve().parent.parent / "docs"
ANCHOR = re.compile(r"`(repro(?:\.[A-Za-z_][A-Za-z0-9_]*)+)`")


def doc_files() -> list[pathlib.Path]:
    return sorted(DOCS.glob("*.md"))


def anchors_in(path: pathlib.Path) -> list[str]:
    return sorted(set(ANCHOR.findall(path.read_text())))


def resolve(dotted: str):
    """Import the longest module prefix, getattr the rest."""
    parts = dotted.split(".")
    last_err = None
    for split in range(len(parts), 0, -1):
        try:
            obj = importlib.import_module(".".join(parts[:split]))
        except ImportError as e:
            last_err = e
            continue
        for attr in parts[split:]:
            if not hasattr(obj, attr):
                raise AttributeError(
                    f"{dotted}: {'.'.join(parts[:split])} has no "
                    f"attribute chain {'.'.join(parts[split:])!r}")
            obj = getattr(obj, attr)
        return obj
    raise ImportError(f"{dotted}: no importable module prefix ({last_err})")


def test_docs_exist_and_carry_anchors():
    files = doc_files()
    names = {p.name for p in files}
    assert {"paper-map.md", "architecture.md", "adaptive-omega.md",
            "observability.md", "fault-tolerance.md",
            "serving-gateway.md", "hierarchical-coding.md"} <= names, names
    assert anchors_in(DOCS / "paper-map.md"), \
        "paper-map.md lost its code anchors"


@pytest.mark.parametrize("doc", doc_files(), ids=lambda p: p.name)
def test_every_doc_anchor_imports(doc):
    bad = []
    for dotted in anchors_in(doc):
        try:
            resolve(dotted)
        except Exception as e:   # noqa: BLE001 - report every rot at once
            bad.append(f"{dotted}: {type(e).__name__}: {e}")
    assert not bad, (
        f"{doc.name} references symbols that no longer resolve:\n  "
        + "\n  ".join(bad))


def test_paper_map_covers_the_load_bearing_surface():
    """The map must keep naming the core artifacts it exists to anchor."""
    text = (DOCS / "paper-map.md").read_text()
    for required in (
            "repro.core.layering.layered_matmul_reference",
            "repro.core.coding.PolynomialCode",
            "repro.core.coding.DecodePlan",
            "repro.core.scheduling.load_split",
            "repro.core.simulator.simulate",
            "repro.runtime.master.Master.run",
            "repro.runtime.adaptive.OmegaController",
            "repro.runtime.telemetry.Tracer",
            "repro.runtime.trace_export.chrome_trace",
            "repro.runtime.faults.FaultSupervisor",
            "repro.runtime.gateway.ServingGateway",
            "repro.runtime.gateway.AdmissionController",
            "repro.runtime.master.Master.serve_queue",
            "repro.runtime.transport.shm.BlockArena",
            "repro.runtime.tasks.ArenaBatchRef",
            "repro.runtime.transport.socket_host.MAGIC2",
            "repro.core.coding.HierarchicalCode",
            "repro.runtime.tasks.WireGroup",
            "repro.runtime.fusion.FusionNode.begin_group",
    ):
        assert required in text, f"paper-map.md no longer maps {required}"
