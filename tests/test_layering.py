"""Unit + property tests for the paper's core layering math (Definition 1)."""

import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import hypothesis, st

from repro.core import layering


class TestBookkeeping:
    @pytest.mark.parametrize("m", [1, 2, 3, 4, 7])
    def test_minijob_count_sums_to_m_squared(self, m):
        # sum_l J(l) = m^2 -- layering adds zero total compute (paper §III)
        assert sum(layering.minijobs_per_layer(m)) == m * m

    @pytest.mark.parametrize("m", [1, 2, 3, 5])
    def test_J_formula(self, m):
        for l in range(layering.num_layers(m)):
            want = min(l + 1, 2 * m - 1 - l)
            assert layering.minijobs_per_layer(m)[l] == want
            assert len(layering.layer_minijobs(m, l)) == want

    @pytest.mark.parametrize("m", [2, 3, 4])
    def test_layers_partition_all_plane_pairs(self, m):
        seen = set()
        for l in range(layering.num_layers(m)):
            for (i, j) in layering.layer_minijobs(m, l):
                assert (2 * m - 2) - l == i + j
                seen.add((i, j))
        assert seen == {(i, j) for i in range(m) for j in range(m)}

    def test_msb_first_order(self):
        order = layering.all_minijobs_msb_first(3)
        sums = [i + j for (_, i, j) in order]
        assert sums == sorted(sums, reverse=True)

    def test_bad_args(self):
        with pytest.raises(ValueError):
            layering.num_layers(0)
        with pytest.raises(ValueError):
            layering.layer_minijobs(2, 5)


class TestDecompose:
    @hypothesis.given(
        st.integers(min_value=1, max_value=4),
        st.integers(min_value=1, max_value=8),
        st.integers(min_value=0, max_value=2**31 - 1),
    )
    @hypothesis.settings(max_examples=100, deadline=None)
    def test_roundtrip_scalar(self, m, d, value):
        # keep value within m*d bits so the decomposition is exhaustive
        value = value % (2 ** min(m * d, 31))
        x = jnp.asarray([[value]], jnp.int32)
        ch = layering.decompose(x, m, d)
        assert int(layering.reconstruct(ch, d)[0, 0]) == value

    @hypothesis.given(st.integers(min_value=-2**15, max_value=2**15 - 1))
    @hypothesis.settings(max_examples=60, deadline=None)
    def test_roundtrip_signed(self, value):
        x = jnp.asarray([[value]], jnp.int32)
        for (m, d) in [(2, 8), (4, 4), (2, 10)]:
            ch = layering.decompose(x, m, d)
            assert int(layering.reconstruct(ch, d)[0, 0]) == value, (m, d)

    def test_roundtrip_array(self, rng):
        x = jnp.asarray(rng.integers(-2**20, 2**20, size=(33, 17)), jnp.int32)
        ch = layering.decompose(x, 3, 8)
        assert ch.shape == (3, 33, 17)
        np.testing.assert_array_equal(np.asarray(layering.reconstruct(ch, 8)),
                                      np.asarray(x))

    def test_lower_chunks_are_digits(self, rng):
        x = jnp.asarray(rng.integers(-2**15, 2**15, size=(8, 8)), jnp.int32)
        ch = np.asarray(layering.decompose(x, 2, 8))
        assert ch[0].min() >= 0 and ch[0].max() < 256

    def test_rejects_floats(self):
        with pytest.raises(TypeError):
            layering.decompose(jnp.zeros((2, 2), jnp.float32), 2, 8)


class TestLayeredMatmul:
    @pytest.mark.parametrize("m,d", [(2, 8), (3, 6), (4, 4)])
    def test_final_resolution_exact(self, rng, m, d):
        hi = 1 << (m * d - 1)
        A = rng.integers(-hi, hi, size=(24, 9))
        B = rng.integers(-hi, hi, size=(24, 7))
        res = layering.layered_matmul_reference(A, B, m=m, d=d)
        assert res.shape == (2 * m - 1, 9, 7)
        np.testing.assert_array_equal(res[-1], A.T @ B)

    def test_resolution_error_decreases(self, rng):
        m, d = 3, 6
        A = rng.integers(0, 1 << (m * d), size=(32, 8))
        B = rng.integers(0, 1 << (m * d), size=(32, 8))
        res = layering.layered_matmul_reference(A, B, m=m, d=d)
        exact = (A.T @ B).astype(np.float64)
        errs = [np.abs(res[l] - exact).max() for l in range(res.shape[0])]
        assert all(e1 >= e2 for e1, e2 in zip(errs, errs[1:])), errs
        assert errs[-1] == 0

    def test_error_bound_holds(self, rng):
        m, d, K = 2, 8, 16
        A = rng.integers(0, 1 << (m * d), size=(K, 6))
        B = rng.integers(0, 1 << (m * d), size=(K, 6))
        res = layering.layered_matmul_reference(A, B, m=m, d=d)
        exact = A.T @ B
        for l in range(2 * m - 1):
            bound = layering.resolution_error_bound(m, d, K, l)
            assert np.abs(res[l] - exact).max() <= bound

    def test_jnp_path_matches_reference(self, rng):
        m, d = 2, 7
        hi = 1 << (m * d - 1)
        A = jnp.asarray(rng.integers(-hi, hi, size=(16, 8)), jnp.int32)
        B = jnp.asarray(rng.integers(-hi, hi, size=(16, 4)), jnp.int32)
        got = np.asarray(layering.layered_matmul_jnp(A, B, m=m, d=d))
        want = layering.layered_matmul_reference(np.asarray(A),
                                                 np.asarray(B), m=m, d=d)
        np.testing.assert_allclose(got, want.astype(np.float64), rtol=1e-6)


class TestQuantize:
    @hypothesis.given(st.integers(min_value=4, max_value=16))
    @hypothesis.settings(max_examples=20, deadline=None)
    def test_quantize_bounds(self, bits):
        rng = np.random.default_rng(bits)
        x = jnp.asarray(rng.normal(size=(16, 16)), jnp.float32)
        q, scale = layering.quantize(x, bits)
        qmax = 2 ** (bits - 1) - 1
        assert int(jnp.abs(q).max()) <= qmax
        rel = float(jnp.abs(q * scale - x).max())
        assert rel <= float(scale) * 0.5 + 1e-6
