"""The asynchronous runtime engine: units + end-to-end measured runs.

End-to-end runs use real threads and real coded matmuls, so they take a
few seconds each; delays are kept small but large enough to dominate the
per-round overhead (~1 ms).
"""

import threading

import numpy as np
import pytest

from repro.core import layering, simulator
from repro.runtime import (FusionNode, LayeredResult, Master, RoundFusion,
                           RuntimeConfig, StragglerModel, format_delay_table,
                           make_jobs, run_jobs)
from repro.runtime.metrics import RuntimeResult
from repro.runtime.tasks import RoundContext, TaskResult


def _result(job_id, round_idx, task_id, value, t=0.0):
    return TaskResult(job_id=job_id, round_idx=round_idx, task_id=task_id,
                      worker_id=0, value=value, finished_at=t)


class TestRoundFusion:
    def test_fuses_at_kth_result_and_drops_late(self):
        ctx = RoundContext(0, 0)
        rf = RoundFusion(ctx, k=3)
        for t in range(3):
            assert rf.post(_result(0, 0, t, np.full((2, 2), t), t=1.0 + t))
        assert rf.wait(timeout=0.0)
        assert rf.fused_at == 3.0                 # k-th arrival's clock
        assert not rf.post(_result(0, 0, 3, np.zeros((2, 2))))  # stale

    def test_purged_round_rejects_results(self):
        ctx = RoundContext(0, 0)
        rf = RoundFusion(ctx, k=2)
        ctx.purge()
        assert not rf.post(_result(0, 0, 0, np.zeros((2, 2))))
        assert not rf.wait(timeout=0.0)

    def test_decode_reconstructs_minijob(self, rng):
        cfg = RuntimeConfig(mu=(400.0, 500.0), omega=1.5)
        code = cfg.code()
        a = rng.integers(0, 255, size=(32, 8)).astype(np.float64)
        b = rng.integers(0, 255, size=(32, 8)).astype(np.float64)
        X, Y = code.encode(a, b)
        ctx = RoundContext(0, 0)
        rf = RoundFusion(ctx, k=code.k)
        # deliver an arbitrary k-subset, e.g. the last k codewords
        for t in range(code.num_tasks - code.k, code.num_tasks):
            rf.post(_result(0, 0, t, X[t].T @ Y[t]))
        np.testing.assert_allclose(rf.decode(code), a.T @ b,
                                   rtol=1e-9, atol=1e-6)

    def test_fusion_node_routes_and_counts_stale(self):
        node = FusionNode()
        ctx = RoundContext(job_id=1, round_idx=2)
        rf = node.begin_round(ctx, k=1)
        node.post(_result(9, 9, 0, np.zeros((1, 1))))   # wrong round
        assert node.stale_results == 1
        node.post(_result(1, 2, 0, np.zeros((1, 1))))
        assert rf.wait(timeout=0.0)


class TestLayeredResult:
    def test_best_resolution_scans_from_top(self):
        """MSB-first publishing means the first set event from the top is
        the answer; unset lower layers must not mask a ready higher one."""
        lr = LayeredResult(job_id=0, num_layers=4)
        lr.mark_resolution(0, np.zeros((1, 1)), t=0.0)
        lr.mark_resolution(1, np.ones((1, 1)), t=1.0)
        assert lr.best_resolution() == 1
        lr.mark_resolution(3, np.full((1, 1), 3.0), t=2.0)
        assert lr.best_resolution() == 3        # layer 2 still unset
        np.testing.assert_array_equal(lr.result(), np.full((1, 1), 3.0))

    def test_per_resolution_readiness_and_release(self):
        lr = LayeredResult(job_id=0, num_layers=3)
        assert lr.best_resolution() == -1
        with pytest.raises(RuntimeError):
            lr.result()
        lr.mark_resolution(0, np.ones((2, 2)), t=1.5)
        assert lr.resolution_ready(0) and not lr.resolution_ready(1)
        assert lr.best_resolution() == 0
        lr.release(terminated=True)
        assert lr.terminated and lr.released_resolution == 0
        np.testing.assert_array_equal(lr.result(), np.ones((2, 2)))

    def test_wait_resolution_unblocks_consumer(self):
        lr = LayeredResult(job_id=0, num_layers=2)
        seen = {}

        def consumer():
            lr.wait_resolution(0, timeout=5.0)
            seen["value"] = lr.resolution(0)

        th = threading.Thread(target=consumer)
        th.start()
        lr.mark_resolution(0, np.full((1,), 7.0), t=0.0)
        th.join(timeout=5.0)
        assert seen["value"][0] == 7.0


class TestStragglerModel:
    def _model(self, **kw):
        cfg = RuntimeConfig(mu=(400.0, 500.0, 600.0), **kw)
        return cfg, StragglerModel(cfg, np.random.default_rng(0))

    def test_none_injects_zero(self):
        _, sm = self._model(straggler="none")
        assert (sm.sample(0, 5) == 0).all()

    def test_exp_matches_simulator_scale(self):
        cfg, sm = self._model(straggler="exp", complexity=8.0)
        draws = sm.sample(1, 20000)
        want = cfg.minijob_complexity / cfg.mu[1]
        assert draws.mean() == pytest.approx(want, rel=0.05)

    def test_stall_pins_listed_workers(self):
        cfg, sm = self._model(straggler="stall", stall_workers=(2,),
                              stall_seconds=9.0)
        assert (sm.sample(2, 3) == 9.0).all()
        assert (sm.sample(0, 3) < 9.0).all()   # exp draws, not stalled

    def test_bad_model_rejected(self):
        with pytest.raises(ValueError):
            RuntimeConfig(mu=(1.0,), straggler="bogus")
        with pytest.raises(ValueError):
            RuntimeConfig(mu=(1.0,), stall_workers=(3,))


class TestConfig:
    def test_load_split_sums_to_total_tasks(self):
        cfg = RuntimeConfig(mu=(400.0, 650.0, 380.0), omega=1.5)
        kappa = cfg.load_split()
        assert kappa.sum() == cfg.total_tasks == 6
        assert cfg.k == 4 and cfg.num_layers == 3 and cfg.num_rounds == 4

    def test_to_system_config_roundtrip(self):
        cfg = RuntimeConfig(mu=(400.0, 500.0), arrival_rate=3.0,
                            complexity=7.0, omega=1.25, gamma=2.0)
        scfg = cfg.to_system_config()
        assert scfg.k == cfg.k and scfg.total_tasks == cfg.total_tasks
        assert scfg.m == cfg.m and scfg.mu == cfg.mu
        assert scfg.arrival_rate == cfg.arrival_rate


def _metrics_result(released, L=3):
    """Minimal RuntimeResult with just the fields the metrics under test
    read (released + layer_compute's L)."""
    J = len(released)
    return RuntimeResult(
        arrivals=np.zeros(J), starts=np.zeros(J), ends=np.zeros(J),
        layer_compute=np.zeros((J, L)), success=np.ones((J, L), bool),
        terminated=np.zeros(J, bool), kappa=np.zeros(3, dtype=np.int64),
        released=np.asarray(released, dtype=np.int64))


class TestMetrics:
    def test_format_delay_table_empty_rows(self):
        """Regression: an empty table (e.g. a run terminated before any
        release) must render a placeholder, not IndexError on rows[0]."""
        assert format_delay_table([]) == "(no resolutions to report)"

    def test_format_delay_table_none_percentiles(self):
        table = format_delay_table([{
            "resolution": 0, "mean_delay": float("inf"),
            "p50_delay": None, "p95_delay": None, "success_rate": 0.0}])
        assert "-" in table and "res" in table

    def test_release_histogram_counts_and_dtype(self):
        res = _metrics_result([-1, 0, 0, 2, 1, 2, 2], L=3)
        hist = res.release_histogram()
        assert hist.tolist() == [1, 2, 1, 3]     # none, res0, res1, res2
        assert hist.sum() == res.num_jobs

    def test_release_histogram_empty_and_single_bin(self):
        assert _metrics_result([], L=3).release_histogram().tolist() == \
            [0, 0, 0, 0]
        # all jobs unreleased: histogram still spans every resolution
        assert _metrics_result([-1, -1], L=2).release_histogram().tolist() \
            == [2, 0, 0]


class TestEndToEnd:
    def test_completes_and_decode_verifies(self):
        """No stragglers, no deadline: every job reaches full resolution
        and every resolution bit-matches the exact layered oracle (to
        float64 decode precision)."""
        cfg = RuntimeConfig(mu=(400.0, 650.0, 380.0), arrival_rate=100.0,
                            complexity=0.2, straggler="none", seed=0)
        res, futures = run_jobs(cfg, num_jobs=6, K=64, M=8, N=8, verify=True)
        assert res.success.all()
        assert (res.released == cfg.num_layers - 1).all()
        assert not res.terminated.any()
        assert np.nanmax(res.verify_errors) < 1e-9
        # the futures hold the actual products
        jobs = make_jobs(cfg, 6, K=64, M=8, N=8)
        exact = jobs[0].a.T @ jobs[0].b
        np.testing.assert_allclose(futures[0].resolution(cfg.num_layers - 1),
                                   exact, rtol=1e-9)

    def test_deadline_releases_verified_lower_resolution(self):
        """The acceptance scenario: an injected straggler plus a deadline
        the final resolution misses — the run still releases a correct
        (decode-verified) lower resolution, and measured per-resolution
        mean delays are ordered res0 < ... < final.

        The deadline is calibrated against a measured deadline-free
        baseline of the same stall regime (not a hard-coded wall-clock
        constant): resolution 0 must always make it (the assertion
        below), which only holds if the deadline comfortably clears this
        machine's actual res-0 service time — 30 ms is plenty on an idle
        box but flaky under CI load.  2.2x the measured res-0 mean keeps
        the final resolution impossible (the stalled worker holds it back
        by stall_seconds = 2 s) while making res 0 safe by construction.
        """
        base = dict(mu=(400.0, 650.0, 380.0), arrival_rate=14.0,
                    complexity=8.0, straggler="stall", stall_workers=(2,),
                    stall_seconds=2.0, seed=0)
        probe, _ = run_jobs(RuntimeConfig(**base), num_jobs=6,
                            K=64, M=8, N=8)
        deadline = max(0.030, 2.2 * float(probe.layer_compute[:, 0].mean()))
        cfg = RuntimeConfig(deadline=deadline, **base)
        res, futures = run_jobs(cfg, num_jobs=20, K=64, M=8, N=8,
                                verify=True)
        assert res.terminated.any()              # the deadline binds
        sr = res.success_rate()
        assert sr[0] == pytest.approx(1.0)       # §IV regime: res 0 always
        assert sr[-1] < 1.0                      # final resolution missed
        term = np.flatnonzero(res.terminated)
        assert (res.released[term] >= 0).all()   # partials still shipped
        assert (res.released[term] < cfg.num_layers - 1).any()
        # every released resolution is decode-verified vs the exact oracle
        assert np.nanmax(res.verify_errors) < 1e-9
        # MSB-first delay ordering, qualitatively matching simulate()
        md = res.mean_delay()
        assert np.all(np.diff(md) > 0)
        sim = simulator.simulate(cfg.to_system_config(), 2000, layered=True,
                                 seed=0)
        assert np.all(np.diff(sim.mean_delay()) > 0)

    def test_termination_requires_queued_successor(self):
        """A single job can blow way past the deadline: with nothing
        queued behind it, §IV never terminates it."""
        cfg = RuntimeConfig(mu=(400.0, 650.0, 380.0), arrival_rate=100.0,
                            complexity=4.0, deadline=1e-4,
                            straggler="exp", seed=1)
        res, _ = run_jobs(cfg, num_jobs=1, K=64, M=8, N=8)
        assert not res.terminated[0]
        assert res.success[0].all()
        assert res.layer_compute[0, -1] > 1e-4   # deadline WAS exceeded

    def test_purged_tasks_are_reclaimed(self):
        """Stale coded tasks are purged at fusion: with T - k = 2 spare
        tasks per round, late results are dropped, and the stalled
        worker's queue never blocks later rounds."""
        cfg = RuntimeConfig(mu=(400.0, 650.0, 380.0), arrival_rate=50.0,
                            complexity=1.0, straggler="stall",
                            stall_workers=(2,), stall_seconds=2.0, seed=0)
        res, _ = run_jobs(cfg, num_jobs=4, K=64, M=8, N=8)
        assert res.success.all()                 # stall never blocks fusion
        # worker 2 (kappa=1) never completed a task: all purged or pending
        assert res.stale_results >= 0
        assert res.wall_elapsed < 1.5            # not serialized behind stalls

    def test_runtime_agrees_with_simulator(self):
        """Measured mean first-resolution delay under the exp straggler
        model agrees with simulate() on the same configuration.

        Delay scales (~25 ms/task) are chosen to dominate the container's
        timer granularity (Event.wait oversleeps ~1-3 ms per wait) and the
        ~1 ms/round master overhead; at this scale the measured/simulated
        ratio sits around 1.1."""
        cfg = RuntimeConfig(mu=(400.0, 650.0, 380.0), arrival_rate=2.0,
                            complexity=40.0, straggler="exp", seed=2)
        res, _ = run_jobs(cfg, num_jobs=12, K=64, M=8, N=8)
        sim = simulator.simulate(cfg.to_system_config(), 4000, layered=True,
                                 seed=7)
        md, sd = res.mean_delay(), sim.mean_delay()
        assert md[0] == pytest.approx(sd[0], rel=0.30)
        # ordering agrees across ALL resolutions
        assert np.all(np.diff(md) > 0) and np.all(np.diff(sd) > 0)

    def test_stage_timings_recorded(self):
        """Every pipeline stage is accounted and the per-round master
        overhead (encode + decode) is well under a millisecond."""
        from repro.runtime.metrics import STAGES

        cfg = RuntimeConfig(mu=(400.0, 650.0, 380.0), arrival_rate=100.0,
                            complexity=0.2, straggler="none", seed=0)
        res, _ = run_jobs(cfg, num_jobs=8, K=64, M=8, N=8)
        assert set(res.stage_seconds) == set(STAGES)
        assert res.stage_rounds == 8 * cfg.num_rounds
        assert all(v >= 0.0 for v in res.stage_seconds.values())
        assert res.stage_seconds["encode"] > 0.0
        assert res.stage_seconds["decode"] > 0.0
        assert np.isfinite(res.per_round_overhead())
        # generous ceiling (loaded CI runners): the dev-container value is
        # ~300 us/round; the hard perf gate lives in the bench regression
        # check, not here
        assert res.per_round_overhead() < 1e-2

    def test_zero_copy_round_batches(self):
        """dispatch_round hands each worker a view into the round's coded
        buffers — no per-task copies."""
        from repro.runtime.tasks import RoundBatch
        from repro.runtime.worker import WorkerPool

        cfg = RuntimeConfig(mu=(400.0, 650.0, 380.0), straggler="none")
        seen = []
        pool = WorkerPool(cfg, sink=lambda r: None)
        for w in pool.workers:       # don't start threads; inspect queues
            w.submit_round = seen.append
        code = cfg.code()
        X = np.zeros((cfg.total_tasks, 8, 4))
        Y = np.zeros((cfg.total_tasks, 8, 4))
        pool.dispatch_round(RoundContext(0, 0), X, Y, cfg.load_split())
        assert sum(b.count for b in seen) == cfg.total_tasks
        for batch in seen:
            assert isinstance(batch, RoundBatch)
            assert batch.x.base is X and batch.y.base is Y   # views
            np.testing.assert_array_equal(
                batch.x, X[batch.first_task_id:
                           batch.first_task_id + batch.count])

    def test_trace_driven_arrivals(self):
        """Explicit arrival traces (batch-at-once) are honoured: jobs
        queue FIFO and starts are spaced by service, not arrivals."""
        cfg = RuntimeConfig(mu=(400.0, 650.0, 380.0), complexity=0.2,
                            straggler="none", seed=0)
        res, _ = run_jobs(cfg, num_jobs=4, K=64, M=8, N=8,
                          arrivals=[0.0, 0.0, 0.0, 0.0])
        assert res.success.all()
        assert np.all(np.diff(res.starts) >= -1e-9)
        # FIFO: each job starts where the previous one ended
        np.testing.assert_allclose(res.starts[1:], res.ends[:-1], atol=5e-3)
