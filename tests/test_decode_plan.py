"""DecodePlan: cached-operator decode equivalence, LRU behavior, reuse.

The property test (hypothesis-optional via ``_hypothesis_compat``) checks
the ISSUE's contract: decoding through the cached plan is *bit-identical*
to the uncached path (operator rebuilt fresh, same arithmetic) for random
arrival-ID subsets, in both float and gfp modes.
"""

import numpy as np
import pytest
from _hypothesis_compat import hypothesis, st

from repro.core import coding


def _task_results(code, rng, K=32, mb=4, nb=4):
    """Encode a random job and compute every coded task's result."""
    if code.mode == "float":
        a = rng.integers(0, 255, size=(K, mb * code.n1)).astype(np.float64)
        b = rng.integers(0, 255, size=(K, nb * code.n2)).astype(np.float64)
        X, Y = code.encode(a, b)
        tasks = np.stack([X[t].T @ Y[t] for t in range(code.num_tasks)])
    else:
        a = rng.integers(0, 255, size=(K, mb * code.n1)).astype(np.uint64)
        b = rng.integers(0, 255, size=(K, nb * code.n2)).astype(np.uint64)
        X, Y = code.encode(a, b)
        tasks = code.compute_all_tasks(X, Y)
    return a, b, tasks


class TestDecodePlanEquivalence:
    @pytest.mark.parametrize("mode", ["float", "gfp"])
    @hypothesis.given(seed=st.integers(0, 2**32 - 1))
    @hypothesis.settings(max_examples=20, deadline=None)
    def test_cached_decode_bit_identical_to_uncached(self, mode, seed):
        rng = np.random.default_rng(seed)
        code = coding.PolynomialCode(n1=2, n2=2, omega=1.5, mode=mode)
        _, _, tasks = _task_results(code, rng)
        ids = rng.permutation(code.num_tasks)[: code.k]
        plan = coding.DecodePlan(code.points(), code.k, mode=mode)
        res = tasks[np.asarray(ids)]
        cached = plan.solve(list(ids), res)               # populates cache
        cached2 = plan.solve(list(ids), res)              # cache hit
        uncached = plan.solve(list(ids), res, use_cache=False)
        np.testing.assert_array_equal(cached, uncached)
        np.testing.assert_array_equal(cached, cached2)
        assert plan.hits >= 1

    @pytest.mark.parametrize("mode", ["float", "gfp"])
    def test_plan_decode_matches_exact_product(self, mode):
        rng = np.random.default_rng(7)
        code = coding.PolynomialCode(n1=2, n2=2, omega=1.5, mode=mode)
        a, b, tasks = _task_results(code, rng)
        exact = a.astype(np.int64).T @ b.astype(np.int64)
        for trial in range(5):
            ids = rng.permutation(code.num_tasks)[: code.k]
            dec = np.asarray(code.decode(list(ids), tasks[np.asarray(ids)]))
            if mode == "gfp":
                np.testing.assert_array_equal(dec.astype(np.int64), exact)
            else:
                np.testing.assert_allclose(dec, exact, rtol=1e-9, atol=1e-6)

    def test_arrival_order_canonicalized(self):
        """Permuted arrivals of the same ID set are one cache entry and
        decode to the same coefficients."""
        rng = np.random.default_rng(3)
        code = coding.PolynomialCode(n1=2, n2=2, omega=1.5)
        _, _, tasks = _task_results(code, rng)
        plan = coding.DecodePlan(code.points(), code.k)
        ids = [4, 1, 5, 2]
        out1 = plan.solve(ids, tasks[np.asarray(ids)])
        perm = [5, 2, 4, 1]
        out2 = plan.solve(perm, tasks[np.asarray(perm)])
        np.testing.assert_allclose(out1, out2, rtol=1e-12, atol=1e-12)
        info = plan.cache_info()
        assert info["misses"] == 1 and info["hits"] == 1


class TestDecodePlanCache:
    def test_lru_eviction(self):
        """With cache_size=2 a third distinct ID set evicts the least
        recently used entry; revisiting it is a fresh miss."""
        code = coding.PolynomialCode(n1=2, n2=1, omega=2.0)  # k=2, T=4
        plan = coding.DecodePlan(code.points(), code.k, cache_size=2)
        res = np.zeros((2, 3, 3))
        plan.solve([0, 1], res)          # miss: {0,1}
        plan.solve([0, 2], res)          # miss: {0,2}
        plan.solve([0, 1], res)          # hit, refreshes {0,1}
        plan.solve([0, 3], res)          # miss, evicts LRU {0,2}
        info = plan.cache_info()
        assert info == {"hits": 1, "misses": 3, "evictions": 1,
                        "currsize": 2, "maxsize": 2}
        plan.solve([0, 2], res)          # evicted -> miss again
        assert plan.cache_info()["misses"] == 4

    def test_code_plan_is_shared_per_geometry(self):
        c1 = coding.PolynomialCode(n1=2, n2=2, omega=1.5)
        c2 = coding.PolynomialCode(n1=2, n2=2, omega=1.5)
        c3 = coding.PolynomialCode(n1=2, n2=2, omega=2.0)
        assert c1.plan() is c2.plan()
        assert c1.plan() is not c3.plan()

    def test_mds_decode_stays_jit_traceable(self):
        """JAX codewords take the device path: decode composes with
        jax.jit (ids static), as before the plan refactor."""
        import jax
        import jax.numpy as jnp

        rng = np.random.default_rng(1)
        mds = coding.MDSCode(k=3, n=5)
        shards = jnp.asarray(rng.normal(size=(3, 6)), jnp.float32)
        cw = mds.encode(shards)
        ids = (3, 0, 4)
        fn = jax.jit(lambda c: mds.decode(ids, c))
        rec = fn(cw[jnp.asarray(ids)])
        np.testing.assert_allclose(np.asarray(rec), np.asarray(shards),
                                   rtol=1e-3, atol=1e-4)

    def test_mds_decode_through_plan(self):
        rng = np.random.default_rng(0)
        mds = coding.MDSCode(k=3, n=5)
        shards = rng.normal(size=(3, 6)).astype(np.float32)
        cw = np.asarray(mds.encode(shards))
        before = mds.plan().cache_info()["misses"]
        ids = [4, 0, 2]
        rec = np.asarray(mds.decode(ids, cw[np.asarray(ids)]))
        np.testing.assert_allclose(rec, shards, rtol=1e-3, atol=1e-4)
        assert mds.plan().cache_info()["misses"] == before + 1
        mds.decode(ids, cw[np.asarray(ids)])
        assert mds.plan().cache_info()["misses"] == before + 1  # cache hit
