"""Integration tests: end-to-end training learns; serving profiles; the
int8 KV cache; train-loop checkpoint/resume."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.configs.base import (AttentionConfig, ModelConfig, ShapeConfig,
                                TrainConfig)
from repro.launch import steps as steps_lib
from repro.launch.mesh import make_test_mesh
from repro.launch.train import train_loop
from repro.models import transformer as T


def tiny_cfg(**kw):
    base = dict(name="tiny", family="dense", num_layers=2, d_model=64,
                d_ff=128, vocab_size=128, compute_dtype="float32",
                remat_policy="none", tie_embeddings=True,
                attention=AttentionConfig(num_heads=4, num_kv_heads=2,
                                          head_dim=16))
    base.update(kw)
    return ModelConfig(**base)


class TestTrainingLearns:
    def test_loss_decreases_on_bigram_chain(self):
        cfg = tiny_cfg(vocab_size=32)   # small table -> learns in ~100 steps
        tcfg = TrainConfig(learning_rate=5e-3, warmup_steps=5,
                           total_steps=100, weight_decay=0.0)
        out = train_loop(cfg, tcfg, batch=4, seq=64, steps=100,
                         log_every=20)
        first, last = out["losses"][0][1], out["losses"][-1][1]
        # vocab ceiling ln(32) ~ 3.47; chain entropy ln(8) ~ 2.08
        assert last < first - 0.3, (first, last)

    def test_checkpoint_resume_continues(self, tmp_path):
        cfg = tiny_cfg()
        tcfg = TrainConfig(learning_rate=1e-3, warmup_steps=2,
                           total_steps=20)
        train_loop(cfg, tcfg, batch=2, seq=32, steps=10,
                   ckpt_dir=str(tmp_path), ckpt_every=5, log_every=5)
        out = train_loop(cfg, tcfg, batch=2, seq=32, steps=20,
                         ckpt_dir=str(tmp_path), resume=True, log_every=5)
        assert out["losses"][0][0] > 10  # resumed past step 10


class TestShardingProfiles:
    @pytest.mark.parametrize("profile", ["tp_fsdp", "fsdp", "serve"])
    def test_profiles_lower_and_run(self, profile):
        cfg = tiny_cfg()
        mesh = make_test_mesh(1, 1)
        cell = steps_lib.build_cell(cfg, ShapeConfig("t", 32, 2, "train"),
                                    mesh, TrainConfig(bf16_weight_gather=True,
                                                      bf16_grads=True),
                                    profile=profile)
        params = T.init_params(jax.random.PRNGKey(0), cfg)
        _, opt = steps_lib.make_train_step(cfg, TrainConfig())
        state = opt.init(params)
        batch = {"tokens": jnp.zeros((2, 32), jnp.int32),
                 "targets": jnp.zeros((2, 32), jnp.int32)}
        p2, s2, m = cell.fn(params, state, batch)
        assert np.isfinite(float(m["loss"]))

    def test_serve_profile_drops_fsdp_axis(self):
        from jax.sharding import PartitionSpec as P

        from repro.launch import sharding as sh

        class FakeMesh:
            shape = {"data": 16, "model": 16}
            axis_names = ("data", "model")

        shapes = jax.eval_shape(
            lambda: {"w_gate": jnp.zeros((2, 4096, 14336))})
        tp = sh.param_specs(shapes, FakeMesh())
        srv = sh.param_specs(shapes, FakeMesh(), profile="serve")
        assert "data" in str(tp["w_gate"])
        assert "data" not in str(srv["w_gate"])
        assert "model" in str(srv["w_gate"])


class TestInt8KVCache:
    def test_decode_consistency_within_quant_error(self, rng):
        cfg = tiny_cfg(kv_cache_dtype="int8")
        params = T.init_params(jax.random.PRNGKey(0), cfg)
        toks = jnp.asarray(rng.integers(0, 128, (2, 17)), jnp.int32)
        ref_cfg = dataclasses.replace(cfg, kv_cache_dtype="")
        full, _ = T.forward(params, toks, ref_cfg)
        want = np.asarray(full[:, -1, :], np.float32)
        _, cache = T.prefill(params, toks[:, :16], cfg, max_len=24)
        got, _ = T.decode_step(params, toks[:, 16:17], cache,
                               jnp.int32(16), cfg)
        err = (np.abs(np.asarray(got, np.float32) - want).max()
               / np.abs(want).max())
        assert err < 0.1, err

    def test_cache_is_actually_int8(self):
        cfg = tiny_cfg(kv_cache_dtype="int8")
        caches = T.init_cache(cfg, 2, 16)
        leaves = jax.tree.leaves(caches)
        assert any(x.dtype == jnp.int8 for x in leaves)

    def test_hybrid_int8_window_cache(self, rng):
        from repro.configs.base import RGLRUConfig
        cfg = tiny_cfg(family="hybrid", num_layers=3,
                       rglru=RGLRUConfig(d_rnn=64, window=8),
                       attention=AttentionConfig(num_heads=4,
                                                 num_kv_heads=1,
                                                 head_dim=16),
                       kv_cache_dtype="int8")
        params = T.init_params(jax.random.PRNGKey(0), cfg)
        toks = jnp.asarray(rng.integers(0, 128, (2, 16)), jnp.int32)
        _, cache = T.prefill(params, toks, cfg, max_len=24)
        logits, _ = T.decode_step(params, toks[:, -1:], cache,
                                  jnp.int32(16), cfg)
        assert np.isfinite(np.asarray(logits, np.float32)).all()


class TestMoEDispatchGroup:
    def test_group_size_is_semantically_neutral(self, rng):
        """Changing dispatch_group (the A1 perf knob) must not change the
        routed outputs when capacity is ample."""
        from repro.configs.base import MoEConfig
        from repro.models import moe as moe_lib

        cfg_a = MoEConfig(num_experts=4, top_k=2, d_ff_expert=16,
                          capacity_factor=8.0, dispatch_group=4096)
        cfg_b = dataclasses.replace(cfg_a, dispatch_group=8)
        params = moe_lib.init_moe_params(jax.random.PRNGKey(1), 8, cfg_a,
                                         jnp.float32)
        x = jnp.asarray(rng.normal(size=(32, 8)), jnp.float32)
        ya = np.asarray(moe_lib.moe_block(params, x, cfg_a))
        yb = np.asarray(moe_lib.moe_block(params, x, cfg_b))
        np.testing.assert_allclose(ya, yb, rtol=2e-4, atol=2e-4)
