"""Pallas kernel validation: shape/dtype sweeps vs pure-jnp/NumPy oracles.

All kernels run in interpret mode on CPU (the TPU BlockSpecs execute as
Python), matching the brief's validation recipe.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import hypothesis, st

from repro.core import layering
from repro.kernels import ops, ref


class TestLayeredMatmulKernel:
    @pytest.mark.parametrize("m,d,K,M,N", [
        (2, 7, 64, 16, 24),
        (2, 7, 1024, 128, 128),   # multi-block K accumulation
        (3, 5, 128, 128, 128),
        (4, 4, 32, 8, 8),
        (1, 7, 16, 8, 8),         # degenerate single layer
    ])
    def test_partials_exact(self, rng, m, d, K, M, N):
        hi = 1 << (m * d - 1)
        A = jnp.asarray(rng.integers(-hi, hi, size=(K, M)), jnp.int32)
        B = jnp.asarray(rng.integers(-hi, hi, size=(K, N)), jnp.int32)
        parts = np.asarray(ops.layered_matmul_partials(A, B, m=m, d=d,
                                                       interpret=True))
        pa = np.asarray(layering.decompose(A, m, d), np.int64)
        pb = np.asarray(layering.decompose(B, m, d), np.int64)
        L = 2 * m - 1
        want = np.stack([
            sum(pa[i].T @ pb[j]
                for (i, j) in layering.layer_minijobs(m, l))
            for l in range(L)])
        np.testing.assert_array_equal(parts, want)

    def test_host_fusion_bit_exact(self, rng):
        m, d, K = 2, 7, 256
        hi = 1 << (m * d - 1)
        A = jnp.asarray(rng.integers(-hi, hi, size=(K, 16)), jnp.int32)
        B = jnp.asarray(rng.integers(-hi, hi, size=(K, 16)), jnp.int32)
        parts = np.asarray(ops.layered_matmul_partials(A, B, m=m, d=d,
                                                       interpret=True),
                           np.int64)
        scales = np.asarray([1 << ((2 * m - 2 - l) * d)
                             for l in range(2 * m - 1)], np.int64)
        recon = (parts * scales[:, None, None]).cumsum(0)[-1]
        exact = np.asarray(A, np.int64).T @ np.asarray(B, np.int64)
        np.testing.assert_array_equal(recon, exact)

    def test_fused_wrapper_matches_oracle(self, rng):
        m, d = 2, 6
        hi = 1 << (m * d - 1)
        A = jnp.asarray(rng.integers(-hi, hi, size=(64, 32)), jnp.int32)
        B = jnp.asarray(rng.integers(-hi, hi, size=(64, 8)), jnp.int32)
        got = np.asarray(ops.layered_matmul(A, B, m=m, d=d, interpret=True))
        want = ref.layered_matmul_ref(
            np.asarray(layering.decompose(A, m, d)),
            np.asarray(layering.decompose(B, m, d)), d=d)
        np.testing.assert_allclose(got, want, rtol=1e-6)

    def test_resolution_monotone_improvement(self, rng):
        m, d = 3, 4
        A = jnp.asarray(rng.integers(0, 1 << (m * d - 1), size=(32, 16)),
                        jnp.int32)
        B = jnp.asarray(rng.integers(0, 1 << (m * d - 1), size=(32, 16)),
                        jnp.int32)
        res = np.asarray(ops.layered_matmul(A, B, m=m, d=d, interpret=True))
        exact = np.asarray(A, np.int64).T @ np.asarray(B, np.int64)
        errs = [np.abs(res[l] - exact).max() for l in range(res.shape[0])]
        assert all(a >= b for a, b in zip(errs, errs[1:]))

    def test_d_too_large_rejected(self):
        with pytest.raises(ValueError):
            ops.layered_matmul(jnp.zeros((8, 8), jnp.int32),
                               jnp.zeros((8, 8), jnp.int32), m=2, d=8,
                               interpret=True)


class TestFlashAttentionKernel:
    @pytest.mark.parametrize("B,S,H,kv,dh,causal,window,dtype", [
        (2, 128, 4, 2, 64, True, None, jnp.float32),
        (1, 256, 2, 1, 32, True, 64, jnp.float32),
        (2, 64, 4, 4, 16, False, None, jnp.float32),
        (1, 512, 2, 2, 128, True, None, jnp.float32),
        (1, 128, 2, 2, 64, True, None, jnp.bfloat16),
    ])
    def test_matches_reference(self, rng, B, S, H, kv, dh, causal, window,
                               dtype):
        q = jnp.asarray(rng.normal(size=(B, S, H, dh)), dtype)
        k = jnp.asarray(rng.normal(size=(B, S, kv, dh)), dtype)
        v = jnp.asarray(rng.normal(size=(B, S, kv, dh)), dtype)
        got = np.asarray(ops.flash_attention(q, k, v, causal=causal,
                                             window=window, interpret=True),
                         np.float32)
        G = H // kv
        qf = q.transpose(0, 2, 1, 3).reshape(B * H, S, dh)
        kf = jnp.broadcast_to(k.transpose(0, 2, 1, 3)[:, :, None],
                              (B, kv, G, S, dh)).reshape(B * H, S, dh)
        vf = jnp.broadcast_to(v.transpose(0, 2, 1, 3)[:, :, None],
                              (B, kv, G, S, dh)).reshape(B * H, S, dh)
        want = np.asarray(ref.flash_attention_ref(qf, kf, vf, causal=causal,
                                                  window=window), np.float32)
        want = want.reshape(B, H, S, dh).transpose(0, 2, 1, 3)
        tol = 2e-2 if dtype == jnp.bfloat16 else 3e-5
        np.testing.assert_allclose(got, want, atol=tol, rtol=tol)

    def test_matches_model_attention_layer(self, rng):
        """Kernel agrees with the jnp attention used by the models."""
        from repro.configs.base import AttentionConfig
        from repro.models.layers import attention

        B, S, H, kv, dh = 2, 128, 4, 2, 32
        cfg = AttentionConfig(num_heads=H, num_kv_heads=kv, head_dim=dh)
        q = jnp.asarray(rng.normal(size=(B, S, H, dh)), jnp.float32)
        k = jnp.asarray(rng.normal(size=(B, S, kv, dh)), jnp.float32)
        v = jnp.asarray(rng.normal(size=(B, S, kv, dh)), jnp.float32)
        pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
        want = np.asarray(attention(q, k, v, pos, pos, cfg))
        got = np.asarray(ops.flash_attention(q, k, v, causal=True,
                                             interpret=True))
        np.testing.assert_allclose(got, want, atol=5e-5, rtol=5e-5)

    @hypothesis.given(st.integers(1, 3), st.sampled_from([64, 128, 256]),
                      st.sampled_from([16, 32, 64]))
    @hypothesis.settings(max_examples=8, deadline=None)
    def test_property_rows_are_convex_combinations(self, B, S, dh):
        rng = np.random.default_rng(S + dh)
        q = jnp.asarray(rng.normal(size=(B, S, 2, dh)), jnp.float32)
        k = jnp.asarray(rng.normal(size=(B, S, 2, dh)), jnp.float32)
        v = jnp.asarray(rng.normal(size=(B, S, 2, dh)), jnp.float32)
        out = np.asarray(ops.flash_attention(q, k, v, causal=True,
                                             interpret=True))
        # every output is a convex combination of values -> bounded by V
        vmax = np.abs(np.asarray(v)).max()
        assert np.abs(out).max() <= vmax + 1e-4


class TestSSDScanKernel:
    @pytest.mark.parametrize("B,S,H,P,N,chunk", [
        (2, 48, 4, 8, 16, 16),
        (1, 64, 2, 16, 32, 32),
        (1, 32, 8, 8, 8, 8),
    ])
    def test_matches_jnp_ssd(self, rng, B, S, H, P, N, chunk):
        from repro.kernels.ops import ssd_scan_fused
        from repro.models.ssm import ssd_scan

        x = jnp.asarray(rng.normal(size=(B, S, H, P)), jnp.float32)
        dt = jnp.asarray(rng.uniform(0.01, 0.2, size=(B, S, H)),
                         jnp.float32)
        A = -jnp.asarray(rng.uniform(0.5, 2.0, size=(H,)), jnp.float32)
        Bm = jnp.asarray(rng.normal(size=(B, S, 1, N)), jnp.float32)
        Cm = jnp.asarray(rng.normal(size=(B, S, 1, N)), jnp.float32)
        got_y, got_s = ssd_scan_fused(x, dt, A, Bm, Cm, chunk=chunk,
                                      interpret=True)
        want_y, want_s = ssd_scan(x, dt, A, Bm, Cm, chunk=chunk)
        np.testing.assert_allclose(np.asarray(got_y), np.asarray(want_y),
                                   rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(np.asarray(got_s), np.asarray(want_s),
                                   rtol=1e-4, atol=1e-4)

    def test_state_carries_across_chunks(self, rng):
        """One long scan == same scan with 4x more chunks (state carried)."""
        from repro.kernels.ops import ssd_scan_fused

        B, S, H, P, N = 1, 64, 2, 8, 8
        x = jnp.asarray(rng.normal(size=(B, S, H, P)), jnp.float32)
        dt = jnp.asarray(rng.uniform(0.01, 0.1, size=(B, S, H)), jnp.float32)
        A = -jnp.ones((H,), jnp.float32)
        Bm = jnp.asarray(rng.normal(size=(B, S, 1, N)), jnp.float32)
        Cm = jnp.asarray(rng.normal(size=(B, S, 1, N)), jnp.float32)
        y1, s1 = ssd_scan_fused(x, dt, A, Bm, Cm, chunk=64, interpret=True)
        y2, s2 = ssd_scan_fused(x, dt, A, Bm, Cm, chunk=16, interpret=True)
        np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                                   rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(np.asarray(s1), np.asarray(s2),
                                   rtol=1e-4, atol=1e-4)
