"""Per-architecture smoke tests: REDUCED same-family configs, one forward +
one train step on CPU, asserting output shapes and finiteness (the full
configs are exercised only via the dry-run)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.configs.base import ShapeConfig, TrainConfig
from repro.launch import steps as steps_lib
from repro.launch.mesh import make_test_mesh
from repro.models import transformer as T

ARCHS = list(registry.ARCH_IDS)


def _batch(cfg, B, S, rng):
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)),
                                   jnp.int32)}
    batch["targets"] = jnp.roll(batch["tokens"], -1, axis=1)
    if cfg.num_image_tokens:
        batch["extra_embeds"] = jnp.asarray(
            rng.normal(size=(B, cfg.num_image_tokens, cfg.d_model)),
            cfg.cdtype())
    if cfg.is_encdec:
        batch["audio_embeds"] = jnp.asarray(
            rng.normal(size=(B, cfg.encoder_seq, cfg.d_model)), cfg.cdtype())
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_and_train_step(arch, rng):
    cfg = registry.get_smoke_config(arch)
    assert cfg.family == registry.get_config(arch).family
    B, S = 2, 32
    mesh = make_test_mesh(1, 1)
    cell = steps_lib.build_cell(cfg, ShapeConfig("smoke", S, B, "train"),
                                mesh, TrainConfig(warmup_steps=2,
                                                  total_steps=10))
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    _, optimizer = steps_lib.make_train_step(cfg, TrainConfig(
        warmup_steps=2, total_steps=10))
    opt_state = optimizer.init(params)
    batch = _batch(cfg, B, S, rng)

    logits, _ = T.forward(params, batch["tokens"], cfg,
                          extra_embeds=batch.get("extra_embeds"),
                          audio_embeds=batch.get("audio_embeds"))
    assert logits.shape == (B, S, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all()

    # snapshot BEFORE the step: train_step donates params (they are
    # deleted after the call)
    embed_before = np.asarray(params["embed"], np.float32).copy()
    p2, o2, metrics = cell.fn(params, opt_state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    # params actually changed
    delta = np.abs(np.asarray(p2["embed"], np.float32)
                   - embed_before).max()
    assert delta > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_serve_step(arch, rng):
    cfg = registry.get_smoke_config(arch)
    B, S = 2, 16
    mesh = make_test_mesh(1, 1)
    cell = steps_lib.build_cell(cfg, ShapeConfig("smoke_d", S, B, "decode"),
                                mesh)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    caches = T.init_cache(cfg, B, S)
    if cfg.is_encdec:
        a = cfg.attention
        kvs = []
        for (unit, reps) in T.block_groups(cfg):
            for _ in unit:
                shp = (reps, B, cfg.encoder_seq, a.num_kv_heads, a.head_dim)
                kvs.append((jnp.zeros(shp, cfg.cdtype()),
                            jnp.zeros(shp, cfg.cdtype())))
        caches = (caches, kvs)
    batch = {"token": jnp.zeros((B, 1), jnp.int32),
             "pos": jnp.int32(S // 2), "caches": caches}
    logits, next_token, new_caches = cell.fn(params, batch)
    assert logits.shape == (B, cfg.vocab_size)
    assert next_token.shape == (B,)
    assert np.isfinite(np.asarray(logits, np.float32)).all()


def test_all_archs_have_exact_assigned_hyperparams():
    """Spot-check the exact published numbers from the assignment table."""
    c = registry.get_config("qwen2-moe-a2.7b")
    assert (c.num_layers, c.d_model, c.attention.num_heads,
            c.moe.num_experts, c.moe.top_k) == (24, 2048, 16, 60, 4)
    c = registry.get_config("llama4-maverick-400b-a17b")
    assert (c.num_layers, c.d_model, c.attention.num_kv_heads,
            c.moe.num_experts, c.moe.top_k, c.vocab_size) == (
        48, 5120, 8, 128, 1, 202_048)
    c = registry.get_config("llama3-8b")
    assert (c.num_layers, c.d_model, c.d_ff, c.vocab_size) == (
        32, 4096, 14336, 128_256)
    c = registry.get_config("mamba2-370m")
    assert (c.num_layers, c.d_model, c.ssm.d_state) == (48, 1024, 128)
    c = registry.get_config("recurrentgemma-9b")
    assert (c.num_layers, c.d_model, c.attention.num_kv_heads,
            c.vocab_size) == (38, 4096, 1, 256_000)
    c = registry.get_config("starcoder2-7b")
    assert (c.d_model, c.attention.num_heads, c.activation) == (
        4608, 36, "gelu")
    c = registry.get_config("whisper-tiny")
    assert (c.encoder_layers, c.num_layers, c.d_model) == (4, 4, 384)
    c = registry.get_config("glm4-9b")
    assert (c.num_layers, c.d_ff, c.attention.num_kv_heads) == (40, 13696, 2)
    c = registry.get_config("yi-6b")
    assert (c.d_ff, c.vocab_size, c.attention.num_kv_heads) == (
        11008, 64_000, 4)
    c = registry.get_config("internvl2-1b")
    assert (c.num_layers, c.d_model, c.attention.num_heads) == (24, 896, 14)


def test_param_counts_are_plausible():
    """Abstract parameter counts match the advertised model sizes."""
    import functools
    expected = {  # (total_low, total_high) in billions
        "llama3-8b": (7.5, 8.6),
        "yi-6b": (5.5, 6.5),
        "glm4-9b": (8.5, 10.0),
        "starcoder2-7b": (6.8, 7.9),
        "recurrentgemma-9b": (8.0, 10.5),
        "qwen2-moe-a2.7b": (13.0, 15.5),
        "llama4-maverick-400b-a17b": (370.0, 430.0),
        "mamba2-370m": (0.30, 0.45),
        "internvl2-1b": (0.35, 0.75),   # LM backbone only (ViT stubbed)
        "whisper-tiny": (0.025, 0.06),
    }
    for arch, (lo, hi) in expected.items():
        cfg = registry.get_config(arch)
        shapes = jax.eval_shape(
            functools.partial(T.init_params, cfg=cfg), jax.random.PRNGKey(0))
        n = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(shapes)) / 1e9
        assert lo <= n <= hi, f"{arch}: {n:.3f}B not in [{lo}, {hi}]"


def test_shape_cells_applicability():
    for arch in ARCHS:
        cfg = registry.get_config(arch)
        cells = registry.shape_cells(cfg)
        if arch in ("mamba2-370m", "recurrentgemma-9b"):
            assert "long_500k" in cells
        else:
            assert "long_500k" not in cells  # full attention: noted skip
        assert {"train_4k", "prefill_32k", "decode_32k"} <= set(cells)
