"""Eq.(1) load balancing + eqs.(2)-(4) G/G/1 bounds + simulator behaviour."""

import numpy as np
import pytest
from _hypothesis_compat import hypothesis, st

from repro.core import queueing, scheduling, simulator


class TestLoadSplit:
    def test_sums_exactly(self):
        stats = [scheduling.worker_job_moments(mu, 1000, 50.0)
                 for mu in simulator.PAPER_SYSTEM.mu]
        for total in [1000, 1018, 1060, 1200]:
            kappa = scheduling.load_split(stats, total)
            assert kappa.sum() == total
            assert (kappa >= 0).all()

    def test_faster_worker_gets_more(self):
        stats = [scheduling.worker_job_moments(mu, 1000, 50.0)
                 for mu in (100.0, 400.0)]
        kappa = scheduling.load_split(stats, 500)
        assert kappa[1] > kappa[0]

    def test_homogeneous_split_is_even(self):
        stats = [scheduling.worker_job_moments(200.0, 100, 10.0)] * 4
        kappa = scheduling.load_split(stats, 100)
        assert kappa.max() - kappa.min() <= 1

    @hypothesis.given(st.lists(st.floats(50.0, 1000.0), min_size=1,
                               max_size=8),
                      st.integers(1, 5000))
    @hypothesis.settings(max_examples=50, deadline=None)
    def test_property_sum_and_nonneg(self, mus, total):
        stats = [scheduling.worker_job_moments(mu, 100, 10.0) for mu in mus]
        kappa = scheduling.load_split(stats, total)
        assert kappa.sum() == total and (kappa >= 0).all()

    def test_zero_and_errors(self):
        stats = [scheduling.worker_job_moments(100.0, 10, 1.0)]
        assert scheduling.load_split(stats, 0).sum() == 0
        with pytest.raises(ValueError):
            scheduling.load_split([], 10)


class TestQueueingTheory:
    def test_service_rate_bound(self):
        # super-worker rate = sum of rates
        assert queueing.service_rate_bound([2.0, 2.0]) == pytest.approx(1.0)

    def test_gg1_reduces_to_mm1(self):
        # Poisson arrivals + exponential service: Marchal is exact (M/M/1)
        lam, mu = 0.5, 1.0
        arrival = queueing.Moments(1 / lam, 2 / lam**2)
        service = queueing.Moments(1 / mu, 2 / mu**2)
        # M/M/1 sojourn: 1/(mu - lam)
        assert queueing.gg1_delay(arrival, service) == pytest.approx(
            1.0 / (mu - lam), rel=1e-6)

    def test_unstable_queue_is_inf(self):
        arrival = queueing.Moments(1.0, 2.0)
        service = queueing.Moments(2.0, 8.0)
        assert queueing.gg1_delay(arrival, service) == np.inf

    def test_layered_bounds_monotone(self):
        cfg = simulator.PAPER_SYSTEM
        service = queueing.Moments(22.7, 22.7**2 * 1.01)
        arrival = queueing.Moments(100.0, 2 * 100.0**2)
        worker_means = [cfg.k * cfg.complexity / mu for mu in cfg.mu]
        b = queueing.layered_delay_bounds(cfg.m, worker_means, arrival,
                                          service)
        assert b.shape == (3,)
        assert b[0] < b[1] < b[2]


class TestSimulator:
    def test_paper_shape_of_results(self):
        r = simulator.simulate(simulator.PAPER_SYSTEM, 200, layered=True,
                               seed=0)
        assert r.layer_compute.shape == (200, 3)
        # resolutions complete in order
        assert (np.diff(r.layer_compute, axis=1) >= 0).all()
        # no termination without deadline
        assert not r.terminated.any()
        assert r.success.all()

    def test_layer_delays_ordered_and_final_matches_unlayered(self):
        cfg = simulator.PAPER_SYSTEM
        r = simulator.simulate(cfg, 400, layered=True, seed=1)
        rn = simulator.simulate(cfg, 400, layered=False, seed=1)
        d = r.mean_delay()
        assert d[0] < d[1] < d[2]
        # final layered resolution ~ no-layering delay (paper Fig 2a claim)
        assert abs(d[2] - rn.mean_delay()[0]) / d[2] < 0.05

    def test_theory_bound_is_lower_bound_and_tight(self):
        cfg = simulator.SystemConfig(omega=1.06)
        r = simulator.simulate(cfg, 600, layered=True, seed=2)
        bounds = simulator.theory_bounds(cfg, r.service_moments(),
                                         layered=True)
        d = r.mean_delay()
        assert (d >= bounds - 1e-9).all()
        # tight at ~6% redundancy (paper: "empirically achievable")
        assert ((d - bounds) / bounds < 0.08).all()

    def test_deadline_layer0_survives(self):
        cfg = simulator.PAPER_SYSTEM
        r = simulator.simulate(cfg, 300, layered=True, deadline=10.0, seed=3)
        sr = r.success_rate()
        assert sr[0] == 1.0                  # paper Fig 3b headline claim
        assert sr[2] < 1.0
        assert (np.diff(sr) <= 1e-9).all()   # monotone in resolution

    def test_deadline_requires_queued_successor(self):
        # huge inter-arrival gap -> queue empty -> nothing terminated
        cfg = simulator.SystemConfig(arrival_rate=1e-5)
        r = simulator.simulate(cfg, 50, layered=True, deadline=1.0, seed=4)
        assert not r.terminated.any()

    def test_more_redundancy_not_slower(self):
        cfg1 = simulator.SystemConfig(omega=1.0)
        cfg2 = simulator.SystemConfig(omega=1.1)
        d1 = simulator.simulate(cfg1, 400, seed=5).mean_delay()[-1]
        d2 = simulator.simulate(cfg2, 400, seed=5).mean_delay()[-1]
        assert d2 <= d1 * 1.02

    def test_kappa_used_matches_eq1(self):
        cfg = simulator.PAPER_SYSTEM
        r = simulator.simulate(cfg, 10, layered=True, seed=6)
        assert r.kappa.sum() == cfg.total_tasks
