"""Eq.(1) load balancing + eqs.(2)-(4) G/G/1 bounds + simulator behaviour.

The G/G/1 waiting-time term is also the serving gateway's admission
bound, so beyond the closed forms this file validates it against queue
waits *measured* on a live gateway fleet (TestGatewayMeasuredWaits)."""

import time

import numpy as np
import pytest
from _hypothesis_compat import hypothesis, st

from repro.core import queueing, scheduling, simulator


class TestLoadSplit:
    def test_sums_exactly(self):
        stats = [scheduling.worker_job_moments(mu, 1000, 50.0)
                 for mu in simulator.PAPER_SYSTEM.mu]
        for total in [1000, 1018, 1060, 1200]:
            kappa = scheduling.load_split(stats, total)
            assert kappa.sum() == total
            assert (kappa >= 0).all()

    def test_faster_worker_gets_more(self):
        stats = [scheduling.worker_job_moments(mu, 1000, 50.0)
                 for mu in (100.0, 400.0)]
        kappa = scheduling.load_split(stats, 500)
        assert kappa[1] > kappa[0]

    def test_homogeneous_split_is_even(self):
        stats = [scheduling.worker_job_moments(200.0, 100, 10.0)] * 4
        kappa = scheduling.load_split(stats, 100)
        assert kappa.max() - kappa.min() <= 1

    @hypothesis.given(st.lists(st.floats(50.0, 1000.0), min_size=1,
                               max_size=8),
                      st.integers(1, 5000))
    @hypothesis.settings(max_examples=50, deadline=None)
    def test_property_sum_and_nonneg(self, mus, total):
        stats = [scheduling.worker_job_moments(mu, 100, 10.0) for mu in mus]
        kappa = scheduling.load_split(stats, total)
        assert kappa.sum() == total and (kappa >= 0).all()

    def test_zero_and_errors(self):
        stats = [scheduling.worker_job_moments(100.0, 10, 1.0)]
        assert scheduling.load_split(stats, 0).sum() == 0
        with pytest.raises(ValueError):
            scheduling.load_split([], 10)


class TestQueueingTheory:
    def test_service_rate_bound(self):
        # super-worker rate = sum of rates
        assert queueing.service_rate_bound([2.0, 2.0]) == pytest.approx(1.0)

    def test_gg1_reduces_to_mm1(self):
        # Poisson arrivals + exponential service: Marchal is exact (M/M/1)
        lam, mu = 0.5, 1.0
        arrival = queueing.Moments(1 / lam, 2 / lam**2)
        service = queueing.Moments(1 / mu, 2 / mu**2)
        # M/M/1 sojourn: 1/(mu - lam)
        assert queueing.gg1_delay(arrival, service) == pytest.approx(
            1.0 / (mu - lam), rel=1e-6)

    def test_unstable_queue_is_inf(self):
        arrival = queueing.Moments(1.0, 2.0)
        service = queueing.Moments(2.0, 8.0)
        assert queueing.gg1_delay(arrival, service) == np.inf

    def test_layered_bounds_monotone(self):
        cfg = simulator.PAPER_SYSTEM
        service = queueing.Moments(22.7, 22.7**2 * 1.01)
        arrival = queueing.Moments(100.0, 2 * 100.0**2)
        worker_means = [cfg.k * cfg.complexity / mu for mu in cfg.mu]
        b = queueing.layered_delay_bounds(cfg.m, worker_means, arrival,
                                          service)
        assert b.shape == (3,)
        assert b[0] < b[1] < b[2]

    def test_waiting_time_mm1_closed_form(self):
        # M/M/1: Marchal's Wq is exact, Wq = rho / (mu - lambda)
        lam, mu = 0.4, 1.0
        arrival = queueing.Moments(1 / lam, 2 / lam**2)
        service = queueing.Moments(1 / mu, 2 / mu**2)
        rho = lam / mu
        assert queueing.gg1_waiting_time(arrival, service) == pytest.approx(
            rho / (mu - lam), rel=1e-9)

    def test_waiting_time_md1_closed_form(self):
        # M/D/1: deterministic service, Wq = rho / (2 (mu - lambda))
        lam, mu = 0.5, 1.0
        arrival = queueing.Moments(1 / lam, 2 / lam**2)
        service = queueing.Moments(1 / mu, 1 / mu**2)   # zero variance
        rho = lam / mu
        assert queueing.gg1_waiting_time(arrival, service) == pytest.approx(
            rho / (2 * (mu - lam)), rel=1e-9)

    def test_delay_decomposes_into_service_plus_wait(self):
        arrival = queueing.Moments(3.0, 2 * 9.0)
        service = queueing.Moments(1.2, 2.0)
        assert queueing.gg1_delay(arrival, service) == pytest.approx(
            service.mean + queueing.gg1_waiting_time(arrival, service))
        # the override swaps only the computational term
        assert queueing.gg1_delay(arrival, service, 0.9) == pytest.approx(
            0.9 + queueing.gg1_waiting_time(arrival, service))

    def test_layered_bounds_decompose(self):
        # eq. (4) = eq. (3)'s layered share + the (layer-independent)
        # G/G/1 waiting time: the same decomposition the gateway's
        # admission estimate prices per-resolution
        from repro.core import layering

        m = 3
        worker_means = [0.05, 0.08, 0.04]
        arrival = queueing.Moments(0.5, 0.6)
        service = queueing.Moments(0.02, 0.0009)
        b = queueing.layered_delay_bounds(m, worker_means, arrival, service)
        w = queueing.gg1_waiting_time(arrival, service)
        rate = queueing.service_rate_bound(worker_means)
        cum = np.asarray(layering.cumulative_minijobs(m), dtype=np.float64)
        np.testing.assert_allclose(b, cum / (m * m) / rate + w, rtol=1e-12)
        assert (np.diff(b) > 0).all()

    def test_waiting_time_zero_at_zero_variability(self):
        # D/D/1 under rho < 1 never queues
        arrival = queueing.Moments(2.0, 4.0)
        service = queueing.Moments(1.0, 1.0)
        assert queueing.gg1_waiting_time(arrival, service) == 0.0


class TestSimulator:
    def test_paper_shape_of_results(self):
        r = simulator.simulate(simulator.PAPER_SYSTEM, 200, layered=True,
                               seed=0)
        assert r.layer_compute.shape == (200, 3)
        # resolutions complete in order
        assert (np.diff(r.layer_compute, axis=1) >= 0).all()
        # no termination without deadline
        assert not r.terminated.any()
        assert r.success.all()

    def test_layer_delays_ordered_and_final_matches_unlayered(self):
        cfg = simulator.PAPER_SYSTEM
        r = simulator.simulate(cfg, 400, layered=True, seed=1)
        rn = simulator.simulate(cfg, 400, layered=False, seed=1)
        d = r.mean_delay()
        assert d[0] < d[1] < d[2]
        # final layered resolution ~ no-layering delay (paper Fig 2a claim)
        assert abs(d[2] - rn.mean_delay()[0]) / d[2] < 0.05

    def test_theory_bound_is_lower_bound_and_tight(self):
        cfg = simulator.SystemConfig(omega=1.06)
        r = simulator.simulate(cfg, 600, layered=True, seed=2)
        bounds = simulator.theory_bounds(cfg, r.service_moments(),
                                         layered=True)
        d = r.mean_delay()
        assert (d >= bounds - 1e-9).all()
        # tight at ~6% redundancy (paper: "empirically achievable")
        assert ((d - bounds) / bounds < 0.08).all()

    def test_deadline_layer0_survives(self):
        cfg = simulator.PAPER_SYSTEM
        r = simulator.simulate(cfg, 300, layered=True, deadline=10.0, seed=3)
        sr = r.success_rate()
        assert sr[0] == 1.0                  # paper Fig 3b headline claim
        assert sr[2] < 1.0
        assert (np.diff(sr) <= 1e-9).all()   # monotone in resolution

    def test_deadline_requires_queued_successor(self):
        # huge inter-arrival gap -> queue empty -> nothing terminated
        cfg = simulator.SystemConfig(arrival_rate=1e-5)
        r = simulator.simulate(cfg, 50, layered=True, deadline=1.0, seed=4)
        assert not r.terminated.any()

    def test_more_redundancy_not_slower(self):
        cfg1 = simulator.SystemConfig(omega=1.0)
        cfg2 = simulator.SystemConfig(omega=1.1)
        d1 = simulator.simulate(cfg1, 400, seed=5).mean_delay()[-1]
        d2 = simulator.simulate(cfg2, 400, seed=5).mean_delay()[-1]
        assert d2 <= d1 * 1.02

    def test_kappa_used_matches_eq1(self):
        cfg = simulator.PAPER_SYSTEM
        r = simulator.simulate(cfg, 10, layered=True, seed=6)
        assert r.kappa.sum() == cfg.total_tasks


class TestGatewayMeasuredWaits:
    """Eqs. (2)-(4) against a *live* fleet: the Marchal waiting time the
    gateway prices into admission, validated on queue waits measured
    from the gateway's own tickets under seeded Poisson load."""

    def test_measured_queue_waits_match_gg1_waiting_time(self):
        from repro.runtime import RuntimeConfig, ServingGateway

        cfg = RuntimeConfig(mu=(385.95, 650.92, 373.40), arrival_rate=30.0,
                            n1=2, n2=2, omega=1.5, m=2, d=8,
                            complexity=10.0, straggler="exp",
                            backend="thread", seed=7)
        rng = np.random.default_rng(7)
        lim = 1 << (cfg.m * cfg.d - 2)

        def operands():
            a = rng.integers(-lim, lim, size=(16, cfg.n1 * 4),
                             dtype=np.int64)
            b = rng.integers(-lim, lim, size=(16, cfg.n2 * 4),
                             dtype=np.int64)
            return a, b

        with ServingGateway(cfg, admission="none") as gw:
            # calibrate: serial requests measure this fleet's service time
            warm = [gw.submit(*operands(), deadline=30.0) for _ in range(4)]
            assert all(t.wait(timeout=60.0) for t in warm)
            mean_s = float(np.mean(
                [t.result.released_at - t.result.service_started_at
                 for t in warm]))
            # open Poisson stream at rho ~ 0.5; deadlines generous so no
            # service is truncated (the bound models no termination)
            gaps = rng.exponential(2.0 * mean_s, size=36)
            tickets = []
            for g in gaps:
                time.sleep(float(g))
                tickets.append(gw.submit(*operands(), deadline=30.0))
            assert all(t.wait(timeout=60.0) for t in tickets)

        services = np.array(
            [t.result.released_at - t.result.service_started_at
             for t in tickets])
        gaps_meas = np.diff(np.array([t.arrival for t in tickets]))
        waits = np.array([t.queue_wait for t in tickets])
        arrival = queueing.Moments(float(gaps_meas.mean()),
                                   float((gaps_meas**2).mean()))
        service = queueing.Moments(float(services.mean()),
                                   float((services**2).mean()))
        rho = service.mean / arrival.mean
        assert 0.2 < rho < 0.95, rho
        w_pred = queueing.gg1_waiting_time(arrival, service)
        w_meas = float(waits.mean())
        assert np.isfinite(w_pred) and w_pred > 0.0
        # Marchal is a mean approximation and the fleet is not an ideal
        # single server: demand agreement within a factor of 4
        assert w_meas <= 4.0 * w_pred, (w_meas, w_pred, rho)
        assert w_meas >= 0.25 * w_pred, (w_meas, w_pred, rho)
