"""Online ω control: policy steps, controller geometry, regime-shift e2e.

Policy/controller units run on synthetic observation traces (no threads);
the end-to-end test runs the real engine through a mid-run worker outage
and asserts the adaptive run strictly beats the worst static ω on
deadline success rate — the ISSUE acceptance scenario, shrunk to test
size.
"""

import dataclasses

import numpy as np
import pytest

from repro.runtime import RuntimeConfig, run_jobs
from repro.runtime.adaptive import (POLICIES, AIMDPolicy,
                                    DeadlineMarginPolicy, FixedPolicy,
                                    OmegaController, RoundObservation,
                                    make_policy)

MU3 = (400.0, 650.0, 380.0)


def obs(round_idx=0, *, wait=0.01, fused=True, stale=0, margin=None,
        rounds_left=3, job_id=0):
    return RoundObservation(round_idx=round_idx, job_id=job_id, wait=wait,
                            fused=fused, stale=stale,
                            deadline_margin=margin, rounds_left=rounds_left)


class TestPolicies:
    def test_fixed_never_moves(self):
        pol = FixedPolicy()
        for i in range(10):
            omega, reason = pol.step(obs(i, fused=(i % 2 == 0), stale=50,
                                         margin=0.0), 1.5)
            assert omega == 1.5 and reason is None

    def test_aimd_grows_on_missed_deadline(self):
        pol = AIMDPolicy(increase=0.25)
        omega, reason = pol.step(obs(fused=False), 1.0)
        assert omega == 1.25 and "missed" in reason

    def test_aimd_grows_on_projected_miss(self):
        """Remaining rounds at the observed wait EWMA overrun the margin."""
        pol = AIMDPolicy(increase=0.25)
        omega, reason = pol.step(
            obs(wait=0.02, margin=0.01, rounds_left=3), 1.0)
        assert omega == 1.25 and "projected" in reason

    def test_aimd_shrinks_multiplicatively_on_stale_pileup(self):
        pol = AIMDPolicy(decrease=0.8, stale_tolerance=1.0)
        omega = 2.0
        for i in range(12):      # EWMA of 3 stale/round crosses tolerance
            omega, reason = pol.step(obs(i, stale=3), omega)
            if reason is not None:
                assert "stale" in reason
                assert omega == pytest.approx(2.0 * 0.8)
                return
        pytest.fail("stale pile-up never triggered a shrink")

    def test_aimd_comfortable_round_is_a_noop(self):
        pol = AIMDPolicy()
        omega, reason = pol.step(
            obs(wait=0.001, margin=1.0, rounds_left=3, stale=0), 1.5)
        assert omega == 1.5 and reason is None

    def test_deadline_margin_grows_when_band_undershot(self):
        pol = DeadlineMarginPolicy(low=1.5, step_up=0.25)
        # margin ratio = 0.012 / (0.01 * 1) = 1.2 < 1.5
        omega, reason = pol.step(
            obs(wait=0.01, margin=0.012, rounds_left=1), 1.0)
        assert omega == 1.25 and "margin ratio" in reason

    def test_deadline_margin_shrinks_only_when_comfortable(self):
        pol = DeadlineMarginPolicy(high=6.0, step_down=0.125,
                                   stale_tolerance=1.0)
        # tight margin + stale: the miss risk wins, no shrink
        omega, reason = pol.step(
            obs(wait=0.01, margin=0.02, rounds_left=1, stale=10), 2.0)
        assert omega >= 2.0
        pol2 = DeadlineMarginPolicy(high=6.0, step_down=0.125,
                                    stale_tolerance=1.0)
        # comfortable margin (ratio 100) + stale pile-up: shrink
        omega, reason = pol2.step(
            obs(wait=0.001, margin=0.1, rounds_left=1, stale=10), 2.0)
        assert omega == pytest.approx(2.0 - 0.125) and "stale" in reason

    def test_deadline_margin_grows_on_realized_miss(self):
        pol = DeadlineMarginPolicy(step_up=0.25)
        omega, reason = pol.step(obs(fused=False), 1.0)
        assert omega == 1.25 and "missed" in reason

    def test_policies_grow_without_a_deadline_on_wait_spike(self):
        """No deadline => no miss/margin signal; a wait explosion (worker
        outage) must still grow omega, or stale-driven shrinks would
        ratchet it one-way to omega_min."""
        for pol in (AIMDPolicy(), DeadlineMarginPolicy()):
            for i in range(5):               # settle the wait EWMA ~5 ms
                omega, _ = pol.step(obs(i, wait=0.005), 1.5)
                assert omega == 1.5
            omega, reason = pol.step(obs(9, wait=0.5), 1.5)
            assert omega > 1.5 and "spike" in reason

    def test_make_policy_resolves_names_and_instances(self):
        assert isinstance(make_policy("aimd"), AIMDPolicy)
        pol = DeadlineMarginPolicy()
        assert make_policy(pol) is pol
        assert isinstance(make_policy(None), FixedPolicy)
        with pytest.raises(ValueError, match="unknown omega policy"):
            make_policy("bogus")
        assert set(POLICIES) == {"fixed", "aimd", "deadline-margin"}


class TestController:
    def _cfg(self, **kw):
        kw.setdefault("mu", MU3)
        kw.setdefault("omega", 1.0)
        kw.setdefault("adapt", "aimd")
        return RuntimeConfig(**kw)

    def test_bounds_respected(self):
        cfg = self._cfg(omega_min=1.0, omega_max=1.5)
        ctrl = OmegaController(cfg)
        for i in range(20):       # every round misses: growth is clipped
            ctrl.observe(obs(i, fused=False))
        assert ctrl.omega == 1.5
        assert all(ev["omega_new"] <= 1.5 for ev in ctrl.trace)
        # and shrink is floored at omega_min
        ctrl2 = OmegaController(self._cfg(omega=1.0, omega_min=1.0))
        for i in range(40):
            ctrl2.observe(obs(i, stale=10))
        assert ctrl2.omega >= 1.0

    def test_geometry_switch_rebuilds_kappa_and_traces_prime(self):
        cfg = self._cfg()
        ctrl = OmegaController(cfg)
        assert ctrl.total_tasks == 4 and ctrl.kappa.sum() == 4
        switched = ctrl.observe(obs(fused=False))   # 1.0 -> 1.25, T 4 -> 5
        assert switched and ctrl.total_tasks == 5
        assert ctrl.kappa.sum() == 5
        assert ctrl.switches == 1
        ev = ctrl.trace[-1]
        assert ev["switched"] and ev["T_old"] == 4 and ev["T_new"] == 5
        assert ev["prime_seconds"] >= 0.0
        assert ctrl.summary()["omega_final"] == 1.25

    def test_omega_move_within_codeword_bucket_switches_nothing(self):
        """ceil(4 * 1.5) == ceil(4 * 1.275) == 6: the retune is traced but
        the geometry (and its DecodePlan) stays."""
        cfg = self._cfg(omega=1.5, adapt="aimd")
        ctrl = OmegaController(cfg, policy=AIMDPolicy(decrease=0.85,
                                                      stale_tolerance=0.5))
        code_before = ctrl.code
        switched = ctrl.observe(obs(stale=10))
        assert ctrl.omega == pytest.approx(1.275)
        assert not switched and ctrl.switches == 0
        assert ctrl.code is code_before
        assert len(ctrl.trace) == 1 and not ctrl.trace[-1]["switched"]

    def test_decode_plan_reused_across_geometry_round_trip(self):
        """Growing away from a geometry and shrinking back must reuse the
        process-wide per-geometry DecodePlan — the round trip's second
        switch pays no Vandermonde rebuild."""
        cfg = self._cfg(omega=1.0)
        ctrl = OmegaController(cfg)
        plan_t4 = ctrl.code.plan()
        ctrl.observe(obs(0, fused=False))           # T 4 -> 5
        plan_t5 = ctrl.code.plan()
        assert plan_t5 is not plan_t4
        for i in range(1, 60):                      # stale until back at 1.0
            ctrl.observe(obs(i, stale=10))
            if ctrl.total_tasks == 4:
                break
        assert ctrl.total_tasks == 4
        assert ctrl.code.plan() is plan_t4          # same object, cached
        # plans key on GEOMETRY, not the exact omega float: AIMD's
        # multiplicative shrink rarely reproduces a prior omega, but
        # constantly revisits prior codeword lengths
        cfg_raw = RuntimeConfig(mu=MU3)
        assert (cfg_raw.code(omega=1.3).plan()
                is cfg_raw.code(omega=1.5).plan())  # both T = 6
        # the plan's arrival-set operator LRU also survives the round trip
        ids = tuple(range(4))
        plan_t4.solve(ids, np.zeros((4, 2, 2)))
        hits_before = plan_t4.cache_info()["hits"]
        plan_t4.solve(ids, np.zeros((4, 2, 2)))
        assert plan_t4.cache_info()["hits"] == hits_before + 1

    def test_fixed_controller_is_static(self):
        cfg = RuntimeConfig(mu=MU3, omega=1.5)      # adapt defaults fixed
        ctrl = OmegaController(cfg)
        for i in range(10):
            assert not ctrl.observe(obs(i, fused=False, stale=50))
        assert ctrl.omega == 1.5 and ctrl.trace == []
        s = ctrl.summary()
        assert s["policy"] == "fixed" and s["retunes"] == 0

    def test_initial_omega_clipped_into_bounds(self):
        cfg = self._cfg(omega=1.2, omega_min=1.5, omega_max=2.0)
        ctrl = OmegaController(cfg)
        assert ctrl.omega == 1.5

    def test_fixed_policy_ignores_inert_adaptive_bounds(self):
        """Static runs must use cfg.omega verbatim — simulator agreement
        depends on the measured geometry matching to_system_config() —
        even when omega sits outside the (unused) adaptive bounds."""
        cfg = RuntimeConfig(mu=MU3, omega=4.0)      # > default omega_max
        ctrl = OmegaController(cfg)
        assert ctrl.omega == 4.0
        assert ctrl.total_tasks == cfg.total_tasks == 16

    def test_config_rejects_bad_bounds_and_bursts(self):
        with pytest.raises(ValueError, match="omega_min"):
            RuntimeConfig(mu=MU3, omega_min=2.0, omega_max=1.5)
        with pytest.raises(ValueError, match="burst_len"):
            RuntimeConfig(mu=MU3, straggler="burst", burst_len=2.0,
                          burst_period=1.0, stall_workers=(1,))
        # shift/burst without stall_workers would be a silent no-op
        for mode in ("shift", "burst"):
            with pytest.raises(ValueError, match="stall_workers"):
                RuntimeConfig(mu=MU3, straggler=mode)


class TestTimeVaryingStragglers:
    def test_shift_regime_flips_at_shift_at(self):
        from repro.runtime.worker import StragglerModel
        cfg = RuntimeConfig(mu=MU3, complexity=8.0, straggler="shift",
                            stall_workers=(2,), shift_at=3600.0,
                            stall_seconds=9.0)
        sm = StragglerModel(cfg, np.random.default_rng(0))
        assert (sm.sample(2, 4) < 9.0).all()        # pre-shift: exp draws
        sm2 = StragglerModel(dataclasses.replace(cfg, shift_at=0.0),
                             np.random.default_rng(0))
        assert (sm2.sample(2, 4) == 9.0).all()      # post-shift: dark
        assert (sm2.sample(0, 4) < 9.0).all()       # others unaffected

    def test_regime_clock_anchors_on_any_first_sample(self):
        """A stall-listed worker can hold kappa = 0 (eq. 1 at omega = 1);
        the regime clock must anchor on the run's first sample for ANY
        worker, not lazily inside the stalled worker's own branch."""
        from repro.runtime.worker import StragglerModel
        cfg = RuntimeConfig(mu=MU3, complexity=8.0, straggler="shift",
                            stall_workers=(2,), shift_at=0.0,
                            stall_seconds=9.0)
        sm = StragglerModel(cfg, np.random.default_rng(0))
        sm.sample(0, 2)                             # worker 2 never sampled
        assert sm._origin is not None               # clock runs anyway
        assert (sm.sample(2, 3) == 9.0).all()       # outage on schedule

    def test_burst_windows_gate_the_stall(self):
        from repro.runtime.worker import StragglerModel
        cfg = RuntimeConfig(mu=MU3, complexity=8.0, straggler="burst",
                            stall_workers=(2,), burst_period=3600.0,
                            burst_len=3600.0, stall_seconds=9.0)
        sm = StragglerModel(cfg, np.random.default_rng(0))
        assert (sm.sample(2, 4) == 9.0).all()       # inside the window
        cfg2 = dataclasses.replace(cfg, burst_len=1e-9)
        sm2 = StragglerModel(cfg2, np.random.default_rng(0))
        sm2._origin = -3600.0                       # far past the window
        assert (sm2.sample(2, 4) < 9.0).all()


class TestEndToEndRegimeShift:
    """The acceptance scenario at test size: a worker outage mid-run.

    At omega=1.0 (T = k) every worker's task is fusion-critical, so the
    outage starves every post-shift round until §IV termination; the
    adaptive run grows omega within a job or two of the shift and keeps
    releasing resolution 0.
    """

    def _base(self, adapt):
        return RuntimeConfig(mu=MU3, arrival_rate=14.0, omega=1.0,
                             complexity=8.0, deadline=0.04,
                             straggler="shift", stall_workers=(2,),
                             shift_at=0.6, stall_seconds=1.0,
                             adapt=adapt, seed=0)

    @pytest.mark.parametrize("policy", ["aimd", "deadline-margin"])
    def test_adaptive_beats_worst_static_on_success_rate(self, policy):
        worst, _ = run_jobs(self._base("fixed"), 24, K=64, M=8, N=8)
        adapt, _ = run_jobs(self._base(policy), 24, K=64, M=8, N=8)
        sr_worst = worst.success_rate()[0]
        sr_adapt = adapt.success_rate()[0]
        assert sr_worst < 0.85           # the outage really binds at T = k
        assert sr_adapt >= sr_worst + 0.15
        ctl = adapt.controller
        assert ctl["policy"] == policy
        assert ctl["switches"] >= 1 and ctl["omega_final"] > 1.0
        assert len(adapt.omega_trace) == ctl["retunes"] >= 1
        # controller time is accounted and the trace records prime costs
        assert adapt.stage_seconds["control"] >= 0.0
        assert ctl["prime_seconds_total"] >= 0.0

    def test_adaptive_run_still_decode_verifies(self):
        """Geometry switches mid-run must not corrupt decodes: every
        released resolution still matches the exact layered oracle."""
        res, _ = run_jobs(self._base("aimd"), 12, K=64, M=8, N=8,
                          verify=True)
        errs = res.verify_errors[np.isfinite(res.verify_errors)]
        assert errs.size and errs.max() < 1e-9
        assert res.controller["switches"] >= 1
