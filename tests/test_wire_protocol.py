"""Wire-protocol tests for the socket transport's frame codec.

Deterministic cases cover every codec and every rejection path (garbage
magic, bad version, unknown codec, truncation on either side of the
header, decompressed-size mismatch); the property-based block (hypothesis,
via the optional shim) round-trips arbitrary ``WireBatch``/``TaskResult``
shapes and dtypes with and without compression — the frames that actually
cross the network in a run.

LRF2 (``proto=2``) gets its own block: raw ndarray buffers ride
out-of-band next to a tiny pickled meta, so the cases additionally pin
down bit-identity, the in-band/out-of-band byte split, and that both
frame generations parse off one stream (the mixed-version window).
"""

import struct

import numpy as np
import pytest

from _hypothesis_compat import HAVE_HYPOTHESIS, hypothesis, st
from repro.runtime.tasks import TaskResult, WireBatch
from repro.runtime.transport.socket_host import (CODECS, COMPRESS_MIN_BYTES,
                                                 HEADER_SIZE, MAGIC, MAGIC2,
                                                 FrameError,
                                                 _encode_frame_info,
                                                 decode_frame, encode_frame,
                                                 have_lz4)

COMPRESS_MODES = ["none", "auto", "zlib"] + (["lz4"] if have_lz4() else [])

DTYPES = (np.float64, np.float32, np.int64, np.int32, np.uint8)


def _batch(rng, shape, dtype):
    n = shape[0]
    x = rng.integers(0, 100, size=shape).astype(dtype)
    y = rng.integers(0, 100, size=shape).astype(dtype)
    return WireBatch(seq=int(rng.integers(0, 1 << 30)),
                     job_id=int(rng.integers(0, 1000)),
                     round_idx=int(rng.integers(0, 16)),
                     first_task_id=int(rng.integers(0, 64)),
                     x=x, y=y, delays=rng.random(n))


def _assert_batches_equal(a: WireBatch, b: WireBatch):
    assert (a.seq, a.job_id, a.round_idx, a.first_task_id) == \
        (b.seq, b.job_id, b.round_idx, b.first_task_id)
    assert a.x.dtype == b.x.dtype and a.y.dtype == b.y.dtype
    np.testing.assert_array_equal(a.x, b.x)
    np.testing.assert_array_equal(a.y, b.y)
    np.testing.assert_array_equal(a.delays, b.delays)


class TestFrameRoundTrip:
    @pytest.mark.parametrize("compress", COMPRESS_MODES)
    @pytest.mark.parametrize("dtype", DTYPES)
    def test_wire_batch_round_trips(self, compress, dtype):
        rng = np.random.default_rng(0)
        batch = _batch(rng, (6, 32, 8), dtype)
        frame = encode_frame(("round", batch), compress=compress)
        (kind, back), consumed = decode_frame(frame)
        assert kind == "round" and consumed == len(frame)
        _assert_batches_equal(batch, back)

    @pytest.mark.parametrize("compress", COMPRESS_MODES)
    def test_task_result_round_trips(self, compress):
        r = TaskResult(job_id=1, round_idx=2, task_id=3, worker_id=4,
                       value=np.arange(64, dtype=np.float64).reshape(8, 8),
                       finished_at=5.5)
        frame = encode_frame(("result", r.to_wire(), 1.25),
                             compress=compress)
        (kind, wire, busy), _ = decode_frame(frame)
        back = TaskResult.from_wire(wire)
        assert kind == "result" and busy == 1.25
        assert (back.job_id, back.round_idx, back.task_id, back.worker_id,
                back.finished_at) == (1, 2, 3, 4, 5.5)
        np.testing.assert_array_equal(back.value, r.value)

    def test_trailing_bytes_not_consumed(self):
        """Frames are self-delimiting: back-to-back frames parse one at a
        time off a single buffer (the stream case)."""
        f1 = encode_frame(("ping",))
        f2 = encode_frame(("purge", 17))
        buf = f1 + f2
        obj1, used1 = decode_frame(buf)
        obj2, used2 = decode_frame(buf[used1:])
        assert obj1 == ("ping",) and obj2 == ("purge", 17)
        assert used1 + used2 == len(buf)

    def test_auto_compresses_large_compressible_payloads(self):
        big = np.zeros((4, 64, 64))        # highly compressible
        frame = encode_frame(("round", big), compress="auto")
        raw_len = struct.unpack("!I", frame[8:12])[0]
        wire_len = struct.unpack("!I", frame[12:16])[0]
        assert raw_len >= COMPRESS_MIN_BYTES
        assert wire_len < raw_len          # actually compressed
        (_, back), _ = decode_frame(frame)
        np.testing.assert_array_equal(back, big)

    def test_auto_skips_tiny_and_incompressible_payloads(self):
        tiny = encode_frame(("ping",), compress="auto")
        assert tiny[5] == CODECS["none"]   # codec byte: below threshold
        noise = np.random.default_rng(0).integers(
            0, 256, size=1 << 16, dtype=np.uint8).tobytes()
        frame = encode_frame(noise, compress="auto")
        assert frame[5] == CODECS["none"]  # incompressible: shipped raw
        obj, _ = decode_frame(frame)
        assert obj == noise

    def test_lz4_mode_errors_clearly_when_unavailable(self):
        if have_lz4():
            pytest.skip("lz4 installed: the unavailable path can't fire")
        with pytest.raises(ValueError, match="lz4"):
            encode_frame(("x",), compress="lz4")


class TestFrameRejection:
    def _frame(self, compress="none"):
        return encode_frame(("round", np.ones((4, 8, 8))),
                            compress=compress)

    def test_truncated_header_rejected(self):
        frame = self._frame()
        for cut in (0, 1, HEADER_SIZE - 1):
            with pytest.raises(FrameError, match="truncated header"):
                decode_frame(frame[:cut])

    def test_truncated_payload_rejected(self):
        frame = self._frame()
        with pytest.raises(FrameError, match="truncated payload"):
            decode_frame(frame[:HEADER_SIZE + 10])

    def test_garbage_magic_rejected(self):
        frame = bytearray(self._frame())
        frame[:4] = b"EVIL"
        with pytest.raises(FrameError, match="bad magic"):
            decode_frame(bytes(frame))

    def test_wrong_version_rejected(self):
        frame = bytearray(self._frame())
        frame[4] = 99
        with pytest.raises(FrameError, match="version"):
            decode_frame(bytes(frame))

    def test_unknown_codec_rejected(self):
        frame = bytearray(self._frame())
        frame[5] = 7
        with pytest.raises(FrameError, match="codec"):
            decode_frame(bytes(frame))

    def test_corrupt_compressed_payload_rejected(self):
        frame = bytearray(self._frame(compress="zlib"))
        frame[HEADER_SIZE] ^= 0xFF          # flip a deflate byte
        with pytest.raises(FrameError,
                           match="corrupt|decompressed size"):
            decode_frame(bytes(frame))

    def test_corrupt_lz4_payload_rejected(self):
        """lz4 raises RuntimeError, not zlib.error: corruption must still
        surface as FrameError or the receiver thread dies on it."""
        if not have_lz4():
            pytest.skip("lz4 not installed in this environment")
        frame = bytearray(self._frame(compress="lz4"))
        frame[HEADER_SIZE] ^= 0xFF
        with pytest.raises(FrameError,
                           match="corrupt|decompressed size"):
            decode_frame(bytes(frame))

    def test_raw_len_mismatch_rejected(self):
        frame = bytearray(self._frame(compress="zlib"))
        good_raw = struct.unpack("!I", frame[8:12])[0]
        frame[8:12] = struct.pack("!I", good_raw + 1)
        with pytest.raises(FrameError, match="decompressed size"):
            decode_frame(bytes(frame))

    def test_random_garbage_rejected(self):
        rng = np.random.default_rng(3)
        for _ in range(32):
            junk = rng.integers(0, 256,
                                size=int(rng.integers(0, 200)),
                                dtype=np.uint8).tobytes()
            with pytest.raises(FrameError):
                decode_frame(junk)


class TestFrameV2:
    """LRF2: pickle-free ndarray payloads (protocol-5 meta + raw buffers)."""

    @pytest.mark.parametrize("compress", COMPRESS_MODES)
    @pytest.mark.parametrize("dtype", DTYPES)
    def test_wire_batch_round_trips(self, compress, dtype):
        rng = np.random.default_rng(0)
        batch = _batch(rng, (6, 32, 8), dtype)
        frame = encode_frame(("round", batch), compress=compress, proto=2)
        assert frame[:4] == MAGIC2
        (kind, back), consumed = decode_frame(frame)
        assert kind == "round" and consumed == len(frame)
        _assert_batches_equal(batch, back)

    def test_result_decodes_bit_identical(self):
        value = np.random.default_rng(1).normal(size=(8, 8))
        r = TaskResult(job_id=1, round_idx=2, task_id=3, worker_id=4,
                       value=value, finished_at=5.5)
        frame = encode_frame(("result", r.to_wire(), 0.5), compress="none",
                             proto=2)
        (kind, wire, busy), _ = decode_frame(frame)
        back = TaskResult.from_wire(wire)
        assert kind == "result" and busy == 0.5
        assert np.array_equal(back.value.view(np.uint64),
                              value.view(np.uint64))

    def test_bulk_bytes_ride_out_of_band(self):
        """The point of the format: ndarray payload bytes are handed to
        the socket as raw buffers, never copied through the pickler —
        only the small metadata stays in-band."""
        batch = _batch(np.random.default_rng(2), (4, 64, 64), np.float64)
        parts, raw_len, inband, oob = _encode_frame_info(
            ("round", batch), compress="none", proto=2)
        bulk = batch.x.nbytes + batch.y.nbytes + batch.delays.nbytes
        assert oob == bulk
        assert inband < 2048                 # meta only
        assert raw_len == inband + oob
        (_, back), _ = decode_frame(b"".join(parts))
        _assert_batches_equal(batch, back)

    def test_control_messages_have_no_buffers(self):
        frame = encode_frame(("purge", 17), proto=2)
        assert frame[:4] == MAGIC2
        obj, used = decode_frame(frame)
        assert obj == ("purge", 17) and used == len(frame)
        _, _, inband, oob = _encode_frame_info(("purge", 17), proto=2)
        assert oob == 0 and inband > 0

    def test_both_generations_parse_off_one_stream(self):
        """Self-delimiting across versions: during the negotiation window
        a receiver may see LRF1 and LRF2 frames back to back."""
        f1 = encode_frame(("ping",), proto=1)
        f2 = encode_frame(("round", np.ones((2, 4, 4))), proto=2)
        buf = f1 + f2
        obj1, used1 = decode_frame(buf)
        (kind, back), used2 = decode_frame(buf[used1:])
        assert obj1 == ("ping",) and kind == "round"
        np.testing.assert_array_equal(back, np.ones((2, 4, 4)))
        assert used1 + used2 == len(buf)

    def test_v2_compression_round_trips_compressible_payload(self):
        big = np.zeros((4, 64, 64))
        frame = encode_frame(("round", big), compress="auto", proto=2)
        wire_len = struct.unpack("!I", frame[12:16])[0]
        raw_len = struct.unpack("!I", frame[8:12])[0]
        assert wire_len < raw_len            # actually compressed
        (_, back), _ = decode_frame(frame)
        np.testing.assert_array_equal(back, big)

    def test_truncated_v2_payload_rejected(self):
        frame = encode_frame(("round", np.ones((4, 8, 8))), proto=2)
        with pytest.raises(FrameError, match="truncated"):
            decode_frame(frame[:HEADER_SIZE + 10])

    def test_corrupt_v2_length_table_rejected(self):
        """A meta length pointing past the payload must surface as
        FrameError, not an index crash in the receiver thread."""
        frame = bytearray(encode_frame(("round", np.ones((4, 8, 8))),
                                       compress="none", proto=2))
        meta_len, nbuf = struct.unpack_from("!IH", frame, HEADER_SIZE)
        struct.pack_into("!IH", frame, HEADER_SIZE, meta_len + 10_000, nbuf)
        with pytest.raises(FrameError):
            decode_frame(bytes(frame))

    def test_wrong_v2_version_rejected(self):
        frame = bytearray(encode_frame(("ping",), proto=2))
        frame[4] = 99
        with pytest.raises(FrameError, match="version"):
            decode_frame(bytes(frame))

    def test_unknown_proto_rejected_at_encode(self):
        with pytest.raises(ValueError, match="proto"):
            encode_frame(("ping",), proto=3)


# -- property-based block (skipped cleanly without hypothesis) ---------------

if HAVE_HYPOTHESIS:
    wire_settings = hypothesis.settings(max_examples=60, deadline=None)
else:                                 # decorators become skip markers
    wire_settings = lambda fn: fn     # noqa: E731


class TestFrameProperties:
    @wire_settings
    @hypothesis.given(
        n=st.integers(1, 8), k=st.integers(1, 48), m=st.integers(1, 24),
        dtype=st.sampled_from(DTYPES),
        compress=st.sampled_from(COMPRESS_MODES),
        proto=st.sampled_from((1, 2)),
        seed=st.integers(0, 2**32 - 1))
    def test_wire_batch_any_geometry_round_trips(self, n, k, m, dtype,
                                                 compress, proto, seed):
        rng = np.random.default_rng(seed)
        batch = _batch(rng, (n, k, m), dtype)
        (kind, back), consumed = decode_frame(
            encode_frame(("round", batch), compress=compress, proto=proto))
        assert kind == "round"
        _assert_batches_equal(batch, back)

    @wire_settings
    @hypothesis.given(
        rows=st.integers(1, 64), cols=st.integers(1, 64),
        dtype=st.sampled_from((np.float64, np.float32)),
        compress=st.sampled_from(COMPRESS_MODES),
        seed=st.integers(0, 2**32 - 1))
    def test_task_result_any_shape_round_trips(self, rows, cols, dtype,
                                               compress, seed):
        rng = np.random.default_rng(seed)
        r = TaskResult(job_id=int(rng.integers(0, 1 << 20)), round_idx=3,
                       task_id=int(rng.integers(0, 64)), worker_id=1,
                       value=rng.random((rows, cols)).astype(dtype),
                       finished_at=float(rng.random()))
        (_, wire, _), _ = decode_frame(
            encode_frame(("result", r.to_wire(), 0.0), compress=compress))
        back = TaskResult.from_wire(wire)
        assert back.value.dtype == r.value.dtype
        np.testing.assert_array_equal(back.value, r.value)

    @wire_settings
    @hypothesis.given(cut=st.integers(0, 200), seed=st.integers(0, 999))
    def test_any_truncation_rejected_never_crashes(self, cut, seed):
        rng = np.random.default_rng(seed)
        frame = encode_frame(("round", rng.random((4, 16, 8))),
                             compress="zlib")
        hypothesis.assume(cut < len(frame))
        with pytest.raises(FrameError):
            decode_frame(frame[:cut])

    @wire_settings
    @hypothesis.given(data=st.binary(max_size=512))
    def test_arbitrary_bytes_reject_or_roundtrip(self, data):
        """decode never crashes with anything but FrameError, and the
        vanishingly-unlikely parse success must satisfy the header
        invariants (a fuzz guard for the receiver thread)."""
        try:
            _, consumed = decode_frame(data)
        except FrameError:
            return
        assert data[:4] in (MAGIC, MAGIC2) and consumed <= len(data)
