"""Optimizers, synthetic data pipeline, checkpoint store, fault tolerance."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import store
from repro.configs.base import TrainConfig
from repro.core.layered_matmul import GradientCoder
from repro.data.pipeline import SyntheticLM
from repro.launch import fault
from repro.optim.optimizers import (adafactor, adamw, cosine_schedule,
                                    global_norm, make_optimizer)


def quad_params(rng):
    return {"a": jnp.asarray(rng.normal(size=(8, 8)), jnp.float32),
            "b": jnp.asarray(rng.normal(size=(8,)), jnp.float32)}


class TestOptimizers:
    @pytest.mark.parametrize("name", ["adamw", "adafactor"])
    def test_minimises_quadratic(self, rng, name):
        tcfg = TrainConfig(optimizer=name, learning_rate=0.05,
                           warmup_steps=5, total_steps=200,
                           weight_decay=0.0)
        opt = make_optimizer(tcfg)
        params = quad_params(rng)
        target = jax.tree.map(lambda x: jnp.ones_like(x), params)
        state = opt.init(params)

        def loss(p):
            return sum(jnp.sum((x - t)**2)
                       for x, t in zip(jax.tree.leaves(p),
                                       jax.tree.leaves(target)))

        l0 = float(loss(params))
        for _ in range(150):
            grads = jax.grad(loss)(params)
            params, state = opt.update(grads, state, params)
        assert float(loss(params)) < 0.05 * l0

    def test_adamw_state_shapes(self, rng):
        opt = adamw(TrainConfig())
        params = quad_params(rng)
        st = opt.init(params)
        assert st["m"]["a"].shape == (8, 8)
        assert st["v"]["b"].dtype == jnp.float32

    def test_adafactor_factored_state_is_small(self, rng):
        opt = adafactor(TrainConfig(optimizer="adafactor"))
        params = {"w": jnp.zeros((64, 128), jnp.float32)}
        st = opt.init(params)
        n_state = sum(int(np.prod(x.shape))
                      for x in jax.tree.leaves(st["v"]))
        assert n_state == 64 + 128  # vr + vc, not 64*128

    def test_grad_clip_bounds_update(self, rng):
        tcfg = TrainConfig(grad_clip=1e-6, learning_rate=1.0,
                           warmup_steps=0, total_steps=10,
                           weight_decay=0.0)
        opt = adamw(tcfg)
        params = quad_params(rng)
        st = opt.init(params)
        huge = jax.tree.map(lambda x: 1e6 * jnp.ones_like(x), params)
        new_params, st2 = opt.update(huge, st, params)
        assert float(st2["gnorm"]) > 1.0
        # after clipping, first-step Adam update magnitude is ~lr
        delta = global_norm(jax.tree.map(lambda a, b: a - b, new_params,
                                         params))
        assert float(delta) < 30.0

    def test_schedule_warmup_and_decay(self):
        tcfg = TrainConfig(learning_rate=1.0, warmup_steps=10,
                           total_steps=100)
        lr = cosine_schedule(tcfg)
        assert float(lr(jnp.int32(5))) == pytest.approx(0.5)
        assert float(lr(jnp.int32(10))) == pytest.approx(1.0, rel=1e-3)
        assert float(lr(jnp.int32(100))) == pytest.approx(0.0, abs=1e-6)


class TestData:
    def test_deterministic_and_step_dependent(self):
        data = SyntheticLM(vocab_size=64, seq_len=16, global_batch=4)
        b1, b2 = data.batch_at(3), data.batch_at(3)
        np.testing.assert_array_equal(np.asarray(b1.tokens),
                                      np.asarray(b2.tokens))
        b3 = data.batch_at(4)
        assert not np.array_equal(np.asarray(b1.tokens),
                                  np.asarray(b3.tokens))

    def test_targets_are_shifted_tokens(self):
        data = SyntheticLM(vocab_size=64, seq_len=16, global_batch=2)
        b = data.batch_at(0)
        np.testing.assert_array_equal(np.asarray(b.tokens[:, 1:]),
                                      np.asarray(b.targets[:, :-1]))

    def test_bigram_structure_is_learnable(self):
        """Every transition comes from the chain table."""
        data = SyntheticLM(vocab_size=32, seq_len=32, global_batch=2,
                           branching=4)
        b = data.batch_at(0)
        table = np.asarray(data.table)
        toks = np.asarray(b.tokens)
        for bi in range(2):
            for t in range(31):
                assert toks[bi, t + 1] in table[toks[bi, t]]


class TestCheckpoint:
    def test_save_restore_roundtrip(self, rng, tmp_path):
        tree = {"params": {"w": jnp.asarray(rng.normal(size=(4, 4)),
                                            jnp.float32)},
                "opt": {"step": jnp.int32(7)}}
        store.save(str(tmp_path), 7, tree)
        assert store.latest_step(str(tmp_path)) == 7
        out = store.restore(str(tmp_path), 7, tree)
        np.testing.assert_array_equal(np.asarray(out["params"]["w"]),
                                      np.asarray(tree["params"]["w"]))
        assert int(out["opt"]["step"]) == 7

    def test_atomic_overwrite_and_gc(self, rng, tmp_path):
        ck = store.AsyncCheckpointer(str(tmp_path), keep=2)
        tree = {"w": jnp.zeros((2,), jnp.float32)}
        for s in (1, 2, 3, 4):
            ck.save(s, jax.tree.map(lambda x: x + s, tree))
        ck.wait()
        steps = sorted(int(d.split("_")[1]) for d in os.listdir(tmp_path)
                       if d.startswith("step_"))
        assert steps == [3, 4]

    def test_shape_mismatch_raises(self, rng, tmp_path):
        tree = {"w": jnp.zeros((4,), jnp.float32)}
        store.save(str(tmp_path), 1, tree)
        with pytest.raises(ValueError):
            store.restore(str(tmp_path), 1, {"w": jnp.zeros((5,),
                                                            jnp.float32)})

    def test_elastic_restore_changes_sharding(self, rng, tmp_path):
        """Restore re-places leaves with the current mesh's shardings."""
        from repro.launch.mesh import make_test_mesh
        mesh = make_test_mesh(1, 1)
        template = {"params": {"embed": jnp.zeros((32, 16), jnp.float32)},
                    "opt": {"step": jnp.int32(0)}}
        store.save(str(tmp_path), 5, template)
        out = fault.elastic_restore(str(tmp_path), 5, template, mesh)
        assert out["params"]["embed"].shape == (32, 16)


class TestCodedDP:
    def test_pod_loss_recovers_exact_gradient(self, rng):
        """Full coded-DP path: shard grads -> codewords -> erase -> decode."""
        coder = GradientCoder(n=4, k=3)
        params = {"w": jnp.asarray(rng.normal(size=(6,)), jnp.float32)}
        batches = [jnp.asarray(rng.normal(size=(3, 6)), jnp.float32)
                   for _ in range(4)]

        def loss_fn(p, batch):
            return jnp.sum((batch @ p["w"])**2)

        cws = fault.coded_dp_grads(loss_fn, params, batches, coder)
        want = jax.tree.map(
            lambda *g: sum(g),
            *[jax.grad(loss_fn)(params, b) for b in batches])
        for lost in range(4):
            surv = [p for p in range(4) if p != lost]
            got = fault.degraded_step_grads(cws, surv, coder)
            np.testing.assert_allclose(np.asarray(got["w"]),
                                       np.asarray(want["w"]), rtol=1e-4,
                                       atol=1e-4)
