"""Serving gateway: G/G/1 admission bounds against hand-computed
numbers, request lifecycle over a live thread fleet, shutdown hygiene,
and a property block over seeded arrival schedules.

The deterministic tier isolates :meth:`AdmissionController.decide` (a
pure function of the moments) so every admit / down-resolve / reject
verdict is checked against arithmetic done by hand in the comments.
"""

import threading
import time

import numpy as np
import pytest

from _hypothesis_compat import hypothesis, st
from repro.core.layering import cumulative_minijobs
from repro.core.queueing import Moments, gg1_waiting_time
from repro.runtime import RuntimeConfig, ServingGateway
from repro.runtime.gateway import MIN_SAMPLES, AdmissionController

MU3 = (385.95, 650.92, 373.40)

# hand-computed fixture: m=2 (cum = [1, 3, 4]), exponential-like moments
#   arrival  E[A]=0.1,  E[A^2]=0.02   -> c_a^2 = 1
#   service  E[S]=0.04, E[S^2]=0.0032 -> c_s^2 = 1, rho = 0.4
#   W = E[S] * rho/(1-rho) * (c_a^2+c_s^2)/2 = 0.04 * (2/3) = 0.0266667
#   est(l) = W + 0.04 * cum[l]/4:
#     est(2) = 0.0666667   est(1) = 0.0566667   est(0) = 0.0366667
ARRIVAL = Moments(0.1, 0.02)
SERVICE = Moments(0.04, 0.0032)
W = 0.04 * (0.4 / 0.6)


def _cfg(**kw):
    defaults = dict(mu=MU3, arrival_rate=50.0, n1=2, n2=2, omega=1.5,
                    m=2, d=8, complexity=10.0, straggler="none",
                    backend="thread", seed=0)
    defaults.update(kw)
    return RuntimeConfig(**defaults)


def _operands(rng, cfg, k=16, n=4):
    lim = 1 << (cfg.m * cfg.d - 2)
    a = rng.integers(-lim, lim, size=(k, cfg.n1 * n), dtype=np.int64)
    b = rng.integers(-lim, lim, size=(k, cfg.n2 * n), dtype=np.int64)
    return a, b


class TestAdmissionBound:
    """decide() against the hand-computed G/G/1 numbers above."""

    def test_waiting_time_matches_hand_computation(self):
        assert gg1_waiting_time(ARRIVAL, SERVICE) == pytest.approx(W)

    def test_admits_full_resolution_when_deadline_covers_it(self):
        dec, res, est = AdmissionController.decide(
            0.07, 2, 0, 0.0, ARRIVAL, SERVICE, m=2, safety=1.0)
        assert (dec, res) == ("admitted", 2)
        assert est == pytest.approx(W + 0.04)

    def test_down_resolves_to_largest_fitting_resolution(self):
        # 0.06 < est(2)=0.0667 but >= est(1)=0.0567
        dec, res, est = AdmissionController.decide(
            0.06, 2, 0, 0.0, ARRIVAL, SERVICE, m=2, safety=1.0)
        assert (dec, res) == ("down-resolved", 1)
        assert est == pytest.approx(W + 0.03)

    def test_rejects_below_the_floor_estimate(self):
        # 0.03 < est(0)=0.0367: nothing fits; estimate reported is the
        # floor resolution's (what the client would have needed)
        dec, res, est = AdmissionController.decide(
            0.03, 2, 0, 0.0, ARRIVAL, SERVICE, m=2, safety=1.0)
        assert (dec, res) == ("rejected", -1)
        assert est == pytest.approx(W + 0.01)

    def test_min_resolution_forbids_the_cheap_escape(self):
        # 0.04 covers est(0)=0.0367 but the client insists on >= 1
        # (est(1)=0.0567 does not fit): reject, don't serve junk
        dec, res, _ = AdmissionController.decide(
            0.04, 2, 1, 0.0, ARRIVAL, SERVICE, m=2, safety=1.0)
        assert (dec, res) == ("rejected", -1)
        dec0, res0, _ = AdmissionController.decide(
            0.04, 2, 0, 0.0, ARRIVAL, SERVICE, m=2, safety=1.0)
        assert (dec0, res0) == ("down-resolved", 0)

    def test_backlog_shifts_every_estimate(self):
        # +21 ms backlog: est(2)=0.0877 and est(1)=0.0777 both exceed
        # 0.07, est(0)=0.0577 fits
        dec, res, est = AdmissionController.decide(
            0.07, 2, 0, 0.021, ARRIVAL, SERVICE, m=2, safety=1.0)
        assert (dec, res) == ("down-resolved", 0)
        assert est == pytest.approx(0.021 + W + 0.01)

    def test_safety_inflates_the_estimate(self):
        # safety 2: 2*est(2)=0.133 and 2*est(1)=0.113 exceed 0.1,
        # 2*est(0)=0.0733 fits
        dec, res, _ = AdmissionController.decide(
            0.1, 2, 0, 0.0, ARRIVAL, SERVICE, m=2, safety=2.0)
        assert (dec, res) == ("down-resolved", 0)

    def test_unstable_queue_rejects_everything(self):
        # rho >= 1: Marchal's W is +inf, no deadline can cover it
        slow = Moments(0.2, 0.08)
        assert gg1_waiting_time(ARRIVAL, slow) == float("inf")
        dec, res, est = AdmissionController.decide(
            1e9, 2, 0, 0.0, ARRIVAL, slow, m=2, safety=1.0)
        assert (dec, res) == ("rejected", -1)
        assert est == float("inf")

    def test_requested_below_full_starts_the_walk_there(self):
        dec, res, _ = AdmissionController.decide(
            0.07, 1, 0, 0.0, ARRIVAL, SERVICE, m=2, safety=1.0)
        assert (dec, res) == ("admitted", 1)


class TestAdmissionController:
    def test_unknown_policy_raises(self):
        with pytest.raises(ValueError):
            AdmissionController(_cfg(), policy="lottery")

    def test_priors_until_min_samples(self):
        ctl = AdmissionController(_cfg())
        prior = ctl.service_moments()
        for _ in range(MIN_SAMPLES - 1):
            ctl.note_service(10.0)
        assert ctl.service_moments() == prior       # still the prior
        ctl.note_service(10.0)
        assert ctl.service_moments().mean == pytest.approx(10.0)

    def test_arrival_gaps_are_consecutive_differences(self):
        ctl = AdmissionController(_cfg())
        for i in range(MIN_SAMPLES + 1):
            ctl.note_arrival(0.5 * i)
        assert ctl.arrival_moments().mean == pytest.approx(0.5)

    def test_policy_none_admits_at_requested(self):
        # even a 1 ns deadline is admitted: pure load-generation mode
        ctl = AdmissionController(_cfg(), policy="none")
        dec, res, est = ctl.admit(1e-9, 2, 0, 0.0)
        assert (dec, res) == ("admitted", 2)
        assert est > 0.0


class TestGatewayLifecycle:
    def test_open_stream_releases_in_order_with_exact_values(self, rng):
        cfg = _cfg()
        with ServingGateway(cfg, admission="none") as gw:
            tickets, oracles = [], []
            for _ in range(3):
                a, b = _operands(rng, cfg)
                oracles.append(a.T @ b)
                tickets.append(gw.submit(a, b, deadline=30.0))
        stats = gw.stats
        stats.reconcile()
        assert stats.submitted == stats.admitted == stats.released == 3
        assert stats.rejected == stats.degraded == 0
        full = cfg.num_layers - 1
        assert stats.release_histogram == {full: 3}
        for t, want in zip(tickets, oracles):
            assert t.done.is_set()
            assert t.released_resolution == full and not t.degraded
            # the decode reconstructs in float64: integer-exact after
            # rounding off the accumulated scaling roundoff
            np.testing.assert_array_equal(
                np.round(t.value()).astype(np.int64), want)
        # FIFO: the shared fleet serves the stream in arrival order
        starts = [t.result.service_started_at for t in tickets]
        assert all(s is not None for s in starts)
        assert starts == sorted(starts)
        arrivals = [t.arrival for t in tickets]
        assert arrivals == sorted(arrivals)

    def test_rejection_is_immediate_and_valueless(self, rng):
        cfg = _cfg()
        with ServingGateway(cfg, admission="gg1") as gw:
            a, b = _operands(rng, cfg)
            t = gw.submit(a, b, deadline=1e-9)
            # priced against the modeled priors: nothing fits 1 ns
            assert t.decision == "rejected" and not t.admitted
            assert t.done.is_set()          # no waiting on a rejection
            assert t.released_resolution == -1 and t.result is None
            with pytest.raises(RuntimeError):
                t.value()
        gw.stats.reconcile()
        assert gw.stats.rejected == 1 and gw.stats.released == 0

    def test_degraded_or_admitted_release_under_pressure(self, rng):
        """Tight deadlines: each admitted request is still answered, at
        >= its admitted resolution or explicitly marked degraded."""
        cfg = _cfg(straggler="exp")
        with ServingGateway(cfg, admission="none") as gw:
            tickets = []
            for _ in range(4):
                a, b = _operands(rng, cfg, k=64, n=8)
                tickets.append(gw.submit(a, b, deadline=2e-3))
        gw.stats.reconcile()
        for t in tickets:
            assert t.done.is_set()
            assert t.degraded == (
                t.released_resolution < t.admitted_resolution)
            if not t.degraded:
                assert t.released_resolution >= t.admitted_resolution

    def test_stop_is_idempotent_and_closes_admission(self, rng):
        cfg = _cfg()
        gw = ServingGateway(cfg, admission="none").start()
        a, b = _operands(rng, cfg)
        gw.submit(a, b, deadline=30.0)
        stats = gw.stop()
        assert gw.stop() is stats           # second stop: no-op
        with pytest.raises(RuntimeError):
            gw.submit(a, b, deadline=30.0)  # admission is closed

    def test_shutdown_leaves_no_gateway_or_fleet_threads(self, rng):
        cfg = _cfg()
        gw = ServingGateway(cfg, admission="none").start()
        a, b = _operands(rng, cfg)
        gw.submit(a, b, deadline=30.0)
        gw.stop()
        leaked = [th.name for th in threading.enumerate()
                  if th.name.startswith(("gateway-", "runtime-"))]
        assert leaked == [], leaked

    def test_start_twice_raises(self):
        gw = ServingGateway(_cfg(), admission="none").start()
        try:
            with pytest.raises(RuntimeError):
                gw.start()
        finally:
            gw.stop()

    def test_submit_validation(self, rng):
        cfg = _cfg()
        a, b = _operands(rng, cfg)
        with ServingGateway(cfg, admission="none") as gw:
            with pytest.raises(ValueError):
                gw.submit(a, b, deadline=0.0)
            with pytest.raises(ValueError):
                gw.submit(a, b, deadline=1.0, resolution=cfg.num_layers)
            with pytest.raises(ValueError):
                gw.submit(a, b, deadline=1.0, resolution=1,
                          min_resolution=2)

    def test_stats_counters_reconcile_midstream(self, rng):
        cfg = _cfg()
        with ServingGateway(cfg, admission="none") as gw:
            a, b = _operands(rng, cfg)
            t = gw.submit(a, b, deadline=30.0)
            gw.stats.reconcile()            # valid while still in flight
            t.wait(timeout=30.0)
        gw.stats.reconcile()


class TestGatewayProperties:
    """Seeded arbitrary arrival schedules against the gateway contract:
    no starvation (every ticket finalized), every admitted request
    released by its deadline (+scheduling slop) at >= its admitted
    resolution or marked degraded, counters reconcile with the event
    log exactly."""

    @hypothesis.given(seed=st.integers(0, 2**16 - 1))
    @hypothesis.settings(max_examples=5, deadline=None)
    def test_no_starvation_and_exact_accounting(self, seed):
        rng = np.random.default_rng(seed)
        # arrival prior of 5/s keeps the modeled queue stable (rho < 1)
        # so generous deadlines actually admit; tight ones still reject
        cfg = _cfg(seed=seed, arrival_rate=5.0)
        n = int(rng.integers(2, 7))
        gaps = rng.exponential(0.005, size=n).clip(0.0, 0.02)
        deadlines = rng.choice([0.002, 0.05, 5.0], size=n)
        with ServingGateway(cfg, admission="gg1", safety=1.0) as gw:
            tickets = []
            for i in range(n):
                time.sleep(float(gaps[i]))
                a, b = _operands(rng, cfg)
                tickets.append(
                    gw.submit(a, b, deadline=float(deadlines[i]),
                              min_resolution=0))
        stats = gw.stats
        stats.reconcile()
        assert stats.submitted == n
        assert stats.released == stats.admitted
        for t in tickets:
            assert t.done.is_set()          # nobody starves
            if not t.admitted:
                assert t.released_resolution == -1
                continue
            # released by the deadline (modulo drain-thread scheduling)
            # unless the job's own release beat it
            assert t.released_at is not None
            assert (t.released_at <= t.deadline_at + 0.25
                    or t.released_resolution >= t.admitted_resolution)
            # the release contract: admitted resolution or degraded
            assert (t.released_resolution >= t.admitted_resolution
                    or t.degraded)
            if t.released_resolution >= 0:
                assert t.released_resolution < cfg.num_layers
