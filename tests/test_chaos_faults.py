"""Property-style chaos suite for the survivable runtime.

Each case draws a *random* fault schedule (seeded — reruns are
reproducible) of worker kills, and revives on the backend that supports
them, against a traced ``fault_policy="degrade"`` run, then checks
invariants that must hold for **every** outcome — whether that schedule
happened to be absorbed, re-dispatched around, or collapsed the fleet:

1.  No hang: the run returns within a bounded join, whatever was killed.
2.  No exception: degrade mode never raises, it quarantines.
3.  Exact event <-> counter reconciliation (requires ``trace_dropped ==
    0``): every QUARANTINE trace event is one ``workers_lost`` and one
    ``fault_log`` quarantine entry; every STALE event is one
    ``stale_results``.
4.  Fused rounds fused from exactly ``k`` accepted results; un-fused
    rounds accepted fewer (the fusion node's RESULT/STALE split).
5.  A purged round never fused (ROUND spans labelled ``purged`` have no
    FUSED instant) — the §IV invariant fault handling must not bend.
6.  Every released resolution decode-verifies against the layered
    oracle; ``degraded`` jobs are a subset of ``terminated`` ones.

Deliberately *not* asserted: how many jobs succeed, whether the fleet
collapsed, or how often re-dispatch fired — those are schedule- and
host-timing-dependent outcomes, exactly what a chaos test must not pin.

The cases are timing-robust but multi-second (real SIGKILLs, real TCP
hosts); CI runs them in their own timeboxed step outside tier-1.
"""

import dataclasses
import multiprocessing
import os
import random
import signal
import threading
import time

import numpy as np
import pytest

from repro.runtime import RuntimeConfig, run_jobs, telemetry
from repro.runtime.transport.socket_host import LocalCluster

MU5 = (400.0, 650.0, 380.0, 420.0, 390.0)

FAULT_KINDS = {"quarantine", "readmit", "redispatch",
               "redispatch-exhausted", "fleet-collapse", "fleet-recovered"}


def _degrade_cfg(backend, hosts=None, seed=0):
    kw = dict(mu=MU5, arrival_rate=8.0, complexity=8.0, seed=seed,
              fault_policy="degrade", trace=True)
    if backend == "socket":
        kw.update(hosts=hosts, heartbeat_interval=0.2,
                  heartbeat_timeout=1.0, reconnect_attempts=1)
    return RuntimeConfig(backend=backend, **kw)


def _await_worker_processes(n, timeout=20.0):
    deadline = time.monotonic() + timeout
    while time.monotonic() < deadline:
        procs = [p for p in multiprocessing.active_children()
                 if p.name.startswith("runtime-proc-worker-")]
        if len(procs) >= n:
            return {int(p.name.rsplit("-", 1)[1]): p for p in procs}
        time.sleep(0.02)
    pytest.fail(f"{n} worker processes never appeared")


def _run_under_chaos(cfg, num_jobs, inject, join_timeout=120.0):
    """Drive the master in a background thread, apply ``inject()`` from
    this one; a hang or an exception is a failure of invariant 1/2."""
    holder: dict = {}

    def drive():
        try:
            holder["out"] = run_jobs(cfg, num_jobs, K=64, M=8, N=8,
                                     verify=True)
        except BaseException as e:
            holder["err"] = e

    t = threading.Thread(target=drive, daemon=True, name="chaos-driver")
    t.start()
    inject()
    t.join(join_timeout)
    if t.is_alive():
        pytest.fail(f"run hung >{join_timeout:.0f}s under chaos schedule")
    if "err" in holder:
        pytest.fail(f"degrade-mode run raised: {holder['err']!r}")
    return holder["out"]


def _check_invariants(res, cfg):
    """The outcome-agnostic contract (module docstring, invariants 3-6)."""
    assert res.fault_policy == "degrade"
    assert res.trace_dropped == 0, "ring overflow voids reconciliation"
    events = res.trace_events or []
    by_kind: dict = {}
    for ev in events:
        by_kind.setdefault(ev.kind, []).append(ev)

    # 3. event <-> counter reconciliation, exact
    quarantines = by_kind.get(telemetry.QUARANTINE, [])
    log_kinds = [e["kind"] for e in (res.fault_log or [])]
    assert len(quarantines) == res.workers_lost \
        == log_kinds.count("quarantine")
    assert set(log_kinds) <= FAULT_KINDS
    assert len(by_kind.get(telemetry.STALE, [])) == res.stale_results

    # 4. fused rounds accepted exactly k results, un-fused fewer
    accepted: dict = {}
    for ev in by_kind.get(telemetry.RESULT, []):
        accepted[(ev.job, ev.round)] = accepted.get((ev.job, ev.round),
                                                    0) + 1
    fused = {(ev.job, ev.round) for ev in by_kind.get(telemetry.FUSED, [])}
    for jr, count in accepted.items():
        if jr in fused:
            assert count == cfg.k, f"round {jr} fused from {count} != k"
        else:
            assert count < cfg.k, f"round {jr} never fused with {count} >= k"

    # 5. purged rounds never fused
    purged = {(ev.job, ev.round)
              for ev in by_kind.get(telemetry.ROUND, [])
              if ev.label == "purged"}
    assert not (purged & fused), f"purged rounds fused: {purged & fused}"

    # 6. releases verify; degraded only ever via termination
    assert res.degraded is not None
    assert res.terminated[res.degraded].all()
    errs = res.verify_errors[res.released >= 0]
    if errs.size:
        assert np.nanmax(errs) < 1e-9


@pytest.mark.parametrize("seed", (11, 23))
def test_process_chaos_random_kills(seed):
    """SIGKILL a random subset of process workers at random instants."""
    rng = random.Random(seed)
    cfg = _degrade_cfg("process", seed=seed)
    n_kills = rng.choice((1, 2))
    schedule = sorted(rng.uniform(0.3, 1.6) for _ in range(n_kills))
    victims = rng.sample(range(len(MU5)), n_kills)

    def inject():
        procs = _await_worker_processes(len(MU5))
        start = time.monotonic()
        for at, wid in zip(schedule, victims):
            time.sleep(max(0.0, start + at - time.monotonic()))
            os.kill(procs[wid].pid, signal.SIGKILL)

    res, _ = _run_under_chaos(cfg, 20, inject)
    assert res.workers_lost >= 1       # the schedule really landed
    _check_invariants(res, cfg)
    assert not [p.name for p in multiprocessing.active_children()
                if p.name.startswith("runtime-")]


def test_socket_chaos_kill_and_revive():
    """Kill a random socket host mid-run, revive it after a random
    pause: whatever the master absorbed — quarantine only, or a full
    readmission — the reconciliation invariants hold."""
    rng = random.Random(7)
    with LocalCluster(len(MU5)) as cluster:
        cfg = _degrade_cfg("socket", hosts=cluster.hosts, seed=7)
        victim = rng.randrange(len(MU5))
        kill_at = rng.uniform(0.8, 1.5)
        revive_after = rng.uniform(1.5, 2.5)

        def inject():
            time.sleep(kill_at)
            cluster.kill(victim)
            time.sleep(revive_after)
            cluster.revive(victim)

        res, _ = _run_under_chaos(cfg, 40, inject, join_timeout=180.0)
    assert res.workers_lost >= 1
    _check_invariants(res, cfg)
    assert not [t.name for t in threading.enumerate()
                if t.name.startswith("runtime-")]


def _hier_degrade_cfg(backend, hosts=None, seed=0):
    cfg = _degrade_cfg(backend, hosts=hosts, seed=seed)
    return dataclasses.replace(cfg, code_family="hierarchical", levels=2)


@pytest.mark.parametrize("backend", ("process", "socket"))
def test_hierarchical_chaos_salvage_ledger_holds(backend):
    """The sub-task-granular family under the same seeded chaos: the
    outcome-agnostic invariants 1-6 hold *unchanged* (invariant 4 reads
    "fused level rounds accepted exactly k sub-task results"), the
    salvage ledger stays well-formed — every accepted sub-task result is
    one RESULT event and the salvaged subset never exceeds it — and
    every released resolution decode-verifies, whatever mix of kills,
    re-dispatches, and (on socket) revives the schedule produced."""
    rng = random.Random(29)
    if backend == "process":
        cfg = _hier_degrade_cfg("process", seed=29)
        victims = rng.sample(range(len(MU5)), rng.choice((1, 2)))
        schedule = sorted(rng.uniform(0.3, 1.6) for _ in victims)

        def inject():
            procs = _await_worker_processes(len(MU5))
            start = time.monotonic()
            for at, wid in zip(schedule, victims):
                time.sleep(max(0.0, start + at - time.monotonic()))
                os.kill(procs[wid].pid, signal.SIGKILL)

        res, _ = _run_under_chaos(cfg, 20, inject)
    else:
        kill_at = rng.uniform(0.8, 1.5)
        revive_after = rng.uniform(1.5, 2.5)
        with LocalCluster(len(MU5)) as cluster:
            cfg = _hier_degrade_cfg("socket", hosts=cluster.hosts, seed=29)
            victim = rng.randrange(len(MU5))

            def inject():
                time.sleep(kill_at)
                cluster.kill(victim)
                time.sleep(revive_after)
                cluster.revive(victim)

            res, _ = _run_under_chaos(cfg, 40, inject, join_timeout=180.0)
    assert res.workers_lost >= 1       # the schedule really landed
    _check_invariants(res, cfg)
    stats = res.transport_stats
    n_results = sum(e.kind == telemetry.RESULT
                    for e in (res.trace_events or []))
    assert stats["subtask_results"] == n_results
    assert 0 <= stats["salvaged_subtasks"] <= stats["subtask_results"]
    released = res.released >= 0
    if released.any():
        assert np.nanmax(res.verify_errors[released]) < 1e-9
    assert not [p.name for p in multiprocessing.active_children()
                if p.name.startswith("runtime-")]
    assert not [t.name for t in threading.enumerate()
                if t.name.startswith("runtime-")]
