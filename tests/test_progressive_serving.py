"""Progressive (layered) serving: LayeredLinear, resolution series, the
deadline-bounded server, and the layered gradient all-reduce."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import AttentionConfig, ModelConfig
from repro.core import progressive
from repro.launch.serve import ProgressiveServer
from repro.models import transformer as T
from repro.optim import layered_grads


class TestLayeredLinear:
    def test_full_resolution_equals_quantized_product(self, rng):
        W = jnp.asarray(rng.normal(size=(32, 16)), jnp.float32)
        x = jnp.asarray(rng.normal(size=(4, 32)), jnp.float32)
        ll = progressive.make_layered_linear(W, m=3, d=5)
        full = progressive.layered_linear_apply(ll, x)
        # error bounded by quantization, not layering
        err = float(jnp.abs(full - x @ W).max())
        assert err < 0.05 * float(jnp.abs(x @ W).max()) + 1e-3

    def test_series_monotone_and_last_equals_full(self, rng):
        W = jnp.asarray(rng.normal(size=(16, 8)), jnp.float32)
        x = jnp.asarray(rng.normal(size=(3, 16)), jnp.float32)
        ll = progressive.make_layered_linear(W, m=4, d=4)
        series = progressive.resolution_series(ll, x)
        assert series.shape[0] == 4
        full = x @ W
        errs = [float(jnp.abs(series[l] - full).max()) for l in range(4)]
        assert all(a >= b for a, b in zip(errs, errs[1:])), errs
        np.testing.assert_allclose(
            np.asarray(series[-1]),
            np.asarray(progressive.layered_linear_apply(ll, x)), rtol=1e-5)

    def test_two_sided_layering_num_layers(self, rng):
        x = jnp.asarray(rng.normal(size=(3, 8)), jnp.float32)
        W = jnp.asarray(rng.normal(size=(8, 6)), jnp.float32)
        out = progressive.two_sided_layered_matmul(x, W, m=3, d=5)
        assert out.shape == (5, 3, 6)  # L = 2m-1
        errs = [float(jnp.abs(out[l] - x @ W).max()) for l in range(5)]
        assert errs[0] >= errs[-1]

    def test_resolution_out_of_range(self, rng):
        ll = progressive.make_layered_linear(jnp.eye(4), m=2, d=4)
        with pytest.raises(ValueError):
            progressive.layered_linear_apply(ll, jnp.ones((1, 4)),
                                             resolution=5)


class TestProgressiveServer:
    def _setup(self, rng):
        cfg = ModelConfig(
            name="t", family="dense", num_layers=2, d_model=32, d_ff=64,
            vocab_size=128, compute_dtype="float32",
            attention=AttentionConfig(num_heads=2, num_kv_heads=1,
                                      head_dim=16))
        params = T.init_params(jax.random.PRNGKey(0), cfg)
        server = ProgressiveServer(cfg, params, m=3, d=5)
        toks = jnp.asarray(rng.integers(0, 128, (2, 8)), jnp.int32)
        return cfg, params, server, toks

    def test_full_budget_matches_reference_decode(self, rng):
        cfg, params, server, toks = self._setup(rng)
        _, caches = server.prefill(toks, max_len=16)
        out, stats = server.decode(toks[:, -1:], caches, 8, 4)
        assert out.shape == (2, 4)
        assert stats.full_resolution == stats.steps == 4
        # compare against plain greedy decode (within quantization slack:
        # argmax can differ only when top-2 logits are within quant error)
        _, caches2 = T.prefill(params, toks, cfg, max_len=16)
        tok = toks[:, -1:]
        agree = 0
        for i in range(4):
            logits, caches2 = T.decode_step(params, tok, caches2,
                                            jnp.int32(8 + i), cfg)
            tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
            agree += int((np.asarray(tok[:, 0]) ==
                          np.asarray(out[:, i])).mean() == 1.0)
        assert agree >= 3  # near-perfect agreement at full resolution

    def test_budget_one_still_generates(self, rng):
        cfg, params, server, toks = self._setup(rng)
        _, caches = server.prefill(toks, max_len=16)
        out, stats = server.decode(toks[:, -1:], caches, 8, 4,
                                   layer_budget=1)
        assert out.shape == (2, 4)
        assert stats.full_resolution == 0
        assert all(r == 1 for r in stats.released_at_layer)

    def test_deadline_ms_bounds_compute(self, rng):
        """The wall-clock deadline path runs each head step as a runtime
        job: an already-expired deadline releases ONLY the guaranteed
        resolution-0 minimum, and a generous one reaches the full
        ``L = 2m - 1`` layered resolution and agrees with the
        non-deadline decode (up to two-sided quantization)."""
        cfg, params, server, toks = self._setup(rng)
        with server:
            _, caches = server.prefill(toks, max_len=16)
            out, stats = server.decode(toks[:, -1:], caches, 8, 4,
                                       deadline_ms=0.0)
            assert out.shape == (2, 4)
            assert stats.resolutions == 2 * server.m - 1
            assert stats.released_at_layer == [1] * 4
            assert stats.full_resolution == 0
            assert len(stats.head_service_seconds) == 4

            _, caches = server.prefill(toks, max_len=16)
            out_full, stats_full = server.decode(toks[:, -1:], caches, 8, 4,
                                                 deadline_ms=1e9)
            assert (stats_full.released_at_layer
                    == [2 * server.m - 1] * 4)
            assert stats_full.full_resolution == 4
            _, caches = server.prefill(toks, max_len=16)
            out_ref, _ = server.decode(toks[:, -1:], caches, 8, 4)
            # the runtime head decomposes BOTH operands (the reference
            # path only layers W), so argmax can drift on near-ties:
            # demand near-perfect agreement, not identity
            agree = int((np.asarray(out_full)
                         == np.asarray(out_ref)).mean() * 8)
            assert agree >= 6, (np.asarray(out_full), np.asarray(out_ref))

    def test_deeper_budget_closer_to_full(self, rng):
        """Fraction of tokens agreeing with the full-resolution decode
        increases with the layer budget (the paper's quality/deadline
        trade-off, on-chip)."""
        cfg, params, server, toks = self._setup(rng)
        _, c0 = server.prefill(toks, max_len=32)
        full, _ = server.decode(toks[:, -1:], c0, 8, 8)
        agreements = []
        for budget in (1, 2, 3):
            _, c = server.prefill(toks, max_len=32)
            out, _ = server.decode(toks[:, -1:], c, 8, 8,
                                   layer_budget=budget)
            agreements.append(
                float((np.asarray(out) == np.asarray(full)).mean()))
        assert agreements[-1] >= agreements[0]


class TestLayeredGradAllreduce:
    def test_plane_roundtrip(self, rng):
        g = jnp.asarray(rng.normal(size=(8, 8)), jnp.float32)
        planes, scale = layered_grads.plane_split(g, m=3, d=5)
        rec = layered_grads.plane_reconstruct(planes, scale, d=5)
        assert float(jnp.abs(rec - g).max()) < float(scale) + 1e-6

    def test_partial_reconstruction_monotone(self, rng):
        g = jnp.asarray(rng.normal(size=(16,)), jnp.float32)
        planes, scale = layered_grads.plane_split(g, m=4, d=4)
        errs = []
        for l in range(4):
            rec = layered_grads.plane_reconstruct(planes, scale, d=4,
                                                  up_to_plane=l)
            errs.append(float(jnp.abs(rec - g).max()))
        assert all(a >= b for a, b in zip(errs, errs[1:])), errs

    def test_single_device_allreduce_tree(self, rng):
        """On a 1-device mesh the layered mean == the gradient itself."""
        from repro.launch.mesh import make_test_mesh
        mesh = make_test_mesh(1, 1)
        g = {"w": jnp.asarray(rng.normal(size=(1, 8, 8)), jnp.float32)}
        out = layered_grads.layered_allreduce_tree(g, mesh, "data", m=2,
                                                   d=8)
        err = float(jnp.abs(out["w"] - g["w"]).max())
        scale = float(jnp.abs(g["w"]).max()) / (2**15 - 1)
        assert err <= scale * 2

    def test_layered_psum_emits_per_plane_collectives(self, rng):
        """The traced program issues one psum per plane (the layered
        collective schedule the paper's deadline semantics need).  On a
        1-device mesh XLA elides the wire op, so we check the jaxpr."""
        import jax
        from jax.sharding import PartitionSpec as P
        from repro.compat import shard_map
        from repro.launch.mesh import make_test_mesh

        mesh = make_test_mesh(1, 1)
        m = 3

        def fn(planes):
            return shard_map(
                lambda p: layered_grads.layered_psum(p, "data"),
                mesh=mesh, in_specs=P(None, "data"),
                out_specs=P(None, "data"))(planes)

        jaxpr = str(jax.make_jaxpr(fn)(
            jnp.zeros((m, 4, 4), jnp.float32)))
        assert jaxpr.count("psum") >= m
