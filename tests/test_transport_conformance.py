"""Backend-conformance suite: every worker transport obeys one contract.

The runtime's correctness claims (§IV semantics, simulator agreement,
adaptive-ω behavior) must hold over *any* transport, not just the thread
pool they were first built on.  This file parametrizes the load-bearing
runtime tests over ``backend in {thread, process, socket}`` — the ``jax``
backend is smoke-only (CPU has one device; its transport loop is the
thread backend's) — plus transport-level contract tests: wire-form round
trips, purge watermarks, and leak-free drain-or-purge shutdown.

The ``socket`` backend runs against a session-scoped
:class:`~repro.runtime.transport.socket_host.LocalCluster` of real worker
host processes on localhost ports — purges, liveness, and shutdown all
cross a TCP connection.  Its *fault-injection* cases (SIGKILL a host,
sever a connection mid-round) spawn private clusters so they cannot
poison the shared one.

End-to-end cases run real workers (threads, OS processes, or TCP worker
hosts) with real coded matmuls; keep delay scales well above per-round
overhead so the measured statistics are about the system, not the
container's timer.
"""

import collections
import dataclasses
import multiprocessing
import os
import signal
import threading
import time

import numpy as np
import pytest

from repro.core import simulator
from repro.runtime import (BACKENDS, FusionNode, RoundContext, RuntimeConfig,
                           TaskResult, TransportDeadError, WireBatch,
                           make_transport, run_jobs)
from repro.runtime import telemetry
from repro.runtime.transport import shm as shm_mod
from repro.runtime.transport.socket_host import LocalCluster

MU3 = (400.0, 650.0, 380.0)
#: five-worker fleet for the degrade-policy scenarios: k = 4, so one
#: SIGKILL is the ISSUE's "n - k workers" budget and two drop below k.
MU5 = (400.0, 650.0, 380.0, 420.0, 390.0)
BACKENDS_FULL = ("thread", "process", "socket")
#: wire-path rows: ``shm`` is the process backend with the shared-memory
#: arena forced on (``process`` rows pin it off so both wire paths stay
#: covered); it is a *config* of the process transport, not a registry
#: entry, so :func:`bcfg` translates it.
BACKENDS_WIRE = ("thread", "process", "shm", "socket")


def _real_backend(backend: str) -> str:
    return "process" if backend == "shm" else backend


@pytest.fixture(scope="session")
def socket_cluster():
    """One LocalCluster for every socket-parametrized case: worker hosts
    serve sessions in a loop, so sequential runs just reuse them."""
    with LocalCluster(len(MU3)) as cluster:
        yield cluster


@pytest.fixture
def bcfg(request):
    """Config factory that knows how to target the shared socket cluster."""

    def make(backend, **kw):
        kw.setdefault("mu", MU3)
        if backend == "shm":
            backend = "process"
            kw.setdefault("shm", "on")
        elif backend == "process":
            kw.setdefault("shm", "off")
        elif backend == "socket":
            kw.setdefault(
                "hosts", request.getfixturevalue("socket_cluster").hosts)
        return RuntimeConfig(backend=backend, **kw)

    return make


def _cfg(**kw):
    kw.setdefault("mu", MU3)
    return RuntimeConfig(**kw)


#: backend -> measured res-0 delay (s) in the deadline scenario's stall
#: regime, deadline-free — cached once per session per backend.
_ROUND_BASELINE: dict = {}


def _round_baseline(backend, bcfg) -> float:
    """Measure how long one fused round actually takes on this machine.

    The §IV deadline case below needs a deadline that res-0 (one round)
    comfortably makes and the final resolution (m² rounds) reliably
    misses.  A fixed constant encodes one machine's speed; on a loaded CI
    container the same 30 ms can cost res-0 too and flake.  So run the
    identical stall regime without a deadline and read off the mean
    res-0 *compute* time — ``layer_compute[:, 0]``, seconds from service
    start, the same clock the deadline is measured on (delay would also
    count queueing wait, which the deadline does not) — the natural
    margin unit for that backend on this host.
    """
    if backend not in _ROUND_BASELINE:
        cfg = bcfg(backend, arrival_rate=14.0, complexity=8.0,
                   straggler="stall", stall_workers=(2,),
                   stall_seconds=2.0, seed=1)
        res, _ = run_jobs(cfg, num_jobs=6, K=64, M=8, N=8)
        _ROUND_BASELINE[backend] = float(res.layer_compute[:, 0].mean())
    return _ROUND_BASELINE[backend]


def _await_worker_processes(n, timeout=20.0) -> dict:
    """Wait for the master's ``n`` spawned worker processes; returns
    ``{worker_id: Process}`` so fault injection can pick its victim."""
    deadline = time.monotonic() + timeout
    while time.monotonic() < deadline:
        procs = [p for p in multiprocessing.active_children()
                 if p.name.startswith("runtime-proc-worker-")]
        if len(procs) >= n:
            return {int(p.name.rsplit("-", 1)[1]): p for p in procs}
        time.sleep(0.02)
    pytest.fail(f"{n} worker processes never appeared")


def _run_with_faults(cfg, num_jobs, inject, join_timeout=120.0):
    """Run the master in a background thread while ``inject()`` applies a
    fault schedule from this one.

    A hang is the worst possible outcome of the survivable-runtime
    contract, so it is converted into a test failure here (bounded
    ``join``) rather than left to the CI-level timeout.  Exceptions the
    run raises are re-raised in the test thread.
    """
    holder: dict = {}

    def drive():
        try:
            holder["out"] = run_jobs(cfg, num_jobs, K=64, M=8, N=8,
                                     verify=True)
        except BaseException as e:
            holder["err"] = e

    t = threading.Thread(target=drive, daemon=True, name="fault-driver")
    t.start()
    inject()
    t.join(join_timeout)
    if t.is_alive():
        pytest.fail(f"run hung >{join_timeout:.0f}s under fault injection")
    if "err" in holder:
        raise holder["err"]
    return holder["out"]


def _runtime_worker_threads() -> list[str]:
    return [t.name for t in threading.enumerate()
            if t.name.startswith("runtime-")]


def _runtime_worker_processes() -> list[str]:
    return [p.name for p in multiprocessing.active_children()
            if p.name.startswith("runtime-")]


class TestRegistry:
    def test_registry_names_match_config_surface(self):
        assert set(BACKENDS) == {"thread", "process", "jax", "socket"}
        for name, cls in BACKENDS.items():
            assert cls.name == name

    def test_unknown_backend_rejected_at_config(self):
        with pytest.raises(ValueError, match="backend"):
            _cfg(backend="rpc")

    def test_legacy_jax_flag_upgrades_to_jax_backend(self):
        cfg = _cfg(straggler="none", use_jax_devices=True)
        transport = make_transport(cfg, sink=lambda r: None)
        assert transport.name == "jax"

    def test_legacy_jax_flag_conflicts_with_other_backend(self):
        """The alias only upgrades the default thread selection; with an
        explicit other backend it must be rejected, not ignored."""
        with pytest.raises(ValueError, match="use_jax_devices"):
            _cfg(backend="process", use_jax_devices=True)
        _cfg(backend="jax", use_jax_devices=True)   # redundant but fine

    def test_socket_backend_config_validation(self):
        """hosts are required (one per worker), well-formed, and rejected
        with any other backend rather than silently ignored."""
        with pytest.raises(ValueError, match="host:port per worker"):
            _cfg(backend="socket")
        with pytest.raises(ValueError, match="host:port per worker"):
            _cfg(backend="socket", hosts=("127.0.0.1:1",))   # 1 for 3
        with pytest.raises(ValueError, match="not of the form"):
            _cfg(backend="socket", hosts=("a:1", "b:2", "noport"))
        with pytest.raises(ValueError, match="only meaningful"):
            _cfg(backend="thread", hosts=("127.0.0.1:1",) * 3)
        with pytest.raises(ValueError, match="compress"):
            _cfg(compress="gzip")
        _cfg(backend="socket", hosts=("a:1", "b:2", "c:3"))   # valid


class TestWireForms:
    def test_round_batch_wire_round_trip(self):
        ctx = RoundContext(job_id=3, round_idx=1)
        ctx.seq = 17
        X = np.arange(48, dtype=np.float64).reshape(6, 4, 2)
        wire = WireBatch(seq=ctx.seq, job_id=ctx.job_id,
                         round_idx=ctx.round_idx, first_task_id=2,
                         x=X[2:4], y=X[4:6], delays=np.zeros(2))
        import pickle

        back = pickle.loads(pickle.dumps(wire))
        assert (back.seq, back.job_id, back.round_idx) == (17, 3, 1)
        assert back.count == 2
        np.testing.assert_array_equal(back.x, X[2:4])
        # pickling a view must serialize just the slice, not the base
        assert back.x.base is None or back.x.base.shape == back.x.shape

    def test_task_result_wire_round_trip(self):
        r = TaskResult(job_id=1, round_idx=2, task_id=3, worker_id=4,
                       value=np.eye(2), finished_at=5.5)
        back = TaskResult.from_wire(r.to_wire())
        assert back == dataclasses.replace(r, value=back.value)
        np.testing.assert_array_equal(back.value, r.value)


@pytest.mark.parametrize("backend", BACKENDS_WIRE)
class TestTransportContract:
    """Direct transport-level checks, no master loop involved."""

    def _round_trip(self, backend, cfg, kappa=None):
        """Submit one coded round through the bare transport; fuse + decode."""
        code = cfg.code()
        rng = np.random.default_rng(0)
        a = rng.integers(0, 255, size=(32, 8)).astype(np.float64)
        b = rng.integers(0, 255, size=(32, 8)).astype(np.float64)
        X, Y = code.encode(a, b)
        fusion = FusionNode()
        transport = make_transport(cfg, sink=fusion.post)
        transport.start()
        try:
            ctx = RoundContext(job_id=0, round_idx=0)
            rf = fusion.begin_round(ctx, code.k)
            transport.submit_round(ctx, np.asarray(X), np.asarray(Y),
                                   cfg.load_split() if kappa is None
                                   else kappa)
            assert rf.wait(timeout=30.0), "round never fused"
            transport.purge_round(ctx)
            np.testing.assert_allclose(rf.decode(code), a.T @ b,
                                       rtol=1e-9, atol=1e-6)
        finally:
            transport.shutdown()

    def test_round_trip_fuses_and_decodes(self, backend, bcfg):
        self._round_trip(backend, bcfg(backend, straggler="none"))

    def test_seq_stamped_monotonic(self, backend, bcfg):
        cfg = bcfg(backend, straggler="none")
        fusion = FusionNode()
        transport = make_transport(cfg, sink=fusion.post)
        transport.start()
        try:
            code = cfg.code()
            X = np.zeros((cfg.total_tasks, 8, 4))
            seqs = []
            for r in range(3):
                ctx = RoundContext(0, r)
                fusion.begin_round(ctx, code.k)
                transport.submit_round(ctx, X, X, cfg.load_split())
                seqs.append(ctx.seq)
                transport.purge_round(ctx)
            assert seqs == sorted(seqs) and len(set(seqs)) == 3
        finally:
            transport.shutdown()

    def test_purge_reclaims_delayed_workers_immediately(self, backend, bcfg):
        """A purge must interrupt a multi-second injected delay at once:
        the next round's fuse proves the workers came back."""
        cfg = bcfg(backend, straggler="stall", stall_workers=(0, 1, 2),
                   stall_seconds=30.0)
        fusion = FusionNode()
        transport = make_transport(cfg, sink=fusion.post)
        transport.start()
        try:
            code = cfg.code()
            rng = np.random.default_rng(1)
            a = rng.integers(0, 9, size=(16, 4)).astype(np.float64)
            b = rng.integers(0, 9, size=(16, 4)).astype(np.float64)
            X, Y = code.encode(a, b)
            # round 0: every worker stalls 30 s; purge instead of waiting
            ctx0 = RoundContext(0, 0)
            rf0 = fusion.begin_round(ctx0, code.k)
            transport.submit_round(ctx0, np.asarray(X), np.asarray(Y),
                                   cfg.load_split())
            time.sleep(0.05)
            t0 = time.monotonic()
            transport.purge_round(ctx0)
            assert not rf0.wait(timeout=0.0)
            # round 1 (no injected delay) fuses fast only if the purge
            # actually reclaimed the stalled workers
            cfg1 = dataclasses.replace(cfg, straggler="none")
            del cfg1  # delays are per-batch: submit with explicit zeros
            ctx1 = RoundContext(0, 1)
            rf1 = fusion.begin_round(ctx1, code.k)
            kappa = cfg.load_split()
            zero_delays = [np.zeros(int(k)) for k in kappa]
            transport.submit_round(ctx1, np.asarray(X), np.asarray(Y),
                                   kappa, delays=zero_delays)
            assert rf1.wait(timeout=10.0), "purged workers never reclaimed"
            reclaim = time.monotonic() - t0
            assert reclaim < 5.0, f"reclaim took {reclaim:.2f}s"
            transport.purge_round(ctx1)
        finally:
            transport.shutdown()

    def test_shutdown_leaks_nothing(self, backend, bcfg):
        cfg = bcfg(backend, straggler="none")
        transport = make_transport(cfg, sink=lambda r: None)
        transport.start()
        transport.shutdown()
        assert not _runtime_worker_threads()
        assert not _runtime_worker_processes()

    def test_purge_mode_shutdown_reclaims_inflight_round(self, backend, bcfg):
        """The ISSUE bugfix: shutting down with an un-purged, delay-bound
        round in flight must neither hang nor leak — queued tasks are
        deterministically counted as purged."""
        cfg = bcfg(backend, straggler="stall", stall_workers=(0, 1, 2),
                   stall_seconds=30.0)
        fusion = FusionNode()
        transport = make_transport(cfg, sink=fusion.post)
        transport.start()
        code = cfg.code()
        X = np.zeros((cfg.total_tasks, 8, 4))
        ctx = RoundContext(0, 0)
        fusion.begin_round(ctx, code.k)
        transport.submit_round(ctx, X, X, cfg.load_split())
        time.sleep(0.05)
        t0 = time.monotonic()
        transport.shutdown(timeout=10.0)   # never purged: drain=False path
        assert time.monotonic() - t0 < 5.0, "shutdown blocked on a stall"
        assert transport.tasks_purged == cfg.total_tasks
        assert transport.tasks_done == 0
        assert not _runtime_worker_threads()
        assert not _runtime_worker_processes()


@pytest.mark.parametrize("backend", BACKENDS_WIRE)
class TestEndToEndConformance:
    """The load-bearing runtime tests, identical over every backend."""

    def test_completes_and_decode_verifies(self, backend, bcfg):
        cfg = bcfg(backend, arrival_rate=100.0, complexity=0.2,
                   straggler="none", seed=0)
        res, futures = run_jobs(cfg, num_jobs=6, K=64, M=8, N=8, verify=True)
        assert res.backend == _real_backend(backend)
        if backend == "shm":
            # the zero-copy path actually carried the run
            assert res.transport_stats["shm_active"]
            assert res.transport_stats["arena_rounds"] > 0
        assert res.success.all()
        assert (res.released == cfg.num_layers - 1).all()
        assert not res.terminated.any()
        assert np.nanmax(res.verify_errors) < 1e-9
        assert not _runtime_worker_threads()
        assert not _runtime_worker_processes()

    def test_deadline_releases_verified_lower_resolution(self, backend, bcfg):
        """The §IV acceptance scenario per backend: a straggler plus a
        deadline the final resolution misses still releases a correct
        lower resolution, MSB-first delays ordered.

        The deadline is derived from a measured per-round baseline
        (:func:`_round_baseline`), not a wall-clock constant: 2.2x the
        deadline-free res-0 delay sits between one round (res-0, ~1x)
        and the final resolution (m^2 = 4 rounds, ~4x) whatever the host
        speed, where a fixed 30 ms flaked on loaded containers.

        Thresholds still carry slack (res-0 >= 0.9, not == 1.0): a tight
        deadline on a loaded container can cost an occasional
        res-0 — the claim under test is the qualitative §IV gap between
        res-0 and the final resolution, not a hard-real-time guarantee."""
        deadline = max(0.030, 2.2 * _round_baseline(backend, bcfg))
        cfg = bcfg(backend, arrival_rate=14.0, complexity=8.0,
                   deadline=deadline, straggler="stall", stall_workers=(2,),
                   stall_seconds=2.0, seed=0)
        res, _ = run_jobs(cfg, num_jobs=20, K=64, M=8, N=8, verify=True)
        assert res.terminated.any()
        sr = res.success_rate()
        assert sr[0] >= 0.9
        assert sr[-1] < 1.0 and sr[-1] < sr[0]
        term = np.flatnonzero(res.terminated)
        assert (res.released[term] >= 0).mean() >= 0.9   # partials shipped
        assert np.nanmax(res.verify_errors) < 1e-9
        assert np.all(np.diff(res.mean_delay()) > 0)

    def test_runtime_agrees_with_simulator(self, backend, bcfg):
        """Measured mean res-0 delay under exp stragglers agrees with
        simulate() on the same configuration — over any transport.

        Sized for the low-utilization regime (~37 ms/task delays,
        inter-arrival >> service): queueing amplifies *any* per-round
        overhead nonlinearly, and the process backend's IPC latency on a
        small container is ~2-3 ms/round of scheduler wake-ups, so the
        comparison must be about the order statistic the simulator
        models, not about M/G/1 sensitivity to the container's core
        count.  At this scale both backends sit within a few percent of
        the simulator (dev container: thread ~0.97x, process ~1.02x)."""
        cfg = bcfg(backend, arrival_rate=0.8, complexity=60.0,
                   straggler="exp", seed=2)
        res, _ = run_jobs(cfg, num_jobs=8, K=64, M=8, N=8)
        sim = simulator.simulate(cfg.to_system_config(), 4000, layered=True,
                                 seed=7)
        md, sd = res.mean_delay(), sim.mean_delay()
        assert md[0] == pytest.approx(sd[0], rel=0.30)
        assert np.all(np.diff(md) > 0) and np.all(np.diff(sd) > 0)

    def test_adaptive_omega_signals_travel(self, backend, bcfg):
        """The ROADMAP transport-agnostic claim: RoundObservation signals
        (wait/stale/margin/utilization) drive the same ω retune loop over
        any backend — the regime-shift scenario recovers res-0 success."""
        base = bcfg(backend, arrival_rate=14.0, omega=1.0,
                    complexity=8.0, deadline=0.04, straggler="shift",
                    stall_workers=(2,), shift_at=0.6, stall_seconds=1.0,
                    adapt="fixed", seed=0)
        worst, _ = run_jobs(base, 24, K=64, M=8, N=8)
        adapt_cfg = dataclasses.replace(base, adapt="deadline-margin")
        adapt, _ = run_jobs(adapt_cfg, 24, K=64, M=8, N=8)
        sr_worst = worst.success_rate()[0]
        sr_adapt = adapt.success_rate()[0]
        assert sr_worst < 0.85           # the outage really binds at T = k
        assert sr_adapt >= sr_worst + 0.15
        ctl = adapt.controller
        assert ctl["switches"] >= 1 and ctl["omega_final"] > 1.0
        # utilization signal arrived over the transport (non-degenerate)
        assert adapt.worker_busy.shape == (len(MU3),)
        assert adapt.worker_busy.sum() > 0.0


class TestProcessLiveness:
    """A lost worker process must fail the run promptly, never hang it."""

    def test_dead_worker_raises_promptly(self):
        cfg = _cfg(backend="process", straggler="none")
        transport = make_transport(cfg, sink=lambda r: None)
        transport.start()
        try:
            transport.assert_alive()            # healthy: no-op
            victim = transport.processes[0]
            victim.terminate()                  # an OOM-kill stand-in
            victim.join(timeout=5.0)
            with pytest.raises(RuntimeError, match="died"):
                transport.assert_alive()
        finally:
            transport.shutdown()
        assert not _runtime_worker_processes()


class TestSocketFaults:
    """Fault injection against the socket backend: a dead host fails the
    run promptly, a severed connection recovers, and in neither case may
    fusion hang.  Each case owns a private LocalCluster — the injected
    faults would poison the session-shared one."""

    def _stalled_round(self, cluster):
        """A transport with one all-workers-stalled round in flight."""
        cfg = _cfg(backend="socket", hosts=cluster.hosts, straggler="stall",
                   stall_workers=(0, 1, 2), stall_seconds=30.0)
        fusion = FusionNode()
        transport = make_transport(cfg, sink=fusion.post)
        transport.start()
        code = cfg.code()
        rng = np.random.default_rng(1)
        a = rng.integers(0, 9, size=(16, 4)).astype(np.float64)
        b = rng.integers(0, 9, size=(16, 4)).astype(np.float64)
        X, Y = code.encode(a, b)
        ctx = RoundContext(0, 0)
        rf = fusion.begin_round(ctx, code.k)
        transport.submit_round(ctx, np.asarray(X), np.asarray(Y),
                               cfg.load_split())
        time.sleep(0.1)
        return transport, fusion, code, (a, b, X, Y), ctx, rf

    def test_sigkill_worker_host_fails_run_promptly(self):
        """SIGKILL a worker host mid-round: assert_alive must raise
        within seconds (EOF -> reconnect-or-fail), and the in-flight
        round must not hang fusion."""
        with LocalCluster(len(MU3)) as cluster:
            transport, fusion, code, _, ctx, rf = self._stalled_round(
                cluster)
            try:
                transport.assert_alive()          # healthy: no-op
                t0 = time.monotonic()
                cluster.kill(0)                   # SIGKILL, no goodbye
                deadline = t0 + 10.0
                while time.monotonic() < deadline:
                    try:
                        transport.assert_alive()
                    except RuntimeError as e:
                        assert "died" in str(e)
                        break
                    time.sleep(0.05)
                else:
                    pytest.fail("dead host never detected")
                detect = time.monotonic() - t0
                assert detect < 8.0, f"detection took {detect:.1f}s"
                assert not rf.wait(timeout=0.0)   # round is dead, not hung
                transport.purge_round(ctx)
            finally:
                # shutdown with a dead member must neither hang nor leak;
                # it may report the host that cannot answer
                try:
                    transport.shutdown(timeout=8.0)
                except RuntimeError as e:
                    assert "worker" in str(e)
            assert not _runtime_worker_threads()

    def test_severed_connection_purge_watermark_clears_round(self):
        """Sever connections during result return: the transport
        reconnects, the re-sent hello carries the purge watermark, and
        the next round fuses fast — the stalled round never zombies."""
        with LocalCluster(len(MU3)) as cluster:
            transport, fusion, code, (a, b, X, Y), ctx0, rf0 = \
                self._stalled_round(cluster)
            try:
                transport.sever_for_test(0)
                transport.sever_for_test(1)
                t0 = time.monotonic()
                transport.purge_round(ctx0)       # watermark rides hello
                assert not rf0.wait(timeout=0.0)
                ctx1 = RoundContext(0, 1)
                rf1 = fusion.begin_round(ctx1, code.k)
                kappa = transport._cfg.load_split()
                zero = [np.zeros(int(k)) for k in kappa]
                transport.submit_round(ctx1, np.asarray(X), np.asarray(Y),
                                       kappa, delays=zero)
                assert rf1.wait(timeout=10.0), \
                    "round after sever never fused"
                recover = time.monotonic() - t0
                assert recover < 5.0, f"recovery took {recover:.2f}s"
                transport.purge_round(ctx1)
                np.testing.assert_allclose(rf1.decode(code), a.T @ b,
                                           rtol=1e-9, atol=1e-6)
                transport.assert_alive()          # reconnected, not dead
            finally:
                transport.shutdown(timeout=8.0)
            assert not _runtime_worker_threads()


class TestDegradeConformance:
    """The survivable-runtime acceptance scenarios: under
    ``fault_policy="degrade"``, SIGKILLing workers mid-run must end in a
    decode-verified completion (``n - k`` kills) or a prompt degraded
    release (below-``k`` kills) — never a hang, never an exception.
    Process-backend workers are killed with a real ``SIGKILL`` (no
    cleanup handlers run); socket cases own a private 5-host cluster."""

    def _degrade_cfg(self, backend, hosts=None, **kw):
        kw.setdefault("mu", MU5)
        kw.setdefault("arrival_rate", 8.0)
        kw.setdefault("complexity", 8.0)
        kw.setdefault("fault_policy", "degrade")
        kw.setdefault("seed", 3)
        if backend == "socket":
            # fast liveness knobs: detection within ~1 s, single re-dial
            kw.setdefault("heartbeat_interval", 0.2)
            kw.setdefault("heartbeat_timeout", 1.0)
            kw.setdefault("reconnect_attempts", 1)
            kw["hosts"] = hosts
        return RuntimeConfig(backend=backend, **kw)

    def test_process_sigkill_n_minus_k_completes_verified(self):
        """The headline acceptance: kill ``n - k = 1`` of 5 process
        workers mid-run; the run completes every job at full resolution,
        decode-verified, with the loss in the fault log — zero
        exceptions, zero degraded releases."""
        cfg = self._degrade_cfg("process")

        def inject():
            procs = _await_worker_processes(len(MU5))
            time.sleep(0.5)
            os.kill(procs[1].pid, signal.SIGKILL)

        res, _ = _run_with_faults(cfg, 20, inject)
        assert res.fault_policy == "degrade"
        assert res.workers_lost == 1
        kinds = [e["kind"] for e in res.fault_log]
        assert kinds.count("quarantine") == 1
        assert res.success.all()
        assert not res.degraded.any()
        assert (res.released == cfg.num_layers - 1).all()
        assert np.nanmax(res.verify_errors) < 1e-9
        assert not _runtime_worker_processes()

    def test_process_shm_sigkill_completes_and_leaks_no_segments(self):
        """The zero-copy wire path under the same headline kill: a worker
        SIGKILLed while it holds live arena slots must not cost
        correctness (degrade absorbs the loss, decode verifies) nor leak
        a single ``/dev/shm`` segment — the master owns and unlinks every
        arena, dead attacher or not."""
        cfg = self._degrade_cfg("process", shm="on")
        prefix = f"lra-{os.getpid():x}-"

        def inject():
            procs = _await_worker_processes(len(MU5))
            time.sleep(0.5)
            os.kill(procs[1].pid, signal.SIGKILL)

        res, _ = _run_with_faults(cfg, 20, inject)
        assert res.workers_lost == 1
        assert res.success.all()
        assert not res.degraded.any()
        assert np.nanmax(res.verify_errors) < 1e-9
        assert res.transport_stats["shm_active"]
        assert res.transport_stats["arena_rounds"] > 0
        assert shm_mod.leaked_segments(prefix) == []
        assert not _runtime_worker_processes()

    def test_process_res0_deadline_success_survives_kill(self):
        """Acceptance: res-0 deadline success is *unchanged* while the
        fleet absorbs an ``n - k`` kill — the proportional geometry refit
        must keep ``T > k`` spare so the stalled survivor's tasks still
        purge instead of gating every round.  Deadline derived from a
        measured deadline-free baseline of the same regime (the same
        calibration the tier-1 deadline test uses)."""
        probe = self._degrade_cfg("process", arrival_rate=14.0,
                                  straggler="stall", stall_workers=(2,),
                                  stall_seconds=2.0, seed=1)
        base_res, _ = run_jobs(probe, num_jobs=6, K=64, M=8, N=8)
        deadline = max(0.030,
                       2.2 * float(base_res.layer_compute[:, 0].mean()))
        cfg = dataclasses.replace(probe, deadline=deadline, seed=0)

        def inject():
            procs = _await_worker_processes(len(MU5))
            time.sleep(0.6)
            os.kill(procs[1].pid, signal.SIGKILL)

        res, _ = _run_with_faults(cfg, 20, inject)
        assert res.workers_lost == 1
        assert res.success_rate()[0] >= 0.9      # same slack as tier-1
        assert np.nanmax(res.verify_errors) < 1e-9
        assert not _runtime_worker_processes()

    def test_process_below_k_survivors_release_degraded_promptly(self):
        """Acceptance: killing down to ``S < k`` survivors releases every
        remaining job at its best-ready resolution, marked degraded, with
        the collapse in the fault log — promptly, not after a timeout."""
        cfg = self._degrade_cfg("process")
        marks: dict = {}

        def inject():
            procs = _await_worker_processes(len(MU5))
            time.sleep(0.5)
            for wid in (1, 3):
                os.kill(procs[wid].pid, signal.SIGKILL)
            marks["killed_at"] = time.monotonic()

        res, _ = _run_with_faults(cfg, 20, inject, join_timeout=60.0)
        # "promptly": well under the 20-job arrival span, nowhere near
        # any heartbeat/backoff timeout regime
        assert time.monotonic() - marks["killed_at"] < 15.0
        assert res.workers_lost == 2
        kinds = [e["kind"] for e in res.fault_log]
        assert kinds.count("quarantine") == 2
        assert "fleet-collapse" in kinds
        assert {e["worker"] for e in res.fault_log
                if e["kind"] == "quarantine"} == {1, 3}
        assert res.degraded.any()
        assert res.terminated[res.degraded].all()
        done = ~res.terminated
        if done.any():          # jobs finished before the kill verify
            assert np.nanmax(res.verify_errors[done]) < 1e-9
        assert not _runtime_worker_processes()

    def test_process_fail_fast_raises_typed_error(self):
        """The default policy's contract is *unchanged* by this PR — a
        SIGKILLed worker still fails the run, now with the typed
        :class:`TransportDeadError` (satellite: typed exceptions)."""
        cfg = self._degrade_cfg("process", fault_policy="fail-fast")

        def inject():
            procs = _await_worker_processes(len(MU5))
            time.sleep(0.4)
            os.kill(procs[0].pid, signal.SIGKILL)

        with pytest.raises(TransportDeadError, match="died"):
            _run_with_faults(cfg, 20, inject)
        assert not _runtime_worker_processes()

    def test_socket_kill_revive_readmits_and_completes(self):
        """Acceptance: a SIGKILLed socket host restarted on its port is
        readmitted through the reconnect + hello/watermark resync path —
        quarantine then readmit in the fault log, geometry restored, and
        the whole stream decode-verified."""
        with LocalCluster(len(MU5)) as cluster:
            cfg = self._degrade_cfg("socket", hosts=cluster.hosts)

            def inject():
                time.sleep(1.2)
                cluster.kill(2)
                time.sleep(1.8)
                cluster.revive(2)

            res, _ = _run_with_faults(cfg, 80, inject, join_timeout=180.0)
        assert res.workers_lost == 1
        kinds = [e["kind"] for e in res.fault_log]
        assert kinds.count("quarantine") == 1
        assert "readmit" in kinds
        assert res.success.all()
        assert not res.degraded.any()
        assert np.nanmax(res.verify_errors) < 1e-9
        assert not _runtime_worker_threads()


class TestGatewayConformance:
    """The serving gateway over every transport: overlapping requests
    multiplex one shared fleet, and a mid-request worker loss under
    ``fault_policy="degrade"`` degrades only the affected request."""

    @staticmethod
    def _operands(rng, cfg, k=16, n=4):
        lim = 1 << (cfg.m * cfg.d - 2)
        a = rng.integers(-lim, lim, size=(k, cfg.n1 * n), dtype=np.int64)
        b = rng.integers(-lim, lim, size=(k, cfg.n2 * n), dtype=np.int64)
        return a, b

    @pytest.mark.parametrize("backend", BACKENDS_FULL)
    def test_two_overlapping_requests_both_decode_verify(self, backend,
                                                         bcfg):
        """Two requests in flight at once over one fleet — no restart
        between them — both released at full resolution with exact
        values (float64 roundoff rounded away)."""
        from repro.runtime import ServingGateway

        cfg = bcfg(backend, arrival_rate=50.0, complexity=0.2,
                   straggler="none", seed=0)
        rng = np.random.default_rng(0)
        with ServingGateway(cfg, admission="none") as gw:
            a0, b0 = self._operands(rng, cfg)
            a1, b1 = self._operands(rng, cfg)
            t_a = gw.submit(a0, b0, deadline=30.0)
            t_b = gw.submit(a1, b1, deadline=30.0)   # queued behind A
            assert t_a.wait(timeout=60.0) and t_b.wait(timeout=60.0)
        full = cfg.num_layers - 1
        for t, want in ((t_a, a0.T @ b0), (t_b, a1.T @ b1)):
            assert t.released_resolution == full and not t.degraded
            np.testing.assert_array_equal(
                np.round(t.value()).astype(np.int64), want)
        # genuinely overlapping: B was admitted before A was released
        assert t_b.arrival < t_a.released_at
        gw.stats.reconcile()
        assert gw.result is not None and gw.result.backend == backend
        assert not _runtime_worker_threads()
        assert not _runtime_worker_processes()

    def test_process_sigkill_mid_stream_keeps_requests_full(self):
        """``n - k = 1`` process workers SIGKILLed while gateway requests
        stream through: the loss is absorbed (quarantine + refit) and
        every admitted request still releases at full resolution."""
        from repro.runtime import ServingGateway

        cfg = RuntimeConfig(backend="process", mu=MU5, arrival_rate=8.0,
                            complexity=8.0, fault_policy="degrade",
                            straggler="none", seed=3)
        rng = np.random.default_rng(3)
        with ServingGateway(cfg, admission="none") as gw:
            procs = _await_worker_processes(len(MU5))
            tickets, oracles = [], []
            for i in range(8):
                a, b = self._operands(rng, cfg, k=64, n=4)
                oracles.append(a.T @ b)
                tickets.append(gw.submit(a, b, deadline=60.0))
                if i == 2:
                    os.kill(procs[1].pid, signal.SIGKILL)
                time.sleep(0.05)
        res = gw.result
        assert res.workers_lost == 1
        assert [e["kind"] for e in res.fault_log].count("quarantine") == 1
        full = cfg.num_layers - 1
        for t, want in zip(tickets, oracles):
            assert t.released_resolution == full and not t.degraded
            np.testing.assert_array_equal(
                np.round(t.value()).astype(np.int64), want)
        gw.stats.reconcile()
        assert not _runtime_worker_processes()

    def test_socket_sigkill_degrades_only_affected_request(self):
        """Below-``k`` SIGKILL mid-request over a socket fleet: the
        in-flight request is released degraded, and a request submitted
        after the hosts revive is readmitted onto the restored geometry
        and decode-verifies at full resolution — one gateway, one fleet,
        no restart."""
        from repro.runtime import ServingGateway

        with LocalCluster(len(MU5)) as cluster:
            cfg = RuntimeConfig(
                backend="socket", hosts=cluster.hosts, mu=MU5,
                arrival_rate=8.0, complexity=8.0, fault_policy="degrade",
                straggler="stall", stall_workers=(0, 1, 2, 3, 4),
                stall_seconds=3.0, heartbeat_interval=0.5,
                heartbeat_timeout=5.0, reconnect_attempts=1, seed=3)
            rng = np.random.default_rng(3)
            with ServingGateway(cfg, admission="none") as gw:
                a0, b0 = self._operands(rng, cfg)
                t_a = gw.submit(a0, b0, deadline=60.0)
                time.sleep(0.4)             # A mid-round (3 s stall)
                cluster.kill(1)
                cluster.kill(3)             # survivors 3 < k = 4
                assert t_a.wait(timeout=30.0), "collapse never released A"
                assert t_a.degraded
                assert t_a.released_resolution < cfg.num_layers - 1
                cluster.revive(1)
                cluster.revive(3)
                time.sleep(1.5)             # > READMIT_INTERVAL
                a1, b1 = self._operands(rng, cfg)
                t_b = gw.submit(a1, b1, deadline=60.0)
                assert t_b.wait(timeout=60.0), "B never released"
                assert not t_b.degraded
                assert t_b.released_resolution == cfg.num_layers - 1
                np.testing.assert_array_equal(
                    np.round(t_b.value()).astype(np.int64), a1.T @ b1)
        res = gw.result
        assert res.workers_lost == 2
        kinds = [e["kind"] for e in res.fault_log]
        assert kinds.count("quarantine") == 2
        assert "fleet-collapse" in kinds
        assert "readmit" in kinds and "fleet-recovered" in kinds
        stats = gw.stats
        stats.reconcile()
        assert stats.degraded == 1          # only the affected request
        assert not _runtime_worker_threads()


class TestJaxBackendSmoke:
    """CPU smoke only: one local device, thread transport loop."""

    def test_jax_backend_runs_and_verifies(self):
        cfg = _cfg(backend="jax", arrival_rate=100.0, complexity=0.2,
                   straggler="none", seed=0)
        res, _ = run_jobs(cfg, num_jobs=3, K=64, M=8, N=8, verify=True)
        assert res.backend == "jax"
        assert res.success.all()
        # float32 device compute: looser than host float64, still tight
        assert np.nanmax(res.verify_errors) < 1e-4
        assert not _runtime_worker_threads()


def _hier_cfg(bcfg, backend, **kw):
    kw.setdefault("code_family", "hierarchical")
    kw.setdefault("levels", 2)
    return bcfg(backend, **kw)


#: backend -> measured res-0 compute (s) for the *hierarchical* family in
#: the deadline scenario's stall regime, deadline-free.  The polynomial
#: baseline above would mis-calibrate: grouped dispatch amortizes wire
#: round-trips and the per-level ``T`` differs, so the hierarchical rows
#: measure their own round.
_HIER_BASELINE: dict = {}


def _hier_baseline(backend, bcfg) -> float:
    if backend not in _HIER_BASELINE:
        cfg = _hier_cfg(bcfg, backend, arrival_rate=14.0, complexity=8.0,
                        straggler="stall", stall_workers=(2,),
                        stall_seconds=2.0, seed=1)
        res, _ = run_jobs(cfg, num_jobs=6, K=64, M=8, N=8)
        _HIER_BASELINE[backend] = float(res.layer_compute[:, 0].mean())
    return _HIER_BASELINE[backend]


@pytest.mark.parametrize("backend", BACKENDS_FULL)
class TestHierarchicalConformance:
    """Sub-task-granular conformance rows, identical over every backend:
    the hierarchical family completes decode-verified while *banking*
    straggler sub-tasks (never discarding them), keeps already-fused
    levels when a §IV deadline purges mid-group, and reconciles its
    sub-task ledger exactly against the telemetry event log."""

    def test_hier_stall_completes_and_salvages_subtasks(self, backend,
                                                        bcfg):
        """Under a hard stall every job still completes at full
        resolution (per-level redundancy purges the stalled worker's
        share), and the salvage ledger is *nonzero*: fast workers' deep-
        level sub-tasks land while the master still waits on the level-0
        frontier — work the task-granular family would have thrown away."""
        cfg = _hier_cfg(bcfg, backend, arrival_rate=14.0, complexity=8.0,
                        straggler="stall", stall_workers=(2,),
                        stall_seconds=2.0, seed=1)
        res, _ = run_jobs(cfg, num_jobs=6, K=64, M=8, N=8, verify=True)
        assert res.backend == _real_backend(backend)
        assert res.success.all()
        assert (res.released == cfg.num_layers - 1).all()
        assert not res.terminated.any()
        assert np.nanmax(res.verify_errors) < 1e-9
        stats = res.transport_stats
        assert stats["subtask_results"] > 0
        assert stats["salvaged_subtasks"] > 0
        assert stats["salvaged_subtasks"] <= stats["subtask_results"]
        assert not _runtime_worker_threads()
        assert not _runtime_worker_processes()

    def test_hier_deadline_purge_keeps_completed_levels(self, backend,
                                                        bcfg):
        """Purge-mid-level: a deadline that cuts jobs off inside a group
        must not cost the levels that already fused — terminated jobs
        still release a verified lower resolution (res-0 keeps its §IV
        success gap), with the same measured-baseline calibration and
        slack rationale as the task-granular deadline row above."""
        deadline = max(0.030, 2.2 * _hier_baseline(backend, bcfg))
        cfg = _hier_cfg(bcfg, backend, arrival_rate=14.0, complexity=8.0,
                        deadline=deadline, straggler="stall",
                        stall_workers=(2,), stall_seconds=2.0, seed=0)
        res, _ = run_jobs(cfg, num_jobs=20, K=64, M=8, N=8, verify=True)
        assert res.terminated.any()
        sr = res.success_rate()
        assert sr[0] >= 0.9
        assert sr[-1] < 1.0 and sr[-1] < sr[0]
        term = np.flatnonzero(res.terminated)
        assert (res.released[term] >= 0).mean() >= 0.9   # partials shipped
        assert np.nanmax(res.verify_errors) < 1e-9
        # res-0 still leads the final resolution; the *strict* per-layer
        # ordering of the task-granular row is deliberately not asserted:
        # a group's last levels are dispatched together and can fuse
        # within microseconds of each other (that concurrency is the
        # salvage mechanism, not a defect)
        md = res.mean_delay()
        assert md[0] < md[-1]
        assert res.transport_stats["salvaged_subtasks"] > 0

    def test_hier_subtask_ledger_reconciles_with_trace(self, backend,
                                                       bcfg):
        """The sub-task ledger is the trace, aggregated: every accepted
        grouped result is exactly one RESULT event, every fused level
        round accepted exactly ``k`` of them, every stale rejection is a
        STALE event, and worker task spans close ``done``/``purged`` in
        exact agreement with the counters.  (Deliberately *not* asserted:
        ``DISPATCH == stage_rounds`` — the grouped path emits one
        DISPATCH per group of ``levels`` rounds, which is the point.)"""
        cfg = _hier_cfg(bcfg, backend, arrival_rate=60.0, complexity=4.0,
                        straggler="none", trace=True, seed=0)
        res, _ = run_jobs(cfg, 5, K=16, M=4, N=4, verify=False)
        evs = res.trace_events
        assert evs is not None and res.trace_dropped == 0
        stats = res.transport_stats
        arrivals = [e for e in evs if e.kind == telemetry.RESULT]
        assert len(arrivals) == stats["subtask_results"]
        assert 0 <= stats["salvaged_subtasks"] <= stats["subtask_results"]
        assert sum(e.kind == telemetry.STALE for e in evs) == \
            res.stale_results
        # fused level rounds accepted exactly k sub-task results each
        per_round = collections.Counter((e.job, e.round) for e in arrivals)
        fused_keys = {(e.job, e.round) for e in evs
                      if e.kind == telemetry.FUSED}
        assert fused_keys
        assert all(per_round[key] == cfg.k for key in fused_keys)
        # worker task spans reconcile across the process/TCP boundary
        tasks = [e for e in evs if e.kind == telemetry.TASK]
        assert sum(e.label == "done" for e in tasks) == res.tasks_done
        assert sum(e.label == "purged" for e in tasks) == res.tasks_purged
        # one ROUND span per level round, one DISPATCH per *group*
        assert sum(e.kind == telemetry.ROUND for e in evs) == \
            res.stage_rounds
        dispatches = [e for e in evs if e.kind == telemetry.DISPATCH]
        assert dispatches and all(e.label == f"group+{cfg.levels}"
                                  for e in dispatches)
        assert len(dispatches) == res.stage_rounds // cfg.levels


class TestHierarchicalDegrade:
    """SIGKILL mid-level under ``fault_policy="degrade"``: the grouped
    dispatch path absorbs worker loss exactly like the task-granular
    family — an ``n - k`` kill completes decode-verified, a below-``k``
    collapse releases every job at its best level-complete resolution
    with the loss itemized in the fault log."""

    def _hcfg(self, **kw):
        kw.setdefault("mu", MU5)
        kw.setdefault("arrival_rate", 8.0)
        kw.setdefault("complexity", 8.0)
        kw.setdefault("fault_policy", "degrade")
        kw.setdefault("code_family", "hierarchical")
        kw.setdefault("levels", 2)
        kw.setdefault("shm", "off")
        kw.setdefault("seed", 3)
        return RuntimeConfig(backend="process", **kw)

    def test_hier_process_sigkill_mid_level_completes_verified(self):
        """Kill ``n - k = 1`` of 5 workers mid-run: its in-flight group
        slices are re-dispatched at the wait frontier and every job still
        completes at full resolution, decode-verified, loss itemized —
        with the salvage ledger intact across the quarantine."""
        cfg = self._hcfg()

        def inject():
            procs = _await_worker_processes(len(MU5))
            time.sleep(0.5)
            os.kill(procs[1].pid, signal.SIGKILL)

        res, _ = _run_with_faults(cfg, 20, inject)
        assert res.workers_lost == 1
        kinds = [e["kind"] for e in res.fault_log]
        assert kinds.count("quarantine") == 1
        assert res.success.all()
        assert not res.degraded.any()
        assert (res.released == cfg.num_layers - 1).all()
        assert np.nanmax(res.verify_errors) < 1e-9
        assert res.transport_stats["subtask_results"] > 0
        assert not _runtime_worker_processes()

    def test_hier_process_below_k_releases_best_level_itemized(self):
        """Kill down to ``S < k`` survivors mid-level: every remaining
        job releases promptly at its best level-complete resolution
        (whatever levels had fused when the fleet collapsed), marked
        degraded, with both quarantines and the collapse itemized — and
        everything that *was* released decode-verifies."""
        cfg = self._hcfg()
        marks: dict = {}

        def inject():
            procs = _await_worker_processes(len(MU5))
            time.sleep(0.5)
            for wid in (1, 3):
                os.kill(procs[wid].pid, signal.SIGKILL)
            marks["killed_at"] = time.monotonic()

        res, _ = _run_with_faults(cfg, 20, inject, join_timeout=60.0)
        assert time.monotonic() - marks["killed_at"] < 15.0
        assert res.workers_lost == 2
        kinds = [e["kind"] for e in res.fault_log]
        assert kinds.count("quarantine") == 2
        assert "fleet-collapse" in kinds
        assert {e["worker"] for e in res.fault_log
                if e["kind"] == "quarantine"} == {1, 3}
        assert res.degraded.any()
        assert res.terminated[res.degraded].all()
        # every level-complete resolution that shipped decode-verifies
        shipped = res.released >= 0
        if shipped.any():
            assert np.nanmax(res.verify_errors[shipped]) < 1e-9
        assert not _runtime_worker_processes()
