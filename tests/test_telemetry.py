"""Structured tracing: tracer units, exporters, and cross-backend
conformance.

The conformance half runs real traced workloads over every transport
({thread, process, socket} — socket against a live LocalCluster) and
checks the one property that makes the trace trustworthy: the event log
*reconciles exactly* with the run's aggregate counters.  Every
``tasks_done`` increment has a ``done`` task span, every purge a
``purged`` one, every stale result a ``stale`` instant, every dispatched
round exactly one round span — over any backend, including events that
crossed a process or TCP boundary to get here.
"""

import json
import threading

import numpy as np
import pytest

from repro.runtime import RuntimeConfig, run_jobs
from repro.runtime import telemetry
from repro.runtime import trace_export
from repro.runtime.telemetry import TraceEvent, Tracer
from repro.runtime.transport.socket_host import LocalCluster

MU3 = (400.0, 650.0, 380.0)
BACKENDS_FULL = ("thread", "process", "socket")


@pytest.fixture(scope="session")
def socket_cluster():
    with LocalCluster(len(MU3)) as cluster:
        yield cluster


@pytest.fixture
def bcfg(request):
    def make(backend, **kw):
        kw.setdefault("mu", MU3)
        kw.setdefault("trace", True)
        if backend == "socket":
            kw.setdefault(
                "hosts", request.getfixturevalue("socket_cluster").hosts)
        return RuntimeConfig(backend=backend, **kw)

    return make


class TestTracer:
    def test_emit_and_sorted_events(self):
        tr = Tracer()
        tr.emit(telemetry.ENCODE, 2.0, dur=0.5, job=1, round=0)
        tr.emit(telemetry.DISPATCH, 1.0, job=1, round=0, value=7.0)
        evs = tr.events()
        assert [e.kind for e in evs] == ["dispatch", "encode"]  # time order
        assert evs[1].dur == 0.5 and evs[0].value == 7.0
        assert tr.events() == evs            # non-destructive

    def test_drain_takes_and_clears(self):
        tr = Tracer()
        tr.emit(telemetry.TASK, 1.0, dur=0.1, label="done")
        assert len(tr.drain()) == 1
        assert tr.drain() == [] and tr.events() == []

    def test_ring_overflow_keeps_newest_and_counts_drops(self):
        tr = Tracer(capacity=4)
        for i in range(10):
            tr.emit(telemetry.RESULT, float(i))
        evs = tr.events()
        assert len(evs) == 4 and tr.dropped == 6
        assert [e.t for e in evs] == [6.0, 7.0, 8.0, 9.0]   # oldest evicted

    def test_ingest_rebases_remote_clock(self):
        tr = Tracer()
        remote = [tuple(TraceEvent(telemetry.TASK, 100.0, 0.25, 3, 1, 2, 0,
                                   0.0, "done"))]
        tr.ingest(remote, shift=-90.0)
        ev = tr.events()[0]
        assert ev.t == pytest.approx(10.0)
        assert (ev.dur, ev.job, ev.round, ev.task, ev.label) == \
            (0.25, 3, 1, 2, "done")
        tr.ingest(remote)                    # shift=0 fast path
        assert tr.events()[-1].t == pytest.approx(100.0)

    def test_threads_do_not_interleave_rings(self):
        tr = Tracer()
        n = 500

        def record(worker):
            for i in range(n):
                tr.emit(telemetry.TASK, float(i), worker=worker)

        threads = [threading.Thread(target=record, args=(w,))
                   for w in range(4)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        evs = tr.events()
        assert len(evs) == 4 * n and tr.dropped == 0
        counts = np.bincount([e.worker for e in evs])
        assert counts.tolist() == [n] * 4

    def test_taxonomy_is_partitioned(self):
        assert not (telemetry.SPAN_KINDS & telemetry.INSTANT_KINDS)
        assert telemetry.EVENT_KINDS == \
            telemetry.SPAN_KINDS | telemetry.INSTANT_KINDS


class TestExporters:
    @pytest.fixture(scope="class")
    def traced(self):
        cfg = RuntimeConfig(mu=MU3, arrival_rate=60.0, complexity=4.0,
                            straggler="none", trace=True, seed=0)
        res, _ = run_jobs(cfg, 4, K=16, M=4, N=4, verify=False)
        return res

    def test_chrome_trace_is_perfetto_shaped(self, traced):
        chrome = trace_export.chrome_trace(traced)
        json.dumps(chrome)                   # serializable end to end
        evs = chrome["traceEvents"]
        assert chrome["displayTimeUnit"] == "ms"
        phases = {e["ph"] for e in evs}
        assert phases <= {"M", "X", "i"}
        spans = [e for e in evs if e["ph"] == "X"]
        assert spans and all(e["dur"] >= 0.0 and e["ts"] >= 0.0
                             for e in spans)
        assert all(e["s"] == "t" for e in evs if e["ph"] == "i")
        names = [e["args"]["name"] for e in evs
                 if e["ph"] == "M" and e["name"] == "process_name"]
        assert names[0].startswith("master")
        assert len(names) == 1 + len(MU3)    # master + one per worker
        # worker task spans live in per-worker processes, master gets none
        assert all(e["pid"] >= 1 for e in evs if e.get("cat") == "task")

    def test_jsonl_round_trips(self, traced):
        lines = list(trace_export.jsonl_lines(traced))
        assert len(lines) == len(traced.trace_events)
        recs = [json.loads(line) for line in lines]
        assert all(r["t"] >= 0.0 for r in recs)
        assert {r["kind"] for r in recs} <= telemetry.EVENT_KINDS

    def test_prometheus_snapshot_counters(self, traced):
        text = trace_export.prometheus_snapshot(traced)
        assert text.endswith("\n")
        assert f'repro_tasks_done_total{{backend="thread"}} ' \
               f"{traced.tasks_done}" in text
        assert f'repro_rounds_total{{backend="thread"}} ' \
               f"{traced.stage_rounds}" in text
        hist = traced.release_histogram()
        assert f'repro_jobs_released_total{{resolution="-1"}} ' \
               f"{int(hist[0])}" in text

    def test_format_timeline_rows(self, traced):
        art = trace_export.format_timeline(traced, width=60)
        lines = art.splitlines()
        assert lines[0].startswith("timeline")
        assert lines[1].lstrip().startswith("master")
        assert len(lines) == 2 + len(MU3)    # header + master + workers
        assert any("#" in line for line in lines[2:])

    def test_untraced_result_rejected(self):
        cfg = RuntimeConfig(mu=MU3, arrival_rate=60.0, complexity=4.0,
                            straggler="none", seed=0)
        res, _ = run_jobs(cfg, 2, K=16, M=4, N=4, verify=False)
        assert res.trace_events is None and res.tasks_done > 0
        with pytest.raises(ValueError, match="trace"):
            trace_export.chrome_trace(res)
        # prometheus reads counters only: works untraced by design
        assert "repro_tasks_done_total" in \
            trace_export.prometheus_snapshot(res)


@pytest.mark.parametrize("backend", BACKENDS_FULL)
class TestTraceConformance:
    """Same schema, exact counter reconciliation, over every transport."""

    def test_events_reconcile_with_counters(self, backend, bcfg):
        cfg = bcfg(backend, arrival_rate=60.0, complexity=4.0,
                   straggler="none", seed=0)
        res, _ = run_jobs(cfg, 5, K=16, M=4, N=4, verify=False)
        evs = res.trace_events
        assert evs is not None and res.trace_dropped == 0
        assert {e.kind for e in evs} <= telemetry.EVENT_KINDS
        assert all(isinstance(e, TraceEvent) for e in evs)

        tasks = [e for e in evs if e.kind == telemetry.TASK]
        assert sum(e.label == "done" for e in tasks) == res.tasks_done
        assert sum(e.label == "purged" for e in tasks) == res.tasks_purged
        assert sum(e.kind == telemetry.STALE for e in evs) == \
            res.stale_results
        rounds = [e for e in evs if e.kind == telemetry.ROUND]
        assert len(rounds) == res.stage_rounds
        assert sum(e.kind == telemetry.DISPATCH for e in evs) == \
            res.stage_rounds
        # accepted arrivals: k per fused round, all within the run window
        fused = sum(e.kind == telemetry.FUSED for e in evs)
        arrivals = sum(e.kind == telemetry.RESULT for e in evs)
        assert arrivals == fused * cfg.k
        assert sum(e.kind == telemetry.JOB for e in evs) == res.num_jobs
        # the merged log is time-sorted and anchored at the run start
        ts = [e.t for e in evs]
        assert ts == sorted(ts)
        assert all(e.t - res.trace_t0 > -1e-4 for e in evs)

    def test_purged_task_spans_close_purged_not_fused(self, backend, bcfg):
        """A deadline-purged round's tasks must close as ``purged`` —
        never as ``fused``/``done`` — and the purged round span must say
        so too."""
        cfg = bcfg(backend, arrival_rate=14.0, complexity=8.0,
                   deadline=0.030, straggler="stall", stall_workers=(2,),
                   stall_seconds=2.0, seed=0)
        res, _ = run_jobs(cfg, 10, K=16, M=4, N=4, verify=False)
        evs = res.trace_events
        assert res.tasks_purged > 0          # the stall really binds
        tasks = [e for e in evs if e.kind == telemetry.TASK]
        assert {e.label for e in tasks} <= {"done", "purged"}
        assert sum(e.label == "purged" for e in tasks) == res.tasks_purged
        rounds = [e for e in evs if e.kind == telemetry.ROUND]
        purged_rounds = {(e.job, e.round) for e in rounds
                         if e.label == "purged"}
        assert purged_rounds                 # some round missed its window
        # a round span closes fused or purged, never both
        fused_keys = {(e.job, e.round) for e in evs
                      if e.kind == telemetry.FUSED}
        assert not (purged_rounds & fused_keys)

    def test_worker_spans_cover_busy_time(self, backend, bcfg):
        """Per-worker span durations sum to that worker's busy-seconds
        counter (the trace is the counter, itemized)."""
        cfg = bcfg(backend, arrival_rate=60.0, complexity=4.0,
                   straggler="none", seed=1)
        res, _ = run_jobs(cfg, 5, K=16, M=4, N=4, verify=False)
        spans = [e for e in res.trace_events if e.kind == telemetry.TASK]
        for w, busy in enumerate(res.worker_busy):
            mine = sum(e.dur for e in spans if e.worker == w)
            assert mine == pytest.approx(float(busy), rel=0.05, abs=2e-3)

    def test_untraced_run_carries_no_events(self, backend, bcfg):
        cfg = bcfg(backend, arrival_rate=60.0, complexity=4.0,
                   straggler="none", trace=False, seed=0)
        res, _ = run_jobs(cfg, 3, K=16, M=4, N=4, verify=False)
        assert res.trace_events is None
        assert res.trace_dropped == 0
        assert res.tasks_done > 0            # counters still flow untraced


class TestSocketClockAlignment:
    """The cross-host half of the tentpole: remote monotonic clocks land
    on the master timeline with error bounded by the measured RTT."""

    def test_offsets_bounded_and_reported(self, bcfg):
        cfg = bcfg("socket", arrival_rate=60.0, complexity=4.0,
                   straggler="none", seed=0)
        res, _ = run_jobs(cfg, 5, K=16, M=4, N=4, verify=False)
        sync = res.clock_sync
        assert sync is not None and len(sync) == len(MU3)
        for row in sync:
            assert row["rtt_s"] is not None and row["rtt_s"] > 0.0
            # same machine, same monotonic clock: the estimated offset is
            # pure protocol error, bounded by the loopback RTT
            assert abs(row["offset_s"]) <= max(row["rtt_s"], 1e-3)

    def test_remote_task_spans_sit_inside_round_spans(self, bcfg):
        """After rebasing, a worker's task span for round r cannot start
        before the master dispatched r (up to the alignment error)."""
        cfg = bcfg("socket", arrival_rate=60.0, complexity=4.0,
                   straggler="none", seed=0)
        res, _ = run_jobs(cfg, 5, K=16, M=4, N=4, verify=False)
        slack = max(max(r["rtt_s"] or 0.0 for r in res.clock_sync), 1e-3)
        dispatch_at = {(e.job, e.round): e.t for e in res.trace_events
                       if e.kind == telemetry.DISPATCH}
        tasks = [e for e in res.trace_events if e.kind == telemetry.TASK]
        assert tasks
        for e in tasks:
            t_disp = dispatch_at.get((e.job, e.round))
            if t_disp is not None:
                assert e.t >= t_disp - slack

    def test_metrics_endpoint_serves_live_counters(self):
        """`runctl serve-worker --metrics-port`: /metrics scrapes reflect
        the runner's live counters in Prometheus text format."""
        import urllib.request

        class _Runner:
            worker_id = 3
            busy_seconds = 1.25
            tasks_done = 42
            tasks_purged = 7

        server, port = telemetry.serve_metrics(
            lambda: telemetry.worker_metrics_text(_Runner(), sessions=2))
        try:
            url = f"http://127.0.0.1:{port}/metrics"
            body = urllib.request.urlopen(url, timeout=5).read().decode()
            assert 'repro_worker_tasks_done_total{worker="3"} 42' in body
            assert 'repro_worker_sessions_total{worker="3"} 2' in body
            assert 'repro_worker_busy_seconds{worker="3"} 1.250000' in body
            with pytest.raises(urllib.error.HTTPError):
                urllib.request.urlopen(
                    f"http://127.0.0.1:{port}/nope", timeout=5)
        finally:
            server.shutdown()
