"""Hierarchical code family: level math, any-k decode, salvage fusion.

The property/conformance tier for the sub-task-granular runtime
(ISSUE: "straggler work is never discarded"):

* **Level math** — MSB-heavy per-level lengths at *equal aggregate
  budget* (``sum == levels * ceil(k*omega)``), every level at least the
  recovery threshold ``k``, deterministic rounding — with hand-computed
  cases pinning the exact allocation.
* **Decode** — every level of a :class:`~repro.core.coding
  .HierarchicalCode` is a true MDS code: any ``k``-subset of its symbols
  reconstructs the product (allclose in float mode, bit-exact in gfp),
  and re-decoding the *same* subset in a different arrival order is
  bit-identical (the fusion node's arrival order must never leak into
  the value).
* **Partial-level isolation** — a level that received fewer than ``k``
  results never corrupts a sibling level's decode: levels are
  independent codewords, and the grouped fusion node routes by
  ``(job_id, round_idx)``.
* **Salvage/stale exactness** — the grouped
  :class:`~repro.runtime.fusion.FusionNode` regression tier for the
  sub-task-granular accounting bugfix: a purged worker's *late* sub-task
  results (duplicate task ids racing a re-dispatch, or arrivals after
  the group closed) are counted stale exactly once each, and the
  salvage ledger counts exactly the accepted results beyond the
  master's wait frontier.

Property blocks ride ``_hypothesis_compat`` — they run when hypothesis
is installed and skip cleanly when not.
"""

import itertools

import numpy as np
import pytest
from _hypothesis_compat import hypothesis, st

from repro.core import coding
from repro.runtime.fusion import FusionNode
from repro.runtime.tasks import RoundContext, RuntimeConfig, TaskResult


def _all_task_products(code, A, B):
    """Every coded symbol's product for one level, stacked (T, ...)."""
    X, Y = np.asarray(code.encode_a(A)), np.asarray(code.encode_b(B))
    return np.stack([X[t].T @ Y[t] for t in range(code.num_tasks)])


class TestLevelLengths:
    def test_hand_computed_exact_split(self):
        # k=4, levels=3, omega=1.5: base = ceil(4*1.5) = 6, budget = 18,
        # extra = 18 - 12 = 6, weights (3,2,1)/6 -> alloc (3,2,1):
        hc = coding.HierarchicalCode(n1=2, n2=2, levels=3, omega=1.5)
        assert hc.base_tasks == 6
        assert hc.level_lengths == (7, 6, 5)
        assert hc.num_tasks == 18

    def test_hand_computed_rounding_leftover_goes_msb_first(self):
        # k=4, levels=2, omega=1.25: base = 5, budget = 10, extra = 2,
        # weights (2,1)/3 -> floor alloc (1,0), leftover 1 -> MSB level:
        hc = coding.HierarchicalCode(n1=2, n2=2, levels=2, omega=1.25)
        assert hc.level_lengths == (6, 4)

    def test_rate_one_every_level_exactly_k(self):
        hc = coding.HierarchicalCode(n1=2, n2=2, levels=3, omega=1.0)
        assert hc.level_lengths == (4, 4, 4)

    @pytest.mark.parametrize("n1,n2,levels,omega",
                             [(2, 2, 2, 1.5), (2, 2, 4, 1.3), (3, 2, 3, 1.1),
                              (2, 1, 5, 2.0), (4, 2, 2, 1.07)])
    def test_budget_preserved_msb_heavy_all_above_k(self, n1, n2, levels,
                                                    omega):
        hc = coding.HierarchicalCode(n1=n1, n2=n2, levels=levels,
                                     omega=omega)
        lengths = hc.level_lengths
        assert sum(lengths) == levels * hc.base_tasks   # equal budget
        assert all(t >= hc.k for t in lengths)          # decodable levels
        assert list(lengths) == sorted(lengths, reverse=True)  # MSB-heavy

    def test_budget_below_levels_times_k_rejected(self):
        with pytest.raises(ValueError, match="budget"):
            coding._hier_level_lengths(4, 3, 11)

    def test_validation(self):
        with pytest.raises(ValueError):
            coding.HierarchicalCode(n1=2, n2=2, levels=0)
        with pytest.raises(ValueError):
            coding.HierarchicalCode(n1=2, n2=2, levels=2, omega=0.5)
        with pytest.raises(ValueError):
            coding.HierarchicalCode(n1=2, n2=2, levels=2, mode="nope")


class TestHierarchicalDecodeFloat:
    def test_hand_computed_two_level_decode(self):
        # k = 2 (n1=2, n2=1), omega=1.5 -> base 3, lengths (4, 2).
        # A is 2x2 split column-wise into two blocks, B one block:
        # the product is small enough to state outright.
        hc = coding.HierarchicalCode(n1=2, n2=1, levels=2, omega=1.5)
        assert hc.level_lengths == (4, 2)
        A = np.array([[1.0, 2.0], [3.0, 4.0]])       # (K=2, M=2)
        B = np.array([[5.0], [6.0]])                 # (K=2, N=1)
        want = np.array([[1 * 5 + 3 * 6], [2 * 5 + 4 * 6]])  # = [[23],[34]]
        for lvl in range(2):
            code = hc.level_code(lvl)
            prods = _all_task_products(code, A, B)
            for ids in itertools.combinations(range(code.num_tasks), hc.k):
                dec = np.asarray(hc.decode_level(lvl, list(ids),
                                                 prods[np.asarray(ids)]))
                np.testing.assert_allclose(dec, want, rtol=1e-9, atol=1e-9)

    def test_any_k_subset_every_level(self, rng):
        hc = coding.HierarchicalCode(n1=2, n2=2, levels=3, omega=1.5)
        A = rng.integers(-100, 100, size=(16, 8)).astype(np.float64)
        B = rng.integers(-100, 100, size=(16, 8)).astype(np.float64)
        exact = A.T @ B
        for lvl in range(hc.levels):
            code = hc.level_code(lvl)
            prods = _all_task_products(code, A, B)
            subsets = [list(range(hc.k)),
                       list(range(code.num_tasks - hc.k, code.num_tasks)),
                       list(rng.choice(code.num_tasks, hc.k,
                                       replace=False))]
            for ids in subsets:
                dec = np.asarray(hc.decode_level(lvl, ids,
                                                 prods[np.asarray(ids)]))
                np.testing.assert_allclose(dec, exact, rtol=1e-8, atol=1e-6)

    def test_same_subset_any_order_bit_identical(self, rng):
        """Arrival order must not leak into the decoded value: the fusion
        node hands ids in arrival order, and a re-dispatch can permute
        it between otherwise identical runs."""
        hc = coding.HierarchicalCode(n1=2, n2=2, levels=2, omega=1.5)
        A = rng.normal(size=(16, 8))
        B = rng.normal(size=(16, 8))
        for lvl in range(hc.levels):
            code = hc.level_code(lvl)
            prods = _all_task_products(code, A, B)
            ids = list(rng.choice(code.num_tasks, hc.k, replace=False))
            base = np.asarray(hc.decode_level(lvl, ids,
                                              prods[np.asarray(ids)]))
            for _ in range(4):
                perm = list(rng.permutation(len(ids)))
                pids = [ids[i] for i in perm]
                dec = np.asarray(hc.decode_level(
                    lvl, pids, prods[np.asarray(pids)]))
                assert base.tobytes() == dec.tobytes()

    def test_shared_plan_cache_across_equal_lengths(self):
        """Two levels with equal codeword length share one DecodePlan —
        the LRU keys by geometry, not by family."""
        hc = coding.HierarchicalCode(n1=2, n2=2, levels=2, omega=1.0)
        assert hc.plan(0) is hc.plan(1)
        flat = coding.PolynomialCode(n1=2, n2=2, omega=1.0)
        assert hc.plan(0) is flat.plan()


class TestHierarchicalDecodeGfp:
    def test_every_subset_bit_exact(self, rng):
        hc = coding.HierarchicalCode(n1=2, n2=1, levels=2, omega=1.5,
                                     mode="gfp")
        A = rng.integers(0, 255, size=(16, 6)).astype(np.uint64)
        B = rng.integers(0, 255, size=(16, 3)).astype(np.uint64)
        exact = A.astype(np.int64).T @ B.astype(np.int64)
        for lvl in range(hc.levels):
            code = hc.level_code(lvl)
            X, Y = code.encode(A, B)
            tasks = code.compute_all_tasks(X, Y)
            for ids in itertools.combinations(range(code.num_tasks), hc.k):
                dec = hc.decode_level(lvl, list(ids), tasks[np.asarray(ids)])
                np.testing.assert_array_equal(np.asarray(dec), exact)


class TestHierarchicalProperties:
    """Hypothesis property block (skips without hypothesis installed)."""

    @hypothesis.given(st.integers(1, 3), st.integers(1, 2),
                      st.integers(2, 4),
                      st.floats(1.0, 2.0, allow_nan=False),
                      st.integers(0, 2 ** 16))
    @hypothesis.settings(max_examples=30, deadline=None)
    def test_any_level_any_subset_decodes(self, n1, n2, levels, omega,
                                          seed):
        rng = np.random.default_rng(seed)
        hc = coding.HierarchicalCode(n1=n1, n2=n2, levels=levels,
                                     omega=omega)
        A = rng.integers(-50, 50, size=(8, 4 * n1)).astype(np.float64)
        B = rng.integers(-50, 50, size=(8, 4 * n2)).astype(np.float64)
        exact = A.T @ B
        lvl = int(rng.integers(hc.levels))
        code = hc.level_code(lvl)
        prods = _all_task_products(code, A, B)
        ids = list(rng.choice(code.num_tasks, hc.k, replace=False))
        dec = np.asarray(hc.decode_level(lvl, ids, prods[np.asarray(ids)]))
        np.testing.assert_allclose(dec, exact, rtol=1e-7, atol=1e-5)
        # and the same subset, re-ordered, is bit-identical
        perm = [ids[i] for i in rng.permutation(len(ids))]
        dec2 = np.asarray(hc.decode_level(lvl, perm,
                                          prods[np.asarray(perm)]))
        assert dec.tobytes() == dec2.tobytes()

    @hypothesis.given(st.integers(2, 4), st.integers(1, 5),
                      st.integers(0, 2 ** 16))
    @hypothesis.settings(max_examples=30, deadline=None)
    def test_partial_level_never_corrupts_siblings(self, levels, short_by,
                                                   seed):
        """Post fewer than ``k`` results to one level of a fusion group:
        the starved level must not fuse, and every *other* level still
        decodes bit-correctly — partial arrivals are isolated."""
        rng = np.random.default_rng(seed)
        hc = coding.HierarchicalCode(n1=2, n2=2, levels=levels, omega=1.5)
        A = rng.integers(-50, 50, size=(8, 8)).astype(np.float64)
        B = rng.integers(-50, 50, size=(8, 8)).astype(np.float64)
        exact = A.T @ B
        starved = int(rng.integers(levels))
        fusion = FusionNode()
        ctxs = [RoundContext(job_id=0, round_idx=l) for l in range(levels)]
        rfs = fusion.begin_group(ctxs, hc.k)
        for lvl in range(levels):
            code = hc.level_code(lvl)
            prods = _all_task_products(code, A, B)
            n_post = (max(0, hc.k - short_by) if lvl == starved else hc.k)
            ids = rng.choice(code.num_tasks, hc.k, replace=False)[:n_post]
            for tid in ids:
                assert fusion.post(TaskResult(
                    job_id=0, round_idx=lvl, task_id=int(tid), worker_id=0,
                    value=prods[tid], finished_at=0.0))
        for lvl in range(levels):
            if lvl == starved:
                assert not rfs[lvl].wait(0.0)
                continue
            assert rfs[lvl].wait(0.0)
            dec = np.asarray(rfs[lvl].decode(hc.level_code(lvl)))
            np.testing.assert_allclose(dec, exact, rtol=1e-8, atol=1e-6)
        fusion.end_group()
        assert fusion.stale_results == 0


def _result(lvl, tid, value=None, worker=0):
    return TaskResult(job_id=0, round_idx=lvl, task_id=tid,
                      worker_id=worker,
                      value=(np.zeros((2, 2)) if value is None else value),
                      finished_at=0.0)


class TestGroupedFusionAccounting:
    """Salvage-ledger and stale-exactness regression tier (the sub-task
    accounting bugfix): dedupe/reconcile stays exact when a purged
    worker's late sub-task results arrive."""

    def _fused_group(self, hc, A, B):
        fusion = FusionNode()
        ctxs = [RoundContext(0, l) for l in range(hc.levels)]
        rfs = fusion.begin_group(ctxs, hc.k)
        prods = [_all_task_products(hc.level_code(l), A, B)
                 for l in range(hc.levels)]
        return fusion, ctxs, rfs, prods

    def test_salvage_counts_results_beyond_frontier(self, rng):
        hc = coding.HierarchicalCode(n1=2, n2=1, levels=2, omega=1.5)
        A = rng.normal(size=(8, 4))
        B = rng.normal(size=(8, 2))
        fusion, ctxs, rfs, prods = self._fused_group(hc, A, B)
        fusion.set_frontier(0)
        # two level-1 results land while the master waits on level 0:
        for tid in range(hc.k):
            assert fusion.post(_result(1, tid, prods[1][tid]))
        assert fusion.salvaged_subtasks == hc.k
        # level-0 results at the frontier are accepted but NOT salvage:
        for tid in range(hc.k):
            assert fusion.post(_result(0, tid, prods[0][tid]))
        assert fusion.subtask_results == 2 * hc.k
        assert fusion.salvaged_subtasks == hc.k
        assert rfs[0].wait(0.0) and rfs[1].wait(0.0)
        assert fusion.stale_results == 0

    def test_late_duplicate_subtask_is_stale_exactly_once(self, rng):
        """The re-dispatch race: a purged worker's last-gasp result for a
        task id the replacement already delivered must be dropped and
        counted exactly once — and never double-fuse the level."""
        hc = coding.HierarchicalCode(n1=2, n2=1, levels=2, omega=1.5)
        A = rng.normal(size=(8, 4))
        B = rng.normal(size=(8, 2))
        fusion, ctxs, rfs, prods = self._fused_group(hc, A, B)
        fusion.set_frontier(0)
        assert fusion.post(_result(0, 0, prods[0][0], worker=1))
        # the dead worker's duplicate of task 0 arrives late:
        assert not fusion.post(_result(0, 0, prods[0][0], worker=2))
        assert fusion.stale_results == 1
        assert fusion.subtask_results == 1          # accepted once only
        for tid in range(1, hc.k):
            assert fusion.post(_result(0, tid, prods[0][tid]))
        assert rfs[0].wait(0.0)
        # post k-th-plus-one to the fused level: stale again, exactly +1
        assert not fusion.post(_result(0, hc.k, prods[0][hc.k]))
        assert fusion.stale_results == 2

    def test_results_after_end_group_are_stale_exactly_once(self, rng):
        hc = coding.HierarchicalCode(n1=2, n2=1, levels=2, omega=1.5)
        A = rng.normal(size=(8, 4))
        B = rng.normal(size=(8, 2))
        fusion, ctxs, rfs, prods = self._fused_group(hc, A, B)
        for lvl in range(2):
            for tid in range(hc.k):
                assert fusion.post(_result(lvl, tid, prods[lvl][tid]))
        before = fusion.subtask_results
        fusion.end_group()
        # the purged straggler's late partials trickle in after close
        # (the value is never dereferenced on the reject path):
        for lvl in range(2):
            assert not fusion.post(_result(lvl, hc.k, prods[lvl][0]))
        assert fusion.stale_results == 2
        assert fusion.subtask_results == before     # ledger untouched

    def test_purged_level_results_stale_not_salvaged(self, rng):
        """A level cancelled mid-group (master purge) rejects its own
        late results without touching the salvage ledger."""
        hc = coding.HierarchicalCode(n1=2, n2=1, levels=2, omega=1.5)
        A = rng.normal(size=(8, 4))
        B = rng.normal(size=(8, 2))
        fusion, ctxs, rfs, prods = self._fused_group(hc, A, B)
        fusion.set_frontier(0)
        ctxs[1].purge()                 # deeper level cancelled
        assert not fusion.post(_result(1, 0, prods[1][0]))
        assert fusion.stale_results == 1
        assert fusion.salvaged_subtasks == 0
        # the frontier level is unaffected:
        for tid in range(hc.k):
            assert fusion.post(_result(0, tid, prods[0][tid]))
        assert rfs[0].wait(0.0)


class TestConfigSurface:
    def test_hier_config_round_trip(self):
        cfg = RuntimeConfig(mu=(1.0, 1.0, 1.0, 1.0), n1=2, n2=2,
                            omega=1.5, code_family="hierarchical", levels=2)
        hc = cfg.hier_code()
        assert isinstance(hc, coding.HierarchicalCode)
        assert hc.levels == 2 and hc.k == cfg.k

    def test_polynomial_rejects_levels(self):
        with pytest.raises(ValueError, match="levels"):
            RuntimeConfig(mu=(1.0,) * 4, levels=3)

    def test_hierarchical_requires_levels(self):
        with pytest.raises(ValueError, match="levels"):
            RuntimeConfig(mu=(1.0,) * 4, code_family="hierarchical",
                          levels=1)

    def test_hierarchical_rejects_forced_shm(self):
        with pytest.raises(ValueError, match="shm"):
            RuntimeConfig(mu=(1.0,) * 4, backend="process", shm="on",
                          code_family="hierarchical", levels=2)

    def test_unknown_family_rejected(self):
        with pytest.raises(ValueError, match="code family"):
            RuntimeConfig(mu=(1.0,) * 4, code_family="fountain")
