"""Deadline semantics of the §IV event simulator.

The paper's termination rule: a running job is cut at
``t_term = max(service_start + deadline, next_job_arrival)`` only when it
has not finished by then — so termination requires BOTH the compute time
to exceed the deadline AND a queued successor.
"""

import numpy as np
import pytest

from repro.core import simulator


def _cfg(**kw):
    base = dict(mu=(385.95, 650.92, 373.40, 415.75, 373.98),
                arrival_rate=0.01, k=1000, complexity=50.0, m=2,
                omega=1.06)
    base.update(kw)
    return simulator.SystemConfig(**base)


class TestTerminationRule:
    def test_deadline_excess_alone_does_not_terminate(self):
        """With arrivals so sparse that no successor is ever queued when a
        job overruns, a deadline far below the compute time terminates
        nothing: t_term = max(start + deadline, next_arrival) waits for
        the successor."""
        cfg = _cfg(arrival_rate=1e-6)   # interarrival ~1e6 >> service time
        res = simulator.simulate(cfg, 50, layered=True, deadline=1e-3,
                                 seed=0)
        assert res.layer_compute[:, -1].min() > 1e-3  # deadline IS exceeded
        assert not res.terminated.any()
        assert res.success.all()

    def test_queued_successor_alone_does_not_terminate(self):
        """A generous deadline never terminates, no matter how congested
        the queue is."""
        cfg = _cfg(arrival_rate=10.0)   # every job has a queued successor
        res = simulator.simulate(cfg, 50, layered=True, deadline=1e9,
                                 seed=0)
        assert not res.terminated.any()
        assert res.success.all()

    def test_both_conditions_terminate(self):
        cfg = _cfg(arrival_rate=10.0)
        res = simulator.simulate(cfg, 200, layered=True, deadline=1e-3,
                                 seed=0)
        assert res.terminated.any()

    def test_last_job_never_terminated(self):
        """No successor can ever queue behind the final job."""
        cfg = _cfg(arrival_rate=10.0)
        res = simulator.simulate(cfg, 100, layered=True, deadline=1e-3,
                                 seed=1)
        assert res.terminated[:-1].any()
        assert not res.terminated[-1]
        assert res.success[-1].all()

    def test_termination_at_next_arrival_not_before(self):
        """When the deadline expires before the successor arrives, the job
        keeps computing until the arrival: ends >= the successor's
        arrival time for every terminated job."""
        cfg = _cfg(arrival_rate=0.005)
        res = simulator.simulate(cfg, 300, layered=True, deadline=1.0,
                                 seed=2)
        term = np.flatnonzero(res.terminated)
        assert term.size > 0
        next_arrivals = res.arrivals[term + 1]   # last job never terminates
        assert np.all(res.ends[term] >= next_arrivals - 1e-9)
        assert np.all(res.ends[term] >= res.starts[term] + 1.0 - 1e-9)


class TestPaperRegime:
    def test_resolution0_success_rate_is_one(self):
        """Paper §IV regime (Fig. 3b working point): the deadline kills
        the final resolution for a visible fraction of jobs, yet the
        first resolution *always* arrives."""
        cfg = _cfg(omega=1.018)
        res = simulator.simulate(cfg, 2000, layered=True, deadline=10.0,
                                 seed=0)
        sr = res.success_rate()
        assert sr[0] == pytest.approx(1.0)
        assert sr[-1] < 1.0                      # deadline binds
        assert np.all(np.diff(sr) <= 1e-12)      # MSB-first monotone

    def test_layered_beats_unlayered_under_deadline(self):
        cfg = _cfg(omega=1.018)
        lay = simulator.simulate(cfg, 1000, layered=True, deadline=10.0,
                                 seed=0)
        unlay = simulator.simulate(cfg, 1000, layered=False, deadline=10.0,
                                   seed=0)
        assert lay.success_rate()[0] > unlay.success_rate()[0]

    def test_mean_delay_ordered_msb_first(self):
        cfg = _cfg()
        res = simulator.simulate(cfg, 1000, layered=True, seed=0)
        md = res.mean_delay()
        assert np.all(np.diff(md) > 0)
