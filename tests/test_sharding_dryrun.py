"""Sharding rules, spec fixing, HLO cost parser, and reduced-mesh lowering.

The production 512-device dry-run runs via ``python -m repro.launch.dryrun``
(it must own the XLA device-count flag); here we verify the same machinery
on 1-device meshes plus the spec/parser logic that the dry-run relies on.
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import registry
from repro.configs.base import ShapeConfig, TrainConfig
from repro.launch import sharding as sh
from repro.launch import steps as steps_lib
from repro.launch.hlo_costs import module_costs
from repro.launch.mesh import TPU_V5E, batch_axes, make_test_mesh
from repro.launch.roofline import RooflineReport, parse_collectives
from repro.models import transformer as T


class FakeMesh:
    """Mesh stand-in with arbitrary axis sizes (pure dict)."""

    def __init__(self, **axes):
        self.shape = axes
        self.axis_names = tuple(axes)


class TestFixSpec:
    def test_divisible_kept(self):
        mesh = FakeMesh(data=16, model=16)
        spec = sh.fix_spec((32, 4096, 32, 128), (None, "data", "model",
                                                 None), mesh)
        assert tuple(spec) == (None, "data", "model", None)

    def test_kv_heads_relocate_to_head_dim(self):
        mesh = FakeMesh(data=16, model=16)
        spec = sh.fix_spec((32, 4096, 8, 128), (None, "data", "model",
                                                None), mesh)
        assert tuple(spec) == (None, "data", None, "model")

    def test_drop_when_nothing_fits(self):
        mesh = FakeMesh(data=16, model=16)
        spec = sh.fix_spec((3, 5), ("data", "model"), mesh)
        assert tuple(spec) == (None, None)

    def test_batch_axes_tuple(self):
        mesh = FakeMesh(pod=2, data=16, model=16)
        spec = sh.fix_spec((256, 4096), (("pod", "data"), None), mesh)
        assert tuple(spec) == (("pod", "data"), None)

    def test_no_relocation_for_batch(self):
        mesh = FakeMesh(data=16, model=16)
        spec = sh.fix_spec((1, 524288), (("data",), None), mesh,
                           relocate=False)
        assert tuple(spec) == (None, None)


class TestParamSpecs:
    @pytest.mark.parametrize("arch", ["llama3-8b", "qwen2-moe-a2.7b",
                                      "mamba2-370m", "recurrentgemma-9b"])
    def test_every_spec_is_legal(self, arch):
        """On the production mesh shape, every param sharding divides."""
        mesh = FakeMesh(data=16, model=16)
        cfg = registry.get_config(arch)
        shapes = jax.eval_shape(
            functools.partial(T.init_params, cfg=cfg), jax.random.PRNGKey(0))
        specs = sh.param_specs(shapes, mesh)
        for leaf, spec in zip(jax.tree.leaves(shapes),
                              jax.tree.leaves(
                                  specs,
                                  is_leaf=lambda x: isinstance(x, P))):
            for dim, ax in zip(leaf.shape, tuple(spec)):
                if ax is None:
                    continue
                axes = ax if isinstance(ax, tuple) else (ax,)
                div = int(np.prod([mesh.shape[a] for a in axes]))
                assert dim % div == 0, (arch, leaf.shape, tuple(spec))

    def test_big_tensors_are_sharded(self):
        """No multi-GB parameter may end up fully replicated."""
        mesh = FakeMesh(data=16, model=16)
        cfg = registry.get_config("llama4-maverick-400b-a17b")
        shapes = jax.eval_shape(
            functools.partial(T.init_params, cfg=cfg), jax.random.PRNGKey(0))
        specs = sh.param_specs(shapes, mesh)
        for leaf, spec in zip(jax.tree.leaves(shapes),
                              jax.tree.leaves(
                                  specs,
                                  is_leaf=lambda x: isinstance(x, P))):
            nbytes = int(np.prod(leaf.shape)) * 4
            if nbytes > 1 << 30:
                assert any(ax is not None for ax in tuple(spec)), leaf.shape

    def test_memory_estimate_fits_hbm(self):
        """Params + optimizer state per device fit in 16 GB for the 400B
        MoE with Adafactor on the multi-pod mesh (the deployment claim)."""
        from repro.optim.optimizers import make_optimizer

        mesh = FakeMesh(pod=2, data=16, model=16)
        cfg = registry.get_config("llama4-maverick-400b-a17b")
        shapes = jax.eval_shape(
            functools.partial(T.init_params, cfg=cfg), jax.random.PRNGKey(0))
        pspecs = sh.param_specs(shapes, mesh)
        opt = make_optimizer(TrainConfig(optimizer="adafactor"))
        oshapes = jax.eval_shape(opt.init, shapes)
        ospecs = sh.opt_state_specs(oshapes, pspecs, mesh)
        total = (sh.spec_bytes_per_device(shapes, pspecs, mesh)
                 + sh.spec_bytes_per_device(oshapes, ospecs, mesh))
        assert total < 10 * 1024**3, f"{total/1e9:.1f} GB"


class TestHloCosts:
    def test_scan_trip_count_correction(self):
        def body(x, w):
            return x @ w, None

        def scanned(x, ws):
            return jax.lax.scan(body, x, ws)[0]

        x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
        ws = jax.ShapeDtypeStruct((6, 128, 128), jnp.float32)
        mc = module_costs(jax.jit(scanned).lower(x, ws).compile().as_text())
        assert mc.flops == pytest.approx(6 * 2 * 128**3, rel=1e-6)
        assert 6 in mc.trip_counts.values()

    def test_raw_cost_analysis_undercounts(self):
        """Documents the bug we correct: cost_analysis counts the body once."""
        def body(x, w):
            return x @ w, None

        def scanned(x, ws):
            return jax.lax.scan(body, x, ws)[0]

        x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
        ws = jax.ShapeDtypeStruct((6, 128, 128), jnp.float32)
        ca = jax.jit(scanned).lower(x, ws).compile().cost_analysis()
        if isinstance(ca, (list, tuple)):   # older jax wraps per-device
            ca = ca[0]
        # one body's worth of dot flops (+ a few scalar loop-carry adds),
        # nowhere near the 6x a trip-count-aware count reports
        assert ca["flops"] == pytest.approx(2 * 128**3, rel=1e-4)

    def test_collective_ring_model(self):
        txt = ('ENTRY %e (p: f32[16,16]) -> f32[16,16] {\n'
               '  %p = f32[16,16]{1,0} parameter(0)\n'
               '  ROOT %ar = f32[16,16]{1,0} all-reduce(%p), '
               'replica_groups={{0,1,2,3}}, to_apply=%add\n'
               '}\n')
        stats = parse_collectives(txt)
        want = 2 * 3 / 4 * 16 * 16 * 4
        assert stats.total_bytes == pytest.approx(want)
        assert stats.counts == {"all-reduce": 1}

    def test_roofline_terms_and_bound(self):
        rep = RooflineReport(
            arch="x", shape="train_4k", mesh="single", kind="train",
            chips=256, flops_per_device=197e12, bytes_per_device=819e9 / 2,
            collective_bytes=50e9 / 4, collective_counts={},
            peak_memory_per_device=None, model_flops=197e12 * 256 / 2)
        t = rep.terms(TPU_V5E)
        assert t["compute_s"] == pytest.approx(1.0)
        assert t["memory_s"] == pytest.approx(0.5)
        assert t["collective_s"] == pytest.approx(0.25)
        assert t["bound"] == "compute"
        assert t["roofline_fraction"] == pytest.approx(0.5)


class TestCellLowering:
    def test_train_cell_on_2_device_mesh_has_collectives(self):
        """Sharded lowering on a real (1x1) and data=1,model=1 mesh works;
        the 512-device production pass is exercised by launch/dryrun."""
        cfg = registry.get_smoke_config("llama3-8b")
        mesh = make_test_mesh(1, 1)
        cell = steps_lib.build_cell(cfg, ShapeConfig("t", 32, 2, "train"),
                                    mesh, TrainConfig())
        compiled = cell.lower().compile()
        mc = module_costs(compiled.as_text())
        assert mc.flops > 0

    @pytest.mark.parametrize("kind", ["train", "prefill", "decode"])
    def test_cell_kinds(self, kind):
        cfg = registry.get_smoke_config("yi-6b")
        mesh = make_test_mesh(1, 1)
        cell = steps_lib.build_cell(cfg, ShapeConfig("t", 32, 2, kind), mesh,
                                    TrainConfig())
        assert cell.kind == kind
        cell.lower().compile()

    def test_batch_axes(self):
        assert batch_axes(make_test_mesh(1, 1)) == ("data",)
