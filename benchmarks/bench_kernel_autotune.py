"""Block-size autotune sweep for the layered-matmul Pallas kernel.

Times ``layered_matmul_kernel_call`` over a small (bm, bn, bk) grid on a
given problem shape and reports the fastest legal configuration.  On TPU
the kernel runs compiled (Mosaic, megacore-parallel M/N grid); on CPU it
runs in interpret mode, where the sweep validates the BlockSpecs and the
relative block-count trade-offs rather than MXU throughput.

Run:  PYTHONPATH=src python benchmarks/bench_kernel_autotune.py \
          --m 2 --d 7 --K 1024 --M 256 --N 256 --repeats 3
"""

from __future__ import annotations

import argparse
import itertools
import json
import pathlib
import time

import jax
import numpy as np

from repro.kernels.layered_matmul import layered_matmul_kernel_call
from repro.kernels.ops import default_interpret

BM_SWEEP = (128, 256)
BN_SWEEP = (128, 256)
BK_SWEEP = (256, 512, 1024)


def candidate_blocks(M: int, N: int, K: int) -> list[tuple[int, int, int]]:
    """Legal (bm, bn, bk) triples: divisors of the problem dims."""
    bms = [b for b in BM_SWEEP if M % b == 0] or [M]
    bns = [b for b in BN_SWEEP if N % b == 0] or [N]
    bks = [b for b in BK_SWEEP if K % b == 0] or [K]
    return list(itertools.product(bms, bns, bks))


def time_config(pa, pb, *, m: int, d: int, bm: int, bn: int, bk: int,
                interpret: bool, repeats: int) -> float:
    """Median seconds per call (after one warm-up/compile call)."""
    call = lambda: layered_matmul_kernel_call(
        pa, pb, m=m, d=d, bm=bm, bn=bn, bk=bk,
        interpret=interpret).block_until_ready()
    call()
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        call()
        times.append(time.perf_counter() - t0)
    return float(np.median(times))


def sweep(*, m: int, d: int, K: int, M: int, N: int, repeats: int,
          interpret: bool, seed: int = 0) -> list[dict]:
    rng = np.random.default_rng(seed)
    hi = 1 << (d - 1)
    pa = jax.numpy.asarray(rng.integers(-hi, hi, size=(m, K, M)),
                           jax.numpy.int8)
    pb = jax.numpy.asarray(rng.integers(-hi, hi, size=(m, K, N)),
                           jax.numpy.int8)
    rows = []
    for bm, bn, bk in candidate_blocks(M, N, K):
        sec = time_config(pa, pb, m=m, d=d, bm=bm, bn=bn, bk=bk,
                          interpret=interpret, repeats=repeats)
        rows.append({"bm": bm, "bn": bn, "bk": bk,
                     "grid": [M // bm, N // bn, K // bk],
                     "seconds": sec})
        print(f"  bm={bm:>4} bn={bn:>4} bk={bk:>5}  "
              f"grid={M // bm}x{N // bn}x{K // bk}  {sec * 1e3:9.3f} ms")
    rows.sort(key=lambda r: r["seconds"])
    return rows


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--m", type=int, default=2)
    ap.add_argument("--d", type=int, default=7)
    ap.add_argument("--K", type=int, default=1024)
    ap.add_argument("--M", type=int, default=256)
    ap.add_argument("--N", type=int, default=256)
    ap.add_argument("--repeats", type=int, default=3)
    ap.add_argument("--compiled", action="store_true",
                    help="force compiled mode even off-TPU")
    ap.add_argument("--json", default=None, help="write sweep rows here")
    args = ap.parse_args(argv)

    interpret = default_interpret() and not args.compiled
    mode = "interpret" if interpret else "compiled"
    print(f"layered_matmul autotune ({mode}): m={args.m} d={args.d} "
          f"K={args.K} M={args.M} N={args.N}")
    rows = sweep(m=args.m, d=args.d, K=args.K, M=args.M, N=args.N,
                 repeats=args.repeats, interpret=interpret)
    best = rows[0]
    print(f"best: bm={best['bm']} bn={best['bn']} bk={best['bk']} "
          f"({best['seconds'] * 1e3:.3f} ms)")
    if args.json:
        path = pathlib.Path(args.json)
        path.write_text(json.dumps(
            {"bench": "layered_matmul_autotune", "mode": mode,
             "shape": {"m": args.m, "d": args.d, "K": args.K, "M": args.M,
                       "N": args.N},
             "rows": rows}, indent=2))
        print(f"wrote {path}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
